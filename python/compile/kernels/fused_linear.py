"""L1 Pallas kernel: fused dense update  Y = act(X @ W + b).

This is the FLOPs hot spot of every GNN layer in the paper (the *Update*
step, Table I): for the evaluated graphs V·F·H dominates the E·F aggregation
cost, and on TPU it is the part that maps onto the MXU systolic array.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper runs PyG's fused
CPU kernels; here the update is expressed as a blocked matmul with
(BM, BN, BK) tiles (defaults below, chosen by the §Perf tile sweep) —
an f32 accumulator tile stays resident in VMEM across the K loop and the
bias add + nonlinearity are fused into the epilogue so the activation
tile never round-trips to HBM between matmul and activation.

`interpret=True` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO through the pallas
interpreter.  Real-TPU efficiency is estimated analytically (DESIGN.md
§Perf): per-step VMEM footprint via `vmem_footprint_bytes` (112 KiB at
the default tile, ≪16 MiB) and padding efficiency via
`mxu_utilization_estimate`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Activation codes shared with the model layer configs.
ACT_NONE = 0
ACT_RELU = 1
ACT_ELU = 2
ACT_LEAKY_RELU = 3

# Default tile, chosen by the §Perf tile sweep (EXPERIMENTS.md): GNN
# update shapes have N = hidden = 64 and K = 32..100, so a square 128^3
# tile would pad N/K heavily (MXU utilization 0.20); (128, 64, 64) hits
# 0.81 at 112 KiB VMEM per step. Still MXU-aligned (the 128x128 systolic
# array consumes 64-wide tiles at full rate via double pumping).
DEFAULT_BM = 128
DEFAULT_BN = 64
DEFAULT_BK = 64


def _apply_act(y, act: int):
    if act == ACT_RELU:
        return jnp.maximum(y, 0.0)
    if act == ACT_ELU:
        return jnp.where(y > 0, y, jnp.expm1(y))
    if act == ACT_LEAKY_RELU:
        return jnp.where(y > 0, y, 0.2 * y)
    return y


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, act: int, nk: int):
    """Grid = (M/BM, N/BN, K/BK); K is the innermost (minor) grid axis so
    the output tile stays resident in VMEM and is revisited across the K
    loop (the canonical pallas accumulate-in-output matmul pattern)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...]
        o_ref[...] = _apply_act(y, act).astype(o_ref.dtype)


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit, static_argnames=("act", "bm", "bn", "bk", "interpret")
)
def fused_linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    act: int = ACT_NONE,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """act(x @ w + b) via the blocked Pallas kernel.

    x: [M, K], w: [K, N], b: [N] -> [M, N]. Arbitrary shapes are padded up
    to tile multiples and the result sliced back, so callers never see the
    tiling.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert b.shape == (n,), (b.shape, n)

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    bp = _pad_to(b.reshape(1, n), bn, 1)
    mp, kp = xp.shape
    _, np_ = wp.shape
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, act=act, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


def vmem_footprint_bytes(bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                         bk: int = DEFAULT_BK, dtype_bytes: int = 4) -> int:
    """Analytic per-step VMEM footprint for the §Perf estimate."""
    return dtype_bytes * (bm * bk + bk * bn + 2 * bm * bn + bn)


def mxu_utilization_estimate(m: int, n: int, k: int,
                             bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                             bk: int = DEFAULT_BK) -> float:
    """Fraction of MXU-issued MACs that are useful (non-padding)."""
    import math

    mp = math.ceil(m / bm) * bm
    np_ = math.ceil(n / bn) * bn
    kp = math.ceil(k / bk) * bk
    return (m * n * k) / (mp * np_ * kp)
