"""Pure-jnp oracles for the Pallas kernels and the GNN layer math.

These are the correctness ground truth: pytest asserts the Pallas kernels
(and through them the AOT-lowered HLO the Rust runtime executes) match
these to float tolerance.  Training (train.py) also uses these — identical
math, friendlier autodiff than interpreter-mode pallas.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .fused_linear import ACT_ELU, ACT_LEAKY_RELU, ACT_NONE, ACT_RELU


def apply_act(y: jax.Array, act: int) -> jax.Array:
    if act == ACT_RELU:
        return jnp.maximum(y, 0.0)
    if act == ACT_ELU:
        return jnp.where(y > 0, y, jnp.expm1(y))
    if act == ACT_LEAKY_RELU:
        return jnp.where(y > 0, y, 0.2 * y)
    assert act == ACT_NONE
    return y


def fused_linear_ref(x, w, b, act: int = ACT_NONE) -> jax.Array:
    return apply_act(x @ w + b, act)


def scale_combine_ref(agg, h, scale, mode: int = 0) -> jax.Array:
    if mode == 0:
        return (agg + h) * scale
    return agg * scale


def segment_aggregate(h, src, dst, ew, num_vertices: int) -> jax.Array:
    """Sum_{(u,v) in E} ew_e * h_u scattered into row v.

    Padding edges carry ew == 0 (and point at vertex 0), so they contribute
    nothing — this is the static-shape TPU formulation of neighbor
    aggregation (DESIGN.md §Hardware-Adaptation).
    """
    msgs = h[src] * ew[:, None]
    return jax.ops.segment_sum(msgs, dst, num_segments=num_vertices)


def segment_softmax(logits, dst, ew, num_vertices: int) -> jax.Array:
    """Numerically-stable per-destination softmax over edges; padding edges
    (ew == 0) are excluded and receive weight 0."""
    neg = jnp.asarray(-1e30, logits.dtype)
    masked = jnp.where(ew > 0, logits, neg)
    seg_max = jax.ops.segment_max(masked, dst, num_segments=num_vertices)
    seg_max = jnp.where(seg_max > -1e29, seg_max, 0.0)
    ex = jnp.where(ew > 0, jnp.exp(masked - seg_max[dst]), 0.0)
    denom = jax.ops.segment_sum(ex, dst, num_segments=num_vertices)
    return ex / jnp.maximum(denom[dst], 1e-16)
