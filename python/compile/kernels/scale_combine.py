"""L1 Pallas kernel: elementwise aggregate/self combine with degree scaling.

GCN's pre-update combine (Table I):   c_v = (a_v + h_v) / (|N_v| + 1)
GraphSAGE's mean normalization:       c_v = a_v / max(|N_v|, 1)

Both are row-scaled elementwise merges of the aggregation output `agg`
[V, F] with the residual activations `h` [V, F] by a per-vertex scale
[V, 1].  On TPU this is VPU work; blocking it (BV, F) keeps each tile in
VMEM and lets XLA fuse the dequantized input straight into the first
layer's combine.  interpret=True as everywhere (see fused_linear.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BV = 256

COMBINE_ADD_SELF = 0  # (agg + h) * scale      (GCN)
COMBINE_AGG_ONLY = 1  # agg * scale            (SAGE mean)


def _combine_kernel(agg_ref, h_ref, scale_ref, o_ref, *, mode: int):
    agg = agg_ref[...]
    s = scale_ref[...]
    if mode == COMBINE_ADD_SELF:
        o_ref[...] = (agg + h_ref[...]) * s
    else:
        o_ref[...] = agg * s


@functools.partial(jax.jit, static_argnames=("mode", "bv", "interpret"))
def scale_combine(
    agg: jax.Array,
    h: jax.Array,
    scale: jax.Array,
    mode: int = COMBINE_ADD_SELF,
    bv: int = DEFAULT_BV,
    interpret: bool = True,
) -> jax.Array:
    """Blocked (agg [V,F], h [V,F], scale [V,1]) -> [V,F] combine."""
    v, f = agg.shape
    assert h.shape == (v, f)
    assert scale.shape == (v, 1), scale.shape

    rem = (-v) % bv
    if rem:
        pad = ((0, rem), (0, 0))
        agg = jnp.pad(agg, pad)
        h = jnp.pad(h, pad)
        scale = jnp.pad(scale, pad)
    vp = agg.shape[0]

    out = pl.pallas_call(
        functools.partial(_combine_kernel, mode=mode),
        grid=(vp // bv,),
        in_specs=[
            pl.BlockSpec((bv, f), lambda i: (i, 0)),
            pl.BlockSpec((bv, f), lambda i: (i, 0)),
            pl.BlockSpec((bv, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bv, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((vp, f), agg.dtype),
        interpret=interpret,
    )(agg, h, scale)
    return out[:v]
