"""Graph -> model-input preparation (python twin of rust/src/runtime/pad.rs).

Turns a loaded .fgr Graph into the (h, src, dst, ew, inv_deg) arrays the
layer functions consume, with the exact model-specific conventions the Rust
runtime also implements:

- gcn:   no self loops; inv_deg = 1 / (deg_in + 1)
- sage:  no self loops; inv_deg = 1 / max(deg_in, 1)
- gat:   self loops appended AFTER the real edges; inv_deg all-ones (unused)
- astgcn: dense row-normalized D^-1 (A + I) adjacency block
"""

from __future__ import annotations

import numpy as np

from .fgio import Graph


def edge_arrays(g: Graph, model: str):
    src, dst = g.edge_list()
    v = g.num_vertices
    # in-degree (CSR here is symmetric for our datasets, but be exact)
    deg_in = np.bincount(dst, minlength=v).astype(np.float32)
    if model == "gat":
        loops = np.arange(v, dtype=np.int32)
        src = np.concatenate([src, loops])
        dst = np.concatenate([dst, loops])
        ew = np.ones(len(src), np.float32)
        inv_deg = np.ones((v, 1), np.float32)
    elif model == "gcn":
        ew = np.ones(len(src), np.float32)
        inv_deg = (1.0 / (deg_in + 1.0)).reshape(v, 1)
    elif model == "sage":
        ew = np.ones(len(src), np.float32)
        inv_deg = (1.0 / np.maximum(deg_in, 1.0)).reshape(v, 1)
    else:
        raise ValueError(model)
    return src.astype(np.int32), dst.astype(np.int32), ew, inv_deg


def dense_norm_adj(g: Graph) -> np.ndarray:
    """Row-normalized D^-1 (A + I) as dense f32 (astgcn)."""
    v = g.num_vertices
    a = np.zeros((v, v), np.float32)
    src, dst = g.edge_list()
    a[dst, src] = 1.0
    a[np.arange(v), np.arange(v)] = 1.0
    rowsum = a.sum(axis=1, keepdims=True)
    return a / np.maximum(rowsum, 1.0)


def pems_windows(g: Graph, window: int, horizon: int,
                 stride: int = 3):
    """Slide (input-window, target-horizon) pairs over the stored series.

    features [V, F, T]; channel 0 is flow (the forecast target).
    Returns (xs [N, V, F*window], ys [N, V, horizon], mean, std) with xs
    standardized per channel and ys in ORIGINAL units.
    """
    v, f, t = g.features.shape
    mean = g.features.mean(axis=(0, 2))  # [F]
    std = g.features.std(axis=(0, 2)) + 1e-6
    norm = (g.features - mean[None, :, None]) / std[None, :, None]
    xs, ys = [], []
    for s in range(0, t - window - horizon + 1, stride):
        xw = norm[:, :, s:s + window].reshape(v, f * window)
        yw = g.features[:, 0, s + window:s + window + horizon]
        xs.append(xw.astype(np.float32))
        ys.append(yw.astype(np.float32))
    return (np.stack(xs), np.stack(ys),
            mean.astype(np.float32), std.astype(np.float32))


def train_test_split(v: int, train_frac: float = 0.7):
    """Deterministic index split (matches rust serving/accuracy.rs)."""
    idx = np.arange(v)
    train = (idx * 2654435761 % 4294967296) % 1000 < int(train_frac * 1000)
    return idx[train], idx[~train]
