"""AOT lowering: every (model, dataset, bucket, layer) -> HLO text artifact.

HLO *text* (not `.serialize()`) is the interchange format: the `xla` crate's
xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction ids); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs under --out-dir (default ../artifacts):
    <name>.hlo.txt          per-layer HLO modules
    manifest.json           index the Rust runtime loads

Input order of every lowered module = params ++ data (see models/common.py).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax

from . import specs
from .models import REGISTRY
from .models.common import shape_structs


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_layer(ld) -> str:
    args = shape_structs(ld.param_spec) + shape_structs(ld.data_spec)
    # keep_unused: every module keeps the full calling convention even when
    # a model ignores an input (e.g. GAT's inv_deg), so the Rust runtime
    # can feed all artifacts identically.
    return to_hlo_text(jax.jit(ld.fn, keep_unused=True).lower(*args))


def artifact_name(model: str, dataset: str, frac: int, layer: int) -> str:
    return f"{model}_{dataset}_f{frac}_l{layer}"


def build_all(out_dir: str, only: str | None = None,
              verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"artifacts": [], "format": 1}
    for model_name, ds_name in specs.PAIRS:
        if only and only not in (model_name, ds_name,
                                 f"{model_name}:{ds_name}"):
            continue
        ds = specs.DATASETS[ds_name]
        ms = specs.MODELS[model_name]
        mod = REGISTRY[model_name]
        f_in = ds.input_dim
        classes = max(ds.classes, 1)
        for frac, v_max, e_max, l_max in specs.buckets_for(ds):
            lds = mod.layers(f_in, ms.hidden, classes, v_max, e_max,
                             num_layers=ms.layers, use_kernels=True,
                             l=l_max)
            for ld in lds:
                name = artifact_name(model_name, ds_name, frac, ld.index)
                path = os.path.join(out_dir, name + ".hlo.txt")
                text = lower_layer(ld)
                with open(path, "w") as f:
                    f.write(text)
                entry = {
                    "name": name,
                    "path": name + ".hlo.txt",
                    "model": model_name,
                    "dataset": ds_name,
                    "frac": frac,
                    "layer": ld.index,
                    "num_layers": ms.layers,
                    "v_max": v_max,
                    "e_max": e_max,
                    "l_max": l_max,
                    "out_dim": ld.out_dim,
                    "params": [[t.name, list(t.shape), t.dtype]
                               for t in ld.param_spec],
                    "data": [[t.name, list(t.shape), t.dtype]
                             for t in ld.data_spec],
                    "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                }
                manifest["artifacts"].append(entry)
                if verbose:
                    print(f"  lowered {name}  (V={v_max} E={e_max} "
                          f"{len(text)//1024} KiB)", flush=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None,
                    help="restrict to a model, dataset, or model:dataset")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    manifest = build_all(args.out_dir, args.only, verbose=not args.quiet)
    mpath = os.path.join(args.out_dir, "manifest.json")
    # Merge with an existing manifest when --only rebuilt a subset.
    if args.only and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        keep = [a for a in old.get("artifacts", [])
                if a["name"] not in {x["name"] for x in manifest["artifacts"]}]
        manifest["artifacts"] = keep + manifest["artifacts"]
    manifest["artifacts"].sort(key=lambda a: a["name"])
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest to "
          f"{os.path.abspath(args.out_dir)}")


if __name__ == "__main__":
    main()
