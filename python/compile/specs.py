"""Dataset + model + artifact-bucket specifications.

These constants are the contract between the Python compile path and the
Rust runtime: the Rust dataset generators (rust/src/graph/datasets.rs)
produce graphs with exactly these vertex/edge counts and feature shapes
(Table III of the paper), and the Rust runtime picks the smallest lowered
bucket that fits a partition.  Edge counts are *undirected*; the CSR both
sides use stores each edge in both directions (e_dir = 2·E).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    vertices: int
    edges: int          # undirected edge count (Table III)
    feature_dim: int
    classes: int        # 0 => regression (PeMS)
    duration: int = 1   # timesteps stored in the .fgr feature series
    window: int = 1     # timesteps per inference input window
    seed: int = 7

    @property
    def directed_edges(self) -> int:
        return 2 * self.edges

    @property
    def input_dim(self) -> int:
        """Flattened per-vertex feature dim of one inference input."""
        return self.feature_dim * self.window


# Table III.
DATASETS: dict[str, DatasetSpec] = {
    "siot": DatasetSpec("siot", 16216, 146117, 52, 2, seed=11),
    "yelp": DatasetSpec("yelp", 10000, 15683, 100, 2, seed=13),
    # 7 days of 5-minute readings stored; each inference consumes a
    # 12-step window and forecasts the next 12 steps (one hour).
    "pems": DatasetSpec("pems", 307, 340, 3, 0, duration=2016, window=12,
                        seed=17),
    "rmat20k": DatasetSpec("rmat20k", 20_000, 199_000, 32, 8, seed=21),
    "rmat40k": DatasetSpec("rmat40k", 40_000, 799_000, 32, 8, seed=22),
    "rmat60k": DatasetSpec("rmat60k", 60_000, 1_790_000, 32, 8, seed=23),
    "rmat80k": DatasetSpec("rmat80k", 80_000, 3_190_000, 32, 8, seed=24),
    "rmat100k": DatasetSpec("rmat100k", 100_000, 4_990_000, 32, 8, seed=25),
}


@dataclass(frozen=True)
class ModelSpec:
    name: str
    hidden: int = 64
    layers: int = 2


MODELS: dict[str, ModelSpec] = {
    "gcn": ModelSpec("gcn"),
    "gat": ModelSpec("gat"),
    "sage": ModelSpec("sage"),
    "astgcn": ModelSpec("astgcn", hidden=64, layers=1),
}

# Which (model, dataset) pairs get artifacts + trained weights.
PAIRS: list[tuple[str, str]] = (
    [(m, d) for m in ("gcn", "gat", "sage") for d in ("siot", "yelp")]
    + [("gcn", d) for d in ("rmat20k", "rmat40k", "rmat60k",
                            "rmat80k", "rmat100k")]
    + [("astgcn", "pems")]
)

# Partition-size bucket denominators: a `frac=d` bucket is sized for one
# d-th of the graph plus halo margin.  Rust picks the smallest fitting one.
BUCKET_FRACS: tuple[int, ...] = (1, 2, 3, 4, 6, 8)

# Halo vertices are numerous on social graphs but cost only zero-padded
# rows (cheap memcpy); edges drive the XLA scatter cost. So v_max is
# generous and e_max tight.
V_HALO_MARGIN = 3.5
E_MARGIN = 1.25
V_ROUND = 256
E_ROUND = 1024


def _ceil_mult(x: float, m: int) -> int:
    from math import ceil
    return int(ceil(x / m)) * m


L_MARGIN = 1.10    # owned rows exceed |V|/frac slightly under imbalance


def bucket_dims(ds: DatasetSpec, frac: int,
                self_loops: bool = True) -> tuple[int, int, int]:
    """(v_max, e_max, l_max) of the artifact bucket for 1/frac of `ds`.

    v_max covers locals + halo; l_max covers owned (local) rows only —
    the update matmul runs over l_max rows so distributed execution does
    not pay for halo rows (DESIGN.md §Hardware-Adaptation).
    """
    v_full = _ceil_mult(ds.vertices + 1, V_ROUND)
    e_full = _ceil_mult(ds.directed_edges + (ds.vertices if self_loops else 0)
                        + 1, E_ROUND)
    if frac == 1:
        return v_full, e_full, v_full
    v = min(v_full, _ceil_mult(ds.vertices / frac * V_HALO_MARGIN, V_ROUND))
    e = min(e_full, _ceil_mult((ds.directed_edges / frac * E_MARGIN)
                               + (v if self_loops else 0), E_ROUND))
    l = min(v, _ceil_mult(ds.vertices / frac * L_MARGIN, 128))
    return v, e, l


def buckets_for(ds: DatasetSpec) -> list[tuple[int, int, int, int]]:
    """Deduplicated (frac, v_max, e_max, l_max) list, largest first."""
    seen: set[tuple[int, int, int]] = set()
    out = []
    for frac in BUCKET_FRACS:
        v, e, l = bucket_dims(ds, frac)
        if (v, e, l) in seen:
            continue
        seen.add((v, e, l))
        out.append((frac, v, e, l))
    return out
