"""Binary interchange formats shared with the Rust side.

.fgr — graph container (written by `repro dataset`, read here for training):
    magic  b"FGR1"
    u32    num_vertices V
    u64    num_edges    E           (directed edge count, CSR)
    u32    feature_dim  F
    u32    num_classes  C           (0 => regression targets)
    u32    duration     T           (timesteps per feature; 1 for static)
    u32    flags        bit0: has labels, bit1: has coords, bit2: has targets
    u64[V+1]  indptr    (CSR row pointers, out-edges)
    u32[E]    indices   (CSR column indices)
    f32[V*F*T] features (vertex-major, then feature, then time)
    i32[V]    labels    (if flag bit0)
    f32[V*2]  coords    (if flag bit1)
    f32[V*T_out]  targets (if flag bit2; T_out stored as u32 before data)

.fgw — named tensor bundle (weights; written here, read by rust/runtime):
    magic  b"FGW1"
    u32    n_tensors
    per tensor:
      u16   name_len, name (utf-8)
      u8    dtype (0 = f32, 1 = i32)
      u8    ndim
      u64[ndim] dims
      data  (little-endian, contiguous)

All integers little-endian.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field

import numpy as np

FGR_MAGIC = b"FGR1"
FGW_MAGIC = b"FGW1"


@dataclass
class Graph:
    """A loaded .fgr graph."""

    indptr: np.ndarray  # u64 [V+1]
    indices: np.ndarray  # u32 [E]
    features: np.ndarray  # f32 [V, F] or [V, F, T]
    labels: np.ndarray | None = None  # i32 [V]
    coords: np.ndarray | None = None  # f32 [V, 2]
    targets: np.ndarray | None = None  # f32 [V, T_out]
    num_classes: int = 0
    duration: int = 1

    @property
    def num_vertices(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return int(self.indptr[-1])

    @property
    def feature_dim(self) -> int:
        return self.features.shape[1]

    def degrees(self) -> np.ndarray:
        return (self.indptr[1:] - self.indptr[:-1]).astype(np.int64)

    def edge_list(self) -> tuple[np.ndarray, np.ndarray]:
        """COO (src, dst) arrays from the CSR out-edge structure."""
        deg = self.degrees()
        src = np.repeat(np.arange(self.num_vertices, dtype=np.int32), deg)
        dst = self.indices.astype(np.int32)
        return src, dst


def read_fgr(path: str) -> Graph:
    with open(path, "rb") as f:
        buf = f.read()
    if buf[:4] != FGR_MAGIC:
        raise ValueError(f"{path}: bad magic {buf[:4]!r}")
    off = 4
    v, = struct.unpack_from("<I", buf, off); off += 4
    e, = struct.unpack_from("<Q", buf, off); off += 8
    fdim, = struct.unpack_from("<I", buf, off); off += 4
    classes, = struct.unpack_from("<I", buf, off); off += 4
    dur, = struct.unpack_from("<I", buf, off); off += 4
    flags, = struct.unpack_from("<I", buf, off); off += 4

    def take(dtype, count):
        nonlocal off
        arr = np.frombuffer(buf, dtype=dtype, count=count, offset=off)
        off += arr.nbytes
        return arr.copy()

    indptr = take("<u8", v + 1)
    indices = take("<u4", e)
    feats = take("<f4", v * fdim * dur)
    features = feats.reshape(v, fdim, dur) if dur > 1 else feats.reshape(v, fdim)
    g = Graph(indptr=indptr, indices=indices, features=features,
              num_classes=classes, duration=dur)
    if flags & 1:
        g.labels = take("<i4", v)
    if flags & 2:
        g.coords = take("<f4", v * 2).reshape(v, 2)
    if flags & 4:
        t_out, = struct.unpack_from("<I", buf, off); off += 4
        g.targets = take("<f4", v * t_out).reshape(v, t_out)
    assert off == len(buf), f"{path}: {len(buf) - off} trailing bytes"
    return g


def write_fgr(path: str, g: Graph) -> None:
    """Mainly for tests; the Rust generator is the production writer."""
    v = g.num_vertices
    dur = g.duration
    flags = (1 if g.labels is not None else 0) \
        | (2 if g.coords is not None else 0) \
        | (4 if g.targets is not None else 0)
    with open(path, "wb") as f:
        f.write(FGR_MAGIC)
        f.write(struct.pack("<IQIIII", v, g.num_edges,
                            g.feature_dim, g.num_classes, dur, flags))
        f.write(g.indptr.astype("<u8").tobytes())
        f.write(g.indices.astype("<u4").tobytes())
        f.write(g.features.astype("<f4").tobytes())
        if g.labels is not None:
            f.write(g.labels.astype("<i4").tobytes())
        if g.coords is not None:
            f.write(g.coords.astype("<f4").tobytes())
        if g.targets is not None:
            f.write(struct.pack("<I", g.targets.shape[1]))
            f.write(g.targets.astype("<f4").tobytes())


def write_fgw(path: str, tensors: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(FGW_MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            if arr.dtype in (np.float32, np.dtype("<f4")):
                dt = 0
                data = arr.astype("<f4")
            elif arr.dtype in (np.int32, np.dtype("<i4")):
                dt = 1
                data = arr.astype("<i4")
            else:
                raise ValueError(f"unsupported dtype {arr.dtype} for {name}")
            f.write(struct.pack("<BB", dt, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(np.ascontiguousarray(data).tobytes())


def read_fgw(path: str) -> list[tuple[str, np.ndarray]]:
    with open(path, "rb") as f:
        buf = f.read()
    if buf[:4] != FGW_MAGIC:
        raise ValueError(f"{path}: bad magic")
    off = 4
    n, = struct.unpack_from("<I", buf, off); off += 4
    out = []
    for _ in range(n):
        ln, = struct.unpack_from("<H", buf, off); off += 2
        name = buf[off:off + ln].decode("utf-8"); off += ln
        dt, ndim = struct.unpack_from("<BB", buf, off); off += 2
        dims = struct.unpack_from(f"<{ndim}Q", buf, off); off += 8 * ndim
        count = int(np.prod(dims)) if ndim else 1
        dtype = "<f4" if dt == 0 else "<i4"
        arr = np.frombuffer(buf, dtype=dtype, count=count, offset=off).copy()
        off += arr.nbytes
        out.append((name, arr.reshape(dims)))
    return out
