"""GraphSAGE (Hamilton et al.), mean-aggregate — Table I of the paper:

    a_v = (1/|N_v|) sum_{u in N_v} h_u
    h_v = sigma( W · concat(a_v, h_v) )

`inv_deg` carries 1 / max(deg_in, 1); no self loops in the edge list.
Hidden layers use ReLU, the output layer is linear.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..kernels import ref
from ..kernels.fused_linear import ACT_NONE, ACT_RELU, fused_linear
from ..kernels.scale_combine import COMBINE_AGG_ONLY, scale_combine
from .common import LayerDef, TensorSpec, edge_data_spec, glorot
from .gcn import layer_dims


def _layer_fn(act: int, use_kernels: bool):
    def fn(w, b, h, src, dst, ew, inv_deg):
        # owned rows only (see gcn.py)
        l = inv_deg.shape[0]
        agg = ref.segment_aggregate(h, src, dst, ew, l)
        h_loc = h[:l]
        if use_kernels:
            mean = scale_combine(agg, h_loc, inv_deg,
                                 mode=COMBINE_AGG_ONLY)
            comb = jnp.concatenate([mean, h_loc], axis=1)
            return fused_linear(comb, w, b, act=act)
        mean = ref.scale_combine_ref(agg, h_loc, inv_deg,
                                     mode=COMBINE_AGG_ONLY)
        comb = jnp.concatenate([mean, h_loc], axis=1)
        return ref.fused_linear_ref(comb, w, b, act=act)

    return fn


def layers(f_in: int, hidden: int, classes: int, v: int, e: int,
           num_layers: int = 2, use_kernels: bool = True,
           l: int | None = None) -> list[LayerDef]:
    out = []
    dims = layer_dims(f_in, hidden, classes, num_layers)
    for i, (fi, fo) in enumerate(dims):
        act = ACT_NONE if i == num_layers - 1 else ACT_RELU
        out.append(LayerDef(
            index=i,
            fn=_layer_fn(act, use_kernels),
            param_spec=[TensorSpec("w", (2 * fi, fo)),
                        TensorSpec("b", (fo,))],
            data_spec=edge_data_spec(v, e, fi, l),
            out_dim=fo,
        ))
    return out


def init_params(rng: np.random.Generator, f_in: int, hidden: int,
                classes: int, num_layers: int = 2):
    params = []
    for fi, fo in layer_dims(f_in, hidden, classes, num_layers):
        params.append([glorot(rng, (2 * fi, fo)), np.zeros(fo, np.float32)])
    return params


def forward(params, h, src, dst, ew, inv_deg, use_kernels: bool = False):
    n = len(params)
    lds = layers(h.shape[1], params[0][0].shape[1] if n > 1 else 0,
                 params[-1][0].shape[1], h.shape[0], src.shape[0],
                 num_layers=n, use_kernels=use_kernels)
    for ld, p in zip(lds, params):
        h = ld.fn(*p, h, src, dst, ew, inv_deg)
    return h
