"""GAT (Velickovic et al.) — Table I of the paper, single attention head:

    a_v = sum_{u in N_v ∪ {v}} alpha_vu · W h_u
    h_v = sigma(a_v)

with alpha the learned attention, at inference computed as
softmax_u( LeakyReLU( a_s · (W h_v) + a_d · (W h_u) ) ) over v's in-edges.
The edge list is expected to INCLUDE self loops (Rust prep adds them);
`inv_deg` is unused but kept so every model shares one calling convention.
Hidden layers use ELU, the output layer is linear.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..kernels import ref
from ..kernels.fused_linear import (ACT_ELU, ACT_LEAKY_RELU, ACT_NONE,
                                    fused_linear)
from .common import LayerDef, TensorSpec, edge_data_spec, glorot
from .gcn import layer_dims


def _layer_fn(act: int, use_kernels: bool):
    def fn(w, b, a_src, a_dst, h, src, dst, ew, inv_deg):
        # z covers ALL rows (halo sources feed the attention), but the
        # softmax-aggregate lands on the owned rows [0, l) only.
        l = inv_deg.shape[0]
        if use_kernels:
            z = fused_linear(h, w, b, act=ACT_NONE)
        else:
            z = ref.fused_linear_ref(h, w, b, act=ACT_NONE)
        es = z @ a_src  # [V]
        ed = z @ a_dst  # [V]
        logits = ref.apply_act(es[src] + ed[dst], ACT_LEAKY_RELU)
        alpha = ref.segment_softmax(logits, dst, ew, l)
        agg = ref.segment_aggregate(z, src, dst, alpha, l)
        return ref.apply_act(agg, act)

    return fn


def layers(f_in: int, hidden: int, classes: int, v: int, e: int,
           num_layers: int = 2, use_kernels: bool = True,
           l: int | None = None) -> list[LayerDef]:
    out = []
    dims = layer_dims(f_in, hidden, classes, num_layers)
    for i, (fi, fo) in enumerate(dims):
        act = ACT_NONE if i == num_layers - 1 else ACT_ELU
        out.append(LayerDef(
            index=i,
            fn=_layer_fn(act, use_kernels),
            param_spec=[
                TensorSpec("w", (fi, fo)),
                TensorSpec("b", (fo,)),
                TensorSpec("a_src", (fo,)),
                TensorSpec("a_dst", (fo,)),
            ],
            data_spec=edge_data_spec(v, e, fi, l),
            out_dim=fo,
        ))
    return out


def init_params(rng: np.random.Generator, f_in: int, hidden: int,
                classes: int, num_layers: int = 2):
    params = []
    for fi, fo in layer_dims(f_in, hidden, classes, num_layers):
        params.append([
            glorot(rng, (fi, fo)),
            np.zeros(fo, np.float32),
            (0.1 * glorot(rng, (fo, 1))[:, 0]).astype(np.float32),
            (0.1 * glorot(rng, (fo, 1))[:, 0]).astype(np.float32),
        ])
    return params


def forward(params, h, src, dst, ew, inv_deg, use_kernels: bool = False):
    n = len(params)
    lds = layers(h.shape[1], params[0][0].shape[1] if n > 1 else 0,
                 params[-1][0].shape[1], h.shape[0], src.shape[0],
                 num_layers=n, use_kernels=use_kernels)
    for ld, p in zip(lds, params):
        h = ld.fn(*p, h, src, dst, ew, inv_deg)
    return h
