"""ASTGCN-lite — attention-based spatial-temporal GCN for traffic
forecasting (Guo et al., AAAI'19), reduced to one ST block as the paper's
case-study workload (§IV-C).

Input is a window of T=12 five-minute readings of F=3 channels per sensor,
flattened to x [V, F·T]; output is the next hour's T_out=12 flow values.

Block structure (dense adjacency — PeMS has 307 sensors, so V² is small):

    S    = row-softmax over N_v of ( (x W1)(x W2)ᵀ / sqrt(d_att) )
    A_eff= Â ⊙ S                      (Â = D⁻¹(A+I), row-normalized)
    H    = ReLU( A_eff (x W_gc) + x W_self )
    y    = H W_out + b_out

The spatial hop is 1 (the attention is masked by Â), so the Rust BSP
runtime executes it with a single halo exchange (K = 1).

Calling convention:  fn(w1, w2, wgc, wself, wout, bout, x, adj) -> y
adj is the dense row-normalized [V, V] block of the (halo-augmented)
partition.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..kernels import ref
from ..kernels.fused_linear import ACT_NONE, ACT_RELU, fused_linear
from .common import LayerDef, TensorSpec, glorot

D_ATT = 16
T_OUT = 12


def _block_fn(use_kernels: bool):
    lin = (lambda x, w, b, act: fused_linear(x, w, b, act=act)) \
        if use_kernels else \
        (lambda x, w, b, act: ref.fused_linear_ref(x, w, b, act=act))

    def fn(w1, w2, wgc, wself, wout, bout, x, adj):
        datt = w1.shape[1]
        z1 = lin(x, w1, jnp.zeros(w1.shape[1], x.dtype), ACT_NONE)
        z2 = lin(x, w2, jnp.zeros(w2.shape[1], x.dtype), ACT_NONE)
        s = (z1 @ z2.T) * (1.0 / np.sqrt(datt))
        s = jnp.where(adj > 0, s, -1e30)
        s = s - jnp.max(s, axis=1, keepdims=True)
        es = jnp.exp(s)
        s = es / jnp.maximum(es.sum(axis=1, keepdims=True), 1e-16)
        a_eff = adj * s
        hg = lin(x, wgc, jnp.zeros(wgc.shape[1], x.dtype), ACT_NONE)
        hs = lin(x, wself, jnp.zeros(wself.shape[1], x.dtype), ACT_NONE)
        h = jnp.maximum(a_eff @ hg + hs, 0.0)
        return lin(h, wout, bout, ACT_NONE)

    return fn


def param_spec(ft: int, hidden: int) -> list[TensorSpec]:
    return [
        TensorSpec("w1", (ft, D_ATT)),
        TensorSpec("w2", (ft, D_ATT)),
        TensorSpec("wgc", (ft, hidden)),
        TensorSpec("wself", (ft, hidden)),
        TensorSpec("wout", (hidden, T_OUT)),
        TensorSpec("bout", (T_OUT,)),
    ]


def layers(f_in: int, hidden: int, classes: int, v: int, e: int,
           num_layers: int = 1, use_kernels: bool = True,
           l: int | None = None) -> list[LayerDef]:
    # dense-adjacency path: attention needs all rows, so l is ignored
    # f_in here is F·T (36 for PeMS); `e` is unused (dense adjacency).
    return [LayerDef(
        index=0,
        fn=_block_fn(use_kernels),
        param_spec=param_spec(f_in, hidden),
        data_spec=[TensorSpec("x", (v, f_in)), TensorSpec("adj", (v, v))],
        out_dim=T_OUT,
    )]


def init_params(rng: np.random.Generator, f_in: int, hidden: int,
                classes: int = 0, num_layers: int = 1):
    return [[
        glorot(rng, (f_in, D_ATT)),
        glorot(rng, (f_in, D_ATT)),
        glorot(rng, (f_in, hidden)),
        glorot(rng, (f_in, hidden)),
        glorot(rng, (hidden, T_OUT)),
        np.zeros(T_OUT, np.float32),
    ]]


def forward(params, x, adj, use_kernels: bool = False):
    return _block_fn(use_kernels)(*params[0], x, adj)
