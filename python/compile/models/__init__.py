"""Model registry: name -> module exposing layers()/init_params()/forward()."""

from . import astgcn, gat, gcn, sage

REGISTRY = {
    "gcn": gcn,
    "gat": gat,
    "sage": sage,
    "astgcn": astgcn,
}


def get(name: str):
    return REGISTRY[name]
