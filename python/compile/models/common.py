"""Shared plumbing for the L2 GNN models.

Every message-passing model is expressed as a stack of *layer functions*
with static shapes, so each (model, dataset, bucket, layer) lowers to one
HLO artifact that the Rust BSP runtime executes between halo-exchange
synchronizations (paper §III-E).

Layer-function calling convention (the Rust runtime mirrors this order):

    fn(*params, h, src, dst, ew, inv_deg) -> h_next

- params: the layer's trained tensors, in the order given by `param_spec`.
- h [V, F_k]  activations (layer 0: dequantized input features)
- src, dst [E] int32 COO edge endpoints (dst-owned edges incl. halo srcs)
- ew [E] f32 edge mask/weight — 0.0 marks padding edges
- inv_deg [V, 1] f32 per-vertex normalization (model-specific; see each
  model's `prep` notes)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import numpy as np


@dataclass(frozen=True)
class TensorSpec:
    name: str
    shape: tuple[int, ...]
    dtype: str = "f32"  # f32 | i32


@dataclass(frozen=True)
class LayerDef:
    """One BSP-synchronized execution step."""

    index: int
    fn: Callable  # fn(*params, *data) -> out
    param_spec: list[TensorSpec]  # shapes independent of bucket
    data_spec: list[TensorSpec]  # shapes in terms of the bucket (v, e)
    out_dim: int  # feature dim of the output


def shape_structs(specs: list[TensorSpec]):
    import jax.numpy as jnp

    dt = {"f32": jnp.float32, "i32": jnp.int32}
    return [jax.ShapeDtypeStruct(s.shape, dt[s.dtype]) for s in specs]


def glorot(rng: np.random.Generator, shape) -> np.ndarray:
    fan_in, fan_out = shape[0], shape[-1]
    lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return rng.uniform(-lim, lim, size=shape).astype(np.float32)


def edge_data_spec(v: int, e: int, f: int, l: int | None = None) \
        -> list[TensorSpec]:
    """Data inputs of one message-passing layer. `l` is the owned-row
    count (inv_deg's leading dim); the layer computes outputs for the
    first `l` rows only, so halo rows cost no update FLOPs."""
    if l is None:
        l = v
    return [
        TensorSpec("h", (v, f)),
        TensorSpec("src", (e,), "i32"),
        TensorSpec("dst", (e,), "i32"),
        TensorSpec("ew", (e,)),
        TensorSpec("inv_deg", (l, 1)),
    ]
