"""Build-time training of every (model, dataset) pair on the Rust-generated
synthetic twins, emitting .fgw weight bundles the Rust runtime loads.

Training uses the pure-jnp reference math (ref.py) — identical numerics to
the Pallas kernels (asserted by pytest) with friendlier autodiff.

Usage:  python -m compile.train --data-dir ../data --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import fgio, prep, specs
from .models import REGISTRY


# ----------------------------------------------------------------- Adam ---
def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": zeros, "t": 0}


def adam_step(params, grads, state, lr=1e-2, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
        params, mh, vh)
    return params, {"m": m, "v": v, "t": t}


# ------------------------------------------------- classification models ---
def train_classifier(model_name: str, g: fgio.Graph, hidden: int,
                     epochs: int, lr: float, seed: int, log):
    mod = REGISTRY[model_name]
    v = g.num_vertices
    f_in = g.feature_dim
    classes = g.num_classes
    rng = np.random.default_rng(seed)
    params = [ [jnp.asarray(t) for t in layer]
               for layer in mod.init_params(rng, f_in, hidden, classes) ]

    src, dst, ew, inv_deg = prep.edge_arrays(g, model_name)
    h0 = jnp.asarray(g.features)
    src, dst = jnp.asarray(src), jnp.asarray(dst)
    ew, inv_deg = jnp.asarray(ew), jnp.asarray(inv_deg)
    labels = jnp.asarray(g.labels)
    tr, te = prep.train_test_split(v)
    tr, te = jnp.asarray(tr), jnp.asarray(te)

    def loss_fn(params):
        logits = mod.forward(params, h0, src, dst, ew, inv_deg)
        lt = logits[tr]
        ls = lt - jax.nn.logsumexp(lt, axis=1, keepdims=True)
        nll = -jnp.take_along_axis(ls, labels[tr][:, None], axis=1).mean()
        return nll

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = adam_step(params, grads, state, lr=lr)
        return params, state, loss

    @jax.jit
    def accuracy(params):
        logits = mod.forward(params, h0, src, dst, ew, inv_deg)
        pred = jnp.argmax(logits, axis=1)
        return ((pred[tr] == labels[tr]).mean(),
                (pred[te] == labels[te]).mean())

    state = adam_init(params)
    for ep in range(epochs):
        params, state, loss = step(params, state)
        if ep % max(1, epochs // 5) == 0 or ep == epochs - 1:
            atr, ate = accuracy(params)
            log(f"    ep {ep:3d} loss {float(loss):.4f} "
                f"acc tr {float(atr):.4f} te {float(ate):.4f}")
    atr, ate = accuracy(params)
    return params, float(ate)


# -------------------------------------------------------------- astgcn ----
def train_astgcn(g: fgio.Graph, hidden: int, steps: int, lr: float,
                 seed: int, log):
    mod = REGISTRY["astgcn"]
    ds = specs.DATASETS["pems"]
    xs, ys, mean, std = prep.pems_windows(g, ds.window, mod_t_out := 12)
    adj = jnp.asarray(prep.dense_norm_adj(g))
    rng = np.random.default_rng(seed)
    f_in = g.feature_dim * ds.window
    params = [[jnp.asarray(t) for t in mod.init_params(rng, f_in, hidden)[0]]]
    n = len(xs)
    split = int(0.8 * n)
    xs_tr, ys_tr = jnp.asarray(xs[:split]), jnp.asarray(ys[:split])
    xs_te, ys_te = jnp.asarray(xs[split:]), jnp.asarray(ys[split:])
    # model predicts NORMALIZED flow; targets normalized with channel 0
    ys_tr_n = (ys_tr - mean[0]) / std[0]

    fwd = jax.vmap(lambda p, x: mod.forward(p, x, adj), in_axes=(None, 0))

    def loss_fn(params, xb, yb):
        return jnp.abs(fwd(params, xb) - yb).mean()

    @jax.jit
    def step(params, state, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb)
        params, state = adam_step(params, grads, state, lr=lr)
        return params, state, loss

    state = adam_init(params)
    bs = 16
    key = np.random.default_rng(seed + 1)
    for it in range(steps):
        idx = key.integers(0, split, size=bs)
        params, state, loss = step(params, state, xs_tr[idx], ys_tr_n[idx])
        if it % max(1, steps // 5) == 0 or it == steps - 1:
            pred = fwd(params, xs_te) * std[0] + mean[0]
            mae = float(jnp.abs(pred - ys_te).mean())
            log(f"    it {it:4d} loss {float(loss):.4f} test MAE {mae:.3f}")
    pred = fwd(params, xs_te) * std[0] + mean[0]
    mae = float(jnp.abs(pred - ys_te).mean())
    return params, mae, mean, std


# ---------------------------------------------------------------- driver --
def flatten_weights(model_name: str, params) -> list[tuple[str, np.ndarray]]:
    mod = REGISTRY[model_name]
    # Recover per-layer param names from a dummy layers() call.
    names = {
        "gcn": [["w", "b"]],
        "sage": [["w", "b"]],
        "gat": [["w", "b", "a_src", "a_dst"]],
        "astgcn": [["w1", "w2", "wgc", "wself", "wout", "bout"]],
    }[model_name]
    out = []
    for li, layer in enumerate(params):
        layer_names = names[0]
        for name, tensor in zip(layer_names, layer):
            out.append((f"l{li}.{name}", np.asarray(tensor)))
    return out


def weights_key(model: str, dataset: str) -> str:
    """All RMAT sizes share feature/class dims -> share one weight bundle."""
    if dataset.startswith("rmat"):
        dataset = "rmat"
    return f"weights_{model}_{dataset}"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--data-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "data"))
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None)
    ap.add_argument("--epochs", type=int, default=150)
    ap.add_argument("--astgcn-steps", type=int, default=400)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    done: set[str] = set()
    report = []
    for model_name, ds_name in specs.PAIRS:
        key = weights_key(model_name, ds_name)
        if key in done:
            continue
        if args.only and args.only not in (model_name, ds_name,
                                           f"{model_name}:{ds_name}"):
            continue
        done.add(key)
        # rmat weights are trained on the smallest twin
        train_ds = "rmat20k" if ds_name.startswith("rmat") else ds_name
        path = os.path.join(args.data_dir, f"{train_ds}.fgr")
        if not os.path.exists(path):
            print(f"!! missing {path} (run `repro dataset` first); skipping")
            continue
        g = fgio.read_fgr(path)
        ms = specs.MODELS[model_name]
        t0 = time.time()
        print(f"training {model_name} on {train_ds} "
              f"(V={g.num_vertices} E={g.num_edges})", flush=True)
        log = lambda s: print(s, flush=True)
        extra: list[tuple[str, np.ndarray]] = []
        if model_name == "astgcn":
            params, metric, mean, std = train_astgcn(
                g, ms.hidden, args.astgcn_steps, 5e-3, 31, log)
            extra = [("norm_mean", mean), ("norm_std", std)]
            report.append((key, f"test MAE {metric:.3f}"))
        else:
            lr = 1e-2
            params, metric = train_classifier(
                model_name, g, ms.hidden, args.epochs, lr, 31, log)
            report.append((key, f"test acc {metric:.4f}"))
        tensors = flatten_weights(model_name, params) + extra
        out = os.path.join(args.out_dir, key + ".fgw")
        fgio.write_fgw(out, tensors)
        print(f"  -> {out}  ({time.time()-t0:.1f}s)", flush=True)
    print("\nsummary:")
    for k, m in report:
        print(f"  {k}: {m}")


if __name__ == "__main__":
    main()
