"""AOT pipeline tests: lowering produces parseable HLO text with the right
entry layout, bucket geometry is sane, and the manifest indexes artifacts
consistently."""

import re

import pytest

from compile import specs
from compile.aot import artifact_name, lower_layer
from compile.models import REGISTRY


def test_bucket_dims_monotone_and_capped():
    ds = specs.DATASETS["siot"]
    buckets = specs.buckets_for(ds)
    assert buckets[0][0] == 1
    v_full, e_full, l_full = buckets[0][1], buckets[0][2], buckets[0][3]
    assert v_full >= ds.vertices and e_full >= ds.directed_edges
    assert l_full == v_full
    vs = [v for _, v, _, _ in buckets]
    assert vs == sorted(vs, reverse=True)
    for _, v, e, l in buckets:
        assert v % specs.V_ROUND == 0
        assert e % specs.E_ROUND == 0
        assert v <= v_full and e <= e_full
        assert l <= v  # owned rows fit within the halo-augmented bucket


def test_bucket_covers_partition_with_halo():
    """A 1/d partition + halo margin must fit its bucket."""
    ds = specs.DATASETS["yelp"]
    for frac, v_max, e_max, l_max in specs.buckets_for(ds):
        if frac == 1:
            continue
        assert v_max >= ds.vertices / frac * 1.3
        assert e_max >= ds.directed_edges / frac
        assert l_max >= ds.vertices / frac


@pytest.mark.parametrize("model", ["gcn", "gat", "sage"])
def test_lowered_hlo_entry_layout(model):
    mod = REGISTRY[model]
    lds = mod.layers(12, 16, 3, 128, 512, use_kernels=True)
    text = lower_layer(lds[0])
    assert text.startswith("HloModule")
    m = re.search(r"entry_computation_layout=\{\(([^)]*)\)", text)
    assert m, "no entry layout in HLO text"
    n_inputs = len(lds[0].param_spec) + len(lds[0].data_spec)
    # count top-level params (f32[...]/s32[...]) in the layout
    params = re.findall(r"[fs]32\[", m.group(1))
    assert len(params) == n_inputs


def test_lowered_astgcn_is_dense_and_small():
    mod = REGISTRY["astgcn"]
    lds = mod.layers(36, 64, 1, 128, 0, num_layers=1, use_kernels=True)
    text = lower_layer(lds[0])
    assert "f32[128,128]" in text  # dense adjacency input
    assert len(text) < 2_000_000


def test_artifact_names_unique_across_pairs():
    names = set()
    for model, ds_name in specs.PAIRS:
        for frac, _, _, _ in specs.buckets_for(specs.DATASETS[ds_name]):
            for layer in range(specs.MODELS[model].layers):
                n = artifact_name(model, ds_name, frac, layer)
                assert n not in names
                names.add(n)
    assert len(names) > 50  # the artifact set is substantial


def test_pairs_reference_known_specs():
    for model, ds in specs.PAIRS:
        assert model in specs.MODELS
        assert ds in specs.DATASETS
