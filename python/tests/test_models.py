"""L2 correctness: kernel-backed model forward == ref-backed forward, plus
structural/shape checks and model math sanity (GCN mean, GAT attention
normalization, SAGE concat)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import REGISTRY
from compile.models import astgcn as astgcn_mod


def tiny_graph(rng, v=50, e=260, f=16):
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    ew = np.ones(e, np.float32)
    h = rng.normal(size=(v, f)).astype(np.float32)
    deg_in = np.bincount(dst, minlength=v).astype(np.float32)
    return h, src, dst, ew, deg_in


@pytest.mark.parametrize("name", ["gcn", "gat", "sage"])
def test_kernel_vs_ref_forward_parity(name):
    rng = np.random.default_rng(42)
    mod = REGISTRY[name]
    h, src, dst, ew, deg_in = tiny_graph(rng)
    v, f = h.shape
    if name == "gat":
        loops = np.arange(v, dtype=np.int32)
        src = np.concatenate([src, loops])
        dst = np.concatenate([dst, loops])
        ew = np.ones(len(src), np.float32)
        inv_deg = np.ones((v, 1), np.float32)
    elif name == "gcn":
        inv_deg = (1 / (deg_in + 1)).reshape(v, 1).astype(np.float32)
    else:
        inv_deg = (1 / np.maximum(deg_in, 1)).reshape(v, 1).astype(np.float32)
    params = [[jnp.asarray(t) for t in layer]
              for layer in mod.init_params(rng, f, 32, 4)]
    args = tuple(map(jnp.asarray, (h, src, dst, ew, inv_deg)))
    out_ref = mod.forward(params, *args, use_kernels=False)
    out_ker = mod.forward(params, *args, use_kernels=True)
    assert out_ref.shape == (v, 4)
    np.testing.assert_allclose(out_ker, out_ref, rtol=1e-4, atol=1e-4)


def test_gcn_isolated_vertex_is_pure_self_update():
    """A vertex with no in-edges: h' = relu(W h / 1)."""
    from compile.models import gcn
    rng = np.random.default_rng(1)
    h = rng.normal(size=(3, 8)).astype(np.float32)
    src = np.array([1], np.int32)
    dst = np.array([2], np.int32)
    ew = np.ones(1, np.float32)
    inv_deg = np.array([[1.0], [1.0], [0.5]], np.float32)
    params = [[jnp.asarray(t) for t in layer]
              for layer in gcn.init_params(rng, 8, 8, 4, num_layers=1)]
    out = gcn.forward(params, *map(jnp.asarray, (h, src, dst, ew, inv_deg)))
    w, b = params[0]
    want = np.asarray(h[0] @ np.asarray(w) + np.asarray(b))
    np.testing.assert_allclose(out[0], want, rtol=1e-5, atol=1e-5)


def test_sage_param_shape_is_concat_width():
    from compile.models import sage
    rng = np.random.default_rng(2)
    params = sage.init_params(rng, 10, 32, 4)
    assert params[0][0].shape == (20, 32)
    assert params[1][0].shape == (64, 4)


def test_gat_attention_is_convex_combination():
    """With ELU removed at the last layer and one destination, GAT output
    lies in the convex hull of the transformed neighbor features."""
    from compile.models import gat
    rng = np.random.default_rng(3)
    v, f = 4, 6
    h = rng.normal(size=(v, f)).astype(np.float32)
    # all of 0,1,2 (+ self loop 3) point at 3
    src = np.array([0, 1, 2, 3], np.int32)
    dst = np.array([3, 3, 3, 3], np.int32)
    ew = np.ones(4, np.float32)
    inv_deg = np.ones((v, 1), np.float32)
    params = [[jnp.asarray(t) for t in layer]
              for layer in gat.init_params(rng, f, f, f, num_layers=1)]
    out = gat.forward(params, *map(jnp.asarray,
                                   (h, src, dst, ew, inv_deg)))
    w, b = np.asarray(params[0][0]), np.asarray(params[0][1])
    z = h @ w + b
    lo, hi = z.min(axis=0) - 1e-4, z.max(axis=0) + 1e-4
    got = np.asarray(out[3])
    assert np.all(got >= lo) and np.all(got <= hi)


def test_astgcn_shapes_and_kernel_parity():
    rng = np.random.default_rng(4)
    v, ft = 37, 36
    x = jnp.asarray(rng.normal(size=(v, ft)).astype(np.float32))
    a = np.zeros((v, v), np.float32)
    for _ in range(120):
        i, j = rng.integers(0, v, 2)
        a[i, j] = 1.0
    a[np.arange(v), np.arange(v)] = 1.0
    adj = jnp.asarray(a / a.sum(axis=1, keepdims=True))
    params = [[jnp.asarray(t) for t in astgcn_mod.init_params(rng, ft, 64)[0]]]
    y_ref = astgcn_mod.forward(params, x, adj, use_kernels=False)
    y_ker = astgcn_mod.forward(params, x, adj, use_kernels=True)
    assert y_ref.shape == (v, astgcn_mod.T_OUT)
    np.testing.assert_allclose(y_ker, y_ref, rtol=2e-4, atol=2e-4)


def test_padding_rows_do_not_affect_real_rows():
    """Bucket padding invariant: appending zero rows/edges leaves the real
    rows' outputs unchanged — the property the Rust pad.rs relies on."""
    from compile.models import gcn
    rng = np.random.default_rng(5)
    h, src, dst, ew, deg_in = tiny_graph(rng, v=30, e=100, f=8)
    inv_deg = (1 / (deg_in + 1)).reshape(-1, 1).astype(np.float32)
    params = [[jnp.asarray(t) for t in layer]
              for layer in gcn.init_params(rng, 8, 16, 3)]
    out = gcn.forward(params, *map(jnp.asarray,
                                   (h, src, dst, ew, inv_deg)))
    # pad to 64 vertices / 160 edges
    hp = np.vstack([h, np.zeros((34, 8), np.float32)])
    srcp = np.concatenate([src, np.zeros(60, np.int32)])
    dstp = np.concatenate([dst, np.zeros(60, np.int32)])
    ewp = np.concatenate([ew, np.zeros(60, np.float32)])
    invp = np.vstack([inv_deg, np.ones((34, 1), np.float32)])
    outp = gcn.forward(params, *map(jnp.asarray,
                                    (hp, srcp, dstp, ewp, invp)))
    np.testing.assert_allclose(outp[:30], out, rtol=1e-5, atol=1e-5)
