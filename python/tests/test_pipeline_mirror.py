"""Numpy mirror of the PR 7 pipelined executor (rust/src/exec/bsp.rs
``BspPipeline``), the fabric's generalized station gate
(rust/src/traffic/fabric.rs) and the probed shard floor
(rust/src/runtime/kernels/shard.rs::derive_floor).

The build container has no Rust toolchain (see ROADMAP.md caveat), so
these mirrors replicate the shipped logic statement-for-statement —
including the flattened ``(bk * n + row) * dim`` buffer layout and the
``[w][bk][dim]`` halo wire format — and check the claims the Rust
tests make:

* dependency-driven dispatch (own rebuild done AND every incoming halo
  delivered), staged delivery for messages that beat their destination
  buffer, and per-fog FIFO reply tags produce outputs bit-identical to
  the barrier executor for any depth and any reply order;
* the generalized release gate ``finishes[released - (pd + 1)]`` and
  exec gate ``finishes[len - pd]`` at pd = 1 equal the legacy
  hard-coded two-station recurrence, and the deferred-drain invariant
  keeps every gate index in range at any depth;
* ``derive_floor`` rounds the break-even row count to a power of two
  inside [64, 4096] and falls back to 256 on degenerate measurements.

Float32 end to end: halo messages are plain row copies and the kernel
is run on identically-assembled buffers in both executors, so equality
is exact (``np.array_equal``), not approximate.
"""

import math

import numpy as np

F32 = np.float32


# ---------------------------------------------------------------------------
# plan mirror: partitions, halo maps, transfers — BatchedBspPlan shape
# ---------------------------------------------------------------------------


class PlanMirror:
    """Round-robin vertex ownership, undirected random topology, and
    the derived per-fog structures the Rust plan carries: vertices =
    owned + halo, halo_index, transfers[src][dst] (src-local owned row
    indices destined for dst), and n_in (incoming-halo source counts).
    """

    def __init__(self, rng, n_fogs, nv, n_edges, dims, owner_of=None):
        self.n_fogs = n_fogs
        self.nv = nv
        self.dims = list(dims)  # dims[0] = f_in, dims[-1] = out_dim
        owner = (
            [owner_of(v) for v in range(nv)]
            if owner_of
            else [v % n_fogs for v in range(nv)]
        )
        nbrs = [set() for _ in range(nv)]
        while sum(len(s) for s in nbrs) // 2 < n_edges:
            a, b = int(rng.integers(0, nv)), int(rng.integers(0, nv))
            if a != b:
                nbrs[a].add(b)
                nbrs[b].add(a)
        self.nbrs = [sorted(s) for s in nbrs]

        self.owned = [
            [v for v in range(nv) if owner[v] == j] for j in range(n_fogs)
        ]
        self.halo = [
            sorted(
                {
                    u
                    for v in self.owned[j]
                    for u in self.nbrs[v]
                    if owner[u] != j
                }
            )
            for j in range(n_fogs)
        ]
        self.vertices = [self.owned[j] + self.halo[j] for j in range(n_fogs)]
        self.n_local = [len(o) for o in self.owned]
        self.n_total = [len(v) for v in self.vertices]
        self.halo_index = [
            {g: self.n_local[j] + i for i, g in enumerate(self.halo[j])}
            for j in range(n_fogs)
        ]
        local_pos = [
            {g: i for i, g in enumerate(self.vertices[j])}
            for j in range(n_fogs)
        ]
        # transfers[src][dst]: src-local indices of src-OWNED vertices
        # that sit in dst's halo, in src-local order (fixed wire order)
        self.transfers = [
            [
                sorted(
                    local_pos[src][u]
                    for u in self.halo[dst]
                    if owner[u] == src
                )
                if src != dst
                else []
                for dst in range(n_fogs)
            ]
            for src in range(n_fogs)
        ]
        self.n_in = [
            sum(
                1
                for s in range(n_fogs)
                if s != d and self.transfers[s][d]
            )
            for d in range(n_fogs)
        ]
        self.active = [self.n_total[j] > 0 for j in range(n_fogs)]
        self.n_active = sum(self.active)
        # per-owned-row aggregation targets in local coordinates
        # (neighbors are owned-or-halo by construction), sorted by gid
        self.agg = [
            [
                [local_pos[j][u] for u in self.nbrs[v]]
                for v in self.owned[j]
            ]
            for j in range(n_fogs)
        ]
        wrng = np.random.default_rng(0xBEEF)
        self.weights = [
            wrng.standard_normal((dims[i], dims[i + 1])).astype(F32)
            for i in range(len(dims) - 1)
        ]

    @property
    def num_layers(self):
        return len(self.weights)


def fog_kernel(plan, j, layer, buf, batch):
    """One fog-layer job: aggregate self + neighbors, multiply by the
    layer weight, relu (except the final layer). Consumes the
    flattened local-space buffer [batch * n_total * dim], emits owned
    rows only [batch * n_local * out_dim] — the message-passing model
    contract both Rust executors share. The SAME function serves the
    barrier and pipelined mirrors, so any output difference is a
    scheduling/delivery bug, which is exactly what is under test.
    """
    n, l = plan.n_total[j], plan.n_local[j]
    dim, out_dim = plan.dims[layer], plan.dims[layer + 1]
    w = plan.weights[layer]
    out = np.zeros(batch * l * out_dim, dtype=F32)
    for bk in range(batch):
        for r in range(l):
            vec = buf[(bk * n + r) * dim : (bk * n + r + 1) * dim].copy()
            for p in plan.agg[j][r]:
                vec = vec + buf[(bk * n + p) * dim : (bk * n + p + 1) * dim]
            row = vec @ w
            if layer + 1 < plan.num_layers:
                row = np.maximum(row, F32(0.0))
            out[(bk * l + r) * out_dim : (bk * l + r + 1) * out_dim] = row
    return out


def layer0_buffer(plan, j, features, batch):
    """submit()'s initial snapshot: owned rows replicated per block,
    halo slots zeroed."""
    n, f_in = plan.n_total[j], plan.dims[0]
    h = np.zeros(batch * n * f_in, dtype=F32)
    for r, gid in enumerate(plan.owned[j]):
        src = features[gid * f_in : (gid + 1) * f_in]
        for bk in range(batch):
            h[(bk * n + r) * f_in : (bk * n + r) * f_in + f_in] = src
    return h


def rebuild_state(plan, j, out, batch, out_dim):
    """process_reply()'s rebuild: owned rows copied into local space,
    halo slots zeroed until their owners' messages arrive."""
    n, l = plan.n_total[j], plan.n_local[j]
    st = np.zeros(batch * n * out_dim, dtype=F32)
    for bk in range(batch):
        st[bk * n * out_dim : (bk * n + l) * out_dim] = out[
            bk * l * out_dim : (bk + 1) * l * out_dim
        ]
    return st


def pack_halo_msg(plan, src, dst, buf, dim, batch):
    """ship_halo()'s wire format: rows [w][bk][dim] from the src
    buffer at the transfer's owner-local indices."""
    n_src = plan.n_total[src]
    wanted = plan.transfers[src][dst]
    msg = np.empty(len(wanted) * batch * dim, dtype=F32)
    at = 0
    for owner_local in wanted:
        for bk in range(batch):
            s0 = (bk * n_src + owner_local) * dim
            msg[at : at + dim] = buf[s0 : s0 + dim]
            at += dim
    return msg


def deliver_halo_msg(plan, src, dst, dbuf, msg, dim, batch):
    """deliver()'s scatter: wire row w lands at the destination's
    halo_index position for the shipped vertex."""
    n_dst = plan.n_total[dst]
    wanted = plan.transfers[src][dst]
    for w, owner_local in enumerate(wanted):
        gid = plan.vertices[src][owner_local]
        pos = plan.halo_index[dst][gid]
        for bk in range(batch):
            m0 = (w * batch + bk) * dim
            d0 = (bk * n_dst + pos) * dim
            dbuf[d0 : d0 + dim] = msg[m0 : m0 + dim]


def assemble_outputs(plan, final_states, batch, out_dim):
    out = np.zeros(batch * plan.nv * out_dim, dtype=F32)
    for j in range(plan.n_fogs):
        n = plan.n_total[j]
        for bk in range(batch):
            for row, gid in enumerate(plan.owned[j]):
                at = (bk * plan.nv + gid) * out_dim
                frm = (bk * n + row) * out_dim
                out[at : at + out_dim] = final_states[j][
                    frm : frm + out_dim
                ]
    return out


# ---------------------------------------------------------------------------
# barrier reference — execute_inner's per-layer lockstep
# ---------------------------------------------------------------------------


def barrier_execute(plan, features, batch):
    states = [
        layer0_buffer(plan, j, features, batch) if plan.active[j] else None
        for j in range(plan.n_fogs)
    ]
    # initial halo exchange (every buffer exists, immediate delivery)
    for src in range(plan.n_fogs):
        for dst in range(plan.n_fogs):
            if src == dst or not plan.transfers[src][dst]:
                continue
            msg = pack_halo_msg(
                plan, src, dst, states[src], plan.dims[0], batch
            )
            deliver_halo_msg(
                plan, src, dst, states[dst], msg, plan.dims[0], batch
            )
    for layer in range(plan.num_layers):
        out_dim = plan.dims[layer + 1]
        nxt = []
        for j in range(plan.n_fogs):
            if not plan.active[j]:
                nxt.append(None)
                continue
            out = fog_kernel(plan, j, layer, states[j], batch)
            nxt.append(rebuild_state(plan, j, out, batch, out_dim))
        states = nxt
        if layer + 1 < plan.num_layers:
            for src in range(plan.n_fogs):
                for dst in range(plan.n_fogs):
                    if src == dst or not plan.transfers[src][dst]:
                        continue
                    msg = pack_halo_msg(
                        plan, src, dst, states[src], out_dim, batch
                    )
                    deliver_halo_msg(
                        plan, src, dst, states[dst], msg, out_dim, batch
                    )
    return assemble_outputs(plan, states, batch, plan.dims[-1])


# ---------------------------------------------------------------------------
# pipelined mirror — BspPipeline's dependency machine, event-driven
# ---------------------------------------------------------------------------


class InflightMirror:
    def __init__(self, plan, seq, batch):
        L, nf = plan.num_layers, plan.n_fogs
        self.seq = seq
        self.batch = batch
        self.bufs = [[None] * nf for _ in range(L)]
        self.own_done = [[False] * nf for _ in range(L)]
        self.copies_in = [[0] * nf for _ in range(L)]
        self.dispatched = [[False] * nf for _ in range(L)]
        self.staged = [[[] for _ in range(nf)] for _ in range(L)]
        self.final_states = [None] * nf
        self.done_last = 0
        self.complete = plan.n_active == 0


class PipelineMirror:
    """BspPipeline: per-fog FIFO job queues stand in for the worker
    pool (per-fog submission order preserved, cross-fog interleaving
    chosen by the test's rng — the reply-order adversary)."""

    def __init__(self, plan, depth):
        assert depth >= 1
        self.plan = plan
        self.depth = depth
        self.inflight = []
        self.tags = [[] for _ in range(plan.n_fogs)]  # (seq, layer) FIFO
        self.queues = [[] for _ in range(plan.n_fogs)]  # (seq, layer, buf)
        self.next_seq = 0
        self.staged_hits = 0
        self.direct_hits = 0

    def pending(self):
        return len(self.inflight)

    def submit(self, features, batch):
        assert self.pending() < self.depth, "collect before submitting"
        p = self.plan
        b = InflightMirror(p, self.next_seq, batch)
        self.next_seq += 1
        for j in range(p.n_fogs):
            if not p.active[j]:
                b.own_done[0][j] = True
                continue
            b.bufs[0][j] = layer0_buffer(p, j, features, batch)
            b.own_done[0][j] = True
        self.inflight.append(b)
        idx = len(self.inflight) - 1
        for src in range(p.n_fogs):
            self._ship_halo(idx, 0, src)
        for j in range(p.n_fogs):
            self._maybe_dispatch(idx, 0, j)

    def _ship_halo(self, idx, layer, src):
        p, b = self.plan, self.inflight[idx]
        dim = p.dims[layer]
        for dst in range(p.n_fogs):
            if dst == src or not p.transfers[src][dst]:
                continue
            msg = pack_halo_msg(
                p, src, dst, b.bufs[layer][src], dim, b.batch
            )
            if b.own_done[layer][dst]:
                deliver_halo_msg(
                    p, src, dst, b.bufs[layer][dst], msg, dim, b.batch
                )
                b.copies_in[layer][dst] += 1
                self.direct_hits += 1
            else:
                b.staged[layer][dst].append((src, msg))
                self.staged_hits += 1

    def _maybe_dispatch(self, idx, layer, j):
        p, b = self.plan, self.inflight[idx]
        if (
            not p.active[j]
            or b.dispatched[layer][j]
            or not b.own_done[layer][j]
            or b.copies_in[layer][j] < p.n_in[j]
        ):
            return
        b.dispatched[layer][j] = True
        buf = b.bufs[layer][j]
        b.bufs[layer][j] = None  # dispatch takes the buffer
        self.tags[j].append((b.seq, layer))
        self.queues[j].append((b.seq, layer, buf))

    def step(self, rng):
        """Complete ONE job on a random busy fog (per-fog FIFO) and
        feed the reply through the dependency machine. Returns False
        when no worker has anything queued."""
        busy = [j for j in range(self.plan.n_fogs) if self.queues[j]]
        if not busy:
            return False
        j = busy[int(rng.integers(0, len(busy)))]
        seq, layer, buf = self.queues[j].pop(0)
        tag = self.tags[j].pop(0)
        assert tag == (seq, layer), "per-fog FIFO tags must match jobs"
        out = fog_kernel(self.plan, j, layer, buf, self.inflight[0].batch)
        self._process_reply(j, seq, layer, out)
        return True

    def _process_reply(self, j, seq, layer, out):
        p = self.plan
        idx = seq - self.inflight[0].seq
        b = self.inflight[idx]
        nxt = layer + 1
        out_dim = p.dims[nxt]
        st = rebuild_state(p, j, out, b.batch, out_dim)
        if nxt == p.num_layers:
            b.final_states[j] = st
            b.done_last += 1
            if b.done_last == p.n_active:
                b.complete = True
            return
        b.bufs[nxt][j] = st
        b.own_done[nxt][j] = True
        staged = b.staged[nxt][j]
        b.staged[nxt][j] = []
        for src, msg in staged:
            deliver_halo_msg(p, src, j, b.bufs[nxt][j], msg, out_dim,
                             b.batch)
            b.copies_in[nxt][j] += 1
        self._ship_halo(idx, nxt, j)
        self._maybe_dispatch(idx, nxt, j)
        for dst in range(p.n_fogs):
            if dst != j and p.transfers[j][dst]:
                self._maybe_dispatch(idx, nxt, dst)

    def collect(self, rng):
        assert self.inflight, "collect with no batch in flight"
        while not self.inflight[0].complete:
            assert self.step(rng), "deadlock: incomplete batch, idle pool"
        b = self.inflight.pop(0)
        return assemble_outputs(
            self.plan, b.final_states, b.batch, self.plan.dims[-1]
        )


def run_pipelined(plan, feature_sets, batch, depth, rng):
    """Adversarial driver: interleave submits, random reply
    processing, and collects, keeping up to `depth` batches in
    flight."""
    pipe = PipelineMirror(plan, depth)
    results, i = [], 0
    while i < len(feature_sets) or pipe.pending():
        if i < len(feature_sets) and pipe.pending() < depth:
            pipe.submit(feature_sets[i], batch)
            i += 1
            for _ in range(int(rng.integers(0, 4))):
                pipe.step(rng)
        else:
            results.append(pipe.collect(rng))
    return results, pipe


# ---------------------------------------------------------------------------
# tests: pipeline bit-identity
# ---------------------------------------------------------------------------


def make_plan(seed, n_fogs=3, nv=24, n_edges=40, dims=(5, 4, 3, 2),
              owner_of=None):
    return PlanMirror(
        np.random.default_rng(seed), n_fogs, nv, n_edges, dims, owner_of
    )


def feature_sets(plan, count, seed):
    rng = np.random.default_rng(seed)
    return [
        rng.standard_normal(plan.nv * plan.dims[0]).astype(F32)
        for _ in range(count)
    ]


def test_pipeline_bitwise_equals_barrier_across_depths_and_orders():
    batch = 2
    for plan_seed in (1, 7):
        plan = make_plan(plan_seed)
        feats = feature_sets(plan, 5, 0xF00 + plan_seed)
        barrier = [barrier_execute(plan, f, batch) for f in feats]
        for depth in (1, 2, 3):
            for order_seed in (11, 23, 99):
                rng = np.random.default_rng(order_seed)
                got, pipe = run_pipelined(plan, feats, batch, depth, rng)
                assert len(got) == len(barrier)
                for g, want in zip(got, barrier):
                    assert g.dtype == np.float32
                    assert np.array_equal(g, want), (
                        f"plan {plan_seed} depth {depth} order "
                        f"{order_seed}: pipelined != barrier"
                    )
                assert pipe.pending() == 0
                assert all(not q for q in pipe.queues)
                assert all(not t for t in pipe.tags)


def test_pipeline_exercises_both_staged_and_direct_delivery():
    plan = make_plan(3)
    feats = feature_sets(plan, 4, 0xD00)
    staged = direct = 0
    for order_seed in range(8):
        rng = np.random.default_rng(order_seed)
        _, pipe = run_pipelined(plan, feats, 2, 3, rng)
        staged += pipe.staged_hits
        direct += pipe.direct_hits
    # layer-0 shipping always delivers directly (every buffer exists
    # at submit); deeper layers under adversarial orders must hit the
    # staging path too, or the test is not covering it
    assert direct > 0
    assert staged > 0


def test_pipeline_handles_empty_fog():
    # fog 3 owns nothing: active=[T,T,T,F], jobs never reach it
    plan = make_plan(5, n_fogs=4, owner_of=lambda v: v % 3)
    assert plan.active == [True, True, True, False]
    feats = feature_sets(plan, 3, 0xE00)
    barrier = [barrier_execute(plan, f, 2) for f in feats]
    rng = np.random.default_rng(42)
    got, pipe = run_pipelined(plan, feats, 2, 2, rng)
    for g, want in zip(got, barrier):
        assert np.array_equal(g, want)
    assert not pipe.queues[3] and not pipe.tags[3]


def test_pipeline_depth1_is_lockstep_but_barrier_free_within_batch():
    plan = make_plan(9)
    feats = feature_sets(plan, 3, 0xA11)
    barrier = [barrier_execute(plan, f, 1) for f in feats]
    rng = np.random.default_rng(0)
    got, _ = run_pipelined(plan, feats, 1, 1, rng)
    for g, want in zip(got, barrier):
        assert np.array_equal(g, want)


# ---------------------------------------------------------------------------
# tests: fabric station-gate arithmetic
# ---------------------------------------------------------------------------


def simulate_stations(colls, execs, pd):
    """The generalized fabric recurrence: release gate
    finishes[released - (pd + 1)], exec gate finishes[len - pd]."""
    finishes, releases, starts = [], [], []
    gate_depth = pd + 1
    for coll_done, exec_time in zip(colls, execs):
        released = len(finishes)  # no deferred batches in this model
        gate = (
            finishes[released - gate_depth]
            if released >= gate_depth
            else 0.0
        )
        releases.append(gate)
        start = max(
            coll_done,
            finishes[len(finishes) - pd] if len(finishes) >= pd else 0.0,
        )
        starts.append(start)
        finishes.append(start + exec_time)
    return releases, starts, finishes


def simulate_stations_legacy(colls, execs):
    """The pre-PR7 fabric: hard-coded PIPELINE_DEPTH = 2 release gate
    (finishes[len - 2]) and the exec_free running max as the exec
    gate."""
    finishes, releases, starts = [], [], []
    exec_free = 0.0
    for coll_done, exec_time in zip(colls, execs):
        gate = finishes[-2] if len(finishes) >= 2 else 0.0
        releases.append(gate)
        start = max(coll_done, exec_free)
        starts.append(start)
        finish = start + exec_time
        exec_free = max(exec_free, finish)
        finishes.append(finish)
    return releases, starts, finishes


def test_gate_depth1_bit_identical_to_legacy_two_station_model():
    rng = np.random.default_rng(0x6A7E)
    for _ in range(50):
        n = int(rng.integers(1, 40))
        colls = np.cumsum(rng.uniform(0.0, 0.5, n)).tolist()
        execs = rng.uniform(0.0, 0.8, n).tolist()
        got = simulate_stations(colls, execs, pd=1)
        want = simulate_stations_legacy(colls, execs)
        assert got == want  # exact float equality, same op order
        # monotone finishes justify finishes[-1] == max(finishes)
        f = got[2]
        assert all(a <= b for a, b in zip(f, f[1:]))


def test_deeper_gates_never_hurt_start_times():
    rng = np.random.default_rng(0xDEE9)
    colls = np.cumsum(rng.uniform(0.0, 0.2, 60)).tolist()
    execs = rng.uniform(0.1, 0.6, 60).tolist()
    prev = None
    for pd in (1, 2, 4, 8):
        _, starts, _ = simulate_stations(colls, execs, pd)
        if prev is not None:
            assert all(s <= p for s, p in zip(starts, prev))
        prev = starts


def test_deferred_drain_invariant_keeps_gate_index_in_range():
    # the fabric pushes released batches into `deferred` and drains
    # while deferred >= pd before each release; the release gate uses
    # released = len(finishes) + len(deferred). Mirror the loop and
    # assert the gate index is always valid.
    rng = np.random.default_rng(0x0D7A)
    for pd in (2, 3, 4):
        gate_depth = pd + 1
        finishes, deferred = [], []
        for k in range(200):
            while len(deferred) >= pd:
                finishes.append(deferred.pop(0))
            released = len(finishes) + len(deferred)
            if released >= gate_depth:
                idx = released - gate_depth
                assert 0 <= idx < len(finishes), (
                    f"pd={pd} k={k}: gate index {idx} out of range "
                    f"(len={len(finishes)})"
                )
            deferred.append(float(k))
            # scheduler ticks flush the whole window at random points
            if rng.uniform() < 0.1:
                while deferred:
                    finishes.append(deferred.pop(0))
        assert len(finishes) + len(deferred) == 200


# ---------------------------------------------------------------------------
# tests: derive_floor arithmetic (kernels/shard.rs)
# ---------------------------------------------------------------------------

FALLBACK_FLOOR = 256
PROBE_FLOOR_MIN = 64
PROBE_FLOOR_MAX = 4096


def next_power_of_two(n):
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def derive_floor(per_row_s, handoff_s):
    if (
        not math.isfinite(per_row_s)
        or not math.isfinite(handoff_s)
        or per_row_s <= 0.0
        or handoff_s <= 0.0
    ):
        return FALLBACK_FLOOR
    breakeven = math.ceil(handoff_s / per_row_s)
    if not math.isfinite(breakeven) or breakeven < 1.0:
        return FALLBACK_FLOOR
    rows = next_power_of_two(max(int(breakeven), 1))
    return min(max(rows, PROBE_FLOOR_MIN), PROBE_FLOOR_MAX)


def test_derive_floor_matches_rust_unit_cases():
    assert derive_floor(1e-6, 100e-6) == 128  # 100 rows -> pow2 128
    assert derive_floor(1e-6, 1e-9) == 64  # tiny handoff -> min clamp
    assert derive_floor(1e-9, 1.0) == 4096  # huge ratio -> max clamp
    assert derive_floor(1e-6, 512e-6) == 512  # exact pow2 stays
    for bad in (0.0, -1.0, float("nan"), float("inf")):
        assert derive_floor(bad, 1e-5) == FALLBACK_FLOOR
        assert derive_floor(1e-6, bad) == FALLBACK_FLOOR


def test_derive_floor_randomized_is_clamped_pow2_above_breakeven():
    rng = np.random.default_rng(0xF1008)
    for _ in range(500):
        per_row = 10.0 ** rng.uniform(-9, -4)
        handoff = 10.0 ** rng.uniform(-8, -2)
        rows = derive_floor(per_row, handoff)
        assert PROBE_FLOOR_MIN <= rows <= PROBE_FLOOR_MAX
        assert rows & (rows - 1) == 0  # power of two
        breakeven = math.ceil(handoff / per_row)
        if PROBE_FLOOR_MIN <= breakeven <= PROBE_FLOOR_MAX:
            assert breakeven <= rows < 2 * breakeven
