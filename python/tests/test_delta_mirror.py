"""Pure-python mirror of the PR 10 incremental topology engine's
mutation substrate (rust/src/graph/delta.rs): the ``DeltaCsr``
(tombstoned base CSR + per-vertex sorted overflow + periodic
compaction), the seeded ``ChurnPlan`` mutation stream, and the
``Rng``/``mix64`` PRNG substrate it draws from (rust/src/util/rng.rs,
SplitMix64-seeded Xoshiro256++ with Lemire's multiply-shift bound).

The build container has no Rust toolchain (see ROADMAP.md caveat), so
this mirror replicates the shipped arithmetic statement-for-statement
— 64-bit wrapping ops masked by hand — and checks the central claims
the Rust suites (delta.rs unit tests + tests/churn_parity.rs) make:

* a CSR mutated in place through any seeded churn trace stays
  IDENTICAL to a from-scratch rebuild of its live topology: same
  ascending neighbor walks, same live-edge pairs, same witnesses;
* compaction fires when tombstones + overflow exceed half the stored
  arcs, and is invisible to every neighbor walk;
* ``targets = max(1, floor(rate x live))`` — a trickle rate of 1e-7
  yields exactly one mutation per round (the partial re-ground gate
  in ``repro churn`` depends on this);
* spec canonicalization (sort by op rank) makes the mutation stream
  invariant under --churn declaration order;
* one edge delta touches at most the two endpoint owners — the upper
  bound the partition-scoped invalidation plane is built on;
* vertex deletion leaves a dead degree-0 id that the next add-vertex
  revives (smallest-first), keeping the id space dense.
"""

MASK = (1 << 64) - 1

CHURN_SALT = 0xDE17A5EE
TOMBSTONE = (1 << 32) - 1
OP_RETRIES = 64

# op -> canonical rank (delta.rs ChurnOp::rank)
RANK = {"add-edge": 0, "del-edge": 1, "add-vertex": 2, "del-vertex": 3}


def _mul(a, b):
    return (a * b) & MASK


def _add(a, b):
    return (a + b) & MASK


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


def mix64(x):
    """util/rng.rs mix64: stateless SplitMix64 finalizer."""
    z = _add(x, 0x9E3779B97F4A7C15)
    z = _mul(z ^ (z >> 30), 0xBF58476D1CE4E5B9)
    z = _mul(z ^ (z >> 27), 0x94D049BB133111EB)
    return z ^ (z >> 31)


class SplitMix64:
    def __init__(self, seed):
        self.state = seed & MASK

    def next_u64(self):
        self.state = _add(self.state, 0x9E3779B97F4A7C15)
        z = self.state
        z = _mul(z ^ (z >> 30), 0xBF58476D1CE4E5B9)
        z = _mul(z ^ (z >> 27), 0x94D049BB133111EB)
        return z ^ (z >> 31)


class Rng:
    """util/rng.rs Rng: Xoshiro256++ seeded from SplitMix64."""

    def __init__(self, seed):
        sm = SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]

    def next_u64(self):
        s = self.s
        out = _add(_rotl(_add(s[0], s[3]), 23), s[0])
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return out

    def below(self, n):
        """Lemire multiply-shift: (next * n) >> 64."""
        assert n > 0
        return (self.next_u64() * n) >> 64


# ---------------------------------------------------------------------------
# DeltaCsr mirror
# ---------------------------------------------------------------------------


def csr_from_undirected(num_vertices, edges):
    """graph/csr.rs from_undirected_edges: counting sort, then each
    adjacency row sorted ascending."""
    adj = [[] for _ in range(num_vertices)]
    for a, b in edges:
        assert a != b
        adj[a].append(b)
        adj[b].append(a)
    indptr = [0]
    indices = []
    for row in adj:
        indices.extend(sorted(row))
        indptr.append(len(indices))
    return indptr, indices


class DeltaCsr:
    """delta.rs DeltaCsr: symmetric CSR with TOMBSTONE holes for
    deletions, per-vertex sorted overflow for insertions, periodic
    compaction, and incremental staleness witnesses."""

    def __init__(self, num_vertices, edges):
        self.indptr, self.indices = csr_from_undirected(
            num_vertices, edges
        )
        nv = num_vertices
        self.extra = [[] for _ in range(nv)]
        self.live_deg = [
            self.indptr[v + 1] - self.indptr[v] for v in range(nv)
        ]
        self.alive = [True] * nv
        self.dead = set()
        self.epoch = 0
        self.n_dead_slots = 0
        self.n_extra = 0
        self.n_live_vertices = nv
        self.n_live_dir_edges = len(self.indices)
        self.compactions = 0

    def num_vertices(self):
        return len(self.indptr) - 1

    def n_live_undirected(self):
        return self.n_live_dir_edges // 2

    def base_row(self, v):
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def neighbors(self, v):
        """Sorted merge of live base entries and the overflow row —
        ascending, exactly delta.rs for_neighbors."""
        base = [x for x in self.base_row(v) if x != TOMBSTONE]
        ex = self.extra[v]
        out, bi, ei = [], 0, 0
        while bi < len(base) or ei < len(ex):
            if bi < len(base) and (
                ei >= len(ex) or base[bi] <= ex[ei]
            ):
                out.append(base[bi])
                bi += 1
            else:
                out.append(ex[ei])
                ei += 1
        return out

    def has_edge(self, u, v):
        return v in self.base_row(u) or v in self.extra[u]

    def _insert_arc(self, u, v):
        row = self.extra[u]
        pos = 0
        while pos < len(row) and row[pos] < v:
            pos += 1
        row.insert(pos, v)
        self.n_extra += 1

    def _remove_arc(self, u, v):
        lo, hi = self.indptr[u], self.indptr[u + 1]
        for slot in range(lo, hi):
            if self.indices[slot] == v:
                self.indices[slot] = TOMBSTONE
                self.n_dead_slots += 1
                return
        self.extra[u].remove(v)
        self.n_extra -= 1

    def add_edge(self, u, v):
        assert u != v and self.alive[u] and self.alive[v]
        assert not self.has_edge(u, v)
        self._insert_arc(u, v)
        self._insert_arc(v, u)
        self.live_deg[u] += 1
        self.live_deg[v] += 1
        self.n_live_dir_edges += 2
        self.epoch += 1

    def del_edge(self, u, v):
        self._remove_arc(u, v)
        self._remove_arc(v, u)
        self.live_deg[u] -= 1
        self.live_deg[v] -= 1
        self.n_live_dir_edges -= 2
        self.epoch += 1

    def add_vertex(self):
        self.epoch += 1
        self.n_live_vertices += 1
        if self.dead:
            v = min(self.dead)  # revive the smallest dead id
            self.dead.remove(v)
            self.alive[v] = True
            return v, True
        v = self.num_vertices()
        self.indptr.append(self.indptr[-1])
        self.extra.append([])
        self.live_deg.append(0)
        self.alive.append(True)
        return v, False

    def del_vertex(self, v):
        assert self.alive[v]
        nbrs = self.neighbors(v)
        for u in nbrs:
            self.del_edge(v, u)
        self.alive[v] = False
        self.dead.add(v)
        self.n_live_vertices -= 1
        self.epoch += 1
        return nbrs

    def maybe_compact(self):
        if (self.n_dead_slots + self.n_extra) * 2 <= max(
            len(self.indices), 64
        ):
            return False
        indptr, indices = [0], []
        for v in range(self.num_vertices()):
            indices.extend(self.neighbors(v))
            indptr.append(len(indices))
        self.indptr, self.indices = indptr, indices
        self.extra = [[] for _ in range(self.num_vertices())]
        self.n_dead_slots = 0
        self.n_extra = 0
        self.compactions += 1
        return True

    def live_edge_pairs(self):
        pairs = []
        for v in range(self.num_vertices()):
            for u in self.neighbors(v):
                if u > v:
                    pairs.append((v, u))
        return pairs

    def check_witnesses(self):
        """delta.rs check_witnesses: recount everything against the
        incremental counters; every row strictly ascending; dead
        vertices have no edges."""
        assert (
            sum(self.alive) == self.n_live_vertices
        ), "live-vertex witness"
        assert len(self.dead) == self.num_vertices() - sum(self.alive)
        dir_edges = 0
        for v in range(self.num_vertices()):
            row = self.neighbors(v)
            assert row == sorted(set(row)), f"row {v} not ascending"
            assert len(row) == self.live_deg[v], f"live_deg[{v}]"
            if not self.alive[v]:
                assert not row, f"dead vertex {v} has edges"
            dir_edges += len(row)
        assert dir_edges == self.n_live_dir_edges, "edge witness"
        dead_slots = sum(
            1 for x in self.indices if x == TOMBSTONE
        )
        assert dead_slots == self.n_dead_slots, "tombstone witness"
        assert (
            sum(len(r) for r in self.extra) == self.n_extra
        ), "overflow witness"


# ---------------------------------------------------------------------------
# ChurnPlan mirror
# ---------------------------------------------------------------------------


def targets(rate, live):
    """delta.rs ChurnPlan::targets: max(1, floor(rate x live))."""
    import math

    return max(1, int(math.floor(rate * live)))


class ChurnPlan:
    """delta.rs ChurnPlan: canonicalized specs (sorted by op rank)
    plus a dedicated Rng stream. Specs are (op, rate, degree)."""

    def __init__(self, specs, seed):
        self.specs = sorted(specs, key=lambda s: RANK[s[0]])
        self.rng = Rng(mix64((seed ^ CHURN_SALT) & MASK))

    def pick_live(self, csr):
        nv = csr.num_vertices()
        for _ in range(OP_RETRIES):
            v = self.rng.below(nv)
            if csr.alive[v]:
                return v
        return None

    def round(self, csr):
        deltas = []
        for op, rate, degree in self.specs:
            if op == "add-edge":
                n = targets(rate, max(csr.n_live_undirected(), 1))
                for _ in range(n):
                    for _ in range(OP_RETRIES):
                        u = self.pick_live(csr)
                        v = self.pick_live(csr)
                        if u is None or v is None:
                            break
                        if u == v or csr.has_edge(u, v):
                            continue
                        csr.add_edge(u, v)
                        deltas.append(
                            ("add-edge", min(u, v), max(u, v))
                        )
                        break
            elif op == "del-edge":
                n = targets(rate, max(csr.n_live_undirected(), 1))
                for _ in range(n):
                    for _ in range(OP_RETRIES):
                        u = self.pick_live(csr)
                        if u is None:
                            break
                        d = csr.live_deg[u]
                        if d == 0:
                            continue
                        k = self.rng.below(d)
                        v = csr.neighbors(u)[k]
                        csr.del_edge(u, v)
                        deltas.append(
                            ("del-edge", min(u, v), max(u, v))
                        )
                        break
            elif op == "add-vertex":
                n = targets(rate, csr.n_live_vertices)
                for _ in range(n):
                    v, revived = csr.add_vertex()
                    nbrs = []
                    for _ in range(degree):
                        for _ in range(OP_RETRIES):
                            u = self.pick_live(csr)
                            if u is None:
                                break
                            if (
                                u == v
                                or u in nbrs
                                or csr.has_edge(v, u)
                            ):
                                continue
                            csr.add_edge(v, u)
                            nbrs.append(u)
                            break
                    deltas.append(("add-vertex", v, revived, nbrs))
            elif op == "del-vertex":
                n = targets(rate, csr.n_live_vertices)
                for _ in range(n):
                    if csr.n_live_vertices <= 2:
                        break
                    v = self.pick_live(csr)
                    if v is None:
                        break
                    nbrs = csr.del_vertex(v)
                    deltas.append(("del-vertex", v, nbrs))
        return deltas


def seed_graph(nv=240, ne=900, seed=0xF09):
    """Seeded random simple graph through the mirrored Rng, so the
    fixture itself is reproducible."""
    rng = Rng(seed)
    edges = set()
    while len(edges) < ne:
        u = rng.below(nv)
        v = rng.below(nv)
        if u != v:
            edges.add((min(u, v), max(u, v)))
    return nv, sorted(edges)


MIXED = [
    ("add-edge", 0.01, 2),
    ("del-edge", 0.008, 2),
    ("add-vertex", 0.004, 3),
    ("del-vertex", 0.002, 2),
]


# ---------------------------------------------------------------------------
# tests
# ---------------------------------------------------------------------------


def test_rng_mirror_is_deterministic_and_bounded():
    a, b = Rng(42), Rng(42)
    assert [a.next_u64() for _ in range(100)] == [
        b.next_u64() for _ in range(100)
    ]
    assert Rng(1).next_u64() != Rng(2).next_u64()
    r = Rng(7)
    seen = set()
    for _ in range(1000):
        x = r.below(10)
        assert 0 <= x < 10
        seen.add(x)
    assert seen == set(range(10))
    # mix64 of the shared salt is how every per-service churn stream
    # is derived — stateless, so equal inputs give equal streams
    assert mix64(CHURN_SALT) == mix64(CHURN_SALT)
    assert mix64(0) != mix64(1)


def test_mutated_csr_equals_from_scratch_rebuild():
    nv, edges = seed_graph()
    csr = DeltaCsr(nv, edges)
    plan = ChurnPlan(MIXED, seed=17)
    for _ in range(6):
        plan.round(csr)
        csr.maybe_compact()
        csr.check_witnesses()
        # the parity contract: live adjacency after in-place mutation
        # == a from-scratch CSR rebuilt from the live edge pairs
        rb_indptr, rb_indices = csr_from_undirected(
            csr.num_vertices(), csr.live_edge_pairs()
        )
        for v in range(csr.num_vertices()):
            assert (
                csr.neighbors(v)
                == rb_indices[rb_indptr[v]:rb_indptr[v + 1]]
            ), f"vertex {v} diverges from the rebuilt CSR"


def test_compaction_fires_under_heavy_deletion_and_is_invisible():
    nv, edges = seed_graph(160, 1200, seed=5)
    csr = DeltaCsr(nv, edges)
    plan = ChurnPlan(
        [("del-edge", 0.4, 2), ("add-edge", 0.1, 2)], seed=31
    )
    for _ in range(10):
        plan.round(csr)
        before = [csr.neighbors(v) for v in range(csr.num_vertices())]
        csr.maybe_compact()
        after = [csr.neighbors(v) for v in range(csr.num_vertices())]
        assert before == after, "compaction changed a neighbor walk"
        csr.check_witnesses()
    assert csr.compactions > 0, (
        "a 40%-per-round deletion trace must trip the half-stored-"
        "arcs compaction threshold"
    )


def test_trickle_rate_yields_exactly_one_mutation_per_round():
    assert targets(1e-7, 10**6) == 1
    assert targets(0.5, 10) == 5
    assert targets(0.0049, 1000) == 4  # floor, not round
    nv, edges = seed_graph()
    csr = DeltaCsr(nv, edges)
    plan = ChurnPlan([("del-edge", 1e-7, 2)], seed=91)
    for _ in range(4):
        deltas = plan.round(csr)
        assert len(deltas) == 1
        csr.check_witnesses()


def test_spec_declaration_order_is_canonicalized_away():
    fwd = [("add-edge", 0.02, 2), ("del-vertex", 0.005, 2)]
    rev = list(reversed(fwd))

    def run(specs):
        nv, edges = seed_graph(180, 700, seed=3)
        csr = DeltaCsr(nv, edges)
        plan = ChurnPlan(specs, seed=55)
        trace = []
        for _ in range(4):
            trace.append(plan.round(csr))
        return trace, csr.live_edge_pairs()

    assert run(fwd) == run(rev)


def test_edge_delta_touches_at_most_two_owners():
    # the invalidation plane's upper bound: one edge delta can dirty
    # only the owners of its two endpoints — every other fog's
    # grounding is untouched by construction
    nv, edges = seed_graph()
    n_fogs = 8
    owner = [
        (mix64(v) % n_fogs) for v in range(nv + 64)
    ]  # slack for appended ids
    csr = DeltaCsr(nv, edges)
    plan = ChurnPlan([("del-edge", 1e-7, 2)], seed=91)
    for _ in range(5):
        deltas = plan.round(csr)
        (kind, u, v) = deltas[0]
        assert kind == "del-edge"
        touched = {owner[u], owner[v]}
        assert len(touched) <= 2
        assert n_fogs - len(touched) >= n_fogs - 2


def test_vertex_delete_then_revive_keeps_id_space_dense():
    nv, edges = seed_graph(120, 400, seed=9)
    csr = DeltaCsr(nv, edges)
    # delete two vertices, revive one: smallest dead id comes back
    a, b = 7, 3
    csr.del_vertex(a)
    csr.del_vertex(b)
    assert not csr.alive[a] and not csr.alive[b]
    assert csr.live_deg[a] == 0 and csr.live_deg[b] == 0
    v, revived = csr.add_vertex()
    assert (v, revived) == (min(a, b), True)
    # a second add with no dead ids left appends a fresh one
    v2, revived2 = csr.add_vertex()
    assert (v2, revived2) == (max(a, b), True)
    v3, revived3 = csr.add_vertex()
    assert (v3, revived3) == (nv, False)
    assert csr.num_vertices() == nv + 1
    csr.check_witnesses()
