"""Numpy mirror of the PR 8 chaos plane's straggler detector
(rust/src/traffic/chaos.rs ``EwmaDetector``) and the fault-window
damage accounting (``window_damage``).

The build container has no Rust toolchain (see ROADMAP.md caveat), so
this mirror replicates the shipped arithmetic statement-for-statement
— priming (first sample sets mean = x, dev = x/2), the
dev-before-mean EWMA update order against the PREVIOUS mean, the
``max(mean + beta * dev, floor)`` deadline, and the outstanding-task
overdue rule — and checks the claims the Rust unit tests make:

* the worked example shared with chaos.rs: durations 0.5/0.7/0.8 at
  alpha=0.25 give mean=0.6125, dev=0.240625, deadline=1.334375; an
  outstanding task is not overdue at elapsed 1.0 and overdue at 1.4;
* the floor keeps microsecond-scale services from hair-trigger
  deadlines;
* under a stationary random workload the deadline converges above the
  p99 duration (few false alarms) while a 10x straggler is flagged;
* ``window_damage``'s p99-delta / goodput-dip / shed counts agree
  with a direct recomputation on random sample sets.

Everything is float64, matching the Rust f64 arithmetic exactly, so
comparisons are ``==`` where the op order is mirrored and 1e-12
otherwise.
"""

import numpy as np

EWMA_ALPHA = 0.25
EWMA_BETA = 3.0
EWMA_FLOOR_S = 0.05


class EwmaDetectorMirror:
    """chaos.rs EwmaDetector: per-fog EWMA of task durations with a
    mean-absolute-deviation band. ``start`` only records the OLDEST
    outstanding task (a silent fog's first unanswered task keeps
    aging); ``complete`` clears it and feeds the duration, updating
    dev against the previous mean, then the mean."""

    def __init__(self, n_fogs, alpha=EWMA_ALPHA, beta=EWMA_BETA,
                 floor_s=EWMA_FLOOR_S):
        self.alpha = alpha
        self.beta = beta
        self.floor_s = floor_s
        self.mean = [0.0] * n_fogs
        self.dev = [0.0] * n_fogs
        self.primed = [False] * n_fogs
        self.started = [None] * n_fogs

    def start(self, fog, now):
        if self.started[fog] is None:
            self.started[fog] = now

    def complete(self, fog, dur):
        self.started[fog] = None
        if not self.primed[fog]:
            self.mean[fog] = dur
            self.dev[fog] = dur / 2.0
            self.primed[fog] = True
        else:
            # dev first, against the mean that existed when the
            # sample arrived — the exact Rust update order
            self.dev[fog] = (
                self.alpha * abs(dur - self.mean[fog])
                + (1.0 - self.alpha) * self.dev[fog]
            )
            self.mean[fog] = (
                self.alpha * dur + (1.0 - self.alpha) * self.mean[fog]
            )

    def deadline(self, fog):
        return max(
            self.mean[fog] + self.beta * self.dev[fog], self.floor_s
        )

    def overdue(self, fog, now):
        return (
            self.primed[fog]
            and self.started[fog] is not None
            and now - self.started[fog] > self.deadline(fog)
        )


def _p99(lats):
    """chaos.rs p99: nearest-rank, ceil(0.99 n) 1-based, clamped."""
    xs = sorted(lats)
    idx = min(max(int(np.ceil(len(xs) * 0.99)), 1), len(xs)) - 1
    return xs[idx]


def window_damage_mirror(samples, shed, t0, t1, duration_s):
    """chaos.rs window_damage: SLO damage over the HALF-OPEN fault
    window [t0, t1). samples = (finish, latency, ok) triples; shed =
    shed times. Returns (p99_delta_ms, goodput_dip, shed_during)."""
    t1 = max(min(t1, duration_s), t0)
    lat_in, lat_out = [], []
    good_in = good_out = 0
    for ft, lat, ok in samples:
        if t0 <= ft < t1:
            lat_in.append(lat)
            good_in += bool(ok)
        else:
            lat_out.append(lat)
            good_out += bool(ok)
    # the delta is defined only when both sides have completions
    p99_delta_ms = (
        (_p99(lat_in) - _p99(lat_out)) * 1e3
        if lat_in and lat_out
        else 0.0
    )
    win = t1 - t0
    rest = max(duration_s - win, 0.0)
    rate_in = good_in / win if win > 0.0 else 0.0
    rate_out = good_out / rest if rest > 0.0 else 0.0
    dip = (
        min(max(1.0 - rate_in / rate_out, 0.0), 1.0)
        if rate_out > 0.0
        else 0.0
    )
    shed_during = sum(1 for t in shed if t0 <= t < t1)
    return p99_delta_ms, dip, shed_during


# ---------------------------------------------------------------------------
# tests: the worked example shared with the Rust unit tests
# ---------------------------------------------------------------------------


def test_worked_example_matches_rust_unit_case():
    det = EwmaDetectorMirror(1)
    for d in (0.5, 0.7, 0.8):
        det.complete(0, d)
    # priming: mean=0.5, dev=0.25; then two EWMA steps with
    # dev-before-mean ordering (1e-12: the algebraic values round in
    # the last ulp, identically in Rust f64 and numpy float64)
    assert det.mean[0] == 0.6125
    assert abs(det.dev[0] - 0.240625) < 1e-12
    assert abs(det.deadline(0) - 1.334375) < 1e-12
    # an outstanding task ages against that deadline
    det.start(0, 10.0)
    assert not det.overdue(0, 11.0)  # elapsed 1.0 < 1.334375
    assert det.overdue(0, 11.4)  # elapsed 1.4 > 1.334375


def test_update_order_is_dev_before_mean():
    # same samples, opposite order: mean-first would give a different
    # deviation, so this pins the statement order
    det = EwmaDetectorMirror(1)
    det.complete(0, 1.0)  # mean=1.0 dev=0.5
    det.complete(0, 2.0)
    # dev against PREVIOUS mean 1.0: 0.25*1.0 + 0.75*0.5 = 0.625
    assert det.dev[0] == 0.625
    assert det.mean[0] == 1.25
    # mean-first would have been 0.25*|2-1.25| + 0.75*0.5 = 0.5625
    assert det.dev[0] != 0.5625


def test_priming_and_outstanding_task_semantics():
    det = EwmaDetectorMirror(2)
    # never fires before the first completed sample primes the fog
    det.start(0, 0.0)
    assert not det.overdue(0, 1e9)
    # the floor bounds hair-trigger deadlines from fast services
    det.complete(0, 1e-4)
    assert det.deadline(0) == EWMA_FLOOR_S
    # start() keeps the OLDEST outstanding task (a silent fog's first
    # unanswered task keeps aging while later batches pile up)
    det.start(1, 5.0)
    det.start(1, 9.0)
    assert det.started[1] == 5.0
    # completion clears the outstanding marker
    det.complete(1, 0.1)
    assert det.started[1] is None


def test_deadline_tracks_stationary_load_and_flags_straggler():
    rng = np.random.default_rng(0xC4A0)
    det = EwmaDetectorMirror(1)
    durs = np.abs(rng.normal(0.2, 0.02, 400))
    for d in durs:
        det.complete(0, float(d))
    dl = det.deadline(0)
    # converged deadline sits above the p99 duration (few false
    # alarms) but within a small multiple of the mean (responsive)
    assert dl > float(np.quantile(durs[200:], 0.99))
    assert dl < 4.0 * float(np.mean(durs[200:]))
    # a 10x straggler blows straight through it
    assert 10.0 * float(np.mean(durs)) > dl
    det.start(0, 100.0)
    assert det.overdue(0, 100.0 + 10.0 * float(np.mean(durs)))


def test_crash_detection_latency_is_one_deadline():
    # a fog that stops replying is flagged exactly one deadline after
    # its oldest outstanding task started — the time-to-detect model
    # the faults report is built on
    det = EwmaDetectorMirror(1)
    for _ in range(50):
        det.complete(0, 0.1)
    dl = det.deadline(0)
    t0 = 42.0
    det.start(0, t0)
    eps = 1e-9
    assert not det.overdue(0, t0 + dl)  # strict inequality
    assert det.overdue(0, t0 + dl + eps)


# ---------------------------------------------------------------------------
# tests: window damage accounting
# ---------------------------------------------------------------------------


def test_window_damage_on_a_synthetic_fault_hole():
    # 0..10s run, fault window [4, 6): latencies triple and half the
    # inside completions bust the SLO
    samples = []
    for i in range(1000):
        t = 10.0 * i / 1000.0
        inside = 4.0 <= t < 6.0
        lat = 0.3 if inside else 0.1
        ok = (i % 2 == 0) if inside else True
        samples.append((t, lat, ok))
    shed = [4.5, 5.0, 5.5, 9.0]
    p99_delta_ms, dip, shed_during = window_damage_mirror(
        samples, shed, 4.0, 6.0, 10.0
    )
    assert abs(p99_delta_ms - 200.0) < 1e-9  # 300ms inside - 100ms out
    assert abs(dip - 0.5) < 1e-9  # exactly half the goodput rate
    assert shed_during == 3  # 9.0 is outside the window


def test_window_damage_clamps_and_degenerates():
    # dip clamps into [0, 1] even when the window is BETTER than the
    # rest of the run, and t1 clamps to the run end
    samples = [(t, 0.1, True) for t in np.linspace(0.0, 10.0, 200)]
    _, dip, _ = window_damage_mirror(samples, [], 2.0, 4.0, 10.0)
    assert 0.0 <= dip <= 1.0
    p99d, dip2, shed = window_damage_mirror(
        samples, [9.5], 8.0, 50.0, 10.0
    )
    assert 0.0 <= dip2 <= 1.0
    assert shed == 1
    assert np.isfinite(p99d)
