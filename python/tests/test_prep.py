"""Tests for the graph→model-input preparation (prep.py) — the conventions
the Rust runtime mirrors (rust/src/runtime/pad.rs)."""

import numpy as np
import pytest

from compile import fgio, prep


def make_graph(rng, v=30, classes=2, f=6, dur=1):
    deg = rng.integers(1, 5, v)
    indptr = np.zeros(v + 1, np.uint64)
    indptr[1:] = np.cumsum(deg)
    indices = rng.integers(0, v, int(indptr[-1])).astype(np.uint32)
    shape = (v, f, dur) if dur > 1 else (v, f)
    return fgio.Graph(
        indptr=indptr,
        indices=indices,
        features=rng.normal(size=shape).astype(np.float32),
        labels=(rng.integers(0, classes, v).astype(np.int32)
                if classes > 0 else None),
        num_classes=classes,
        duration=dur,
    )


def test_gcn_inv_deg_is_one_over_degree_plus_one():
    rng = np.random.default_rng(0)
    g = make_graph(rng)
    src, dst, ew, inv_deg = prep.edge_arrays(g, "gcn")
    deg_in = np.bincount(dst, minlength=g.num_vertices)
    np.testing.assert_allclose(inv_deg[:, 0], 1.0 / (deg_in + 1), rtol=1e-6)
    assert len(src) == g.num_edges
    assert np.all(ew == 1.0)


def test_gat_appends_self_loops_last():
    rng = np.random.default_rng(1)
    g = make_graph(rng)
    v = g.num_vertices
    src, dst, ew, inv_deg = prep.edge_arrays(g, "gat")
    assert len(src) == g.num_edges + v
    np.testing.assert_array_equal(src[-v:], np.arange(v))
    np.testing.assert_array_equal(dst[-v:], np.arange(v))
    assert np.all(inv_deg == 1.0)


def test_sage_inv_deg_floors_at_one():
    rng = np.random.default_rng(2)
    g = make_graph(rng)
    # force a vertex with no in-edges
    g.indices = np.where(g.indices == 0, 1, g.indices).astype(np.uint32)
    _, dst, _, inv_deg = prep.edge_arrays(g, "sage")
    assert 0 not in dst
    assert inv_deg[0, 0] == 1.0


def test_dense_norm_adj_rows_sum_to_one():
    rng = np.random.default_rng(3)
    g = make_graph(rng)
    a = prep.dense_norm_adj(g)
    np.testing.assert_allclose(a.sum(axis=1), 1.0, rtol=1e-5)
    # self loop present
    assert np.all(np.diag(a) > 0)


def test_pems_windows_alignment_and_units():
    rng = np.random.default_rng(4)
    g = make_graph(rng, v=10, classes=0, f=3, dur=80)
    g.labels = None
    xs, ys, mean, std = prep.pems_windows(g, window=12, horizon=12,
                                          stride=4)
    n, v, d = xs.shape
    assert (v, d) == (10, 36)
    assert ys.shape == (n, 10, 12)
    # targets are in ORIGINAL units: first target of window 0 equals the
    # series at t = window
    np.testing.assert_allclose(ys[0, :, 0], g.features[:, 0, 12],
                               rtol=1e-6)
    # inputs are standardized per channel
    assert abs(float(xs.mean())) < 0.5
    # de-normalizing the input recovers the series
    x0 = xs[0, 0, :12] * std[0] + mean[0]
    np.testing.assert_allclose(x0, g.features[0, 0, :12], rtol=1e-4)


def test_train_test_split_is_deterministic_and_disjoint():
    tr1, te1 = prep.train_test_split(1000)
    tr2, te2 = prep.train_test_split(1000)
    np.testing.assert_array_equal(tr1, tr2)
    assert set(tr1).isdisjoint(set(te1))
    assert len(tr1) + len(te1) == 1000
    assert 0.6 < len(tr1) / 1000 < 0.8


def test_split_matches_rust_hash():
    """The Rust side (serving/accuracy.rs) re-derives the same split."""
    _, te = prep.train_test_split(50)
    expected = [i for i in range(50)
                if (i * 2654435761 % 2**32) % 1000 >= 700]
    np.testing.assert_array_equal(te, expected)
