"""Round-trip tests for the .fgr / .fgw binary interchange formats."""

import numpy as np
import pytest

from compile import fgio


def make_graph(rng, v=20, e=60, f=5, classes=3, dur=1):
    deg = rng.integers(0, 6, v)
    indptr = np.zeros(v + 1, np.uint64)
    indptr[1:] = np.cumsum(deg)
    e = int(indptr[-1])
    indices = rng.integers(0, v, e).astype(np.uint32)
    shape = (v, f, dur) if dur > 1 else (v, f)
    features = rng.normal(size=shape).astype(np.float32)
    labels = (rng.integers(0, classes, v).astype(np.int32)
              if classes > 0 else None)
    return fgio.Graph(indptr=indptr, indices=indices, features=features,
                      labels=labels,
                      coords=rng.normal(size=(v, 2)).astype(np.float32),
                      num_classes=classes, duration=dur)


def test_fgr_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    g = make_graph(rng)
    p = str(tmp_path / "g.fgr")
    fgio.write_fgr(p, g)
    g2 = fgio.read_fgr(p)
    np.testing.assert_array_equal(g2.indptr, g.indptr)
    np.testing.assert_array_equal(g2.indices, g.indices)
    np.testing.assert_array_equal(g2.features, g.features)
    np.testing.assert_array_equal(g2.labels, g.labels)
    np.testing.assert_array_equal(g2.coords, g.coords)
    assert g2.num_classes == 3 and g2.duration == 1


def test_fgr_roundtrip_temporal_with_targets(tmp_path):
    rng = np.random.default_rng(1)
    g = make_graph(rng, dur=7, classes=0)
    g.labels = None
    g.targets = rng.normal(size=(g.num_vertices, 4)).astype(np.float32)
    p = str(tmp_path / "t.fgr")
    fgio.write_fgr(p, g)
    g2 = fgio.read_fgr(p)
    assert g2.features.shape == g.features.shape
    assert g2.labels is None
    np.testing.assert_array_equal(g2.targets, g.targets)


def test_fgr_bad_magic(tmp_path):
    p = tmp_path / "bad.fgr"
    p.write_bytes(b"NOPE" + b"\0" * 64)
    with pytest.raises(ValueError, match="bad magic"):
        fgio.read_fgr(str(p))


def test_fgw_roundtrip(tmp_path):
    rng = np.random.default_rng(2)
    tensors = [
        ("l0.w", rng.normal(size=(5, 7)).astype(np.float32)),
        ("l0.b", rng.normal(size=(7,)).astype(np.float32)),
        ("ids", rng.integers(0, 100, (3, 2)).astype(np.int32)),
        ("scalarish", np.array([3.5], np.float32)),
    ]
    p = str(tmp_path / "w.fgw")
    fgio.write_fgw(p, tensors)
    out = fgio.read_fgw(p)
    assert [n for n, _ in out] == [n for n, _ in tensors]
    for (_, a), (_, b) in zip(out, tensors):
        np.testing.assert_array_equal(a, b)
        assert a.dtype == b.dtype


def test_fgw_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(ValueError, match="unsupported dtype"):
        fgio.write_fgw(str(tmp_path / "x.fgw"),
                       [("bad", np.zeros(3, np.float64))])


def test_edge_list_matches_csr():
    rng = np.random.default_rng(3)
    g = make_graph(rng)
    src, dst = g.edge_list()
    assert len(src) == g.num_edges
    for v in range(g.num_vertices):
        lo, hi = int(g.indptr[v]), int(g.indptr[v + 1])
        assert np.all(src[lo:hi] == v)
        np.testing.assert_array_equal(dst[lo:hi], g.indices[lo:hi])
