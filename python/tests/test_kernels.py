"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/acts/tiles; the oracle is the ground truth the
whole stack (including the Rust-executed HLO) is anchored to.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_linear import (
    ACT_ELU, ACT_LEAKY_RELU, ACT_NONE, ACT_RELU, fused_linear,
    mxu_utilization_estimate, vmem_footprint_bytes)
from compile.kernels.scale_combine import (
    COMBINE_ADD_SELF, COMBINE_AGG_ONLY, scale_combine)

ACTS = [ACT_NONE, ACT_RELU, ACT_ELU, ACT_LEAKY_RELU]


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("act", ACTS)
def test_fused_linear_matches_ref_all_acts(act):
    rng = np.random.default_rng(act)
    x, w, b = rand(rng, 200, 52), rand(rng, 52, 64), rand(rng, 64)
    got = fused_linear(x, w, b, act=act)
    want = ref.fused_linear_ref(x, w, b, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 140),
    n=st.integers(1, 140),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_shape_sweep(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    got = fused_linear(x, w, b, act=act)
    want = ref.fused_linear_ref(x, w, b, act=act)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bm,bn,bk", [(64, 64, 64), (128, 128, 128),
                                      (256, 128, 64), (32, 256, 128)])
def test_fused_linear_tile_sweep(bm, bn, bk):
    """Any tile configuration must give the same numbers (perf knob only)."""
    rng = np.random.default_rng(3)
    x, w, b = rand(rng, 300, 100), rand(rng, 100, 70), rand(rng, 70)
    got = fused_linear(x, w, b, act=ACT_RELU, bm=bm, bn=bn, bk=bk)
    want = ref.fused_linear_ref(x, w, b, act=ACT_RELU)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fused_linear_exact_tile_no_padding():
    rng = np.random.default_rng(4)
    x, w, b = rand(rng, 256, 128), rand(rng, 128, 128), rand(rng, 128)
    got = fused_linear(x, w, b)
    want = ref.fused_linear_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", [COMBINE_ADD_SELF, COMBINE_AGG_ONLY])
def test_scale_combine_modes(mode):
    rng = np.random.default_rng(5)
    agg, h = rand(rng, 333, 52), rand(rng, 333, 52)
    s = jnp.asarray(rng.random((333, 1)).astype(np.float32))
    got = scale_combine(agg, h, s, mode=mode)
    want = ref.scale_combine_ref(agg, h, s, mode=mode)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(v=st.integers(1, 600), f=st.integers(1, 128),
       seed=st.integers(0, 2**31 - 1))
def test_scale_combine_shape_sweep(v, f, seed):
    rng = np.random.default_rng(seed)
    agg, h = rand(rng, v, f), rand(rng, v, f)
    s = jnp.asarray(rng.random((v, 1)).astype(np.float32))
    got = scale_combine(agg, h, s)
    want = ref.scale_combine_ref(agg, h, s)
    assert got.shape == (v, f)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_segment_aggregate_padding_edges_are_noops():
    rng = np.random.default_rng(6)
    h = rand(rng, 10, 4)
    src = jnp.array([0, 1, 2, 0, 0], jnp.int32)
    dst = jnp.array([3, 3, 4, 0, 0], jnp.int32)
    ew = jnp.array([1, 1, 1, 0, 0], jnp.float32)  # last two are padding
    agg = ref.segment_aggregate(h, src, dst, ew, 10)
    np.testing.assert_allclose(agg[3], h[0] + h[1], rtol=1e-6)
    np.testing.assert_allclose(agg[4], h[2], rtol=1e-6)
    np.testing.assert_allclose(agg[0], jnp.zeros(4), atol=0)


def test_segment_softmax_sums_to_one_per_destination():
    rng = np.random.default_rng(7)
    e, v = 200, 40
    src = jnp.asarray(rng.integers(0, v, e).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, v, e).astype(np.int32))
    ew = jnp.asarray((rng.random(e) > 0.3).astype(np.float32))
    logits = rand(rng, e)
    alpha = ref.segment_softmax(logits, dst, ew, v)
    sums = np.zeros(v, np.float32)
    np.add.at(sums, np.asarray(dst), np.asarray(alpha))
    has_edge = np.zeros(v, bool)
    np.add.at(has_edge, np.asarray(dst)[np.asarray(ew) > 0], True)
    np.testing.assert_allclose(sums[has_edge], 1.0, rtol=1e-5)
    assert np.all(np.asarray(alpha)[np.asarray(ew) == 0] == 0.0)


def test_segment_softmax_extreme_logits_stable():
    src = jnp.array([0, 1], jnp.int32)
    dst = jnp.array([2, 2], jnp.int32)
    ew = jnp.ones(2, jnp.float32)
    alpha = ref.segment_softmax(jnp.array([1e4, -1e4], jnp.float32),
                                dst, ew, 3)
    assert np.isfinite(np.asarray(alpha)).all()
    np.testing.assert_allclose(float(alpha.sum()), 1.0, rtol=1e-5)


def test_vmem_footprint_within_budget():
    # 128^3 tiles must fit comfortably in 16 MiB VMEM.
    assert vmem_footprint_bytes(128, 128, 128) < 16 * 2**20 // 8


def test_mxu_utilization_estimate_bounds():
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    u = mxu_utilization_estimate(129, 1, 1)
    assert 0 < u < 0.01
