//! Adaptive workload scheduling demo (paper §III-F / Fig. 16): replay a
//! background-load trace against a 4-node cluster and watch the dual-mode
//! scheduler migrate vertices off the overloaded node (diffusion) or
//! trigger a global IEP replan.
//!
//!     cargo run --release --example adaptive_scheduling

use fograph::fog::{Cluster, LoadTrace};
use fograph::graph::datasets;
use fograph::net::NetKind;
use fograph::profile::PerfModel;
use fograph::scheduler::{diffusion, schedule, SchedulerConfig,
                         SchedulerDecision};
use fograph::serving::{Placement, ServeOpts};

fn main() {
    let data_dir = std::path::Path::new("data");
    println!("== dual-mode adaptive scheduling on a load ramp ==\n");
    let g = datasets::load_or_generate(data_dir, "siot")
        .expect("siot is a known dataset");
    let spec = datasets::SIOT;
    let cluster = Cluster::case_study(NetKind::Wifi);
    let n = cluster.len();
    let opts = ServeOpts::new("gcn", Placement::Iep,
                              ServeOpts::co_codec(&g));
    let host = PerfModel::uncalibrated();

    // initial IEP layout under idle loads
    let omegas = vec![host.clone(); n];
    let mut assignment = fograph::serving::pipeline::place(
        &g, &cluster, &opts, &omegas, &spec,
    );
    let count = |a: &[u32], j: u32| a.iter().filter(|&&x| x == j).count();
    println!("initial placement: {:?}",
             (0..n as u32).map(|j| count(&assignment, j)).collect::<Vec<_>>());

    let trace = LoadTrace::fig16(n, 200, 42);
    let cfg = SchedulerConfig::default();
    for t in (0..200).step_by(20) {
        let loads: Vec<f64> = (0..n).map(|j| trace.at(t, j)).collect();
        // scaled per-node models = host ω × capability / (1 - load)
        let scaled: Vec<PerfModel> = (0..n)
            .map(|j| {
                let m = cluster.nodes[j].node_type.cpu_multiplier()
                    / (1.0 - loads[j]);
                PerfModel {
                    beta_v: host.beta_v * m,
                    beta_n: host.beta_n * m,
                    intercept: host.intercept * m,
                    r2: 1.0,
                }
            })
            .collect();
        let times = diffusion::estimate_times(&g, &assignment, n, &scaled);
        let decision = schedule(&g, &spec, &cluster, &opts,
                                &mut assignment, &times, &scaled, &cfg);
        let sizes: Vec<usize> =
            (0..n as u32).map(|j| count(&assignment, j)).collect();
        let what = match decision {
            SchedulerDecision::Keep => "keep".to_string(),
            SchedulerDecision::Diffused(m) => format!("diffuse {m} vertices"),
            SchedulerDecision::Replanned => "GLOBAL REPLAN".to_string(),
        };
        println!(
            "t={t:>3}  loads={loads:.2?}  placement={sizes:?}  -> {what}"
        );
    }
    println!("\nnode 4's load ramp pushes its partition down, then the \
              release hands vertices back.");
}
