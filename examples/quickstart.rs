//! Quickstart: one Fograph inference on the SIoT twin, end to end —
//! dataset → IEP placement → compressed collection → distributed BSP
//! execution via the AOT PJRT runtime — with the latency breakdown.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the PJRT engine when `make artifacts` has been run, otherwise
//! falls back to the pure-Rust reference engine.

use fograph::fog::Cluster;
use fograph::graph::datasets;
use fograph::net::NetKind;
use fograph::profile::PerfModel;
use fograph::runtime::{Engine, EngineKind};
use fograph::serving::{serve, Placement, ServeOpts};

fn main() {
    let data_dir = std::path::Path::new("data");
    let artifacts = std::path::Path::new("artifacts");

    println!("== Fograph quickstart: GCN on the SIoT twin ==\n");
    let g = datasets::load_or_generate(data_dir, "siot")
        .expect("siot is a known dataset");
    let spec = datasets::SIOT;
    println!(
        "graph: {} vertices, {} edges, {}-dim features",
        g.num_vertices(),
        g.undirected_edges(),
        g.feature_dim
    );

    let mut engine = Engine::new(EngineKind::Pjrt, artifacts)
        .unwrap_or_else(|e| {
            println!("(PJRT unavailable: {e}; using reference engine)");
            Engine::new(EngineKind::Reference, artifacts).unwrap()
        });

    // The 6-node heterogeneous testbed of §IV-B over 5G.
    let cluster = Cluster::testbed(NetKind::Cell5G);
    let opts = ServeOpts::new("gcn", Placement::Iep,
                              ServeOpts::co_codec(&g));
    let omegas = vec![PerfModel::uncalibrated(); cluster.len()];

    let report = serve(&g, &spec, &cluster, &opts, &omegas, &mut engine)
        .expect("serving failed");

    println!("\nFograph serving report (5G, 1A+4B+1C):");
    println!("  end-to-end latency : {:.4} s", report.total_s);
    println!("    data collection  : {:.4} s", report.collection_s);
    println!("    execution        : {:.4} s", report.execution_s);
    println!("    BSP sync         : {:.4} s", report.sync_s);
    println!("    unpack (pipelined): {:.4} s", report.unpack_s);
    println!("  throughput         : {:.2} inf/s", report.throughput);
    println!(
        "  upload: {:.2} MB on the wire vs {:.2} MB raw ({:.1}% saved \
         by DAQ+LZ4)",
        report.wire_bytes as f64 / 1e6,
        report.raw_bytes as f64 / 1e6,
        (1.0 - report.wire_bytes as f64 / report.raw_bytes as f64) * 100.0
    );
    println!("\nper-fog placement (heterogeneity-aware):");
    for (j, (v, e)) in report
        .per_fog_vertices
        .iter()
        .zip(&report.per_fog_exec_s)
        .enumerate()
    {
        println!(
            "  fog {} ({}): {:>6} vertices, exec {:.4} s",
            j + 1,
            cluster.nodes[j].node_type.name(),
            v,
            e
        );
    }
}
