//! Traffic-flow forecasting case study (paper §IV-C): serve ASTGCN
//! inference windows over the PeMS sensor-network twin with the 4-node
//! cluster (1×A, 2×B, 1×C), stepping through an afternoon of traffic and
//! reporting per-window latency plus forecasting error against the ground
//! truth.
//!
//!     cargo run --release --example traffic_forecast

use fograph::fog::Cluster;
use fograph::graph::datasets;
use fograph::net::NetKind;
use fograph::profile::PerfModel;
use fograph::runtime::{Engine, EngineKind};
use fograph::serving::accuracy::forecast_errors;
use fograph::serving::{serve, Placement, ServeOpts};

fn main() {
    let data_dir = std::path::Path::new("data");
    let artifacts = std::path::Path::new("artifacts");
    println!("== PeMS traffic flow forecasting with ASTGCN ==\n");
    let g = datasets::load_or_generate(data_dir, "pems")
        .expect("pems is a known dataset");
    let spec = datasets::PEMS;
    println!(
        "sensor network: {} loop detectors, {} road segments, {} days of \
         5-minute readings",
        g.num_vertices(),
        g.undirected_edges(),
        g.duration / 288
    );

    let mut engine = Engine::new(EngineKind::Pjrt, artifacts)
        .unwrap_or_else(|e| {
            println!("(PJRT unavailable: {e}; using reference engine)");
            Engine::new(EngineKind::Reference, artifacts).unwrap()
        });
    let cluster = Cluster::case_study(NetKind::Cell5G);
    let omegas = vec![PerfModel::uncalibrated(); cluster.len()];

    // step through 6 consecutive forecast queries (afternoon of day 6)
    let day6_afternoon = 5 * 288 + 180;
    println!("\nquery  window@      latency    15-min MAE   30-min MAE");
    for q in 0..6 {
        let start = day6_afternoon + q * 12;
        let mut opts = ServeOpts::new("astgcn", Placement::Iep,
                                      ServeOpts::co_codec(&g));
        opts.window_start = start;
        opts.keep_outputs = true;
        let r = serve(&g, &spec, &cluster, &opts, &omegas, &mut engine)
            .expect("serving failed");
        let outputs = r.outputs.as_ref().unwrap();
        let e15 = forecast_errors(&g, &spec, outputs, r.out_dim, start, 3);
        let e30 = forecast_errors(&g, &spec, outputs, r.out_dim, start, 6);
        let hh = (start % 288) / 12;
        let mm = (start % 12) * 5;
        println!(
            "  {q}    {hh:02}:{mm:02}      {:.4} s    {:>8.2}    {:>8.2}",
            r.total_s, e15.mae, e30.mae
        );
    }
    println!(
        "\n(MAE in vehicles / 5 min; real weights required for sensible \
         errors — run `make artifacts` first.)"
    );
}
