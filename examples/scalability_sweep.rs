//! Scalability sweep (paper §IV-E, Fig. 17 in miniature): serve the
//! RMAT-20K twin with 1–6 homogeneous fog nodes and watch the latency
//! curve flatten as resources become ample.
//!
//!     cargo run --release --example scalability_sweep

use fograph::fog::Cluster;
use fograph::graph::datasets;
use fograph::net::NetKind;
use fograph::profile::PerfModel;
use fograph::runtime::{Engine, EngineKind};
use fograph::serving::{serve, Placement, ServeOpts};

fn main() {
    let data_dir = std::path::Path::new("data");
    let artifacts = std::path::Path::new("artifacts");
    println!("== scalability: RMAT-20K across growing type-B clusters ==\n");
    let g = datasets::load_or_generate(data_dir, "rmat20k")
        .expect("rmat20k is a known dataset");
    let spec = datasets::spec_by_name("rmat20k").unwrap();
    let mut engine =
        Engine::new(EngineKind::Reference, artifacts).unwrap();

    println!("fogs   latency    collect    exec      sync      throughput");
    let mut one_fog = 0.0;
    for n in [1usize, 2, 3, 4, 6] {
        let cluster = Cluster::uniform_b(n, NetKind::Wifi);
        let placement = if n == 1 {
            Placement::SingleNode(0)
        } else {
            Placement::Iep
        };
        let opts = ServeOpts::new("gcn", placement,
                                  ServeOpts::co_codec(&g));
        let omegas = vec![PerfModel::uncalibrated(); n];
        let r = serve(&g, &spec, &cluster, &opts, &omegas, &mut engine)
            .expect("serve");
        if n == 1 {
            one_fog = r.total_s;
        }
        println!(
            "  {n}    {:.4} s   {:.4} s   {:.4} s  {:.4} s   {:.2} inf/s \
             ({:.2}x vs 1 fog)",
            r.total_s, r.collection_s, r.execution_s, r.sync_s,
            r.throughput, one_fog / r.total_s
        );
    }
}
