//! Cloud vs fog vs Fograph on the SIoT social-IoT twin (the paper's main
//! comparison, Fig. 11/12 for one cell of the grid), serving a stream of
//! classification queries and reporting latency, throughput and accuracy.
//!
//!     cargo run --release --example siot_serving [-- --net 4g]

use fograph::compress::Codec;
use fograph::fog::Cluster;
use fograph::graph::datasets;
use fograph::net::NetKind;
use fograph::profile::PerfModel;
use fograph::runtime::{Engine, EngineKind};
use fograph::serving::accuracy::accuracy;
use fograph::serving::{serve, Placement, ServeOpts};
use fograph::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[]);
    let net = NetKind::parse(args.get_or("net", "4g")).expect("bad --net");
    let data_dir = std::path::Path::new("data");
    let artifacts = std::path::Path::new("artifacts");

    println!("== SIoT service classification: cloud vs fog vs Fograph \
              ({}) ==\n", net.name());
    let g = datasets::load_or_generate(data_dir, "siot")
        .expect("siot is a known dataset");
    let spec = datasets::SIOT;
    let mut engine = Engine::new(EngineKind::Pjrt, artifacts)
        .unwrap_or_else(|e| {
            println!("(PJRT unavailable: {e}; using reference engine)");
            Engine::new(EngineKind::Reference, artifacts).unwrap()
        });

    let systems: Vec<(&str, Cluster, ServeOpts)> = vec![
        (
            "cloud (V100 behind WAN)",
            Cluster::cloud(net),
            ServeOpts {
                wan: true,
                keep_outputs: true,
                ..ServeOpts::new("gcn", Placement::SingleNode(0),
                                 Codec::None)
            },
        ),
        (
            "straw-man fog (6 nodes)",
            Cluster::testbed(net),
            ServeOpts {
                keep_outputs: true,
                ..ServeOpts::new("gcn", Placement::MetisRandom(1),
                                 Codec::None)
            },
        ),
        (
            "Fograph (IEP + CO)",
            Cluster::testbed(net),
            ServeOpts {
                keep_outputs: true,
                ..ServeOpts::new("gcn", Placement::Iep,
                                 ServeOpts::co_codec(&g))
            },
        ),
    ];

    let labels = g.labels.clone().expect("labels");
    let mut cloud_latency = 0.0;
    for (name, cluster, opts) in systems {
        let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
        let r = serve(&g, &spec, &cluster, &opts, &omegas, &mut engine)
            .expect("serving failed");
        if cloud_latency == 0.0 {
            cloud_latency = r.total_s;
        }
        let acc = accuracy(r.outputs.as_ref().unwrap(), r.out_dim, &labels);
        println!("{name}");
        println!(
            "  latency {:.4} s ({:.2}x vs cloud)   throughput {:.2} inf/s   \
             accuracy {:.2}%",
            r.total_s,
            cloud_latency / r.total_s,
            r.throughput,
            acc * 100.0
        );
        println!(
            "  breakdown: collect {:.4} | exec {:.4} | sync {:.4} | \
             wire {:.2} MB\n",
            r.collection_s, r.execution_s, r.sync_s,
            r.wire_bytes as f64 / 1e6
        );
    }
}
