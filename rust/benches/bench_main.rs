//! `cargo bench` — custom harness (no criterion in the offline registry;
//! rust/src/util/timer.rs provides the measurement core).
//!
//! Two tiers:
//!  * per-paper-experiment end-to-end benches (one per table/figure; the
//!    full-size regeneration lives in `repro exp`, these are the
//!    continuously-runnable scaled versions), and
//!  * hot-path microbenches for the §Perf optimization loop (partitioner,
//!    assignment solvers, CO codec stages, BSP step, reference kernels).
//!
//! Filter with `cargo bench -- <substring>`.

use fograph::compress::{self, bitshuffle, lz4, Codec};
use fograph::fog::Cluster;
use fograph::graph::{datasets, generate, subgraph, DatasetSpec};
use fograph::net::NetKind;
use fograph::partition::{self, MultilevelParams};
use fograph::placement::{hungarian, lbap};
use fograph::profile::PerfModel;
use fograph::runtime::csr_backend::run_layer_csr;
use fograph::runtime::kernels::{gemm, spmm};
use fograph::runtime::{pad, reference, CsrPartition, Engine,
                       EngineKind};
use fograph::serving::{mode_setup, serve, Placement, ServeOpts};
use fograph::traffic::{doc_json, report_json, run_loadtest, ExecMode,
                       TrafficConfig};
use fograph::util::json::{num, obj, s, Json};
use fograph::util::rng::Rng;
use fograph::util::timer::{bench, black_box, BenchResult};

fn siot_like() -> fograph::graph::Graph {
    // 1/8-scale SIoT twin: keeps bench turnaround snappy
    let (mut g, _) = generate::sbm(2048, 18_000, 12, 0.82, 11);
    let mut rng = Rng::new(3);
    g.feature_dim = 52;
    g.features = (0..2048 * 52)
        .map(|_| if rng.bool(0.06) { 1.0 } else { 0.0 })
        .collect();
    g.num_classes = 2;
    g.labels = Some((0..2048).map(|v| (v % 2) as i32).collect());
    g
}

fn spec_for(g: &fograph::graph::Graph, name: &'static str) -> DatasetSpec {
    DatasetSpec {
        name,
        vertices: g.num_vertices(),
        edges: g.undirected_edges(),
        feature_dim: g.feature_dim,
        classes: g.num_classes,
        duration: 1,
        window: 1,
        seed: 0,
    }
}

fn main() {
    // cargo passes flags like --bench; the first non-flag arg filters
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();
    let mut results: Vec<BenchResult> = Vec::new();
    let mut run = |name: &str, min_s: f64, f: &mut dyn FnMut()| {
        if !filter.is_empty() && !name.contains(&filter) {
            return;
        }
        let r = bench(name, min_s, 200, f);
        println!("{r}");
        results.push(r);
    };

    println!("== Fograph bench suite (scaled workloads; see `repro exp` \
              for full-size regenerations) ==\n");
    let g = siot_like();
    let spec = spec_for(&g, "benchsiot");

    // ---- hot paths: partitioning + placement -------------------------------
    run("partition/multilevel_k6_2k", 1.0, &mut || {
        black_box(partition::partition(&g, 6,
                                       &MultilevelParams::default()));
    });
    let mut rng = Rng::new(5);
    let cost: Vec<Vec<f64>> = (0..64)
        .map(|_| (0..64).map(|_| rng.f64() * 100.0).collect())
        .collect();
    run("placement/hungarian_64x64", 0.5, &mut || {
        black_box(hungarian::min_cost_assignment(&cost));
    });
    run("placement/lbap_binary_search_64x64", 0.5, &mut || {
        black_box(lbap::solve(&cost));
    });
    run("placement/lbap_linear_descent_64x64", 0.5, &mut || {
        black_box(lbap::solve_linear_descent(&cost));
    });

    // ---- hot paths: communication optimizer --------------------------------
    let payload: Vec<u8> = {
        let mut rng = Rng::new(7);
        let mut v = vec![0u8; 1 << 20];
        for i in 0..v.len() {
            if rng.bool(0.08) {
                v[i] = rng.below(255) as u8;
            }
        }
        v
    };
    run("co/lz4_compress_1MiB_sparse", 0.5, &mut || {
        black_box(lz4::compress(&payload));
    });
    let compressed = lz4::compress(&payload);
    run("co/lz4_decompress_1MiB_sparse", 0.5, &mut || {
        black_box(lz4::decompress(&compressed).unwrap());
    });
    run("co/bitshuffle_1MiB_w4", 0.5, &mut || {
        black_box(bitshuffle::shuffle(&payload, 4));
    });
    let rows: Vec<&[f32]> = g
        .features
        .chunks_exact(g.feature_dim)
        .collect();
    let degrees: Vec<u64> =
        g.degrees().iter().map(|&d| d as u64).collect();
    let daq = ServeOpts::co_codec(&g);
    run("co/pack_daq_2k_vertices", 0.5, &mut || {
        black_box(compress::pack(&rows, &degrees, &daq));
    });
    let packed = compress::pack(&rows, &degrees, &daq);
    run("co/unpack_daq_2k_vertices", 0.5, &mut || {
        let mut out = Vec::new();
        compress::unpack(&packed, &mut out).unwrap();
        black_box(out);
    });

    // ---- hot paths: reference kernels + BSP --------------------------------
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|v| (v % 4) as u32).collect();
    let (subs, _) = subgraph::extract(&g, &assignment, 4);
    let edges = pad::prep_edges("gcn", &subs[0]).unwrap();
    let h: Vec<f32> = vec![0.5; subs[0].n_total() * 52];
    run("kernel/segment_aggregate_512v", 0.5, &mut || {
        black_box(reference::segment_aggregate(&h, 52, &edges,
                                               edges.n));
    });
    let w = vec![0.01f32; 52 * 64];
    let b = vec![0f32; 64];
    run("kernel/gemm_naive_512x52x64", 0.5, &mut || {
        black_box(gemm::gemm_bias_naive(&h, edges.n, 52, &w, 64, &b));
    });
    run("kernel/gemm_tiled_512x52x64", 0.5, &mut || {
        black_box(gemm::gemm_bias(&h, edges.n, 52, &w, 64, &b));
    });

    let dir = std::env::temp_dir().join("bench_engine");
    std::fs::create_dir_all(&dir).unwrap();
    let mut engine = Engine::new(EngineKind::Reference, &dir).unwrap();

    // ---- hot paths: sparse CSR backend --------------------------------------
    let csr = CsrPartition::from_edges(&edges);
    run("kernel/csr_spmm_naive_512v", 0.5, &mut || {
        black_box(spmm::csr_spmm_naive(&csr, &h, 52));
    });
    run("kernel/csr_spmm_blocked_512v", 0.5, &mut || {
        black_box(spmm::csr_spmm(&csr, &h, 52));
    });
    let wb_gcn = engine.weights("gcn", "benchsiot", 52, 2).clone();
    run("kernel/csr_gcn_layer_512v", 0.5, &mut || {
        black_box(
            run_layer_csr("gcn", 0, &wb_gcn, &h, 52, &csr, false, 1)
                .unwrap(),
        );
    });
    // block-diagonal batch of 8: one stacked GEMM vs 8 solo layers
    let h8: Vec<f32> =
        (0..8).flat_map(|_| h.iter().copied()).collect();
    run("kernel/csr_gcn_layer_batched_b8", 1.0, &mut || {
        black_box(
            run_layer_csr("gcn", 0, &wb_gcn, &h8, 52, &csr, false, 8)
                .unwrap(),
        );
    });

    run("exec/bsp_gcn_2layer_4fogs", 1.0, &mut || {
        black_box(
            fograph::exec::run_bsp(&g, &g.features, 52, &assignment, 4,
                                   "gcn", "benchsiot", 2, &mut engine)
                .unwrap(),
        );
    });
    // measured path: CSR kernels, one std::thread worker per fog,
    // block-diagonal batch of 4 — compare against the serial bench above
    run("exec/bsp_parallel_csr_b4_4fogs", 1.0, &mut || {
        black_box(
            fograph::exec::run_parallel(&g, &g.features, 52,
                                        &assignment, 4, "gcn",
                                        "benchsiot", 2, &mut engine, 4)
                .unwrap(),
        );
    });

    // ---- per-figure end-to-end benches (scaled) -----------------------------
    let omegas6 = vec![PerfModel::uncalibrated(); 6];
    let cases: Vec<(&str, Cluster, ServeOpts)> = vec![
        (
            "fig3/cloud_gcn_4g",
            Cluster::cloud(NetKind::Cell4G),
            ServeOpts { wan: true,
                        ..ServeOpts::new("gcn", Placement::SingleNode(0),
                                         Codec::None) },
        ),
        (
            "fig3/multifog_strawman_4g",
            Cluster::testbed(NetKind::Cell4G),
            ServeOpts::new("gcn", Placement::MetisRandom(3), Codec::None),
        ),
        (
            "fig11/fograph_gcn_4g",
            Cluster::testbed(NetKind::Cell4G),
            ServeOpts::new("gcn", Placement::Iep, ServeOpts::co_codec(&g)),
        ),
        (
            "fig11/fograph_gat_5g",
            Cluster::testbed(NetKind::Cell5G),
            ServeOpts::new("gat", Placement::Iep, ServeOpts::co_codec(&g)),
        ),
        (
            "fig11/fograph_sage_wifi",
            Cluster::testbed(NetKind::Wifi),
            ServeOpts::new("sage", Placement::Iep,
                           ServeOpts::co_codec(&g)),
        ),
        (
            "fig8/iep_e1",
            Cluster::env("E1").unwrap(),
            ServeOpts::new("gcn", Placement::Iep, Codec::None),
        ),
        (
            "fig8/greedy_e1",
            Cluster::env("E1").unwrap(),
            ServeOpts::new("gcn", Placement::MetisGreedy, Codec::None),
        ),
        (
            "fig15/fograph_wo_co",
            Cluster::case_study(NetKind::Cell4G),
            ServeOpts::new("gcn", Placement::Iep, Codec::None),
        ),
    ];
    for (name, cluster, opts) in cases {
        let om = &omegas6[..cluster.len()];
        run(name, 1.0, &mut || {
            black_box(
                serve(&g, &spec, &cluster, &opts, om, &mut engine)
                    .unwrap(),
            );
        });
    }

    // pems / astgcn (fig13, table5 path)
    let pems = datasets::generate("pems").unwrap();
    let pspec = datasets::PEMS;
    let omegas4 = vec![PerfModel::uncalibrated(); 4];
    let pcluster = Cluster::case_study(NetKind::Cell5G);
    let popts = ServeOpts::new("astgcn", Placement::Iep,
                               ServeOpts::co_codec(&pems));
    run("fig13/fograph_astgcn_5g", 1.0, &mut || {
        black_box(
            serve(&pems, &pspec, &pcluster, &popts, &omegas4, &mut engine)
                .unwrap(),
        );
    });

    // scheduler step (fig16 path)
    let cs = Cluster::case_study(NetKind::Wifi);
    let sopts = ServeOpts::new("gcn", Placement::Iep, Codec::None);
    let mut assign2 = fograph::serving::pipeline::place(
        &g, &cs, &sopts, &omegas6[..4], &spec,
    );
    run("fig16/scheduler_step_diffusion", 0.5, &mut || {
        let mut a = assign2.clone();
        black_box(fograph::scheduler::schedule(
            &g, &spec, &cs, &sopts, &mut a,
            &[0.1, 0.1, 0.1, 0.35],
            &omegas6[..4],
            &fograph::scheduler::SchedulerConfig::default(),
        ));
    });
    assign2.clear();

    // ---- request-level loadtest (also emits BENCH_loadtest.json) -----------
    let traffic_cfg = TrafficConfig {
        rps: 150.0,
        duration_s: 8.0,
        seed: 0xBE7C,
        ..Default::default()
    };
    let mut loadtest_runs = Vec::new();
    for mode in ["cloud", "fograph"] {
        let (cluster, topts) =
            mode_setup(mode, "gcn", NetKind::Wifi, &g).unwrap();
        let om = vec![PerfModel::uncalibrated(); cluster.len()];
        let mut last = None;
        run(&format!("traffic/loadtest_{mode}_150rps_8s"), 1.0, &mut || {
            let r = run_loadtest(&g, &spec, &cluster, &topts,
                                 &traffic_cfg, &om, &mut engine)
                .unwrap();
            last = Some(r);
        });
        if let Some(r) = last {
            loadtest_runs.push(report_json(mode, &traffic_cfg, &r));
        }
    }
    // measured mode: real CSR batched kernel execution per micro-batch
    let measured_cfg = TrafficConfig {
        rps: 120.0,
        duration_s: 3.0,
        seed: 0xBE7D,
        exec: ExecMode::Measured,
        ..Default::default()
    };
    {
        let (cluster, topts) =
            mode_setup("fograph", "gcn", NetKind::Wifi, &g).unwrap();
        let om = vec![PerfModel::uncalibrated(); cluster.len()];
        let mut mlast = None;
        run("traffic/loadtest_fograph_measured_120rps_3s", 1.0,
            &mut || {
                let r = run_loadtest(&g, &spec, &cluster, &topts,
                                     &measured_cfg, &om, &mut engine)
                    .unwrap();
                mlast = Some(r);
            });
        if let Some(r) = mlast {
            loadtest_runs
                .push(report_json("fograph-measured", &measured_cfg,
                                  &r));
        }
    }
    if !loadtest_runs.is_empty() {
        // kernel timings + engine kind ride along in the bench doc
        let kernels: Vec<Json> = results
            .iter()
            .filter(|r| {
                r.name.starts_with("kernel/")
                    || r.name.starts_with("exec/")
            })
            .map(|r| {
                obj(vec![
                    ("name", s(&r.name)),
                    ("mean_ms", num(r.mean_ns / 1e6)),
                    ("p50_ms", num(r.p50_ns / 1e6)),
                    ("p95_ms", num(r.p95_ns / 1e6)),
                    ("iters", num(r.iters as f64)),
                ])
            })
            .collect();
        // runs mix analytic (grounding engine) and measured
        // (csr-batched) pricing; each run row carries its own engine
        let doc = doc_json("benchsiot", "gcn", "WiFi", "mixed",
                           loadtest_runs, kernels);
        std::fs::write("BENCH_loadtest.json", format!("{doc}\n"))
            .expect("write BENCH_loadtest.json");
        println!("\nwrote BENCH_loadtest.json");
    }

    println!("\n{} benches complete.", results.len());
}
