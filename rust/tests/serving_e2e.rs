//! End-to-end serving integration tests over generated (small) twins with
//! the reference engine — exercise the full pipeline surface without
//! requiring `make artifacts`.

use std::path::Path;

use fograph::compress::Codec;
use fograph::fog::Cluster;
use fograph::graph::{generate, DatasetSpec, Graph};
use fograph::net::NetKind;
use fograph::profile::PerfModel;
use fograph::runtime::{Engine, EngineKind};
use fograph::serving::{serve, Placement, ServeOpts};
use fograph::util::rng::Rng;

fn small_twin() -> (Graph, DatasetSpec) {
    let (mut g, _) = generate::sbm(1500, 9000, 10, 0.85, 21);
    let mut rng = Rng::new(4);
    g.feature_dim = 24;
    g.features = (0..1500 * 24)
        .map(|_| if rng.bool(0.1) { 1.0 } else { 0.0 })
        .collect();
    g.num_classes = 2;
    g.labels = Some((0..1500).map(|v| (v % 2) as i32).collect());
    let spec = DatasetSpec {
        name: "e2e",
        vertices: 1500,
        edges: 9000,
        feature_dim: 24,
        classes: 2,
        duration: 1,
        window: 1,
        seed: 21,
    };
    (g, spec)
}

fn engine() -> Engine {
    Engine::new(EngineKind::Reference, Path::new("artifacts"))
        .or_else(|_| {
            Engine::new(EngineKind::Reference,
                        &std::env::temp_dir().join("e2e"))
        })
        .unwrap()
}

/// The paper's headline ordering must hold on every network and model:
/// fograph < straw-man fog < cloud in latency; reversed in throughput.
#[test]
fn headline_ordering_holds_across_nets_and_models() {
    let (g, spec) = small_twin();
    let mut eng = engine();
    for net in NetKind::all() {
        for model in ["gcn", "sage"] {
            let cloud = serve(
                &g, &spec, &Cluster::cloud(net),
                &ServeOpts {
                    wan: true,
                    ..ServeOpts::new(model, Placement::SingleNode(0),
                                     Codec::None)
                },
                &[PerfModel::uncalibrated()],
                &mut eng,
            ).unwrap();
            let testbed = Cluster::testbed(net);
            let omegas = vec![PerfModel::uncalibrated(); 6];
            let fog = serve(
                &g, &spec, &testbed,
                &ServeOpts::new(model, Placement::MetisRandom(3),
                                Codec::None),
                &omegas, &mut eng,
            ).unwrap();
            let fograph = serve(
                &g, &spec, &testbed,
                &ServeOpts::new(model, Placement::Iep,
                                ServeOpts::co_codec(&g)),
                &omegas, &mut eng,
            ).unwrap();
            assert!(
                fograph.total_s < fog.total_s
                    && fog.total_s < cloud.total_s,
                "{model}/{:?}: fograph {:.4} fog {:.4} cloud {:.4}",
                net, fograph.total_s, fog.total_s, cloud.total_s
            );
            assert!(fograph.throughput > cloud.throughput);
        }
    }
}

/// DAQ + LZ4 must not perturb predictions: class agreement with the
/// full-precision pipeline stays near-perfect.
#[test]
fn codec_preserves_predictions() {
    let (g, spec) = small_twin();
    let mut eng = engine();
    let testbed = Cluster::testbed(NetKind::Wifi);
    let omegas = vec![PerfModel::uncalibrated(); 6];
    let mut full_opts =
        ServeOpts::new("gcn", Placement::Iep, Codec::None);
    full_opts.keep_outputs = true;
    let full = serve(&g, &spec, &testbed, &full_opts, &omegas, &mut eng)
        .unwrap();
    let mut daq_opts =
        ServeOpts::new("gcn", Placement::Iep, ServeOpts::co_codec(&g));
    daq_opts.keep_outputs = true;
    let daq = serve(&g, &spec, &testbed, &daq_opts, &omegas, &mut eng)
        .unwrap();
    let (a, b) = (full.outputs.unwrap(), daq.outputs.unwrap());
    let d = full.out_dim;
    let mut agree = 0;
    for v in 0..g.num_vertices() {
        let am = argmax(&a[v * d..(v + 1) * d]);
        let bm = argmax(&b[v * d..(v + 1) * d]);
        if am == bm {
            agree += 1;
        }
    }
    assert!(
        agree >= g.num_vertices() * 99 / 100,
        "agreement {agree}/{}",
        g.num_vertices()
    );
    assert!(daq.wire_bytes < full.wire_bytes / 3);
}

/// Failure injection: a fog node that is enormously slowed must not change
/// results, only timing; and an empty partition is tolerated.
#[test]
fn degraded_cluster_still_serves_correctly() {
    let (g, spec) = small_twin();
    let mut eng = engine();
    let mut cluster = Cluster::testbed(NetKind::Wifi);
    cluster.nodes[2].background_load = 0.85; // nearly saturated
    let omegas = vec![PerfModel::uncalibrated(); 6];
    let mut opts = ServeOpts::new("gcn", Placement::Iep,
                                  ServeOpts::co_codec(&g));
    opts.keep_outputs = true;
    let r = serve(&g, &spec, &cluster, &opts, &omegas, &mut eng).unwrap();
    assert!(r.outputs.is_some());
    assert!(r.total_s.is_finite() && r.total_s > 0.0);
    // degenerate: more fogs than useful partitions still works
    let tiny_assign: Vec<u32> = vec![0; g.num_vertices()];
    let (payload, dims) =
        fograph::serving::pipeline::query_payload(&g, &spec, 0);
    let r2 = fograph::serving::serve_with_assignment(
        &g, &spec, &cluster, &opts, &tiny_assign, &payload, dims,
        &mut eng,
    ).unwrap();
    assert!(r2.per_fog_vertices[1..].iter().all(|&v| v == 0));
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}
