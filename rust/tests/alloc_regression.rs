//! Allocation-count regression gates for the scale tier's hot paths.
//!
//! A counting `#[global_allocator]` wraps `System` and the suite runs
//! as ONE test (separate `#[test]`s would race on the shared counter):
//!
//!   A. streamed grounding (`subgraph::extract`) allocates strictly
//!      less than the materialize-everything reference path;
//!   B. `collect_indexed` with a prebuilt [`CollectionIndex`] allocates
//!      strictly less than `collect`, which rebuilds the index per
//!      request;
//!   C. `sync_halo` performs ZERO allocations once the halo index and
//!      state buffers exist — the split-borrow + `copy_from_slice`
//!      rewrite must never regress back to per-row temporaries.
//!   D. an incremental churn round (single-edge delta, partial
//!      re-ground) allocates strictly less than re-extracting the
//!      whole grounding from scratch — the partition-scoped
//!      invalidation plane must never silently fall back to
//!      rebuild-everything.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use fograph::compress::Codec;
use fograph::exec::{build_halo_index, sync_halo};
use fograph::fog::Cluster;
use fograph::graph::{generate, subgraph};
use fograph::net::NetKind;
use fograph::serving::collection::{self, CollectionIndex};
use fograph::util::rng::Rng;

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize)
                      -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocation calls made while running `f` (alloc + realloc +
/// alloc_zeroed; frees are not counted).
fn allocs_during<R>(f: impl FnOnce() -> R) -> (usize, R) {
    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    let out = f();
    (ALLOC_CALLS.load(Ordering::Relaxed) - before, out)
}

#[test]
fn hot_paths_hold_their_allocation_budgets() {
    let n_fogs = 4usize;
    let g = generate::rmat(2048, 8192, 9, (0.57, 0.19, 0.19, 0.05));
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|v| (v % n_fogs) as u32).collect();

    // -- A: streamed grounding beats materialize-everything ----------
    // Warm both paths once so lazy runtime setup does not skew either.
    let _ = subgraph::extract(&g, &assignment, n_fogs);
    let _ = subgraph::extract_materialized(&g, &assignment, n_fogs);
    let (streamed_allocs, (subs, plan)) =
        allocs_during(|| subgraph::extract(&g, &assignment, n_fogs));
    let (materialized_allocs, _) = allocs_during(|| {
        subgraph::extract_materialized(&g, &assignment, n_fogs)
    });
    assert!(
        streamed_allocs < materialized_allocs,
        "streamed grounding must allocate less than the materialized \
         path ({streamed_allocs} vs {materialized_allocs})"
    );

    // -- B: prebuilt collection index beats per-request rebuild ------
    let dims = 16usize;
    let cluster = Cluster::testbed(NetKind::Wifi);
    let asn_c: Vec<u32> = (0..g.num_vertices())
        .map(|v| (v % cluster.len()) as u32)
        .collect();
    let mut rng = Rng::new(41);
    let feats: Vec<f32> = (0..g.num_vertices() * dims)
        .map(|_| rng.normal_f32(0.0, 1.0))
        .collect();
    let idx = CollectionIndex::build(&g, &asn_c, cluster.len());
    // Warm both entry points (thread-locals, lazy tables).
    let _ = collection::collect(&g, &feats, dims, &asn_c, &cluster,
                                &Codec::None, 64, false);
    let _ = collection::collect_indexed(&g, &idx, &feats, dims, &cluster,
                                        &Codec::None, 64, false);
    let (indexed_allocs, _) = allocs_during(|| {
        collection::collect_indexed(&g, &idx, &feats, dims, &cluster,
                                    &Codec::None, 64, false)
    });
    let (unindexed_allocs, _) = allocs_during(|| {
        collection::collect(&g, &feats, dims, &asn_c, &cluster,
                            &Codec::None, 64, false)
    });
    assert!(
        indexed_allocs < unindexed_allocs,
        "indexed collection must allocate less than the index-per-call \
         path ({indexed_allocs} vs {unindexed_allocs})"
    );

    // -- C: halo sync is allocation-free once buffers exist ----------
    let dim = 8usize;
    let batch = 2usize;
    let halo_index = build_halo_index(&subs);
    let mut states: Vec<Vec<f32>> = subs
        .iter()
        .map(|s| vec![0.5f32; batch * s.n_total() * dim])
        .collect();
    let warm =
        sync_halo(&subs, &plan, &halo_index, &mut states, dim, batch);
    assert!(warm > 0, "fixture must actually exchange halo rows");
    let (sync_allocs, bytes) = allocs_during(|| {
        sync_halo(&subs, &plan, &halo_index, &mut states, dim, batch)
    });
    assert_eq!(
        sync_allocs, 0,
        "sync_halo must not allocate on the steady-state path"
    );
    assert_eq!(bytes, warm, "byte accounting is deterministic");

    // -- D: partial re-ground beats a from-scratch extract -----------
    use fograph::graph::delta::Delta;
    use fograph::graph::{ChurnPlan, ChurnSpec, TopologyEngine};
    let mut engine = TopologyEngine::new(&g, &assignment, n_fogs);
    // warm the engine's round path once (scratch vecs, first deltas)
    let warm_spec =
        vec![ChurnSpec::parse("del-edge@rate=0.0000001").unwrap()];
    let mut warm_plan = ChurnPlan::new(&warm_spec, 3);
    let rep = engine.churn_round(&mut warm_plan);
    assert!(rep.deltas <= 1);
    // measured round: one hand-built edge delta -> partial re-ground
    let (u, v) = {
        let mut found = None;
        'outer: for u in 0..g.num_vertices() as u32 {
            if !engine.csr.is_alive(u) {
                continue;
            }
            let mut nb = Vec::new();
            engine.csr.for_neighbors(u, |x| nb.push(x));
            for &w in &nb {
                if w > u {
                    found = Some((u, w));
                    break 'outer;
                }
            }
        }
        found.unwrap()
    };
    let (churn_allocs, rep) = allocs_during(|| {
        engine.csr.del_edge(u, v);
        engine.integrate(&[Delta::DelEdge(u, v)])
    });
    assert!(
        rep.preserved > 0,
        "a single edge delta must leave some fogs untouched"
    );
    let (full_allocs, _) = allocs_during(|| {
        subgraph::extract_materialized(
            &engine.csr.to_graph(),
            &engine.assignment,
            n_fogs,
        )
    });
    assert!(
        churn_allocs < full_allocs,
        "partial re-ground must allocate less than a from-scratch \
         extract ({churn_allocs} vs {full_allocs})"
    );
    engine.parity_check().expect("post-budget parity");
}
