//! Incremental-topology parity suite (churn tentpole): across seeded
//! rmat / sbm / road topologies, mutation traces and fog counts, a
//! [`TopologyEngine`] mutated in place must stay bit-identical to a
//! from-scratch rebuild of the live topology — same per-fog sub-CSRs
//! (vertex order, edge order, degrees), same exchange plan, same
//! fingerprints, and bitwise-identical served outputs — while rounds
//! that touch few fogs leave the untouched fogs' structures
//! physically unmodified. The in-crate unit tests cover hand-built
//! fixtures; this suite covers the generator zoo and the replay /
//! compaction behaviors the `repro churn` sweep relies on.

use fograph::graph::delta::bsp_aggregate;
use fograph::graph::{generate, ChurnPlan, ChurnSpec, Graph,
                     TopologyEngine};

fn parse_specs(texts: &[&str]) -> Vec<ChurnSpec> {
    texts
        .iter()
        .map(|t| ChurnSpec::parse(t).expect("valid spec"))
        .collect()
}

/// Seeded pseudo-random assignment hitting every fog (LCG scramble —
/// same family the grounding-parity suite uses).
fn scrambled(nv: usize, n_fogs: usize, salt: u64) -> Vec<u32> {
    (0..nv as u64)
        .map(|v| {
            let h = (v ^ salt)
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            ((h >> 33) % n_fogs as u64) as u32
        })
        .collect()
}

fn graph_zoo() -> Vec<(&'static str, Graph)> {
    vec![
        ("rmat",
         generate::rmat(600, 2400, 7, (0.57, 0.19, 0.19, 0.05))),
        ("sbm", generate::sbm(500, 2500, 5, 0.8, 11).0),
        ("road", generate::road_network(400, 500, 3, 13).0),
    ]
}

fn mixed_trace() -> Vec<ChurnSpec> {
    parse_specs(&[
        "add-edge@rate=0.01",
        "del-edge@rate=0.008",
        "add-vertex@rate=0.004,degree=3",
        "del-vertex@rate=0.002",
    ])
}

#[test]
fn mutated_equals_rebuilt_across_zoo_seeds_and_fog_counts() {
    for (tag, g) in graph_zoo() {
        for &n_fogs in &[2usize, 5, 8] {
            for seed in [3u64, 17] {
                let asn =
                    scrambled(g.num_vertices(), n_fogs, seed);
                let mut engine =
                    TopologyEngine::new(&g, &asn, n_fogs);
                let mut plan =
                    ChurnPlan::new(&mixed_trace(), seed);
                for round in 0..5 {
                    engine.churn_round(&mut plan);
                    engine.parity_check().unwrap_or_else(|e| {
                        panic!(
                            "{tag}/f{n_fogs}/s{seed} round \
                             {round}: {e}"
                        )
                    });
                }
            }
        }
    }
}

#[test]
fn served_outputs_stay_bitwise_identical_under_churn() {
    let g = generate::rmat(500, 2000, 9, (0.57, 0.19, 0.19, 0.05));
    let dims = 4usize;
    let asn = scrambled(g.num_vertices(), 4, 5);
    let mut engine = TopologyEngine::new(&g, &asn, 4);
    let mut plan = ChurnPlan::new(&mixed_trace(), 77);
    for _ in 0..4 {
        engine.churn_round(&mut plan);
    }
    let nv = engine.csr.num_vertices();
    let features: Vec<f32> = (0..nv * dims)
        .map(|i| (i as f32).sin() * 0.25 + 1.0)
        .collect();
    let rebuilt = engine.csr.to_graph();
    let (ref_subs, ref_plan) = fograph::graph::subgraph::extract(
        &rebuilt, &engine.assignment, 4);
    let got = bsp_aggregate(&engine.subs, &engine.plan,
                            &engine.assignment, &features, dims);
    let want = bsp_aggregate(&ref_subs, &ref_plan,
                             &engine.assignment, &features, dims);
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(),
                   "served output row diverges at {i}");
    }
}

#[test]
fn replay_with_same_seed_is_deterministic() {
    let g = generate::rmat(400, 1600, 21, (0.57, 0.19, 0.19, 0.05));
    let asn = scrambled(g.num_vertices(), 5, 9);
    let run = || {
        let mut engine = TopologyEngine::new(&g, &asn, 5);
        let mut plan = ChurnPlan::new(&mixed_trace(), 123);
        for _ in 0..6 {
            engine.churn_round(&mut plan);
        }
        (
            engine.fingerprints.clone(),
            engine.assignment.clone(),
            engine.stats,
            engine.summary().final_edges,
        )
    };
    assert_eq!(run(), run(), "same seed must replay bit-for-bit");
}

#[test]
fn declaration_order_of_specs_is_irrelevant() {
    let g = generate::sbm(300, 1500, 3, 0.8, 31).0;
    let asn = scrambled(g.num_vertices(), 3, 2);
    let fwd = parse_specs(&["add-edge@rate=0.02",
                            "del-vertex@rate=0.005"]);
    let rev = parse_specs(&["del-vertex@rate=0.005",
                            "add-edge@rate=0.02"]);
    let run = |specs: &[ChurnSpec]| {
        let mut engine = TopologyEngine::new(&g, &asn, 3);
        let mut plan = ChurnPlan::new(specs, 55);
        for _ in 0..4 {
            engine.churn_round(&mut plan);
        }
        engine.fingerprints.clone()
    };
    assert_eq!(run(&fwd), run(&rev));
}

#[test]
fn heavy_deletion_triggers_compaction_and_parity_survives() {
    let g = generate::rmat(300, 3000, 13, (0.57, 0.19, 0.19, 0.05));
    let asn = scrambled(g.num_vertices(), 3, 4);
    let mut engine = TopologyEngine::new(&g, &asn, 3);
    let mut plan = ChurnPlan::new(
        &parse_specs(&["del-edge@rate=0.4", "add-edge@rate=0.1"]),
        31,
    );
    for round in 0..12 {
        engine.churn_round(&mut plan);
        engine
            .parity_check()
            .unwrap_or_else(|e| panic!("round {round}: {e}"));
    }
    assert!(
        engine.stats.compactions > 0,
        "a 40%-per-round deletion trace must trip the tombstone \
         compaction threshold"
    );
}

#[test]
fn single_delta_rounds_preserve_untouched_subs_physically() {
    let g = generate::rmat(800, 3200, 15, (0.57, 0.19, 0.19, 0.05));
    let n_fogs = 8usize;
    let asn = scrambled(g.num_vertices(), n_fogs, 6);
    let mut engine = TopologyEngine::new(&g, &asn, n_fogs);
    // floor(rate * live) clamps to one delta per round
    let mut plan = ChurnPlan::new(
        &parse_specs(&["del-edge@rate=0.0000001"]),
        91,
    );
    let mut saw_preserved = false;
    for round in 0..4 {
        let before: Vec<_> = engine.subs.to_vec();
        let fp_before = engine.fingerprints.clone();
        let rep = engine.churn_round(&mut plan);
        assert!(
            rep.preserved > 0,
            "round {round}: one delta dirtied all {n_fogs} fogs"
        );
        saw_preserved = true;
        let touched: Vec<u32> = rep
            .dirty
            .iter()
            .chain(rep.patched.iter())
            .copied()
            .collect();
        for j in 0..n_fogs {
            if touched.contains(&(j as u32)) {
                continue;
            }
            assert_eq!(
                engine.subs[j], before[j],
                "round {round}: preserved fog {j} sub mutated"
            );
            assert_eq!(
                engine.fingerprints[j], fp_before[j],
                "round {round}: preserved fog {j} fingerprint moved"
            );
        }
        engine
            .parity_check()
            .unwrap_or_else(|e| panic!("round {round}: {e}"));
    }
    assert!(saw_preserved);
}
