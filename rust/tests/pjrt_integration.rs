//! Cross-engine integration tests: the AOT PJRT path (python-lowered HLO
//! artifacts executed via the `xla` crate) must match the pure-Rust
//! reference engine numerically, end to end through the distributed BSP
//! runtime.
//!
//! These tests need `make artifacts` to have produced `artifacts/`; they
//! are skipped (with a notice) when it hasn't, so `cargo test` stays green
//! on a fresh checkout.

use std::path::Path;

use fograph::exec;
use fograph::graph::datasets;
use fograph::runtime::{Engine, EngineKind};

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping PJRT integration test: run `make artifacts`");
        None
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
}

#[test]
fn pjrt_matches_reference_on_siot_all_models() {
    let Some(dir) = artifacts_dir() else { return };
    let g = datasets::load_or_generate(Path::new("data"), "siot")
        .expect("siot twin");
    let mut pjrt = Engine::new(EngineKind::Pjrt, dir).expect("pjrt engine");
    let mut refe = Engine::new(EngineKind::Reference, dir).unwrap();
    // 3-way partition, includes halo exchange across fogs
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|v| (v % 3) as u32).collect();
    for model in ["gcn", "sage", "gat"] {
        let a = exec::run_bsp(&g, &g.features, g.feature_dim, &assignment,
                              3, model, "siot", 2, &mut pjrt)
            .expect("pjrt bsp");
        let b = exec::run_bsp(&g, &g.features, g.feature_dim, &assignment,
                              3, model, "siot", 2, &mut refe)
            .expect("ref bsp");
        assert_eq!(a.out_dim, b.out_dim);
        let err = max_abs_diff(&a.outputs, &b.outputs);
        assert!(
            err < 5e-3,
            "{model}: PJRT deviates from reference by {err}"
        );
        // predictions must agree on essentially every vertex
        let nv = g.num_vertices();
        let mut agree = 0;
        for v in 0..nv {
            let row_a = &a.outputs[v * a.out_dim..(v + 1) * a.out_dim];
            let row_b = &b.outputs[v * b.out_dim..(v + 1) * b.out_dim];
            let am = argmax(row_a);
            let bm = argmax(row_b);
            if am == bm {
                agree += 1;
            }
        }
        assert!(
            agree as f64 / nv as f64 > 0.999,
            "{model}: prediction agreement {agree}/{nv}"
        );
    }
}

#[test]
fn pjrt_matches_reference_astgcn_pems() {
    let Some(dir) = artifacts_dir() else { return };
    let g = datasets::load_or_generate(Path::new("data"), "pems")
        .expect("pems twin");
    let spec = datasets::PEMS;
    let (payload, dims) =
        fograph::serving::pipeline::query_payload(&g, &spec, 900);
    let mut pjrt = Engine::new(EngineKind::Pjrt, dir).expect("pjrt engine");
    let mut refe = Engine::new(EngineKind::Reference, dir).unwrap();
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|v| (v % 2) as u32).collect();
    let a = exec::run_bsp(&g, &payload, dims, &assignment, 2, "astgcn",
                          "pems", 0, &mut pjrt)
        .expect("pjrt astgcn");
    let b = exec::run_bsp(&g, &payload, dims, &assignment, 2, "astgcn",
                          "pems", 0, &mut refe)
        .expect("ref astgcn");
    let err = max_abs_diff(&a.outputs, &b.outputs);
    // astgcn outputs are in normalized-flow units ~O(1..10)
    assert!(err < 5e-2, "astgcn PJRT vs reference deviates by {err}");
}

#[test]
fn bucket_selection_spans_partition_sizes() {
    let Some(dir) = artifacts_dir() else { return };
    let m = fograph::runtime::Manifest::load(dir).unwrap();
    // the SIoT bucket ladder must cover both a 1/8 partition and the
    // full graph for every layer of every static model
    for model in ["gcn", "gat", "sage"] {
        for layer in 0..2 {
            let small = m.select(model, "siot", layer, 2500, 50_000)
                .expect("small bucket");
            let full = m.select(model, "siot", layer, 16216, 309_000)
                .expect("full bucket");
            assert!(small.v_max < full.v_max,
                    "{model} l{layer}: no graded buckets");
        }
    }
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}
