//! Observability-plane integration tests: the analytic loadtest report
//! must be bit-identical with span tracing on or off, the Chrome trace
//! must parse and nest sanely (no negative durations, children inside
//! parents, non-overlapping per-fog execution), the virtual span sums
//! must reconcile with the registry's `phase_breakdown` within 1%, a
//! measured run must land real wall-clock kernel spans, and histogram
//! aggregation across real threads must match a single-threaded oracle.

use std::sync::Arc;

use fograph::fog::Cluster;
use fograph::graph::{generate, DatasetSpec, Graph};
use fograph::net::NetKind;
use fograph::obs::{chrome_trace, ClockMode, Histogram, Recorder,
                   WALL_TID_BASE};
use fograph::profile::PerfModel;
use fograph::runtime::{Engine, EngineKind};
use fograph::serving::pipeline::{Placement, ServeOpts};
use fograph::traffic::{report_json, run_loadtest_traced, ExecMode,
                       LoadtestReport, TrafficConfig};
use fograph::util::json::Json;
use fograph::util::rng::Rng;

fn tiny() -> (Graph, DatasetSpec) {
    let (mut g, _) = generate::sbm(400, 2000, 8, 0.85, 3);
    let mut rng = Rng::new(5);
    g.feature_dim = 16;
    g.features = (0..400 * 16)
        .map(|_| if rng.bool(0.15) { 1.0 } else { 0.0 })
        .collect();
    let spec = DatasetSpec {
        name: "tiny",
        vertices: 400,
        edges: 2000,
        feature_dim: 16,
        classes: 3,
        duration: 1,
        window: 1,
        seed: 1,
    };
    (g, spec)
}

fn engine(tag: &str) -> Engine {
    let dir = std::env::temp_dir().join(format!("obs_trace_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    Engine::new(EngineKind::Reference, &dir).unwrap()
}

fn fog_setup(g: &Graph) -> (Cluster, ServeOpts, Vec<PerfModel>) {
    let cluster = Cluster::case_study(NetKind::Wifi);
    let opts =
        ServeOpts::new("gcn", Placement::Iep, ServeOpts::co_codec(g));
    let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
    (cluster, opts, omegas)
}

fn quick_traffic() -> TrafficConfig {
    TrafficConfig {
        rps: 60.0,
        duration_s: 6.0,
        seed: 42,
        ..Default::default()
    }
}

fn run_with(rec: &Arc<Recorder>, tag: &str) -> LoadtestReport {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = fog_setup(&g);
    let mut eng = engine(tag);
    run_loadtest_traced(&g, &spec, &cluster, &opts, &quick_traffic(),
                        &omegas, &mut eng, rec)
        .unwrap()
}

/// The tentpole invariant: enabling span tracing must not change a
/// single byte of the analytic report — the registry is always live,
/// and recording is write-only with respect to the event loop.
#[test]
fn analytic_report_is_bit_identical_with_tracing_on_and_off() {
    let off = run_with(&Recorder::disabled(), "onoff");
    let rec = Recorder::with_capacity(ClockMode::Virtual, 1 << 20);
    let on = run_with(&rec, "onoff");
    assert!(!rec.events().is_empty(), "tracing recorded no spans");
    assert_eq!(off.latencies, on.latencies);
    assert_eq!(off.slo.offered, on.slo.offered);
    assert_eq!(off.slo.shed, on.slo.shed);
    let t = quick_traffic();
    assert_eq!(
        report_json("bitrepro", &t, &off).to_string(),
        report_json("bitrepro", &t, &on).to_string(),
        "report bytes changed when tracing was enabled"
    );
}

/// Extract `(name, cat, pid, tid, ts, dur)` for every `ph: "X"` event.
fn spans_of(doc: &Json)
            -> Vec<(String, String, usize, usize, f64, f64)> {
    doc.get("traceEvents")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
        .map(|e| {
            (
                e.get("name").unwrap().as_str().unwrap().to_string(),
                e.get("cat").unwrap().as_str().unwrap().to_string(),
                e.get("pid").unwrap().as_usize().unwrap(),
                e.get("tid").unwrap().as_usize().unwrap(),
                e.get("ts").unwrap().as_f64().unwrap(),
                e.get("dur").unwrap().as_f64().unwrap(),
            )
        })
        .collect()
}

#[test]
fn chrome_trace_parses_and_spans_nest() {
    let rec = Recorder::with_capacity(ClockMode::Virtual, 1 << 20);
    run_with(&rec, "nest");
    assert_eq!(rec.dropped(), 0, "ring wrapped; grow the capacity");
    let doc = chrome_trace(&rec, &["default".to_string()]);
    let parsed = Json::parse(&doc.to_string()).unwrap();
    let spans = spans_of(&parsed);
    assert!(!spans.is_empty());

    let eps = 1e-6; // µs
    for (name, _, _, _, ts, dur) in &spans {
        assert!(ts.is_finite() && *ts >= 0.0, "{name}: bad ts {ts}");
        assert!(dur.is_finite() && *dur >= 0.0,
                "{name}: negative duration {dur}");
    }
    let kernels: Vec<_> = spans
        .iter()
        .filter(|s| s.0 == "kernel" && s.1 == "virtual")
        .collect();
    assert!(!kernels.is_empty(), "no kernel spans in the trace");

    // child within parent: every transfer sub-span sits inside a
    // collect span of the same tenant
    let collects: Vec<_> =
        spans.iter().filter(|s| s.0 == "collect").collect();
    for t in spans.iter().filter(|s| s.0 == "transfer") {
        assert!(
            collects.iter().any(|c| {
                c.2 == t.2
                    && c.4 <= t.4 + eps
                    && t.4 + t.5 <= c.4 + c.5 + eps
            }),
            "transfer span at {} escapes every collect window",
            t.4
        );
    }
    // kernel spans stay inside the batch lifecycle: at or after the
    // first collect window opened, done by the last reply
    let first_collect =
        collects.iter().map(|c| c.4).fold(f64::INFINITY, f64::min);
    let last_reply = spans
        .iter()
        .filter(|s| s.0 == "reply")
        .map(|s| s.4)
        .fold(0.0, f64::max);
    for k in &kernels {
        assert!(k.4 >= first_collect - eps);
        assert!(k.4 + k.5 <= last_reply + eps,
                "kernel span past the last reply");
    }
    // per-fog virtual execution is serial: spans on one fog track
    // never overlap (BSP batches run back to back)
    let mut tracks: std::collections::BTreeMap<(usize, usize),
                                               Vec<(f64, f64)>> =
        std::collections::BTreeMap::new();
    for s in &spans {
        if s.1 == "virtual" && s.3 >= 1 && s.3 < WALL_TID_BASE {
            tracks.entry((s.2, s.3)).or_default().push((s.4, s.5));
        }
    }
    assert!(!tracks.is_empty());
    for ((pid, tid), mut evs) in tracks {
        evs.sort_by(|a, b| a.0.total_cmp(&b.0));
        for w in evs.windows(2) {
            assert!(
                w[1].0 + eps >= w[0].0 + w[0].1,
                "overlap on fog track pid={pid} tid={tid}: \
                 [{}, +{}] then [{}, +{}]",
                w[0].0, w[0].1, w[1].0, w[1].1
            );
        }
    }
}

/// Acceptance check from the issue: per-phase time summed from the
/// trace's virtual spans must reconcile with the registry's
/// `phase_breakdown` within 1% (and exactly on counts) — same events,
/// two independent accounting paths. `transfer` is span-only by
/// convention (it shadows `collect` for nesting) so the breakdown
/// never lists it.
#[test]
fn virtual_span_sums_reconcile_with_phase_breakdown() {
    let rec = Recorder::with_capacity(ClockMode::Virtual, 1 << 20);
    let r = run_with(&rec, "reconcile");
    assert_eq!(rec.dropped(), 0);

    let mut span_secs: std::collections::BTreeMap<String, f64> =
        std::collections::BTreeMap::new();
    let mut span_count: std::collections::BTreeMap<String, u64> =
        std::collections::BTreeMap::new();
    for ev in rec.events() {
        if ev.wall || ev.tenant != 0 {
            continue;
        }
        *span_secs.entry(ev.phase.name().to_string()).or_default() +=
            ev.dur_us / 1e6;
        *span_count.entry(ev.phase.name().to_string()).or_default() += 1;
    }

    let phases = match r.phase_breakdown.at(&["default", "phases"]) {
        Some(Json::Obj(m)) => m,
        other => panic!("phase_breakdown malformed: {other:?}"),
    };
    assert!(phases.contains_key("kernel"));
    assert!(phases.contains_key("collect"));
    assert!(!phases.contains_key("transfer"),
            "transfer must stay span-only");
    for (name, entry) in phases {
        let secs = entry.get("seconds").unwrap().as_f64().unwrap();
        let count =
            entry.get("count").unwrap().as_f64().unwrap() as u64;
        let got = span_secs.get(name).copied().unwrap_or(0.0);
        if secs > 0.0 {
            let rel = (got - secs).abs() / secs;
            assert!(rel < 0.01,
                    "{name}: spans sum to {got}s, breakdown says \
                     {secs}s ({:.3}% off)",
                    rel * 100.0);
        } else {
            assert_eq!(got, 0.0, "{name}: spans carry time the \
                                  breakdown lacks");
        }
        assert_eq!(span_count.get(name).copied().unwrap_or(0), count,
                   "{name}: span count != breakdown count");
    }
}

#[test]
fn measured_trace_records_wall_kernel_spans() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = fog_setup(&g);
    let mut eng = engine("measured");
    let traffic = TrafficConfig {
        rps: 60.0,
        duration_s: 1.5,
        seed: 42,
        exec: ExecMode::Measured,
        ..Default::default()
    };
    let rec = Recorder::with_capacity(ClockMode::Wall, 1 << 20);
    let r = run_loadtest_traced(&g, &spec, &cluster, &opts, &traffic,
                                &omegas, &mut eng, &rec)
        .unwrap();
    assert!(r.slo.completed > 0);
    let evs = rec.events();
    let wall_kernels = evs
        .iter()
        .filter(|e| {
            e.wall && e.phase == fograph::obs::Phase::Kernel
        })
        .count();
    assert!(wall_kernels > 0, "measured run recorded no wall kernels");
    let virt_kernels = evs
        .iter()
        .filter(|e| {
            !e.wall && e.phase == fograph::obs::Phase::Kernel
        })
        .count();
    assert!(virt_kernels > 0, "virtual timeline lost its kernels");
    for e in &evs {
        assert!(e.dur_us >= 0.0 && e.t_us.is_finite());
    }
    // wall spans land on the offset track block in the exporter
    let doc = chrome_trace(&rec, &["default".to_string()]);
    let parsed = Json::parse(&doc.to_string()).unwrap();
    assert!(spans_of(&parsed)
        .iter()
        .any(|s| s.1 == "wall" && s.3 >= WALL_TID_BASE));
}

/// Cross-thread histogram aggregation: four producer threads record
/// into private histograms that are then merged; the result must match
/// a single-threaded oracle fed the same values.
#[test]
fn histogram_merge_across_threads_matches_oracle() {
    let shards: Vec<Histogram> =
        (0..4).map(|_| Histogram::new()).collect();
    std::thread::scope(|scope| {
        for (i, h) in shards.iter().enumerate() {
            scope.spawn(move || {
                let mut rng = Rng::new(100 + i as u64);
                for _ in 0..5000 {
                    h.record(rng.f64() * 1e7);
                }
            });
        }
    });
    let oracle = Histogram::new();
    for i in 0..4u64 {
        let mut rng = Rng::new(100 + i);
        for _ in 0..5000 {
            oracle.record(rng.f64() * 1e7);
        }
    }
    let merged = Histogram::new();
    for s in &shards {
        merged.merge(s);
    }
    assert_eq!(merged.count(), oracle.count());
    assert_eq!(merged.bucket_counts(), oracle.bucket_counts());
    assert!((merged.sum() - oracle.sum()).abs()
            <= 1e-6 * oracle.sum().abs());
    for p in [50.0, 90.0, 99.0] {
        assert_eq!(merged.percentile(p), oracle.percentile(p));
    }
}
