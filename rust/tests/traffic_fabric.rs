//! Multi-tenant serving-fabric properties:
//!
//! * a one-tenant fabric run (built through the `--tenant` spec path)
//!   reproduces the legacy single-stream `LoadtestReport` EXACTLY in
//!   analytic mode, and structurally in measured mode (wall-clock
//!   timings are inherently non-deterministic there);
//! * an N-tenant analytic run is bit-deterministic for a fixed seed
//!   and invariant under tenant declaration order;
//! * deficit-round-robin weighted-fair admission protects a low-weight
//!   Poisson tenant's p99 and goodput from a high-weight bursty
//!   tenant's saturating burst, strictly better than the shared-FIFO
//!   control under identical streams (scenario rates derived from a
//!   capacity probe so the contrast holds on any host);
//! * the plan cache builds exactly one plan per distinct
//!   `(model, dataset)` and counts tenant bindings as hits;
//! * `--pipeline-depth`: analytic runs ignore it bit-for-bit (no
//!   pipeline report keys), measured depth-2 runs account every
//!   offered request and report per-fog occupancy + stall time, and
//!   out-of-range depths are library-level errors (the CLI maps them
//!   to exit 2);
//! * the chaos plane: `run_fabric_chaos` with no faults is bitwise
//!   the fault-free path, fault schedules are bit-deterministic for a
//!   fixed seed and invariant under `--fault` declaration order, a
//!   seeded crash is detected + evacuated + reported without wedging
//!   the run, slow/link faults recover at their `until`, and
//!   malformed specs / out-of-range ids / bad task deadlines are
//!   loud errors;
//! * the streaming-graph plane: `run_fabric_churn` with no specs is
//!   bitwise the churn-free path, churn runs are bit-deterministic
//!   for a fixed seed, the report carries a `churn` summary with
//!   partial re-grounds, and measured exec / `--fault` combos / a
//!   disabled scheduler are loud errors.

use std::path::Path;

use fograph::fog::Cluster;
use fograph::graph::{generate, DatasetSpec, Graph};
use fograph::net::NetKind;
use fograph::profile::PerfModel;
use fograph::runtime::{Engine, EngineKind};
use fograph::serving::pipeline::{mode_setup, ServeOpts};
use fograph::obs::Recorder;
use fograph::runtime::kernels::DEFAULT_TASK_DEADLINE_S;
use fograph::graph::ChurnSpec;
use fograph::traffic::{jain_index, run_fabric, run_fabric_chaos,
                       run_fabric_churn, run_loadtest, ArrivalKind,
                       ExecMode, FabricReport, FairPolicy, FaultSpec,
                       Tenant, TenantInput, TenantSpec,
                       TrafficConfig};

fn tiny() -> (Graph, DatasetSpec) {
    let (mut g, _) = generate::sbm(400, 2000, 8, 0.85, 3);
    let mut rng = fograph::util::rng::Rng::new(5);
    g.feature_dim = 16;
    g.features = (0..400 * 16)
        .map(|_| if rng.bool(0.15) { 1.0 } else { 0.0 })
        .collect();
    let spec = DatasetSpec {
        name: "tiny",
        vertices: 400,
        edges: 2000,
        feature_dim: 16,
        classes: 3,
        duration: 1,
        window: 1,
        seed: 1,
    };
    (g, spec)
}

fn engine() -> Engine {
    let dir = std::env::temp_dir().join("traffic_fabric_test");
    std::fs::create_dir_all(&dir).unwrap();
    Engine::new(EngineKind::Reference, Path::new(&dir)).unwrap()
}

fn setup(g: &Graph) -> (Cluster, ServeOpts, Vec<PerfModel>) {
    let (cluster, opts) = mode_setup("fograph", "gcn", NetKind::Wifi, g)
        .expect("known mode");
    let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
    (cluster, opts, omegas)
}

fn input_for<'a>(tenant: Tenant, g: &'a Graph, spec: DatasetSpec,
                 cluster_len: usize) -> TenantInput<'a> {
    let (_, opts) =
        mode_setup("fograph", &tenant.model, NetKind::Wifi, g)
            .expect("known mode");
    let omegas =
        vec![PerfModel::uncalibrated_for(&tenant.model); cluster_len];
    TenantInput { tenant, g, spec, opts, omegas }
}

#[test]
fn one_tenant_fabric_reproduces_legacy_loadtest_exactly() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = setup(&g);
    let traffic = TrafficConfig {
        rps: 120.0,
        duration_s: 6.0,
        seed: 42,
        ..Default::default()
    };
    let mut eng = engine();
    let legacy = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                              &omegas, &mut eng)
        .unwrap();
    // the CLI spec path must resolve to the identical legacy tenant...
    let resolved = TenantSpec::parse(
        &format!("name=default,seed={}", traffic.seed))
        .unwrap()
        .resolve(&traffic, "gcn", "tiny");
    assert_eq!(resolved, Tenant::legacy(&traffic, "gcn", "tiny"));
    // ...and the one-tenant fabric must replay the legacy run bit-
    // for-bit (analytic mode is a pure function of inputs + seed)
    let input = TenantInput {
        tenant: resolved,
        g: &g,
        spec,
        opts: opts.clone(),
        omegas: omegas.clone(),
    };
    let fr = run_fabric(&cluster, vec![input], &traffic,
                        FairPolicy::Drr, &mut eng)
        .unwrap();
    let a = &fr.aggregate;
    assert_eq!(a.latencies, legacy.latencies);
    assert_eq!(a.slo.offered, legacy.slo.offered);
    assert_eq!(a.slo.completed, legacy.slo.completed);
    assert_eq!(a.slo.shed, legacy.slo.shed);
    assert_eq!(a.slo.spilled, legacy.slo.spilled);
    assert_eq!(a.slo.within_slo, legacy.slo.within_slo);
    assert_eq!(a.slo.goodput_rps, legacy.slo.goodput_rps);
    assert_eq!(a.slo.batches, legacy.slo.batches);
    assert_eq!(a.slo.mean_batch, legacy.slo.mean_batch);
    assert_eq!(a.slo.diffusions, legacy.slo.diffusions);
    assert_eq!(a.slo.replans, legacy.slo.replans);
    assert_eq!(a.slo.queue.samples, legacy.slo.queue.samples);
    assert_eq!(a.exec_utilization, legacy.exec_utilization);
    assert_eq!(a.queue_len_max, legacy.queue_len_max);
    assert_eq!(a.queue_len_mean, legacy.queue_len_mean);
    assert_eq!(a.base_collection_s, legacy.base_collection_s);
    assert_eq!(a.base_sync_s, legacy.base_sync_s);
    assert_eq!(a.base_wire_bytes, legacy.base_wire_bytes);
    // degenerate fairness: one tenant is perfectly fair to itself
    assert_eq!(fr.fairness_jain, 1.0);
    assert_eq!(fr.tenants.len(), 1);
    assert_eq!(fr.tenants[0].slo.offered, legacy.slo.offered);
    assert_eq!(fr.plan_cache.len(), 1);
    assert_eq!(fr.plan_cache[0].builds, 1);
    assert_eq!(fr.plan_cache[0].hits, 0);
}

#[test]
fn one_tenant_fabric_matches_legacy_in_measured_mode() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = setup(&g);
    let traffic = TrafficConfig {
        rps: 60.0,
        duration_s: 2.0,
        seed: 42,
        exec: ExecMode::Measured,
        ..Default::default()
    };
    let mut eng = engine();
    let legacy = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                              &omegas, &mut eng)
        .unwrap();
    let input = TenantInput {
        tenant: Tenant::legacy(&traffic, "gcn", "tiny"),
        g: &g,
        spec,
        opts: opts.clone(),
        omegas: omegas.clone(),
    };
    let fr = run_fabric(&cluster, vec![input], &traffic,
                        FairPolicy::Drr, &mut eng)
        .unwrap();
    let a = &fr.aggregate;
    // the offered stream is a pure function of the seed — identical;
    // wall-clock kernel timings are not, so the rest is structural
    assert_eq!(a.slo.offered, legacy.slo.offered);
    assert_eq!(
        a.slo.offered,
        a.slo.completed + a.slo.shed + a.slo.spilled
    );
    assert_eq!(a.engine, "csr-batched");
    assert_eq!(a.engine, legacy.engine);
    assert_eq!(a.kernel_threads, legacy.kernel_threads);
    assert!(a.slo.completed > 0);
    assert!(!a.bucket_host_ms.is_empty());
    assert!(a.latencies.iter().all(|&l| l > 0.0));
}

#[test]
fn n_tenant_run_is_deterministic_and_order_independent() {
    let (g, spec) = tiny();
    let (cluster, _, _) = setup(&g);
    let traffic = TrafficConfig {
        rps: 80.0,
        duration_s: 5.0,
        seed: 0xFA1,
        ..Default::default()
    };
    let tenants = || {
        let mk = |spec_str: &str| {
            TenantSpec::parse(spec_str)
                .unwrap()
                .resolve(&traffic, "gcn", "tiny")
        };
        vec![
            mk("name=alpha,model=gcn,arrival=poisson,rps=70,weight=2"),
            mk("name=beta,model=sage,arrival=bursty,rps=50"),
            mk("name=gamma,model=gcn,arrival=diurnal,rps=30,\
                weight=3,slo-ms=500"),
        ]
    };
    let run = |order: &[usize], eng: &mut Engine| -> FabricReport {
        let ts = tenants();
        let inputs: Vec<TenantInput<'_>> = order
            .iter()
            .map(|&i| input_for(ts[i].clone(), &g, spec,
                                cluster.len()))
            .collect();
        run_fabric(&cluster, inputs, &traffic, FairPolicy::Drr,
                   eng)
            .unwrap()
    };
    let mut eng = engine();
    let a = run(&[0, 1, 2], &mut eng);
    let b = run(&[0, 1, 2], &mut eng);
    let c = run(&[2, 0, 1], &mut eng);
    // (a) bit-deterministic under a fixed seed
    assert_eq!(a.aggregate.latencies, b.aggregate.latencies);
    assert_eq!(a.fairness_jain, b.fairness_jain);
    // (b) invariant under declaration order: reports come back in
    // canonical (name-sorted) order with identical contents
    assert_eq!(a.aggregate.latencies, c.aggregate.latencies);
    assert_eq!(a.aggregate.slo.shed, c.aggregate.slo.shed);
    assert_eq!(a.fairness_jain, c.fairness_jain);
    assert_eq!(a.tenants.len(), 3);
    for (ta, tc) in a.tenants.iter().zip(&c.tenants) {
        assert_eq!(ta.name, tc.name);
        assert_eq!(ta.latencies, tc.latencies, "tenant {}", ta.name);
        assert_eq!(ta.slo.offered, tc.slo.offered);
        assert_eq!(ta.slo.shed, tc.slo.shed);
        assert_eq!(ta.slo.goodput_rps, tc.slo.goodput_rps);
    }
    let names: Vec<&str> =
        a.tenants.iter().map(|t| t.name.as_str()).collect();
    assert_eq!(names, vec!["alpha", "beta", "gamma"]);
    // every tenant saw traffic and every request is accounted for
    for t in &a.tenants {
        assert!(t.slo.offered > 0, "tenant {} offered 0", t.name);
        assert_eq!(
            t.slo.offered,
            t.slo.completed + t.slo.shed + t.slo.spilled,
            "tenant {}",
            t.name
        );
        if t.slo.batches > 0 {
            assert!(
                t.slo.mean_batch > 0.0,
                "tenant {} has batches but mean_batch 0",
                t.name
            );
        }
    }
    // plan cache: gcn/tiny shared by alpha+gamma, sage/tiny by beta
    assert_eq!(a.plan_cache.len(), 2);
    let gcn = a
        .plan_cache
        .iter()
        .find(|e| e.model == "gcn")
        .unwrap();
    assert_eq!((gcn.builds, gcn.hits), (1, 1));
    let sage = a
        .plan_cache
        .iter()
        .find(|e| e.model == "sage")
        .unwrap();
    assert_eq!((sage.builds, sage.hits), (1, 0));
}

#[test]
fn weighted_fair_drr_protects_low_weight_tenant_from_burst() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = setup(&g);
    let mut eng = engine();
    // capacity probe: saturate the single-workload loop and read off
    // the completion rate, so the scenario scales to this host's
    // analytic service capacity instead of hard-coding one
    let probe_traffic = TrafficConfig {
        rps: 4000.0,
        duration_s: 3.0,
        seed: 0xCAB,
        ..Default::default()
    };
    let probe = run_loadtest(&g, &spec, &cluster, &opts,
                             &probe_traffic, &omegas, &mut eng)
        .unwrap();
    let cap =
        (probe.slo.completed as f64 / probe_traffic.duration_s)
            .max(50.0);

    let traffic = TrafficConfig {
        duration_s: 8.0,
        seed: 0xFA2,
        ..Default::default()
    };
    let run = |fair: FairPolicy, eng: &mut Engine| -> FabricReport {
        // the canonical scenario, shared with the loadtest
        // experiment's DRR-vs-FIFO table: high-weight bursty tenant
        // saturating the cluster (calm rate 1.25x capacity, bursts
        // 7.5x) with a deep queue and a lenient SLO vs a low-weight
        // latency-sensitive Poisson tenant at ~8% of capacity,
        // guaranteed a 20% DRR share by the 4:1 weights
        let (hi, lo) = fograph::traffic::tenant::burst_fairness_pair(
            &traffic, cap, "gcn", "sage", "tiny");
        assert_eq!(hi.arrival, ArrivalKind::Bursty);
        let inputs = vec![
            input_for(hi, &g, spec, cluster.len()),
            input_for(lo, &g, spec, cluster.len()),
        ];
        run_fabric(&cluster, inputs, &traffic, fair, eng).unwrap()
    };
    let drr = run(FairPolicy::Drr, &mut eng);
    let fifo = run(FairPolicy::Fifo, &mut eng);
    let lo_of = |fr: &FabricReport| {
        fr.tenants
            .iter()
            .find(|t| t.name == "lo-steady")
            .unwrap()
            .clone()
    };
    let (lo_drr, lo_fifo) = (lo_of(&drr), lo_of(&fifo));
    // identical seeded streams under both policies
    assert_eq!(lo_drr.slo.offered, lo_fifo.slo.offered);
    assert!(lo_drr.slo.offered > 0);
    // the fairness headline: under the burst the low-weight tenant's
    // p99 and goodput degrade STRICTLY less with weighted-fair DRR
    // than under the shared-FIFO control
    assert!(
        lo_drr.slo.latency.p99_s < lo_fifo.slo.latency.p99_s,
        "lo p99: drr {} !< fifo {}",
        lo_drr.slo.latency.p99_s,
        lo_fifo.slo.latency.p99_s
    );
    assert!(
        lo_drr.slo.goodput_rps > lo_fifo.slo.goodput_rps,
        "lo goodput: drr {} !> fifo {}",
        lo_drr.slo.goodput_rps,
        lo_fifo.slo.goodput_rps
    );
    // weight-normalized goodput is more evenly shared under DRR
    assert!(
        drr.fairness_jain >= fifo.fairness_jain,
        "jain: drr {} < fifo {}",
        drr.fairness_jain,
        fifo.fairness_jain
    );
    // sanity on the index itself
    let j = jain_index(&[1.0, 1.0]);
    assert!((j - 1.0).abs() < 1e-12);
}

#[test]
fn plan_cache_builds_each_measured_plan_once() {
    let (g, spec) = tiny();
    let (cluster, _, _) = setup(&g);
    let traffic = TrafficConfig {
        rps: 45.0,
        duration_s: 1.5,
        seed: 11,
        exec: ExecMode::Measured,
        kernel_threads: 2,
        ..Default::default()
    };
    let mk = |s: &str| {
        TenantSpec::parse(s).unwrap().resolve(&traffic, "gcn", "tiny")
    };
    let inputs = vec![
        input_for(mk("name=a1,model=gcn,rps=30"), &g, spec,
                  cluster.len()),
        input_for(mk("name=a2,model=gcn,rps=20,weight=2"), &g, spec,
                  cluster.len()),
        input_for(mk("name=b,model=sage,rps=15"), &g, spec,
                  cluster.len()),
    ];
    let mut eng = engine();
    let fr = run_fabric(&cluster, inputs, &traffic, FairPolicy::Drr,
                        &mut eng)
        .unwrap();
    // two distinct (model, dataset) services for three tenants: each
    // plan built exactly once, the shared gcn plan hit once
    assert_eq!(fr.plan_cache.len(), 2);
    for e in &fr.plan_cache {
        assert_eq!(e.builds, 1, "{}/{} built {} times", e.model,
                   e.dataset, e.builds);
    }
    let hits: usize = fr.plan_cache.iter().map(|e| e.hits).sum();
    assert_eq!(hits, 1, "3 tenants over 2 services = 1 cache hit");
    assert_eq!(fr.aggregate.engine, "csr-batched");
    assert_eq!(fr.aggregate.kernel_threads, 2);
    assert!(fr.aggregate.slo.completed > 0);
    assert!(!fr.aggregate.bucket_host_ms.is_empty());
    // every tenant was actually served real kernels
    for t in &fr.tenants {
        assert!(t.slo.completed > 0, "tenant {} served nothing",
                t.name);
    }
}

#[test]
fn pipelined_measured_fabric_accounts_every_request() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = setup(&g);
    let cfg = |depth: usize| TrafficConfig {
        rps: 60.0,
        duration_s: 2.0,
        seed: 42,
        exec: ExecMode::Measured,
        kernel_threads: 2,
        pipeline_depth: depth,
        ..Default::default()
    };
    let mut eng = engine();
    let mut run = |depth: usize| {
        let traffic = cfg(depth);
        let input = TenantInput {
            tenant: Tenant::legacy(&traffic, "gcn", "tiny"),
            g: &g,
            spec,
            opts: opts.clone(),
            omegas: omegas.clone(),
        };
        run_fabric(&cluster, vec![input], &traffic, FairPolicy::Drr,
                   &mut eng)
            .unwrap()
    };
    let d1 = run(1);
    let d2 = run(2);
    // the offered stream is a pure function of the seed, so depth
    // must not change WHAT arrives — only when it executes
    assert_eq!(d1.aggregate.slo.offered, d2.aggregate.slo.offered);
    for (label, fr) in [("depth1", &d1), ("depth2", &d2)] {
        let a = &fr.aggregate;
        assert_eq!(
            a.slo.offered,
            a.slo.completed + a.slo.shed + a.slo.spilled,
            "{label}: requests leaked through the deferred queue"
        );
        assert!(a.slo.completed > 0, "{label}: nothing served");
        assert!(a.latencies.iter().all(|&l| l > 0.0), "{label}");
        // every measured run carries the pipeline report
        let p = a.pipeline.as_ref().expect("measured pipeline report");
        assert_eq!(p.occupancy.len(), cluster.len(),
                   "{label}: occupancy is per-fog");
        assert!(
            p.occupancy.iter().all(|&o| (0.0..=1.0).contains(&o)),
            "{label}: occupancy out of [0,1]: {:?}",
            p.occupancy
        );
        assert!(p.stall_s >= 0.0, "{label}");
    }
    let p1 = d1.aggregate.pipeline.as_ref().unwrap();
    let p2 = d2.aggregate.pipeline.as_ref().unwrap();
    assert_eq!(p1.depth, 1);
    assert_eq!(p2.depth, 2);
    // a serial window never blocks on a full pipeline
    assert_eq!(p1.stall_s, 0.0);
}

#[test]
fn analytic_runs_ignore_pipeline_depth_bit_for_bit() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = setup(&g);
    let cfg = |depth: usize| TrafficConfig {
        rps: 80.0,
        duration_s: 4.0,
        seed: 0xFA3,
        pipeline_depth: depth,
        ..Default::default()
    };
    let mut eng = engine();
    let mut run = |depth: usize| {
        let traffic = cfg(depth);
        let input = TenantInput {
            tenant: Tenant::legacy(&traffic, "gcn", "tiny"),
            g: &g,
            spec,
            opts: opts.clone(),
            omegas: omegas.clone(),
        };
        run_fabric(&cluster, vec![input], &traffic, FairPolicy::Drr,
                   &mut eng)
            .unwrap()
    };
    let d1 = run(1);
    let d4 = run(4);
    // analytic pricing never builds a pipeline: identical timelines,
    // and no pipeline keys to perturb the committed report bytes
    assert_eq!(d1.aggregate.latencies, d4.aggregate.latencies);
    assert_eq!(d1.aggregate.slo.offered, d4.aggregate.slo.offered);
    assert_eq!(d1.aggregate.slo.completed, d4.aggregate.slo.completed);
    assert_eq!(d1.aggregate.slo.shed, d4.aggregate.slo.shed);
    assert_eq!(d1.fairness_jain, d4.fairness_jain);
    assert!(d1.aggregate.pipeline.is_none());
    assert!(d4.aggregate.pipeline.is_none());
}

#[test]
fn pipeline_depth_out_of_range_is_rejected() {
    let (g, spec) = tiny();
    let (cluster, _, _) = setup(&g);
    for bad in [0usize, fograph::util::cli::MAX_PIPELINE_DEPTH + 1] {
        let traffic = TrafficConfig {
            pipeline_depth: bad,
            ..Default::default()
        };
        let input = input_for(Tenant::legacy(&traffic, "gcn", "tiny"),
                              &g, spec, cluster.len());
        let mut eng = engine();
        assert!(
            run_fabric(&cluster, vec![input], &traffic,
                       FairPolicy::Drr, &mut eng)
                .is_err(),
            "pipeline_depth={bad} accepted"
        );
    }
}

#[test]
fn duplicate_tenant_names_are_rejected() {
    let (g, spec) = tiny();
    let (cluster, _, _) = setup(&g);
    let traffic = TrafficConfig::default();
    let t = Tenant::legacy(&traffic, "gcn", "tiny");
    let inputs = vec![
        input_for(t.clone(), &g, spec, cluster.len()),
        input_for(t, &g, spec, cluster.len()),
    ];
    let mut eng = engine();
    assert!(run_fabric(&cluster, inputs, &traffic, FairPolicy::Drr,
                       &mut eng)
        .is_err());
}

#[test]
fn malformed_tenant_specs_are_cli_errors() {
    // the exit-2 surface: zero weights and malformed fields must be
    // parse errors, never silently-defaulted tenants
    for bad in ["weight=0", "rps=-5", "arrival=sometimes",
                "weight=", "slo-ms=nan,weight=1", "rps"] {
        assert!(TenantSpec::parse(bad).is_err(), "{bad:?} accepted");
    }
}

// ----- chaos plane ------------------------------------------------

/// One-tenant analytic fabric run through the chaos entry point.
fn chaos_run(g: &Graph, spec: DatasetSpec, cluster: &Cluster,
             opts: &ServeOpts, omegas: &[PerfModel],
             traffic: &TrafficConfig, faults: &[FaultSpec],
             eng: &mut Engine) -> FabricReport {
    let input = TenantInput {
        tenant: Tenant::legacy(traffic, "gcn", "tiny"),
        g,
        spec,
        opts: opts.clone(),
        omegas: omegas.to_vec(),
    };
    run_fabric_chaos(cluster, vec![input], traffic, FairPolicy::Drr,
                     eng, &Recorder::disabled(), faults,
                     DEFAULT_TASK_DEADLINE_S)
        .unwrap()
}

#[test]
fn chaos_plane_with_no_faults_is_bitwise_fault_free() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = setup(&g);
    let traffic = TrafficConfig {
        rps: 100.0,
        duration_s: 5.0,
        seed: 0xC0,
        ..Default::default()
    };
    let mut eng = engine();
    let input = TenantInput {
        tenant: Tenant::legacy(&traffic, "gcn", "tiny"),
        g: &g,
        spec,
        opts: opts.clone(),
        omegas: omegas.clone(),
    };
    let plain = run_fabric(&cluster, vec![input], &traffic,
                           FairPolicy::Drr, &mut eng)
        .unwrap();
    let chaosless = chaos_run(&g, spec, &cluster, &opts, &omegas,
                              &traffic, &[], &mut eng);
    // the chaos plane compiled in but unarmed must not perturb a
    // single bit of the fault-free timeline or its report
    assert_eq!(plain.aggregate.latencies,
               chaosless.aggregate.latencies);
    assert_eq!(plain.aggregate.slo.offered,
               chaosless.aggregate.slo.offered);
    assert_eq!(plain.aggregate.slo.goodput_rps,
               chaosless.aggregate.slo.goodput_rps);
    assert_eq!(plain.aggregate.slo.shed, chaosless.aggregate.slo.shed);
    assert_eq!(plain.aggregate.exec_utilization,
               chaosless.aggregate.exec_utilization);
    assert!(plain.aggregate.faults.is_none());
    assert!(chaosless.aggregate.faults.is_none());
}

#[test]
fn chaos_run_is_deterministic_and_order_invariant() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = setup(&g);
    assert!(cluster.len() >= 2, "chaos scenario needs >= 2 fogs");
    let traffic = TrafficConfig {
        rps: 90.0,
        duration_s: 6.0,
        seed: 0xC1,
        ..Default::default()
    };
    let specs = [
        "crash@t=2,fog=1,rejoin=4",
        "slow@t=1,fog=0,factor=0.5,until=5",
        "link@t=3,src=0,dst=1,bw=0.5x,until=5",
    ];
    let parse_all = |order: &[usize]| -> Vec<FaultSpec> {
        order
            .iter()
            .map(|&i| FaultSpec::parse(specs[i]).unwrap())
            .collect()
    };
    let mut eng = engine();
    let a = chaos_run(&g, spec, &cluster, &opts, &omegas, &traffic,
                      &parse_all(&[0, 1, 2]), &mut eng);
    let b = chaos_run(&g, spec, &cluster, &opts, &omegas, &traffic,
                      &parse_all(&[0, 1, 2]), &mut eng);
    let c = chaos_run(&g, spec, &cluster, &opts, &omegas, &traffic,
                      &parse_all(&[2, 1, 0]), &mut eng);
    // (a) bit-deterministic for a fixed seed
    assert_eq!(a.aggregate.latencies, b.aggregate.latencies);
    assert_eq!(a.aggregate.faults, b.aggregate.faults);
    // (b) the schedule is canonicalized before jitter is drawn, so
    // declaration order cannot change a single bit either
    assert_eq!(a.aggregate.latencies, c.aggregate.latencies);
    assert_eq!(a.aggregate.faults, c.aggregate.faults);
    assert_eq!(a.aggregate.slo.goodput_rps,
               c.aggregate.slo.goodput_rps);
    let f = a.aggregate.faults.as_ref().expect("chaos report");
    // outcomes come back in canonical (t, class) order
    let classes: Vec<&str> =
        f.outcomes.iter().map(|o| o.class).collect();
    assert_eq!(classes, vec!["slow", "crash", "link"]);
}

#[test]
fn analytic_crash_is_detected_evacuated_and_reported() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = setup(&g);
    assert!(cluster.len() >= 2);
    let traffic = TrafficConfig {
        rps: 120.0,
        duration_s: 6.0,
        seed: 42,
        ..Default::default()
    };
    let faults = [FaultSpec::parse("crash@t=2,fog=1").unwrap()];
    let mut eng = engine();
    let fr = chaos_run(&g, spec, &cluster, &opts, &omegas, &traffic,
                       &faults, &mut eng);
    let a = &fr.aggregate;
    // the headline: a dead fog does not wedge the run
    assert!(a.slo.completed > 0);
    let c = a.faults.as_ref().expect("chaos report");
    assert_eq!(c.outcomes.len(), 1);
    let o = &c.outcomes[0];
    assert_eq!(o.class, "crash");
    assert_eq!(o.fog, 1);
    assert_eq!(o.peer, -1);
    assert!(o.t_fault_s >= 2.0 && o.t_fault_s < 2.1,
            "jittered onset out of band: {}", o.t_fault_s);
    // detected by the EWMA deadline, then evacuated (recovered) —
    // both within the run, recovery no earlier than detection
    assert!(o.time_to_detect_s >= 0.0, "undetected: {o:?}");
    assert!(o.recovered, "unrecovered: {o:?}");
    assert!(o.time_to_recover_s >= o.time_to_detect_s, "{o:?}");
    // the dead fog was priced/attributed at least once in the hole
    assert!(o.hedges >= 1, "{o:?}");
    assert!((0.0..=1.0).contains(&o.goodput_dip), "{o:?}");
    assert!(o.p99_delta_ms.is_finite());
    // evacuation rides the dual-mode rescheduler
    assert!(a.slo.replans >= 1, "no evacuation replan recorded");
}

#[test]
fn slow_and_link_faults_recover_at_until() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = setup(&g);
    assert!(cluster.len() >= 2);
    let traffic = TrafficConfig {
        rps: 100.0,
        duration_s: 6.0,
        seed: 7,
        ..Default::default()
    };
    let faults = [
        FaultSpec::parse("slow@t=1,fog=0,factor=0.3,until=3").unwrap(),
        FaultSpec::parse("link@t=2,src=0,dst=1,bw=0.2x,until=4")
            .unwrap(),
    ];
    let mut eng = engine();
    let fr = chaos_run(&g, spec, &cluster, &opts, &omegas, &traffic,
                       &faults, &mut eng);
    let c = fr.aggregate.faults.as_ref().expect("chaos report");
    assert_eq!(c.outcomes.len(), 2);
    let slow = &c.outcomes[0];
    assert_eq!(slow.class, "slow");
    assert_eq!((slow.fog, slow.peer), (0, -1));
    let link = &c.outcomes[1];
    assert_eq!(link.class, "link");
    assert_eq!((link.fog, link.peer), (0, 1));
    // both fault classes clear on their own at `until` — recovery is
    // the first batch finish past it
    for o in [slow, link] {
        assert!(o.recovered, "{o:?}");
        assert!(o.time_to_recover_s > 0.0, "{o:?}");
    }
    assert!(fr.aggregate.slo.completed > 0);
}

#[test]
fn malformed_fault_specs_are_cli_errors() {
    // the exit-2 surface, one rejection per grammar rule
    for bad in [
        "crash",                          // no class@... split
        "crash@t=2",                      // missing fog
        "crash@fog=1",                    // missing t
        "crash@t=-1,fog=0",               // negative onset
        "crash@t=2,fog=1,rejoin=1",       // rejoin before t
        "crash@t=2,fog=1,color=red",      // unknown key
        "crash@t=2,fog=1,t=3",            // duplicate key
        "slow@t=1,fog=0,factor=0",        // factor out of (0,1]
        "slow@t=1,fog=0,factor=1.5",      // factor out of (0,1]
        "slow@t=1,fog=0,factor=fast",     // non-numeric factor
        "slow@t=1,fog=0,factor=0.5,until=0.5", // until before t
        "link@t=1,src=0,dst=0,bw=0.5x",   // src == dst
        "link@t=1,src=0,dst=1",           // missing bw
        "meteor@t=1,fog=0",               // unknown class
    ] {
        assert!(FaultSpec::parse(bad).is_err(), "{bad:?} accepted");
    }
}

#[test]
fn out_of_range_faults_and_deadlines_are_rejected() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = setup(&g);
    let n = cluster.len();
    // fog ids past the cluster and onsets past the run end fail spec
    // validation...
    let dead = FaultSpec::parse(&format!("crash@t=2,fog={n}")).unwrap();
    assert!(dead.validate(n, 6.0).is_err());
    let late = FaultSpec::parse("crash@t=50,fog=0").unwrap();
    assert!(late.validate(n, 6.0).is_err());
    // ...and the library entry point enforces the same checks plus a
    // sane task deadline, so no caller can skip them
    let traffic = TrafficConfig {
        duration_s: 6.0,
        ..Default::default()
    };
    let mk_input = || TenantInput {
        tenant: Tenant::legacy(&traffic, "gcn", "tiny"),
        g: &g,
        spec,
        opts: opts.clone(),
        omegas: omegas.clone(),
    };
    let mut eng = engine();
    assert!(run_fabric_chaos(&cluster, vec![mk_input()], &traffic,
                             FairPolicy::Drr, &mut eng,
                             &Recorder::disabled(), &[dead],
                             DEFAULT_TASK_DEADLINE_S)
        .is_err());
    let ok = FaultSpec::parse("crash@t=2,fog=0").unwrap();
    for bad_deadline in [0.0, -1.0, f64::NAN, f64::INFINITY] {
        assert!(run_fabric_chaos(&cluster, vec![mk_input()], &traffic,
                                 FairPolicy::Drr, &mut eng,
                                 &Recorder::disabled(), &[ok],
                                 bad_deadline)
            .is_err(), "task deadline {bad_deadline} accepted");
    }
}

// ----- streaming-graph plane --------------------------------------

/// One-tenant analytic fabric run through the churn entry point.
fn churn_run(g: &Graph, spec: DatasetSpec, cluster: &Cluster,
             opts: &ServeOpts, omegas: &[PerfModel],
             traffic: &TrafficConfig, churn: &[ChurnSpec],
             eng: &mut Engine)
             -> Result<FabricReport, fograph::runtime::EngineError> {
    let input = TenantInput {
        tenant: Tenant::legacy(traffic, "gcn", "tiny"),
        g,
        spec,
        opts: opts.clone(),
        omegas: omegas.to_vec(),
    };
    run_fabric_churn(cluster, vec![input], traffic, FairPolicy::Drr,
                     eng, &Recorder::disabled(), &[],
                     DEFAULT_TASK_DEADLINE_S, churn)
}

fn churn_specs(texts: &[&str]) -> Vec<ChurnSpec> {
    texts
        .iter()
        .map(|t| ChurnSpec::parse(t).expect("valid churn spec"))
        .collect()
}

#[test]
fn churn_plumbing_with_no_specs_is_bitwise_churn_free() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = setup(&g);
    let traffic = TrafficConfig {
        rps: 100.0,
        duration_s: 5.0,
        seed: 0xD0,
        ..Default::default()
    };
    let mut eng = engine();
    let input = TenantInput {
        tenant: Tenant::legacy(&traffic, "gcn", "tiny"),
        g: &g,
        spec,
        opts: opts.clone(),
        omegas: omegas.clone(),
    };
    let plain = run_fabric(&cluster, vec![input], &traffic,
                           FairPolicy::Drr, &mut eng)
        .unwrap();
    let churnless = churn_run(&g, spec, &cluster, &opts, &omegas,
                              &traffic, &[], &mut eng)
        .unwrap();
    // the churn plane compiled in but unarmed must not perturb a
    // single bit of the static-topology timeline or its report
    assert_eq!(plain.aggregate.latencies,
               churnless.aggregate.latencies);
    assert_eq!(plain.aggregate.slo.offered,
               churnless.aggregate.slo.offered);
    assert_eq!(plain.aggregate.slo.goodput_rps,
               churnless.aggregate.slo.goodput_rps);
    assert_eq!(plain.aggregate.slo.diffusions,
               churnless.aggregate.slo.diffusions);
    assert_eq!(plain.aggregate.slo.replans,
               churnless.aggregate.slo.replans);
    assert_eq!(plain.aggregate.exec_utilization,
               churnless.aggregate.exec_utilization);
    assert!(plain.aggregate.churn.is_none());
    assert!(churnless.aggregate.churn.is_none());
}

#[test]
fn churn_run_reports_partial_regrounds_and_is_deterministic() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = setup(&g);
    assert!(cluster.len() >= 2, "churn scenario needs >= 2 fogs");
    let traffic = TrafficConfig {
        rps: 90.0,
        duration_s: 6.0,
        seed: 0xD1,
        scheduler_period_s: 1.0,
        ..Default::default()
    };
    let specs = churn_specs(&[
        "add-edge@rate=0.01",
        "del-edge@rate=0.008",
        "add-vertex@rate=0.004,degree=3",
        "del-vertex@rate=0.002",
    ]);
    let mut eng = engine();
    let a = churn_run(&g, spec, &cluster, &opts, &omegas, &traffic,
                      &specs, &mut eng)
        .unwrap();
    let b = churn_run(&g, spec, &cluster, &opts, &omegas, &traffic,
                      &specs, &mut eng)
        .unwrap();
    // bit-deterministic for a fixed seed: same latency timeline,
    // same topology trajectory, same invalidation counters
    assert_eq!(a.aggregate.latencies, b.aggregate.latencies);
    assert_eq!(a.aggregate.churn, b.aggregate.churn);
    let c = a.aggregate.churn.expect("churn summary");
    assert!(c.stats.rounds > 0, "no churn rounds fired: {c:?}");
    assert!(c.stats.deltas_applied > 0, "{c:?}");
    assert!(c.final_live_vertices > 0, "{c:?}");
    // the mutating run still serves traffic
    assert!(a.aggregate.slo.completed > 0);
    // declaration order of specs cannot change a bit either
    let rev: Vec<ChurnSpec> =
        specs.iter().rev().cloned().collect();
    let d = churn_run(&g, spec, &cluster, &opts, &omegas, &traffic,
                      &rev, &mut eng)
        .unwrap();
    assert_eq!(a.aggregate.latencies, d.aggregate.latencies);
    assert_eq!(a.aggregate.churn, d.aggregate.churn);
}

#[test]
fn invalid_churn_combinations_are_loud_errors() {
    let (g, spec) = tiny();
    let (cluster, opts, omegas) = setup(&g);
    let specs = churn_specs(&["add-edge@rate=0.01"]);
    let mut eng = engine();
    // measured exec pins the topology in the worker pool
    let measured = TrafficConfig {
        duration_s: 2.0,
        exec: ExecMode::Measured,
        ..Default::default()
    };
    assert!(churn_run(&g, spec, &cluster, &opts, &omegas, &measured,
                      &specs, &mut eng)
        .is_err());
    // a disabled scheduler leaves no replan barriers to churn at
    let no_sched = TrafficConfig {
        duration_s: 2.0,
        scheduler_period_s: 0.0,
        ..Default::default()
    };
    assert!(churn_run(&g, spec, &cluster, &opts, &omegas, &no_sched,
                      &specs, &mut eng)
        .is_err());
    // churn + chaos faults is rejected: the evacuation replans
    // against the static grounding graph
    let traffic = TrafficConfig {
        duration_s: 6.0,
        ..Default::default()
    };
    let input = TenantInput {
        tenant: Tenant::legacy(&traffic, "gcn", "tiny"),
        g: &g,
        spec,
        opts: opts.clone(),
        omegas: omegas.clone(),
    };
    let fault = [FaultSpec::parse("crash@t=2,fog=0").unwrap()];
    assert!(run_fabric_churn(&cluster, vec![input], &traffic,
                             FairPolicy::Drr, &mut eng,
                             &Recorder::disabled(), &fault,
                             DEFAULT_TASK_DEADLINE_S, &specs)
        .is_err());
}
