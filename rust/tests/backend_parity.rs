//! Cross-backend parity: the sparse CSR backend must agree with the
//! dense reference forward within 1e-5 on gcn/gat/sage over a seeded
//! random graph — per layer, end-to-end through the distributed BSP
//! runtime, and for block-diagonal batched execution vs per-request
//! execution.

use fograph::exec;
use fograph::graph::{generate, subgraph, Graph};
use fograph::runtime::csr_backend::{run_layer_csr, CsrPartition};
use fograph::runtime::{pad, Engine, EngineKind, WeightBundle};

fn seeded_graph() -> Graph {
    let (mut g, _) = generate::sbm(300, 1200, 4, 0.85, 3);
    let f_in = 8;
    let mut rng = fograph::util::rng::Rng::new(41);
    g.features =
        (0..300 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    g.feature_dim = f_in;
    g
}

fn engine(kind: EngineKind) -> Engine {
    let dir = std::env::temp_dir().join("backend_parity");
    std::fs::create_dir_all(&dir).unwrap();
    Engine::new(kind, &dir).unwrap()
}

fn synth_weights(model: &str, f_in: usize) -> WeightBundle {
    engine(EngineKind::Reference)
        .weights(model, "tiny", f_in, 3)
        .clone()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
}

#[test]
fn csr_layer_matches_reference_layer_all_models() {
    let g = seeded_graph();
    let f_in = g.feature_dim;
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|v| (v % 3) as u32).collect();
    let (subs, _) = subgraph::extract(&g, &assignment, 3);
    for model in ["gcn", "sage", "gat"] {
        let wb = synth_weights(model, f_in);
        for sub in &subs {
            let edges = pad::prep_edges(model, sub).unwrap();
            let csr = CsrPartition::from_edges(&edges);
            let mut rng = fograph::util::rng::Rng::new(7);
            let h: Vec<f32> = (0..sub.n_total() * f_in)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let dense = fograph::runtime::reference::run_layer(
                model, 0, &wb, &h, f_in, &edges, false,
            )
            .unwrap();
            let sparse =
                run_layer_csr(model, 0, &wb, &h, f_in, &csr, false, 1)
                    .unwrap();
            let err = max_abs_diff(&dense, &sparse);
            assert!(err < 1e-5, "{model}: layer deviates by {err}");
        }
    }
}

#[test]
fn batched_blockdiagonal_matches_per_request_all_models() {
    let g = seeded_graph();
    let f_in = g.feature_dim;
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|v| (v % 2) as u32).collect();
    let (subs, _) = subgraph::extract(&g, &assignment, 2);
    let batch = 4;
    for model in ["gcn", "sage", "gat"] {
        let wb = synth_weights(model, f_in);
        let edges = pad::prep_edges(model, &subs[0]).unwrap();
        let csr = CsrPartition::from_edges(&edges);
        let n = subs[0].n_total();
        let l = subs[0].n_local;
        // DIFFERENT features per block: the block-diagonal structure
        // must keep requests fully independent
        let mut rng = fograph::util::rng::Rng::new(91);
        let h: Vec<f32> = (0..batch * n * f_in)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let stacked =
            run_layer_csr(model, 0, &wb, &h, f_in, &csr, false, batch)
                .unwrap();
        let fo = stacked.len() / (batch * l);
        for bk in 0..batch {
            let one = run_layer_csr(
                model,
                0,
                &wb,
                &h[bk * n * f_in..(bk + 1) * n * f_in],
                f_in,
                &csr,
                false,
                1,
            )
            .unwrap();
            assert_eq!(
                &stacked[bk * l * fo..(bk + 1) * l * fo],
                &one[..],
                "{model}: block {bk} differs from its solo run"
            );
        }
    }
}

#[test]
fn csr_engine_matches_reference_through_bsp() {
    let g = seeded_graph();
    let f_in = g.feature_dim;
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|v| (v % 3) as u32).collect();
    for model in ["gcn", "sage", "gat"] {
        let mut re = engine(EngineKind::Reference);
        let mut ce = engine(EngineKind::Csr);
        let a = exec::run_bsp(&g, &g.features, f_in, &assignment, 3,
                              model, "tiny", 3, &mut re)
            .unwrap();
        let b = exec::run_bsp(&g, &g.features, f_in, &assignment, 3,
                              model, "tiny", 3, &mut ce)
            .unwrap();
        assert_eq!(a.out_dim, b.out_dim);
        // two f32 summation orders drift slightly more across the
        // stacked 2-layer pipeline than within one layer
        let err = max_abs_diff(&a.outputs, &b.outputs);
        assert!(err < 1e-4, "{model}: bsp outputs deviate by {err}");
    }
}

#[test]
fn sparse_astgcn_matches_dense_reference() {
    let (mut g, _) = generate::sbm(60, 220, 3, 0.8, 9);
    let ft = 36;
    let mut rng = fograph::util::rng::Rng::new(13);
    g.features = (0..60 * ft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    g.feature_dim = ft;
    let assignment: Vec<u32> = (0..60).map(|v| (v % 2) as u32).collect();
    let mut re = engine(EngineKind::Reference);
    let mut ce = engine(EngineKind::Csr);
    let a = exec::run_bsp(&g, &g.features, ft, &assignment, 2, "astgcn",
                          "tinypems", 0, &mut re)
        .unwrap();
    let b = exec::run_bsp(&g, &g.features, ft, &assignment, 2, "astgcn",
                          "tinypems", 0, &mut ce)
        .unwrap();
    assert_eq!(a.out_dim, b.out_dim);
    let err = max_abs_diff(&a.outputs, &b.outputs);
    assert!(err < 1e-4, "astgcn sparse attention deviates by {err}");
}

#[test]
fn parallel_batched_bsp_matches_serial_reference() {
    let g = seeded_graph();
    let f_in = g.feature_dim;
    let nv = g.num_vertices();
    let assignment: Vec<u32> =
        (0..nv).map(|v| (v % 3) as u32).collect();
    let batch = 2;
    for model in ["gcn", "sage", "gat"] {
        let mut re = engine(EngineKind::Reference);
        let serial = exec::run_bsp(&g, &g.features, f_in, &assignment,
                                   3, model, "tiny", 3, &mut re)
            .unwrap();
        let mut ce = engine(EngineKind::Csr);
        let par = exec::run_parallel(&g, &g.features, f_in, &assignment,
                                     3, model, "tiny", 3, &mut ce,
                                     batch)
            .unwrap();
        assert_eq!(par.out_dim, serial.out_dim);
        assert_eq!(par.outputs.len(),
                   batch * serial.outputs.len());
        // every block of the block-diagonal batch equals the serial run
        let per = nv * par.out_dim;
        for bk in 0..batch {
            let err = max_abs_diff(
                &par.outputs[bk * per..(bk + 1) * per],
                &serial.outputs,
            );
            assert!(
                err < 1e-4,
                "{model}: batched block {bk} deviates by {err}"
            );
        }
        // measured timings exist for every layer × fog
        assert_eq!(par.layer_host_seconds.len(), 2);
        assert!(par.layer_host_seconds.iter().all(|l| l.len() == 3));
        // batched sync ships `batch` copies of the halo rows
        assert_eq!(par.sync_bytes[0], batch * serial.sync_bytes[0]);
    }
}

#[test]
fn measured_path_rejects_astgcn() {
    let g = seeded_graph();
    let mut ce = engine(EngineKind::Csr);
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|_| 0u32).collect();
    let r = exec::run_parallel(&g, &g.features, g.feature_dim,
                               &assignment, 1, "astgcn", "tiny", 0,
                               &mut ce, 1);
    assert!(r.is_err());
}
