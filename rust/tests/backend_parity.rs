//! Cross-backend and cross-kernel parity: the sparse CSR backend must
//! agree with the dense reference forward within 1e-5 on gcn/gat/sage
//! over a seeded random graph — per layer, end-to-end through the
//! distributed BSP runtime, and for block-diagonal batched execution vs
//! per-request execution. The tiled/blocked kernels
//! (`runtime::kernels`) must agree with their naive baselines across
//! random shapes (including non-multiples of the tile sizes and empty
//! rows), pool-executed BSP must equal the serial oracle bit-for-bit,
//! intra-fog row-sharded execution must be bitwise identical to the
//! unsharded path for ANY shard width / batch size (row-decomposition
//! invariance), and the runtime-dispatched AVX2+FMA micro-kernels must
//! agree with the portable scalar kernels within 1e-5 when the feature
//! is detected. Chaos mode adds the recovery parity oracle: a crashed
//! fog's hedged re-dispatch and a slowed fog's delayed replies must
//! reproduce the fault-free barrier outputs bit-for-bit — faults may
//! only ever change timing, never bytes.

use std::sync::Arc;

use fograph::exec::{self, BatchedBspPlan};
use fograph::graph::{generate, subgraph, Graph};
use fograph::runtime::csr_backend::{in_neighbor_lists,
                                    run_astgcn_csr,
                                    run_astgcn_csr_sharded,
                                    run_layer_csr,
                                    run_layer_csr_sharded,
                                    CsrPartition};
use fograph::runtime::kernels::{gemm, simd, spmm, ShardExec};
use fograph::runtime::{pad, EdgeArrays, Engine, EngineKind,
                       WeightBundle};
use fograph::util::rng::Rng;

fn seeded_graph() -> Graph {
    let (mut g, _) = generate::sbm(300, 1200, 4, 0.85, 3);
    let f_in = 8;
    let mut rng = fograph::util::rng::Rng::new(41);
    g.features =
        (0..300 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    g.feature_dim = f_in;
    g
}

fn engine(kind: EngineKind) -> Engine {
    let dir = std::env::temp_dir().join("backend_parity");
    std::fs::create_dir_all(&dir).unwrap();
    Engine::new(kind, &dir).unwrap()
}

fn synth_weights(model: &str, f_in: usize) -> WeightBundle {
    engine(EngineKind::Reference)
        .weights(model, "tiny", f_in, 3)
        .clone()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
}

#[test]
fn csr_layer_matches_reference_layer_all_models() {
    let g = seeded_graph();
    let f_in = g.feature_dim;
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|v| (v % 3) as u32).collect();
    let (subs, _) = subgraph::extract(&g, &assignment, 3);
    for model in ["gcn", "sage", "gat"] {
        let wb = synth_weights(model, f_in);
        for sub in &subs {
            let edges = pad::prep_edges(model, sub).unwrap();
            let csr = CsrPartition::from_edges(&edges);
            let mut rng = fograph::util::rng::Rng::new(7);
            let h: Vec<f32> = (0..sub.n_total() * f_in)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let dense = fograph::runtime::reference::run_layer(
                model, 0, &wb, &h, f_in, &edges, false,
            )
            .unwrap();
            let sparse =
                run_layer_csr(model, 0, &wb, &h, f_in, &csr, false, 1)
                    .unwrap();
            let err = max_abs_diff(&dense, &sparse);
            assert!(err < 1e-5, "{model}: layer deviates by {err}");
        }
    }
}

#[test]
fn batched_blockdiagonal_matches_per_request_all_models() {
    let g = seeded_graph();
    let f_in = g.feature_dim;
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|v| (v % 2) as u32).collect();
    let (subs, _) = subgraph::extract(&g, &assignment, 2);
    let batch = 4;
    for model in ["gcn", "sage", "gat"] {
        let wb = synth_weights(model, f_in);
        let edges = pad::prep_edges(model, &subs[0]).unwrap();
        let csr = CsrPartition::from_edges(&edges);
        let n = subs[0].n_total();
        let l = subs[0].n_local;
        // DIFFERENT features per block: the block-diagonal structure
        // must keep requests fully independent
        let mut rng = fograph::util::rng::Rng::new(91);
        let h: Vec<f32> = (0..batch * n * f_in)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let stacked =
            run_layer_csr(model, 0, &wb, &h, f_in, &csr, false, batch)
                .unwrap();
        let fo = stacked.len() / (batch * l);
        for bk in 0..batch {
            let one = run_layer_csr(
                model,
                0,
                &wb,
                &h[bk * n * f_in..(bk + 1) * n * f_in],
                f_in,
                &csr,
                false,
                1,
            )
            .unwrap();
            assert_eq!(
                &stacked[bk * l * fo..(bk + 1) * l * fo],
                &one[..],
                "{model}: block {bk} differs from its solo run"
            );
        }
    }
}

#[test]
fn csr_engine_matches_reference_through_bsp() {
    let g = seeded_graph();
    let f_in = g.feature_dim;
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|v| (v % 3) as u32).collect();
    for model in ["gcn", "sage", "gat"] {
        let mut re = engine(EngineKind::Reference);
        let mut ce = engine(EngineKind::Csr);
        let a = exec::run_bsp(&g, &g.features, f_in, &assignment, 3,
                              model, "tiny", 3, &mut re)
            .unwrap();
        let b = exec::run_bsp(&g, &g.features, f_in, &assignment, 3,
                              model, "tiny", 3, &mut ce)
            .unwrap();
        assert_eq!(a.out_dim, b.out_dim);
        // two f32 summation orders drift slightly more across the
        // stacked 2-layer pipeline than within one layer
        let err = max_abs_diff(&a.outputs, &b.outputs);
        assert!(err < 1e-4, "{model}: bsp outputs deviate by {err}");
    }
}

#[test]
fn sparse_astgcn_matches_dense_reference() {
    let (mut g, _) = generate::sbm(60, 220, 3, 0.8, 9);
    let ft = 36;
    let mut rng = fograph::util::rng::Rng::new(13);
    g.features = (0..60 * ft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    g.feature_dim = ft;
    let assignment: Vec<u32> = (0..60).map(|v| (v % 2) as u32).collect();
    let mut re = engine(EngineKind::Reference);
    let mut ce = engine(EngineKind::Csr);
    let a = exec::run_bsp(&g, &g.features, ft, &assignment, 2, "astgcn",
                          "tinypems", 0, &mut re)
        .unwrap();
    let b = exec::run_bsp(&g, &g.features, ft, &assignment, 2, "astgcn",
                          "tinypems", 0, &mut ce)
        .unwrap();
    assert_eq!(a.out_dim, b.out_dim);
    let err = max_abs_diff(&a.outputs, &b.outputs);
    assert!(err < 1e-4, "astgcn sparse attention deviates by {err}");
}

#[test]
fn parallel_batched_bsp_matches_serial_reference() {
    let g = seeded_graph();
    let f_in = g.feature_dim;
    let nv = g.num_vertices();
    let assignment: Vec<u32> =
        (0..nv).map(|v| (v % 3) as u32).collect();
    let batch = 2;
    for model in ["gcn", "sage", "gat"] {
        let mut re = engine(EngineKind::Reference);
        let serial = exec::run_bsp(&g, &g.features, f_in, &assignment,
                                   3, model, "tiny", 3, &mut re)
            .unwrap();
        let mut ce = engine(EngineKind::Csr);
        let par = exec::run_parallel(&g, &g.features, f_in, &assignment,
                                     3, model, "tiny", 3, &mut ce,
                                     batch)
            .unwrap();
        assert_eq!(par.out_dim, serial.out_dim);
        assert_eq!(par.outputs.len(),
                   batch * serial.outputs.len());
        // every block of the block-diagonal batch equals the serial run
        let per = nv * par.out_dim;
        for bk in 0..batch {
            let err = max_abs_diff(
                &par.outputs[bk * per..(bk + 1) * per],
                &serial.outputs,
            );
            assert!(
                err < 1e-4,
                "{model}: batched block {bk} deviates by {err}"
            );
        }
        // measured timings exist for every layer × fog
        assert_eq!(par.layer_host_seconds.len(), 2);
        assert!(par.layer_host_seconds.iter().all(|l| l.len() == 3));
        // batched sync ships `batch` copies of the halo rows
        assert_eq!(par.sync_bytes[0], batch * serial.sync_bytes[0]);
    }
}

#[test]
fn measured_path_astgcn_matches_reference_bsp() {
    let (mut g, _) = generate::sbm(60, 220, 3, 0.8, 9);
    let ft = 36;
    let mut rng = Rng::new(77);
    g.features = (0..60 * ft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    g.feature_dim = ft;
    let assignment: Vec<u32> = (0..60).map(|v| (v % 2) as u32).collect();
    let mut re = engine(EngineKind::Reference);
    let serial = exec::run_bsp(&g, &g.features, ft, &assignment, 2,
                               "astgcn", "tinypems", 0, &mut re)
        .unwrap();
    let mut ce = engine(EngineKind::Csr);
    let batch = 3;
    let par = exec::run_parallel(&g, &g.features, ft, &assignment, 2,
                                 "astgcn", "tinypems", 0, &mut ce,
                                 batch)
        .unwrap();
    assert_eq!(par.out_dim, serial.out_dim);
    let per = 60 * par.out_dim;
    for bk in 0..batch {
        let err = max_abs_diff(
            &par.outputs[bk * per..(bk + 1) * per],
            &serial.outputs,
        );
        assert!(err < 1e-4,
                "astgcn batched block {bk} deviates by {err}");
    }
    // one layer, one measured timing per fog
    assert_eq!(par.layer_host_seconds.len(), 1);
    assert_eq!(par.layer_host_seconds[0].len(), 2);
}

// ---- kernel-level property parity (tiled/blocked vs naive) -------------

/// Random shapes around the tile boundaries: exact multiples, one-off,
/// degenerate dims, fo below the column-tile width.
#[test]
fn tiled_gemm_matches_naive_across_random_shapes() {
    let mut rng = Rng::new(0x9E1);
    for trial in 0..60 {
        let n = 1 + rng.usize_below(70);
        let fi = 1 + rng.usize_below(300);
        let fo = 1 + rng.usize_below(90);
        // one-hot-ish sparsity exercises the zero-row skip fast path
        let zero_p = if trial % 2 == 0 { 0.0 } else { 0.6 };
        let x: Vec<f32> = (0..n * fi)
            .map(|_| {
                if zero_p > 0.0 && rng.bool(zero_p) {
                    0.0
                } else {
                    rng.normal_f32(0.0, 0.3)
                }
            })
            .collect();
        let w: Vec<f32> =
            (0..fi * fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let b: Vec<f32> =
            (0..fo).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let tiled = gemm::gemm_bias(&x, n, fi, &w, fo, &b);
        let naive = gemm::gemm_bias_naive(&x, n, fi, &w, fo, &b);
        for (i, (a, e)) in tiled.iter().zip(&naive).enumerate() {
            let tol = 1e-5 * (1.0 + a.abs().max(e.abs()));
            assert!(
                (a - e).abs() <= tol,
                "trial {trial} ({n}x{fi}x{fo}) elem {i}: {a} vs {e}"
            );
        }
    }
}

/// Random CSR structures with empty rows, halo columns and mixed edge
/// weights (including masked zeros dropped at construction).
#[test]
fn blocked_spmm_matches_naive_across_random_structures() {
    let mut rng = Rng::new(0x5B2);
    for trial in 0..40 {
        let l = 1 + rng.usize_below(120);
        let n = l + rng.usize_below(30); // halo rows
        let ne = rng.usize_below(6 * l + 1);
        let mut src = Vec::with_capacity(ne);
        let mut dst = Vec::with_capacity(ne);
        let mut ew = Vec::with_capacity(ne);
        for _ in 0..ne {
            src.push(rng.usize_below(n) as u32);
            dst.push(rng.usize_below(l) as u32);
            ew.push(match rng.usize_below(4) {
                0 => 1.0,
                1 => 0.0, // masked: dropped at construction
                _ => rng.normal_f32(0.5, 0.3),
            });
        }
        let edges = EdgeArrays {
            src,
            dst,
            ew,
            inv_deg: vec![1.0; l],
            n,
            n_local: l,
        };
        let csr = CsrPartition::from_edges(&edges);
        let f = 1 + rng.usize_below(200);
        let h: Vec<f32> =
            (0..n * f).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let blocked = spmm::csr_spmm(&csr, &h, f);
        let naive = spmm::csr_spmm_naive(&csr, &h, f);
        // the blocked kernel vs the naive loop over the same CSR
        for (i, (a, e)) in blocked.iter().zip(&naive).enumerate() {
            let tol = 1e-5 * (1.0 + a.abs().max(e.abs()));
            assert!(
                (a - e).abs() <= tol,
                "trial {trial} (l={l} f={f}) elem {i}: {a} vs {e}"
            );
        }
        // and vs the masked COO reference (covers the zero-drop)
        let coo = fograph::runtime::reference::segment_aggregate(
            &h, f, &edges, l,
        );
        for (i, (a, e)) in blocked.iter().zip(&coo).enumerate() {
            let tol = 1e-5 * (1.0 + a.abs().max(e.abs()));
            assert!(
                (a - e).abs() <= tol,
                "trial {trial} coo elem {i}: {a} vs {e}"
            );
        }
    }
}

/// Pool-executed BSP must equal the spawn-free serial oracle
/// bit-for-bit (same kernels, same order, only the threading differs)
/// — and the intra-fog sharded pool (`--kernel-threads 4`) must equal
/// BOTH, at a batch size that genuinely splits rows.
#[test]
fn pooled_bsp_equals_serial_oracle_bitwise() {
    let g = seeded_graph();
    let f_in = g.feature_dim;
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|v| (v % 3) as u32).collect();
    for model in ["gcn", "sage", "gat"] {
        let wb = Arc::new(synth_weights(model, f_in));
        let plan = BatchedBspPlan::new(&g, &assignment, 3, model)
            .unwrap();
        let pooled = plan.execute(&g.features, f_in, &wb, 4);
        let serial = plan.execute_serial(&g.features, f_in, &wb, 4);
        assert_eq!(pooled.out_dim, serial.out_dim);
        assert_eq!(pooled.outputs, serial.outputs,
                   "{model}: pooled != serial");
        assert_eq!(pooled.sync_bytes, serial.sync_bytes);
        // 100 owned rows per fog × batch 8 clears the shard threshold
        let sharded =
            BatchedBspPlan::with_threads(&g, &assignment, 3, model, 4)
                .unwrap();
        let pooled8 = plan.execute(&g.features, f_in, &wb, 8);
        let sharded8 = sharded.execute(&g.features, f_in, &wb, 8);
        let sharded8s =
            sharded.execute_serial(&g.features, f_in, &wb, 8);
        assert_eq!(sharded8.outputs, pooled8.outputs,
                   "{model}: sharded pool != single-threaded pool");
        assert_eq!(sharded8.outputs, sharded8s.outputs,
                   "{model}: sharded pool != its serial oracle");
    }
}

/// Row-sharded layer execution must be bitwise identical to the
/// unsharded path for ANY shard width and batch size — the shard
/// widths pick different contiguous split points, and
/// row-decomposition invariance makes every one of them exact.
#[test]
fn sharded_layer_bitwise_equals_unsharded_across_widths() {
    let g = seeded_graph();
    let f_in = g.feature_dim;
    // one fog owning everything: the case intra-fog sharding exists for
    let (subs, _) =
        subgraph::extract(&g, &vec![0; g.num_vertices()], 1);
    for model in ["gcn", "sage", "gat"] {
        let wb = Arc::new(synth_weights(model, f_in));
        let edges = pad::prep_edges(model, &subs[0]).unwrap();
        let csr = Arc::new(CsrPartition::from_edges(&edges));
        let n = subs[0].n_total();
        let mut rng = Rng::new(0x5AA + f_in as u64);
        for batch in [1usize, 2, 5] {
            let h: Vec<f32> = (0..batch * n * f_in)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let unsharded = run_layer_csr(model, 0, &wb, &h, f_in,
                                          &csr, false, batch)
                .unwrap();
            let h = Arc::new(h);
            for width in [2usize, 3, 4, 7] {
                let exec = ShardExec::Inline(width);
                let sharded = run_layer_csr_sharded(
                    model, 0, &wb, &h, f_in, &csr, false, batch,
                    &exec,
                )
                .unwrap();
                assert_eq!(
                    sharded, unsharded,
                    "{model} batch={batch} width={width}: sharded \
                     deviates"
                );
            }
        }
    }
}

/// Same invariant for the ASTGCN block: sharded projections +
/// attention combine reproduce the per-block serial loop bit-for-bit.
#[test]
fn sharded_astgcn_bitwise_equals_unsharded() {
    let (mut g, _) = generate::sbm(600, 2400, 4, 0.8, 15);
    let ft = 36;
    let mut rng = Rng::new(0xA57);
    g.features =
        (0..600 * ft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    g.feature_dim = ft;
    let sub = Arc::new(subgraph::extract(&g, &vec![0; 600], 1).0
        .remove(0));
    let n = sub.n_total();
    let wb = Arc::new(
        engine(EngineKind::Reference)
            .weights("astgcn", "tinypems", ft, 0)
            .clone(),
    );
    let batch = 2;
    let x: Vec<f32> = (0..batch * n * ft)
        .map(|_| rng.normal_f32(0.0, 1.0))
        .collect();
    let mut unsharded = Vec::new();
    for bk in 0..batch {
        unsharded.extend(run_astgcn_csr(
            &wb,
            &x[bk * n * ft..(bk + 1) * n * ft],
            n,
            ft,
            &sub,
        ));
    }
    let x = Arc::new(x);
    let nbr = Arc::new(in_neighbor_lists(&sub, n));
    for width in [2usize, 4] {
        let exec = ShardExec::Inline(width);
        let sharded = run_astgcn_csr_sharded(&wb, &x, n, ft, &nbr,
                                             batch, &exec);
        assert_eq!(sharded, unsharded,
                   "astgcn width={width}: sharded deviates");
    }
}

/// AVX2-vs-scalar parity within 1e-5 relative across random shapes —
/// exercised only when the runtime dispatcher detected the feature
/// (skipped otherwise: both paths would be the same code).
#[test]
fn avx2_kernels_match_scalar_within_tolerance() {
    if !simd::avx2_active() {
        eprintln!("avx2+fma not detected ({}): parity test skipped",
                  simd::name());
        return;
    }
    let mut rng = Rng::new(0xA5A5);
    for trial in 0..40 {
        let n = 1 + rng.usize_below(60);
        let fi = 1 + rng.usize_below(120);
        let fo = 1 + rng.usize_below(100);
        let zero_p = if trial % 2 == 0 { 0.0 } else { 0.5 };
        let x: Vec<f32> = (0..n * fi)
            .map(|_| {
                if zero_p > 0.0 && rng.bool(zero_p) {
                    0.0
                } else {
                    rng.normal_f32(0.0, 0.3)
                }
            })
            .collect();
        let w: Vec<f32> =
            (0..fi * fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let b: Vec<f32> =
            (0..fo).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let dispatched = gemm::gemm_bias(&x, n, fi, &w, fo, &b);
        let mut scalar = vec![0f32; n * fo];
        gemm::gemm_bias_into_scalar(&x, n, fi, &w, fo, &b,
                                    &mut scalar);
        for (i, (a, e)) in dispatched.iter().zip(&scalar).enumerate() {
            let tol = 1e-5 * (1.0 + a.abs().max(e.abs()));
            assert!(
                (a - e).abs() <= tol,
                "gemm trial {trial} ({n}x{fi}x{fo}) elem {i}: {a} vs \
                 {e}"
            );
        }
    }
    for trial in 0..20 {
        let l = 1 + rng.usize_below(100);
        let n = l + rng.usize_below(30);
        let ne = rng.usize_below(6 * l + 1);
        let mut src = Vec::with_capacity(ne);
        let mut dst = Vec::with_capacity(ne);
        let mut ew = Vec::with_capacity(ne);
        for _ in 0..ne {
            src.push(rng.usize_below(n) as u32);
            dst.push(rng.usize_below(l) as u32);
            ew.push(match rng.usize_below(4) {
                0 => 1.0,
                1 => 0.0,
                _ => rng.normal_f32(0.5, 0.3),
            });
        }
        let edges = EdgeArrays {
            src,
            dst,
            ew,
            inv_deg: vec![1.0; l],
            n,
            n_local: l,
        };
        let csr = CsrPartition::from_edges(&edges);
        let f = 1 + rng.usize_below(150);
        let h: Vec<f32> =
            (0..n * f).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        // the AVX2 SpMM kernel is kept in-tree but NOT dispatched
        // (measured even with the portable kernel — spmm.rs design
        // note); parity must hold regardless
        let mut avx2 = vec![0f32; l * f];
        assert!(
            simd::try_csr_spmm_rows_into(&csr, &h, f, 0, l, &mut avx2),
            "avx2_active but spmm hook declined"
        );
        let scalar = spmm::csr_spmm(&csr, &h, f);
        for (i, (a, e)) in avx2.iter().zip(&scalar).enumerate() {
            let tol = 1e-5 * (1.0 + a.abs().max(e.abs()));
            assert!(
                (a - e).abs() <= tol,
                "spmm trial {trial} (l={l} f={f}) elem {i}: {a} vs {e}"
            );
        }
    }
}

/// The pipelined executor (`BspPipeline`) must reproduce the barrier
/// path bit-for-bit at every depth, for every model, across seeds —
/// the dependency-driven dispatch changes WHEN each (layer, fog) task
/// runs, never WHAT it computes: identical closures over identical
/// row ranges, halo bytes staged instead of barrier-copied, ordered
/// reassembly. Re-running the same window must also be deterministic.
#[test]
fn pipelined_bsp_bitwise_equals_barrier_across_models_and_depths() {
    let base = seeded_graph();
    let f_in = base.feature_dim;
    let nv = base.num_vertices();
    let assignment: Vec<u32> =
        (0..nv).map(|v| (v % 3) as u32).collect();
    let batch = 4;
    for model in ["gcn", "sage", "gat"] {
        let wb = Arc::new(synth_weights(model, f_in));
        let plan =
            BatchedBspPlan::with_threads(&base, &assignment, 3, model, 2)
                .unwrap();
        for seed in [11u64, 23] {
            let mut rng = Rng::new(seed);
            let feats: Vec<f32> = (0..nv * f_in)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let barrier = plan.execute(&feats, f_in, &wb, batch);
            for depth in [2usize, 4] {
                let run = |n_batches: usize| -> Vec<exec::BspResult> {
                    let mut pipe =
                        exec::BspPipeline::new(3, depth, true);
                    let mut out = Vec::new();
                    for _ in 0..n_batches {
                        if pipe.pending() == depth {
                            out.push(pipe.collect(&plan, None));
                        }
                        pipe.submit(&plan, &feats, f_in, &wb, batch,
                                    None);
                    }
                    while pipe.pending() > 0 {
                        out.push(pipe.collect(&plan, None));
                    }
                    out
                };
                let first = run(depth + 2);
                for (i, r) in first.iter().enumerate() {
                    assert_eq!(r.out_dim, barrier.out_dim);
                    assert_eq!(
                        r.outputs, barrier.outputs,
                        "{model} seed={seed} depth={depth}: \
                         pipelined batch {i} != barrier"
                    );
                    assert_eq!(r.sync_bytes, barrier.sync_bytes);
                }
                // deterministic re-run: same window, same bytes
                let again = run(depth + 2);
                for (a, b) in first.iter().zip(&again) {
                    assert_eq!(a.outputs, b.outputs,
                               "{model} depth={depth}: re-run drifted");
                }
            }
        }
    }
}

/// Same bit-identity for the single-layer ASTGCN block through the
/// pipelined executor, interleaving two distinct feature sets in one
/// window so cross-batch isolation is exercised, not just throughput.
#[test]
fn pipelined_bsp_bitwise_equals_barrier_for_astgcn() {
    let (mut g, _) = generate::sbm(60, 220, 3, 0.8, 9);
    let ft = 36;
    let mut rng = Rng::new(0xB0B);
    g.features = (0..60 * ft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    g.feature_dim = ft;
    let assignment: Vec<u32> = (0..60).map(|v| (v % 2) as u32).collect();
    let wb = Arc::new(
        engine(EngineKind::Reference)
            .weights("astgcn", "tinypems", ft, 0)
            .clone(),
    );
    let plan =
        BatchedBspPlan::with_threads(&g, &assignment, 2, "astgcn", 2)
            .unwrap();
    let alt: Vec<f32> =
        (0..60 * ft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let batch = 3;
    let want_a = plan.execute(&g.features, ft, &wb, batch);
    let want_b = plan.execute(&alt, ft, &wb, batch);
    let mut pipe = exec::BspPipeline::new(2, 2, true);
    pipe.submit(&plan, &g.features, ft, &wb, batch, None);
    pipe.submit(&plan, &alt, ft, &wb, batch, None);
    let got_a = pipe.collect(&plan, None);
    pipe.submit(&plan, &g.features, ft, &wb, batch, None);
    let got_b = pipe.collect(&plan, None);
    let got_a2 = pipe.collect(&plan, None);
    assert_eq!(got_a.outputs, want_a.outputs,
               "astgcn pipelined batch 0 != barrier");
    assert_eq!(got_b.outputs, want_b.outputs,
               "astgcn pipelined batch 1 (distinct features) != barrier");
    assert_eq!(got_a2.outputs, want_a.outputs,
               "astgcn pipelined batch 2 != barrier");
    assert_eq!(pipe.pending(), 0);
}

/// A crashed fog withholds every reply (`Inject::DropReply` — the
/// exact dead-node signature), so each of its tasks must be hedged to
/// a healthy fog after the task deadline. The replica runs the
/// identical job over identical row ranges, so the assembled outputs
/// equal the fault-free barrier oracle bit-for-bit — only timing and
/// the hedge counters change.
#[test]
fn hedged_pipeline_bitwise_equals_barrier_under_crash() {
    let g = seeded_graph();
    let f_in = g.feature_dim;
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|v| (v % 3) as u32).collect();
    let batch = 2;
    for model in ["gcn", "sage"] {
        let wb = Arc::new(synth_weights(model, f_in));
        let plan =
            BatchedBspPlan::new(&g, &assignment, 3, model).unwrap();
        let barrier = plan.execute(&g.features, f_in, &wb, batch);
        let mut pipe = exec::BspPipeline::new(3, 2, true);
        pipe.set_chaos(Some(exec::PipelineChaos {
            crashed: vec![false, true, false],
            speed: vec![1.0; 3],
        }));
        // short deadline so the dead fog's tasks hedge quickly
        pipe.set_task_deadline(0.05);
        let mut outs = Vec::new();
        for _ in 0..3 {
            pipe.submit(&plan, &g.features, f_in, &wb, batch, None);
            outs.push(pipe.collect(&plan, None));
        }
        for (i, r) in outs.iter().enumerate() {
            assert_eq!(r.out_dim, barrier.out_dim);
            assert_eq!(r.outputs, barrier.outputs,
                       "{model}: hedged batch {i} != barrier");
            assert_eq!(r.sync_bytes, barrier.sync_bytes);
        }
        let (wins, _) = pipe.hedge_stats();
        assert!(wins > 0,
                "{model}: crashed fog's tasks were never hedged");
    }
}

/// A slowed fog still computes and replies — late, never wrong. The
/// worker returns unchanged bytes with only its reported seconds
/// inflated, so outputs stay bitwise equal to the barrier oracle and
/// no hedge fires under the default (generous) task deadline.
#[test]
fn slowed_pipeline_bitwise_equals_barrier() {
    let g = seeded_graph();
    let f_in = g.feature_dim;
    let assignment: Vec<u32> =
        (0..g.num_vertices()).map(|v| (v % 3) as u32).collect();
    let batch = 2;
    let wb = Arc::new(synth_weights("gcn", f_in));
    let plan =
        BatchedBspPlan::new(&g, &assignment, 3, "gcn").unwrap();
    let barrier = plan.execute(&g.features, f_in, &wb, batch);
    let mut pipe = exec::BspPipeline::new(3, 2, true);
    pipe.set_chaos(Some(exec::PipelineChaos {
        crashed: vec![false; 3],
        speed: vec![1.0, 0.25, 1.0],
    }));
    for i in 0..2 {
        pipe.submit(&plan, &g.features, f_in, &wb, batch, None);
        let r = pipe.collect(&plan, None);
        assert_eq!(r.outputs, barrier.outputs,
                   "slowed batch {i} != barrier");
    }
    assert_eq!(pipe.hedge_stats(), (0, 0),
               "a merely slow fog must not trigger hedging");
}

/// Random row-split points stitched back together must equal the
/// full-matrix kernels bit-for-bit (the direct statement of
/// row-decomposition invariance, independent of `split_rows`).
#[test]
fn random_row_splits_stitch_bitwise() {
    let mut rng = Rng::new(0x517C);
    for trial in 0..30 {
        let n = 4 + rng.usize_below(60);
        let fi = 1 + rng.usize_below(80);
        let fo = 1 + rng.usize_below(60);
        let x: Vec<f32> = (0..n * fi)
            .map(|_| {
                if rng.bool(0.3) {
                    0.0
                } else {
                    rng.normal_f32(0.0, 0.3)
                }
            })
            .collect();
        let w: Vec<f32> =
            (0..fi * fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let b: Vec<f32> =
            (0..fo).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let full = gemm::gemm_bias(&x, n, fi, &w, fo, &b);
        // random number of random cut points
        let mut cuts = vec![0usize, n];
        for _ in 0..rng.usize_below(4) {
            cuts.push(rng.usize_below(n));
        }
        cuts.sort_unstable();
        cuts.dedup();
        let mut stitched = Vec::with_capacity(n * fo);
        for pair in cuts.windows(2) {
            stitched.extend(gemm::gemm_bias_rows(&x, fi, &w, fo, &b,
                                                 pair[0], pair[1]));
        }
        assert_eq!(full, stitched,
                   "gemm trial {trial}: random splits {cuts:?} deviate");
    }
}
