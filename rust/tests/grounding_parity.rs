//! Streamed-vs-materialized grounding parity (scale tier, satellite
//! of the streamed-grounding tentpole): across seeded rmat / sbm /
//! road topologies and several assignment families, the streamed
//! [`GroundingStream`] path must reproduce the original materialize-
//! everything extractor bit for bit — same sub-CSRs (vertex order,
//! edge order, degrees) and the same transfer plan. The in-crate
//! fixture test covers one hand-built graph; this suite covers the
//! generator zoo the `repro scale` sweep actually runs on.

use fograph::graph::{generate, subgraph, Graph};

/// Assignment families the serving planners actually produce:
/// contiguous blocks (scale sweep), modulo striping (worst-case halo),
/// and a seeded pseudo-random map (replan churn).
fn assignments(nv: usize, n_fogs: usize) -> Vec<(&'static str, Vec<u32>)> {
    let contiguous: Vec<u32> = (0..nv)
        .map(|v| (v as u64 * n_fogs as u64 / nv as u64) as u32)
        .collect();
    let modulo: Vec<u32> =
        (0..nv).map(|v| (v % n_fogs) as u32).collect();
    // LCG scramble: deterministic, hits every fog, no util deps.
    let scrambled: Vec<u32> = (0..nv as u64)
        .map(|v| {
            let h = v
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            ((h >> 33) % n_fogs as u64) as u32
        })
        .collect();
    vec![
        ("contiguous", contiguous),
        ("modulo", modulo),
        ("scrambled", scrambled),
    ]
}

fn assert_parity(tag: &str, g: &Graph, n_fogs: usize) {
    for (name, asn) in assignments(g.num_vertices(), n_fogs) {
        let (subs_s, plan_s) = subgraph::extract(g, &asn, n_fogs);
        let (subs_m, plan_m) =
            subgraph::extract_materialized(g, &asn, n_fogs);
        assert_eq!(subs_s.len(), subs_m.len(), "{tag}/{name}: sub count");
        for (j, (s, m)) in subs_s.iter().zip(&subs_m).enumerate() {
            assert_eq!(s, m, "{tag}/{name}: fog {j} sub-CSR differs");
        }
        assert_eq!(plan_s, plan_m, "{tag}/{name}: exchange plan differs");
        // The plan must be internally coherent too: every transfer
        // index addresses an owned vertex of the sending fog.
        for (owner, row) in plan_s.transfers.iter().enumerate() {
            let n_owned = subs_s[owner].n_local;
            for cell in row {
                for &idx in cell {
                    assert!(
                        (idx as usize) < n_owned,
                        "{tag}/{name}: transfer index out of range"
                    );
                }
            }
        }
    }
}

#[test]
fn rmat_parity_across_fog_counts() {
    for &(nv, ne, seed) in
        &[(512usize, 2048usize, 7u64), (2000, 9000, 21), (4096, 16384, 99)]
    {
        let g = generate::rmat(nv, ne, seed, (0.57, 0.19, 0.19, 0.05));
        for &k in &[2usize, 3, 7] {
            assert_parity("rmat", &g, k);
        }
    }
}

#[test]
fn sbm_parity_matches_community_structure() {
    for &(nv, ne, comms, seed) in
        &[(600usize, 2400usize, 4usize, 5u64), (1500, 7500, 6, 31)]
    {
        let (g, _) = generate::sbm(nv, ne, comms, 0.8, seed);
        for &k in &[2usize, comms, comms + 1] {
            assert_parity("sbm", &g, k);
        }
    }
}

#[test]
fn road_parity_on_lane_graphs() {
    for &(nv, ne, lanes, seed) in
        &[(800usize, 1000usize, 4usize, 13u64), (3000, 3750, 8, 47)]
    {
        let (g, _) = generate::road_network(nv, ne, lanes, seed);
        for &k in &[2usize, 5] {
            assert_parity("road", &g, k);
        }
    }
}

#[test]
fn degenerate_assignments_stay_bit_identical() {
    let g = generate::rmat(1024, 4096, 3, (0.45, 0.22, 0.22, 0.11));
    // All vertices on one fog of several (empty peers), and a fog
    // count of 1 (no halo at all).
    let all_on_two: Vec<u32> = vec![2; g.num_vertices()];
    let (subs_s, plan_s) = subgraph::extract(&g, &all_on_two, 5);
    let (subs_m, plan_m) =
        subgraph::extract_materialized(&g, &all_on_two, 5);
    assert_eq!(subs_s, subs_m);
    assert_eq!(plan_s, plan_m);
    assert_eq!(plan_s.total_vertices(), 0, "no cross-fog traffic");

    let solo: Vec<u32> = vec![0; g.num_vertices()];
    let (subs_s, plan_s) = subgraph::extract(&g, &solo, 1);
    let (subs_m, plan_m) = subgraph::extract_materialized(&g, &solo, 1);
    assert_eq!(subs_s, subs_m);
    assert_eq!(plan_s, plan_m);
    assert_eq!(subs_s[0].n_halo(), 0);
}
