//! Streamed-vs-materialized grounding parity (scale tier, satellite
//! of the streamed-grounding tentpole): across seeded rmat / sbm /
//! road topologies and several assignment families, the streamed
//! [`GroundingStream`] path must reproduce the original materialize-
//! everything extractor bit for bit — same sub-CSRs (vertex order,
//! edge order, degrees) and the same transfer plan. The in-crate
//! fixture test covers one hand-built graph; this suite covers the
//! generator zoo the `repro scale` sweep actually runs on.

use fograph::graph::{generate, subgraph, Graph};

/// Assignment families the serving planners actually produce:
/// contiguous blocks (scale sweep), modulo striping (worst-case halo),
/// and a seeded pseudo-random map (replan churn).
fn assignments(nv: usize, n_fogs: usize) -> Vec<(&'static str, Vec<u32>)> {
    let contiguous: Vec<u32> = (0..nv)
        .map(|v| (v as u64 * n_fogs as u64 / nv as u64) as u32)
        .collect();
    let modulo: Vec<u32> =
        (0..nv).map(|v| (v % n_fogs) as u32).collect();
    // LCG scramble: deterministic, hits every fog, no util deps.
    let scrambled: Vec<u32> = (0..nv as u64)
        .map(|v| {
            let h = v
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            ((h >> 33) % n_fogs as u64) as u32
        })
        .collect();
    vec![
        ("contiguous", contiguous),
        ("modulo", modulo),
        ("scrambled", scrambled),
    ]
}

fn assert_parity(tag: &str, g: &Graph, n_fogs: usize) {
    for (name, asn) in assignments(g.num_vertices(), n_fogs) {
        let (subs_s, plan_s) = subgraph::extract(g, &asn, n_fogs);
        let (subs_m, plan_m) =
            subgraph::extract_materialized(g, &asn, n_fogs);
        assert_eq!(subs_s.len(), subs_m.len(), "{tag}/{name}: sub count");
        for (j, (s, m)) in subs_s.iter().zip(&subs_m).enumerate() {
            assert_eq!(s, m, "{tag}/{name}: fog {j} sub-CSR differs");
        }
        assert_eq!(plan_s, plan_m, "{tag}/{name}: exchange plan differs");
        // The plan must be internally coherent too: every transfer
        // index addresses an owned vertex of the sending fog.
        for (owner, row) in plan_s.transfers.iter().enumerate() {
            let n_owned = subs_s[owner].n_local;
            for cell in row {
                for &idx in cell {
                    assert!(
                        (idx as usize) < n_owned,
                        "{tag}/{name}: transfer index out of range"
                    );
                }
            }
        }
    }
}

#[test]
fn rmat_parity_across_fog_counts() {
    for &(nv, ne, seed) in
        &[(512usize, 2048usize, 7u64), (2000, 9000, 21), (4096, 16384, 99)]
    {
        let g = generate::rmat(nv, ne, seed, (0.57, 0.19, 0.19, 0.05));
        for &k in &[2usize, 3, 7] {
            assert_parity("rmat", &g, k);
        }
    }
}

#[test]
fn sbm_parity_matches_community_structure() {
    for &(nv, ne, comms, seed) in
        &[(600usize, 2400usize, 4usize, 5u64), (1500, 7500, 6, 31)]
    {
        let (g, _) = generate::sbm(nv, ne, comms, 0.8, seed);
        for &k in &[2usize, comms, comms + 1] {
            assert_parity("sbm", &g, k);
        }
    }
}

#[test]
fn road_parity_on_lane_graphs() {
    for &(nv, ne, lanes, seed) in
        &[(800usize, 1000usize, 4usize, 13u64), (3000, 3750, 8, 47)]
    {
        let (g, _) = generate::road_network(nv, ne, lanes, seed);
        for &k in &[2usize, 5] {
            assert_parity("road", &g, k);
        }
    }
}

#[test]
fn degenerate_assignments_stay_bit_identical() {
    let g = generate::rmat(1024, 4096, 3, (0.45, 0.22, 0.22, 0.11));
    // All vertices on one fog of several (empty peers), and a fog
    // count of 1 (no halo at all).
    let all_on_two: Vec<u32> = vec![2; g.num_vertices()];
    let (subs_s, plan_s) = subgraph::extract(&g, &all_on_two, 5);
    let (subs_m, plan_m) =
        subgraph::extract_materialized(&g, &all_on_two, 5);
    assert_eq!(subs_s, subs_m);
    assert_eq!(plan_s, plan_m);
    assert_eq!(plan_s.total_vertices(), 0, "no cross-fog traffic");

    let solo: Vec<u32> = vec![0; g.num_vertices()];
    let (subs_s, plan_s) = subgraph::extract(&g, &solo, 1);
    let (subs_m, plan_m) = subgraph::extract_materialized(&g, &solo, 1);
    assert_eq!(subs_s, subs_m);
    assert_eq!(plan_s, plan_m);
    assert_eq!(subs_s[0].n_halo(), 0);
}

// ---- degenerate churn outcomes (incremental topology engine) --------
//
// The engine applies deltas in place and partially re-grounds; these
// corners — a fog whose every owned vertex dies, a vertex revived
// after removal, an edge deleted then re-added — must all stay
// bit-identical to a from-scratch extract over the rebuilt topology.

mod churn_degenerate {
    use fograph::graph::delta::Delta;
    use fograph::graph::{generate, TopologyEngine};

    fn scrambled(nv: usize, n_fogs: usize) -> Vec<u32> {
        (0..nv as u64)
            .map(|v| {
                let h = v
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                ((h >> 33) % n_fogs as u64) as u32
            })
            .collect()
    }

    #[test]
    fn fog_emptied_by_deletions_stays_coherent() {
        let g = generate::rmat(240, 960, 5, (0.57, 0.19, 0.19, 0.05));
        let nv = g.num_vertices();
        let asn = scrambled(nv, 3);
        let mut engine = TopologyEngine::new(&g, &asn, 3);
        // kill every vertex fog 1 owns in a single batch: victims are
        // all dead before the boundary-refinement pass runs, and dead
        // vertices never migrate, so fog 1 keeps its (dead) ids
        let victims: Vec<u32> = (0..nv as u32)
            .filter(|&v| asn[v as usize] == 1)
            .collect();
        assert!(!victims.is_empty());
        let mut deltas = Vec::new();
        for &v in &victims {
            let nbrs = engine.csr.del_vertex(v);
            deltas.push(Delta::DelVertex { v, nbrs });
        }
        engine.integrate(&deltas);
        engine.parity_check().expect("parity after full drain");
        assert!(victims.iter().all(|&v| !engine.csr.is_alive(v)));
        assert!(victims
            .iter()
            .all(|&v| engine.assignment[v as usize] == 1));
        // dead ids stay as degree-0 owned vertices — exactly what a
        // from-scratch extract sees for isolated vertices
        let sub = &engine.subs[1];
        assert!(sub.n_local >= victims.len());
        for (i, &gv) in sub.vertices[..sub.n_local].iter().enumerate()
        {
            if !engine.csr.is_alive(gv) {
                assert_eq!(sub.global_degree[i], 0,
                           "dead vertex {gv} kept edges");
            }
        }
        // a later trickle round over the drained topology still holds
        let u = (0..nv as u32)
            .find(|&v| engine.csr.live_deg(v) > 0)
            .expect("survivors keep edges");
        let w = {
            let mut buf = Vec::new();
            engine.csr.for_neighbors(u, |x| buf.push(x));
            buf[0]
        };
        engine.csr.del_edge(u, w);
        engine.integrate(&[Delta::DelEdge(u, w)]);
        engine.parity_check().expect("parity after post-drain delta");
    }

    #[test]
    fn vertex_readded_after_removal_keeps_owner_and_parity() {
        let g = generate::rmat(200, 800, 9, (0.57, 0.19, 0.19, 0.05));
        let asn = scrambled(g.num_vertices(), 4);
        let mut engine = TopologyEngine::new(&g, &asn, 4);
        // pick a vertex with >= 2 same-fog neighbors: after revival
        // its edges are all internal, so the strictly-positive-gain
        // boundary pass provably leaves it on its home fog
        let same_fog = |v: u32| -> Vec<u32> {
            g.neighbors(v as usize)
                .iter()
                .copied()
                .filter(|&u| {
                    u != v && asn[u as usize] == asn[v as usize]
                })
                .collect::<Vec<u32>>()
        };
        let v = (0..g.num_vertices() as u32)
            .find(|&v| same_fog(v).len() >= 2)
            .expect("rmat has same-fog adjacent pairs");
        let home = engine.assignment[v as usize];
        let nbrs = engine.csr.del_vertex(v);
        engine.integrate(&[Delta::DelVertex { v, nbrs }]);
        engine.parity_check().expect("parity after removal");
        // revival returns the smallest dead id — v is the only one
        let (rv, revived) = engine.csr.add_vertex();
        assert_eq!((rv, revived), (v, true));
        // filter against the engine's CURRENT assignment — the
        // removal round's boundary pass may have migrated neighbors
        let attach: Vec<u32> = same_fog(v)
            .into_iter()
            .filter(|&u| {
                engine.csr.is_alive(u)
                    && engine.assignment[u as usize] == home
            })
            .take(2)
            .collect();
        assert!(!attach.is_empty());
        for &u in &attach {
            engine.csr.add_edge(v, u);
        }
        engine.integrate(&[Delta::AddVertex {
            v,
            revived: true,
            nbrs: attach,
        }]);
        assert_eq!(
            engine.assignment[v as usize], home,
            "revival must keep the vertex's previous owner"
        );
        engine.parity_check().expect("parity after revival");
    }

    #[test]
    fn edge_delete_then_readd_restores_live_structure() {
        let g = generate::rmat(180, 720, 3, (0.57, 0.19, 0.19, 0.05));
        let asn = scrambled(g.num_vertices(), 3);
        let mut engine = TopologyEngine::new(&g, &asn, 3);
        // a cross-fog edge: deletion and re-add touch two partitions
        let (u, v) = {
            let mut found = None;
            'outer: for u in 0..g.num_vertices() as u32 {
                for &w in g.neighbors(u as usize) {
                    if w > u && asn[u as usize] != asn[w as usize] {
                        found = Some((u, w));
                        break 'outer;
                    }
                }
            }
            found.expect("scrambled assignment has cut edges")
        };
        engine.csr.del_edge(u, v);
        engine.integrate(&[Delta::DelEdge(u, v)]);
        engine.parity_check().expect("parity after delete");
        engine.csr.add_edge(u, v);
        engine.integrate(&[Delta::AddEdge(u, v)]);
        engine.parity_check().expect("parity after re-add");
        // the live topology is exactly the original again
        let rebuilt = engine.csr.to_graph();
        assert_eq!(rebuilt.indptr, g.indptr);
        assert_eq!(rebuilt.indices, g.indices);
    }
}
