//! Request-level loadtest integration: the acceptance-level properties
//! of `repro loadtest` on the real SIoT twin — deterministic replay
//! under a fixed seed, and strictly higher goodput for fograph than for
//! cloud serving under identical traffic.

use std::path::Path;

use fograph::graph::datasets;
use fograph::net::NetKind;
use fograph::profile::PerfModel;
use fograph::runtime::{Engine, EngineKind};
use fograph::serving::pipeline::mode_setup;
use fograph::traffic::{run_loadtest, LoadtestReport, TrafficConfig};

fn engine() -> Engine {
    Engine::new(EngineKind::Reference, Path::new("artifacts"))
        .or_else(|_| {
            Engine::new(EngineKind::Reference,
                        &std::env::temp_dir().join("loadtest_e2e"))
        })
        .unwrap()
}

/// The acceptance traffic, shortened for test turnaround and with the
/// background-load trace off so the margins are analytic.
fn traffic() -> TrafficConfig {
    TrafficConfig {
        rps: 200.0,
        duration_s: 20.0,
        seed: 0x51D7,
        background_load: false,
        ..Default::default()
    }
}

fn run_mode(mode: &str) -> LoadtestReport {
    let g = datasets::generate("siot").expect("siot twin");
    let spec = datasets::spec_by_name("siot").unwrap();
    let (cluster, opts) =
        mode_setup(mode, "gcn", NetKind::Wifi, &g).expect("known mode");
    let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
    let mut eng = engine();
    run_loadtest(&g, &spec, &cluster, &opts, &traffic(), &omegas,
                 &mut eng)
        .expect("loadtest run")
}

#[test]
fn fograph_goodput_strictly_beats_cloud_under_identical_traffic() {
    let cloud = run_mode("cloud");
    let fog = run_mode("fograph");
    assert!(!cloud.slo.oom && !fog.slo.oom);
    // cloud serving pays the full-graph WAN upload per window (~1.4 s on
    // WiFi for SIoT), so it cannot meet a 1 s SLO at all; the fog tier
    // collects in parallel over compressed uploads and can.
    assert!(
        fog.slo.goodput_rps > cloud.slo.goodput_rps,
        "fograph goodput {} !> cloud goodput {}",
        fog.slo.goodput_rps,
        cloud.slo.goodput_rps
    );
    assert!(fog.slo.goodput_rps > 0.0);
    // both systems saw the identical seeded stream
    assert_eq!(fog.slo.offered, cloud.slo.offered);
}

#[test]
fn loadtest_replays_bit_identically_under_a_fixed_seed() {
    let a = run_mode("fograph");
    let b = run_mode("fograph");
    assert_eq!(a.latencies, b.latencies);
    assert_eq!(a.slo.goodput_rps, b.slo.goodput_rps);
    assert_eq!(a.slo.shed, b.slo.shed);
    assert_eq!(a.slo.within_slo, b.slo.within_slo);
    assert_eq!(a.base_collection_s, b.base_collection_s);
    assert_eq!(a.slo.queue.samples, b.slo.queue.samples);
}
