//! Property-based invariant suite (via util::testkit): randomized sweeps
//! over the substrates' core guarantees.

use std::path::Path;

use fograph::compress::{self, Codec, DaqConfig, IntervalScheme,
                        DEFAULT_BITS};
use fograph::exec;
use fograph::graph::{generate, subgraph, Graph};
use fograph::partition::{self, wgraph, MultilevelParams};
use fograph::placement::lbap;
use fograph::profile::PerfModel;
use fograph::runtime::{Engine, EngineKind};
use fograph::scheduler::diffusion;
use fograph::util::json::Json;
use fograph::util::rng::Rng;
use fograph::util::testkit::forall;

fn engine() -> Engine {
    Engine::new(EngineKind::Reference, Path::new("artifacts"))
        .or_else(|_| {
            Engine::new(EngineKind::Reference,
                        &std::env::temp_dir().join("props"))
        })
        .unwrap()
}

/// Multilevel partitions are balanced and beat random cuts on community
/// graphs of any shape.
#[test]
fn prop_partition_balance_and_cut() {
    forall(
        0xA11CE,
        8,
        |r| {
            let nv = 300 + r.usize_below(900);
            let ne = nv * (2 + r.usize_below(4));
            let k = 2 + r.usize_below(5);
            let comms = 4 + r.usize_below(8);
            (nv, ne, k, comms, r.next_u64())
        },
        |&(nv, ne, k, comms, seed)| {
            let (g, _) = generate::sbm(nv, ne, comms, 0.9, seed);
            let res = partition::partition(&g, k,
                                           &MultilevelParams::default());
            let ideal = nv as f64 / k as f64;
            let balanced = res
                .part_weights
                .iter()
                .all(|&w| (w as f64) <= ideal * 1.25 + 2.0);
            let wg = wgraph::WGraph::from_graph(&g);
            let mut rng = Rng::new(seed ^ 1);
            let rand_assign: Vec<u32> =
                (0..nv).map(|_| rng.below(k as u64) as u32).collect();
            let rand_cut = wgraph::edge_cut(&wg, &rand_assign);
            balanced && res.edge_cut <= rand_cut
        },
    );
}

/// LBAP's bottleneck is never worse than the Hungarian (min-sum)
/// solution's bottleneck, and the mapping is always a permutation.
#[test]
fn prop_lbap_dominates_min_sum_on_bottleneck() {
    forall(
        0xB0B,
        60,
        |r| {
            let n = 2 + r.usize_below(7);
            (0..n)
                .map(|_| (0..n).map(|_| r.below(1000) as f64).collect())
                .collect::<Vec<Vec<f64>>>()
        },
        |w| {
            let n = w.len();
            let (assign, bn) = lbap::solve(w);
            let mut sorted = assign.clone();
            sorted.sort_unstable();
            let perm_ok = sorted == (0..n).collect::<Vec<_>>();
            let (hung, _) =
                fograph::placement::hungarian::min_cost_assignment(w);
            let hung_bn = lbap::bottleneck(w, &hung);
            perm_ok && bn <= hung_bn + 1e-9
        },
    );
}

/// Pack→unpack round-trips within the quantization error bound for every
/// codec, on arbitrary feature matrices and degree profiles.
#[test]
fn prop_codec_roundtrip_error_bounds() {
    forall(
        0xC0DEC,
        20,
        |r| {
            let n = 1 + r.usize_below(400);
            let dims = 1 + r.usize_below(64);
            let spread = r.range_f64(0.5, 100.0);
            (n, dims, spread, r.next_u64())
        },
        |&(n, dims, spread, seed)| {
            let mut rng = Rng::new(seed);
            let rows: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    (0..dims)
                        .map(|_| rng.normal_f32(0.0, spread as f32))
                        .collect()
                })
                .collect();
            let degrees: Vec<u64> =
                (0..n).map(|_| rng.below(300)).collect();
            let d32: Vec<u32> = degrees.iter().map(|&d| d as u32).collect();
            let cfg = DaqConfig::from_degrees(&d32,
                                              IntervalScheme::EqualMass,
                                              DEFAULT_BITS);
            for codec in [
                Codec::Daq(cfg),
                Codec::Uniform(8),
                Codec::Uniform(16),
                Codec::Lz4Only,
            ] {
                let refs: Vec<&[f32]> =
                    rows.iter().map(|r| r.as_slice()).collect();
                let p = compress::pack(&refs, &degrees, &codec);
                let mut out = Vec::new();
                if compress::unpack(&p, &mut out).is_err() {
                    return false;
                }
                // worst quantizer: 8 bits over the row's range
                for (orig, back) in rows.iter().zip(&out) {
                    let lo = orig.iter().cloned().fold(f32::MAX, f32::min);
                    let hi = orig.iter().cloned().fold(f32::MIN, f32::max);
                    let bound = ((hi - lo) / 255.0).max(1e-5) * 1.01;
                    for (a, b) in orig.iter().zip(back) {
                        if (a - b).abs() > bound {
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}

/// Distributed BSP output equals the single-fog output for arbitrary
/// graphs, assignments and models (the system's core correctness claim).
#[test]
fn prop_bsp_placement_invariance() {
    let mut eng = engine();
    let mut failures = Vec::new();
    let mut rng = Rng::new(0xD157);
    for case in 0..6 {
        let nv = 150 + rng.usize_below(300);
        let ne = nv * 3;
        let comms = 3 + rng.usize_below(5);
        let k = 2 + rng.usize_below(4);
        let (mut g, _) = generate::sbm(nv, ne, comms, 0.85, rng.next_u64());
        let f_in = 8;
        g.feature_dim = f_in;
        g.features =
            (0..nv * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let model = ["gcn", "sage", "gat"][case % 3];
        let assignment: Vec<u32> =
            (0..nv).map(|_| rng.below(k as u64) as u32).collect();
        let single = exec::run_bsp(&g, &g.features, f_in, &vec![0; nv], 1,
                                   model, "prop", 3, &mut eng)
            .unwrap();
        let multi = exec::run_bsp(&g, &g.features, f_in, &assignment,
                                  k, model, "prop", 3, &mut eng)
            .unwrap();
        let err = single
            .outputs
            .iter()
            .zip(&multi.outputs)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        if err > 5e-4 {
            failures.push((case, model, k, err));
        }
    }
    assert!(failures.is_empty(), "BSP invariance violated: {failures:?}");
}

/// Halo extraction partitions every directed edge exactly once (by
/// destination), for arbitrary assignments.
#[test]
fn prop_halo_extraction_covers_all_edges() {
    forall(
        0xE49E,
        10,
        |r| {
            let nv = 100 + r.usize_below(500);
            (nv, nv * (1 + r.usize_below(5)), 1 + r.usize_below(6),
             r.next_u64())
        },
        |&(nv, ne, k, seed)| {
            let (g, _) = generate::sbm(nv, ne, 6, 0.8, seed);
            let mut rng = Rng::new(seed ^ 3);
            let assignment: Vec<u32> =
                (0..nv).map(|_| rng.below(k as u64) as u32).collect();
            let (subs, plan) = subgraph::extract(&g, &assignment, k);
            let total: usize = subs.iter().map(|s| s.num_edges()).sum();
            let dst_local =
                subs.iter().all(|s| {
                    s.dst.iter().all(|&d| (d as usize) < s.n_local)
                });
            // every halo vertex is covered by exactly one transfer
            let halo_total: usize = subs.iter().map(|s| s.n_halo()).sum();
            total == g.num_edges() && dst_local
                && plan.total_vertices() == halo_total
        },
    );
}

/// Diffusion never increases the estimated bottleneck.
#[test]
fn prop_diffusion_never_hurts_bottleneck() {
    forall(
        0xD1FF,
        8,
        |r| (300 + r.usize_below(600), 2 + r.usize_below(4), r.next_u64()),
        |&(nv, k, seed)| {
            let (g, _) = generate::sbm(nv, nv * 4, 6, 0.9, seed);
            let mut rng = Rng::new(seed ^ 9);
            let mut assignment: Vec<u32> =
                (0..nv).map(|_| rng.below(k as u64) as u32).collect();
            let omegas: Vec<PerfModel> = (0..k)
                .map(|j| {
                    let m = 1.0 + rng.f64() * 3.0 * (j == 0) as u8 as f64;
                    PerfModel {
                        beta_v: 2e-6 * m,
                        beta_n: 3e-7 * m,
                        intercept: 1e-3 * m,
                        r2: 1.0,
                    }
                })
                .collect();
            let before = diffusion::estimate_times(&g, &assignment, k,
                                                   &omegas);
            let max_before =
                before.iter().cloned().fold(0f64, f64::max);
            diffusion::diffuse(&g, &mut assignment, &omegas, k, 1.2);
            let after = diffusion::estimate_times(&g, &assignment, k,
                                                  &omegas);
            let max_after = after.iter().cloned().fold(0f64, f64::max);
            max_after <= max_before * 1.001 + 1e-9
        },
    );
}

/// JSON round-trips arbitrary (generated) documents.
#[test]
fn prop_json_roundtrip() {
    fn gen_value(r: &mut Rng, depth: usize) -> Json {
        match if depth > 2 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.bool(0.5)),
            2 => Json::Num((r.below(1_000_000) as f64) / 8.0),
            3 => {
                let s: String = (0..r.usize_below(12))
                    .map(|_| {
                        char::from_u32(32 + r.below(90) as u32).unwrap()
                    })
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr(
                (0..r.usize_below(5))
                    .map(|_| gen_value(r, depth + 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..r.usize_below(5))
                    .map(|i| (format!("k{i}"), gen_value(r, depth + 1)))
                    .collect(),
            ),
        }
    }
    forall(
        0x1503,
        200,
        |r| gen_value(r, 0),
        |v| Json::parse(&v.to_string()).map(|p| p == *v).unwrap_or(false),
    );
}

/// Theorem 2's analytic ratio matches the measured quantized payload for
/// arbitrary degree distributions.
#[test]
fn prop_theorem2_matches_measurement() {
    forall(
        0x7E02,
        15,
        |r| {
            let n = 200 + r.usize_below(2000);
            let alpha = r.range_f64(0.4, 1.4);
            (n, alpha, r.next_u64())
        },
        |&(n, alpha, seed)| {
            let mut rng = Rng::new(seed);
            let degrees: Vec<u32> = (0..n)
                .map(|_| {
                    let u = rng.f64();
                    ((1.0 / (1.0 - u)).powf(alpha) as u32).min(2000)
                })
                .collect();
            let cfg = DaqConfig::from_degrees(&degrees,
                                              IntervalScheme::EqualMass,
                                              DEFAULT_BITS);
            let predicted = cfg.theorem2_ratio(&degrees, 64.0);
            let actual: f64 = degrees
                .iter()
                .map(|&d| cfg.bits_for_degree(d as u64) as f64)
                .sum::<f64>()
                / degrees.len() as f64
                / 64.0;
            (predicted - actual).abs() < 0.03
        },
    );
}
