//! Flight recorder: per-thread ring buffers of sequence-stamped
//! `SpanEvent`s plus an always-on metrics `Registry`.
//!
//! Design constraints, in order:
//! 1. **Analytic runs stay bit-reproducible with tracing on or off.**
//!    Recording is strictly write-only — nothing recorded ever feeds
//!    back into event-loop arithmetic, and the virtual timeline never
//!    reads the wall clock.
//! 2. **The hot path takes no locks.** Each producer thread owns one
//!    `Ring` (single-writer); a push is one relaxed load, one slot
//!    write, one release store, plus one relaxed fetch-add for the
//!    global sequence stamp. The only mutex in the recorder guards
//!    ring *registration*, which happens once per producer at setup.
//! 3. **Disabled costs one predictable branch.** `span()` returns
//!    immediately when the recorder is disabled; `bench-kernels
//!    --smoke` gates the enabled overhead (<2%) and reports the
//!    disabled delta in its `recorder_overhead` section.
//!
//! Ring capacity comes from `FOGRAPH_TRACE_BUF` (events per ring),
//! validated at startup exactly like `FOGRAPH_MIN_ROWS_PER_SHARD`.
//! When a ring wraps, the oldest spans are overwritten — the registry
//! keeps exact phase totals regardless, so `phase_breakdown` never
//! loses time even when the trace does.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use super::clock::ClockMode;
use super::registry::Registry;
use super::span::SpanEvent;
use crate::util::cli::parse_bounded_usize;

/// Default events per ring (~4.6 MB at 72 B/event across 16 rings —
/// plenty for a smoke loadtest, bounded for long runs).
pub const DEFAULT_TRACE_BUF: usize = 65_536;
/// Environment override for the per-ring event capacity.
pub const TRACE_BUF_ENV: &str = "FOGRAPH_TRACE_BUF";
/// Upper bound on the override: 2^24 events per ring (~1.2 GB across
/// a 16-fog pool) — anything larger is a typo, not a tuning.
pub const MAX_TRACE_BUF: usize = 1 << 24;

/// Parse a `FOGRAPH_TRACE_BUF` value: a positive integer in
/// `1..=MAX_TRACE_BUF`, same contract as `FOGRAPH_MIN_ROWS_PER_SHARD`
/// (and sharing its parser, so the two can never drift).
pub fn parse_trace_buf(v: &str) -> Result<usize, String> {
    parse_bounded_usize(TRACE_BUF_ENV, v, 1, MAX_TRACE_BUF)
}

/// Read and validate the env override; `Ok(DEFAULT_TRACE_BUF)` when
/// unset. `main` calls this at startup and turns `Err` into exit 2.
pub fn trace_buf_env() -> Result<usize, String> {
    match std::env::var(TRACE_BUF_ENV) {
        Ok(v) => parse_trace_buf(&v),
        Err(_) => Ok(DEFAULT_TRACE_BUF),
    }
}

static ACTIVE_TRACE_BUF: OnceLock<usize> = OnceLock::new();

/// The ring capacity in effect, latched on first use (invalid env
/// values fall back to the default here; startup validation already
/// rejected them for the CLI).
pub fn active_trace_buf() -> usize {
    *ACTIVE_TRACE_BUF
        .get_or_init(|| trace_buf_env().unwrap_or(DEFAULT_TRACE_BUF))
}

/// A single-producer wraparound span buffer. Exactly one thread may
/// `push` (the owning producer); `snapshot` is only meaningful at
/// quiescence — after the producer finished or between dispatch
/// barriers — which the release/acquire pair on `head` makes safe.
pub struct Ring {
    buf: UnsafeCell<Box<[SpanEvent]>>,
    head: AtomicU64,
}

// SAFETY: the single-writer contract above. `head` is the only shared
// cursor; slots are published by the release store and read after the
// matching acquire load, and readers only run at producer quiescence.
unsafe impl Send for Ring {}
unsafe impl Sync for Ring {}

impl Ring {
    pub fn new(cap: usize) -> Ring {
        let cap = cap.max(1);
        Ring {
            buf: UnsafeCell::new(
                vec![SpanEvent::empty(); cap].into_boxed_slice(),
            ),
            head: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        // SAFETY: length is immutable after construction.
        unsafe { (*self.buf.get()).len() }
    }

    /// Total events ever pushed (≥ retained when wrapped).
    pub fn pushed(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Write one event. Single-producer: only the owning thread.
    #[inline]
    pub fn push(&self, ev: SpanEvent) {
        let h = self.head.load(Ordering::Relaxed);
        // SAFETY: single-writer contract; readers wait for the
        // release store below.
        let buf = unsafe { &mut *self.buf.get() };
        let cap = buf.len();
        buf[(h as usize) % cap] = ev;
        self.head.store(h + 1, Ordering::Release);
    }

    /// Copy out the retained events, oldest first. Call only at
    /// producer quiescence.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let h = self.head.load(Ordering::Acquire) as usize;
        // SAFETY: quiescence contract — no concurrent writer.
        let buf = unsafe { &*self.buf.get() };
        let cap = buf.len();
        let n = h.min(cap);
        ((h - n)..h).map(|i| buf[i % cap]).collect()
    }
}

/// The recorder: owns the sequence counter, the ring directory, and
/// the metrics registry. Cheap to share (`Arc`); one per run.
///
/// The registry is *always* live — phase totals and queue-depth
/// gauges feed `phase_breakdown` in every report, traced or not, so
/// enabling tracing cannot change report bytes. The `enabled` flag
/// gates only span recording into rings.
pub struct Recorder {
    enabled: bool,
    mode: ClockMode,
    epoch: Instant,
    ring_cap: usize,
    seq: AtomicU64,
    rings: Mutex<Vec<Arc<Ring>>>,
    registry: Registry,
}

impl Recorder {
    fn build(enabled: bool, mode: ClockMode, ring_cap: usize) -> Recorder {
        Recorder {
            enabled,
            mode,
            epoch: Instant::now(),
            ring_cap: ring_cap.max(1),
            seq: AtomicU64::new(0),
            rings: Mutex::new(Vec::new()),
            registry: Registry::new(),
        }
    }

    /// A recorder that records no spans but still accumulates the
    /// registry — the default for untraced runs.
    pub fn disabled() -> Arc<Recorder> {
        Arc::new(Recorder::build(false, ClockMode::Virtual, 1))
    }

    /// An enabled recorder with ring capacity from the (validated)
    /// environment.
    pub fn enabled(mode: ClockMode) -> Arc<Recorder> {
        Arc::new(Recorder::build(true, mode, active_trace_buf()))
    }

    /// An enabled recorder with an explicit ring capacity (tests and
    /// benches).
    pub fn with_capacity(mode: ClockMode, cap: usize) -> Arc<Recorder> {
        Arc::new(Recorder::build(true, mode, cap))
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    pub fn mode(&self) -> ClockMode {
        self.mode
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Register and return a fresh ring for one producer thread.
    /// Disabled recorders hand out capacity-1 rings so producers keep
    /// a uniform code path at negligible memory cost.
    pub fn ring(&self) -> Arc<Ring> {
        let cap = if self.enabled { self.ring_cap } else { 1 };
        let r = Arc::new(Ring::new(cap));
        self.rings.lock().unwrap().push(Arc::clone(&r));
        r
    }

    /// Microseconds since the recorder epoch on the wall clock — the
    /// timebase of `wall` spans. Virtual-timeline code must not call
    /// this.
    pub fn wall_now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }

    /// Record one span into `ring`, stamping the global sequence.
    /// No-op (one branch) when disabled.
    #[inline]
    pub fn span(&self, ring: &Ring, mut ev: SpanEvent) {
        if !self.enabled {
            return;
        }
        ev.seq = self.seq.fetch_add(1, Ordering::Relaxed);
        ring.push(ev);
    }

    /// All retained events across rings, in sequence order. Call at
    /// quiescence (after the run's pools and loops finished).
    pub fn events(&self) -> Vec<SpanEvent> {
        let rings = self.rings.lock().unwrap();
        let mut out: Vec<SpanEvent> =
            rings.iter().flat_map(|r| r.snapshot()).collect();
        out.sort_by_key(|e| e.seq);
        out
    }

    /// Events pushed but overwritten by ring wraparound.
    pub fn dropped(&self) -> u64 {
        let rings = self.rings.lock().unwrap();
        rings
            .iter()
            .map(|r| r.pushed().saturating_sub(r.capacity() as u64))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::Phase;

    #[test]
    fn ring_wraps_and_keeps_newest() {
        let r = Ring::new(4);
        for i in 0..10u64 {
            let mut ev = SpanEvent::new(Phase::Queue, 0, i as f64, 0.0);
            ev.seq = i;
            r.push(ev);
        }
        let got = r.snapshot();
        assert_eq!(got.len(), 4);
        assert_eq!(
            got.iter().map(|e| e.seq).collect::<Vec<_>>(),
            vec![6, 7, 8, 9]
        );
        assert_eq!(r.pushed(), 10);
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let rec = Recorder::disabled();
        let ring = rec.ring();
        for _ in 0..100 {
            rec.span(&ring, SpanEvent::new(Phase::Kernel, 0, 0.0, 1.0));
        }
        assert!(rec.events().is_empty());
        assert_eq!(ring.pushed(), 0);
        // the registry is still live
        rec.registry().record_phase(0, -1, Phase::Queue, 0.5);
        assert!(rec.registry().phase_seconds(0, -1, Phase::Queue) > 0.0);
    }

    #[test]
    fn sequence_stamps_order_across_rings() {
        let rec = Recorder::with_capacity(ClockMode::Virtual, 16);
        let a = rec.ring();
        let b = rec.ring();
        rec.span(&a, SpanEvent::new(Phase::Arrive, 0, 0.0, 0.0));
        rec.span(&b, SpanEvent::new(Phase::Queue, 0, 1.0, 2.0));
        rec.span(&a, SpanEvent::new(Phase::Reply, 0, 3.0, 0.0));
        let evs = rec.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(
            evs.iter().map(|e| e.seq).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        assert_eq!(evs[1].phase, Phase::Queue);
        assert_eq!(rec.dropped(), 0);
    }

    #[test]
    fn trace_buf_parse_matches_min_rows_contract() {
        assert_eq!(parse_trace_buf("1"), Ok(1));
        assert_eq!(parse_trace_buf(" 4096 "), Ok(4096));
        assert!(parse_trace_buf("0").is_err());
        assert!(parse_trace_buf("-3").is_err());
        assert!(parse_trace_buf("abc").is_err());
        assert!(parse_trace_buf("").is_err());
        assert!(parse_trace_buf(&format!("{}", MAX_TRACE_BUF + 1)).is_err());
        assert_eq!(parse_trace_buf(&format!("{MAX_TRACE_BUF}")),
                   Ok(MAX_TRACE_BUF));
    }
}
