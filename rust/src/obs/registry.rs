//! Unified metrics registry: named counters, log-linear histograms,
//! exact per-(tenant, fog, phase) time accumulators, and per-(tenant,
//! fog) queue-depth gauges.
//!
//! The registry is clock-agnostic — callers hand it durations from
//! whichever timeline they run on — and always live, so analytic and
//! measured runs share one accounting path and reports carry a
//! `phase_breakdown` whether or not span tracing is enabled. Phase
//! totals are exact f64 sums updated in event order by the (single
//! threaded) fabric loop, so they are bit-reproducible; histograms
//! and counters are atomic and may additionally be fed from worker
//! threads.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::span::{Phase, NO_TENANT};
use crate::util::json::{self, Json};

/// A monotonic atomic counter.
#[derive(Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Sub-buckets per power-of-two octave.
pub const HIST_SUB: usize = 4;
/// Octaves covered: values in `[1, 2^40)` units resolve log-linearly;
/// smaller values land in bucket 0, larger saturate the top bucket.
pub const HIST_OCTAVES: usize = 40;
/// Total bucket count (one underflow bucket + the log-linear grid).
pub const HIST_BUCKETS: usize = 1 + HIST_SUB * HIST_OCTAVES;

/// A lock-free log-linear histogram: each power-of-two octave is
/// split into `HIST_SUB` equal sub-buckets, giving ≤ ~12% relative
/// error over 12 decades with a fixed 161-slot table. Units are the
/// caller's choice (the crate records microseconds).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    /// Bucket index for a value (non-finite and sub-1 values underflow
    /// to bucket 0; values past the top octave saturate).
    pub fn bucket_index(v: f64) -> usize {
        if !v.is_finite() || v < 1.0 {
            return 0;
        }
        let l = (v.log2().floor() as usize).min(HIST_OCTAVES - 1);
        let base = (1u64 << l) as f64;
        let sub = (((v / base) - 1.0) * HIST_SUB as f64) as usize;
        1 + l * HIST_SUB + sub.min(HIST_SUB - 1)
    }

    /// Upper edge of bucket `i` (inclusive-exclusive grid; bucket 0 is
    /// `< 1`).
    pub fn bucket_upper(i: usize) -> f64 {
        if i == 0 {
            return 1.0;
        }
        let k = i - 1;
        let (l, sub) = (k / HIST_SUB, k % HIST_SUB);
        (1u64 << l) as f64 * (1.0 + (sub + 1) as f64 / HIST_SUB as f64)
    }

    pub fn record(&self, v: f64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Fold `other` into `self` — the cross-thread aggregation path,
    /// tested against a single-threaded oracle.
    pub fn merge(&self, other: &Histogram) {
        for (i, b) in other.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                self.buckets[i].fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        let add = other.sum();
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + add).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Approximate percentile: the upper edge of the bucket holding
    /// the p-th sample (0 when empty).
    pub fn percentile(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target =
            ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_upper(i);
            }
        }
        Self::bucket_upper(HIST_BUCKETS - 1)
    }
}

#[derive(Clone, Copy, Default)]
struct PhaseAcc {
    seconds: f64,
    count: u64,
}

#[derive(Clone, Copy, Default)]
struct MeanMax {
    sum: f64,
    max: f64,
    n: u64,
}

/// The registry proper. Interior-mutable so one `&Registry` can be
/// shared everywhere a `Recorder` travels.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
    phases: Mutex<BTreeMap<(u32, i32, u8), PhaseAcc>>,
    queue_depth: Mutex<BTreeMap<(u32, u32), MeanMax>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create a named counter. Callers cache the handle; the
    /// lock is a setup cost, not a hot-path one.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(
            self.counters
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Get-or-create a named histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        Arc::clone(
            self.hists
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    /// Accumulate time-in-phase. `fog = -1` means tenant-level (the
    /// lifecycle track); per-fog rows carry the kernel/sync split.
    pub fn record_phase(&self, tenant: u32, fog: i32, phase: Phase,
                        seconds: f64) {
        let mut m = self.phases.lock().unwrap();
        let acc = m.entry((tenant, fog, phase as u8)).or_default();
        acc.seconds += seconds;
        acc.count += 1;
    }

    pub fn phase_seconds(&self, tenant: u32, fog: i32,
                         phase: Phase) -> f64 {
        self.phases
            .lock()
            .unwrap()
            .get(&(tenant, fog, phase as u8))
            .map_or(0.0, |a| a.seconds)
    }

    pub fn phase_count(&self, tenant: u32, fog: i32,
                       phase: Phase) -> u64 {
        self.phases
            .lock()
            .unwrap()
            .get(&(tenant, fog, phase as u8))
            .map_or(0, |a| a.count)
    }

    /// Sample one tenant's backlog on one fog (work-seconds), feeding
    /// the per-fog queue timelines every tenant now reports.
    pub fn record_queue_depth(&self, tenant: u32, fog: u32, depth: f64) {
        let mut m = self.queue_depth.lock().unwrap();
        let g = m.entry((tenant, fog)).or_default();
        g.sum += depth;
        g.n += 1;
        if depth > g.max {
            g.max = depth;
        }
    }

    /// `(mean, max)` queue depth per fog for one tenant; zero-filled
    /// up to `n_fogs` so reports stay rectangular.
    pub fn queue_depth_stats(&self, tenant: u32,
                             n_fogs: usize) -> (Vec<f64>, Vec<f64>) {
        let m = self.queue_depth.lock().unwrap();
        let mut mean = vec![0.0; n_fogs];
        let mut max = vec![0.0; n_fogs];
        for ((t, fog), g) in m.iter() {
            if *t == tenant && (*fog as usize) < n_fogs && g.n > 0 {
                mean[*fog as usize] = g.sum / g.n as f64;
                max[*fog as usize] = g.max;
            }
        }
        (mean, max)
    }

    /// Highest fog index seen (+1) across phase and queue records —
    /// the fog dimension of the breakdown.
    fn n_fogs_seen(&self) -> usize {
        let p = self.phases.lock().unwrap();
        let q = self.queue_depth.lock().unwrap();
        let a = p.keys().map(|(_, f, _)| *f + 1).max().unwrap_or(0);
        let b = q.keys().map(|(_, f)| *f as i32 + 1).max().unwrap_or(0);
        a.max(b).max(0) as usize
    }

    /// The `phase_breakdown` report section: per tenant, tenant-level
    /// time-in-phase (seconds, count, fraction of the tenant's total
    /// accounted time), per-fog kernel/sync/queue-depth rows, and the
    /// headline queue-wait vs. kernel split.
    pub fn phase_breakdown(&self, tenants: &[String]) -> Json {
        let n_fogs = self.n_fogs_seen();
        let mut out = BTreeMap::new();
        for (ti, name) in tenants.iter().enumerate() {
            let ti = ti as u32;
            let mut total = 0.0;
            for ph in Phase::ALL {
                total += self.phase_seconds(ti, -1, ph);
            }
            for fog in 0..n_fogs {
                for ph in [Phase::Kernel, Phase::Sync] {
                    total += self.phase_seconds(ti, fog as i32, ph);
                }
            }
            let mut phases = BTreeMap::new();
            for ph in Phase::ALL {
                let mut secs = self.phase_seconds(ti, -1, ph);
                let mut count = self.phase_count(ti, -1, ph);
                // kernel/sync live on per-fog rows; fold them up
                if matches!(ph, Phase::Kernel | Phase::Sync) {
                    for fog in 0..n_fogs {
                        secs += self.phase_seconds(ti, fog as i32, ph);
                        count += self.phase_count(ti, fog as i32, ph);
                    }
                }
                if count == 0 && secs == 0.0 {
                    continue;
                }
                phases.insert(
                    ph.name().to_string(),
                    json::obj(vec![
                        ("seconds", json::num(secs)),
                        ("count", json::num(count as f64)),
                        (
                            "fraction",
                            json::num(if total > 0.0 {
                                secs / total
                            } else {
                                0.0
                            }),
                        ),
                    ]),
                );
            }
            let (qd_mean, qd_max) = self.queue_depth_stats(ti, n_fogs);
            let per_fog = (0..n_fogs)
                .map(|fog| {
                    json::obj(vec![
                        ("fog", json::num(fog as f64)),
                        (
                            "kernel_s",
                            json::num(self.phase_seconds(
                                ti,
                                fog as i32,
                                Phase::Kernel,
                            )),
                        ),
                        (
                            "sync_s",
                            json::num(self.phase_seconds(
                                ti,
                                fog as i32,
                                Phase::Sync,
                            )),
                        ),
                        ("queue_depth_mean_s", json::num(qd_mean[fog])),
                        ("queue_depth_max_s", json::num(qd_max[fog])),
                    ])
                })
                .collect::<Vec<_>>();
            let kernel_s: f64 = (0..n_fogs)
                .map(|f| self.phase_seconds(ti, f as i32, Phase::Kernel))
                .sum();
            out.insert(
                name.clone(),
                json::obj(vec![
                    ("total_s", json::num(total)),
                    ("phases", Json::Obj(phases)),
                    ("per_fog", Json::Arr(per_fog)),
                    (
                        "queue_wait_s",
                        json::num(self.phase_seconds(ti, -1, Phase::Queue)),
                    ),
                    ("kernel_s", json::num(kernel_s)),
                ]),
            );
        }
        Json::Obj(out)
    }

    /// Prometheus text-exposition snapshot of everything the registry
    /// holds.
    pub fn prometheus_text(&self, tenants: &[String]) -> String {
        let tenant_label = |t: u32| -> String {
            if t == NO_TENANT {
                "control".to_string()
            } else {
                tenants
                    .get(t as usize)
                    .cloned()
                    .unwrap_or_else(|| format!("tenant{t}"))
            }
        };
        let mut out = String::new();
        out.push_str("# TYPE fograph_phase_seconds gauge\n");
        for ((t, fog, ph), acc) in self.phases.lock().unwrap().iter() {
            let ph = Phase::from_u8(*ph).map_or("unknown", |p| p.name());
            out.push_str(&format!(
                "fograph_phase_seconds{{tenant=\"{}\",fog=\"{}\",\
                 phase=\"{}\"}} {}\n",
                tenant_label(*t),
                fog,
                ph,
                acc.seconds
            ));
            out.push_str(&format!(
                "fograph_phase_count{{tenant=\"{}\",fog=\"{}\",\
                 phase=\"{}\"}} {}\n",
                tenant_label(*t),
                fog,
                ph,
                acc.count
            ));
        }
        out.push_str("# TYPE fograph_queue_depth_mean_s gauge\n");
        for ((t, fog), g) in self.queue_depth.lock().unwrap().iter() {
            if g.n == 0 {
                continue;
            }
            out.push_str(&format!(
                "fograph_queue_depth_mean_s{{tenant=\"{}\",fog=\"{}\"}} \
                 {}\n",
                tenant_label(*t),
                fog,
                g.sum / g.n as f64
            ));
            out.push_str(&format!(
                "fograph_queue_depth_max_s{{tenant=\"{}\",fog=\"{}\"}} \
                 {}\n",
                tenant_label(*t),
                fog,
                g.max
            ));
        }
        for (name, c) in self.counters.lock().unwrap().iter() {
            let n = sanitize(name);
            out.push_str(&format!("# TYPE fograph_{n} counter\n"));
            out.push_str(&format!("fograph_{n} {}\n", c.get()));
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            let n = sanitize(name);
            out.push_str(&format!("# TYPE fograph_{n} histogram\n"));
            let mut cum = 0u64;
            for (i, b) in h.bucket_counts().into_iter().enumerate() {
                if b == 0 {
                    continue;
                }
                cum += b;
                out.push_str(&format!(
                    "fograph_{n}_bucket{{le=\"{}\"}} {cum}\n",
                    Histogram::bucket_upper(i)
                ));
            }
            out.push_str(&format!(
                "fograph_{n}_bucket{{le=\"+Inf\"}} {}\n",
                h.count()
            ));
            out.push_str(&format!("fograph_{n}_sum {}\n", h.sum()));
            out.push_str(&format!("fograph_{n}_count {}\n", h.count()));
        }
        out
    }
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn counters_count() {
        let reg = Registry::new();
        let c = reg.counter("sheds");
        c.inc();
        c.add(4);
        assert_eq!(reg.counter("sheds").get(), 5);
        assert_eq!(reg.counter("other").get(), 0);
    }

    #[test]
    fn histogram_buckets_are_monotone_and_cover() {
        let mut last = 0.0;
        for i in 0..HIST_BUCKETS {
            let u = Histogram::bucket_upper(i);
            assert!(u > last, "bucket {i} upper {u} <= {last}");
            last = u;
        }
        // every bucketed value falls below its bucket's upper edge
        for v in [0.0, 0.5, 1.0, 1.49, 3.0, 7.9, 1000.0, 1e9] {
            let i = Histogram::bucket_index(v);
            assert!(v <= Histogram::bucket_upper(i) + 1e-9,
                    "v={v} i={i}");
            if i > 0 {
                assert!(v >= Histogram::bucket_upper(i - 1) * 0.999,
                        "v={v} i={i}");
            }
        }
        assert_eq!(Histogram::bucket_index(f64::NAN), 0);
        assert_eq!(Histogram::bucket_index(f64::INFINITY), 0);
    }

    #[test]
    fn histogram_merge_matches_single_threaded_oracle() {
        let mut rng = Rng::new(7);
        let oracle = Histogram::new();
        let shards: Vec<Histogram> =
            (0..4).map(|_| Histogram::new()).collect();
        for i in 0..4000 {
            let v = rng.f64() * 1e7;
            oracle.record(v);
            shards[i % 4].record(v);
        }
        let merged = Histogram::new();
        for s in &shards {
            merged.merge(s);
        }
        assert_eq!(merged.bucket_counts(), oracle.bucket_counts());
        assert_eq!(merged.count(), oracle.count());
        assert!((merged.sum() - oracle.sum()).abs()
                <= 1e-6 * oracle.sum().abs());
        for p in [50.0, 95.0, 99.0] {
            assert_eq!(merged.percentile(p), oracle.percentile(p));
        }
    }

    #[test]
    fn phase_accumulation_and_breakdown() {
        let reg = Registry::new();
        reg.record_phase(0, -1, Phase::Queue, 2.0);
        reg.record_phase(0, -1, Phase::Collect, 1.0);
        reg.record_phase(0, 0, Phase::Kernel, 3.0);
        reg.record_phase(0, 1, Phase::Kernel, 1.0);
        reg.record_phase(0, 1, Phase::Sync, 0.5);
        reg.record_queue_depth(0, 0, 4.0);
        reg.record_queue_depth(0, 0, 2.0);
        let bd = reg.phase_breakdown(&["t0".to_string()]);
        let t0 = bd.get("t0").unwrap();
        assert_eq!(t0.get("total_s").unwrap().as_f64(), Some(7.5));
        assert_eq!(t0.get("kernel_s").unwrap().as_f64(), Some(4.0));
        assert_eq!(t0.get("queue_wait_s").unwrap().as_f64(), Some(2.0));
        let kr = t0.at(&["phases", "kernel", "fraction"]).unwrap();
        assert!((kr.as_f64().unwrap() - 4.0 / 7.5).abs() < 1e-12);
        let pf = t0.get("per_fog").unwrap().as_arr().unwrap();
        assert_eq!(pf.len(), 2);
        assert_eq!(pf[0].get("kernel_s").unwrap().as_f64(), Some(3.0));
        assert_eq!(
            pf[0].get("queue_depth_mean_s").unwrap().as_f64(),
            Some(3.0)
        );
        assert_eq!(
            pf[0].get("queue_depth_max_s").unwrap().as_f64(),
            Some(4.0)
        );
        // deterministic serialization (bit-reproducibility contract)
        assert_eq!(bd.to_string(),
                   reg.phase_breakdown(&["t0".to_string()]).to_string());
    }

    #[test]
    fn prometheus_text_mentions_everything() {
        let reg = Registry::new();
        reg.counter("sheds").add(2);
        reg.histogram("kernel_us").record(12.0);
        reg.record_phase(0, -1, Phase::Queue, 1.0);
        reg.record_queue_depth(0, 1, 2.5);
        let txt = reg.prometheus_text(&["hi".to_string()]);
        assert!(txt.contains("fograph_sheds 2"));
        assert!(txt.contains("fograph_kernel_us_count 1"));
        assert!(txt.contains(
            "fograph_phase_seconds{tenant=\"hi\",fog=\"-1\",\
             phase=\"queue\"} 1"
        ));
        assert!(txt.contains("fograph_queue_depth_mean_s"));
        assert!(txt.contains("le=\"+Inf\""));
    }
}
