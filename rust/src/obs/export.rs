//! Exporters: Chrome trace-event JSON (loadable in `chrome://tracing`
//! and Perfetto) and the text/JSON snapshots built on the registry.
//!
//! Track layout: one trace *process* per tenant (pid = canonical
//! tenant index; the synthetic "control" process carries scheduler
//! events), one *thread* per track within it — tid 0 is the request
//! lifecycle, tid `1 + fog` the per-fog virtual timeline, and tid
//! `1000 + fog` the wall-clock kernel timeline of measured runs (the
//! offset keeps the two clock domains visually separate).

use std::collections::BTreeMap;

use super::recorder::Recorder;
use super::span::{SpanEvent, NO_TENANT};
use crate::util::json::{self, Json};

/// Wall-clock tracks start here so they sort after virtual tracks.
pub const WALL_TID_BASE: usize = 1000;

fn track_tid(ev: &SpanEvent) -> usize {
    if ev.wall {
        // fog -1 (coordinator work like halo sync) gets the base slot
        WALL_TID_BASE + (ev.fog + 1) as usize
    } else if ev.fog < 0 {
        0
    } else {
        1 + ev.fog as usize
    }
}

fn track_pid(ev: &SpanEvent, n_tenants: usize) -> usize {
    if ev.tenant == NO_TENANT {
        n_tenants
    } else {
        ev.tenant as usize
    }
}

fn meta_event(name: &str, pid: usize, tid: Option<usize>,
              value: &str) -> Json {
    let mut fields = vec![
        ("name", json::s(name)),
        ("ph", json::s("M")),
        ("pid", json::num(pid as f64)),
        ("args", json::obj(vec![("name", json::s(value))])),
    ];
    if let Some(tid) = tid {
        fields.push(("tid", json::num(tid as f64)));
    }
    json::obj(fields)
}

/// Build the Chrome trace-event document for everything the recorder
/// retained. `tenants` is the canonical (name-sorted) tenant order
/// the fabric ran with, so pids are stable across runs.
pub fn chrome_trace(rec: &Recorder, tenants: &[String]) -> Json {
    let events = rec.events();
    let mut out: Vec<Json> = Vec::with_capacity(events.len() + 16);

    // process/thread naming metadata
    for (i, t) in tenants.iter().enumerate() {
        out.push(meta_event("process_name", i, None, t));
    }
    out.push(meta_event("process_name", tenants.len(), None, "control"));
    let mut tracks: BTreeMap<(usize, usize), String> = BTreeMap::new();
    for ev in &events {
        let key = (track_pid(ev, tenants.len()), track_tid(ev));
        tracks.entry(key).or_insert_with(|| {
            match (ev.wall, ev.fog < 0) {
                (false, true) => "lifecycle".to_string(),
                (false, false) => format!("fog {}", ev.fog),
                (true, true) => "coordinator (wall)".to_string(),
                (true, false) => format!("fog {} (wall)", ev.fog),
            }
        });
    }
    for ((pid, tid), name) in &tracks {
        out.push(meta_event("thread_name", *pid, Some(*tid), name));
    }

    for ev in &events {
        let mut args = vec![("seq", json::num(ev.seq as f64))];
        if ev.layer >= 0 {
            args.push(("layer", json::num(f64::from(ev.layer))));
        }
        if ev.shard >= 0 {
            args.push(("shard", json::num(f64::from(ev.shard))));
        }
        if ev.n > 0 {
            args.push(("n", json::num(f64::from(ev.n))));
        }
        if let Some(cause) = ev.cause {
            args.push(("cause", json::s(cause)));
        }
        out.push(json::obj(vec![
            ("name", json::s(ev.phase.name())),
            (
                "cat",
                json::s(if ev.wall { "wall" } else { "virtual" }),
            ),
            ("ph", json::s("X")),
            ("ts", json::num(ev.t_us)),
            ("dur", json::num(ev.dur_us)),
            ("pid", json::num(track_pid(ev, tenants.len()) as f64)),
            ("tid", json::num(track_tid(ev) as f64)),
            ("args", json::obj(args)),
        ]));
    }

    json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", json::s("ms")),
        (
            "otherData",
            json::obj(vec![
                ("clock", json::s(rec.mode().name())),
                ("dropped_events", json::num(rec.dropped() as f64)),
            ]),
        ),
    ])
}

/// Write the trace document plus the Prometheus snapshot (same stem,
/// `.prom` extension). Returns the snapshot path.
pub fn write_trace_files(rec: &Recorder, tenants: &[String],
                         trace_path: &str) -> std::io::Result<String> {
    let doc = chrome_trace(rec, tenants);
    std::fs::write(trace_path, format!("{doc}\n"))?;
    let prom_path = match trace_path.rsplit_once('.') {
        Some((stem, _)) => format!("{stem}.prom"),
        None => format!("{trace_path}.prom"),
    };
    std::fs::write(&prom_path, rec.registry().prometheus_text(tenants))?;
    Ok(prom_path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::clock::ClockMode;
    use crate::obs::span::Phase;

    #[test]
    fn trace_parses_and_names_tracks() {
        let rec = Recorder::with_capacity(ClockMode::Virtual, 64);
        let ring = rec.ring();
        rec.span(&ring, SpanEvent::new(Phase::Arrive, 0, 0.0, 0.0));
        rec.span(
            &ring,
            SpanEvent::new(Phase::Kernel, 1, 10.0, 5.0).fog(2).layer(0),
        );
        rec.span(
            &ring,
            SpanEvent::new(Phase::Kernel, 0, 20.0, 3.0)
                .fog(1)
                .on_wall(),
        );
        rec.span(
            &ring,
            SpanEvent::new(Phase::Replan, NO_TENANT, 30.0, 0.0)
                .because("iep-replan"),
        );
        let tenants = vec!["a".to_string(), "b".to_string()];
        let doc = chrome_trace(&rec, &tenants);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // metadata + 4 spans
        assert!(evs.len() >= 4);
        let spans: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        assert_eq!(spans.len(), 4);
        // wall kernel lands on the offset track
        let wall = spans
            .iter()
            .find(|e| e.get("cat").unwrap().as_str() == Some("wall"))
            .unwrap();
        assert_eq!(
            wall.get("tid").unwrap().as_usize(),
            Some(WALL_TID_BASE + 2)
        );
        // control events live on the synthetic pid
        let replan = spans
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("replan"))
            .unwrap();
        assert_eq!(replan.get("pid").unwrap().as_usize(), Some(2));
        assert_eq!(
            replan.at(&["args", "cause"]).unwrap().as_str(),
            Some("iep-replan")
        );
        assert_eq!(
            parsed.at(&["otherData", "clock"]).unwrap().as_str(),
            Some("virtual")
        );
    }

    #[test]
    fn trace_files_roundtrip_on_disk() {
        let rec = Recorder::with_capacity(ClockMode::Wall, 16);
        let ring = rec.ring();
        rec.span(
            &ring,
            SpanEvent::new(Phase::Kernel, 0, 0.0, 2.0).fog(0).on_wall(),
        );
        rec.registry().counter("sheds").inc();
        let dir = std::env::temp_dir().join("fograph_obs_export_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let prom = write_trace_files(
            &rec,
            &["solo".to_string()],
            path.to_str().unwrap(),
        )
        .unwrap();
        let txt = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(txt.trim()).is_ok());
        let ptxt = std::fs::read_to_string(&prom).unwrap();
        assert!(ptxt.contains("fograph_sheds 1"));
        assert!(prom.ends_with(".prom"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
