//! Observability plane: a low-overhead flight recorder threaded
//! through the serving path, the unified metrics registry that
//! subsumes the crate's ad-hoc statistics, and the exporters that
//! turn a run into a Perfetto-loadable trace, a Prometheus snapshot,
//! and the `phase_breakdown` report section.
//!
//! Layering: [`span`] defines the taxonomy, [`clock`] the two
//! timelines, [`recorder`] the lock-free per-thread rings plus the
//! always-on [`registry`], and [`export`] the output formats. The
//! fabric, measured executor, kernel pool and scheduler record into
//! this plane; the roadmap's fault-detection, pipelining-occupancy
//! and autoscaling items consume it.

pub mod clock;
pub mod export;
pub mod recorder;
pub mod registry;
pub mod span;

pub use clock::{ClockMode, Stopwatch};
pub use export::{chrome_trace, write_trace_files, WALL_TID_BASE};
pub use recorder::{
    active_trace_buf, parse_trace_buf, trace_buf_env, Recorder, Ring,
    DEFAULT_TRACE_BUF, MAX_TRACE_BUF, TRACE_BUF_ENV,
};
pub use registry::{Counter, Histogram, Registry};
pub use span::{Phase, SpanEvent, NO_TENANT};
