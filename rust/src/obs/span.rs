//! Span taxonomy for the request lifecycle. One `SpanEvent` is a
//! fixed-size, `Copy` record — cheap enough to write into a ring
//! buffer on the hot path — covering the full serving pipeline of the
//! paper's cost model: `arrive → queue → admit/shed → batch → collect
//! → compress → transfer → kernel[layer, fog, shard] → sync → reply`,
//! plus `replan` control events carrying their trigger cause.
//!
//! Timestamps are microseconds on one of two timelines, selected by
//! the `wall` flag: the fabric's virtual clock (both analytic and
//! measured runs schedule on simulated seconds) or the wall clock of
//! a worker thread (measured kernel execution only). The two never
//! mix on one track; the exporter places them on separate tracks.

/// A lifecycle phase. Discriminants are stable and used as compact
/// registry keys; `ALL` and `name()` keep exporters and the docs table
/// in one place.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Phase {
    /// Request entered the fabric (instant).
    Arrive = 0,
    /// Time spent waiting in a tenant's admission queue.
    Queue = 1,
    /// Request admitted past the queue bound (instant).
    Admit = 2,
    /// Request shed or spilled at admission (instant, cause-tagged).
    Shed = 3,
    /// Micro-batch formed (instant; `n` = batch size).
    Batch = 4,
    /// Feature collection window for a released batch.
    Collect = 5,
    /// Degree-aware compression share of the collection window.
    Compress = 6,
    /// Wire-transfer share of the collection window.
    Transfer = 7,
    /// Per-fog kernel execution (layer/fog/shard-tagged).
    Kernel = 8,
    /// BSP halo-synchronization barrier.
    Sync = 9,
    /// Batch results handed back to clients (instant; `n` = count).
    Reply = 10,
    /// Scheduler intervention (instant, cause-tagged).
    Replan = 11,
    /// Backpressure stall: the pipelined executor's in-flight window
    /// is full, so a release blocks until the oldest batch drains.
    /// Kept distinct from `Queue` (admission wait) and from the pool's
    /// job-channel queue-wait so OnlineProfiler observations stay
    /// queueing-free.
    PipelineStall = 12,
    /// Emergency fault recovery: evacuating a dead fog's partitions
    /// through the rescheduler and re-grounding the plan. Kept
    /// distinct from `Replan` (steady-state skew replans) so profiler
    /// observations and the phase breakdown stay clean under chaos.
    Recovery = 13,
}

impl Phase {
    pub const ALL: [Phase; 14] = [
        Phase::Arrive,
        Phase::Queue,
        Phase::Admit,
        Phase::Shed,
        Phase::Batch,
        Phase::Collect,
        Phase::Compress,
        Phase::Transfer,
        Phase::Kernel,
        Phase::Sync,
        Phase::Reply,
        Phase::Replan,
        Phase::PipelineStall,
        Phase::Recovery,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Arrive => "arrive",
            Phase::Queue => "queue",
            Phase::Admit => "admit",
            Phase::Shed => "shed",
            Phase::Batch => "batch",
            Phase::Collect => "collect",
            Phase::Compress => "compress",
            Phase::Transfer => "transfer",
            Phase::Kernel => "kernel",
            Phase::Sync => "sync",
            Phase::Reply => "reply",
            Phase::Replan => "replan",
            Phase::PipelineStall => "pipeline_stall",
            Phase::Recovery => "recovery",
        }
    }

    pub fn from_u8(d: u8) -> Option<Phase> {
        Phase::ALL.get(d as usize).copied()
    }
}

/// Tenant index meaning "no tenant" — control-plane events (scheduler
/// replans on a shared service) land on a dedicated exporter track.
pub const NO_TENANT: u32 = u32::MAX;

/// One recorded span. `dur_us == 0` marks an instant event. `fog`,
/// `layer` and `shard` are `-1` when not applicable; `n` is a free
/// count (batch size, shed count). `cause` is a static tag for
/// shed/replan triggers so recording never allocates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanEvent {
    pub seq: u64,
    pub phase: Phase,
    /// `false`: virtual (simulated-seconds) timeline; `true`: wall
    /// clock of the recording thread, relative to the recorder epoch.
    pub wall: bool,
    pub tenant: u32,
    pub fog: i32,
    pub layer: i32,
    pub shard: i32,
    pub n: u32,
    pub t_us: f64,
    pub dur_us: f64,
    pub cause: Option<&'static str>,
}

impl SpanEvent {
    /// A zeroed placeholder used to pre-fill ring storage.
    pub const fn empty() -> SpanEvent {
        SpanEvent {
            seq: 0,
            phase: Phase::Arrive,
            wall: false,
            tenant: NO_TENANT,
            fog: -1,
            layer: -1,
            shard: -1,
            n: 0,
            t_us: 0.0,
            dur_us: 0.0,
            cause: None,
        }
    }

    /// Start a span description; the recorder stamps `seq` on write.
    pub fn new(phase: Phase, tenant: u32, t_us: f64,
               dur_us: f64) -> SpanEvent {
        SpanEvent { phase, tenant, t_us, dur_us, ..SpanEvent::empty() }
    }

    pub fn on_wall(mut self) -> SpanEvent {
        self.wall = true;
        self
    }

    pub fn fog(mut self, fog: usize) -> SpanEvent {
        self.fog = fog as i32;
        self
    }

    pub fn layer(mut self, layer: usize) -> SpanEvent {
        self.layer = layer as i32;
        self
    }

    pub fn shard(mut self, shard: usize) -> SpanEvent {
        self.shard = shard as i32;
        self
    }

    pub fn count(mut self, n: usize) -> SpanEvent {
        self.n = n as u32;
        self
    }

    pub fn because(mut self, cause: &'static str) -> SpanEvent {
        self.cause = Some(cause);
        self
    }

    pub fn end_us(&self) -> f64 {
        self.t_us + self.dur_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_names_are_unique_and_roundtrip() {
        let mut seen: Vec<&str> = Vec::new();
        for (i, p) in Phase::ALL.iter().enumerate() {
            assert_eq!(*p as u8 as usize, i);
            assert_eq!(Phase::from_u8(*p as u8), Some(*p));
            assert!(!seen.contains(&p.name()), "dup {:?}", p.name());
            seen.push(p.name());
        }
        assert_eq!(Phase::from_u8(200), None);
    }

    #[test]
    fn builder_sets_fields() {
        let ev = SpanEvent::new(Phase::Kernel, 2, 10.0, 5.0)
            .fog(3)
            .layer(1)
            .shard(0)
            .count(8)
            .on_wall()
            .because("test");
        assert_eq!(ev.phase, Phase::Kernel);
        assert_eq!((ev.tenant, ev.fog, ev.layer, ev.shard), (2, 3, 1, 0));
        assert_eq!(ev.n, 8);
        assert!(ev.wall);
        assert_eq!(ev.cause, Some("test"));
        assert_eq!(ev.end_us(), 15.0);
    }
}
