//! Clock abstraction for the observability plane. The fabric event
//! loop advances a *virtual* clock (simulated seconds) in both
//! analytic and `--exec measured` runs; only kernel workers ever read
//! the wall clock. Centralizing that distinction here keeps analytic
//! runs bit-reproducible with tracing on or off: nothing on the
//! virtual timeline may consult `Instant`.

use std::time::Instant;

/// Which timeline a recorder (and its exported trace) is anchored to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClockMode {
    /// Simulated seconds driven by the fabric event loop (analytic
    /// runs; also the scheduling timeline of measured runs).
    Virtual,
    /// Wall clock relative to the recorder's epoch (measured kernel
    /// execution inside worker threads).
    Wall,
}

impl ClockMode {
    pub fn name(self) -> &'static str {
        match self {
            ClockMode::Virtual => "virtual",
            ClockMode::Wall => "wall",
        }
    }
}

/// The one sanctioned wall-clock primitive: every wall measurement in
/// the crate (bench harness, serving collection, kernel workers) goes
/// through a `Stopwatch` so wall-time reads are greppable and the
/// virtual timeline provably never touches one.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { t0: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    pub fn elapsed_ns(&self) -> f64 {
        self.t0.elapsed().as_nanos() as f64
    }

    /// Elapsed seconds since start (or the last `lap`), resetting the
    /// origin — for phase-to-phase splits without nested watches.
    pub fn lap_s(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.t0).as_secs_f64();
        self.t0 = now;
        dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_is_monotone() {
        let mut w = Stopwatch::start();
        let a = w.elapsed_ns();
        let b = w.elapsed_ns();
        assert!(b >= a);
        assert!(a >= 0.0);
        let lap = w.lap_s();
        assert!(lap >= 0.0);
        // after a lap the origin resets, so elapsed restarts near zero
        assert!(w.elapsed_s() <= lap + 1.0);
    }

    #[test]
    fn clock_mode_names() {
        assert_eq!(ClockMode::Virtual.name(), "virtual");
        assert_eq!(ClockMode::Wall.name(), "wall");
    }
}
