//! Seeded request-arrival processes for the load generator. Three
//! processes cover the IoT serving regimes the paper targets:
//!
//! * `poisson` — memoryless baseline at a constant target rate,
//! * `bursty`  — a 2-state Markov-modulated Poisson process (calm /
//!   burst) whose stationary mean equals the target rate,
//! * `diurnal` — an inhomogeneous Poisson day-curve (trough at the start
//!   of the run, peak mid-run) sampled by thinning.
//!
//! Every stream is fully determined by `(kind, rate, seed)` — no wall
//! clock anywhere — so loadtest runs are replayable.

use crate::util::rng::Rng;

/// MMPP calm-state rate as a fraction of the target.
const BURSTY_CALM_FACTOR: f64 = 0.5;
/// Mean sojourn in the calm state (seconds).
const BURSTY_CALM_HOLD_S: f64 = 4.0;
/// Mean sojourn in the burst state (seconds).
const BURSTY_BURST_HOLD_S: f64 = 1.0;
/// Relative amplitude of the diurnal rate curve.
const DIURNAL_AMPLITUDE: f64 = 0.75;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalKind {
    Poisson,
    Bursty,
    Diurnal,
}

impl ArrivalKind {
    pub fn parse(s: &str) -> Option<ArrivalKind> {
        match s {
            "poisson" => Some(ArrivalKind::Poisson),
            "bursty" | "mmpp" => Some(ArrivalKind::Bursty),
            "diurnal" => Some(ArrivalKind::Diurnal),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Bursty => "bursty",
            ArrivalKind::Diurnal => "diurnal",
        }
    }

    pub fn all() -> [ArrivalKind; 3] {
        [ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Diurnal]
    }
}

/// Generator of one request stream.
pub struct ArrivalProcess {
    kind: ArrivalKind,
    rate_rps: f64,
    rng: Rng,
}

/// Burst-state rate so that the stationary mean hits the target:
/// π_calm·r_calm + π_burst·r_burst = rate.
fn bursty_burst_factor() -> f64 {
    let pi_burst =
        BURSTY_BURST_HOLD_S / (BURSTY_CALM_HOLD_S + BURSTY_BURST_HOLD_S);
    (1.0 - (1.0 - pi_burst) * BURSTY_CALM_FACTOR) / pi_burst
}

impl ArrivalProcess {
    pub fn new(kind: ArrivalKind, rate_rps: f64, seed: u64)
               -> ArrivalProcess {
        assert!(rate_rps > 0.0, "arrival rate must be positive");
        ArrivalProcess { kind, rate_rps, rng: Rng::new(seed) }
    }

    /// Exponential inter-arrival gap at `rate` events/second.
    fn exp_gap(&mut self, rate: f64) -> f64 {
        // 1 - U ∈ (0, 1]: never ln(0)
        -(1.0 - self.rng.f64()).ln() / rate
    }

    /// All arrival timestamps in `[0, duration_s)`, non-decreasing.
    pub fn times(&mut self, duration_s: f64) -> Vec<f64> {
        match self.kind {
            ArrivalKind::Poisson => self.poisson(duration_s),
            ArrivalKind::Bursty => self.bursty(duration_s),
            ArrivalKind::Diurnal => self.diurnal(duration_s),
        }
    }

    fn poisson(&mut self, duration_s: f64) -> Vec<f64> {
        let mut out = Vec::new();
        let mut t = self.exp_gap(self.rate_rps);
        while t < duration_s {
            out.push(t);
            t += self.exp_gap(self.rate_rps);
        }
        out
    }

    fn bursty(&mut self, duration_s: f64) -> Vec<f64> {
        let r_calm = self.rate_rps * BURSTY_CALM_FACTOR;
        let r_burst = self.rate_rps * bursty_burst_factor();
        let mut out = Vec::new();
        let mut t = 0f64;
        let mut burst = false;
        let mut next_switch = self.exp_gap(1.0 / BURSTY_CALM_HOLD_S);
        while t < duration_s {
            let rate = if burst { r_burst } else { r_calm };
            let dt = self.exp_gap(rate);
            if t + dt >= next_switch {
                // memorylessness makes regenerating at the switch exact
                t = next_switch;
                burst = !burst;
                let hold = if burst {
                    BURSTY_BURST_HOLD_S
                } else {
                    BURSTY_CALM_HOLD_S
                };
                next_switch = t + self.exp_gap(1.0 / hold);
                continue;
            }
            t += dt;
            if t < duration_s {
                out.push(t);
            }
        }
        out
    }

    fn diurnal(&mut self, duration_s: f64) -> Vec<f64> {
        // one full day-cycle per run: trough at t=0, peak at t=T/2
        let rate = self.rate_rps;
        let rate_max = rate * (1.0 + DIURNAL_AMPLITUDE);
        let rate_at = move |t: f64| -> f64 {
            let phase = t / duration_s * std::f64::consts::TAU;
            rate * (1.0 - DIURNAL_AMPLITUDE * phase.cos())
        };
        let mut out = Vec::new();
        let mut t = self.exp_gap(rate_max);
        while t < duration_s {
            if self.rng.f64() < rate_at(t) / rate_max {
                out.push(t);
            }
            t += self.exp_gap(rate_max);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_are_deterministic_under_a_fixed_seed() {
        for kind in ArrivalKind::all() {
            let a = ArrivalProcess::new(kind, 50.0, 7).times(20.0);
            let b = ArrivalProcess::new(kind, 50.0, 7).times(20.0);
            assert_eq!(a, b, "{} stream not reproducible", kind.name());
            let c = ArrivalProcess::new(kind, 50.0, 8).times(20.0);
            assert_ne!(a, c, "{} stream ignores the seed", kind.name());
        }
    }

    #[test]
    fn timestamps_are_ordered_and_in_range() {
        for kind in ArrivalKind::all() {
            let ts = ArrivalProcess::new(kind, 80.0, 3).times(10.0);
            assert!(!ts.is_empty());
            for w in ts.windows(2) {
                assert!(w[0] <= w[1], "{} unordered", kind.name());
            }
            assert!(*ts.last().unwrap() < 10.0);
            assert!(ts[0] >= 0.0);
        }
    }

    #[test]
    fn empirical_rate_matches_target_within_tolerance() {
        // 200 rps × 60 s = 12000 expected; σ/μ ≈ 1% for Poisson, wider
        // for the modulated processes — 8% covers all three at p≪1e-6.
        for kind in ArrivalKind::all() {
            let ts = ArrivalProcess::new(kind, 200.0, 11).times(60.0);
            let rate = ts.len() as f64 / 60.0;
            assert!(
                (rate - 200.0).abs() < 16.0,
                "{}: empirical rate {rate} vs target 200",
                kind.name()
            );
        }
    }

    #[test]
    fn bursty_has_higher_gap_variance_than_poisson() {
        let gaps = |ts: &[f64]| -> Vec<f64> {
            ts.windows(2).map(|w| w[1] - w[0]).collect()
        };
        let p = ArrivalProcess::new(ArrivalKind::Poisson, 100.0, 5)
            .times(60.0);
        let b = ArrivalProcess::new(ArrivalKind::Bursty, 100.0, 5)
            .times(60.0);
        let cv = |xs: &[f64]| {
            crate::util::stats::stddev(xs)
                / crate::util::stats::mean(xs).max(1e-12)
        };
        let cv_p = cv(&gaps(&p));
        let cv_b = cv(&gaps(&b));
        // Poisson gaps have CV ≈ 1; MMPP strictly above
        assert!(cv_p < 1.2, "poisson CV {cv_p}");
        assert!(cv_b > cv_p, "bursty CV {cv_b} !> poisson CV {cv_p}");
    }

    #[test]
    fn diurnal_peaks_mid_run() {
        let ts = ArrivalProcess::new(ArrivalKind::Diurnal, 200.0, 9)
            .times(40.0);
        let count = |lo: f64, hi: f64| {
            ts.iter().filter(|&&t| t >= lo && t < hi).count()
        };
        let trough = count(0.0, 8.0) + count(32.0, 40.0);
        let peak = count(16.0, 24.0);
        // peak window rate ≈ (1+A)·r vs trough ≈ (1-A)·r with A=0.75
        assert!(
            peak as f64 > 1.5 * trough as f64 / 2.0,
            "no diurnal shape: peak {peak} trough {trough}"
        );
    }

    #[test]
    fn parse_round_trips() {
        for kind in ArrivalKind::all() {
            assert_eq!(ArrivalKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ArrivalKind::parse("mmpp"), Some(ArrivalKind::Bursty));
        assert_eq!(ArrivalKind::parse("weekly"), None);
    }
}
