//! Adaptive micro-batching for the online serving loop: queued requests
//! are released as one batch when either the size bound fills or the
//! oldest request has waited out the latency budget. Formed batches are
//! costed at the next power-of-two *bucket* — the same padding discipline
//! as `runtime/pad.rs`, where an executable exists per bucket shape and a
//! batch pays for the bucket it runs in, not its exact size.

use std::collections::VecDeque;

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Release a batch as soon as this many requests are queued.
    pub max_batch: usize,
    /// Release an underfull batch once its oldest request has waited
    /// this long (the batching share of the latency budget).
    pub max_delay_s: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_delay_s: 0.02 }
    }
}

/// Execution-cost bucket for a batch of `n` requests: the next power of
/// two ≥ n. Mirrors the lowered-artifact buckets of the runtime.
pub fn bucket(n: usize) -> usize {
    n.max(1).next_power_of_two()
}

/// FIFO micro-batcher over request arrival times (simulation seconds).
#[derive(Clone, Debug)]
pub struct MicroBatcher {
    policy: BatchPolicy,
    pending: VecDeque<f64>,
}

impl MicroBatcher {
    pub fn new(policy: BatchPolicy) -> MicroBatcher {
        assert!(policy.max_batch >= 1);
        assert!(policy.max_delay_s >= 0.0);
        MicroBatcher { policy, pending: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue a request that arrived at `arrival_s` (non-decreasing).
    pub fn push(&mut self, arrival_s: f64) {
        debug_assert!(
            self.pending.back().map_or(true, |&b| b <= arrival_s),
            "arrivals must be pushed in time order"
        );
        self.pending.push_back(arrival_s);
    }

    /// Arrival time of the oldest queued request (`None` when empty) —
    /// the head-of-line timestamp shared-FIFO arbitration compares.
    pub fn oldest(&self) -> Option<f64> {
        self.pending.front().copied()
    }

    /// Earliest simulation time at which a batch may be released under
    /// the policy: the arrival that filled the size bound, or the oldest
    /// request's deadline. `None` while the queue is empty.
    pub fn ready_at(&self) -> Option<f64> {
        if self.pending.is_empty() {
            return None;
        }
        if self.pending.len() >= self.policy.max_batch {
            return Some(self.pending[self.policy.max_batch - 1]);
        }
        Some(self.pending[0] + self.policy.max_delay_s)
    }

    /// Remove the oldest `≤ max_batch` requests as one batch (FIFO).
    pub fn take_batch(&mut self) -> Vec<f64> {
        let k = self.pending.len().min(self.policy.max_batch);
        self.pending.drain(..k).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, max_delay_s: f64) -> BatchPolicy {
        BatchPolicy { max_batch, max_delay_s }
    }

    #[test]
    fn size_bound_is_respected() {
        let mut b = MicroBatcher::new(policy(4, 1.0));
        for i in 0..11 {
            b.push(i as f64 * 0.001);
        }
        // size condition met at the 4th arrival, not the deadline
        assert_eq!(b.ready_at(), Some(0.003));
        let batch = b.take_batch();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0], 0.0); // FIFO
        assert_eq!(b.len(), 7);
        assert_eq!(b.take_batch().len(), 4);
        assert_eq!(b.take_batch().len(), 3); // final underfull batch
        assert!(b.is_empty());
        assert_eq!(b.ready_at(), None);
    }

    #[test]
    fn deadline_bound_releases_underfull_batches() {
        let mut b = MicroBatcher::new(policy(32, 0.05));
        b.push(10.0);
        b.push(10.01);
        assert_eq!(b.ready_at(), Some(10.05));
        let batch = b.take_batch();
        assert_eq!(batch, vec![10.0, 10.01]);
    }

    #[test]
    fn deadline_follows_the_oldest_request() {
        let mut b = MicroBatcher::new(policy(8, 0.1));
        b.push(1.0);
        b.push(1.09);
        // the second arrival must not extend the first one's deadline
        assert_eq!(b.ready_at(), Some(1.1));
    }

    #[test]
    fn buckets_are_powers_of_two() {
        assert_eq!(bucket(0), 1);
        assert_eq!(bucket(1), 1);
        assert_eq!(bucket(2), 2);
        assert_eq!(bucket(3), 4);
        assert_eq!(bucket(17), 32);
        assert_eq!(bucket(32), 32);
    }
}
