//! Measured per-batch execution for the online serving loop
//! (`--exec measured`): each released micro-batch drives the real CSR
//! batched BSP kernels (`exec::BatchedBspPlan`) at its padded bucket
//! size, with per-fog layer compute on the persistent worker pool
//! (`runtime::kernels::pool`). Measured per-fog timings feed the
//! online profiler (η-scaled ω′ models, paper §III-B runtime phase),
//! so mid-run diffusion / IEP replans reason over OBSERVED costs
//! instead of the closed-form ω — the calibration loop the
//! edge-serving cost models argue for. Covers every model, astgcn
//! included.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use crate::exec::{BatchedBspPlan, BspPipeline, BspResult, ExecTrace,
                  PipelineChaos};
use crate::graph::Graph;
use crate::obs::recorder::Recorder;
use crate::profile::{Cardinality, Observation, OnlineProfiler,
                     PerfModel};
use crate::runtime::{Engine, EngineError, WeightBundle};
use crate::util::cli::MAX_PIPELINE_DEPTH;

/// Accumulated wall-clock for one padded bucket size. Kernel seconds
/// and pool queue waits are accumulated separately, so the per-bucket
/// timings (and the profiler observations derived from them) reflect
/// pure kernel cost.
#[derive(Clone, Copy, Debug, Default)]
pub struct BucketStat {
    /// Sum of per-batch BSP barrier host seconds (Σ_layer max_fog).
    pub total_host_s: f64,
    /// Sum of per-batch pool queue waits (Σ_layer max_fog of the
    /// job-channel send-to-dequeue latency).
    pub total_queue_wait_s: f64,
    pub batches: usize,
}

impl BucketStat {
    pub fn mean_ms(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.total_host_s / self.batches as f64 * 1e3
        }
    }

    pub fn mean_queue_wait_ms(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.total_queue_wait_s / self.batches as f64 * 1e3
        }
    }
}

/// One row of the measured per-bucket summary.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BucketRow {
    pub bucket: usize,
    /// Mean per-batch kernel barrier time (pure kernel cost).
    pub mean_host_ms: f64,
    /// Mean per-batch pool queue wait, reported apart from kernel
    /// seconds.
    pub mean_queue_wait_ms: f64,
    pub batches: usize,
}

/// Real-kernel executor for the serving loop: owns the pre-extracted
/// partition plan, the weight bundle and the per-fog online profilers.
pub struct MeasuredExec {
    plan: BatchedBspPlan,
    wb: Arc<WeightBundle>,
    features: Vec<f32>,
    f_in: usize,
    kernel_threads: usize,
    profilers: Vec<OnlineProfiler>,
    bucket_stats: BTreeMap<usize, BucketStat>,
    /// Flight-recorder context (`attach_recorder`); `None` keeps the
    /// executor on the identical untraced path.
    trace: Option<ExecTrace>,
    /// `--pipeline-depth`; 1 keeps the classic barrier `run_batch`
    /// path bit-identical.
    pipeline_depth: usize,
    /// Pipelined executor, present iff `pipeline_depth > 1`.
    pipeline: Option<BspPipeline>,
    /// Bucket sizes of in-flight pipelined batches, submission order.
    inflight_buckets: VecDeque<usize>,
    /// Per-fog cumulative measured kernel seconds (both exec paths) —
    /// the numerator of `pipeline_occupancy`.
    busy_s: Vec<f64>,
    /// Wall window from first batch submission to last collection —
    /// the denominator of `pipeline_occupancy`.
    window_start: Option<Instant>,
    window_s: f64,
    /// Chaos masks currently applied to the pipeline: per-fog crashed
    /// flags, per-fog speed multipliers, and the task deadline that
    /// triggers hedged re-dispatch. `None` keeps every execution path
    /// bit-identical to the fault-free executor.
    chaos_cfg: Option<(Vec<bool>, Vec<f64>, f64)>,
    /// Hedge (wins, waste) carried over from pipelines retired by
    /// `rebuild`, so run totals survive mid-run replans.
    hedge_acc: (u64, u64),
}

impl MeasuredExec {
    /// `payload`/`dims` are the raw (pre-codec) per-inference upload —
    /// the same snapshot the grounding pipeline run served; `omegas`
    /// seed the profilers' offline models; `kernel_threads` sizes the
    /// per-fog shard groups (`--kernel-threads`; 1 = no intra-fog
    /// parallelism).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        g: &Graph,
        assignment: &[u32],
        n_fogs: usize,
        model: &str,
        dataset: &str,
        payload: &[f32],
        dims: usize,
        classes: usize,
        omegas: &[PerfModel],
        engine: &mut Engine,
        kernel_threads: usize,
    ) -> Result<MeasuredExec, EngineError> {
        MeasuredExec::build(g, assignment, n_fogs, model, dataset,
                            payload, dims, classes, omegas, engine,
                            kernel_threads, None)
    }

    /// Like `new`, but execute on an EXISTING worker pool instead of
    /// spawning a private one — the multi-tenant fabric's plan cache
    /// uses this so every `(model, dataset)` plan shares one
    /// `--kernel-threads` budget of threads.
    #[allow(clippy::too_many_arguments)]
    pub fn with_pool(
        g: &Graph,
        assignment: &[u32],
        n_fogs: usize,
        model: &str,
        dataset: &str,
        payload: &[f32],
        dims: usize,
        classes: usize,
        omegas: &[PerfModel],
        engine: &mut Engine,
        kernel_threads: usize,
        pool: Arc<crate::runtime::FogWorkerPool>,
    ) -> Result<MeasuredExec, EngineError> {
        MeasuredExec::build(g, assignment, n_fogs, model, dataset,
                            payload, dims, classes, omegas, engine,
                            kernel_threads, Some(pool))
    }

    #[allow(clippy::too_many_arguments)]
    fn build(
        g: &Graph,
        assignment: &[u32],
        n_fogs: usize,
        model: &str,
        dataset: &str,
        payload: &[f32],
        dims: usize,
        classes: usize,
        omegas: &[PerfModel],
        engine: &mut Engine,
        kernel_threads: usize,
        pool: Option<Arc<crate::runtime::FogWorkerPool>>,
    ) -> Result<MeasuredExec, EngineError> {
        let plan = match pool {
            Some(pool) => BatchedBspPlan::with_shared_pool(
                g, assignment, n_fogs, model, kernel_threads, pool,
            )?,
            None => BatchedBspPlan::with_threads(
                g, assignment, n_fogs, model, kernel_threads,
            )?,
        };
        let wb =
            Arc::new(engine.weights(model, dataset, dims, classes).clone());
        Ok(MeasuredExec {
            plan,
            wb,
            features: payload.to_vec(),
            f_in: dims,
            kernel_threads,
            profilers: omegas
                .iter()
                .map(|m| OnlineProfiler::new(m.clone()))
                .collect(),
            bucket_stats: BTreeMap::new(),
            trace: None,
            pipeline_depth: 1,
            pipeline: None,
            inflight_buckets: VecDeque::new(),
            busy_s: vec![0.0; n_fogs],
            window_start: None,
            window_s: 0.0,
            chaos_cfg: None,
            hedge_acc: (0, 0),
        })
    }

    /// Attach the flight recorder: subsequent batches record per-fog
    /// wall `kernel`/`queue` spans (attributed to canonical tenant
    /// index `tenant`) plus kernel-barrier / queue-wait histograms in
    /// the registry. Numerically a no-op — tracing only observes the
    /// seconds `run_batch` already reports.
    pub fn attach_recorder(&mut self, rec: &Arc<Recorder>,
                           tenant: u32) {
        self.trace =
            Some(ExecTrace::new(rec, self.plan.n_fogs(), tenant));
    }

    /// Retag subsequent wall spans with the tenant about to be served —
    /// a shared-service plan executes batches for several tenants, and
    /// attribution must follow the admission arbiter's pick. No-op when
    /// no recorder is attached.
    pub fn set_trace_tenant(&mut self, tenant: u32) {
        if let Some(tr) = self.trace.as_mut() {
            tr.tenant = tenant;
        }
    }

    pub fn engine_name(&self) -> &'static str {
        "csr-batched"
    }

    /// The `--kernel-threads` value the worker pool was built with.
    pub fn kernel_threads(&self) -> usize {
        self.kernel_threads
    }

    /// Execute one micro-batch at bucket size `bucket`; returns the
    /// measured `layer_host_seconds[layer][fog]` and feeds the per-fog
    /// profilers with per-request-normalized observations. Pool queue
    /// waits accumulate separately (`BucketRow::mean_queue_wait_ms`),
    /// so kernel timings — and the profiler observations — never fold
    /// in channel queueing.
    pub fn run_batch(&mut self, bucket: usize) -> Vec<Vec<f64>> {
        // Under chaos the barrier path would wedge on a crashed fog
        // (its worker withholds the reply), so route the batch through
        // the tagged pipeline: hedged re-dispatch and the task
        // deadline live in `BspPipeline::collect`. Submitting then
        // immediately collecting keeps barrier semantics (one batch in
        // flight), so accounting is unchanged.
        if self.chaos_cfg.is_some() {
            self.submit_batch(bucket);
            return self.collect_batch();
        }
        self.mark_window_start();
        let res = self.plan.execute_timings_traced(
            &self.features,
            self.f_in,
            &self.wb,
            bucket,
            self.trace.as_ref(),
        );
        self.account(res, bucket)
    }

    /// Shared post-execution accounting for both execution paths
    /// (barrier `run_batch` and pipelined `collect_batch`): histograms,
    /// bucket stats, profiler observations and occupancy bookkeeping.
    fn account(&mut self, res: BspResult,
               bucket: usize) -> Vec<Vec<f64>> {
        let mut barrier = 0f64;
        for layer_times in &res.layer_host_seconds {
            barrier +=
                layer_times.iter().cloned().fold(0f64, f64::max);
        }
        let mut wait_barrier = 0f64;
        for layer_waits in &res.layer_queue_wait_seconds {
            wait_barrier +=
                layer_waits.iter().cloned().fold(0f64, f64::max);
        }
        if let Some(tr) = &self.trace {
            let reg = tr.rec.registry();
            reg.histogram("measured_kernel_barrier_ms")
                .record(barrier * 1e3);
            reg.histogram("measured_queue_wait_ms")
                .record(wait_barrier * 1e3);
        }
        let stat = self.bucket_stats.entry(bucket).or_default();
        stat.total_host_s += barrier;
        stat.total_queue_wait_s += wait_barrier;
        stat.batches += 1;
        for j in 0..self.plan.n_fogs() {
            let (v, ne) = self.plan.cardinality(j);
            if v == 0 {
                continue;
            }
            let total_j: f64 = res
                .layer_host_seconds
                .iter()
                .map(|lt| lt[j])
                .sum();
            self.busy_s[j] += total_j;
            // ω predicts single-inference latency; the batch amortizes
            // fixed costs, so consume the per-request share (the same
            // seconds the recorder's wall kernel spans carry)
            self.profilers[j].consume(Observation::new(
                Cardinality::new(v, ne),
                total_j / bucket as f64,
            ));
        }
        if let Some(t0) = self.window_start {
            self.window_s = t0.elapsed().as_secs_f64();
        }
        res.layer_host_seconds
    }

    fn mark_window_start(&mut self) {
        if self.window_start.is_none() {
            self.window_start = Some(Instant::now());
        }
    }

    /// Switch the executor to pipelined submission with up to `depth`
    /// micro-batches in flight (`--pipeline-depth`). Depth 1 keeps the
    /// classic barrier path (`run_batch`) and is bit-identical to not
    /// calling this at all; 0 and absurd depths are errors so the CLI
    /// can exit 2. Must not be called with batches in flight.
    pub fn set_pipeline_depth(&mut self,
                              depth: usize) -> Result<(), String> {
        if depth == 0 || depth > MAX_PIPELINE_DEPTH {
            return Err(format!(
                "pipeline depth must be in 1..={MAX_PIPELINE_DEPTH} \
                 (got {depth})"
            ));
        }
        assert!(
            self.inflight_buckets.is_empty(),
            "cannot change pipeline depth with batches in flight"
        );
        self.pipeline_depth = depth;
        self.pipeline = if depth > 1 {
            Some(BspPipeline::new(self.plan.n_fogs(), depth, false))
        } else {
            None
        };
        Ok(())
    }

    /// The configured `--pipeline-depth`.
    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }

    /// Apply (or refresh) chaos masks: per-fog `crashed` flags (the
    /// worker withholds its reply — the exact dead-node signature),
    /// per-fog `speed` multipliers in (0, 1] (1.0 = healthy), and the
    /// task deadline in seconds after which an unanswered `(batch,
    /// layer, fog)` task is hedged to another fog. Lazily creates a
    /// depth-1 pipeline when the executor is still on the barrier
    /// path, because fault injection needs tagged tasks. Must not be
    /// called with batches in flight.
    pub fn set_chaos(&mut self, crashed: Vec<bool>, speed: Vec<f64>,
                     task_deadline_s: f64) {
        assert!(
            self.inflight_buckets.is_empty(),
            "cannot change chaos masks with batches in flight"
        );
        let n = self.plan.n_fogs();
        assert_eq!(crashed.len(), n, "crashed mask length");
        assert_eq!(speed.len(), n, "speed mask length");
        if self.pipeline.is_none() {
            self.pipeline = Some(BspPipeline::new(n, 1, false));
        }
        let pipe = self.pipeline.as_mut().unwrap();
        pipe.set_chaos(Some(PipelineChaos {
            crashed: crashed.clone(),
            speed: speed.clone(),
        }));
        pipe.set_task_deadline(task_deadline_s);
        self.chaos_cfg = Some((crashed, speed, task_deadline_s));
    }

    /// Cumulative hedge (wins, waste) across the whole run, including
    /// pipelines retired by replan rebuilds.
    pub fn hedge_stats(&self) -> (u64, u64) {
        let (mut w, mut l) = self.hedge_acc;
        if let Some(pipe) = &self.pipeline {
            let (pw, pl) = pipe.hedge_stats();
            w += pw;
            l += pl;
        }
        (w, l)
    }

    /// Batches submitted but not yet collected (0 on the barrier
    /// path).
    pub fn pending(&self) -> usize {
        self.inflight_buckets.len()
    }

    /// Submit one micro-batch into the pipeline without waiting for
    /// its result — batch N+1's collection/compression on the fabric
    /// thread overlaps batch N's kernels. The caller must keep
    /// `pending() < pipeline_depth()` by collecting (the blocking wait
    /// is the backpressure the fabric accounts as `pipeline_stall`).
    pub fn submit_batch(&mut self, bucket: usize) {
        self.mark_window_start();
        let pipe = self
            .pipeline
            .as_mut()
            .expect("submit_batch requires pipeline depth > 1");
        pipe.submit(&self.plan, &self.features, self.f_in, &self.wb,
                    bucket, self.trace.as_ref());
        self.inflight_buckets.push_back(bucket);
    }

    /// Drain worker replies that are already waiting (non-blocking),
    /// keeping the workers fed while the fabric thread is between
    /// batches.
    pub fn pump(&mut self) {
        if let Some(pipe) = self.pipeline.as_mut() {
            pipe.pump(&self.plan, self.trace.as_ref());
        }
    }

    /// Block until the OLDEST in-flight batch completes and account it
    /// exactly like `run_batch` does; returns its measured
    /// `layer_host_seconds[layer][fog]`.
    pub fn collect_batch(&mut self) -> Vec<Vec<f64>> {
        let bucket = self
            .inflight_buckets
            .pop_front()
            .expect("collect_batch with no batch in flight");
        let pipe = self
            .pipeline
            .as_mut()
            .expect("pipelined batch in flight without a pipeline");
        let res = pipe.collect(&self.plan, self.trace.as_ref());
        self.account(res, bucket)
    }

    /// Per-fog pipeline occupancy: cumulative measured kernel seconds
    /// divided by the wall window from first batch submission to last
    /// collection. Near 1.0 means the fog's kernels never starved
    /// while the run was in progress; empty fogs report 0.
    pub fn pipeline_occupancy(&self) -> Vec<f64> {
        if self.window_s <= 0.0 {
            return vec![0.0; self.busy_s.len()];
        }
        self.busy_s
            .iter()
            .map(|&b| (b / self.window_s).min(1.0))
            .collect()
    }

    /// Per-fog cumulative kernel seconds and the occupancy wall
    /// window, for merging occupancy across services that share a
    /// run (the fabric sums busy over a common window).
    pub fn busy_window(&self) -> (&[f64], f64) {
        (&self.busy_s, self.window_s)
    }

    /// η-scaled ω′ per fog — what diffusion / IEP replans consume in
    /// place of the analytic omegas.
    pub fn scaled_omegas(&self) -> Vec<PerfModel> {
        self.profilers.iter().map(|p| p.scaled_model()).collect()
    }

    /// Re-extract partition structures after a migration (profilers,
    /// bucket stats and the kernel-thread budget carry over; η is a
    /// node property, not a placement property). The worker pool is
    /// reused — a replan never respawns a thread — UNLESS a worker
    /// panic poisoned it, in which case the rebuild spawns a fresh
    /// pool ("rebuild the plan" stays the documented recovery path).
    pub fn rebuild(&mut self, g: &Graph, assignment: &[u32],
                   model: &str) -> Result<(), EngineError> {
        assert!(
            self.inflight_buckets.is_empty(),
            "drain the pipeline (collect all batches) before a replan \
             rebuild"
        );
        let pool = self.plan.pool_handle();
        self.plan = if pool.is_poisoned() {
            BatchedBspPlan::with_threads(
                g,
                assignment,
                self.plan.n_fogs(),
                model,
                self.kernel_threads,
            )?
        } else {
            BatchedBspPlan::with_shared_pool(
                g,
                assignment,
                self.plan.n_fogs(),
                model,
                self.kernel_threads,
                pool,
            )?
        };
        // fresh rings for the new plan: keeps each ring single-writer
        // even when a poisoned pool forced a worker respawn
        if let Some(tr) = &self.trace {
            let rec = tr.rec.clone();
            let tenant = tr.tenant;
            self.trace =
                Some(ExecTrace::new(&rec, self.plan.n_fogs(), tenant));
        }
        // fresh pipeline over the new plan (tag queues and reply
        // channel must not straddle a re-extraction); hedge totals
        // from the retired pipeline survive in the accumulator
        if let Some(pipe) = &self.pipeline {
            let (w, l) = pipe.hedge_stats();
            self.hedge_acc.0 += w;
            self.hedge_acc.1 += l;
        }
        if self.pipeline_depth > 1 || self.chaos_cfg.is_some() {
            self.pipeline = Some(BspPipeline::new(
                self.plan.n_fogs(),
                self.pipeline_depth.max(1),
                false,
            ));
        } else {
            self.pipeline = None;
        }
        if let Some((crashed, speed, dl)) = self.chaos_cfg.clone() {
            let pipe = self.pipeline.as_mut().unwrap();
            pipe.set_chaos(Some(PipelineChaos { crashed, speed }));
            pipe.set_task_deadline(dl);
        }
        Ok(())
    }

    /// Handle to the worker pool (for sharing with further plans).
    pub fn pool_handle(&self) -> Arc<crate::runtime::FogWorkerPool> {
        self.plan.pool_handle()
    }

    /// Measured per-bucket rows, smallest bucket first.
    pub fn bucket_summary(&self) -> Vec<BucketRow> {
        self.bucket_stats
            .iter()
            .map(|(&b, st)| BucketRow {
                bucket: b,
                mean_host_ms: st.mean_ms(),
                mean_queue_wait_ms: st.mean_queue_wait_ms(),
                batches: st.batches,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::runtime::EngineKind;

    #[test]
    fn measured_exec_runs_and_profiles() {
        let (mut g, _) = generate::sbm(200, 900, 4, 0.85, 3);
        let f_in = 8;
        let mut rng = crate::util::rng::Rng::new(17);
        g.features =
            (0..200 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = f_in;
        let dir = std::env::temp_dir().join("measured_exec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let assignment: Vec<u32> =
            (0..200).map(|v| (v % 2) as u32).collect();
        let omegas = vec![PerfModel::uncalibrated(); 2];
        let mut me = MeasuredExec::new(
            &g, &assignment, 2, "gcn", "tiny", &g.features, f_in, 3,
            &omegas, &mut eng, 2,
        )
        .unwrap();
        assert_eq!(me.kernel_threads(), 2);
        let lhs = me.run_batch(4);
        assert_eq!(lhs.len(), 2, "gcn has 2 layers");
        assert_eq!(lhs[0].len(), 2, "one timing per fog");
        assert!(lhs.iter().flatten().all(|&s| s >= 0.0));
        let summary = me.bucket_summary();
        assert_eq!(summary.len(), 1);
        assert_eq!(summary[0].bucket, 4);
        assert_eq!(summary[0].batches, 1);
        assert!(summary[0].mean_host_ms >= 0.0);
        assert!(summary[0].mean_queue_wait_ms >= 0.0);
        // profilers observed the run: scaled models exist per fog
        let scaled = me.scaled_omegas();
        assert_eq!(scaled.len(), 2);
        assert!(scaled.iter().all(|m| m.beta_v >= 0.0));
    }

    #[test]
    fn attached_recorder_captures_kernel_spans() {
        use crate::obs::clock::ClockMode;
        use crate::obs::span::Phase;
        let (mut g, _) = generate::sbm(120, 500, 3, 0.85, 3);
        let f_in = 8;
        let mut rng = crate::util::rng::Rng::new(23);
        g.features =
            (0..120 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = f_in;
        let dir = std::env::temp_dir().join("measured_exec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let assignment: Vec<u32> =
            (0..120).map(|v| (v % 2) as u32).collect();
        let omegas = vec![PerfModel::uncalibrated(); 2];
        let mut me = MeasuredExec::new(
            &g, &assignment, 2, "gcn", "tiny", &g.features, f_in, 3,
            &omegas, &mut eng, 1,
        )
        .unwrap();
        let rec = Recorder::enabled(ClockMode::Wall);
        me.attach_recorder(&rec, 0);
        me.run_batch(4);
        let evs = rec.events();
        // 2 gcn layers × 2 fogs
        let kernels = evs
            .iter()
            .filter(|e| e.phase == Phase::Kernel && e.wall)
            .count();
        assert_eq!(kernels, 4);
        let syncs = evs
            .iter()
            .filter(|e| e.phase == Phase::Sync && e.wall)
            .count();
        assert_eq!(syncs, 2, "one halo-sync span per layer");
        assert!(evs
            .iter()
            .all(|e| e.dur_us >= 0.0 && e.tenant == 0));
        assert_eq!(
            rec.registry()
                .histogram("measured_kernel_barrier_ms")
                .count(),
            1
        );
        // rebuild keeps tracing alive on fresh rings
        me.rebuild(&g, &assignment, "gcn").unwrap();
        me.run_batch(4);
        let kernels2 = rec
            .events()
            .iter()
            .filter(|e| e.phase == Phase::Kernel && e.wall)
            .count();
        assert_eq!(kernels2, 8);
    }

    #[test]
    fn measured_exec_serves_astgcn() {
        let (mut g, _) = generate::sbm(50, 200, 2, 0.8, 5);
        let ft = 24;
        let mut rng = crate::util::rng::Rng::new(29);
        g.features =
            (0..50 * ft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = ft;
        let dir = std::env::temp_dir().join("measured_exec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let assignment: Vec<u32> =
            (0..50).map(|v| (v % 2) as u32).collect();
        let omegas = vec![PerfModel::uncalibrated(); 2];
        let mut me = MeasuredExec::new(
            &g, &assignment, 2, "astgcn", "tinypems", &g.features, ft,
            0, &omegas, &mut eng, 1,
        )
        .unwrap();
        let lhs = me.run_batch(2);
        assert_eq!(lhs.len(), 1, "astgcn has 1 layer");
        assert_eq!(lhs[0].len(), 2, "one timing per fog");
        assert!(lhs.iter().flatten().all(|&s| s >= 0.0));
        assert_eq!(me.bucket_summary().len(), 1);
    }

    /// The pipelined submission path must account batches exactly like
    /// `run_batch` (bucket stats, profilers, occupancy window) while
    /// keeping up to `depth` batches in flight.
    #[test]
    fn pipelined_submission_accounts_like_run_batch() {
        let (mut g, _) = generate::sbm(200, 900, 4, 0.85, 3);
        let f_in = 8;
        let mut rng = crate::util::rng::Rng::new(19);
        g.features =
            (0..200 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = f_in;
        let dir = std::env::temp_dir().join("measured_exec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let assignment: Vec<u32> =
            (0..200).map(|v| (v % 2) as u32).collect();
        let omegas = vec![PerfModel::uncalibrated(); 2];
        let mut me = MeasuredExec::new(
            &g, &assignment, 2, "gcn", "tiny", &g.features, f_in, 3,
            &omegas, &mut eng, 1,
        )
        .unwrap();
        assert!(me.set_pipeline_depth(0).is_err());
        assert!(me.set_pipeline_depth(99).is_err());
        me.set_pipeline_depth(2).unwrap();
        assert_eq!(me.pipeline_depth(), 2);
        // window full → collect before each further submit
        let total = 5;
        let mut collected = Vec::new();
        for _ in 0..total {
            if me.pending() == 2 {
                collected.push(me.collect_batch());
            }
            me.submit_batch(4);
            me.pump();
        }
        while me.pending() > 0 {
            collected.push(me.collect_batch());
        }
        assert_eq!(collected.len(), total);
        for lhs in &collected {
            assert_eq!(lhs.len(), 2, "gcn has 2 layers");
            assert_eq!(lhs[0].len(), 2, "one timing per fog");
        }
        let summary = me.bucket_summary();
        assert_eq!(summary.len(), 1);
        assert_eq!(summary[0].bucket, 4);
        assert_eq!(summary[0].batches, total);
        let occ = me.pipeline_occupancy();
        assert_eq!(occ.len(), 2);
        assert!(occ.iter().all(|&o| (0.0..=1.0).contains(&o)));
        let (busy, window) = me.busy_window();
        assert_eq!(busy.len(), 2);
        assert!(window > 0.0);
        // rebuild with a drained pipeline recreates it cleanly
        me.rebuild(&g, &assignment, "gcn").unwrap();
        me.submit_batch(4);
        me.collect_batch();
        assert_eq!(me.bucket_summary()[0].batches, total + 1);
        // depth 1 reverts to the barrier path
        me.set_pipeline_depth(1).unwrap();
        me.run_batch(4);
        assert_eq!(me.bucket_summary()[0].batches, total + 2);
    }
}
