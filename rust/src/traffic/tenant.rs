//! Tenant declarations for the multi-tenant serving fabric: one
//! `TenantSpec` per `--tenant` CLI flag (repeatable), each naming a
//! workload — model, dataset, arrival process, offered rate, fair-share
//! weight, latency objective — that shares the fog cluster with every
//! other tenant. Unset fields inherit the legacy single-tenant flags,
//! so `--tenant model=sage,rps=50` rides on the same `--arrival`,
//! `--slo-ms` and `--queue-cap` the run was given.
//!
//! Identity discipline: every derived quantity (per-tenant stream
//! seeds, scheduling tie-breaks, report ordering) keys off the tenant
//! NAME, never the declaration position, so an N-tenant run is
//! invariant under reordering its `--tenant` flags — asserted by the
//! fabric property tests.

use super::arrival::ArrivalKind;
use super::sim::TrafficConfig;
use crate::util::rng::mix64;

/// How the fabric arbitrates released batches between tenants
/// competing for the shared execution station.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FairPolicy {
    /// Deficit-round-robin weighted-fair queuing: each tenant earns
    /// service credit in proportion to its weight, so one tenant's
    /// burst cannot starve another's SLO (the default).
    #[default]
    Drr,
    /// Shared-FIFO control: always serve the tenant whose oldest
    /// queued request arrived first, weights ignored — the baseline a
    /// fairness claim must beat.
    Fifo,
}

impl FairPolicy {
    pub fn parse(s: &str) -> Option<FairPolicy> {
        match s {
            "drr" | "wfq" => Some(FairPolicy::Drr),
            "fifo" => Some(FairPolicy::Fifo),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FairPolicy::Drr => "drr",
            FairPolicy::Fifo => "fifo",
        }
    }
}

/// One `--tenant` declaration, fields optional where a legacy flag
/// provides the default.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TenantSpec {
    pub name: Option<String>,
    pub model: Option<String>,
    pub dataset: Option<String>,
    pub arrival: Option<ArrivalKind>,
    pub rps: Option<f64>,
    /// Fair-share weight (DRR credit rate). Defaults to 1.
    pub weight: Option<f64>,
    pub slo_s: Option<f64>,
    /// Explicit arrival-stream seed; defaults to a stable mix of the
    /// run seed and the tenant name.
    pub seed: Option<u64>,
    pub queue_cap: Option<usize>,
}

impl TenantSpec {
    /// Parse one `--tenant` value: comma-separated `key=value` pairs.
    /// Recognized keys: `name`, `model`, `dataset`, `arrival`, `rps`,
    /// `weight`, `slo-ms`, `seed`, `queue-cap`. Malformed specs —
    /// unknown or duplicate keys, non-numeric numbers, zero or
    /// negative `weight`/`rps`/`slo-ms` — are errors the CLI turns
    /// into exit code 2.
    pub fn parse(spec: &str) -> Result<TenantSpec, String> {
        let mut out = TenantSpec::default();
        let mut seen: Vec<&str> = Vec::new();
        if spec.trim().is_empty() {
            return Err("empty --tenant spec".to_string());
        }
        for part in spec.split(',') {
            let part = part.trim();
            let (key, value) = part.split_once('=').ok_or_else(|| {
                format!(
                    "--tenant field {part:?} is not key=value \
                     (expected e.g. model=gcn,rps=100,weight=2)"
                )
            })?;
            if seen.contains(&key) {
                return Err(format!(
                    "--tenant field {key:?} given twice in {spec:?}"
                ));
            }
            let bad_num = |what: &str| {
                format!(
                    "--tenant {key}={value:?} is not a valid {what}"
                )
            };
            match key {
                "name" => {
                    if value.is_empty() {
                        return Err(
                            "--tenant name= must not be empty".into()
                        );
                    }
                    out.name = Some(value.to_string());
                }
                "model" => out.model = Some(value.to_string()),
                "dataset" => out.dataset = Some(value.to_string()),
                "arrival" => {
                    out.arrival =
                        Some(ArrivalKind::parse(value).ok_or_else(
                            || {
                                format!(
                                    "--tenant arrival={value:?} \
                                     (expected \
                                     poisson|bursty|diurnal)"
                                )
                            },
                        )?)
                }
                "rps" => {
                    let v: f64 = value
                        .parse()
                        .map_err(|_| bad_num("rate"))?;
                    if !v.is_finite() || v <= 0.0 {
                        return Err(format!(
                            "--tenant rps must be positive and \
                             finite (got {value})"
                        ));
                    }
                    out.rps = Some(v);
                }
                "weight" => {
                    let v: f64 = value
                        .parse()
                        .map_err(|_| bad_num("weight"))?;
                    if !v.is_finite() || v <= 0.0 {
                        return Err(format!(
                            "--tenant weight must be positive and \
                             finite (got {value}); a zero-weight \
                             tenant would never be scheduled"
                        ));
                    }
                    out.weight = Some(v);
                }
                "slo-ms" => {
                    let v: f64 = value
                        .parse()
                        .map_err(|_| bad_num("latency bound"))?;
                    if !v.is_finite() || v <= 0.0 {
                        return Err(format!(
                            "--tenant slo-ms must be positive and \
                             finite (got {value})"
                        ));
                    }
                    out.slo_s = Some(v / 1e3);
                }
                "seed" => {
                    out.seed = Some(
                        value.parse().map_err(|_| bad_num("seed"))?,
                    )
                }
                "queue-cap" => {
                    let v: usize = value
                        .parse()
                        .map_err(|_| bad_num("queue bound"))?;
                    if v == 0 {
                        return Err(
                            "--tenant queue-cap must be >= 1".into()
                        );
                    }
                    out.queue_cap = Some(v);
                }
                _ => {
                    return Err(format!(
                        "unknown --tenant field {key:?} (expected \
                         name|model|dataset|arrival|rps|weight|\
                         slo-ms|seed|queue-cap)"
                    ))
                }
            }
            seen.push(key);
        }
        Ok(out)
    }

    /// Fill the unset fields from the legacy single-tenant flags and
    /// produce the runnable tenant. `default_model`/`default_dataset`
    /// are the run-level `--model`/`--dataset`.
    pub fn resolve(&self, base: &TrafficConfig, default_model: &str,
                   default_dataset: &str) -> Tenant {
        let model = self
            .model
            .clone()
            .unwrap_or_else(|| default_model.to_string());
        let dataset = self
            .dataset
            .clone()
            .unwrap_or_else(|| default_dataset.to_string());
        let name = self
            .name
            .clone()
            .unwrap_or_else(|| format!("{model}-{dataset}"));
        let stream_seed = self
            .seed
            .unwrap_or_else(|| tenant_stream_seed(base.seed, &name));
        Tenant {
            name,
            model,
            dataset,
            arrival: self.arrival.unwrap_or(base.arrival),
            rps: self.rps.unwrap_or(base.rps),
            weight: self.weight.unwrap_or(1.0),
            slo_s: self.slo_s.unwrap_or(base.slo_s),
            stream_seed,
            queue_cap: self.queue_cap.unwrap_or(base.queue_cap),
        }
    }
}

/// A fully-resolved tenant the fabric runs.
#[derive(Clone, Debug, PartialEq)]
pub struct Tenant {
    pub name: String,
    pub model: String,
    pub dataset: String,
    pub arrival: ArrivalKind,
    /// Mean offered load, requests/second.
    pub rps: f64,
    /// Fair-share weight (DRR credit rate).
    pub weight: f64,
    /// This tenant's end-to-end latency objective.
    pub slo_s: f64,
    /// Seed of this tenant's arrival stream (identity-derived unless
    /// the spec pinned one), so runs are invariant under `--tenant`
    /// reordering.
    pub stream_seed: u64,
    /// Admission bound on this tenant's wait queue (requests; the
    /// fabric enforces at least one full batch, like the legacy
    /// `effective_queue_cap`).
    pub queue_cap: usize,
}

impl Tenant {
    /// The legacy single-stream flags as a one-tenant fabric: the
    /// stream seed is the run seed itself (NOT name-mixed), so a
    /// one-tenant fabric run replays the exact request stream the
    /// pre-fabric loop generated for the same `--seed`.
    pub fn legacy(base: &TrafficConfig, model: &str,
                  dataset: &str) -> Tenant {
        Tenant {
            name: "default".to_string(),
            model: model.to_string(),
            dataset: dataset.to_string(),
            arrival: base.arrival,
            rps: base.rps,
            weight: 1.0,
            slo_s: base.slo_s,
            stream_seed: base.seed,
            queue_cap: base.queue_cap,
        }
    }

    /// A tenant named `name` riding on the legacy flag defaults, with
    /// the stream seed derived from the NAME (the identity
    /// discipline). Use this — not `legacy(..)` plus a `name`
    /// mutation, which would leave `stream_seed` stale and silently
    /// correlate two tenants' arrival streams.
    pub fn named(base: &TrafficConfig, name: &str, model: &str,
                 dataset: &str) -> Tenant {
        Tenant {
            name: name.to_string(),
            stream_seed: tenant_stream_seed(base.seed, name),
            ..Tenant::legacy(base, model, dataset)
        }
    }
}

/// The canonical burst-fairness scenario: a bursty, throughput-
/// oriented high-weight tenant saturating the cluster (offered 2.5×
/// the probed capacity, 4:1 weight, lenient 5 s SLO, ~1.2 s of queue)
/// against a latency-sensitive low-weight Poisson tenant at ~8% of
/// capacity with a 600 ms SLO. ONE definition, shared by the loadtest
/// experiment's DRR-vs-FIFO table and the fairness integration test,
/// so the reported numbers and the asserted property can never drift
/// onto different scenarios. `cap` is the measured service capacity
/// (completions/second) from a saturating single-tenant probe run.
pub fn burst_fairness_pair(base: &TrafficConfig, cap: f64,
                           hi_model: &str, lo_model: &str,
                           dataset: &str) -> (Tenant, Tenant) {
    let mut hi = Tenant::named(base, "hi-burst", hi_model, dataset);
    hi.arrival = ArrivalKind::Bursty;
    hi.rps = 2.5 * cap;
    hi.weight = 4.0;
    hi.slo_s = 5.0;
    hi.queue_cap = (1.2 * cap).ceil() as usize;
    let mut lo = Tenant::named(base, "lo-steady", lo_model, dataset);
    lo.rps = (0.08 * cap).max(20.0);
    lo.weight = 1.0;
    lo.slo_s = 0.6;
    (hi, lo)
}

/// FNV-1a over the tenant name — a stable, dependency-free identity
/// hash (NOT `DefaultHasher`, whose output may change across rustc
/// releases and would silently re-seed every recorded run).
pub fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Per-tenant arrival-stream seed: a stable mix of the run seed and
/// the tenant identity. Declaration order never enters.
pub fn tenant_stream_seed(run_seed: u64, name: &str) -> u64 {
    mix64(run_seed ^ fnv1a(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_spec() {
        let t = TenantSpec::parse(
            "name=hi,model=gcn,dataset=siot,arrival=bursty,rps=300,\
             weight=4,slo-ms=150,seed=9,queue-cap=128",
        )
        .unwrap();
        assert_eq!(t.name.as_deref(), Some("hi"));
        assert_eq!(t.model.as_deref(), Some("gcn"));
        assert_eq!(t.dataset.as_deref(), Some("siot"));
        assert_eq!(t.arrival, Some(ArrivalKind::Bursty));
        assert_eq!(t.rps, Some(300.0));
        assert_eq!(t.weight, Some(4.0));
        assert_eq!(t.slo_s, Some(0.15));
        assert_eq!(t.seed, Some(9));
        assert_eq!(t.queue_cap, Some(128));
    }

    #[test]
    fn malformed_specs_are_errors() {
        for bad in [
            "",
            "model",                    // not key=value
            "model=gcn,model=sage",     // duplicate key
            "weight=0",                 // zero weight
            "weight=-1",
            "weight=abc",
            "rps=0",
            "rps=inf",
            "slo-ms=0",
            "queue-cap=0",
            "arrival=weekly",
            "name=",
            "color=blue",               // unknown key
        ] {
            assert!(TenantSpec::parse(bad).is_err(),
                    "{bad:?} accepted");
        }
    }

    #[test]
    fn resolve_inherits_legacy_flags() {
        let base = TrafficConfig::default();
        let t = TenantSpec::parse("model=sage,rps=50")
            .unwrap()
            .resolve(&base, "gcn", "siot");
        assert_eq!(t.name, "sage-siot");
        assert_eq!(t.model, "sage");
        assert_eq!(t.dataset, "siot");
        assert_eq!(t.arrival, base.arrival);
        assert_eq!(t.rps, 50.0);
        assert_eq!(t.weight, 1.0);
        assert_eq!(t.slo_s, base.slo_s);
        assert_eq!(t.queue_cap, base.queue_cap);
        // identity-derived stream seed, stable and name-keyed
        assert_eq!(t.stream_seed,
                   tenant_stream_seed(base.seed, "sage-siot"));
    }

    #[test]
    fn stream_seeds_are_identity_keyed() {
        let a = tenant_stream_seed(7, "alpha");
        assert_eq!(a, tenant_stream_seed(7, "alpha"));
        assert_ne!(a, tenant_stream_seed(7, "beta"));
        assert_ne!(a, tenant_stream_seed(8, "alpha"));
        // legacy mapping uses the raw run seed, not the mix
        let base = TrafficConfig::default();
        let t = Tenant::legacy(&base, "gcn", "siot");
        assert_eq!(t.stream_seed, base.seed);
        assert_eq!(t.name, "default");
        // the named constructor derives the seed from the name
        let a = Tenant::named(&base, "alpha", "gcn", "siot");
        assert_eq!(a.name, "alpha");
        assert_eq!(a.stream_seed,
                   tenant_stream_seed(base.seed, "alpha"));
        assert_ne!(a.stream_seed,
                   Tenant::named(&base, "beta", "gcn", "siot")
                       .stream_seed);
    }

    #[test]
    fn burst_fairness_pair_is_the_canonical_scenario() {
        let base = TrafficConfig::default();
        let (hi, lo) = burst_fairness_pair(&base, 500.0, "gcn",
                                           "sage", "siot");
        assert_eq!(hi.name, "hi-burst");
        assert_eq!(lo.name, "lo-steady");
        assert_eq!(hi.arrival, ArrivalKind::Bursty);
        assert_eq!(hi.rps, 2.5 * 500.0);
        assert_eq!((hi.weight, lo.weight), (4.0, 1.0));
        assert!(hi.slo_s > lo.slo_s);
        assert_eq!(hi.queue_cap, 600);
        assert_eq!(lo.rps, 40.0);
        // independent identity-derived streams
        assert_ne!(hi.stream_seed, lo.stream_seed);
        assert_eq!(hi.stream_seed,
                   tenant_stream_seed(base.seed, "hi-burst"));
        // tiny probed capacity: the low tenant keeps a sane floor
        let (_, lo2) =
            burst_fairness_pair(&base, 60.0, "gcn", "sage", "siot");
        assert_eq!(lo2.rps, 20.0);
    }

    #[test]
    fn fair_policy_parses() {
        assert_eq!(FairPolicy::parse("drr"), Some(FairPolicy::Drr));
        assert_eq!(FairPolicy::parse("wfq"), Some(FairPolicy::Drr));
        assert_eq!(FairPolicy::parse("fifo"), Some(FairPolicy::Fifo));
        assert_eq!(FairPolicy::parse("edf"), None);
        assert_eq!(FairPolicy::Drr.name(), "drr");
        assert_eq!(FairPolicy::Fifo.name(), "fifo");
    }
}
