//! Event-driven online serving loop: drives the existing serving
//! pipeline with a sustained, seeded request stream through admission
//! control, adaptive micro-batching and the dual-mode scheduler.
//!
//! One real end-to-end run of the pipeline (per layout) exercises the
//! full serving surface (placement, compression, BSP execution, the OOM
//! check). The loop then prices execution in one of two modes
//! (`ExecMode`):
//!
//! * **analytic** (default) — per-fog execution from the calibratable ω
//!   models (`profile::PerfModel`), the analytic transfer share of
//!   collection and the analytic sync cost — exactly the quantities the
//!   scheduler reasons about (as in the Fig. 16 experiment). Every
//!   reported number is a pure function of `(inputs, seed)`: analytic
//!   loadtest runs are bit-reproducible.
//! * **measured** — every released micro-batch executes the real sparse
//!   CSR batched BSP kernels at its padded bucket size
//!   (`traffic::measured`), per-fog compute on the persistent worker
//!   pool (`runtime::kernels::pool`); measured timings feed the online
//!   profiler so diffusion / IEP replans use η-scaled OBSERVED costs
//!   (ω′) instead of ω. Wall-clock measurements are inherently
//!   non-deterministic.
//!
//! Stations and timing model:
//!
//! * **collection** — one snapshot upload per micro-batch window; the
//!   batch shares it, so collection cost grows only mildly with batch
//!   size (devices stream once per window, §III-D).
//! * **execution**  — BSP over all fogs: the batch finishes when the
//!   slowest fog finishes. Batching amortizes the per-inference fixed
//!   overhead; a batch pays for its padded power-of-two *bucket*
//!   (`batcher::bucket`), mirroring the lowered-artifact shapes.
//! * the two stations pipeline with depth 2 (collection of batch k
//!   overlaps execution of batch k-1), the paper's throughput model.
//!
//! Admission control sheds (or spills to the cloud tier) when the wait
//! queue exceeds its bound; per-fog queue depths in work-seconds feed the
//! skew indicators, so diffusion / IEP replans fire mid-run when the
//! background load tilts the cluster.

use crate::fog::{Cluster, LoadTrace};
use crate::graph::{DatasetSpec, Graph};
use crate::profile::PerfModel;
use crate::runtime::{Engine, EngineError};
use crate::scheduler::{schedule, SchedulerConfig, SchedulerDecision};
use crate::scheduler::diffusion::estimate_times;
use crate::serving::collection;
use crate::serving::pipeline::{self, Placement, ServeOpts};
use crate::util::json::{arr, num, obj, s, Json};

use super::arrival::{ArrivalKind, ArrivalProcess};
use super::batcher::{bucket, BatchPolicy, MicroBatcher};
use super::measured::{BucketRow, MeasuredExec};
use super::slo::{QueueTimeline, SloReport};

/// How the loop prices per-batch execution (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// ω-model execution costs; bit-reproducible for a fixed seed.
    #[default]
    Analytic,
    /// Real CSR batched kernel execution, measured per batch.
    Measured,
}

impl ExecMode {
    pub fn parse(s: &str) -> Option<ExecMode> {
        match s {
            "analytic" => Some(ExecMode::Analytic),
            "measured" => Some(ExecMode::Measured),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Analytic => "analytic",
            ExecMode::Measured => "measured",
        }
    }
}

/// Fraction of a batch's execution cost that is fixed per batch (kernel
/// launch, BSP barriers); the rest scales with the padded bucket size.
const EXEC_FIXED_FRAC: f64 = 0.85;
/// Fixed share of the per-window collection cost; the rest grows with
/// batch fill (larger windows admit marginally more device traffic).
const COLL_FIXED_FRAC: f64 = 0.85;
/// Collection of batch k may overlap execution of batch k-1.
const PIPELINE_DEPTH: usize = 2;

#[derive(Clone, Copy, Debug)]
pub struct TrafficConfig {
    pub arrival: ArrivalKind,
    /// Mean offered load, requests/second.
    pub rps: f64,
    /// Offered-traffic window (simulation seconds); the loop drains
    /// queued work past this point.
    pub duration_s: f64,
    pub seed: u64,
    /// End-to-end latency objective.
    pub slo_s: f64,
    pub batch: BatchPolicy,
    /// Admission bound on the wait queue (requests).
    pub queue_cap: usize,
    /// Spill over-bound requests to the cloud tier instead of dropping.
    pub spill: bool,
    /// Dual-mode scheduler period (simulation seconds); 0 disables.
    pub scheduler_period_s: f64,
    /// Replay a background-load trace over the fogs.
    pub background_load: bool,
    /// Analytic ω-model pricing (default) or measured per-batch kernel
    /// execution.
    pub exec: ExecMode,
    /// Worker-group width the largest fog partition gets in measured
    /// mode (`--kernel-threads`; 1 = no intra-fog sharding). Analytic
    /// pricing ignores it.
    pub kernel_threads: usize,
}

impl TrafficConfig {
    /// The admission bound the loop actually enforces: never below one
    /// full batch, or the batcher could starve.
    pub fn effective_queue_cap(&self) -> usize {
        self.queue_cap.max(self.batch.max_batch)
    }
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            arrival: ArrivalKind::Poisson,
            rps: 100.0,
            duration_s: 30.0,
            seed: 0xF06,
            slo_s: 1.0,
            batch: BatchPolicy::default(),
            // bound the worst-case admission wait near SLO/2 at the
            // cluster's typical service rate (see sim tests)
            queue_cap: 64,
            spill: false,
            scheduler_period_s: 5.0,
            background_load: true,
            exec: ExecMode::Analytic,
            kernel_threads: 1,
        }
    }
}

/// Outcome of one loadtest run.
#[derive(Clone, Debug, Default)]
pub struct LoadtestReport {
    pub slo: SloReport,
    /// Raw per-request fog-tier latencies (seconds, completion order).
    pub latencies: Vec<f64>,
    /// Busy fraction of the execution station over the run.
    pub exec_utilization: f64,
    /// Wait-queue length extremes (requests).
    pub queue_len_max: usize,
    pub queue_len_mean: f64,
    /// Communication constants from the grounding pipeline run.
    pub base_collection_s: f64,
    pub base_sync_s: f64,
    pub base_wire_bytes: usize,
    /// Execution pricing mode the run used.
    pub exec_mode: ExecMode,
    /// Engine behind the run ("csr-batched" for measured mode, else
    /// the analytic model over the grounding engine).
    pub engine: String,
    /// Measured per-bucket rows (kernel ms and pool queue-wait ms
    /// separated) — empty in analytic mode.
    pub bucket_host_ms: Vec<BucketRow>,
    /// Worker-group width the measured pool was built with (1 in
    /// analytic mode).
    pub kernel_threads: usize,
    /// SIMD path the one-time kernel dispatcher picked
    /// ("avx2+fma" | "sse2-baseline").
    pub simd: String,
}

fn scaled_model(m: &PerfModel, k: f64) -> PerfModel {
    PerfModel {
        beta_v: m.beta_v * k,
        beta_n: m.beta_n * k,
        intercept: m.intercept * k,
        r2: m.r2,
    }
}

/// Deterministic per-window collection cost for a layout: the slowest
/// fog's analytic transfer time (device-side packing pipelines with the
/// previous window's upload, so it is off the steady-state critical
/// path, like the fog-side unpack thread).
fn collection_transfer_s(
    g: &Graph,
    payload: &[f32],
    dims: usize,
    assignment: &[u32],
    cluster: &Cluster,
    opts: &ServeOpts,
) -> f64 {
    let coll = collection::collect(g, payload, dims, assignment, cluster,
                                   &opts.codec, opts.devices, opts.wan);
    coll.per_fog_transfer_s.iter().cloned().fold(0f64, f64::max)
}

/// Per-fog execution seconds for one inference at simulation time `t`:
/// host-model prediction × node capability × background-load slowdown.
fn exec_per_fog(
    host_times: &[f64],
    node_mult: &[f64],
    trace: &LoadTrace,
    t: f64,
) -> Vec<f64> {
    let step = t.max(0.0) as usize;
    host_times
        .iter()
        .zip(node_mult)
        .enumerate()
        .map(|(j, (&h, &m))| {
            let load = trace.at(step, j).clamp(0.0, 0.85);
            h * m / (1.0 - load)
        })
        .collect()
}

/// Drive the serving stack under a sustained request stream.
#[allow(clippy::too_many_arguments)]
pub fn run_loadtest(
    g: &Graph,
    spec: &DatasetSpec,
    cluster: &Cluster,
    opts: &ServeOpts,
    traffic: &TrafficConfig,
    omegas: &[PerfModel],
    engine: &mut Engine,
) -> Result<LoadtestReport, EngineError> {
    assert!(traffic.rps > 0.0 && traffic.duration_s > 0.0);
    assert_eq!(omegas.len(), cluster.len());
    let n = cluster.len();
    let queue_cap = traffic.effective_queue_cap();

    // ---- ground the model with one real pipeline run --------------------
    let mut assignment = pipeline::place(g, cluster, opts, omegas, spec);
    let (payload, dims) = pipeline::query_payload(g, spec,
                                                  opts.window_start);
    let base = pipeline::serve_with_assignment(
        g, spec, cluster, opts, &assignment, &payload, dims, engine,
    )?;
    let mut coll_s = collection_transfer_s(g, &payload, dims, &assignment,
                                           cluster, opts);
    let mut report = LoadtestReport {
        base_collection_s: coll_s,
        base_sync_s: base.sync_s,
        base_wire_bytes: base.wire_bytes,
        exec_mode: traffic.exec,
        engine: engine.backend_name().to_string(),
        kernel_threads: if traffic.exec == ExecMode::Measured {
            traffic.kernel_threads.max(1)
        } else {
            1
        },
        simd: crate::runtime::kernels::simd::name().to_string(),
        ..Default::default()
    };
    report.slo.slo_s = traffic.slo_s;
    report.slo.duration_s = traffic.duration_s;
    if base.oom {
        report.slo.oom = true;
        return Ok(report);
    }

    // ---- measured executor (real CSR batched kernels) -------------------
    let mut measured: Option<MeasuredExec> =
        if traffic.exec == ExecMode::Measured {
            Some(MeasuredExec::new(
                g, &assignment, n, &opts.model, spec.name, &payload,
                dims, spec.classes, omegas, engine,
                traffic.kernel_threads.max(1),
            )?)
        } else {
            None
        };

    // ---- analytic execution model (deterministic) -----------------------
    let node_mult: Vec<f64> = cluster
        .nodes
        .iter()
        .map(|nd| nd.effective_multiplier())
        .collect();
    let mut host_times = estimate_times(g, &assignment, n, omegas);
    let trace = if traffic.background_load {
        LoadTrace::random_walk(
            n,
            traffic.duration_s.ceil() as usize + 2,
            traffic.seed ^ 0x10AD,
        )
    } else {
        LoadTrace { loads: vec![vec![0.0; n]; 1] }
    };

    // adaptive replanning only makes sense for distributed layouts
    let scheduler_on = n > 1
        && traffic.scheduler_period_s > 0.0
        && !matches!(opts.placement, Placement::SingleNode(_));
    let cfg = SchedulerConfig::default();

    // ---- request stream --------------------------------------------------
    let arrivals = ArrivalProcess::new(traffic.arrival, traffic.rps,
                                       traffic.seed)
        .times(traffic.duration_s);
    report.slo.offered = arrivals.len();

    // ---- event loop ------------------------------------------------------
    let mut batcher = MicroBatcher::new(traffic.batch);
    let mut coll_free = 0f64;
    let mut exec_free = 0f64;
    let mut finishes: Vec<f64> = Vec::new();
    let mut latencies: Vec<f64> = Vec::new();
    let mut batch_total = 0usize;
    let mut exec_busy = 0f64;
    let mut qlen_sum = 0usize;
    let mut qlen_ticks = 0usize;
    let mut queue = QueueTimeline::default();
    let mut next_sample = 0f64;
    let mut next_sched = if scheduler_on {
        traffic.scheduler_period_s
    } else {
        f64::INFINITY
    };
    let mut idx = 0usize;
    loop {
        let t_arr = arrivals.get(idx).copied().unwrap_or(f64::INFINITY);
        // pipeline-depth gate: batch k waits for batch k-PIPELINE_DEPTH
        let gate = if finishes.len() >= PIPELINE_DEPTH {
            finishes[finishes.len() - PIPELINE_DEPTH]
        } else {
            0.0
        };
        let t_form = match batcher.ready_at() {
            Some(r) => r.max(coll_free).max(gate),
            None => f64::INFINITY,
        };
        let t_next = t_arr.min(t_form);
        if t_next == f64::INFINITY {
            break;
        }

        // per-second queue-depth timeline up to the next event
        while next_sample <= t_next && next_sample <= traffic.duration_s {
            let per_fog =
                exec_per_fog(&host_times, &node_mult, &trace, next_sample);
            let depth = batcher.len() as f64;
            queue.record(per_fog.iter().map(|&e| depth * e).collect());
            qlen_sum += batcher.len();
            qlen_ticks += 1;
            report.queue_len_max = report.queue_len_max.max(batcher.len());
            next_sample += 1.0;
        }

        // dual-mode scheduler ticks (metadata reporting period)
        while next_sched <= t_next && next_sched <= traffic.duration_s {
            let step = next_sched as usize;
            // measured mode replans over η-scaled OBSERVED costs (ω′
            // from the online profiler); analytic mode over ω itself
            let eff_omegas: Vec<PerfModel> = match &measured {
                Some(m) => m.scaled_omegas(),
                None => omegas.to_vec(),
            };
            let scaled: Vec<PerfModel> = (0..n)
                .map(|j| {
                    let load = trace.at(step, j).clamp(0.0, 0.85);
                    scaled_model(&eff_omegas[j],
                                 node_mult[j] / (1.0 - load))
                })
                .collect();
            let real_times = estimate_times(g, &assignment, n, &scaled);
            match schedule(g, spec, cluster, opts, &mut assignment,
                           &real_times, &scaled, &cfg) {
                SchedulerDecision::Keep => {}
                SchedulerDecision::Diffused(_) => {
                    report.slo.diffusions += 1;
                    if let Some(m) = measured.as_mut() {
                        m.rebuild(g, &assignment, &opts.model)?;
                    }
                    host_times =
                        estimate_times(g, &assignment, n, &eff_omegas);
                    coll_s = collection_transfer_s(
                        g, &payload, dims, &assignment, cluster, opts,
                    );
                }
                SchedulerDecision::Replanned => {
                    report.slo.replans += 1;
                    if let Some(m) = measured.as_mut() {
                        m.rebuild(g, &assignment, &opts.model)?;
                    }
                    host_times =
                        estimate_times(g, &assignment, n, &eff_omegas);
                    coll_s = collection_transfer_s(
                        g, &payload, dims, &assignment, cluster, opts,
                    );
                }
            }
            next_sched += traffic.scheduler_period_s;
        }

        if t_arr <= t_next {
            // admission
            idx += 1;
            if batcher.len() >= queue_cap {
                if traffic.spill {
                    report.slo.spilled += 1;
                } else {
                    report.slo.shed += 1;
                }
            } else {
                batcher.push(t_arr);
            }
        } else {
            // release one micro-batch at t_form
            let batch = batcher.take_batch();
            let b = batch.len();
            // the executable only exists at power-of-two shapes; a
            // 17..=32 batch really pays for the 32 bucket
            let slot = bucket(b);
            let coll_time = coll_s
                * (COLL_FIXED_FRAC
                    + (1.0 - COLL_FIXED_FRAC) * b as f64
                        / traffic.batch.max_batch as f64);
            let coll_done = t_next + coll_time;
            let start_exec = coll_done.max(exec_free);
            let exec_time = if let Some(m) = measured.as_mut() {
                // real batched kernels at the padded bucket size; scale
                // each fog's measured host time by its capability and
                // current background load, BSP barrier per layer
                let step = start_exec.max(0.0) as usize;
                let mut total = 0f64;
                for layer_times in m.run_batch(slot) {
                    let mut mx = 0f64;
                    for (j, &h) in layer_times.iter().enumerate() {
                        let load = trace.at(step, j).clamp(0.0, 0.85);
                        mx = mx.max(h * node_mult[j] / (1.0 - load));
                    }
                    total += mx;
                }
                // the block-diagonal batch ships `slot` copies of the
                // halo rows, so the (bandwidth-dominated) sync share
                // scales with the bucket
                total + report.base_sync_s * slot as f64
            } else {
                let per_fog = exec_per_fog(&host_times, &node_mult,
                                           &trace, start_exec);
                let slowest =
                    per_fog.iter().cloned().fold(0f64, f64::max);
                (slowest + report.base_sync_s)
                    * (EXEC_FIXED_FRAC
                        + (1.0 - EXEC_FIXED_FRAC) * slot as f64)
            };
            let finish = start_exec + exec_time;
            coll_free = coll_done;
            exec_free = finish;
            exec_busy += exec_time;
            finishes.push(finish);
            report.slo.batches += 1;
            batch_total += b;
            report.slo.completed += b;
            for &a in &batch {
                latencies.push(finish - a);
            }
        }
    }

    // ---- summaries -------------------------------------------------------
    report.slo.mean_batch = if report.slo.batches > 0 {
        batch_total as f64 / report.slo.batches as f64
    } else {
        0.0
    };
    report.exec_utilization = if exec_free > 0.0 {
        (exec_busy / exec_free.max(traffic.duration_s)).min(1.0)
    } else {
        0.0
    };
    report.queue_len_mean = if qlen_ticks > 0 {
        qlen_sum as f64 / qlen_ticks as f64
    } else {
        0.0
    };
    report.slo.finalize(&latencies);
    report.slo.queue = queue;
    report.latencies = latencies;
    if let Some(m) = &measured {
        report.engine = m.engine_name().to_string();
        report.bucket_host_ms = m.bucket_summary();
    }
    Ok(report)
}

/// JSON record of one loadtest run (everything in here is deterministic
/// for a fixed seed).
pub fn report_json(label: &str, traffic: &TrafficConfig,
                   r: &LoadtestReport) -> Json {
    let slo = &r.slo;
    obj(vec![
        ("label", s(label)),
        ("arrival", s(traffic.arrival.name())),
        ("rps", num(traffic.rps)),
        ("duration_s", num(traffic.duration_s)),
        // string: a u64 seed above 2^53 would lose digits as an f64,
        // breaking replay from the recorded artifact
        ("seed", s(&traffic.seed.to_string())),
        ("slo_ms", num(traffic.slo_s * 1e3)),
        ("max_batch", num(traffic.batch.max_batch as f64)),
        ("batch_deadline_ms", num(traffic.batch.max_delay_s * 1e3)),
        ("queue_cap", num(traffic.effective_queue_cap() as f64)),
        ("offered", num(slo.offered as f64)),
        ("completed", num(slo.completed as f64)),
        ("within_slo", num(slo.within_slo as f64)),
        ("shed", num(slo.shed as f64)),
        ("spilled", num(slo.spilled as f64)),
        ("shed_rate", num(slo.shed_rate())),
        ("goodput_rps", num(slo.goodput_rps)),
        ("p50_ms", num(slo.latency.p50_s * 1e3)),
        ("p95_ms", num(slo.latency.p95_s * 1e3)),
        ("p99_ms", num(slo.latency.p99_s * 1e3)),
        ("mean_ms", num(slo.latency.mean_s * 1e3)),
        ("batches", num(slo.batches as f64)),
        ("mean_batch", num(slo.mean_batch)),
        ("diffusions", num(slo.diffusions as f64)),
        ("replans", num(slo.replans as f64)),
        ("oom", Json::Bool(slo.oom)),
        ("exec_utilization", num(r.exec_utilization)),
        ("queue_len_max", num(r.queue_len_max as f64)),
        ("queue_len_mean", num(r.queue_len_mean)),
        ("queue_skew", num(slo.queue.mean_skew())),
        (
            "per_fog_queue_depth_mean_s",
            arr(slo.queue.per_fog_mean().into_iter().map(num)),
        ),
        (
            "per_fog_queue_depth_max_s",
            arr(slo.queue.per_fog_max().into_iter().map(num)),
        ),
        ("collection_s", num(r.base_collection_s)),
        ("sync_s", num(r.base_sync_s)),
        ("wire_bytes", num(r.base_wire_bytes as f64)),
        ("exec", s(r.exec_mode.name())),
        ("engine", s(&r.engine)),
        ("kernel_threads", num(r.kernel_threads as f64)),
        ("simd", s(&r.simd)),
        (
            "measured_buckets",
            arr(r.bucket_host_ms.iter().map(|row| {
                obj(vec![
                    ("bucket", num(row.bucket as f64)),
                    ("mean_host_ms", num(row.mean_host_ms)),
                    (
                        "mean_queue_wait_ms",
                        num(row.mean_queue_wait_ms),
                    ),
                    ("batches", num(row.batches as f64)),
                ])
            })),
        ),
    ])
}

/// Top-level loadtest document shared by the CLI's BENCH_loadtest.json,
/// the bench harness and the loadtest experiment — one schema. `engine`
/// names the execution engine behind the runs; `kernels` carries
/// kernel-level bench timings (empty outside the bench harness).
pub fn doc_json(dataset: &str, model: &str, net: &str, engine: &str,
                runs: Vec<Json>, kernels: Vec<Json>) -> Json {
    obj(vec![
        ("benchmark", s("loadtest")),
        ("dataset", s(dataset)),
        ("model", s(model)),
        ("net", s(net)),
        ("engine", s(engine)),
        ("runs", arr(runs)),
        ("kernel_benches", arr(kernels)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetKind;
    use crate::runtime::EngineKind;

    fn tiny() -> (Graph, DatasetSpec) {
        let (mut g, _) = crate::graph::generate::sbm(400, 2000, 8, 0.85, 3);
        let mut rng = crate::util::rng::Rng::new(5);
        g.feature_dim = 16;
        g.features = (0..400 * 16)
            .map(|_| if rng.bool(0.15) { 1.0 } else { 0.0 })
            .collect();
        let spec = DatasetSpec {
            name: "tiny",
            vertices: 400,
            edges: 2000,
            feature_dim: 16,
            classes: 3,
            duration: 1,
            window: 1,
            seed: 1,
        };
        (g, spec)
    }

    fn engine() -> Engine {
        let dir = std::env::temp_dir().join("traffic_sim_test");
        std::fs::create_dir_all(&dir).unwrap();
        Engine::new(EngineKind::Reference, &dir).unwrap()
    }

    fn fog_setup(g: &Graph) -> (Cluster, ServeOpts, Vec<PerfModel>) {
        let cluster = Cluster::case_study(NetKind::Wifi);
        let opts = ServeOpts::new("gcn", Placement::Iep,
                                  ServeOpts::co_codec(g));
        let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
        (cluster, opts, omegas)
    }

    fn quick_traffic() -> TrafficConfig {
        TrafficConfig {
            rps: 60.0,
            duration_s: 6.0,
            seed: 42,
            ..Default::default()
        }
    }

    #[test]
    fn loadtest_is_deterministic_for_a_fixed_seed() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let traffic = quick_traffic();
        let a = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        let b = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        assert_eq!(a.latencies, b.latencies);
        assert_eq!(a.slo.offered, b.slo.offered);
        assert_eq!(a.slo.shed, b.slo.shed);
        assert_eq!(a.slo.goodput_rps, b.slo.goodput_rps);
        assert_eq!(a.slo.queue.samples, b.slo.queue.samples);
        assert!(a.slo.offered > 0);
        assert!(a.slo.completed > 0);
        // every offered request is accounted for
        assert_eq!(
            a.slo.offered,
            a.slo.completed + a.slo.shed + a.slo.spilled
        );
    }

    #[test]
    fn different_seed_changes_the_stream() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let t1 = quick_traffic();
        let t2 = TrafficConfig { seed: 43, ..t1 };
        let a = run_loadtest(&g, &spec, &cluster, &opts, &t1, &omegas,
                             &mut eng)
            .unwrap();
        let b = run_loadtest(&g, &spec, &cluster, &opts, &t2, &omegas,
                             &mut eng)
            .unwrap();
        assert_ne!(a.latencies, b.latencies);
    }

    #[test]
    fn overload_sheds_and_respects_queue_bound() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let traffic = TrafficConfig {
            rps: 4000.0,
            duration_s: 4.0,
            queue_cap: 64,
            seed: 7,
            ..Default::default()
        };
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        assert!(r.slo.shed > 0, "no shedding under 40x overload");
        assert!(r.queue_len_max <= 64);
        assert!(r.slo.shed_rate() > 0.3);
        // goodput can't exceed what the SLO admits
        assert!(r.slo.within_slo <= r.slo.completed);
    }

    #[test]
    fn spill_replaces_shed() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let traffic = TrafficConfig {
            rps: 4000.0,
            duration_s: 2.0,
            queue_cap: 64,
            spill: true,
            seed: 7,
            ..Default::default()
        };
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        assert_eq!(r.slo.shed, 0);
        assert!(r.slo.spilled > 0);
    }

    #[test]
    fn batching_beats_serial_service() {
        // with batching off (max_batch 1) the same stream must finish
        // with strictly lower goodput than with micro-batching on
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let batched = TrafficConfig {
            rps: 300.0,
            duration_s: 5.0,
            seed: 13,
            ..Default::default()
        };
        let serial = TrafficConfig {
            batch: BatchPolicy { max_batch: 1, max_delay_s: 0.0 },
            ..batched
        };
        let rb = run_loadtest(&g, &spec, &cluster, &opts, &batched,
                              &omegas, &mut eng)
            .unwrap();
        let rs = run_loadtest(&g, &spec, &cluster, &opts, &serial,
                              &omegas, &mut eng)
            .unwrap();
        assert!(
            rb.slo.goodput_rps > rs.slo.goodput_rps,
            "batched {} !> serial {}",
            rb.slo.goodput_rps,
            rs.slo.goodput_rps
        );
    }

    #[test]
    fn measured_exec_runs_real_kernels_and_records_buckets() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let traffic = TrafficConfig {
            rps: 60.0,
            duration_s: 2.0,
            seed: 42,
            exec: ExecMode::Measured,
            ..Default::default()
        };
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        assert_eq!(r.exec_mode, ExecMode::Measured);
        assert_eq!(r.engine, "csr-batched");
        assert!(r.slo.completed > 0);
        assert!(!r.bucket_host_ms.is_empty(),
                "measured buckets recorded");
        for row in &r.bucket_host_ms {
            assert!(row.bucket.is_power_of_two());
            assert!(row.mean_host_ms >= 0.0);
            assert!(row.mean_queue_wait_ms >= 0.0);
            assert!(row.batches > 0);
        }
        assert_eq!(r.kernel_threads, 1);
        assert!(!r.simd.is_empty());
        // measured latencies are strictly positive wall-clock sums
        assert!(r.latencies.iter().all(|&l| l > 0.0));
        let j = report_json("measured", &traffic, &r);
        assert_eq!(j.get("exec").unwrap().as_str(), Some("measured"));
        assert_eq!(j.get("engine").unwrap().as_str(),
                   Some("csr-batched"));
        assert!(j.get("measured_buckets").is_some());
        assert_eq!(j.get("kernel_threads").unwrap().as_usize(),
                   Some(1));
        assert!(j.get("simd").unwrap().as_str().is_some());
    }

    #[test]
    fn measured_exec_with_kernel_threads_runs() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let traffic = TrafficConfig {
            rps: 60.0,
            duration_s: 2.0,
            seed: 42,
            exec: ExecMode::Measured,
            kernel_threads: 2,
            ..Default::default()
        };
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        assert_eq!(r.kernel_threads, 2);
        assert!(r.slo.completed > 0);
        let j = report_json("measured", &traffic, &r);
        assert_eq!(j.get("kernel_threads").unwrap().as_usize(),
                   Some(2));
    }

    #[test]
    fn measured_mode_serves_astgcn() {
        let (g, spec) = tiny();
        let (cluster, _, omegas) = fog_setup(&g);
        let opts = ServeOpts::new("astgcn", Placement::Iep,
                                  ServeOpts::co_codec(&g));
        let mut eng = engine();
        let traffic = TrafficConfig {
            rps: 20.0,
            duration_s: 1.0,
            exec: ExecMode::Measured,
            ..Default::default()
        };
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        assert_eq!(r.exec_mode, ExecMode::Measured);
        assert_eq!(r.engine, "csr-batched");
        assert!(r.slo.completed > 0);
        assert!(!r.bucket_host_ms.is_empty());
    }

    #[test]
    fn report_json_has_the_headline_fields() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let traffic = quick_traffic();
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        let j = report_json("fograph", &traffic, &r);
        for key in ["goodput_rps", "p50_ms", "p95_ms", "p99_ms",
                    "shed_rate", "per_fog_queue_depth_mean_s"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        let txt = j.to_string();
        let parsed = Json::parse(&txt).unwrap();
        assert_eq!(parsed.get("label").unwrap().as_str(), Some("fograph"));
    }
}
