//! Single-workload loadtest entry point over the multi-tenant serving
//! fabric (`traffic::fabric`), plus the shared loadtest configuration
//! and report types.
//!
//! The event-driven serving loop itself lives in `fabric`: N per-tenant
//! request streams merged into one deterministic event loop over shared
//! collection/execution stations, deficit-round-robin weighted-fair
//! admission, per-service plan caching and per-service dual-mode
//! rescheduling. `run_loadtest` here maps the legacy single-tenant
//! flags onto a ONE-tenant fabric — same stream seed, same admission
//! bound, weight 1 — which reduces step-for-step to the pre-fabric
//! loop, so `--exec analytic` runs stay bit-reproducible against
//! existing seeds (asserted by `tests/traffic_fabric.rs`).
//!
//! Execution pricing (`ExecMode`):
//!
//! * **analytic** (default) — per-fog execution from the calibratable ω
//!   models (`profile::PerfModel`), the analytic transfer share of
//!   collection and the analytic sync cost — exactly the quantities the
//!   scheduler reasons about (as in the Fig. 16 experiment). Every
//!   reported number is a pure function of `(inputs, seed)`: analytic
//!   loadtest runs are bit-reproducible.
//! * **measured** — every released micro-batch executes the real sparse
//!   CSR batched BSP kernels at its padded bucket size
//!   (`traffic::measured`), per-fog compute on the persistent worker
//!   pool (`runtime::kernels::pool`); measured timings feed the online
//!   profiler so diffusion / IEP replans use η-scaled OBSERVED costs
//!   (ω′) instead of ω. Wall-clock measurements are inherently
//!   non-deterministic.
//!
//! Stations and timing model (see `fabric` for the loop):
//!
//! * **collection** — one snapshot upload per micro-batch window; the
//!   batch shares it, so collection cost grows only mildly with batch
//!   size (devices stream once per window, §III-D).
//! * **execution**  — BSP over all fogs: the batch finishes when the
//!   slowest fog finishes. Batching amortizes the per-inference fixed
//!   overhead; a batch pays for its padded power-of-two *bucket*
//!   (`batcher::bucket`), mirroring the lowered-artifact shapes.
//! * the two stations pipeline with configurable depth
//!   (`--pipeline-depth`): at the default depth 1, collection of
//!   batch k overlaps execution of batch k-1 — the paper's throughput
//!   model; deeper windows let collection/compression of batch N+1
//!   overlap the kernels of up to `depth` earlier batches (measured
//!   mode runs them through the real pipelined executor).

use std::sync::Arc;

use crate::fog::Cluster;
use crate::graph::delta::{ChurnSpec, ChurnSummary};
use crate::graph::{DatasetSpec, Graph};
use crate::obs::recorder::Recorder;
use crate::profile::PerfModel;
use crate::runtime::{Engine, EngineError};
use crate::serving::pipeline::ServeOpts;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::provenance::{git_rev, peak_rss_bytes,
                              utc_date_string};

use super::arrival::ArrivalKind;
use super::batcher::BatchPolicy;
use super::chaos::{chaos_json, ChaosReport, FaultSpec};
use super::fabric::{run_fabric_chaos, run_fabric_churn,
                    run_fabric_traced, TenantInput};
use super::measured::BucketRow;
use super::slo::SloReport;
use super::tenant::{FairPolicy, Tenant};

/// How the loop prices per-batch execution (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// ω-model execution costs; bit-reproducible for a fixed seed.
    #[default]
    Analytic,
    /// Real CSR batched kernel execution, measured per batch.
    Measured,
}

impl ExecMode {
    pub fn parse(s: &str) -> Option<ExecMode> {
        match s {
            "analytic" => Some(ExecMode::Analytic),
            "measured" => Some(ExecMode::Measured),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Analytic => "analytic",
            ExecMode::Measured => "measured",
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct TrafficConfig {
    pub arrival: ArrivalKind,
    /// Mean offered load, requests/second.
    pub rps: f64,
    /// Offered-traffic window (simulation seconds); the loop drains
    /// queued work past this point.
    pub duration_s: f64,
    pub seed: u64,
    /// End-to-end latency objective.
    pub slo_s: f64,
    pub batch: BatchPolicy,
    /// Admission bound on the wait queue (requests).
    pub queue_cap: usize,
    /// Spill over-bound requests to the cloud tier instead of dropping.
    pub spill: bool,
    /// Dual-mode scheduler period (simulation seconds); 0 disables.
    pub scheduler_period_s: f64,
    /// Replay a background-load trace over the fogs.
    pub background_load: bool,
    /// Analytic ω-model pricing (default) or measured per-batch kernel
    /// execution.
    pub exec: ExecMode,
    /// Worker-group width the largest fog partition gets in measured
    /// mode (`--kernel-threads`; 1 = no intra-fog sharding). Analytic
    /// pricing ignores it.
    pub kernel_threads: usize,
    /// In-flight micro-batch window of the pipelined executor
    /// (`--pipeline-depth`): batch N+1's collection/compression
    /// overlaps batch N's kernels, up to `depth` batches deep. 1 (the
    /// default) keeps the serial measured path and bit-identical
    /// reports; analytic pricing models the overlap in its timeline.
    pub pipeline_depth: usize,
}

impl TrafficConfig {
    /// The admission bound the loop actually enforces: never below one
    /// full batch, or the batcher could starve.
    pub fn effective_queue_cap(&self) -> usize {
        self.queue_cap.max(self.batch.max_batch)
    }
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            arrival: ArrivalKind::Poisson,
            rps: 100.0,
            duration_s: 30.0,
            seed: 0xF06,
            slo_s: 1.0,
            batch: BatchPolicy::default(),
            // bound the worst-case admission wait near SLO/2 at the
            // cluster's typical service rate (see sim tests)
            queue_cap: 64,
            spill: false,
            scheduler_period_s: 5.0,
            background_load: true,
            exec: ExecMode::Analytic,
            kernel_threads: 1,
            pipeline_depth: 1,
        }
    }
}

/// Pipelined-executor outcome of a measured run (`--pipeline-depth`).
/// `None` on analytic runs and absent from their JSON, so analytic
/// reports stay byte-identical to the pre-pipeline schema.
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineReport {
    /// The `--pipeline-depth` the run used (1 = serial barrier path).
    pub depth: usize,
    /// Per-fog occupancy: cumulative busy-kernel seconds divided by
    /// the wall window between the first submitted and last collected
    /// batch.
    pub occupancy: Vec<f64>,
    /// Wall seconds the fabric thread spent blocked because the
    /// in-flight window was full (accounted as the `pipeline_stall`
    /// phase, apart from admission queueing).
    pub stall_s: f64,
}

/// Outcome of one loadtest run.
#[derive(Clone, Debug, Default)]
pub struct LoadtestReport {
    pub slo: SloReport,
    /// Raw per-request fog-tier latencies (seconds, completion order).
    pub latencies: Vec<f64>,
    /// Busy fraction of the execution station over the run.
    pub exec_utilization: f64,
    /// Wait-queue length extremes (requests).
    pub queue_len_max: usize,
    pub queue_len_mean: f64,
    /// Communication constants from the grounding pipeline run.
    pub base_collection_s: f64,
    pub base_sync_s: f64,
    pub base_wire_bytes: usize,
    /// Execution pricing mode the run used.
    pub exec_mode: ExecMode,
    /// Engine behind the run ("csr-batched" for measured mode, else
    /// the analytic model over the grounding engine).
    pub engine: String,
    /// Measured per-bucket rows (kernel ms and pool queue-wait ms
    /// separated) — empty in analytic mode.
    pub bucket_host_ms: Vec<BucketRow>,
    /// Worker-group width the measured pool was built with (1 in
    /// analytic mode).
    pub kernel_threads: usize,
    /// SIMD path the one-time kernel dispatcher picked
    /// ("avx2+fma" | "sse2-baseline").
    pub simd: String,
    /// Pipelined-executor accounting — `Some` exactly for measured
    /// runs (any depth), `None` for analytic runs.
    pub pipeline: Option<PipelineReport>,
    /// Per-tenant, per-fog time-in-phase accounting from the obs
    /// registry (`Registry::phase_breakdown`). Always populated — the
    /// registry is live even with span tracing off, so this section is
    /// bit-identical with `--trace-out` on or off in analytic mode.
    pub phase_breakdown: Json,
    /// Chaos outcome — `Some` exactly when the run declared `--fault`
    /// specs; `None` (and absent from the JSON) otherwise, so
    /// fault-free reports stay byte-identical to the pre-chaos schema.
    pub faults: Option<ChaosReport>,
    /// Streaming-graph outcome — `Some` exactly when the run declared
    /// `--churn` specs: final topology plus the cumulative partition-
    /// scoped invalidation counters. `None` (and absent from the
    /// JSON) otherwise, so churn-free reports stay byte-identical to
    /// the static-topology schema.
    pub churn: Option<ChurnSummary>,
}

/// Drive the serving stack under a sustained request stream: the
/// legacy single-tenant flags mapped onto a one-tenant fabric
/// (weight 1, the run seed as the stream seed), which reduces exactly
/// to the pre-fabric single-workload loop.
#[allow(clippy::too_many_arguments)]
pub fn run_loadtest(
    g: &Graph,
    spec: &DatasetSpec,
    cluster: &Cluster,
    opts: &ServeOpts,
    traffic: &TrafficConfig,
    omegas: &[PerfModel],
    engine: &mut Engine,
) -> Result<LoadtestReport, EngineError> {
    run_loadtest_traced(g, spec, cluster, opts, traffic, omegas,
                        engine, &Recorder::disabled())
}

/// `run_loadtest` with a flight recorder attached (`--trace-out`).
/// With a disabled recorder this IS `run_loadtest` — the one-tenant
/// fabric threads the recorder through the whole serving path.
#[allow(clippy::too_many_arguments)]
pub fn run_loadtest_traced(
    g: &Graph,
    spec: &DatasetSpec,
    cluster: &Cluster,
    opts: &ServeOpts,
    traffic: &TrafficConfig,
    omegas: &[PerfModel],
    engine: &mut Engine,
    rec: &Arc<Recorder>,
) -> Result<LoadtestReport, EngineError> {
    assert!(traffic.rps > 0.0 && traffic.duration_s > 0.0);
    assert_eq!(omegas.len(), cluster.len());
    let input = TenantInput {
        tenant: Tenant::legacy(traffic, &opts.model, spec.name),
        g,
        spec: *spec,
        opts: opts.clone(),
        omegas: omegas.to_vec(),
    };
    let fabric = run_fabric_traced(cluster, vec![input], traffic,
                                   FairPolicy::Drr, engine, rec)?;
    Ok(fabric.aggregate)
}

/// `run_loadtest_traced` under a seeded fault schedule: the one-tenant
/// mapping onto `fabric::run_fabric_chaos`. With `faults` empty this
/// is exactly `run_loadtest_traced`.
#[allow(clippy::too_many_arguments)]
pub fn run_loadtest_chaos(
    g: &Graph,
    spec: &DatasetSpec,
    cluster: &Cluster,
    opts: &ServeOpts,
    traffic: &TrafficConfig,
    omegas: &[PerfModel],
    engine: &mut Engine,
    rec: &Arc<Recorder>,
    faults: &[FaultSpec],
    task_deadline_s: f64,
) -> Result<LoadtestReport, EngineError> {
    assert!(traffic.rps > 0.0 && traffic.duration_s > 0.0);
    assert_eq!(omegas.len(), cluster.len());
    let input = TenantInput {
        tenant: Tenant::legacy(traffic, &opts.model, spec.name),
        g,
        spec: *spec,
        opts: opts.clone(),
        omegas: omegas.to_vec(),
    };
    let fabric = run_fabric_chaos(cluster, vec![input], traffic,
                                  FairPolicy::Drr, engine, rec,
                                  faults, task_deadline_s)?;
    Ok(fabric.aggregate)
}

/// `run_loadtest_chaos` plus the streaming-graph plane: the one-tenant
/// mapping onto `fabric::run_fabric_churn`. With `churn` empty this is
/// exactly `run_loadtest_chaos`.
#[allow(clippy::too_many_arguments)]
pub fn run_loadtest_churn(
    g: &Graph,
    spec: &DatasetSpec,
    cluster: &Cluster,
    opts: &ServeOpts,
    traffic: &TrafficConfig,
    omegas: &[PerfModel],
    engine: &mut Engine,
    rec: &Arc<Recorder>,
    faults: &[FaultSpec],
    task_deadline_s: f64,
    churn: &[ChurnSpec],
) -> Result<LoadtestReport, EngineError> {
    assert!(traffic.rps > 0.0 && traffic.duration_s > 0.0);
    assert_eq!(omegas.len(), cluster.len());
    let input = TenantInput {
        tenant: Tenant::legacy(traffic, &opts.model, spec.name),
        g,
        spec: *spec,
        opts: opts.clone(),
        omegas: omegas.to_vec(),
    };
    let fabric = run_fabric_churn(cluster, vec![input], traffic,
                                  FairPolicy::Drr, engine, rec,
                                  faults, task_deadline_s, churn)?;
    Ok(fabric.aggregate)
}

/// JSON record of one loadtest run (everything in here is deterministic
/// for a fixed seed).
pub fn report_json(label: &str, traffic: &TrafficConfig,
                   r: &LoadtestReport) -> Json {
    let slo = &r.slo;
    let mut fields = vec![
        ("label", s(label)),
        ("arrival", s(traffic.arrival.name())),
        ("rps", num(traffic.rps)),
        ("duration_s", num(traffic.duration_s)),
        // string: a u64 seed above 2^53 would lose digits as an f64,
        // breaking replay from the recorded artifact
        ("seed", s(&traffic.seed.to_string())),
        ("slo_ms", num(traffic.slo_s * 1e3)),
        ("max_batch", num(traffic.batch.max_batch as f64)),
        ("batch_deadline_ms", num(traffic.batch.max_delay_s * 1e3)),
        ("queue_cap", num(traffic.effective_queue_cap() as f64)),
        ("offered", num(slo.offered as f64)),
        ("completed", num(slo.completed as f64)),
        ("within_slo", num(slo.within_slo as f64)),
        ("shed", num(slo.shed as f64)),
        ("spilled", num(slo.spilled as f64)),
        ("shed_rate", num(slo.shed_rate())),
        ("goodput_rps", num(slo.goodput_rps)),
        ("p50_ms", num(slo.latency.p50_s * 1e3)),
        ("p95_ms", num(slo.latency.p95_s * 1e3)),
        ("p99_ms", num(slo.latency.p99_s * 1e3)),
        ("mean_ms", num(slo.latency.mean_s * 1e3)),
        ("batches", num(slo.batches as f64)),
        ("mean_batch", num(slo.mean_batch)),
        ("diffusions", num(slo.diffusions as f64)),
        ("replans", num(slo.replans as f64)),
        ("oom", Json::Bool(slo.oom)),
        ("exec_utilization", num(r.exec_utilization)),
        ("queue_len_max", num(r.queue_len_max as f64)),
        ("queue_len_mean", num(r.queue_len_mean)),
        ("queue_skew", num(slo.queue.mean_skew())),
        (
            "per_fog_queue_depth_mean_s",
            arr(slo.queue.per_fog_mean().into_iter().map(num)),
        ),
        (
            "per_fog_queue_depth_max_s",
            arr(slo.queue.per_fog_max().into_iter().map(num)),
        ),
        ("collection_s", num(r.base_collection_s)),
        ("sync_s", num(r.base_sync_s)),
        ("wire_bytes", num(r.base_wire_bytes as f64)),
        ("exec", s(r.exec_mode.name())),
        ("engine", s(&r.engine)),
        ("kernel_threads", num(r.kernel_threads as f64)),
        ("simd", s(&r.simd)),
    ];
    // measured runs only — analytic reports stay byte-identical to
    // the pre-pipeline schema (no keys added)
    if let Some(p) = &r.pipeline {
        fields.push(("pipeline_depth", num(p.depth as f64)));
        fields.push((
            "pipeline_occupancy",
            arr(p.occupancy.iter().copied().map(num)),
        ));
        fields.push(("pipeline_stall_s", num(p.stall_s)));
    }
    // chaos runs only — fault-free reports keep the pre-chaos schema
    // byte-for-byte (no keys added)
    if let Some(f) = &r.faults {
        fields.push(("faults", chaos_json(f)));
    }
    // churn runs only — static-topology reports keep the pre-churn
    // schema byte-for-byte (no keys added)
    if let Some(c) = &r.churn {
        fields.push(("churn", c.json()));
    }
    fields.push(("phase_breakdown", r.phase_breakdown.clone()));
    fields.push((
        "measured_buckets",
        arr(r.bucket_host_ms.iter().map(|row| {
            obj(vec![
                ("bucket", num(row.bucket as f64)),
                ("mean_host_ms", num(row.mean_host_ms)),
                (
                    "mean_queue_wait_ms",
                    num(row.mean_queue_wait_ms),
                ),
                ("batches", num(row.batches as f64)),
            ])
        })),
    ));
    obj(fields)
}

/// Top-level loadtest document shared by the CLI's BENCH_loadtest.json,
/// the bench harness and the loadtest experiment — one schema. `engine`
/// names the execution engine behind the runs; `kernels` carries
/// kernel-level bench timings (empty outside the bench harness).
/// Stamped with the same `rev`/`date` provenance fields as
/// BENCH_history.jsonl, so recorded loadtest numbers are traceable
/// across PRs.
pub fn doc_json(dataset: &str, model: &str, net: &str, engine: &str,
                runs: Vec<Json>, kernels: Vec<Json>) -> Json {
    obj(vec![
        ("benchmark", s("loadtest")),
        ("rev", s(&git_rev())),
        ("date", s(&utc_date_string())),
        ("dataset", s(dataset)),
        ("model", s(model)),
        ("net", s(net)),
        ("engine", s(engine)),
        ("runs", arr(runs)),
        ("kernel_benches", arr(kernels)),
        (
            "peak_rss_bytes",
            peak_rss_bytes().map_or(Json::Null, |b| num(b as f64)),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fog::Cluster;
    use crate::net::NetKind;
    use crate::runtime::EngineKind;
    use crate::serving::pipeline::Placement;

    fn tiny() -> (Graph, DatasetSpec) {
        let (mut g, _) = crate::graph::generate::sbm(400, 2000, 8, 0.85, 3);
        let mut rng = crate::util::rng::Rng::new(5);
        g.feature_dim = 16;
        g.features = (0..400 * 16)
            .map(|_| if rng.bool(0.15) { 1.0 } else { 0.0 })
            .collect();
        let spec = DatasetSpec {
            name: "tiny",
            vertices: 400,
            edges: 2000,
            feature_dim: 16,
            classes: 3,
            duration: 1,
            window: 1,
            seed: 1,
        };
        (g, spec)
    }

    fn engine() -> Engine {
        let dir = std::env::temp_dir().join("traffic_sim_test");
        std::fs::create_dir_all(&dir).unwrap();
        Engine::new(EngineKind::Reference, &dir).unwrap()
    }

    fn fog_setup(g: &Graph) -> (Cluster, ServeOpts, Vec<PerfModel>) {
        let cluster = Cluster::case_study(NetKind::Wifi);
        let opts = ServeOpts::new("gcn", Placement::Iep,
                                  ServeOpts::co_codec(g));
        let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
        (cluster, opts, omegas)
    }

    fn quick_traffic() -> TrafficConfig {
        TrafficConfig {
            rps: 60.0,
            duration_s: 6.0,
            seed: 42,
            ..Default::default()
        }
    }

    #[test]
    fn loadtest_is_deterministic_for_a_fixed_seed() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let traffic = quick_traffic();
        let a = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        let b = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        assert_eq!(a.latencies, b.latencies);
        assert_eq!(a.slo.offered, b.slo.offered);
        assert_eq!(a.slo.shed, b.slo.shed);
        assert_eq!(a.slo.goodput_rps, b.slo.goodput_rps);
        assert_eq!(a.slo.queue.samples, b.slo.queue.samples);
        assert!(a.slo.offered > 0);
        assert!(a.slo.completed > 0);
        // every offered request is accounted for
        assert_eq!(
            a.slo.offered,
            a.slo.completed + a.slo.shed + a.slo.spilled
        );
    }

    #[test]
    fn different_seed_changes_the_stream() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let t1 = quick_traffic();
        let t2 = TrafficConfig { seed: 43, ..t1 };
        let a = run_loadtest(&g, &spec, &cluster, &opts, &t1, &omegas,
                             &mut eng)
            .unwrap();
        let b = run_loadtest(&g, &spec, &cluster, &opts, &t2, &omegas,
                             &mut eng)
            .unwrap();
        assert_ne!(a.latencies, b.latencies);
    }

    #[test]
    fn overload_sheds_and_respects_queue_bound() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let traffic = TrafficConfig {
            rps: 4000.0,
            duration_s: 4.0,
            queue_cap: 64,
            seed: 7,
            ..Default::default()
        };
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        assert!(r.slo.shed > 0, "no shedding under 40x overload");
        assert!(r.queue_len_max <= 64);
        assert!(r.slo.shed_rate() > 0.3);
        // goodput can't exceed what the SLO admits
        assert!(r.slo.within_slo <= r.slo.completed);
    }

    #[test]
    fn spill_replaces_shed() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let traffic = TrafficConfig {
            rps: 4000.0,
            duration_s: 2.0,
            queue_cap: 64,
            spill: true,
            seed: 7,
            ..Default::default()
        };
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        assert_eq!(r.slo.shed, 0);
        assert!(r.slo.spilled > 0);
    }

    #[test]
    fn batching_beats_serial_service() {
        // with batching off (max_batch 1) the same stream must finish
        // with strictly lower goodput than with micro-batching on
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let batched = TrafficConfig {
            rps: 300.0,
            duration_s: 5.0,
            seed: 13,
            ..Default::default()
        };
        let serial = TrafficConfig {
            batch: BatchPolicy { max_batch: 1, max_delay_s: 0.0 },
            ..batched
        };
        let rb = run_loadtest(&g, &spec, &cluster, &opts, &batched,
                              &omegas, &mut eng)
            .unwrap();
        let rs = run_loadtest(&g, &spec, &cluster, &opts, &serial,
                              &omegas, &mut eng)
            .unwrap();
        assert!(
            rb.slo.goodput_rps > rs.slo.goodput_rps,
            "batched {} !> serial {}",
            rb.slo.goodput_rps,
            rs.slo.goodput_rps
        );
    }

    #[test]
    fn measured_exec_runs_real_kernels_and_records_buckets() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let traffic = TrafficConfig {
            rps: 60.0,
            duration_s: 2.0,
            seed: 42,
            exec: ExecMode::Measured,
            ..Default::default()
        };
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        assert_eq!(r.exec_mode, ExecMode::Measured);
        assert_eq!(r.engine, "csr-batched");
        assert!(r.slo.completed > 0);
        assert!(!r.bucket_host_ms.is_empty(),
                "measured buckets recorded");
        for row in &r.bucket_host_ms {
            assert!(row.bucket.is_power_of_two());
            assert!(row.mean_host_ms >= 0.0);
            assert!(row.mean_queue_wait_ms >= 0.0);
            assert!(row.batches > 0);
        }
        assert_eq!(r.kernel_threads, 1);
        assert!(!r.simd.is_empty());
        // measured latencies are strictly positive wall-clock sums
        assert!(r.latencies.iter().all(|&l| l > 0.0));
        let j = report_json("measured", &traffic, &r);
        assert_eq!(j.get("exec").unwrap().as_str(), Some("measured"));
        assert_eq!(j.get("engine").unwrap().as_str(),
                   Some("csr-batched"));
        assert!(j.get("measured_buckets").is_some());
        assert_eq!(j.get("kernel_threads").unwrap().as_usize(),
                   Some(1));
        assert!(j.get("simd").unwrap().as_str().is_some());
    }

    #[test]
    fn measured_exec_with_kernel_threads_runs() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let traffic = TrafficConfig {
            rps: 60.0,
            duration_s: 2.0,
            seed: 42,
            exec: ExecMode::Measured,
            kernel_threads: 2,
            ..Default::default()
        };
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        assert_eq!(r.kernel_threads, 2);
        assert!(r.slo.completed > 0);
        let j = report_json("measured", &traffic, &r);
        assert_eq!(j.get("kernel_threads").unwrap().as_usize(),
                   Some(2));
    }

    #[test]
    fn measured_mode_serves_astgcn() {
        let (g, spec) = tiny();
        let (cluster, _, omegas) = fog_setup(&g);
        let opts = ServeOpts::new("astgcn", Placement::Iep,
                                  ServeOpts::co_codec(&g));
        let mut eng = engine();
        let traffic = TrafficConfig {
            rps: 20.0,
            duration_s: 1.0,
            exec: ExecMode::Measured,
            ..Default::default()
        };
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        assert_eq!(r.exec_mode, ExecMode::Measured);
        assert_eq!(r.engine, "csr-batched");
        assert!(r.slo.completed > 0);
        assert!(!r.bucket_host_ms.is_empty());
    }

    #[test]
    fn report_json_has_the_headline_fields() {
        let (g, spec) = tiny();
        let (cluster, opts, omegas) = fog_setup(&g);
        let mut eng = engine();
        let traffic = quick_traffic();
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, &mut eng)
            .unwrap();
        let j = report_json("fograph", &traffic, &r);
        for key in ["goodput_rps", "p50_ms", "p95_ms", "p99_ms",
                    "shed_rate", "per_fog_queue_depth_mean_s"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        let txt = j.to_string();
        let parsed = Json::parse(&txt).unwrap();
        assert_eq!(parsed.get("label").unwrap().as_str(), Some("fograph"));
    }

    #[test]
    fn doc_json_carries_provenance() {
        let doc = doc_json("siot", "gcn", "wifi", "analytic",
                           Vec::new(), Vec::new());
        let rev = doc.get("rev").unwrap().as_str().unwrap();
        assert!(!rev.is_empty());
        let date = doc.get("date").unwrap().as_str().unwrap();
        assert_eq!(date.len(), 10);
        assert_eq!(doc.get("benchmark").unwrap().as_str(),
                   Some("loadtest"));
    }
}
