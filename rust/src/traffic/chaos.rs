//! Chaos serving plane — seeded, repeatable fault injection plus the
//! detection and recovery arithmetic the fabric uses to survive it.
//!
//! Fault specs arrive as repeatable `--fault` CLI strings:
//!
//! ```text
//!   crash@t=4,fog=1[,rejoin=8]          fog stops replying at ~t
//!   slow@t=4,fog=2,factor=0.3[,until=9] fog runs at factor× speed
//!   link@t=4,src=0,dst=3,bw=0.1x[,until=9]  uplink bandwidth collapse
//! ```
//!
//! A `ChaosPlan` canonicalizes the declared faults (sorted by onset
//! time, then class, then ids) and then draws a small onset jitter for
//! each from a dedicated RNG stream (`seed ^ CHAOS_SALT`), so runs
//! stay bit-deterministic for a fixed seed and invariant under
//! `--fault` declaration order, and an empty fault list leaves every
//! other seeded stream untouched.
//!
//! The `EwmaDetector` tracks per-fog task *durations* (not completion
//! intervals: in a BSP fabric every fog finishes each batch at the
//! same virtual time, so intervals only see batch cadence). A fog is
//! overdue when its oldest outstanding task has been running past
//! `mean + beta·dev`, the same deadline the fabric prices hedged
//! analytic dispatch with.

use crate::util::json::{arr, num, obj, s, Json};
use crate::util::rng::{mix64, Rng};

/// Salt for the dedicated chaos RNG stream: fault-onset jitter must
/// not perturb the arrival/load-trace streams, so an identical run
/// with no faults declared stays bit-identical.
const CHAOS_SALT: u64 = 0xC4A0_5EED;

/// Max onset jitter (seconds) added to each fault's declared time.
const ONSET_JITTER_S: f64 = 0.1;

// EWMA deadline constants: alpha is the observation weight, beta the
// deviation multiplier (mean + beta·dev), floor_s a lower bound so a
// few fast samples cannot produce a hair-trigger deadline.
const EWMA_ALPHA: f64 = 0.25;
const EWMA_BETA: f64 = 3.0;
const EWMA_FLOOR_S: f64 = 0.05;

/// One fault class with its class-specific parameters. Times are
/// absolute run seconds; `factor`/`bw` are ratios in (0, 1].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Fog stops completing tasks at onset; optionally rejoins later.
    Crash { fog: usize, rejoin: Option<f64> },
    /// Fog executes at `factor`× speed until `until` (or forever).
    Slow { fog: usize, factor: f64, until: Option<f64> },
    /// The src→dst uplink drops to `bw`× bandwidth until `until`.
    Link { src: usize, dst: usize, bw: f64, until: Option<f64> },
}

impl FaultKind {
    pub fn class(&self) -> &'static str {
        match self {
            FaultKind::Crash { .. } => "crash",
            FaultKind::Slow { .. } => "slow",
            FaultKind::Link { .. } => "link",
        }
    }

    fn class_rank(&self) -> u8 {
        match self {
            FaultKind::Crash { .. } => 0,
            FaultKind::Slow { .. } => 1,
            FaultKind::Link { .. } => 2,
        }
    }

    fn ids(&self) -> (usize, usize) {
        match *self {
            FaultKind::Crash { fog, .. } => (fog, 0),
            FaultKind::Slow { fog, .. } => (fog, 0),
            FaultKind::Link { src, dst, .. } => (src, dst),
        }
    }
}

/// One declared fault: class parameters plus the onset time `t`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    pub t: f64,
    pub kind: FaultKind,
}

fn parse_kv<'a>(
    rest: &'a str,
    spec: &str,
) -> Result<Vec<(&'a str, &'a str)>, String> {
    let mut out: Vec<(&str, &str)> = Vec::new();
    for part in rest.split(',') {
        let (k, v) = part.split_once('=').ok_or_else(|| {
            format!("fault spec '{spec}': expected key=value, got '{part}'")
        })?;
        let (k, v) = (k.trim(), v.trim());
        if k.is_empty() || v.is_empty() {
            return Err(format!(
                "fault spec '{spec}': empty key or value in '{part}'"
            ));
        }
        if out.iter().any(|(ek, _)| *ek == k) {
            return Err(format!("fault spec '{spec}': duplicate key '{k}'"));
        }
        out.push((k, v));
    }
    Ok(out)
}

fn take<'a>(kv: &mut Vec<(&'a str, &'a str)>, key: &str) -> Option<&'a str> {
    kv.iter()
        .position(|(k, _)| *k == key)
        .map(|i| kv.remove(i).1)
}

fn need<'a>(
    kv: &mut Vec<(&'a str, &'a str)>,
    key: &str,
    spec: &str,
) -> Result<&'a str, String> {
    take(kv, key)
        .ok_or_else(|| format!("fault spec '{spec}': missing '{key}='"))
}

fn parse_time(v: &str, key: &str, spec: &str) -> Result<f64, String> {
    let t: f64 = v.parse().map_err(|_| {
        format!("fault spec '{spec}': '{key}={v}' is not a number")
    })?;
    if !t.is_finite() || t < 0.0 {
        return Err(format!(
            "fault spec '{spec}': '{key}={v}' must be a finite time >= 0"
        ));
    }
    Ok(t)
}

fn parse_id(v: &str, key: &str, spec: &str) -> Result<usize, String> {
    v.parse().map_err(|_| {
        format!("fault spec '{spec}': '{key}={v}' is not a fog index")
    })
}

/// A speed/bandwidth ratio: a number in (0, 1], optionally suffixed
/// with `x` (`0.1x` == `0.1`).
fn parse_ratio(v: &str, key: &str, spec: &str) -> Result<f64, String> {
    let body = v
        .strip_suffix('x')
        .or_else(|| v.strip_suffix('X'))
        .unwrap_or(v);
    let r: f64 = body.parse().map_err(|_| {
        format!("fault spec '{spec}': '{key}={v}' is not a ratio")
    })?;
    if !r.is_finite() || r <= 0.0 || r > 1.0 {
        return Err(format!(
            "fault spec '{spec}': '{key}={v}' must be in (0, 1]"
        ));
    }
    Ok(r)
}

impl FaultSpec {
    /// Parse one `--fault` spec (`class@k=v,k=v,...`). Errors name the
    /// offending spec and field so the CLI can exit 2 with a usable
    /// message.
    pub fn parse(spec: &str) -> Result<FaultSpec, String> {
        let (class, rest) = spec.split_once('@').ok_or_else(|| {
            format!(
                "fault spec '{spec}': expected class@k=v,... \
                 (classes: crash, slow, link)"
            )
        })?;
        let mut kv = parse_kv(rest, spec)?;
        let t = parse_time(need(&mut kv, "t", spec)?, "t", spec)?;
        let kind = match class.trim() {
            "crash" => {
                let fog = parse_id(need(&mut kv, "fog", spec)?, "fog", spec)?;
                let rejoin = match take(&mut kv, "rejoin") {
                    Some(v) => {
                        let r = parse_time(v, "rejoin", spec)?;
                        if r <= t {
                            return Err(format!(
                                "fault spec '{spec}': rejoin must be \
                                 after t"
                            ));
                        }
                        Some(r)
                    }
                    None => None,
                };
                FaultKind::Crash { fog, rejoin }
            }
            "slow" => {
                let fog = parse_id(need(&mut kv, "fog", spec)?, "fog", spec)?;
                let factor = parse_ratio(
                    need(&mut kv, "factor", spec)?,
                    "factor",
                    spec,
                )?;
                let until = parse_until(&mut kv, t, spec)?;
                FaultKind::Slow { fog, factor, until }
            }
            "link" => {
                let src = parse_id(need(&mut kv, "src", spec)?, "src", spec)?;
                let dst = parse_id(need(&mut kv, "dst", spec)?, "dst", spec)?;
                if src == dst {
                    return Err(format!(
                        "fault spec '{spec}': src and dst must differ"
                    ));
                }
                let bw =
                    parse_ratio(need(&mut kv, "bw", spec)?, "bw", spec)?;
                let until = parse_until(&mut kv, t, spec)?;
                FaultKind::Link { src, dst, bw, until }
            }
            other => {
                return Err(format!(
                    "fault spec '{spec}': unknown class '{other}' \
                     (classes: crash, slow, link)"
                ))
            }
        };
        if let Some((k, _)) = kv.first() {
            return Err(format!("fault spec '{spec}': unknown key '{k}'"));
        }
        Ok(FaultSpec { t, kind })
    }

    /// Check a parsed spec against a concrete run: every fog id must
    /// exist and the onset must land inside the run.
    pub fn validate(
        &self,
        n_fogs: usize,
        duration_s: f64,
    ) -> Result<(), String> {
        let (a, b) = self.kind.ids();
        for id in [a, b] {
            if id >= n_fogs {
                return Err(format!(
                    "{} fault references fog {id} but the cluster has \
                     {n_fogs} fogs",
                    self.kind.class()
                ));
            }
        }
        if self.t >= duration_s {
            return Err(format!(
                "{} fault at t={} is past the run end ({duration_s}s)",
                self.kind.class(),
                self.t
            ));
        }
        Ok(())
    }

    fn sort_key(&self) -> (f64, u8, usize, usize) {
        let (a, b) = self.kind.ids();
        (self.t, self.kind.class_rank(), a, b)
    }
}

fn parse_until(
    kv: &mut Vec<(&str, &str)>,
    t: f64,
    spec: &str,
) -> Result<Option<f64>, String> {
    match take(kv, "until") {
        Some(v) => {
            let u = parse_time(v, "until", spec)?;
            if u <= t {
                return Err(format!(
                    "fault spec '{spec}': until must be after t"
                ));
            }
            Ok(Some(u))
        }
        None => Ok(None),
    }
}

/// A declared fault with its jittered onset time.
#[derive(Clone, Copy, Debug)]
pub struct ActiveFault {
    pub spec: FaultSpec,
    /// Actual onset: declared `t` plus a seeded jitter in
    /// `[0, ONSET_JITTER_S)`.
    pub t_on: f64,
}

/// The canonical, seeded fault schedule for one run. Jitter is drawn
/// *after* sorting into canonical order, so the plan is invariant
/// under `--fault` declaration order.
#[derive(Clone, Debug, Default)]
pub struct ChaosPlan {
    pub faults: Vec<ActiveFault>,
}

impl ChaosPlan {
    pub fn new(specs: &[FaultSpec], seed: u64) -> ChaosPlan {
        let mut sorted = specs.to_vec();
        // spec times are finite by construction (parse rejects
        // NaN/inf), so the partial order is total here
        sorted.sort_by(|a, b| {
            a.sort_key().partial_cmp(&b.sort_key()).unwrap()
        });
        let mut rng = Rng::new(mix64(seed ^ CHAOS_SALT));
        let faults = sorted
            .into_iter()
            .map(|spec| ActiveFault {
                t_on: spec.t + rng.range_f64(0.0, ONSET_JITTER_S),
                spec,
            })
            .collect();
        ChaosPlan { faults }
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Is `fog` dead at virtual time `t`? (Rejoin is un-jittered: the
    /// operator said when the node comes back.)
    pub fn crashed(&self, fog: usize, t: f64) -> bool {
        self.faults.iter().any(|f| match f.spec.kind {
            FaultKind::Crash { fog: g, rejoin } => {
                g == fog && t >= f.t_on && rejoin.map_or(true, |r| t < r)
            }
            _ => false,
        })
    }

    /// Speed multiplier for `fog` at `t`: the product of every active
    /// slow fault's factor (1.0 when healthy).
    pub fn slow_factor(&self, fog: usize, t: f64) -> f64 {
        let mut k = 1.0;
        for f in &self.faults {
            if let FaultKind::Slow { fog: g, factor, until } = f.spec.kind {
                if g == fog && t >= f.t_on && until.map_or(true, |u| t < u)
                {
                    k *= factor;
                }
            }
        }
        k
    }

    /// Bandwidth multiplier for collection/sync transfers at `t`: the
    /// worst (minimum) active link fault (1.0 when healthy). The
    /// fabric's transfer model prices the whole collection window, so
    /// one degraded uplink throttles that window's wire share.
    pub fn link_factor(&self, t: f64) -> f64 {
        let mut bw: f64 = 1.0;
        for f in &self.faults {
            if let FaultKind::Link { bw: b, until, .. } = f.spec.kind {
                if t >= f.t_on && until.map_or(true, |u| t < u) {
                    bw = bw.min(b);
                }
            }
        }
        bw
    }
}

/// Straggler/crash detector: an EWMA of per-fog task durations with a
/// mean + beta·dev deadline. `start` marks the *oldest* outstanding
/// task (later starts while one is pending are ignored, so a crashed
/// fog's first unanswered task keeps aging); `complete` clears it and
/// feeds the duration. Deviation is updated against the previous mean
/// — the estimate that existed when the sample arrived.
#[derive(Clone, Debug)]
pub struct EwmaDetector {
    alpha: f64,
    beta: f64,
    floor_s: f64,
    mean: Vec<f64>,
    dev: Vec<f64>,
    primed: Vec<bool>,
    started: Vec<Option<f64>>,
}

impl EwmaDetector {
    pub fn new(n_fogs: usize) -> EwmaDetector {
        EwmaDetector {
            alpha: EWMA_ALPHA,
            beta: EWMA_BETA,
            floor_s: EWMA_FLOOR_S,
            mean: vec![0.0; n_fogs],
            dev: vec![0.0; n_fogs],
            primed: vec![false; n_fogs],
            started: vec![None; n_fogs],
        }
    }

    /// Mark a task outstanding on `fog` since `now` (no-op while an
    /// older one is still pending).
    pub fn start(&mut self, fog: usize, now: f64) {
        if self.started[fog].is_none() {
            self.started[fog] = Some(now);
        }
    }

    /// A task on `fog` completed after running `dur` seconds.
    pub fn complete(&mut self, fog: usize, dur: f64) {
        self.started[fog] = None;
        if !self.primed[fog] {
            self.mean[fog] = dur;
            self.dev[fog] = dur / 2.0;
            self.primed[fog] = true;
        } else {
            self.dev[fog] = self.alpha * (dur - self.mean[fog]).abs()
                + (1.0 - self.alpha) * self.dev[fog];
            self.mean[fog] = self.alpha * dur
                + (1.0 - self.alpha) * self.mean[fog];
        }
    }

    /// The duration past which a task on `fog` counts as overdue.
    pub fn deadline(&self, fog: usize) -> f64 {
        (self.mean[fog] + self.beta * self.dev[fog]).max(self.floor_s)
    }

    pub fn primed(&self, fog: usize) -> bool {
        self.primed[fog]
    }

    /// Is `fog`'s oldest outstanding task past its deadline at `now`?
    /// Never fires before the first completed observation primes the
    /// estimate.
    pub fn overdue(&self, fog: usize, now: f64) -> bool {
        self.primed[fog]
            && self.started[fog]
                .map(|s0| now - s0 > self.deadline(fog))
                .unwrap_or(false)
    }
}

/// Per-fault recovery record in the `faults` report section. Times
/// are seconds relative to the fault's (jittered) onset; `-1.0` means
/// "never happened during the run".
#[derive(Clone, Debug, PartialEq)]
pub struct FaultOutcome {
    pub class: &'static str,
    pub fog: i32,
    /// Link faults: the dst fog; -1 otherwise.
    pub peer: i32,
    pub t_fault_s: f64,
    pub time_to_detect_s: f64,
    pub time_to_recover_s: f64,
    /// p99 latency over the fault window minus the rest-of-run p99.
    pub p99_delta_ms: f64,
    /// 1 - (goodput rate inside the window / rate outside), in [0, 1].
    pub goodput_dip: f64,
    /// Requests shed while the fault window was open.
    pub shed_during: usize,
    /// Hedged/evacuated dispatches attributed to this fault.
    pub hedges: u64,
    pub recovered: bool,
}

/// The `faults` section of a chaos run's report.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosReport {
    pub task_deadline_s: f64,
    pub hedge_wins: u64,
    pub hedge_waste: u64,
    pub outcomes: Vec<FaultOutcome>,
}

pub fn chaos_json(r: &ChaosReport) -> Json {
    obj(vec![
        ("task_deadline_s", num(r.task_deadline_s)),
        ("hedge_wins", num(r.hedge_wins as f64)),
        ("hedge_waste", num(r.hedge_waste as f64)),
        (
            "outcomes",
            arr(r.outcomes.iter().map(|o| {
                obj(vec![
                    ("class", s(o.class)),
                    ("fog", num(o.fog as f64)),
                    ("peer", num(o.peer as f64)),
                    ("t_fault_s", num(o.t_fault_s)),
                    ("time_to_detect_s", num(o.time_to_detect_s)),
                    ("time_to_recover_s", num(o.time_to_recover_s)),
                    ("p99_delta_ms", num(o.p99_delta_ms)),
                    ("goodput_dip", num(o.goodput_dip)),
                    ("shed_during", num(o.shed_during as f64)),
                    ("hedges", num(o.hedges as f64)),
                    ("recovered", Json::Bool(o.recovered)),
                ])
            })),
        ),
    ])
}

fn p99(lat: &mut Vec<f64>) -> f64 {
    if lat.is_empty() {
        return 0.0;
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((lat.len() as f64 * 0.99).ceil() as usize)
        .clamp(1, lat.len())
        - 1;
    lat[idx]
}

/// SLO damage over one fault window `[t0, t1)`: the p99 delta and
/// goodput dip of completions inside the window vs. the rest of the
/// run, plus the shed count inside the window. `samples` are
/// `(finish_t, latency_s, within_slo)` completion records;
/// `duration_s` is the full run length.
pub fn window_damage(
    samples: &[(f64, f64, bool)],
    shed: &[f64],
    t0: f64,
    t1: f64,
    duration_s: f64,
) -> (f64, f64, usize) {
    let t1 = t1.min(duration_s).max(t0);
    let mut lat_in = Vec::new();
    let mut lat_out = Vec::new();
    let (mut good_in, mut good_out) = (0usize, 0usize);
    for &(ft, lat, ok) in samples {
        if ft >= t0 && ft < t1 {
            lat_in.push(lat);
            good_in += ok as usize;
        } else {
            lat_out.push(lat);
            good_out += ok as usize;
        }
    }
    let p99_delta_ms = if lat_in.is_empty() || lat_out.is_empty() {
        0.0
    } else {
        (p99(&mut lat_in) - p99(&mut lat_out)) * 1e3
    };
    let win = t1 - t0;
    let rest = (duration_s - win).max(0.0);
    let rate_in = if win > 0.0 { good_in as f64 / win } else { 0.0 };
    let rate_out =
        if rest > 0.0 { good_out as f64 / rest } else { 0.0 };
    let dip = if rate_out > 0.0 {
        (1.0 - rate_in / rate_out).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let shed_during =
        shed.iter().filter(|&&t| t >= t0 && t < t1).count();
    (p99_delta_ms, dip, shed_during)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_crash_with_rejoin() {
        let f = FaultSpec::parse("crash@t=4,fog=1,rejoin=8").unwrap();
        assert_eq!(f.t, 4.0);
        assert_eq!(
            f.kind,
            FaultKind::Crash { fog: 1, rejoin: Some(8.0) }
        );
        let f = FaultSpec::parse("crash@t=4,fog=1").unwrap();
        assert_eq!(f.kind, FaultKind::Crash { fog: 1, rejoin: None });
    }

    #[test]
    fn parses_slow_and_link() {
        let f = FaultSpec::parse("slow@t=2.5,fog=0,factor=0.3").unwrap();
        assert_eq!(
            f.kind,
            FaultKind::Slow { fog: 0, factor: 0.3, until: None }
        );
        let f =
            FaultSpec::parse("link@t=1,src=0,dst=3,bw=0.1x,until=9")
                .unwrap();
        assert_eq!(
            f.kind,
            FaultKind::Link { src: 0, dst: 3, bw: 0.1, until: Some(9.0) }
        );
    }

    #[test]
    fn rejects_missing_class_separator() {
        assert!(FaultSpec::parse("crash,t=4,fog=1").is_err());
    }

    #[test]
    fn rejects_unknown_class() {
        assert!(FaultSpec::parse("explode@t=4,fog=1").is_err());
    }

    #[test]
    fn rejects_bad_pair_and_empty_value() {
        assert!(FaultSpec::parse("crash@t=4,fog").is_err());
        assert!(FaultSpec::parse("crash@t=4,fog=").is_err());
    }

    #[test]
    fn rejects_duplicate_key() {
        assert!(FaultSpec::parse("crash@t=4,fog=1,fog=2").is_err());
    }

    #[test]
    fn rejects_unknown_key() {
        assert!(FaultSpec::parse("crash@t=4,fog=1,bw=0.5").is_err());
    }

    #[test]
    fn rejects_missing_required_key() {
        assert!(FaultSpec::parse("crash@t=4").is_err());
        assert!(FaultSpec::parse("slow@t=4,fog=1").is_err());
        assert!(FaultSpec::parse("link@t=4,src=0,dst=1").is_err());
    }

    #[test]
    fn rejects_factor_outside_unit_interval() {
        assert!(FaultSpec::parse("slow@t=4,fog=1,factor=0").is_err());
        assert!(FaultSpec::parse("slow@t=4,fog=1,factor=1.5").is_err());
        assert!(FaultSpec::parse("slow@t=4,fog=1,factor=-0.3").is_err());
        assert!(FaultSpec::parse("slow@t=4,fog=1,factor=fast").is_err());
        // 1.0 is the no-op boundary and legal
        assert!(FaultSpec::parse("slow@t=4,fog=1,factor=1.0x").is_ok());
    }

    #[test]
    fn rejects_bad_times() {
        assert!(FaultSpec::parse("crash@t=-1,fog=1").is_err());
        assert!(FaultSpec::parse("crash@t=nan,fog=1").is_err());
        assert!(FaultSpec::parse("crash@t=4,fog=1,rejoin=3").is_err());
        assert!(
            FaultSpec::parse("slow@t=4,fog=1,factor=0.5,until=4").is_err()
        );
    }

    #[test]
    fn rejects_self_link() {
        assert!(
            FaultSpec::parse("link@t=1,src=2,dst=2,bw=0.5").is_err()
        );
    }

    #[test]
    fn validate_rejects_unknown_fog_and_late_onset() {
        let f = FaultSpec::parse("crash@t=4,fog=9").unwrap();
        assert!(f.validate(3, 10.0).is_err());
        let f = FaultSpec::parse("link@t=1,src=0,dst=7,bw=0.5").unwrap();
        assert!(f.validate(3, 10.0).is_err());
        let f = FaultSpec::parse("crash@t=12,fog=0").unwrap();
        assert!(f.validate(3, 10.0).is_err());
        assert!(f.validate(3, 15.0).is_ok());
    }

    #[test]
    fn plan_is_deterministic_and_declaration_order_invariant() {
        let a = FaultSpec::parse("crash@t=4,fog=1").unwrap();
        let b = FaultSpec::parse("slow@t=2,fog=0,factor=0.5").unwrap();
        let c =
            FaultSpec::parse("link@t=4,src=0,dst=2,bw=0.2x").unwrap();
        let p1 = ChaosPlan::new(&[a, b, c], 7);
        let p2 = ChaosPlan::new(&[c, a, b], 7);
        let p3 = ChaosPlan::new(&[b, c, a], 7);
        let key = |p: &ChaosPlan| {
            p.faults
                .iter()
                .map(|f| (f.t_on, f.spec.kind.class(), f.spec.kind.ids()))
                .collect::<Vec<_>>()
        };
        assert_eq!(key(&p1), key(&p2));
        assert_eq!(key(&p1), key(&p3));
        // canonical order: by onset time first
        assert_eq!(p1.faults[0].spec.kind.class(), "slow");
        // jitter is small and nonnegative
        for f in &p1.faults {
            assert!(f.t_on >= f.spec.t);
            assert!(f.t_on < f.spec.t + ONSET_JITTER_S);
        }
        // different seed → different jitter
        let p4 = ChaosPlan::new(&[a, b, c], 8);
        assert_ne!(key(&p1), key(&p4));
    }

    #[test]
    fn plan_queries_respect_windows() {
        let crash =
            FaultSpec::parse("crash@t=4,fog=1,rejoin=8").unwrap();
        let slow =
            FaultSpec::parse("slow@t=2,fog=0,factor=0.5,until=6")
                .unwrap();
        let link =
            FaultSpec::parse("link@t=3,src=0,dst=2,bw=0.25,until=5")
                .unwrap();
        let p = ChaosPlan::new(&[crash, slow, link], 11);
        // before any onset everything is healthy
        assert!(!p.crashed(1, 0.0));
        assert_eq!(p.slow_factor(0, 0.0), 1.0);
        assert_eq!(p.link_factor(0.0), 1.0);
        // mid-window (jitter < 0.1 so t=4.5 is inside the crash)
        assert!(p.crashed(1, 4.5));
        assert!(!p.crashed(0, 4.5));
        assert_eq!(p.slow_factor(0, 4.5), 0.5);
        assert_eq!(p.link_factor(4.5), 0.25);
        // after rejoin/until everything heals
        assert!(!p.crashed(1, 8.0));
        assert_eq!(p.slow_factor(0, 6.0), 1.0);
        assert_eq!(p.link_factor(5.0), 1.0);
    }

    // Worked example shared with python/tests/test_chaos_mirror.py:
    // durations 0.5, 0.7, 0.8 at alpha=0.25, beta=3.0.
    #[test]
    fn detector_matches_worked_example() {
        let mut d = EwmaDetector::new(2);
        assert!(!d.primed(0));
        assert!(!d.overdue(0, 100.0)); // unprimed never fires
        d.start(0, 0.0);
        d.complete(0, 0.5); // primes: mean=0.5, dev=0.25
        d.complete(0, 0.7);
        d.complete(0, 0.8);
        assert!((d.deadline(0) - 1.334375).abs() < 1e-12);
        d.start(0, 10.0);
        d.start(0, 10.7); // ignored: an older task is outstanding
        assert!(!d.overdue(0, 11.0)); // elapsed 1.0 < deadline
        assert!(d.overdue(0, 11.4)); // elapsed 1.4 > deadline
        d.complete(0, 0.6);
        assert!(!d.overdue(0, 20.0)); // nothing outstanding
        // fog 1 untouched
        assert!(!d.primed(1));
    }

    #[test]
    fn detector_deadline_has_a_floor() {
        let mut d = EwmaDetector::new(1);
        d.complete(0, 0.001);
        assert_eq!(d.deadline(0), EWMA_FLOOR_S);
    }

    #[test]
    fn window_damage_measures_the_hole() {
        // 10s run; healthy completions every 0.5s at 10ms latency,
        // except a hole in [4, 6) where only one slow completion lands
        let mut samples = Vec::new();
        let mut t = 0.25;
        while t < 10.0 {
            if !(4.0..6.0).contains(&t) {
                samples.push((t, 0.010, true));
            }
            t += 0.5;
        }
        samples.push((5.5, 0.300, true));
        let shed = vec![4.2, 4.7, 8.0];
        let (dp99, dip, shed_n) =
            window_damage(&samples, &shed, 4.0, 6.0, 10.0);
        assert!(dp99 > 200.0, "p99 delta {dp99}");
        assert!(dip > 0.5 && dip <= 1.0, "dip {dip}");
        assert_eq!(shed_n, 2);
        // empty window → no damage
        let (z1, z2, z3) = window_damage(&samples, &[], 0.0, 0.0, 10.0);
        assert_eq!((z1, z2, z3), (0.0, 0.0, 0));
    }
}
