//! SLO accounting for sustained-traffic runs: latency percentiles,
//! goodput (completions inside the latency objective per second), shed
//! rate and per-fog queue-depth timelines. All summaries build on
//! `util/stats`; nothing here touches the wall clock.

use crate::util::stats;

/// Percentile summary of per-request end-to-end latencies (seconds).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub mean_s: f64,
    pub max_s: f64,
}

impl LatencySummary {
    pub fn from_samples(xs: &[f64]) -> LatencySummary {
        LatencySummary {
            p50_s: stats::percentile(xs, 50.0),
            p95_s: stats::percentile(xs, 95.0),
            p99_s: stats::percentile(xs, 99.0),
            mean_s: stats::mean(xs),
            max_s: xs.iter().cloned().fold(0f64, f64::max),
        }
    }
}

/// Per-fog queue-depth samples over the run, one row per sampling tick.
/// Depths are in *work seconds* (queued requests × that fog's marginal
/// per-request execution time under its current background load), which
/// is the quantity the dual-mode scheduler balances.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QueueTimeline {
    pub samples: Vec<Vec<f64>>,
}

impl QueueTimeline {
    pub fn record(&mut self, depths: Vec<f64>) {
        debug_assert!(
            self.samples.last().map_or(true, |p| p.len() == depths.len())
        );
        self.samples.push(depths);
    }

    pub fn num_fogs(&self) -> usize {
        self.samples.first().map_or(0, |r| r.len())
    }

    pub fn per_fog_mean(&self) -> Vec<f64> {
        let n = self.num_fogs();
        let mut acc = vec![0f64; n];
        for row in &self.samples {
            for (a, &d) in acc.iter_mut().zip(row) {
                *a += d;
            }
        }
        let steps = self.samples.len().max(1) as f64;
        for a in acc.iter_mut() {
            *a /= steps;
        }
        acc
    }

    pub fn per_fog_max(&self) -> Vec<f64> {
        let n = self.num_fogs();
        let mut acc = vec![0f64; n];
        for row in &self.samples {
            for (a, &d) in acc.iter_mut().zip(row) {
                *a = a.max(d);
            }
        }
        acc
    }

    /// Mean over ticks of (max fog depth / mean fog depth) — 1.0 means
    /// perfectly balanced queues; the scheduler's λ applies to this.
    pub fn mean_skew(&self) -> f64 {
        if self.samples.is_empty() {
            return 1.0;
        }
        let mut acc = 0f64;
        let mut counted = 0usize;
        for row in &self.samples {
            let mean = stats::mean(row);
            if mean <= 0.0 {
                continue;
            }
            let mx = row.iter().cloned().fold(0f64, f64::max);
            acc += mx / mean;
            counted += 1;
        }
        if counted == 0 {
            1.0
        } else {
            acc / counted as f64
        }
    }
}

/// Full SLO accounting of one loadtest run.
#[derive(Clone, Debug, Default)]
pub struct SloReport {
    /// Requests the generator offered.
    pub offered: usize,
    /// Requests served by the fog tier.
    pub completed: usize,
    /// Completions within the latency objective.
    pub within_slo: usize,
    /// Requests dropped by admission control.
    pub shed: usize,
    /// Requests redirected to the cloud tier by admission control
    /// (served out-of-band; excluded from fog latency stats).
    pub spilled: usize,
    pub slo_s: f64,
    pub duration_s: f64,
    pub latency: LatencySummary,
    /// Within-SLO completions per second of offered-traffic window.
    pub goodput_rps: f64,
    pub batches: usize,
    pub mean_batch: f64,
    /// Dual-mode scheduler decisions taken mid-run.
    pub diffusions: usize,
    pub replans: usize,
    /// A placement exceeded fog memory; the run was aborted.
    pub oom: bool,
    pub queue: QueueTimeline,
}

impl SloReport {
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.shed as f64 / self.offered as f64
        }
    }

    /// Fill the derived fields from raw per-request latencies.
    pub fn finalize(&mut self, latencies: &[f64]) {
        self.latency = LatencySummary::from_samples(latencies);
        self.within_slo =
            latencies.iter().filter(|&&l| l <= self.slo_s).count();
        self.goodput_rps = if self.duration_s > 0.0 {
            self.within_slo as f64 / self.duration_s
        } else {
            0.0
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64 / 100.0).collect();
        let s = LatencySummary::from_samples(&xs);
        assert!((s.p50_s - 0.505).abs() < 1e-9);
        assert!((s.p95_s - 0.9505).abs() < 1e-6);
        assert!((s.p99_s - 0.9901).abs() < 1e-6);
        assert_eq!(s.max_s, 1.0);
        assert!((s.mean_s - 0.505).abs() < 1e-9);
    }

    #[test]
    fn empty_latencies_are_all_zero() {
        let s = LatencySummary::from_samples(&[]);
        assert_eq!(s, LatencySummary::default());
    }

    #[test]
    fn finalize_counts_slo_and_goodput() {
        let mut r = SloReport {
            offered: 6,
            completed: 4,
            shed: 2,
            slo_s: 0.5,
            duration_s: 2.0,
            ..Default::default()
        };
        r.finalize(&[0.1, 0.2, 0.4, 0.9]);
        assert_eq!(r.within_slo, 3);
        assert!((r.goodput_rps - 1.5).abs() < 1e-12);
        assert!((r.shed_rate() - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn queue_timeline_summaries() {
        let mut q = QueueTimeline::default();
        q.record(vec![1.0, 1.0]);
        q.record(vec![3.0, 1.0]);
        assert_eq!(q.num_fogs(), 2);
        assert_eq!(q.per_fog_mean(), vec![2.0, 1.0]);
        assert_eq!(q.per_fog_max(), vec![3.0, 1.0]);
        // tick skews: 1.0 and 3/2 → mean 1.25
        assert!((q.mean_skew() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn empty_timeline_is_balanced() {
        let q = QueueTimeline::default();
        assert_eq!(q.mean_skew(), 1.0);
        assert!(q.per_fog_mean().is_empty());
    }
}
