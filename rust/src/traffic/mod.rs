//! Request-level traffic subsystem: seeded workload generation (Poisson /
//! bursty / diurnal arrival processes), a multi-tenant event-driven
//! online serving fabric with per-tenant admission queues,
//! deficit-round-robin weighted-fair scheduling, adaptive
//! micro-batching, admission control with backpressure, and SLO metrics
//! (latency percentiles, goodput, shed rate, queue-depth timelines,
//! Jain fairness index). The loop feeds queue-skew back into the
//! dual-mode scheduler so diffusion / IEP replans fire mid-run, per
//! `(model, dataset)` service — `repro loadtest` is the CLI entry
//! point, `--tenant` (repeatable) declares the tenants.
//!
//! Execution is priced either analytically (ω models; bit-reproducible)
//! or measured (`--exec measured`): real CSR batched BSP kernels per
//! micro-batch — one cached `BatchedBspPlan` per distinct
//! `(model, dataset)`, all sharing one persistent worker pool — with
//! the observations fed back into profiler calibration (see
//! `measured`).
//!
//! The chaos plane (`chaos`) injects seeded, repeatable fog faults
//! (`--fault crash@.. / slow@.. / link@..`) and drives the recovery
//! machinery: an EWMA straggler detector, hedged re-dispatch on the
//! measured path, and emergency evacuation of a dead fog's partitions
//! through the dual-mode rescheduler. Outcomes (time-to-detect,
//! time-to-recover, SLO damage) land in the report's `faults` section.
//!
//! The streaming-graph plane (`--churn add-edge@rate=… / del-edge@… /
//! add-vertex@… / del-vertex@…`) evolves every service's topology in
//! place at replan barriers through the incremental topology engine
//! (`graph::delta`): seeded repeatable mutation streams, in-place CSR
//! deltas with tombstones, boundary-only repartitioning and
//! partition-scoped invalidation — only touched fogs re-ground,
//! untouched fogs stay bit-identical. Outcomes land in the report's
//! `churn` section.

pub mod arrival;
pub mod batcher;
pub mod chaos;
pub mod fabric;
pub mod measured;
pub mod sim;
pub mod slo;
pub mod tenant;

pub use arrival::{ArrivalKind, ArrivalProcess};
pub use batcher::{bucket, BatchPolicy, MicroBatcher};
pub use chaos::{chaos_json, ChaosPlan, ChaosReport, EwmaDetector,
                FaultKind, FaultOutcome, FaultSpec};
pub use fabric::{fabric_json, jain_index, run_fabric,
                 run_fabric_chaos, run_fabric_churn,
                 run_fabric_traced, FabricReport, PlanCacheEntry,
                 TenantInput, TenantReport};
pub use measured::{BucketRow, MeasuredExec};
pub use sim::{doc_json, report_json, run_loadtest,
              run_loadtest_chaos, run_loadtest_churn,
              run_loadtest_traced, ExecMode, LoadtestReport,
              PipelineReport, TrafficConfig};
pub use slo::{LatencySummary, QueueTimeline, SloReport};
pub use tenant::{FairPolicy, Tenant, TenantSpec};
