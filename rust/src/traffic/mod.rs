//! Request-level traffic subsystem: seeded workload generation (Poisson /
//! bursty / diurnal arrival processes), an event-driven online serving
//! loop with per-fog queues, adaptive micro-batching, admission control
//! with backpressure, and SLO metrics (latency percentiles, goodput,
//! shed rate, queue-depth timelines). The loop feeds queue-skew back into
//! the dual-mode scheduler so diffusion / IEP replans fire mid-run —
//! `repro loadtest` is the CLI entry point.
//!
//! Execution is priced either analytically (ω models; bit-reproducible)
//! or measured (`--exec measured`): real CSR batched BSP kernels per
//! micro-batch with the observations fed back into profiler calibration
//! (see `measured`).

pub mod arrival;
pub mod batcher;
pub mod measured;
pub mod sim;
pub mod slo;

pub use arrival::{ArrivalKind, ArrivalProcess};
pub use batcher::{bucket, BatchPolicy, MicroBatcher};
pub use measured::{BucketRow, MeasuredExec};
pub use sim::{doc_json, report_json, run_loadtest, ExecMode,
              LoadtestReport, TrafficConfig};
pub use slo::{LatencySummary, QueueTimeline, SloReport};
