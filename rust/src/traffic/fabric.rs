//! The multi-tenant serving fabric: N seeded request streams — one per
//! tenant, each naming its own `(model, dataset)` workload, arrival
//! process, rate, fair-share weight and SLO — merged into ONE
//! deterministic event loop over the shared fog cluster.
//!
//! Architecture (generalizing the single-workload loop this module
//! replaced; `sim::run_loadtest` is now the one-tenant mapping):
//!
//! * **Tenants** own an arrival stream, an admission queue
//!   (`MicroBatcher` + per-tenant queue cap with shed/spill), a weight
//!   and an SLO. Everything about a tenant keys off its NAME, so runs
//!   are invariant under `--tenant` declaration order.
//! * **Services** are distinct `(model, dataset)` pairs — the fabric's
//!   plan cache. Tenants sharing a service share its placement, its
//!   grounding pipeline run, its analytic ω estimates, and (in
//!   measured mode) one `BatchedBspPlan` + per-fog online profilers;
//!   the cache records builds/hits so a plan is provably constructed
//!   once per key. All measured plans execute on ONE persistent
//!   worker-pool handle (`--kernel-threads` budget), shared across
//!   plans and survived by replans.
//! * **Stations** — collection and BSP execution — are shared: the
//!   whole point of the fabric is contention between tenants on real
//!   shared fog resources. The stations pipeline with configurable
//!   depth (`--pipeline-depth`): collection/compression of batch N+1
//!   overlaps the kernels of up to `depth` earlier batches. Depth 1
//!   (default) is the classic two-station overlap and keeps reports
//!   bit-identical to the pre-pipeline fabric; at depth > 1 in
//!   measured mode, released batches are SUBMITTED into the pipelined
//!   executor (`MeasuredExec::submit_batch` over `exec::BspPipeline`)
//!   and their timeline/SLO accounting is deferred to collection, in
//!   submission order. Window-full waits are accounted as the
//!   distinct `pipeline_stall` phase, never as queueing or kernel
//!   time.
//! * **Admission arbitration** — when several tenants have releasable
//!   batches, deficit-round-robin weighted-fair queuing (`FairPolicy::
//!   Drr`) picks who runs: each tenant earns credit in proportion to
//!   its weight and pays its batch's padded bucket size, so a bursty
//!   tenant saturating the cluster cannot starve a low-weight
//!   tenant's SLO. `FairPolicy::Fifo` (serve the globally oldest
//!   head-of-line request) is kept as the control the fairness claim
//!   is measured against.
//! * **Scheduling** — the dual-mode scheduler ticks per service:
//!   per-model ω (or η-scaled ω′ from that service's profilers in
//!   measured mode) drive diffusion / IEP replans of that service's
//!   placement, exactly as in the single-workload loop.
//!
//! Reported per tenant: p50/p95/p99/mean latency, goodput, shed/spill,
//! batches — plus a Jain fairness index over weight-normalized
//! goodput and the plan-cache hit counts, all surfaced in
//! BENCH_loadtest.json.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use crate::fog::{Cluster, LoadTrace};
use crate::graph::delta::{validate_churn_specs, ChurnPlan, ChurnSpec,
                          TopologyEngine, CHURN_SALT};
use crate::graph::{DatasetSpec, Graph};
use crate::obs::recorder::{Recorder, Ring};
use crate::obs::span::{Phase, SpanEvent, NO_TENANT};
use crate::profile::{Cardinality, PerfModel};
use crate::runtime::kernels::DEFAULT_TASK_DEADLINE_S;
use crate::runtime::{Engine, EngineError};
use crate::scheduler::diffusion::estimate_times;
use crate::scheduler::{schedule, SchedulerConfig, SchedulerDecision};
use crate::serving::collection::{self, CollectionIndex};
use crate::serving::pipeline::{self, Placement, ServeOpts};
use crate::util::cli::MAX_PIPELINE_DEPTH;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::rng::mix64;

use super::arrival::ArrivalProcess;
use super::batcher::{bucket, MicroBatcher};
use super::chaos::{window_damage, ChaosPlan, ChaosReport,
                   EwmaDetector, FaultKind, FaultOutcome, FaultSpec};
use super::measured::{BucketRow, MeasuredExec};
use super::sim::{report_json, ExecMode, LoadtestReport,
                 PipelineReport, TrafficConfig};
use super::slo::{QueueTimeline, SloReport};
use super::tenant::{FairPolicy, Tenant};

/// Fraction of a batch's execution cost that is fixed per batch (kernel
/// launch, BSP barriers); the rest scales with the padded bucket size.
const EXEC_FIXED_FRAC: f64 = 0.85;
/// Fixed share of the per-window collection cost; the rest grows with
/// batch fill (larger windows admit marginally more device traffic).
const COLL_FIXED_FRAC: f64 = 0.85;
/// One micro-batch released but not yet accounted into the simulation
/// timeline: at `--pipeline-depth` > 1 the measured fabric submits
/// batches into the pipelined executor (`MeasuredExec::submit_batch`)
/// and defers all timeline/SLO accounting to collection time, in
/// strict submission order, so the deferred path stays deterministic
/// given the measured kernel seconds.
struct DeferredBatch {
    service: usize,
    /// Canonical tenant index the batch belongs to.
    tenant: usize,
    /// Request arrival times in the batch (for latency accounting).
    arrivals: Vec<f64>,
    /// Actual batch fill and its padded power-of-two bucket.
    b: usize,
    slot: usize,
    t_form: f64,
    coll_done: f64,
    /// 1 / link bandwidth factor at formation time (1.0 when no link
    /// fault was active): the deferred sync share is priced with the
    /// conditions the batch was released under, not collection-time
    /// conditions, so the deferred path stays order-deterministic.
    link_inv: f64,
}

/// Live chaos state for one fabric run: the seeded fault schedule, the
/// EWMA straggler/crash detector, per-fault detection and recovery
/// marks, and the completion/shed samples SLO damage is computed from
/// when the run summarizes.
struct ChaosRuntime {
    plan: ChaosPlan,
    det: EwmaDetector,
    /// Per fault (canonical plan order): virtual detection time.
    det_t: Vec<Option<f64>>,
    /// Per fault: virtual recovery time.
    rec_t: Vec<Option<f64>>,
    /// Per fault: the emergency replan already evacuated this crash.
    evacuated: Vec<bool>,
    /// Per fault: batches that needed a hedged/detoured dispatch while
    /// the fault was active.
    hedge_per_fault: Vec<u64>,
    /// Completion records `(finish_t, latency_s, within_slo)`.
    samples: Vec<(f64, f64, bool)>,
    /// Arrival times of requests shed while queues were full.
    shed_times: Vec<f64>,
    /// Masks last pushed into the measured executors, so the fabric
    /// only quiesces the pipelined window when the masks change.
    applied: Option<(Vec<bool>, Vec<f64>)>,
    /// Latest accounted batch finish (virtual) — the "now" emergency
    /// recovery decisions run at.
    last_finish: f64,
    task_deadline_s: f64,
}

impl ChaosRuntime {
    fn new(plan: ChaosPlan, n_fogs: usize,
           task_deadline_s: f64) -> ChaosRuntime {
        let nf = plan.faults.len();
        ChaosRuntime {
            plan,
            det: EwmaDetector::new(n_fogs),
            det_t: vec![None; nf],
            rec_t: vec![None; nf],
            evacuated: vec![false; nf],
            hedge_per_fault: vec![0; nf],
            samples: Vec::new(),
            shed_times: Vec::new(),
            applied: None,
            last_finish: 0.0,
            task_deadline_s,
        }
    }

    /// Has some crash fault on `fog` already been evacuated? (Its
    /// partitions are gone, so the fog prices at zero afterwards.)
    fn evacuated_fog(&self, fog: usize) -> bool {
        self.plan.faults.iter().enumerate().any(|(fi, f)| {
            matches!(f.spec.kind, FaultKind::Crash { fog: g, .. }
                     if g == fog)
                && self.evacuated[fi]
        })
    }

    /// Feed one accounted batch into the detector and run per-class
    /// detection/recovery bookkeeping at the batch's finish time.
    /// `per_fog` is the batch's per-fog virtual execution seconds (0 =
    /// no work on that fog).
    fn observe_batch(&mut self, start_exec: f64, finish: f64,
                     per_fog: &[f64]) {
        self.last_finish = self.last_finish.max(finish);
        for (j, &d) in per_fog.iter().enumerate() {
            if d <= 0.0 {
                continue;
            }
            self.det.start(j, start_exec);
            if self.plan.crashed(j, start_exec) {
                // a dead fog never answers: leave the task outstanding
                // so it keeps aging toward the EWMA deadline, and
                // attribute the hedged dispatch to the fault
                for (fi, f) in self.plan.faults.iter().enumerate() {
                    if matches!(f.spec.kind,
                                FaultKind::Crash { fog: g, .. }
                                if g == j)
                        && start_exec >= f.t_on
                    {
                        self.hedge_per_fault[fi] += 1;
                    }
                }
                continue;
            }
            // straggler detection compares the sample against the
            // deadline that existed BEFORE the sample updates it
            if self.det.primed(j) && d > self.det.deadline(j) {
                for (fi, f) in self.plan.faults.iter().enumerate() {
                    if self.det_t[fi].is_none()
                        && finish >= f.t_on
                        && matches!(f.spec.kind,
                                    FaultKind::Slow { fog: g, .. }
                                    if g == j)
                    {
                        self.det_t[fi] = Some(finish);
                    }
                }
            }
            self.det.complete(j, d);
        }
        for fi in 0..self.plan.faults.len() {
            let f = self.plan.faults[fi];
            match f.spec.kind {
                FaultKind::Crash { fog, rejoin } => {
                    if self.det_t[fi].is_none()
                        && finish >= f.t_on
                        && self.det.overdue(fog, finish)
                    {
                        self.det_t[fi] = Some(finish);
                    }
                    if self.rec_t[fi].is_none() {
                        if let Some(r) = rejoin {
                            if finish >= r {
                                self.rec_t[fi] = Some(finish);
                            }
                        }
                    }
                }
                FaultKind::Slow { until, .. } => {
                    if self.rec_t[fi].is_none() {
                        if let Some(u) = until {
                            if finish >= u {
                                self.rec_t[fi] = Some(finish);
                            }
                        }
                    }
                }
                FaultKind::Link { until, .. } => {
                    // a degraded uplink is visible the moment a batch
                    // priced under it completes
                    if self.det_t[fi].is_none() && finish >= f.t_on {
                        self.det_t[fi] = Some(finish);
                    }
                    if self.rec_t[fi].is_none() {
                        if let Some(u) = until {
                            if finish >= u {
                                self.rec_t[fi] = Some(finish);
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Account one collected pipelined batch into the simulation timeline
/// — the exact accounting the depth-1 measured branch performs inline
/// at release time, deferred to collection: virtual Kernel/Sync spans
/// from the measured layer seconds, admission gate
/// `start_exec = coll_done.max(finish(N - depth))`, SLO counters and
/// per-request latencies. The blocking wait inside
/// `MeasuredExec::collect_batch` is the pipeline's backpressure stall;
/// it is measured in wall time and accounted as `Phase::PipelineStall`
/// — NOT `Queue` or `Kernel` — so OnlineProfiler observations and the
/// headline queue-wait stay queueing-free (the profiler consumed pure
/// worker-measured kernel seconds already).
#[allow(clippy::too_many_arguments)]
fn account_pipelined_batch(
    meta: DeferredBatch,
    services: &mut [Service<'_>],
    tenants: &mut [TenantState],
    aggregate: &mut LoadtestReport,
    finishes: &mut Vec<f64>,
    exec_free: &mut f64,
    exec_busy: &mut f64,
    batch_total: &mut usize,
    latencies: &mut Vec<f64>,
    depth: usize,
    node_mult: &[f64],
    load_trace: &LoadTrace,
    rec: &Arc<Recorder>,
    ring: &Arc<Ring>,
    stall_total: &mut f64,
    chaos: Option<&mut ChaosRuntime>,
) {
    let tid = meta.tenant as u32;
    let reg = rec.registry();
    let us = |t: f64| t * 1e6;
    let sw = Instant::now();
    let layer_seconds = services[meta.service]
        .measured
        .as_mut()
        .expect("deferred batch on a measured service")
        .collect_batch();
    let stall = sw.elapsed().as_secs_f64();
    *stall_total += stall;
    reg.record_phase(tid, -1, Phase::PipelineStall, stall);
    rec.span(ring,
             SpanEvent::new(Phase::PipelineStall, tid,
                            us(meta.t_form), stall * 1e6)
                 .count(meta.b)
                 .on_wall());
    let start_exec = meta.coll_done.max(if finishes.len() >= depth {
        finishes[finishes.len() - depth]
    } else {
        0.0
    });
    let step = start_exec.max(0.0) as usize;
    let mut t_cursor = start_exec;
    let mut total = 0f64;
    let n = node_mult.len();
    let mut fog_dur: Vec<f64> = if chaos.is_some() {
        vec![0.0; n]
    } else {
        Vec::new()
    };
    for (layer, layer_times) in layer_seconds.into_iter().enumerate() {
        let mut mx = 0f64;
        for (j, &h) in layer_times.iter().enumerate() {
            let load = load_trace.at(step, j).clamp(0.0, 0.85);
            let mut scaled = h * node_mult[j] / (1.0 - load);
            if let Some(c) = &chaos {
                // a dead fog's task ages to the EWMA deadline on the
                // virtual timeline before its hedge's result (already
                // attributed to this fog by task tag) lands
                if c.plan.crashed(j, start_exec)
                    && !c.evacuated_fog(j)
                {
                    scaled += c.det.deadline(j);
                }
            }
            mx = mx.max(scaled);
            if !fog_dur.is_empty() {
                fog_dur[j] += scaled;
            }
            if scaled > 0.0 {
                let mut ev = SpanEvent::new(Phase::Kernel, tid,
                                            us(t_cursor), us(scaled))
                    .fog(j)
                    .count(meta.b);
                ev.layer = layer as i32;
                rec.span(ring, ev);
                reg.record_phase(tid, j as i32, Phase::Kernel, scaled);
            }
        }
        t_cursor += mx;
        total += mx;
    }
    let sync_t = services[meta.service].base_sync_s
        * meta.slot as f64
        * meta.link_inv;
    for j in 0..node_mult.len() {
        rec.span(ring, SpanEvent::new(Phase::Sync, tid, us(t_cursor),
                                      us(sync_t))
            .fog(j)
            .count(meta.b));
        reg.record_phase(tid, j as i32, Phase::Sync, sync_t);
    }
    let exec_time = total + sync_t;
    let finish = start_exec + exec_time;
    *exec_free = exec_free.max(finish);
    *exec_busy += exec_time;
    finishes.push(finish);
    aggregate.slo.batches += 1;
    *batch_total += meta.b;
    aggregate.slo.completed += meta.b;
    let t = &mut tenants[meta.tenant];
    t.slo.batches += 1;
    t.slo.completed += meta.b;
    for &a in &meta.arrivals {
        latencies.push(finish - a);
        t.latencies.push(finish - a);
    }
    if let Some(c) = chaos {
        let slo = t.slo.slo_s;
        for &a in &meta.arrivals {
            let l = finish - a;
            c.samples.push((finish, l, l <= slo));
        }
        c.observe_batch(start_exec, finish, &fog_dur);
    }
    rec.span(ring, SpanEvent::new(Phase::Reply, tid, us(finish), 0.0)
        .count(meta.b));
    reg.record_phase(tid, -1, Phase::Reply, 0.0);
}

/// Emergency replan: once a crash is DETECTED and the fog is still
/// dead (no rejoin yet), evacuate its partitions through the existing
/// dual-mode rescheduler — the dead fog's ω is priced prohibitively so
/// diffusion/IEP moves everything off it — and charge the evacuation
/// transfer as the distinct `Phase::Recovery` on the collection
/// station. The pipelined window was already drained by the caller
/// (replan barrier), so rebuilds see a quiesced plan.
#[allow(clippy::too_many_arguments)]
fn evacuate_detected_crashes(
    c: &mut ChaosRuntime,
    services: &mut [Service<'_>],
    aggregate: &mut LoadtestReport,
    cluster: &Cluster,
    cfg: &SchedulerConfig,
    coll_free: &mut f64,
    rec: &Arc<Recorder>,
    ring: &Arc<Ring>,
) -> Result<(), EngineError> {
    let now = c.last_finish;
    // a fog that rejoined before we got to evacuate needs no replan;
    // close the fault out so pipelined callers stop forcing barriers
    for (fi, f) in c.plan.faults.iter().enumerate() {
        if let FaultKind::Crash { rejoin: Some(r), .. } = f.spec.kind {
            if c.det_t[fi].is_some() && !c.evacuated[fi] && now >= r {
                c.evacuated[fi] = true;
            }
        }
    }
    let todo: Vec<(usize, usize)> = c
        .plan
        .faults
        .iter()
        .enumerate()
        .filter_map(|(fi, f)| match f.spec.kind {
            FaultKind::Crash { fog, rejoin }
                if c.det_t[fi].is_some()
                    && !c.evacuated[fi]
                    && rejoin.map_or(true, |r| now < r) =>
            {
                Some((fi, fog))
            }
            _ => None,
        })
        .collect();
    if todo.is_empty() {
        return Ok(());
    }
    let n = cluster.len();
    let us = |t: f64| t * 1e6;
    let reg = rec.registry();
    for (fi, dead) in todo {
        let mut evac_s = 0f64;
        let mut moved_any = false;
        for svc in services.iter_mut() {
            if n <= 1
                || matches!(svc.opts.placement,
                            Placement::SingleNode(_))
            {
                continue;
            }
            let eff: Vec<PerfModel> = match &svc.measured {
                Some(m) => m.scaled_omegas(),
                None => svc.omegas.clone(),
            };
            // price every currently-dead fog out of the placement; the
            // detector's deadline is the evidence, the rescheduler is
            // the mechanism
            let scaled: Vec<PerfModel> = (0..n)
                .map(|j| {
                    if j == dead || c.plan.crashed(j, now) {
                        scaled_model(&eff[j], 1e6)
                    } else {
                        scaled_model(&eff[j], 1.0)
                    }
                })
                .collect();
            let real_times =
                estimate_times(svc.g, &svc.assignment, n, &scaled);
            let decision = schedule(
                svc.g, &svc.spec, cluster, &svc.opts,
                &mut svc.assignment, &real_times, &scaled, cfg,
            );
            rec.span(ring, SpanEvent::new(Phase::Replan, NO_TENANT,
                                          us(now), 0.0)
                .because("fault-evacuation"));
            reg.record_phase(NO_TENANT, -1, Phase::Replan, 0.0);
            let moved = match decision {
                SchedulerDecision::Keep => false,
                SchedulerDecision::Diffused(_) => {
                    svc.diffusions += 1;
                    aggregate.slo.diffusions += 1;
                    true
                }
                SchedulerDecision::Replanned => {
                    svc.replans += 1;
                    aggregate.slo.replans += 1;
                    true
                }
            };
            if moved {
                moved_any = true;
                if let Some(m) = svc.measured.as_mut() {
                    m.rebuild(svc.g, &svc.assignment, &svc.model)?;
                    svc.rebuilds += 1;
                }
                svc.host_times =
                    estimate_times(svc.g, &svc.assignment, n, &eff);
                svc.coll_index =
                    CollectionIndex::build(svc.g, &svc.assignment, n);
                svc.coll_s = collection_transfer_s(
                    svc.g, &svc.payload, svc.dims, &svc.coll_index,
                    cluster, &svc.opts,
                );
                evac_s += svc.coll_s;
                rec.span(ring,
                         SpanEvent::new(Phase::Recovery, NO_TENANT,
                                        us(now), us(svc.coll_s))
                             .fog(dead)
                             .because("evacuate-dead-fog"));
                reg.record_phase(NO_TENANT, dead as i32,
                                 Phase::Recovery, svc.coll_s);
            }
        }
        // only a replan that actually moved work counts as evacuated —
        // `evacuated_fog` prices the fog at zero afterwards, which is
        // only sound once its partitions are gone
        c.evacuated[fi] = moved_any;
        if moved_any {
            // the evacuation transfer occupies the collection station
            let done = now + evac_s;
            *coll_free = coll_free.max(done);
            if c.rec_t[fi].is_none() {
                c.rec_t[fi] = Some(done);
            }
        }
    }
    // measured executors were rebuilt: force a mask re-push so the new
    // pipelines learn the crashed/slow state before the next batch
    c.applied = None;
    Ok(())
}

/// One tenant plus the workload inputs it runs against. `opts` must be
/// built for this tenant's model (`pipeline::mode_setup`); tenants
/// sharing a `(model, dataset)` service must pass identical
/// `opts`/`omegas` (they share the service's placement and plan).
pub struct TenantInput<'a> {
    pub tenant: Tenant,
    pub g: &'a Graph,
    pub spec: DatasetSpec,
    pub opts: ServeOpts,
    pub omegas: Vec<PerfModel>,
}

/// Per-tenant outcome of a fabric run.
#[derive(Clone, Debug, Default)]
pub struct TenantReport {
    pub name: String,
    pub model: String,
    pub dataset: String,
    pub arrival: &'static str,
    pub rps: f64,
    pub weight: f64,
    pub stream_seed: u64,
    pub slo: SloReport,
    /// Raw per-request fog-tier latencies (completion order).
    pub latencies: Vec<f64>,
    pub queue_len_max: usize,
    pub queue_len_mean: f64,
    /// Per-fog mean queue backlog (seconds of work), from the obs
    /// registry's per-second sampler — reported uniformly for EVERY
    /// tenant, not just the aggregate.
    pub per_fog_queue_depth_mean_s: Vec<f64>,
    /// Per-fog peak queue backlog (seconds of work).
    pub per_fog_queue_depth_max_s: Vec<f64>,
}

/// One plan-cache key's accounting: a `(model, dataset)` service is
/// built exactly once (`builds`), every further tenant binding to it
/// is a `hits`, and scheduler migrations rebuild its partition
/// structures in place (`rebuilds`, measured mode only — the worker
/// pool is respawned only if a worker panic poisoned it). Each entry
/// also carries its OWN grounding constants — the aggregate report's
/// single `base_*` fields describe only the canonical-first service,
/// so mixed-blend runs read per-service values from here.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PlanCacheEntry {
    pub model: String,
    pub dataset: String,
    pub builds: usize,
    pub hits: usize,
    pub rebuilds: usize,
    /// Grounding-run communication constants for THIS service.
    pub collection_s: f64,
    pub sync_s: f64,
    pub wire_bytes: usize,
}

/// Outcome of one fabric run: the legacy-shaped aggregate plus the
/// per-tenant breakdown, fairness index and plan-cache accounting.
#[derive(Clone, Debug, Default)]
pub struct FabricReport {
    pub aggregate: LoadtestReport,
    /// Canonical (name-sorted) order.
    pub tenants: Vec<TenantReport>,
    /// Jain index over weight-normalized per-tenant goodput
    /// (`goodput_i / weight_i`): 1.0 = perfectly weighted-fair.
    pub fairness_jain: f64,
    pub fair: FairPolicy,
    /// Canonical (key-sorted) order.
    pub plan_cache: Vec<PlanCacheEntry>,
}

/// Jain's fairness index over non-negative allocations:
/// `(Σx)² / (n·Σx²)` ∈ (0, 1], with 1.0 iff all equal. Degenerate
/// all-zero input reports 1.0 (nothing was unfairly shared).
pub fn jain_index(xs: &[f64]) -> f64 {
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if xs.is_empty() || sq <= 0.0 {
        1.0
    } else {
        sum * sum / (xs.len() as f64 * sq)
    }
}

fn scaled_model(m: &PerfModel, k: f64) -> PerfModel {
    PerfModel {
        beta_v: m.beta_v * k,
        beta_n: m.beta_n * k,
        intercept: m.intercept * k,
        r2: m.r2,
    }
}

/// Deterministic per-window collection cost for a layout: the slowest
/// fog's analytic transfer time (device-side packing pipelines with the
/// previous window's upload, so it is off the steady-state critical
/// path, like the fog-side unpack thread).
fn collection_transfer_s(
    g: &Graph,
    payload: &[f32],
    dims: usize,
    idx: &CollectionIndex,
    cluster: &Cluster,
    opts: &ServeOpts,
) -> f64 {
    let coll = collection::collect_indexed(g, idx, payload, dims, cluster,
                                           &opts.codec, opts.devices,
                                           opts.wan);
    coll.per_fog_transfer_s.iter().cloned().fold(0f64, f64::max)
}

/// Per-fog execution seconds for one inference at simulation time `t`:
/// host-model prediction × node capability × background-load slowdown.
fn exec_per_fog(
    host_times: &[f64],
    node_mult: &[f64],
    trace: &LoadTrace,
    t: f64,
) -> Vec<f64> {
    let step = t.max(0.0) as usize;
    host_times
        .iter()
        .zip(node_mult)
        .enumerate()
        .map(|(j, (&h, &m))| {
            let load = trace.at(step, j).clamp(0.0, 0.85);
            h * m / (1.0 - load)
        })
        .collect()
}

/// Per-service incremental-topology state under `--churn`: the
/// engine owns the evolving delta CSR plus the partition-scoped
/// serving structures; the plan is that service's seeded mutation
/// stream (drawn once per replan barrier).
struct ChurnState {
    engine: TopologyEngine,
    plan: ChurnPlan,
}

/// One `(model, dataset)` plan-cache entry at runtime.
struct Service<'a> {
    model: String,
    dataset: String,
    g: &'a Graph,
    spec: DatasetSpec,
    opts: ServeOpts,
    omegas: Vec<PerfModel>,
    assignment: Vec<u32>,
    /// Placement-static collection index, rebuilt only when a
    /// diffusion / replan / evacuation moves `assignment`.
    coll_index: CollectionIndex,
    payload: Vec<f32>,
    dims: usize,
    coll_s: f64,
    base_sync_s: f64,
    base_wire_bytes: usize,
    host_times: Vec<f64>,
    measured: Option<MeasuredExec>,
    /// `Some` exactly when the run declared `--churn` specs: the
    /// service's topology then evolves in place at replan barriers.
    churn: Option<ChurnState>,
    scheduler_on: bool,
    /// Canonical tenant indices bound to this service.
    tenants: Vec<usize>,
    hits: usize,
    rebuilds: usize,
    diffusions: usize,
    replans: usize,
    oom: bool,
    /// Grounding actually ran (false when an earlier service's OOM
    /// aborted the run first) — the plan-cache `builds` witness.
    grounded: bool,
}

/// Per-tenant runtime state in the event loop.
struct TenantState {
    tenant: Tenant,
    service: usize,
    arrivals: Vec<f64>,
    next_arrival: usize,
    batcher: MicroBatcher,
    queue_cap: usize,
    slo: SloReport,
    latencies: Vec<f64>,
    qlen_sum: usize,
    queue_len_max: usize,
}

/// Deficit-round-robin arbiter over the canonical tenant order.
struct DrrState {
    deficit: Vec<f64>,
    quantum: Vec<f64>,
    cursor: usize,
}

impl DrrState {
    fn new(weights: &[f64], max_batch: usize) -> DrrState {
        let w_max = weights.iter().cloned().fold(0f64, f64::max).max(1e-12);
        // the max-weight tenant earns one full padded batch of credit
        // per replenish round, others proportionally less — so a scan
        // after one replenish always finds an eligible candidate
        let unit = bucket(max_batch) as f64;
        DrrState {
            deficit: vec![0.0; weights.len()],
            quantum: weights.iter().map(|w| w / w_max * unit).collect(),
            cursor: 0,
        }
    }

    /// Pick the next tenant to serve among `ready` (canonical indices,
    /// ascending), each with its head-batch cost. Replenishes credit
    /// only when no ready tenant can pay — an idle tenant never banks
    /// credit it did not need. The replenish jumps straight to the
    /// first round at which some candidate qualifies (identical
    /// deficits and selection as adding one quantum at a time, but
    /// O(1) even for extreme weight ratios).
    fn pick(&mut self, ready: &[usize], cost: &[f64]) -> usize {
        assert!(!ready.is_empty());
        let n = self.deficit.len();
        loop {
            for off in 0..n {
                let i = (self.cursor + off) % n;
                if ready.contains(&i) && self.deficit[i] >= cost[i] {
                    self.deficit[i] -= cost[i];
                    self.cursor = (i + 1) % n;
                    return i;
                }
            }
            // rounds until the first candidate can pay (>= 1; quanta
            // are positive because run_fabric rejects w <= 0)
            let rounds = ready
                .iter()
                .map(|&i| {
                    ((cost[i] - self.deficit[i]) / self.quantum[i])
                        .ceil()
                        .max(1.0)
                })
                .fold(f64::INFINITY, f64::min);
            for &i in ready {
                self.deficit[i] += rounds * self.quantum[i];
            }
        }
    }
}

/// Run the multi-tenant serving fabric. See the module docs; with one
/// tenant this is step-for-step the legacy single-stream loop
/// (`sim::run_loadtest` delegates here).
pub fn run_fabric<'a>(
    cluster: &Cluster,
    inputs: Vec<TenantInput<'a>>,
    base: &TrafficConfig,
    fair: FairPolicy,
    engine: &mut Engine,
) -> Result<FabricReport, EngineError> {
    run_fabric_traced(cluster, inputs, base, fair, engine,
                      &Recorder::disabled())
}

/// `run_fabric` with a flight recorder attached. Span emission is
/// gated on the recorder being enabled, but every emission is paired
/// with an unconditional fold into the recorder's ALWAYS-live metrics
/// registry — the report's `phase_breakdown` and per-tenant queue
/// timelines come from the registry, so analytic reports are
/// bit-identical with tracing on or off. All fabric spans carry the
/// VIRTUAL clock (simulated seconds → µs); only the measured
/// executor's worker-pool spans (attached here via
/// `MeasuredExec::attach_recorder`) are wall-clock.
pub fn run_fabric_traced<'a>(
    cluster: &Cluster,
    inputs: Vec<TenantInput<'a>>,
    base: &TrafficConfig,
    fair: FairPolicy,
    engine: &mut Engine,
    rec: &Arc<Recorder>,
) -> Result<FabricReport, EngineError> {
    run_fabric_chaos(cluster, inputs, base, fair, engine, rec, &[],
                     DEFAULT_TASK_DEADLINE_S)
}

/// `run_fabric_traced` plus the chaos plane: a seeded fault schedule
/// (`--fault` specs, canonicalized and jittered by `ChaosPlan` so runs
/// are bit-deterministic and invariant under declaration order) is
/// applied to the run — crashed fogs withhold replies (measured mode
/// injects `Inject::DropReply` into the worker; the pipeline hedges
/// the task to a healthy fog after `task_deadline_s`), slow fogs price
/// and execute at `factor`× speed, and degraded links inflate
/// collection/sync transfer shares. An EWMA detector over per-fog
/// task durations flags dead/straggling fogs; a detected crash
/// triggers an emergency evacuation replan (`Phase::Recovery`).
/// Outcomes land in the report's `faults` section: per fault,
/// time-to-detect, time-to-recover and SLO damage over the fault
/// window. With `faults` empty this is exactly `run_fabric_traced` —
/// every chaos hook is gated, so reports stay byte-identical.
#[allow(clippy::too_many_arguments)]
pub fn run_fabric_chaos<'a>(
    cluster: &Cluster,
    inputs: Vec<TenantInput<'a>>,
    base: &TrafficConfig,
    fair: FairPolicy,
    engine: &mut Engine,
    rec: &Arc<Recorder>,
    faults: &[FaultSpec],
    task_deadline_s: f64,
) -> Result<FabricReport, EngineError> {
    run_fabric_churn(cluster, inputs, base, fair, engine, rec, faults,
                     task_deadline_s, &[])
}

/// `run_fabric_chaos` plus the streaming-graph plane: a seeded,
/// canonicalized topology-mutation stream (`--churn` specs) applied to
/// every service's graph at each replan barrier. Deltas land in place
/// on an incremental CSR ([`TopologyEngine`]); only the fogs a round
/// actually touches are re-grounded (partition-scoped invalidation —
/// untouched fogs keep their sub-CSRs, plan rows and fingerprints
/// bit-for-bit), and the dual-mode scheduler consumes the resulting
/// skew through engine-recounted cardinalities at the same barriers.
/// With `churn` empty this is exactly `run_fabric_chaos` — every hook
/// is gated, so churn-free reports stay byte-identical.
#[allow(clippy::too_many_arguments)]
pub fn run_fabric_churn<'a>(
    cluster: &Cluster,
    inputs: Vec<TenantInput<'a>>,
    base: &TrafficConfig,
    fair: FairPolicy,
    engine: &mut Engine,
    rec: &Arc<Recorder>,
    faults: &[FaultSpec],
    task_deadline_s: f64,
    churn: &[ChurnSpec],
) -> Result<FabricReport, EngineError> {
    assert!(!inputs.is_empty(), "fabric needs at least one tenant");
    assert!(base.duration_s > 0.0);
    let n = cluster.len();
    if !churn.is_empty() {
        validate_churn_specs(churn)
            .map_err(EngineError::Unsupported)?;
        if base.exec == ExecMode::Measured {
            return Err(EngineError::Unsupported(
                "--churn requires analytic execution: measured plans \
                 pin a fixed topology in the worker pool (incremental \
                 plan rebuilds are ROADMAP item 5 territory)"
                    .into(),
            ));
        }
        if !faults.is_empty() {
            return Err(EngineError::Unsupported(
                "--churn cannot be combined with --fault: the chaos \
                 evacuation replans against the static grounding graph"
                    .into(),
            ));
        }
        if base.scheduler_period_s <= 0.0 {
            return Err(EngineError::Unsupported(
                "--churn requires a positive --scheduler-period: \
                 topology deltas apply at replan barriers"
                    .into(),
            ));
        }
    }
    if !(task_deadline_s.is_finite() && task_deadline_s > 0.0) {
        return Err(EngineError::Unsupported(format!(
            "task deadline must be positive and finite (got \
             {task_deadline_s})"
        )));
    }
    for f in faults {
        f.validate(n, base.duration_s)
            .map_err(EngineError::Unsupported)?;
    }
    let mut chaos = if faults.is_empty() {
        None
    } else {
        Some(ChaosRuntime::new(ChaosPlan::new(faults, base.seed), n,
                               task_deadline_s))
    };
    // same recoverable-error contract as kernel_threads: a zero or
    // absurd depth is an input error, not a panic (CLI exits 2 on it)
    if base.pipeline_depth == 0
        || base.pipeline_depth > MAX_PIPELINE_DEPTH
    {
        return Err(EngineError::Unsupported(format!(
            "pipeline depth must be in 1..={MAX_PIPELINE_DEPTH} (got \
             {})",
            base.pipeline_depth
        )));
    }
    // depth D: collection/compression of batch N+1 overlaps the
    // kernels of up to D earlier batches; D = 1 is the classic
    // two-station overlap (collect k over execute k-1), bit-identical
    // to the pre-pipeline fabric
    let pd = base.pipeline_depth;
    let gate_depth = pd + 1;
    // recoverable input errors on the library path too (same contract
    // as BatchedBspPlan's kernel_threads validation), not panics —
    // callers constructing Tenants directly bypass TenantSpec::parse
    for inp in &inputs {
        let t = &inp.tenant;
        if !t.rps.is_finite() || t.rps <= 0.0 {
            return Err(EngineError::Unsupported(format!(
                "tenant {:?}: rps must be positive and finite (got \
                 {})",
                t.name, t.rps
            )));
        }
        if !t.weight.is_finite() || t.weight <= 0.0 {
            return Err(EngineError::Unsupported(format!(
                "tenant {:?}: weight must be positive and finite (got \
                 {}); a zero-weight tenant would never be scheduled",
                t.name, t.weight
            )));
        }
        if inp.omegas.len() != n {
            return Err(EngineError::Unsupported(format!(
                "tenant {:?}: {} ω models for a {n}-fog cluster",
                t.name,
                inp.omegas.len()
            )));
        }
    }

    // ---- canonical tenant order (name-sorted, declaration-free) ---------
    let mut inputs = inputs;
    inputs.sort_by(|a, b| a.tenant.name.cmp(&b.tenant.name));
    for w in inputs.windows(2) {
        if w[0].tenant.name == w[1].tenant.name {
            return Err(EngineError::Unsupported(format!(
                "duplicate tenant name {:?}: tenant identities must \
                 be unique (set name=... on the --tenant spec)",
                w[0].tenant.name
            )));
        }
    }

    // ---- plan cache: one service per distinct (model, dataset) ----------
    let mut key_to_service: BTreeMap<(String, String), usize> =
        BTreeMap::new();
    let mut services: Vec<Service<'a>> = Vec::new();
    let mut tenants: Vec<TenantState> = Vec::new();
    for (ti, inp) in inputs.into_iter().enumerate() {
        let key =
            (inp.tenant.model.clone(), inp.tenant.dataset.clone());
        let si = match key_to_service.get(&key) {
            Some(&si) => {
                // a cache hit drops this tenant's opts/omegas in favor
                // of the service's; that is only sound if they are the
                // same — enforce the documented precondition instead
                // of silently repricing the tenant with another's
                // models
                let svc = &services[si];
                let same_omegas = svc.omegas.len() == inp.omegas.len()
                    && svc.omegas.iter().zip(&inp.omegas).all(
                        |(a, b)| {
                            a.beta_v == b.beta_v
                                && a.beta_n == b.beta_n
                                && a.intercept == b.intercept
                        },
                    );
                if !same_omegas
                    || format!("{:?}", svc.opts)
                        != format!("{:?}", inp.opts)
                {
                    return Err(EngineError::Unsupported(format!(
                        "tenant {:?} shares service ({}, {}) but \
                         passes different opts/ω models than the \
                         tenant that built it",
                        inp.tenant.name, key.0, key.1
                    )));
                }
                services[si].hits += 1;
                si
            }
            None => {
                let si = services.len();
                key_to_service.insert(key.clone(), si);
                services.push(Service {
                    model: key.0,
                    dataset: key.1,
                    g: inp.g,
                    spec: inp.spec,
                    opts: inp.opts,
                    omegas: inp.omegas,
                    assignment: Vec::new(),
                    coll_index: CollectionIndex::empty(cluster.len()),
                    payload: Vec::new(),
                    dims: 0,
                    coll_s: 0.0,
                    base_sync_s: 0.0,
                    base_wire_bytes: 0,
                    host_times: Vec::new(),
                    measured: None,
                    churn: None,
                    scheduler_on: false,
                    tenants: Vec::new(),
                    hits: 0,
                    rebuilds: 0,
                    diffusions: 0,
                    replans: 0,
                    oom: false,
                    grounded: false,
                });
                si
            }
        };
        services[si].tenants.push(ti);
        let queue_cap =
            inp.tenant.queue_cap.max(base.batch.max_batch);
        tenants.push(TenantState {
            tenant: inp.tenant,
            service: si,
            arrivals: Vec::new(),
            next_arrival: 0,
            batcher: MicroBatcher::new(base.batch),
            queue_cap,
            slo: SloReport {
                slo_s: 0.0,
                duration_s: base.duration_s,
                ..Default::default()
            },
            latencies: Vec::new(),
            qlen_sum: 0,
            queue_len_max: 0,
        });
    }
    for t in tenants.iter_mut() {
        t.slo.slo_s = t.tenant.slo_s;
    }

    // note: services are created in canonical TENANT order, which
    // makes service creation order itself declaration-independent

    // lifecycle spans are emitted from this single-threaded event loop
    // only, so one single-producer ring holds them all; the registry
    // fold (`reg`) runs unconditionally so phase accounting exists
    // even when span recording is off
    let ring = rec.ring();
    let reg = rec.registry();
    let us = |t: f64| t * 1e6;

    // ---- ground every service with one real pipeline run ----------------
    let mut aggregate = LoadtestReport {
        exec_mode: base.exec,
        engine: engine.backend_name().to_string(),
        kernel_threads: if base.exec == ExecMode::Measured {
            base.kernel_threads.max(1)
        } else {
            1
        },
        simd: crate::runtime::kernels::simd::name().to_string(),
        ..Default::default()
    };
    aggregate.slo.slo_s = base.slo_s;
    aggregate.slo.duration_s = base.duration_s;
    let cfg = SchedulerConfig::default();
    let mut shared_pool = None;
    for (si, svc) in services.iter_mut().enumerate() {
        if aggregate.slo.oom {
            // an earlier service already aborted the run; don't pay
            // for grounding (or plan builds) the run will never use
            break;
        }
        svc.grounded = true;
        svc.assignment = pipeline::place(svc.g, cluster, &svc.opts,
                                         &svc.omegas, &svc.spec);
        let (payload, dims) = pipeline::query_payload(
            svc.g, &svc.spec, svc.opts.window_start);
        let ground = pipeline::serve_with_assignment(
            svc.g, &svc.spec, cluster, &svc.opts, &svc.assignment,
            &payload, dims, engine,
        )?;
        svc.payload = payload;
        svc.dims = dims;
        svc.coll_index =
            CollectionIndex::build(svc.g, &svc.assignment, n);
        svc.coll_s = collection_transfer_s(
            svc.g, &svc.payload, svc.dims, &svc.coll_index, cluster,
            &svc.opts,
        );
        svc.base_sync_s = ground.sync_s;
        svc.base_wire_bytes = ground.wire_bytes;
        if si == 0 {
            aggregate.base_collection_s = svc.coll_s;
            aggregate.base_sync_s = svc.base_sync_s;
            aggregate.base_wire_bytes = svc.base_wire_bytes;
        }
        if ground.oom {
            svc.oom = true;
            aggregate.slo.oom = true;
            continue;
        }
        if base.exec == ExecMode::Measured {
            let kt = base.kernel_threads.max(1);
            let mut m = match &shared_pool {
                // every (model, dataset) plan shares the first
                // service's worker pool: one --kernel-threads thread
                // budget for the whole fabric
                Some(pool) => MeasuredExec::with_pool(
                    svc.g, &svc.assignment, n, &svc.model,
                    svc.spec.name, &svc.payload, svc.dims,
                    svc.spec.classes, &svc.omegas, engine, kt,
                    std::sync::Arc::clone(pool),
                )?,
                None => MeasuredExec::new(
                    svc.g, &svc.assignment, n, &svc.model,
                    svc.spec.name, &svc.payload, svc.dims,
                    svc.spec.classes, &svc.omegas, engine, kt,
                )?,
            };
            if shared_pool.is_none() {
                shared_pool = Some(m.pool_handle());
            }
            // wall-clock kernel/sync spans for this plan; retagged per
            // batch with the tenant actually served
            m.attach_recorder(
                rec,
                svc.tenants.first().copied().unwrap_or(0) as u32,
            );
            if pd > 1 {
                m.set_pipeline_depth(pd)
                    .map_err(EngineError::Unsupported)?;
            }
            // the hung-worker backstop (`--task-deadline`) applies to
            // the shared pool's barrier dispatch too, chaos or not
            m.pool_handle().set_task_deadline(task_deadline_s);
            svc.measured = Some(m);
        }
        svc.host_times =
            estimate_times(svc.g, &svc.assignment, n, &svc.omegas);
        svc.scheduler_on = n > 1
            && base.scheduler_period_s > 0.0
            && !matches!(svc.opts.placement, Placement::SingleNode(_));
        if !churn.is_empty() {
            if !svc.scheduler_on {
                return Err(EngineError::Unsupported(format!(
                    "--churn requires an active dual-mode scheduler \
                     for every service (multi-fog cluster, positive \
                     --scheduler-period, non-pinned placement); \
                     service ({}, {}) has none",
                    svc.model, svc.dataset
                )));
            }
            // identity-seeded per service (canonical creation order),
            // so churn streams are declaration-order invariant and
            // distinct services never share a draw sequence
            let churn_seed =
                mix64(base.seed ^ CHURN_SALT) ^ mix64(si as u64);
            svc.churn = Some(ChurnState {
                engine: TopologyEngine::new(svc.g, &svc.assignment, n),
                plan: ChurnPlan::new(churn, churn_seed),
            });
        }
    }
    if aggregate.slo.oom {
        // a service's placement exceeds fog memory: the run is aborted
        // before any traffic, exactly like the single-workload loop
        let mut out = FabricReport {
            aggregate,
            fair,
            plan_cache: plan_cache_entries(&services),
            fairness_jain: 1.0,
            ..Default::default()
        };
        for (ti, t) in tenants.iter().enumerate() {
            let mut tr = tenant_report_base(t);
            tr.slo.oom = services[t.service].oom;
            let (qmean, qmax) = reg.queue_depth_stats(ti as u32, n);
            tr.per_fog_queue_depth_mean_s = qmean;
            tr.per_fog_queue_depth_max_s = qmax;
            out.tenants.push(tr);
        }
        let names: Vec<String> =
            out.tenants.iter().map(|t| t.name.clone()).collect();
        out.aggregate.phase_breakdown = reg.phase_breakdown(&names);
        return Ok(out);
    }

    // ---- analytic execution substrate (shared across services) ----------
    let node_mult: Vec<f64> = cluster
        .nodes
        .iter()
        .map(|nd| nd.effective_multiplier())
        .collect();
    let trace = if base.background_load {
        LoadTrace::random_walk(
            n,
            base.duration_s.ceil() as usize + 2,
            base.seed ^ 0x10AD,
        )
    } else {
        LoadTrace { loads: vec![vec![0.0; n]; 1] }
    };

    // ---- request streams (per tenant, identity-seeded) -------------------
    for t in tenants.iter_mut() {
        t.arrivals = ArrivalProcess::new(
            t.tenant.arrival,
            t.tenant.rps,
            t.tenant.stream_seed,
        )
        .times(base.duration_s);
        t.slo.offered = t.arrivals.len();
        aggregate.slo.offered += t.arrivals.len();
    }

    // ---- merged event loop -----------------------------------------------
    let nt = tenants.len();
    let mut drr = DrrState::new(
        &tenants.iter().map(|t| t.tenant.weight).collect::<Vec<_>>(),
        base.batch.max_batch,
    );
    let mut coll_free = 0f64;
    let mut exec_free = 0f64;
    let mut finishes: Vec<f64> = Vec::new();
    let mut latencies: Vec<f64> = Vec::new();
    let mut batch_total = 0usize;
    let mut exec_busy = 0f64;
    // released-but-uncollected pipelined batches (measured, depth > 1;
    // empty otherwise) and total wall seconds blocked on a full window
    let mut deferred: VecDeque<DeferredBatch> = VecDeque::new();
    let mut stall_total = 0f64;
    let mut qlen_sum = 0usize;
    let mut qlen_ticks = 0usize;
    let mut queue = QueueTimeline::default();
    let mut next_sample = 0f64;
    let scheduler_on = services.iter().any(|s| s.scheduler_on);
    let mut next_sched = if scheduler_on {
        base.scheduler_period_s
    } else {
        f64::INFINITY
    };
    // hoisted per-event scratch: the legacy loop allocated nothing per
    // event, and a capacity probe drives tens of thousands of events
    let mut forms: Vec<f64> = vec![f64::INFINITY; nt];
    let mut ready: Vec<usize> = Vec::with_capacity(nt);
    let mut cost: Vec<f64> = vec![0.0; nt];
    loop {
        // next arrival across tenants (ties: canonical order)
        let mut arr_tenant = usize::MAX;
        let mut t_arr = f64::INFINITY;
        for (i, t) in tenants.iter().enumerate() {
            let a = t
                .arrivals
                .get(t.next_arrival)
                .copied()
                .unwrap_or(f64::INFINITY);
            if a < t_arr {
                t_arr = a;
                arr_tenant = i;
            }
        }
        // pipeline-depth gate: batch k's release waits for batch
        // k-(depth+1) to finish — at most depth+1 batches occupy the
        // two stations at once (deferred batches count as released)
        let released = finishes.len() + deferred.len();
        let gate = if released >= gate_depth {
            finishes[released - gate_depth]
        } else {
            0.0
        };
        // earliest releasable batch per tenant, and the global earliest
        let mut t_form = f64::INFINITY;
        for (slot, t) in forms.iter_mut().zip(&tenants) {
            let f = match t.batcher.ready_at() {
                Some(r) => r.max(coll_free).max(gate),
                None => f64::INFINITY,
            };
            t_form = t_form.min(f);
            *slot = f;
        }
        let t_next = t_arr.min(t_form);
        if t_next == f64::INFINITY {
            break;
        }

        // per-second queue-depth timeline up to the next event
        while next_sample <= t_next && next_sample <= base.duration_s {
            let mut row = vec![0f64; n];
            for svc in services.iter() {
                let per_fog = exec_per_fog(&svc.host_times, &node_mult,
                                           &trace, next_sample);
                let mut depth = 0f64;
                for &ti in &svc.tenants {
                    let d = tenants[ti].batcher.len() as f64;
                    depth += d;
                    // per-tenant per-fog backlog sample, so the report
                    // can surface fog timelines for EVERY tenant
                    for (j, &e) in per_fog.iter().enumerate() {
                        reg.record_queue_depth(ti as u32, j as u32,
                                               d * e);
                    }
                }
                for (r, &e) in row.iter_mut().zip(&per_fog) {
                    *r += depth * e;
                }
            }
            queue.record(row);
            let total_len: usize =
                tenants.iter().map(|t| t.batcher.len()).sum();
            qlen_sum += total_len;
            qlen_ticks += 1;
            aggregate.queue_len_max =
                aggregate.queue_len_max.max(total_len);
            for t in tenants.iter_mut() {
                t.qlen_sum += t.batcher.len();
                t.queue_len_max = t.queue_len_max.max(t.batcher.len());
            }
            next_sample += 1.0;
        }

        // dual-mode scheduler ticks (metadata reporting period), one
        // replan pass per service: per-model ω — or that service's
        // η-scaled OBSERVED ω′ in measured mode — drive its decisions
        while next_sched <= t_next && next_sched <= base.duration_s {
            // replan barrier: drain the pipelined window first, so a
            // migration rebuild sees a quiesced plan and the replan
            // prices fully-observed profilers (documented flush point)
            while let Some(meta) = deferred.pop_front() {
                account_pipelined_batch(
                    meta, &mut services, &mut tenants, &mut aggregate,
                    &mut finishes, &mut exec_free, &mut exec_busy,
                    &mut batch_total, &mut latencies, pd, &node_mult,
                    &trace, rec, &ring, &mut stall_total,
                    chaos.as_mut(),
                );
            }
            if let Some(c) = chaos.as_mut() {
                evacuate_detected_crashes(
                    c, &mut services, &mut aggregate, cluster, &cfg,
                    &mut coll_free, rec, &ring,
                )?;
            }
            let step = next_sched as usize;
            for svc in services.iter_mut() {
                if !svc.scheduler_on {
                    continue;
                }
                // ---- topology churn: draw + apply this barrier's
                // deltas in place, re-grounding only touched fogs ----
                if let Some(cs) = svc.churn.as_mut() {
                    cs.engine.churn_round(&mut cs.plan);
                    // the engine owns the evolving assignment
                    // (boundary refinement may migrate vertices;
                    // vertex appends grow the universe)
                    svc.assignment.clear();
                    svc.assignment
                        .extend_from_slice(&cs.engine.assignment);
                    // appended vertices read zero feature rows —
                    // deterministic, and the collection path sizes
                    // itself off the payload, not the grounding graph
                    let want =
                        cs.engine.csr.num_vertices() * svc.dims;
                    if svc.payload.len() < want {
                        svc.payload.resize(want, 0.0);
                    }
                }
                let eff_omegas: Vec<PerfModel> = match &svc.measured {
                    Some(m) => m.scaled_omegas(),
                    None => svc.omegas.clone(),
                };
                let scaled: Vec<PerfModel> = (0..n)
                    .map(|j| {
                        let load = trace.at(step, j).clamp(0.0, 0.85);
                        let mut k = node_mult[j] / (1.0 - load);
                        // a periodic replan must not move work back
                        // onto a fog the chaos plan currently holds
                        // dead — price it out, like the evacuation does
                        if let Some(c) = &chaos {
                            if c.plan.crashed(j, next_sched) {
                                k *= 1e6;
                            }
                        }
                        scaled_model(&eff_omegas[j], k)
                    })
                    .collect();
                // churned services price skew off the engine's live
                // cardinalities — `estimate_times` recounts from the
                // STALE grounding graph (and would index past it once
                // adds grew the universe)
                let real_times: Vec<f64> = match &svc.churn {
                    Some(cs) => cs
                        .engine
                        .cardinalities()
                        .iter()
                        .zip(&scaled)
                        .map(|(&(v, e), m)| {
                            m.predict(Cardinality::new(v, e))
                        })
                        .collect(),
                    None => estimate_times(svc.g, &svc.assignment, n,
                                           &scaled),
                };
                // under churn a full IEP replan would repartition the
                // stale grounding graph (shrinking the grown universe);
                // the barrier consumes skew through diffusion only
                let scfg = if svc.churn.is_some() {
                    SchedulerConfig { theta: 1.0, ..cfg }
                } else {
                    cfg
                };
                let decision = schedule(
                    svc.g, &svc.spec, cluster, &svc.opts,
                    &mut svc.assignment, &real_times, &scaled, &scfg,
                );
                if let Some(cause) = decision.cause() {
                    rec.span(&ring, SpanEvent::new(Phase::Replan,
                                                   NO_TENANT,
                                                   us(next_sched), 0.0)
                        .because(cause));
                    reg.record_phase(NO_TENANT, -1, Phase::Replan, 0.0);
                }
                let moved = match decision {
                    SchedulerDecision::Keep => false,
                    SchedulerDecision::Diffused(_) => {
                        svc.diffusions += 1;
                        aggregate.slo.diffusions += 1;
                        true
                    }
                    SchedulerDecision::Replanned => {
                        svc.replans += 1;
                        aggregate.slo.replans += 1;
                        true
                    }
                };
                if moved {
                    if let Some(cs) = svc.churn.as_mut() {
                        // absorb the diffusion's moves into the
                        // engine: dirties only the fogs on either
                        // side of a move, re-grounds just those
                        cs.engine.sync_assignment(&svc.assignment);
                    } else if let Some(m) = svc.measured.as_mut() {
                        m.rebuild(svc.g, &svc.assignment,
                                  &svc.model)?;
                        svc.rebuilds += 1;
                    }
                }
                if let Some(cs) = svc.churn.as_ref() {
                    // topology moved this barrier even when the
                    // scheduler kept the placement: re-derive every
                    // placement-static constant from engine state
                    svc.host_times = cs
                        .engine
                        .cardinalities()
                        .iter()
                        .zip(&eff_omegas)
                        .map(|(&(v, e), m)| {
                            m.predict(Cardinality::new(v, e))
                        })
                        .collect();
                    let (rows, degs) = cs.engine.collection_rows();
                    svc.coll_index =
                        CollectionIndex::from_parts(rows, degs);
                    svc.coll_s = collection_transfer_s(
                        svc.g, &svc.payload, svc.dims,
                        &svc.coll_index, cluster, &svc.opts,
                    );
                } else if moved {
                    svc.host_times = estimate_times(
                        svc.g, &svc.assignment, n, &eff_omegas);
                    svc.coll_index = CollectionIndex::build(
                        svc.g, &svc.assignment, n);
                    svc.coll_s = collection_transfer_s(
                        svc.g, &svc.payload, svc.dims,
                        &svc.coll_index, cluster, &svc.opts,
                    );
                }
            }
            next_sched += base.scheduler_period_s;
        }

        if t_arr <= t_next {
            // admission: one request of the earliest-arriving tenant
            let tid = arr_tenant as u32;
            rec.span(&ring,
                     SpanEvent::new(Phase::Arrive, tid, us(t_arr), 0.0));
            reg.record_phase(tid, -1, Phase::Arrive, 0.0);
            let t = &mut tenants[arr_tenant];
            t.next_arrival += 1;
            if t.batcher.len() >= t.queue_cap {
                let cause = if base.spill {
                    t.slo.spilled += 1;
                    aggregate.slo.spilled += 1;
                    "queue-full-spill"
                } else {
                    t.slo.shed += 1;
                    aggregate.slo.shed += 1;
                    if let Some(c) = chaos.as_mut() {
                        c.shed_times.push(t_arr);
                    }
                    "queue-full-shed"
                };
                rec.span(&ring,
                         SpanEvent::new(Phase::Shed, tid, us(t_arr),
                                        0.0)
                             .because(cause));
                reg.record_phase(tid, -1, Phase::Shed, 0.0);
            } else {
                t.batcher.push(t_arr);
                rec.span(&ring,
                         SpanEvent::new(Phase::Admit, tid, us(t_arr),
                                        0.0));
                reg.record_phase(tid, -1, Phase::Admit, 0.0);
            }
        } else {
            // release one micro-batch at t_form: the fair-admission
            // arbiter picks among every tenant releasable NOW (head-
            // batch costs are only computed for those)
            ready.clear();
            for i in 0..nt {
                if forms[i] <= t_form {
                    ready.push(i);
                    cost[i] = bucket(
                        tenants[i]
                            .batcher
                            .len()
                            .min(base.batch.max_batch),
                    ) as f64;
                }
            }
            let sel = match fair {
                FairPolicy::Drr => drr.pick(&ready, &cost),
                FairPolicy::Fifo => {
                    // globally oldest head-of-line request wins
                    let mut best = ready[0];
                    let mut best_head = f64::INFINITY;
                    for &i in &ready {
                        let head = tenants[i]
                            .batcher
                            .oldest()
                            .unwrap_or(f64::INFINITY);
                        if head < best_head {
                            best_head = head;
                            best = i;
                        }
                    }
                    best
                }
            };
            let svc_idx = tenants[sel].service;
            let batch = tenants[sel].batcher.take_batch();
            if tenants[sel].batcher.is_empty() {
                // classic DRR: an emptied queue banks no credit
                drr.deficit[sel] = 0.0;
            }
            let b = batch.len();
            // the executable only exists at power-of-two shapes; a
            // 17..=32 batch really pays for the 32 bucket
            let slot = bucket(b);
            // pipelined measured path: backpressure first — drain the
            // oldest in-flight batches until the window has room (the
            // blocking waits are accounted as `pipeline_stall`)
            let pipelined =
                pd > 1 && base.exec == ExecMode::Measured;
            if pipelined {
                while deferred.len() >= pd {
                    account_pipelined_batch(
                        deferred.pop_front().unwrap(), &mut services,
                        &mut tenants, &mut aggregate, &mut finishes,
                        &mut exec_free, &mut exec_busy,
                        &mut batch_total, &mut latencies, pd,
                        &node_mult, &trace, rec, &ring,
                        &mut stall_total, chaos.as_mut(),
                    );
                }
            }
            // chaos: a crash detected while draining forces the full
            // replan barrier NOW — the evacuation rebuild must see a
            // quiesced pipeline, and waiting for the next scheduler
            // tick would leave the dead fog timing out every batch
            if pipelined {
                let need_evac = chaos.as_ref().map_or(false, |c| {
                    c.plan.faults.iter().enumerate().any(|(fi, f)| {
                        matches!(f.spec.kind, FaultKind::Crash { .. })
                            && c.det_t[fi].is_some()
                            && !c.evacuated[fi]
                    })
                });
                if need_evac {
                    while let Some(meta) = deferred.pop_front() {
                        account_pipelined_batch(
                            meta, &mut services, &mut tenants,
                            &mut aggregate, &mut finishes,
                            &mut exec_free, &mut exec_busy,
                            &mut batch_total, &mut latencies, pd,
                            &node_mult, &trace, rec, &ring,
                            &mut stall_total, chaos.as_mut(),
                        );
                    }
                    if let Some(c) = chaos.as_mut() {
                        evacuate_detected_crashes(
                            c, &mut services, &mut aggregate, cluster,
                            &cfg, &mut coll_free, rec, &ring,
                        )?;
                    }
                }
            }
            // chaos: push the fault masks as of this batch's formation
            // into the measured executors; a mask CHANGE (fault onset
            // or rejoin) first quiesces the pipelined window so no
            // in-flight batch straddles two fault states
            if base.exec == ExecMode::Measured {
                if let Some(c) = chaos.as_mut() {
                    let cur = (
                        (0..n)
                            .map(|j| c.plan.crashed(j, t_form))
                            .collect::<Vec<_>>(),
                        (0..n)
                            .map(|j| c.plan.slow_factor(j, t_form))
                            .collect::<Vec<_>>(),
                    );
                    if c.applied.as_ref() != Some(&cur) {
                        while let Some(meta) = deferred.pop_front() {
                            account_pipelined_batch(
                                meta, &mut services, &mut tenants,
                                &mut aggregate, &mut finishes,
                                &mut exec_free, &mut exec_busy,
                                &mut batch_total, &mut latencies, pd,
                                &node_mult, &trace, rec, &ring,
                                &mut stall_total, Some(&mut *c),
                            );
                        }
                        for svc in services.iter_mut() {
                            if let Some(m) = svc.measured.as_mut() {
                                m.set_chaos(cur.0.clone(),
                                            cur.1.clone(),
                                            c.task_deadline_s);
                            }
                        }
                        c.applied = Some(cur);
                    }
                }
            }
            let svc = &mut services[svc_idx];
            // a degraded uplink throttles this batch's collection
            // window and its sync share (1.0 — exact — when healthy)
            let link_inv = chaos
                .as_ref()
                .map_or(1.0, |c| 1.0 / c.plan.link_factor(t_form));
            let coll_time = svc.coll_s
                * (COLL_FIXED_FRAC
                    + (1.0 - COLL_FIXED_FRAC) * b as f64
                        / base.batch.max_batch as f64)
                * link_inv;
            let coll_done = t_form + coll_time;
            let tid = sel as u32;
            let oldest = batch.first().copied().unwrap_or(t_form);
            let qwait = (t_form - oldest).max(0.0);
            rec.span(&ring, SpanEvent::new(Phase::Queue, tid,
                                           us(oldest), us(qwait))
                .count(b));
            reg.record_phase(tid, -1, Phase::Queue, qwait);
            rec.span(&ring,
                     SpanEvent::new(Phase::Batch, tid, us(t_form), 0.0)
                         .count(b));
            reg.record_phase(tid, -1, Phase::Batch, 0.0);
            rec.span(&ring, SpanEvent::new(Phase::Collect, tid,
                                           us(t_form), us(coll_time))
                .count(b));
            reg.record_phase(tid, -1, Phase::Collect, coll_time);
            // the collect window's critical path is pure wire transfer
            // (packing pipelines off-path, see collection_transfer_s);
            // emit the sub-span for trace nesting but account only
            // `collect`, keeping phase totals free of double counting
            rec.span(&ring, SpanEvent::new(Phase::Transfer, tid,
                                           us(t_form), us(coll_time))
                .count(b));
            if pipelined {
                // submit into the pipelined executor and return to the
                // event loop — the NEXT batch's collection/compression
                // (and arrival admission) now overlaps these kernels;
                // timeline/SLO accounting happens at collection
                let m = svc.measured.as_mut().expect(
                    "measured mode builds an executor per service",
                );
                m.set_trace_tenant(tid);
                m.submit_batch(slot);
                deferred.push_back(DeferredBatch {
                    service: svc_idx,
                    tenant: sel,
                    arrivals: batch,
                    b,
                    slot,
                    t_form,
                    coll_done,
                    link_inv,
                });
                coll_free = coll_done;
                continue;
            }
            // exec admission: a batch may start once the batch `depth`
            // places ahead of it has finished (depth 1 = the classic
            // single-station serialization, bit-identical to the
            // pre-pipeline `exec_free` gate)
            let start_exec = coll_done.max(if finishes.len() >= pd {
                finishes[finishes.len() - pd]
            } else {
                0.0
            });
            // per-fog virtual exec seconds for the chaos detector
            // (empty — and never touched — on fault-free runs)
            let mut fog_dur: Vec<f64> = if chaos.is_some() {
                vec![0.0; n]
            } else {
                Vec::new()
            };
            let exec_time = if let Some(m) = svc.measured.as_mut() {
                // real batched kernels at the padded bucket size; scale
                // each fog's measured host time by its capability and
                // current background load, BSP barrier per layer
                m.set_trace_tenant(tid);
                let step = start_exec.max(0.0) as usize;
                let mut t_cursor = start_exec;
                let mut total = 0f64;
                for (layer, layer_times) in
                    m.run_batch(slot).into_iter().enumerate()
                {
                    let mut mx = 0f64;
                    for (j, &h) in layer_times.iter().enumerate() {
                        let load = trace.at(step, j).clamp(0.0, 0.85);
                        let mut scaled =
                            h * node_mult[j] / (1.0 - load);
                        if let Some(c) = &chaos {
                            // a dead fog's task ages to the EWMA
                            // deadline on the virtual timeline before
                            // its hedge's reply (attributed to this
                            // fog by task tag) lands
                            if c.plan.crashed(j, start_exec)
                                && !c.evacuated_fog(j)
                            {
                                scaled += c.det.deadline(j);
                            }
                        }
                        mx = mx.max(scaled);
                        if !fog_dur.is_empty() {
                            fog_dur[j] += scaled;
                        }
                        if scaled > 0.0 {
                            let mut ev = SpanEvent::new(
                                Phase::Kernel, tid, us(t_cursor),
                                us(scaled),
                            )
                            .fog(j)
                            .count(b);
                            ev.layer = layer as i32;
                            rec.span(&ring, ev);
                            reg.record_phase(tid, j as i32,
                                             Phase::Kernel, scaled);
                        }
                    }
                    t_cursor += mx;
                    total += mx;
                }
                // the block-diagonal batch ships `slot` copies of the
                // halo rows, so the (bandwidth-dominated) sync share
                // scales with the bucket
                let sync_t = svc.base_sync_s * slot as f64 * link_inv;
                for j in 0..n {
                    rec.span(&ring, SpanEvent::new(Phase::Sync, tid,
                                                   us(t_cursor),
                                                   us(sync_t))
                        .fog(j)
                        .count(b));
                    reg.record_phase(tid, j as i32, Phase::Sync,
                                     sync_t);
                }
                total + sync_t
            } else {
                let mut per_fog = exec_per_fog(&svc.host_times,
                                               &node_mult, &trace,
                                               start_exec);
                if let Some(c) = &chaos {
                    // slow fogs price at 1/factor; a crashed fog's
                    // shard waits out the detector deadline and is
                    // then re-dispatched to the fastest healthy fog
                    // (first-reply-wins — the dead original never
                    // answers), unless it was already evacuated
                    for (j, v) in per_fog.iter_mut().enumerate() {
                        let sf = c.plan.slow_factor(j, start_exec);
                        if sf < 1.0 {
                            *v /= sf;
                        }
                    }
                    let healthy_min = per_fog
                        .iter()
                        .enumerate()
                        .filter(|&(j, _)| {
                            !c.plan.crashed(j, start_exec)
                        })
                        .map(|(_, &v)| v)
                        .fold(f64::INFINITY, f64::min);
                    for (j, v) in per_fog.iter_mut().enumerate() {
                        if c.plan.crashed(j, start_exec) {
                            *v = if c.evacuated_fog(j) {
                                0.0
                            } else if healthy_min.is_finite() {
                                c.det.deadline(j) + healthy_min
                            } else {
                                c.det.deadline(j)
                            };
                        }
                    }
                }
                let slowest =
                    per_fog.iter().cloned().fold(0f64, f64::max);
                let scale = EXEC_FIXED_FRAC
                    + (1.0 - EXEC_FIXED_FRAC) * slot as f64;
                for (j, &h) in per_fog.iter().enumerate() {
                    let k = h * scale;
                    if !fog_dur.is_empty() {
                        fog_dur[j] = k;
                    }
                    if k > 0.0 {
                        rec.span(&ring,
                                 SpanEvent::new(Phase::Kernel, tid,
                                                us(start_exec), us(k))
                                     .fog(j)
                                     .count(b));
                        reg.record_phase(tid, j as i32, Phase::Kernel,
                                         k);
                    }
                }
                let sync_t = svc.base_sync_s * scale * link_inv;
                let barrier_end = start_exec + slowest * scale;
                for j in 0..n {
                    rec.span(&ring, SpanEvent::new(Phase::Sync, tid,
                                                   us(barrier_end),
                                                   us(sync_t))
                        .fog(j)
                        .count(b));
                    reg.record_phase(tid, j as i32, Phase::Sync,
                                     sync_t);
                }
                if link_inv == 1.0 {
                    // bit-identical to the pre-chaos arithmetic
                    (slowest + svc.base_sync_s) * scale
                } else {
                    slowest * scale + sync_t
                }
            };
            let finish = start_exec + exec_time;
            coll_free = coll_done;
            exec_free = exec_free.max(finish);
            exec_busy += exec_time;
            finishes.push(finish);
            aggregate.slo.batches += 1;
            batch_total += b;
            aggregate.slo.completed += b;
            let t = &mut tenants[sel];
            t.slo.batches += 1;
            t.slo.completed += b;
            for &a in &batch {
                latencies.push(finish - a);
                t.latencies.push(finish - a);
            }
            rec.span(&ring,
                     SpanEvent::new(Phase::Reply, tid, us(finish), 0.0)
                         .count(b));
            reg.record_phase(tid, -1, Phase::Reply, 0.0);
            if let Some(c) = chaos.as_mut() {
                let slo = tenants[sel].tenant.slo_s;
                for &a in &batch {
                    let l = finish - a;
                    c.samples.push((finish, l, l <= slo));
                }
                c.observe_batch(start_exec, finish, &fog_dur);
                // non-pipelined path: nothing is in flight, so a
                // detection can evacuate immediately
                evacuate_detected_crashes(
                    c, &mut services, &mut aggregate, cluster, &cfg,
                    &mut coll_free, rec, &ring,
                )?;
            }
        }
    }

    // flush the pipelined window: every released batch is collected
    // and accounted before the run summarizes
    while let Some(meta) = deferred.pop_front() {
        account_pipelined_batch(
            meta, &mut services, &mut tenants, &mut aggregate,
            &mut finishes, &mut exec_free, &mut exec_busy,
            &mut batch_total, &mut latencies, pd, &node_mult, &trace,
            rec, &ring, &mut stall_total, chaos.as_mut(),
        );
    }

    // ---- summaries -------------------------------------------------------
    aggregate.slo.mean_batch = if aggregate.slo.batches > 0 {
        batch_total as f64 / aggregate.slo.batches as f64
    } else {
        0.0
    };
    aggregate.exec_utilization = if exec_free > 0.0 {
        (exec_busy / exec_free.max(base.duration_s)).min(1.0)
    } else {
        0.0
    };
    aggregate.queue_len_mean = if qlen_ticks > 0 {
        qlen_sum as f64 / qlen_ticks as f64
    } else {
        0.0
    };
    aggregate.slo.finalize(&latencies);
    aggregate.slo.queue = queue;
    aggregate.latencies = latencies;
    if base.exec == ExecMode::Measured {
        if let Some(m) =
            services.iter().find_map(|s| s.measured.as_ref())
        {
            aggregate.engine = m.engine_name().to_string();
        }
        aggregate.bucket_host_ms = merged_bucket_rows(&services);
        // per-fog occupancy merged across the services sharing the
        // run: summed busy-kernel seconds over the longest service
        // window (services run interleaved on one wall clock)
        let mut busy = vec![0f64; n];
        let mut window = 0f64;
        for svc in &services {
            if let Some(m) = &svc.measured {
                let (b, w) = m.busy_window();
                for (acc, &x) in busy.iter_mut().zip(b) {
                    *acc += x;
                }
                window = window.max(w);
            }
        }
        let occupancy: Vec<f64> = if window > 0.0 {
            busy.iter().map(|&x| (x / window).min(1.0)).collect()
        } else {
            vec![0.0; n]
        };
        aggregate.pipeline = Some(PipelineReport {
            depth: pd,
            occupancy,
            stall_s: stall_total,
        });
    }

    if let Some(cs) = services.iter().find_map(|s| s.churn.as_ref()) {
        // like the base_* grounding constants, the aggregate's churn
        // section describes the canonical-first service
        aggregate.churn = Some(cs.engine.summary());
    }
    let mut report = FabricReport {
        aggregate,
        fair,
        plan_cache: plan_cache_entries(&services),
        ..Default::default()
    };
    for (ti, t) in tenants.iter_mut().enumerate() {
        // tenant_report_base already carries the final slo counters
        let mut tr = tenant_report_base(t);
        tr.slo.mean_batch = if t.slo.batches > 0 {
            t.slo.completed as f64 / t.slo.batches as f64
        } else {
            0.0
        };
        tr.slo.finalize(&t.latencies);
        tr.latencies = std::mem::take(&mut t.latencies);
        tr.queue_len_max = t.queue_len_max;
        tr.queue_len_mean = if qlen_ticks > 0 {
            t.qlen_sum as f64 / qlen_ticks as f64
        } else {
            0.0
        };
        let (qmean, qmax) = reg.queue_depth_stats(ti as u32, n);
        tr.per_fog_queue_depth_mean_s = qmean;
        tr.per_fog_queue_depth_max_s = qmax;
        report.tenants.push(tr);
    }
    let names: Vec<String> =
        report.tenants.iter().map(|t| t.name.clone()).collect();
    report.aggregate.phase_breakdown = reg.phase_breakdown(&names);
    // the aggregate SLO attainment honors each tenant's OWN objective
    // (a request that misses its tenant's SLO must not count as
    // goodput just because the run-level --slo-ms is looser); for one
    // tenant this equals the legacy computation bit-for-bit, since
    // the legacy mapping sets tenant slo == run slo
    report.aggregate.slo.within_slo =
        report.tenants.iter().map(|t| t.slo.within_slo).sum();
    report.aggregate.slo.goodput_rps = if base.duration_s > 0.0 {
        report.aggregate.slo.within_slo as f64 / base.duration_s
    } else {
        0.0
    };
    let weighted: Vec<f64> = report
        .tenants
        .iter()
        .map(|t| t.slo.goodput_rps / t.weight.max(1e-12))
        .collect();
    report.fairness_jain = jain_index(&weighted);
    if let Some(c) = chaos {
        // hedge totals: measured mode reads the pipelines' task-tag
        // accounting (wins = replica replied first, waste = late loser
        // discarded); analytic mode counts the priced re-dispatches
        let (mut hw, mut hl) = (0u64, 0u64);
        if base.exec == ExecMode::Measured {
            for svc in &services {
                if let Some(m) = &svc.measured {
                    let (w, l) = m.hedge_stats();
                    hw += w;
                    hl += l;
                }
            }
        } else {
            hw = c.hedge_per_fault.iter().sum();
        }
        let mut outcomes = Vec::new();
        for (fi, f) in c.plan.faults.iter().enumerate() {
            // SLO damage over the fault's open window: onset until
            // recovery (or end of run if it never recovered)
            let t1 = c.rec_t[fi].unwrap_or(base.duration_s);
            let (p99_delta_ms, goodput_dip, shed_during) =
                window_damage(&c.samples, &c.shed_times, f.t_on, t1,
                              base.duration_s);
            let (fog, peer) = match f.spec.kind {
                FaultKind::Crash { fog, .. }
                | FaultKind::Slow { fog, .. } => (fog as i32, -1),
                FaultKind::Link { src, dst, .. } => {
                    (src as i32, dst as i32)
                }
            };
            outcomes.push(FaultOutcome {
                class: f.spec.kind.class(),
                fog,
                peer,
                t_fault_s: f.t_on,
                time_to_detect_s: c.det_t[fi]
                    .map_or(-1.0, |d| d - f.t_on),
                time_to_recover_s: c.rec_t[fi]
                    .map_or(-1.0, |r| r - f.t_on),
                p99_delta_ms,
                goodput_dip,
                shed_during,
                hedges: c.hedge_per_fault[fi],
                recovered: c.rec_t[fi].is_some(),
            });
        }
        report.aggregate.faults = Some(ChaosReport {
            task_deadline_s: c.task_deadline_s,
            hedge_wins: hw,
            hedge_waste: hl,
            outcomes,
        });
    }
    Ok(report)
}

fn tenant_report_base(t: &TenantState) -> TenantReport {
    TenantReport {
        name: t.tenant.name.clone(),
        model: t.tenant.model.clone(),
        dataset: t.tenant.dataset.clone(),
        arrival: t.tenant.arrival.name(),
        rps: t.tenant.rps,
        weight: t.tenant.weight,
        stream_seed: t.tenant.stream_seed,
        slo: t.slo.clone(),
        ..Default::default()
    }
}

fn plan_cache_entries(services: &[Service<'_>]) -> Vec<PlanCacheEntry> {
    services
        .iter()
        .map(|s| PlanCacheEntry {
            model: s.model.clone(),
            dataset: s.dataset.clone(),
            builds: usize::from(s.grounded),
            hits: s.hits,
            rebuilds: s.rebuilds,
            collection_s: s.coll_s,
            sync_s: s.base_sync_s,
            wire_bytes: s.base_wire_bytes,
        })
        .collect()
}

/// Merge per-service measured bucket summaries into one aggregate
/// table (batch-weighted means per bucket size). A single-service run
/// returns its summary as-is — no float round-trip, so the one-tenant
/// fabric reports exactly what the legacy loop reported.
fn merged_bucket_rows(services: &[Service<'_>]) -> Vec<BucketRow> {
    let measured: Vec<&MeasuredExec> =
        services.iter().filter_map(|s| s.measured.as_ref()).collect();
    if let [only] = measured.as_slice() {
        return only.bucket_summary();
    }
    let mut acc: BTreeMap<usize, (f64, f64, usize)> = BTreeMap::new();
    for svc in services {
        let Some(m) = &svc.measured else { continue };
        for row in m.bucket_summary() {
            let e = acc.entry(row.bucket).or_insert((0.0, 0.0, 0));
            e.0 += row.mean_host_ms * row.batches as f64;
            e.1 += row.mean_queue_wait_ms * row.batches as f64;
            e.2 += row.batches;
        }
    }
    acc.into_iter()
        .map(|(bucket, (host, wait, batches))| BucketRow {
            bucket,
            mean_host_ms: host / batches.max(1) as f64,
            mean_queue_wait_ms: wait / batches.max(1) as f64,
            batches,
        })
        .collect()
}

/// JSON record of one fabric run: the legacy aggregate record plus the
/// fairness policy/index, the per-tenant SLO summaries and the
/// plan-cache accounting.
pub fn fabric_json(label: &str, base: &TrafficConfig,
                   fr: &FabricReport) -> Json {
    let mut j = report_json(label, base, &fr.aggregate);
    let tenants: Vec<Json> = fr
        .tenants
        .iter()
        .map(|t| {
            obj(vec![
                ("name", s(&t.name)),
                ("model", s(&t.model)),
                ("dataset", s(&t.dataset)),
                ("arrival", s(t.arrival)),
                ("rps", num(t.rps)),
                ("weight", num(t.weight)),
                // string for the same u64-precision reason as the run
                // seed in `report_json`
                ("seed", s(&t.stream_seed.to_string())),
                ("slo_ms", num(t.slo.slo_s * 1e3)),
                ("offered", num(t.slo.offered as f64)),
                ("completed", num(t.slo.completed as f64)),
                ("within_slo", num(t.slo.within_slo as f64)),
                ("shed", num(t.slo.shed as f64)),
                ("spilled", num(t.slo.spilled as f64)),
                ("shed_rate", num(t.slo.shed_rate())),
                ("goodput_rps", num(t.slo.goodput_rps)),
                ("p50_ms", num(t.slo.latency.p50_s * 1e3)),
                ("p95_ms", num(t.slo.latency.p95_s * 1e3)),
                ("p99_ms", num(t.slo.latency.p99_s * 1e3)),
                ("mean_ms", num(t.slo.latency.mean_s * 1e3)),
                ("batches", num(t.slo.batches as f64)),
                ("mean_batch", num(t.slo.mean_batch)),
                ("queue_len_max", num(t.queue_len_max as f64)),
                ("queue_len_mean", num(t.queue_len_mean)),
                ("per_fog_queue_depth_mean_s",
                 arr(t.per_fog_queue_depth_mean_s
                     .iter()
                     .map(|&v| num(v))
                     .collect::<Vec<_>>())),
                ("per_fog_queue_depth_max_s",
                 arr(t.per_fog_queue_depth_max_s
                     .iter()
                     .map(|&v| num(v))
                     .collect::<Vec<_>>())),
                ("oom", Json::Bool(t.slo.oom)),
            ])
        })
        .collect();
    let cache: Vec<Json> = fr
        .plan_cache
        .iter()
        .map(|e| {
            obj(vec![
                ("model", s(&e.model)),
                ("dataset", s(&e.dataset)),
                ("builds", num(e.builds as f64)),
                ("hits", num(e.hits as f64)),
                ("rebuilds", num(e.rebuilds as f64)),
                ("collection_s", num(e.collection_s)),
                ("sync_s", num(e.sync_s)),
                ("wire_bytes", num(e.wire_bytes as f64)),
            ])
        })
        .collect();
    if let Json::Obj(map) = &mut j {
        map.insert("fair".to_string(), s(fr.fair.name()));
        map.insert("fairness_jain".to_string(),
                   num(fr.fairness_jain));
        map.insert("tenants".to_string(), arr(tenants));
        map.insert("plan_cache".to_string(), arr(cache));
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jain_index_bounds_and_equality() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
        assert!((jain_index(&[5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
        // one-of-four monopoly: J = 1/4
        assert!((jain_index(&[8.0, 0.0, 0.0, 0.0]) - 0.25).abs()
                < 1e-12);
        let j = jain_index(&[4.0, 1.0]);
        assert!(j > 0.5 && j < 1.0, "{j}");
    }

    #[test]
    fn drr_serves_in_weight_proportion_under_saturation() {
        // both tenants always ready with full batches (cost 32): the
        // long-run service ratio must match the 4:1 weights
        let mut drr = DrrState::new(&[4.0, 1.0], 32);
        let ready = [0usize, 1];
        let cost = [32.0, 32.0];
        let mut served = [0usize; 2];
        for _ in 0..500 {
            served[drr.pick(&ready, &cost)] += 1;
        }
        let ratio = served[0] as f64 / served[1] as f64;
        assert!((ratio - 4.0).abs() < 0.3,
                "served {served:?}, ratio {ratio}");
    }

    #[test]
    fn drr_lets_a_cheap_underfull_batch_through_quickly() {
        // tenant 1 (low weight) has a small batch (cost 2); it must be
        // served within a handful of opportunities even while tenant 0
        // (heavy weight) is saturating with full batches
        let mut drr = DrrState::new(&[4.0, 1.0], 32);
        let ready = [0usize, 1];
        let cost = [32.0, 2.0];
        let mut first_low = None;
        for k in 0..20 {
            if drr.pick(&ready, &cost) == 1 {
                first_low = Some(k);
                break;
            }
        }
        assert!(first_low.is_some() && first_low.unwrap() <= 4,
                "low tenant first served at {first_low:?}");
    }

    #[test]
    fn drr_is_deterministic() {
        let run = || {
            let mut drr = DrrState::new(&[2.0, 1.0, 1.0], 16);
            let ready = [0usize, 1, 2];
            let cost = [16.0, 8.0, 4.0];
            (0..200).map(|_| drr.pick(&ready, &cost)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
