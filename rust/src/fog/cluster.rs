//! Cluster presets used across the paper's experiments, plus the metadata
//! wiring: §IV-B's 6-node testbed (1×A, 4×B, 1×C), §IV-C's 4-node
//! case-study cluster (1×A, 2×B, 1×C), the Fig. 8 environments E1–E3, and
//! homogeneous type-B clusters for the scalability/GPU studies.

use crate::net::{NetKind, NetProfile};

use super::node::{FogNode, NodeType, GTX1050};

#[derive(Clone, Debug)]
pub struct Cluster {
    pub nodes: Vec<FogNode>,
    pub net: NetProfile,
}

impl Cluster {
    pub fn new(types: &[NodeType], net: NetKind) -> Cluster {
        Cluster {
            nodes: types
                .iter()
                .enumerate()
                .map(|(i, &t)| FogNode::new(i, t))
                .collect(),
            net: NetProfile::get(net),
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn with_gpus(mut self) -> Cluster {
        for n in &mut self.nodes {
            n.gpu = Some(GTX1050);
        }
        self
    }

    /// The most powerful node's index (used for single-fog serving,
    /// §II-C: "we select the most powerful one").
    pub fn most_powerful(&self) -> usize {
        self.nodes
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.effective_multiplier()
                    .partial_cmp(&b.effective_multiplier())
                    .unwrap()
            })
            .map(|(i, _)| i)
            .unwrap()
    }

    // ---- paper presets ----------------------------------------------------

    /// §IV-B testbed: 1×A, 4×B, 1×C.
    pub fn testbed(net: NetKind) -> Cluster {
        Cluster::new(
            &[NodeType::A, NodeType::B, NodeType::B, NodeType::B,
              NodeType::B, NodeType::C],
            net,
        )
    }

    /// §IV-C case study: 1×A, 2×B, 1×C.
    pub fn case_study(net: NetKind) -> Cluster {
        Cluster::new(
            &[NodeType::A, NodeType::B, NodeType::B, NodeType::C],
            net,
        )
    }

    /// Fig. 8 environments.
    pub fn env(name: &str) -> Option<Cluster> {
        match name {
            // E1: {1×A, 4×B, 1×C, 4G}
            "E1" => Some(Cluster::testbed(NetKind::Cell4G)),
            // E2: {1×A, 4×B, 1×C, 5G}
            "E2" => Some(Cluster::testbed(NetKind::Cell5G)),
            // E3: {1×A, 2×B, 1×C, WiFi}
            "E3" => Some(Cluster::new(
                &[NodeType::A, NodeType::B, NodeType::B, NodeType::C],
                NetKind::Wifi,
            )),
            _ => None,
        }
    }

    /// Homogeneous type-B cluster (scalability / GPU studies).
    pub fn uniform_b(n: usize, net: NetKind) -> Cluster {
        Cluster::new(&vec![NodeType::B; n], net)
    }

    /// Single cloud node behind the WAN.
    pub fn cloud(net: NetKind) -> Cluster {
        Cluster::new(&[NodeType::Cloud], net)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper() {
        let t = Cluster::testbed(NetKind::Cell4G);
        assert_eq!(t.len(), 6);
        let counts = |c: &Cluster, ty: NodeType| {
            c.nodes.iter().filter(|n| n.node_type == ty).count()
        };
        assert_eq!(counts(&t, NodeType::A), 1);
        assert_eq!(counts(&t, NodeType::B), 4);
        assert_eq!(counts(&t, NodeType::C), 1);
        let cs = Cluster::case_study(NetKind::Wifi);
        assert_eq!(cs.len(), 4);
        assert_eq!(counts(&cs, NodeType::B), 2);
        assert!(Cluster::env("E1").is_some());
        assert!(Cluster::env("E3").unwrap().len() == 4);
        assert!(Cluster::env("E9").is_none());
    }

    #[test]
    fn most_powerful_is_type_c() {
        let t = Cluster::testbed(NetKind::Wifi);
        assert_eq!(t.nodes[t.most_powerful()].node_type, NodeType::C);
    }

    #[test]
    fn gpu_cluster_is_faster() {
        let plain = Cluster::uniform_b(3, NetKind::Wifi);
        let gpu = Cluster::uniform_b(3, NetKind::Wifi).with_gpus();
        assert!(
            gpu.nodes[0].effective_multiplier()
                < plain.nodes[0].effective_multiplier()
        );
    }
}
