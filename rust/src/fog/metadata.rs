//! Metadata server (paper §III-B, Fig. 5 ❶): a dedicated fog node that
//! registers device-independent configuration (graph skeleton, model,
//! bandwidth) and device-specific capability profiles, and aggregates
//! online load reports for execution-plan refinement.

use std::collections::HashMap;

use crate::profile::{OnlineProfiler, PerfModel};

/// Device-independent invariants registered once per deployment.
#[derive(Clone, Debug)]
pub struct StaticMetadata {
    pub dataset: String,
    pub model: String,
    pub num_vertices: usize,
    pub num_edges: usize,
    pub feature_dim: usize,
    pub gnn_layers: usize,
    /// Degree histogram of the registered graph skeleton (drives DAQ).
    pub degrees: Vec<u32>,
}

/// Per-node registration entry.
#[derive(Clone, Debug)]
pub struct NodeRecord {
    pub node_id: usize,
    pub profiler: OnlineProfiler,
    /// Timestamp (logical) of the node's last report.
    pub last_report: u64,
}

/// The metadata server state machine.
#[derive(Clone, Debug)]
pub struct MetadataServer {
    pub static_meta: Option<StaticMetadata>,
    pub nodes: HashMap<usize, NodeRecord>,
    clock: u64,
}

impl MetadataServer {
    pub fn new() -> Self {
        Self { static_meta: None, nodes: HashMap::new(), clock: 0 }
    }

    pub fn register_static(&mut self, meta: StaticMetadata) {
        self.static_meta = Some(meta);
    }

    /// Register a node's offline calibration profile (setup phase).
    pub fn register_node(&mut self, node_id: usize, offline: PerfModel) {
        self.clock += 1;
        self.nodes.insert(
            node_id,
            NodeRecord {
                node_id,
                profiler: OnlineProfiler::new(offline),
                last_report: self.clock,
            },
        );
    }

    /// Apply a runtime report: measured execution time for a cardinality.
    pub fn report(&mut self, node_id: usize,
                  card: crate::profile::Cardinality, real_s: f64) {
        self.clock += 1;
        if let Some(rec) = self.nodes.get_mut(&node_id) {
            rec.profiler.observe(card, real_s);
            rec.last_report = self.clock;
        }
    }

    /// Current η-scaled models for all registered nodes, ordered by id —
    /// the ω' the planner and the dual-mode scheduler consume.
    pub fn scaled_models(&self) -> Vec<PerfModel> {
        let mut ids: Vec<usize> = self.nodes.keys().copied().collect();
        ids.sort_unstable();
        ids.iter()
            .map(|id| self.nodes[id].profiler.scaled_model())
            .collect()
    }

    /// Latest raw measurements per node (for the load-balance indicator).
    pub fn last_measurements(&self) -> Vec<f64> {
        let mut ids: Vec<usize> = self.nodes.keys().copied().collect();
        ids.sort_unstable();
        ids.iter().map(|id| self.nodes[id].profiler.last_real_s).collect()
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
}

impl Default for MetadataServer {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::Cardinality;

    fn model() -> PerfModel {
        PerfModel { beta_v: 1e-6, beta_n: 1e-7, intercept: 0.0, r2: 1.0 }
    }

    #[test]
    fn registration_and_reporting_flow() {
        let mut ms = MetadataServer::new();
        ms.register_node(0, model());
        ms.register_node(1, model());
        assert_eq!(ms.num_nodes(), 2);
        let c = Cardinality::new(1000, 4000);
        let base = model().predict(c);
        ms.report(1, c, base * 2.0);
        let scaled = ms.scaled_models();
        // node 1's model now predicts 2x
        assert!((scaled[1].predict(c) - base * 2.0).abs() < 1e-12);
        assert!((scaled[0].predict(c) - base).abs() < 1e-12);
        assert_eq!(ms.last_measurements()[1], base * 2.0);
    }

    #[test]
    fn reports_to_unknown_nodes_are_ignored() {
        let mut ms = MetadataServer::new();
        ms.register_node(0, model());
        ms.report(99, Cardinality::new(1, 1), 1.0);
        assert_eq!(ms.num_nodes(), 1);
    }
}
