//! Fog-environment substrate: heterogeneous node models (Table II),
//! cluster presets for every experiment, background-load traces, and the
//! metadata server of the paper's workflow (Fig. 5/6).

pub mod cluster;
pub mod loadtrace;
pub mod metadata;
pub mod node;

pub use cluster::Cluster;
pub use loadtrace::LoadTrace;
pub use metadata::{MetadataServer, StaticMetadata};
pub use node::{FogNode, GpuSpec, NodeType, GTX1050};
