//! Background-load traces — the stand-in for the paper's Alibaba
//! production snapshot (Fig. 16): per-node CPU load over 1000 timestamps
//! with a pronounced ramp on one node, plus a generic regime-switching
//! generator for stress tests.

use crate::util::rng::Rng;

/// loads[t][node] in [0, 0.85].
#[derive(Clone, Debug)]
pub struct LoadTrace {
    pub loads: Vec<Vec<f64>>,
}

impl LoadTrace {
    pub fn steps(&self) -> usize {
        self.loads.len()
    }

    pub fn nodes(&self) -> usize {
        self.loads.first().map(|v| v.len()).unwrap_or(0)
    }

    pub fn at(&self, t: usize, node: usize) -> f64 {
        self.loads[t.min(self.loads.len() - 1)][node]
    }

    /// The Fig. 16 scenario: `n` nodes idle-ish; the LAST node's load
    /// climbs steeply mid-trace, plateaus, then releases — reproducing the
    /// snapshot the paper replays.
    pub fn fig16(n: usize, steps: usize, seed: u64) -> LoadTrace {
        let mut rng = Rng::new(seed);
        let mut loads = vec![vec![0.0; n]; steps];
        let ramp_start = steps * 15 / 100;
        let ramp_top = steps * 35 / 100;
        let release = steps * 70 / 100;
        let tail = steps * 85 / 100;
        for t in 0..steps {
            for node in 0..n {
                let base = 0.06 + 0.04 * ((t as f64 / 37.0).sin() + 1.0) / 2.0;
                let jitter = rng.f64() * 0.05;
                let mut load = base + jitter;
                if node == n - 1 {
                    load += ramp_profile(t, ramp_start, ramp_top, release,
                                         tail) * 0.65;
                }
                loads[t][node] = load.clamp(0.0, 0.85);
            }
        }
        LoadTrace { loads }
    }

    /// Regime-switching random walk (generic stress workload).
    pub fn random_walk(n: usize, steps: usize, seed: u64) -> LoadTrace {
        let mut rng = Rng::new(seed);
        let mut cur = vec![0.1; n];
        let mut target = vec![0.1; n];
        let mut loads = Vec::with_capacity(steps);
        for t in 0..steps {
            for i in 0..n {
                if t % 50 == 0 && rng.bool(0.3) {
                    target[i] = rng.f64() * 0.8;
                }
                cur[i] += (target[i] - cur[i]) * 0.1
                    + rng.normal() * 0.01;
                cur[i] = cur[i].clamp(0.0, 0.85);
            }
            loads.push(cur.clone());
        }
        LoadTrace { loads }
    }
}

fn ramp_profile(t: usize, start: usize, top: usize, release: usize,
                tail: usize) -> f64 {
    if t < start {
        0.0
    } else if t < top {
        (t - start) as f64 / (top - start) as f64
    } else if t < release {
        1.0
    } else if t < tail {
        1.0 - (t - release) as f64 / (tail - release) as f64
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig16_has_ramp_on_last_node() {
        let tr = LoadTrace::fig16(4, 1000, 1);
        assert_eq!(tr.steps(), 1000);
        assert_eq!(tr.nodes(), 4);
        // early: all nodes low
        assert!(tr.at(50, 3) < 0.25);
        // mid: node 3 heavily loaded, others still light
        assert!(tr.at(500, 3) > 0.55, "mid load {}", tr.at(500, 3));
        assert!(tr.at(500, 0) < 0.25);
        // end: released
        assert!(tr.at(950, 3) < 0.25);
        // all in range
        for t in 0..1000 {
            for n in 0..4 {
                let l = tr.at(t, n);
                assert!((0.0..=0.85).contains(&l));
            }
        }
    }

    #[test]
    fn random_walk_stays_in_range_and_moves() {
        let tr = LoadTrace::random_walk(3, 500, 2);
        let first = tr.at(0, 0);
        let later: Vec<f64> = (0..500).map(|t| tr.at(t, 0)).collect();
        let spread = later.iter().cloned().fold(f64::MIN, f64::max)
            - later.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 0.05, "trace too flat");
        assert!((0.0..=0.85).contains(&first));
    }
}
