//! Fog-node model: the heterogeneous compute substrate of the paper's
//! testbed (Table II), expressed as *capability multipliers* over this
//! host's measured execution time — the simulation contract documented in
//! DESIGN.md's substitution log.

/// Hardware class (Table II + the cloud and the Fig. 18 GPU variant).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NodeType {
    /// 8-core i7-6700 / 4 GB — weak (memory-starved).
    A,
    /// 8-core i7-6700 / 8 GB — moderate (the calibration baseline).
    B,
    /// 16-core Xeon W-2145 / 32 GB — powerful.
    C,
    /// Aliyun 8 vCPU + Tesla V100 — the cloud baseline server.
    Cloud,
}

impl NodeType {
    /// Execution-time multiplier relative to a type-B node running the
    /// same PJRT executable. Calibrated to the paper's observations:
    /// A is 37.8% slower than B (§IV-A) despite the same CPU (memory
    /// pressure), C's 16-core Xeon roughly halves B's time, and the
    /// cloud's V100 makes execution <2% of cloud-serving latency (§II-C).
    pub fn cpu_multiplier(&self) -> f64 {
        match self {
            NodeType::A => 1.378,
            NodeType::B => 1.0,
            NodeType::C => 0.45,
            NodeType::Cloud => 0.035,
        }
    }

    /// Share of the access network's collection bandwidth this node class
    /// gets (the heterogeneous b_j of Eq. (5): "their available bandwidth
    /// allocated for serving also vary", §I). Calibrated with the §II-C
    /// collection-reduction test in net/mod.rs.
    pub fn bandwidth_share(&self) -> f64 {
        match self {
            NodeType::A => 0.65,
            NodeType::B => 1.0,
            NodeType::C => 1.3,
            NodeType::Cloud => 1.0,
        }
    }

    pub fn memory_bytes(&self) -> usize {
        match self {
            NodeType::A => 4 << 30,
            NodeType::B => 8 << 30,
            NodeType::C => 32 << 30,
            NodeType::Cloud => 32 << 30,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            NodeType::A => "A",
            NodeType::B => "B",
            NodeType::C => "C",
            NodeType::Cloud => "cloud",
        }
    }
}

/// GTX-1050 attachment for the Fig. 18 study: big speedup on the dense
/// update phase, tight 2 GiB device memory.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuSpec {
    pub multiplier: f64,
    pub memory_bytes: usize,
}

pub const GTX1050: GpuSpec = GpuSpec {
    multiplier: 0.22,
    // 2 GiB card minus CUDA context/driver overhead
    memory_bytes: (2usize << 30) - (400 << 20),
};

/// One fog node instance in a cluster.
#[derive(Clone, Debug)]
pub struct FogNode {
    pub id: usize,
    pub node_type: NodeType,
    pub gpu: Option<GpuSpec>,
    /// Background load fraction in [0, 0.85] (from the load trace);
    /// effective slowdown is 1 / (1 - load).
    pub background_load: f64,
}

impl FogNode {
    pub fn new(id: usize, node_type: NodeType) -> FogNode {
        FogNode { id, node_type, gpu: None, background_load: 0.0 }
    }

    pub fn with_gpu(mut self, gpu: GpuSpec) -> FogNode {
        self.gpu = Some(gpu);
        self
    }

    /// Effective execution multiplier under current load.
    pub fn effective_multiplier(&self) -> f64 {
        let base = match self.gpu {
            Some(g) => g.multiplier,
            None => self.node_type.cpu_multiplier(),
        };
        base / (1.0 - self.background_load.clamp(0.0, 0.85))
    }

    /// Memory available to the serving runtime.
    pub fn serving_memory_bytes(&self) -> usize {
        match self.gpu {
            Some(g) => g.memory_bytes,
            None => self.node_type.memory_bytes(),
        }
    }

    /// Scale a host-measured execution time to this node.
    pub fn scale_time(&self, host_seconds: f64) -> f64 {
        host_seconds * self.effective_multiplier()
    }
}

/// Estimated resident footprint of serving one partition bucket:
/// activations (in + hidden), edge gather buffers and executable
/// workspace. Used for the Fig. 18 OOM check.
pub fn partition_footprint_bytes(
    v_max: usize,
    e_max: usize,
    f_in: usize,
    hidden: usize,
) -> usize {
    let acts = v_max * (f_in + hidden + hidden) * 4;
    // message buffer of the first (feature-dim) aggregation; the engine
    // streams the hidden-dim layer in blocks, so f_in sizes the peak
    let gather = e_max * f_in * 4;
    let indices = e_max * 12;
    let workspace = (acts + gather) / 4;
    acts + gather + indices + workspace
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn type_ordering_matches_table_ii() {
        assert!(NodeType::A.cpu_multiplier() > NodeType::B.cpu_multiplier());
        assert!(NodeType::B.cpu_multiplier() > NodeType::C.cpu_multiplier());
        assert!(NodeType::C.cpu_multiplier() > NodeType::Cloud.cpu_multiplier());
        // the measured 37.8% A-vs-B gap
        assert!((NodeType::A.cpu_multiplier() - 1.378).abs() < 1e-9);
    }

    #[test]
    fn background_load_slows_node() {
        let mut n = FogNode::new(0, NodeType::B);
        let base = n.scale_time(1.0);
        n.background_load = 0.5;
        assert!((n.scale_time(1.0) - 2.0 * base).abs() < 1e-9);
        n.background_load = 2.0; // clamped
        assert!(n.scale_time(1.0) < 8.0);
    }

    #[test]
    fn gpu_overrides_cpu_and_memory() {
        let n = FogNode::new(1, NodeType::B).with_gpu(GTX1050);
        assert!(n.effective_multiplier() < 0.3);
        assert_eq!(n.serving_memory_bytes(), GTX1050.memory_bytes);
    }

    #[test]
    fn rmat100k_oom_on_single_gpu_fog_only() {
        // Fig. 18: single GPU fog OOMs on RMAT-100K; >=2 fogs fit.
        let full = partition_footprint_bytes(100_352, 10_000_000, 32, 64);
        assert!(full > GTX1050.memory_bytes, "full graph must OOM");
        let half = partition_footprint_bytes(52_000, 5_800_000, 32, 64);
        assert!(half < GTX1050.memory_bytes, "1/2 partition must fit");
        // and the full graph still fits an 8 GiB type-B CPU node
        assert!(full < NodeType::B.memory_bytes());
    }
}
