//! Fograph: distributed real-time GNN inference serving over
//! heterogeneous fog nodes — a full reproduction of the CS.DC 2023 paper
//! as a Rust (L3 coordinator) + JAX (L2 models) + Pallas (L1 kernels)
//! stack with AOT compilation via PJRT. See DESIGN.md.

pub mod compress;
pub mod exec;
pub mod experiments;
pub mod fog;
pub mod graph;
pub mod net;
pub mod obs;
pub mod partition;
pub mod placement;
pub mod profile;
pub mod runtime;
pub mod scheduler;
pub mod serving;
pub mod traffic;
pub mod util;
