//! Measurement substrate for the custom bench harness (no criterion
//! offline): warmup + repeated timing with mean/p50/p95 summaries.
//! All wall reads go through `obs::clock::Stopwatch`, the crate's one
//! sanctioned wall-clock primitive.

use crate::obs::clock::Stopwatch;
use crate::util::stats;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn throughput_per_s(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` adaptively: warm up, then run until `min_time_s` of samples or
/// `max_iters`, whichever first. Returns summary statistics.
pub fn bench<F: FnMut()>(name: &str, min_time_s: f64, max_iters: usize,
                         mut f: F) -> BenchResult {
    // warmup
    let warm_start = Stopwatch::start();
    let mut warm_iters = 0usize;
    while warm_start.elapsed_s() < min_time_s * 0.2 && warm_iters < 3 {
        f();
        warm_iters += 1;
    }
    let mut samples_ns: Vec<f64> = Vec::new();
    let start = Stopwatch::start();
    while start.elapsed_s() < min_time_s && samples_ns.len() < max_iters
    {
        let t = Stopwatch::start();
        f();
        samples_ns.push(t.elapsed_ns());
    }
    if samples_ns.is_empty() {
        let t = Stopwatch::start();
        f();
        samples_ns.push(t.elapsed_ns());
    }
    BenchResult {
        name: name.to_string(),
        iters: samples_ns.len(),
        mean_ns: stats::mean(&samples_ns),
        p50_ns: stats::percentile(&samples_ns, 50.0),
        p95_ns: stats::percentile(&samples_ns, 95.0),
        stddev_ns: stats::stddev(&samples_ns),
    }
}

/// Prevent the optimizer from discarding a value (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("spin", 0.05, 10_000, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 1);
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with("s"));
    }
}
