//! Foundation substrates: PRNG, JSON, statistics, CLI parsing, a mini
//! property-test harness, and a bench timer. These stand in for the crates
//! (`rand`, `serde`, `clap`, `proptest`, `criterion`) the offline registry
//! does not provide — see DESIGN.md's substitution log.

pub mod cli;
pub mod json;
pub mod provenance;
pub mod rng;
pub mod stats;
pub mod testkit;
pub mod timer;
