//! Mini property-testing substrate (no proptest offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` over `cases` generated
//! inputs; on failure it performs a bounded greedy shrink via the
//! generator's `Shrink` hook and panics with the minimal counterexample's
//! debug representation plus the reproducing seed.

use crate::util::rng::Rng;

/// Run `prop` on `cases` inputs produced by `gen`. On failure, retries the
/// input's shrinks (produced by `shrink`) to find a smaller counterexample.
pub fn forall_shrink<T, G, S, P>(
    seed: u64,
    cases: usize,
    gen: G,
    shrink: S,
    prop: P,
) where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if prop(&input) {
            continue;
        }
        // shrink loop (greedy, bounded)
        let mut cur = input;
        'outer: for _ in 0..200 {
            for cand in shrink(&cur) {
                if !prop(&cand) {
                    cur = cand;
                    continue 'outer;
                }
            }
            break;
        }
        panic!(
            "property failed (seed={seed}, case={case});\n\
             minimal counterexample: {cur:#?}"
        );
    }
}

/// `forall` without shrinking.
pub fn forall<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    forall_shrink(seed, cases, gen, |_| Vec::new(), prop);
}

/// Common shrinker: all ways of halving/removing elements of a Vec.
pub fn shrink_vec<T: Clone>(xs: &Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if xs.is_empty() {
        return out;
    }
    out.push(xs[..xs.len() / 2].to_vec());
    out.push(xs[xs.len() / 2..].to_vec());
    if xs.len() <= 8 {
        for i in 0..xs.len() {
            let mut c = xs.clone();
            c.remove(i);
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_silent() {
        forall(1, 200, |r| r.below(100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(2, 200, |r| r.below(100), |&x| x < 50);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn shrinking_reaches_small_case() {
        // property: all vecs have sum < 10; generator makes big vecs.
        forall_shrink(
            3,
            50,
            |r| (0..20).map(|_| r.below(5)).collect::<Vec<u64>>(),
            shrink_vec,
            |xs| xs.iter().sum::<u64>() < 10,
        );
    }
}
