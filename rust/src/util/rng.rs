//! Deterministic PRNG substrate (the offline registry has no `rand`).
//!
//! `SplitMix64` seeds `Xoshiro256++` (Blackman & Vigna). All dataset
//! generation, placement tie-breaking and simulation jitter flow through
//! this module so every experiment is reproducible from a single seed.

/// SplitMix64: used for seeding and cheap stateless hashing.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Stateless 64-bit mix (hash of a value with a salt).
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (for parallel substructures).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ mix64(tag))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let out = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        out
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift; unbiased enough for
    /// simulation workloads).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k << n assumed).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let x = self.usize_below(n);
            if seen.insert(x) {
                out.push(x);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        for (n, k) in [(100, 10), (10, 10), (1000, 700)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&x| x < n));
        }
    }
}
