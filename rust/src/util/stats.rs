//! Small statistics substrate: summaries, percentiles, linear regression
//! (the profiler's latency model, Eq. (3) of the paper), and CDFs
//! (Theorem 2's compression-ratio formula).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Multivariate ordinary least squares: y ≈ X·beta + eps.
/// Returns (beta [d], intercept). Solved by normal equations with
/// Gaussian elimination — dimensions here are tiny (d = 2 for the
/// cardinality model ⟨|V|, |N_V|⟩).
pub fn linreg(xs: &[Vec<f64>], ys: &[f64]) -> (Vec<f64>, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    let d = xs[0].len();
    let n = xs.len();
    // augmented design matrix with intercept column
    let dd = d + 1;
    let mut ata = vec![vec![0.0f64; dd]; dd];
    let mut aty = vec![0.0f64; dd];
    for (row, &y) in xs.iter().zip(ys) {
        let mut aug = row.clone();
        aug.push(1.0);
        for i in 0..dd {
            aty[i] += aug[i] * y;
            for j in 0..dd {
                ata[i][j] += aug[i] * aug[j];
            }
        }
    }
    // ridge epsilon for numerical safety
    for (i, row) in ata.iter_mut().enumerate() {
        row[i] += 1e-9 * n as f64;
    }
    let beta = solve(ata, aty);
    let intercept = beta[d];
    (beta[..d].to_vec(), intercept)
}

/// Gaussian elimination with partial pivoting.
fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let pv = a[col][col];
        if pv.abs() < 1e-30 {
            continue;
        }
        for row in col + 1..n {
            let f = a[row][col] / pv;
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for k in row + 1..n {
            acc -= a[row][k] * x[k];
        }
        x[row] = if a[row][row].abs() < 1e-30 { 0.0 } else { acc / a[row][row] };
    }
    x
}

/// Empirical CDF over integer-valued samples (e.g. vertex degrees):
/// `cdf.at(d)` = P(X <= d).  Used by Theorem 2's compression-ratio check.
pub struct EmpiricalCdf {
    sorted: Vec<u64>,
}

impl EmpiricalCdf {
    pub fn new(mut samples: Vec<u64>) -> Self {
        samples.sort_unstable();
        Self { sorted: samples }
    }

    pub fn at(&self, x: u64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        let idx = self.sorted.partition_point(|&s| s <= x);
        idx as f64 / self.sorted.len() as f64
    }

    pub fn quantile(&self, q: f64) -> u64 {
        if self.sorted.is_empty() {
            return 0;
        }
        let idx = ((q * self.sorted.len() as f64) as usize)
            .min(self.sorted.len() - 1);
        self.sorted[idx]
    }

    pub fn max(&self) -> u64 {
        self.sorted.last().copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_edge_cases_for_slo_tails() {
        // unsorted input; the SLO metrics rely on these exact semantics
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert!((percentile(&xs, 95.0) - 3.85).abs() < 1e-12);
        assert!((percentile(&xs, 99.0) - 3.97).abs() < 1e-12);
        // degenerate inputs
        assert_eq!(percentile(&[], 95.0), 0.0);
        assert_eq!(percentile(&[7.5], 99.0), 7.5);
        // monotone in q
        let mut last = f64::MIN;
        for q in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let p = percentile(&xs, q);
            assert!(p >= last);
            last = p;
        }
    }

    #[test]
    fn linreg_recovers_plane() {
        // y = 3 x0 - 2 x1 + 5
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..20 {
            for j in 0..20 {
                xs.push(vec![i as f64, j as f64]);
                ys.push(3.0 * i as f64 - 2.0 * j as f64 + 5.0);
            }
        }
        let (beta, c) = linreg(&xs, &ys);
        assert!((beta[0] - 3.0).abs() < 1e-6);
        assert!((beta[1] + 2.0).abs() < 1e-6);
        assert!((c - 5.0).abs() < 1e-6);
    }

    #[test]
    fn linreg_with_noise_is_close() {
        let mut rng = crate::util::rng::Rng::new(3);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..500 {
            let a = rng.range_f64(0.0, 100.0);
            let b = rng.range_f64(0.0, 50.0);
            xs.push(vec![a, b]);
            ys.push(0.7 * a + 1.3 * b + 10.0 + rng.normal());
        }
        let (beta, c) = linreg(&xs, &ys);
        assert!((beta[0] - 0.7).abs() < 0.01);
        assert!((beta[1] - 1.3).abs() < 0.01);
        assert!((c - 10.0).abs() < 0.5);
    }

    #[test]
    fn cdf_basics() {
        let cdf = EmpiricalCdf::new(vec![1, 1, 2, 3, 5, 8]);
        assert_eq!(cdf.at(0), 0.0);
        assert!((cdf.at(1) - 2.0 / 6.0).abs() < 1e-12);
        assert!((cdf.at(4) - 4.0 / 6.0).abs() < 1e-12);
        assert_eq!(cdf.at(8), 1.0);
        assert_eq!(cdf.max(), 8);
        assert_eq!(cdf.quantile(0.5), 3);
    }
}
