//! Tiny CLI argument substrate (no clap offline): subcommand + `--key value`
//! flags + `--switch` booleans + positionals.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    /// Every `--key value` occurrence in argv order — the substrate
    /// for REPEATABLE flags (`get_all`), which `flags` (last wins)
    /// cannot represent.
    pub occurrences: Vec<(String, String)>,
}

impl Args {
    /// Parse raw argv (excluding program name). `known_switches` lists
    /// boolean flags that take no value.
    pub fn parse(argv: &[String], known_switches: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.occurrences
                        .push((k.to_string(), v.to_string()));
                } else if known_switches.contains(&name) {
                    out.switches.push(name.to_string());
                } else if i + 1 < argv.len()
                    && !argv[i + 1].starts_with("--")
                {
                    out.flags.insert(name.to_string(), argv[i + 1].clone());
                    out.occurrences
                        .push((name.to_string(), argv[i + 1].clone()));
                    i += 1;
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Every value a repeatable flag was given, in argv order (empty
    /// when absent) — e.g. one element per `--tenant` spec.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.occurrences
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

/// Upper bound for `--kernel-threads`: generous headroom over any sane
/// host core count while still catching typos like 5000.
pub const MAX_KERNEL_THREADS: usize = 64;

/// Shared validator for bounded positive-integer knobs
/// (`FOGRAPH_MIN_ROWS_PER_SHARD`, `FOGRAPH_TRACE_BUF`): trimmed
/// integer in `lo..=hi`, everything else an error naming the knob —
/// one parser, so every env override is validated "the same way" by
/// construction.
pub fn parse_bounded_usize(what: &str, v: &str, lo: usize,
                           hi: usize) -> Result<usize, String> {
    match v.trim().parse::<usize>() {
        Ok(n) if (lo..=hi).contains(&n) => Ok(n),
        _ => Err(format!(
            "{what} must be an integer in {lo}..={hi} (got {v:?})"
        )),
    }
}

/// Probe that `path` is writable by opening it in append/create mode
/// — the `--trace-out` preflight, so a bad path fails at argument
/// time (exit 2) instead of after a multi-second run. Leaves existing
/// file contents untouched.
pub fn probe_writable(path: &str) -> Result<(), String> {
    if path.is_empty() {
        return Err("path is empty".to_string());
    }
    std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .map(|_| ())
        .map_err(|e| format!("cannot open {path:?} for writing: {e}"))
}

/// Validated `--kernel-threads` (default 1 = no intra-fog sharding).
/// 0, non-numeric and absurd values are errors, so callers can exit
/// with CLI code 2 instead of silently falling back to a default.
pub fn parse_kernel_threads(args: &Args) -> Result<usize, String> {
    match args.get("kernel-threads") {
        None => Ok(1),
        Some(v) => match v.parse::<usize>() {
            Ok(k) if (1..=MAX_KERNEL_THREADS).contains(&k) => Ok(k),
            _ => Err(format!(
                "--kernel-threads must be an integer in \
                 1..={MAX_KERNEL_THREADS} (got {v})"
            )),
        },
    }
}

/// Upper bound for `--pipeline-depth`: enough to drown any realistic
/// collection/compute overlap while still catching typos. Depth is an
/// in-flight *batch* window, not a thread count, so the ceiling is
/// deliberately small.
pub const MAX_PIPELINE_DEPTH: usize = 32;

/// Validated `--pipeline-depth` (default 1 = today's fully serial
/// measured executor, bit-identical reports). 0, non-numeric and
/// absurd values are errors so callers can exit with CLI code 2, the
/// same contract as `--kernel-threads`.
pub fn parse_pipeline_depth(args: &Args) -> Result<usize, String> {
    match args.get("pipeline-depth") {
        None => Ok(1),
        Some(v) => match v.parse::<usize>() {
            Ok(d) if (1..=MAX_PIPELINE_DEPTH).contains(&d) => Ok(d),
            _ => Err(format!(
                "--pipeline-depth must be an integer in \
                 1..={MAX_PIPELINE_DEPTH} (got {v})"
            )),
        },
    }
}

/// Validated `--task-deadline` seconds (default = the pool's
/// `DEFAULT_TASK_DEADLINE_S`, passed in by the caller so this module
/// stays runtime-free). The deadline bounds how long the measured
/// executor waits on any single fog task before hedging (chaos runs)
/// or declaring the worker hung — zero, negative, non-finite and
/// non-numeric values are errors so callers can exit with CLI code 2.
pub fn parse_task_deadline(args: &Args,
                           default_s: f64) -> Result<f64, String> {
    match args.get("task-deadline") {
        None => Ok(default_s),
        Some(v) => match v.parse::<f64>() {
            Ok(s) if s.is_finite() && s > 0.0 => Ok(s),
            _ => Err(format!(
                "--task-deadline must be a positive number of \
                 seconds (got {v})"
            )),
        },
    }
}

/// Upper bound for `--fog-mem-mb`: 1 TiB of per-fog feature budget is
/// far beyond any single fog while still catching pasted byte counts.
pub const MAX_FOG_MEM_MB: usize = 1 << 20;

/// Validated `--fog-mem-mb` per-fog feature-memory budget in MiB
/// (default `None` = unbounded, the exact pre-spill resident path).
/// A bare `--fog-mem-mb` with no value, 0, non-numeric and absurd
/// values are errors so callers can exit with CLI code 2, the same
/// contract as `--kernel-threads`.
pub fn parse_fog_mem_mb(args: &Args) -> Result<Option<usize>, String> {
    if args.has("fog-mem-mb") {
        return Err("--fog-mem-mb requires a value in MiB \
                    (e.g. --fog-mem-mb 64)"
            .to_string());
    }
    match args.get("fog-mem-mb") {
        None => Ok(None),
        Some(v) => match v.parse::<usize>() {
            Ok(mb) if (1..=MAX_FOG_MEM_MB).contains(&mb) => Ok(Some(mb)),
            _ => Err(format!(
                "--fog-mem-mb must be an integer in \
                 1..={MAX_FOG_MEM_MB} MiB (got {v})"
            )),
        },
    }
}

/// Upper bound for a churn spec's per-round mutation rate: more than
/// half the live graph per scheduler period is a rebuild in disguise,
/// not churn, so the incremental engine refuses it at parse time.
pub const MAX_CHURN_RATE: f64 = 0.5;

/// Upper bound for `degree=` in `add-vertex` churn specs: how many
/// attachment edges a newly joined vertex draws. IoT sensors attach to
/// a handful of gateways, not to half the graph.
pub const MAX_CHURN_DEGREE: usize = 64;

/// Validated `rate=` field of a `--churn` spec: the fraction of live
/// vertices (or live edges, for edge ops) mutated per scheduler round.
/// Zero is an error — a no-op churn spec is always a typo — as are
/// non-finite, negative and rebuild-scale (> 0.5) values. `what`
/// names the offending spec in the message so the CLI can exit 2.
pub fn parse_churn_rate(what: &str, v: &str) -> Result<f64, String> {
    match v.trim().parse::<f64>() {
        Ok(r) if r.is_finite() && r > 0.0 && r <= MAX_CHURN_RATE => Ok(r),
        _ => Err(format!(
            "{what}: 'rate={v}' must be a number in (0, \
             {MAX_CHURN_RATE}]"
        )),
    }
}

/// Validated `degree=` field of an `add-vertex` churn spec (attachment
/// edges per new vertex). 0, non-numeric and absurd values are errors;
/// the default when the key is absent is the caller's concern.
pub fn parse_churn_degree(what: &str, v: &str) -> Result<usize, String> {
    match v.trim().parse::<usize>() {
        Ok(d) if (1..=MAX_CHURN_DEGREE).contains(&d) => Ok(d),
        _ => Err(format!(
            "{what}: 'degree={v}' must be an integer in \
             1..={MAX_CHURN_DEGREE}"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_args() {
        let a = Args::parse(
            &v(&["exp", "fig11", "--repeats", "5", "--verbose",
                 "--out=results.md"]),
            &["verbose"],
        );
        assert_eq!(a.positional, vec!["exp", "fig11"]);
        assert_eq!(a.get("repeats"), Some("5"));
        assert_eq!(a.get("out"), Some("results.md"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_usize("repeats", 1), 5);
        assert_eq!(a.get_usize("missing", 9), 9);
    }

    #[test]
    fn repeated_flags_collect_in_order() {
        let a = Args::parse(
            &v(&["loadtest", "--tenant", "model=gcn,rps=100",
                 "--tenant=model=sage,weight=2", "--rps", "50"]),
            &[],
        );
        assert_eq!(
            a.get_all("tenant"),
            vec!["model=gcn,rps=100", "model=sage,weight=2"]
        );
        // the map keeps last-wins semantics for single-valued flags
        assert_eq!(a.get("tenant"), Some("model=sage,weight=2"));
        assert_eq!(a.get_all("rps"), vec!["50"]);
        assert!(a.get_all("absent").is_empty());
    }

    #[test]
    fn trailing_flag_without_value_is_switch() {
        let a = Args::parse(&v(&["--gpu"]), &[]);
        assert!(a.has("gpu"));
    }

    #[test]
    fn equals_form_always_has_value() {
        let a = Args::parse(&v(&["--x=--weird"]), &[]);
        assert_eq!(a.get("x"), Some("--weird"));
    }

    #[test]
    fn bounded_usize_validation() {
        assert_eq!(parse_bounded_usize("X", "4", 1, 64), Ok(4));
        assert_eq!(parse_bounded_usize("X", " 64 ", 1, 64), Ok(64));
        for bad in ["0", "65", "-1", "abc", "", "4.5"] {
            let e = parse_bounded_usize("KNOB", bad, 1, 64);
            assert!(e.is_err(), "{bad:?} accepted");
            assert!(e.unwrap_err().contains("KNOB"));
        }
    }

    #[test]
    fn probe_writable_accepts_tmp_and_rejects_bad_dirs() {
        let dir = std::env::temp_dir().join("fograph_cli_probe_test");
        std::fs::create_dir_all(&dir).unwrap();
        let ok = dir.join("trace.json");
        assert!(probe_writable(ok.to_str().unwrap()).is_ok());
        assert!(probe_writable("").is_err());
        let bad = dir.join("no_such_subdir").join("trace.json");
        assert!(probe_writable(bad.to_str().unwrap()).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn kernel_threads_validation() {
        let ok = |xs: &[&str]| parse_kernel_threads(&Args::parse(
            &v(xs), &[]));
        assert_eq!(ok(&[]), Ok(1));
        assert_eq!(ok(&["--kernel-threads", "4"]), Ok(4));
        assert_eq!(ok(&["--kernel-threads=64"]), Ok(64));
        assert!(ok(&["--kernel-threads", "0"]).is_err());
        assert!(ok(&["--kernel-threads", "65"]).is_err());
        assert!(ok(&["--kernel-threads", "many"]).is_err());
        assert!(ok(&["--kernel-threads", "-2"]).is_err());
    }

    #[test]
    fn task_deadline_validation() {
        let ok = |xs: &[&str]| parse_task_deadline(
            &Args::parse(&v(xs), &[]), 30.0);
        assert_eq!(ok(&[]), Ok(30.0));
        assert_eq!(ok(&["--task-deadline", "0.1"]), Ok(0.1));
        assert_eq!(ok(&["--task-deadline=5"]), Ok(5.0));
        assert!(ok(&["--task-deadline", "0"]).is_err());
        assert!(ok(&["--task-deadline", "-1"]).is_err());
        assert!(ok(&["--task-deadline", "inf"]).is_err());
        assert!(ok(&["--task-deadline", "nan"]).is_err());
        assert!(ok(&["--task-deadline", "soon"]).is_err());
    }

    #[test]
    fn fog_mem_mb_validation() {
        let ok = |xs: &[&str]| parse_fog_mem_mb(&Args::parse(
            &v(xs), &["smoke"]));
        assert_eq!(ok(&[]), Ok(None));
        assert_eq!(ok(&["--fog-mem-mb", "64"]), Ok(Some(64)));
        assert_eq!(ok(&["--fog-mem-mb=1"]), Ok(Some(1)));
        assert!(ok(&["--fog-mem-mb", "0"]).is_err());
        assert!(ok(&["--fog-mem-mb", "abc"]).is_err());
        assert!(ok(&["--fog-mem-mb", "-4"]).is_err());
        assert!(ok(&["--fog-mem-mb", "1048577"]).is_err());
        // bare flag: the value was eaten by the shell or forgotten
        assert!(ok(&["--fog-mem-mb"]).is_err());
        assert!(ok(&["--fog-mem-mb", "--smoke"]).is_err());
    }

    #[test]
    fn churn_rate_validation() {
        assert_eq!(parse_churn_rate("S", "0.01"), Ok(0.01));
        assert_eq!(parse_churn_rate("S", " 0.5 "), Ok(0.5));
        for bad in ["0", "0.0", "-0.1", "0.51", "1", "inf", "nan",
                    "lots", ""] {
            let e = parse_churn_rate("SPEC", bad);
            assert!(e.is_err(), "rate {bad:?} accepted");
            assert!(e.unwrap_err().contains("SPEC"));
        }
    }

    #[test]
    fn churn_degree_validation() {
        assert_eq!(parse_churn_degree("S", "1"), Ok(1));
        assert_eq!(parse_churn_degree("S", "64"), Ok(64));
        for bad in ["0", "65", "-1", "2.5", "few", ""] {
            let e = parse_churn_degree("SPEC", bad);
            assert!(e.is_err(), "degree {bad:?} accepted");
            assert!(e.unwrap_err().contains("SPEC"));
        }
    }

    #[test]
    fn pipeline_depth_validation() {
        let ok = |xs: &[&str]| parse_pipeline_depth(&Args::parse(
            &v(xs), &[]));
        assert_eq!(ok(&[]), Ok(1));
        assert_eq!(ok(&["--pipeline-depth", "1"]), Ok(1));
        assert_eq!(ok(&["--pipeline-depth", "4"]), Ok(4));
        assert_eq!(ok(&["--pipeline-depth=32"]), Ok(32));
        assert!(ok(&["--pipeline-depth", "0"]).is_err());
        assert!(ok(&["--pipeline-depth", "33"]).is_err());
        assert!(ok(&["--pipeline-depth", "deep"]).is_err());
        assert!(ok(&["--pipeline-depth", "-1"]).is_err());
    }
}
