//! Minimal JSON substrate (the offline registry has no serde):
//! a full parser + writer covering everything the artifact manifest and
//! experiment reports need.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Default for Json {
    fn default() -> Json {
        Json::Null
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style access helper for tests and loaders.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(v) => v.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }
}

// ---- builders -------------------------------------------------------------

pub fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

// ---- writer ----------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

// ---- parser ----------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let rest = &self.b[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
            Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e'
                || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let txt = r#"{"a":[1,2.5,-3],"b":"hi\n","c":true,"d":null,"e":{}}"#;
        let v = Json::parse(txt).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"x":{"y":[10,20,{"z":"w"}]}}"#).unwrap();
        assert_eq!(v.at(&["x", "y", "1"]).unwrap().as_f64(), Some(20.0));
        assert_eq!(v.at(&["x", "y", "2", "z"]).unwrap().as_str(), Some("w"));
        assert!(v.at(&["x", "nope"]).is_none());
    }

    #[test]
    fn parses_manifest_like_payload() {
        let txt = r#"{
 "artifacts": [
  {"name": "gcn_siot_f1_l0", "v_max": 16384, "e_max": 309248,
   "params": [["w", [52, 64], "f32"]], "sha256": "ab12"}
 ],
 "format": 1
}"#;
        let v = Json::parse(txt).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("v_max").unwrap().as_usize(), Some(16384));
        assert_eq!(
            a.at(&["params", "0", "1", "1"]).unwrap().as_usize(),
            Some(64)
        );
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(parsed, v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""Ab""#).unwrap().as_str(),
            Some("Ab")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integer_formatting_is_exact() {
        assert_eq!(num(16384.0).to_string(), "16384");
        assert_eq!(num(2.5).to_string(), "2.5");
    }
}
