//! Run provenance for committed artifacts: the short git revision, the
//! UTC civil date, and the process peak RSS. Every benchmark artifact
//! that outlives a PR (BENCH_kernels.json, BENCH_history.jsonl,
//! BENCH_loadtest.json, BENCH_scale.json) stamps all three, so a
//! number in a working tree is always traceable to the code that
//! produced it — and memory regressions are attributable ACROSS runs
//! with one shared metric, not just within one artifact.

/// Short git revision, or "unknown" outside a work tree.
pub fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// UTC civil date from the system clock, YYYY-MM-DD (no chrono
/// offline; Hinnant's days-to-civil algorithm).
pub fn utc_date_string() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let z = (secs / 86_400) as i64 + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = yoe + era * 400 + i64::from(m <= 2);
    format!("{y:04}-{m:02}-{d:02}")
}

/// Peak resident-set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`), or `None` where procfs is unavailable
/// (non-Linux hosts). A monotone high-water mark: it never decreases
/// within a process, so artifacts record it once at write time and
/// within-run comparisons use logical-bytes accounting instead.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 =
                rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn date_is_iso_shaped() {
        let d = utc_date_string();
        assert_eq!(d.len(), 10, "{d}");
        let bytes = d.as_bytes();
        assert_eq!(bytes[4], b'-');
        assert_eq!(bytes[7], b'-');
        assert!(d[..4].parse::<i64>().unwrap() >= 2024);
        let month: u32 = d[5..7].parse().unwrap();
        let day: u32 = d[8..10].parse().unwrap();
        assert!((1..=12).contains(&month));
        assert!((1..=31).contains(&day));
    }

    #[test]
    fn peak_rss_is_positive_and_monotone_on_linux() {
        let first = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            let a = first.expect("procfs VmHWM on linux");
            assert!(a > 0);
            // touching memory can only raise the high-water mark
            let sink = vec![1u8; 1 << 20];
            std::hint::black_box(&sink);
            let b = peak_rss_bytes().unwrap();
            assert!(b >= a, "VmHWM decreased: {a} -> {b}");
        }
    }

    #[test]
    fn rev_is_nonempty() {
        // inside the repo's work tree this is a short hash; elsewhere
        // the documented "unknown" fallback — never an empty string
        assert!(!git_rev().is_empty());
    }
}
