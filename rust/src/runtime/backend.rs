//! Pluggable execution backends behind the `Engine` façade.
//!
//! The engine owns what is common to every backend — weight-bundle
//! loading/synthesis and the artifact manifest — and delegates the actual
//! kernel execution to an `ExecBackend`:
//!
//! * `ReferenceBackend` — the dense in-tree forward (numeric oracle).
//! * `CsrBackend` (`csr_backend.rs`) — sparse CSR aggregation with true
//!   block-diagonal batched execution; no O(V²) dense adjacency.
//! * `PjrtBackend` (`engine.rs`, behind the `pjrt` cargo feature) — AOT
//!   HLO artifacts compiled once per bucket on the PJRT CPU client.
//!
//! Per-bucket artifact caching, CSR-view caching and any other
//! backend-specific state live behind the trait; callers only see
//! `run_layer` / `run_layer_batched` / `run_astgcn`. The CPU backends'
//! numerics (tiled GEMM, blocked SpMM) live in `runtime::kernels` —
//! backends own structure and scratch, kernels own the loops.

use crate::graph::LocalGraph;
use crate::obs::clock::Stopwatch;

use super::engine::{EngineError, LayerOut};
use super::pad::{self, EdgeArrays};
use super::reference;
use super::weights::WeightBundle;

/// Everything the engine façade resolves before dispatching one layer to
/// a backend: model identity, dims, and the (already loaded) weights.
pub struct LayerCtx<'a> {
    pub model: &'a str,
    pub dataset: &'a str,
    pub layer: usize,
    /// Input feature dim of THIS layer.
    pub f_in: usize,
    /// Raw input feature dim of layer 0 (artifact selection).
    pub f_raw: usize,
    pub classes: usize,
    /// True on the output head (no activation).
    pub last: bool,
    pub weights: &'a WeightBundle,
}

/// One execution backend. `run_layer` computes a single message-passing
/// layer over a partition; `run_layer_batched` runs a block-diagonal
/// micro-batch of `batch` requests sharing the partition structure (the
/// default falls back to a serial per-request loop for backends without
/// a batched kernel); `run_astgcn` executes the ASTGCN block.
pub trait ExecBackend {
    fn name(&self) -> &'static str;

    fn run_layer(&mut self, ctx: &LayerCtx<'_>, h: &[f32],
                 edges: &EdgeArrays) -> Result<LayerOut, EngineError>;

    /// Block-diagonal batched forward: `h` stacks `batch` feature
    /// matrices ([batch * n, f_in] block-major) over the SAME partition;
    /// the output stacks `batch` × [n_local, out_dim] blocks.
    fn run_layer_batched(&mut self, ctx: &LayerCtx<'_>, h: &[f32],
                         edges: &EdgeArrays, batch: usize)
                         -> Result<LayerOut, EngineError> {
        let per = edges.n * ctx.f_in;
        debug_assert_eq!(h.len(), batch * per);
        let mut out: Vec<f32> = Vec::new();
        let mut host = 0f64;
        let mut out_dim = 0usize;
        for bk in 0..batch {
            let r = self.run_layer(ctx, &h[bk * per..(bk + 1) * per],
                                   edges)?;
            host += r.host_seconds;
            out_dim = r.out_dim;
            out.extend_from_slice(&r.h);
        }
        Ok(LayerOut { h: out, out_dim, host_seconds: host })
    }

    /// ASTGCN block over a partition (`ctx.f_in` is the window dim F·T).
    fn run_astgcn(&mut self, ctx: &LayerCtx<'_>, x: &[f32], n: usize,
                  sub: &LocalGraph) -> Result<LayerOut, EngineError>;
}

/// The pure-Rust dense forward — numeric oracle for every other backend.
#[derive(Debug, Default)]
pub struct ReferenceBackend;

impl ExecBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn run_layer(&mut self, ctx: &LayerCtx<'_>, h: &[f32],
                 edges: &EdgeArrays) -> Result<LayerOut, EngineError> {
        let t = Stopwatch::start();
        let out = reference::run_layer(ctx.model, ctx.layer, ctx.weights,
                                       h, ctx.f_in, edges, ctx.last)?;
        let host = t.elapsed_s();
        let out_dim = out.len() / edges.n_local.max(1);
        Ok(LayerOut { h: out, out_dim, host_seconds: host })
    }

    fn run_astgcn(&mut self, ctx: &LayerCtx<'_>, x: &[f32], n: usize,
                  sub: &LocalGraph) -> Result<LayerOut, EngineError> {
        let adj = pad::dense_norm_adj(sub, n)?;
        let t = Stopwatch::start();
        let out = reference::run_astgcn(ctx.weights, x, n, ctx.f_in, &adj);
        let host = t.elapsed_s();
        let out_dim = out.len() / n.max(1);
        Ok(LayerOut { h: out, out_dim, host_seconds: host })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The default batched implementation must agree with per-request
    /// execution block for block (it IS the per-request loop).
    #[test]
    fn default_batched_concatenates_blocks() {
        let wb = synth_bundle();
        let edges = two_vertex_edges();
        let ctx = LayerCtx {
            model: "gcn",
            dataset: "tiny",
            layer: 0,
            f_in: 2,
            f_raw: 2,
            classes: 2,
            last: true,
            weights: &wb,
        };
        let mut be = ReferenceBackend;
        let h = [1.0f32, 0.0, 0.0, 1.0, 2.0, 0.0, 0.0, 2.0];
        let batched = be.run_layer_batched(&ctx, &h, &edges, 2).unwrap();
        let a = be.run_layer(&ctx, &h[..4], &edges).unwrap();
        let b = be.run_layer(&ctx, &h[4..], &edges).unwrap();
        assert_eq!(batched.out_dim, a.out_dim);
        assert_eq!(&batched.h[..4], &a.h[..]);
        assert_eq!(&batched.h[4..], &b.h[..]);
    }

    fn synth_bundle() -> WeightBundle {
        use super::super::weights::{read_fgw, write_fgw};
        let dir = std::env::temp_dir().join("backend_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("b.fgw");
        let w = [1.0f32, 0.0, 0.0, 1.0];
        let b = [0.0f32, 0.0];
        write_fgw(&p, &[("l0.w", &[2, 2], &w), ("l0.b", &[2], &b)])
            .unwrap();
        read_fgw(&p).unwrap()
    }

    fn two_vertex_edges() -> EdgeArrays {
        EdgeArrays {
            src: vec![0, 1],
            dst: vec![1, 0],
            ew: vec![1.0, 1.0],
            inv_deg: vec![0.5, 0.5],
            n: 2,
            n_local: 2,
        }
    }
}
