//! Engine façade over the pluggable execution backends (`backend.rs`):
//!
//! * `EngineKind::Pjrt` — loads the AOT HLO-text artifacts produced by the
//!   Python compile path, compiles them ONCE on the PJRT CPU client (one
//!   executable per bucket, cached) and executes layers from the request
//!   path. Python never runs here.
//! * `EngineKind::Reference` — the in-tree pure-Rust dense forward
//!   (numeric oracle; also used for very large sweeps where bucket
//!   padding cost obscures the effect under study).
//! * `EngineKind::Csr` — sparse CSR aggregation with block-diagonal
//!   batched execution (`csr_backend.rs`); no O(V²) dense buffers.
//!
//! The engine owns weight bundles (from
//! `artifacts/weights_<model>_<dataset>.fgw`, the training output) and
//! the artifact manifest; backends own their kernel state (compiled
//! executables, CSR views). When a bundle is absent the engine falls
//! back to a deterministic glorot init so latency experiments remain
//! runnable without the training step; accuracy experiments require
//! real weights.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::rng::{mix64, Rng};

use super::artifacts::{Manifest, ManifestError};
use super::backend::{ExecBackend, LayerCtx, ReferenceBackend};
use super::csr_backend::CsrBackend;
use super::pad;
use super::reference;
use super::weights::{read_fgw, write_fgw, WeightBundle};

#[derive(Debug)]
pub enum EngineError {
    Manifest(ManifestError),
    Weights(super::weights::FgwError),
    Xla(String),
    Io(std::io::Error),
    /// Unknown model name reached the runtime (user input).
    Model(String),
    /// The requested execution is outside this backend's envelope
    /// (e.g. a dense-adjacency build above the sizing guard).
    Unsupported(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Manifest(e) => write!(f, "manifest: {e}"),
            EngineError::Weights(e) => write!(f, "weights: {e}"),
            EngineError::Xla(m) => write!(f, "xla: {m}"),
            EngineError::Io(e) => write!(f, "io: {e}"),
            EngineError::Model(m) => write!(f, "unknown model {m}"),
            EngineError::Unsupported(m) => write!(f, "unsupported: {m}"),
        }
    }
}

impl std::error::Error for EngineError {}

impl From<ManifestError> for EngineError {
    fn from(e: ManifestError) -> Self {
        EngineError::Manifest(e)
    }
}

impl From<super::weights::FgwError> for EngineError {
    fn from(e: super::weights::FgwError) -> Self {
        EngineError::Weights(e)
    }
}

impl From<std::io::Error> for EngineError {
    fn from(e: std::io::Error) -> Self {
        EngineError::Io(e)
    }
}

impl From<pad::UnknownModel> for EngineError {
    fn from(e: pad::UnknownModel) -> Self {
        EngineError::Model(e.0)
    }
}

impl From<pad::DenseAdjTooLarge> for EngineError {
    fn from(e: pad::DenseAdjTooLarge) -> Self {
        EngineError::Unsupported(e.to_string())
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for EngineError {
    fn from(e: xla::Error) -> Self {
        EngineError::Xla(e.to_string())
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Pjrt,
    Reference,
    Csr,
}

/// Output of one layer execution.
#[derive(Clone, Debug)]
pub struct LayerOut {
    /// [n, out_dim] row-major, unpadded ([batch * n, out_dim] for the
    /// batched entry points).
    pub h: Vec<f32>,
    pub out_dim: usize,
    /// Host wall-clock of the compute (scaled by fog multipliers
    /// upstream).
    pub host_seconds: f64,
}

pub struct Engine {
    pub kind: EngineKind,
    artifacts_dir: PathBuf,
    manifest: Option<Manifest>,
    backend: Box<dyn ExecBackend>,
    weights: HashMap<String, WeightBundle>,
    /// Names of bundles that were random-initialized (missing on disk).
    pub synthetic_weights: Vec<String>,
}

fn weights_key(model: &str, dataset: &str) -> String {
    let ds = if dataset.starts_with("rmat") { "rmat" } else { dataset };
    format!("weights_{model}_{ds}")
}

impl Engine {
    pub fn new(kind: EngineKind, artifacts_dir: &Path)
               -> Result<Engine, EngineError> {
        let manifest = Manifest::load(artifacts_dir).ok();
        let backend: Box<dyn ExecBackend> = match kind {
            EngineKind::Reference => Box::new(ReferenceBackend),
            EngineKind::Csr => Box::new(CsrBackend::new()),
            EngineKind::Pjrt => {
                new_pjrt_backend(artifacts_dir, manifest.as_ref())?
            }
        };
        Ok(Engine {
            kind,
            artifacts_dir: artifacts_dir.to_path_buf(),
            manifest,
            backend,
            weights: HashMap::new(),
            synthetic_weights: Vec::new(),
        })
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    /// The active backend's display name (for reports/benchmarks).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Load (or synthesize) the bundle into the cache if absent.
    fn ensure_weights(&mut self, model: &str, dataset: &str, f_in: usize,
                      classes: usize) {
        let key = weights_key(model, dataset);
        if !self.weights.contains_key(&key) {
            let path = self.artifacts_dir.join(format!("{key}.fgw"));
            let bundle = match read_fgw(&path) {
                Ok(b) => b,
                Err(_) => {
                    self.synthetic_weights.push(key.clone());
                    synthesize_weights(model, f_in, classes, &key)
                }
            };
            self.weights.insert(key, bundle);
        }
    }

    /// Fetch (or lazily load / synthesize) the weight bundle.
    pub fn weights(&mut self, model: &str, dataset: &str, f_in: usize,
                   classes: usize) -> &WeightBundle {
        self.ensure_weights(model, dataset, f_in, classes);
        &self.weights[&weights_key(model, dataset)]
    }

    /// Execute one message-passing layer on a partition.
    #[allow(clippy::too_many_arguments)]
    pub fn run_layer(
        &mut self,
        model: &str,
        dataset: &str,
        layer: usize,
        h: &[f32],
        f_in: usize,
        edges: &super::pad::EdgeArrays,
        f_raw: usize,
        classes: usize,
    ) -> Result<LayerOut, EngineError> {
        self.ensure_weights(model, dataset, f_raw, classes);
        let last = layer + 1 == reference::model_layers(model);
        let ctx = LayerCtx {
            model,
            dataset,
            layer,
            f_in,
            f_raw,
            classes,
            last,
            weights: &self.weights[&weights_key(model, dataset)],
        };
        self.backend.run_layer(&ctx, h, edges)
    }

    /// Execute one layer over a block-diagonal batch of `batch`
    /// requests sharing the partition structure (`h` is
    /// [batch * n, f_in] block-major). Backends without a native
    /// batched kernel fall back to a serial per-request loop.
    #[allow(clippy::too_many_arguments)]
    pub fn run_layer_batched(
        &mut self,
        model: &str,
        dataset: &str,
        layer: usize,
        h: &[f32],
        f_in: usize,
        edges: &super::pad::EdgeArrays,
        f_raw: usize,
        classes: usize,
        batch: usize,
    ) -> Result<LayerOut, EngineError> {
        self.ensure_weights(model, dataset, f_raw, classes);
        let last = layer + 1 == reference::model_layers(model);
        let ctx = LayerCtx {
            model,
            dataset,
            layer,
            f_in,
            f_raw,
            classes,
            last,
            weights: &self.weights[&weights_key(model, dataset)],
        };
        self.backend.run_layer_batched(&ctx, h, edges, batch)
    }

    /// Execute the ASTGCN block on a partition.
    pub fn run_astgcn(&mut self, dataset: &str, x: &[f32], n: usize,
                      ft: usize, sub: &crate::graph::LocalGraph)
                      -> Result<LayerOut, EngineError> {
        self.ensure_weights("astgcn", dataset, ft, 0);
        let ctx = LayerCtx {
            model: "astgcn",
            dataset,
            layer: 0,
            f_in: ft,
            f_raw: ft,
            classes: 0,
            last: true,
            weights: &self.weights[&weights_key("astgcn", dataset)],
        };
        self.backend.run_astgcn(&ctx, x, n, sub)
    }
}

#[cfg(not(feature = "pjrt"))]
fn new_pjrt_backend(_artifacts_dir: &Path, _manifest: Option<&Manifest>)
                    -> Result<Box<dyn ExecBackend>, EngineError> {
    Err(EngineError::Xla(
        "built without the `pjrt` cargo feature; use the reference or \
         csr engine, or vendor the xla crate (see rust/Cargo.toml) and \
         rebuild with --features pjrt"
            .to_string(),
    ))
}

#[cfg(feature = "pjrt")]
fn new_pjrt_backend(artifacts_dir: &Path, manifest: Option<&Manifest>)
                    -> Result<Box<dyn ExecBackend>, EngineError> {
    // reuse the facade's parsed manifest; reload only to surface the
    // precise load error when it was absent
    let manifest = match manifest {
        Some(m) => m.clone(),
        None => Manifest::load(artifacts_dir)?,
    };
    Ok(Box::new(pjrt::PjrtBackend::new(manifest)?))
}

/// The AOT PJRT backend: per-bucket executables compiled once and
/// cached, constant parameter literals built once per artifact.
#[cfg(feature = "pjrt")]
mod pjrt {
    use std::collections::HashMap;
    use std::time::Instant;

    use super::super::artifacts::{ArtifactMeta, Manifest};
    use super::super::backend::{ExecBackend, LayerCtx};
    use super::super::pad::{self, EdgeArrays};
    use super::{EngineError, LayerOut};

    pub struct PjrtBackend {
        manifest: Manifest,
        client: xla::PjRtClient,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
        /// Trained-parameter literals per artifact — weights are
        /// constant across the serving lifetime, so build them once
        /// (§Perf iter. 4).
        param_literals: HashMap<String, Vec<xla::Literal>>,
    }

    impl PjrtBackend {
        pub fn new(manifest: Manifest)
                   -> Result<PjrtBackend, EngineError> {
            let client = xla::PjRtClient::cpu()?;
            Ok(PjrtBackend {
                manifest,
                client,
                executables: HashMap::new(),
                param_literals: HashMap::new(),
            })
        }

        fn compiled(&mut self, meta: &ArtifactMeta)
                    -> Result<(), EngineError> {
            if self.executables.contains_key(&meta.name) {
                return Ok(());
            }
            if std::env::var_os("FOGRAPH_DEBUG").is_some() {
                eprintln!("[engine] compiling {} (v={} e={} l={})",
                          meta.name, meta.v_max, meta.e_max, meta.l_max);
            }
            let proto = xla::HloModuleProto::from_text_file(&meta.path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.executables.insert(meta.name.clone(), exe);
            Ok(())
        }

        fn ensure_params(&mut self, meta: &ArtifactMeta,
                         ctx: &LayerCtx<'_>)
                         -> Result<(), EngineError> {
            if self.param_literals.contains_key(&meta.name) {
                return Ok(());
            }
            let mut params: Vec<xla::Literal> = Vec::new();
            for (pname, dims) in &meta.params {
                let t = ctx
                    .weights
                    .get(&format!("l{}.{pname}", ctx.layer))
                    .expect("weight tensor for artifact param");
                params.push(f32_literal(&t.f32_data, dims)?);
            }
            self.param_literals.insert(meta.name.clone(), params);
            Ok(())
        }
    }

    impl ExecBackend for PjrtBackend {
        fn name(&self) -> &'static str {
            "pjrt"
        }

        fn run_layer(&mut self, ctx: &LayerCtx<'_>, h: &[f32],
                     edges: &EdgeArrays)
                     -> Result<LayerOut, EngineError> {
            let n = edges.n;
            let meta = self
                .manifest
                .select_l(ctx.model, ctx.dataset, ctx.layer, n,
                          edges.num_edges(), edges.n_local)?
                .clone();
            self.compiled(&meta)?;
            self.ensure_params(&meta, ctx)?;

            let t0 = Instant::now();
            let padded = pad::pad_layer(h, n, ctx.f_in, edges,
                                        meta.v_max, meta.e_max,
                                        meta.l_max);
            let mut literals: Vec<&xla::Literal> = Vec::new();
            let cached = &self.param_literals[&meta.name];
            for lit in cached {
                literals.push(lit);
            }
            let mut data_literals: Vec<xla::Literal> = Vec::new();
            for (dname, dims, dtype) in &meta.data {
                let lit = match (dname.as_str(), dtype.as_str()) {
                    ("h", _) => f32_literal(&padded.h, dims)?,
                    ("src", _) => i32_literal(&padded.src, dims)?,
                    ("dst", _) => i32_literal(&padded.dst, dims)?,
                    ("ew", _) => f32_literal(&padded.ew, dims)?,
                    ("inv_deg", _) => {
                        f32_literal(&padded.inv_deg, dims)?
                    }
                    (other, _) => panic!("unknown data input {other}"),
                };
                data_literals.push(lit);
            }
            for lit in &data_literals {
                literals.push(lit);
            }
            let exe = &self.executables[&meta.name];
            let result = exe.execute::<&xla::Literal>(&literals)?[0][0]
                .to_literal_sync()?;
            let out_padded: Vec<f32> =
                result.to_tuple1()?.to_vec::<f32>()?;
            let host = t0.elapsed().as_secs_f64();
            let out_dim = meta.out_dim;
            // the artifact computes [l_max, out_dim]; keep owned rows
            let l = edges.n_local;
            let mut out = vec![0f32; l * out_dim];
            out.copy_from_slice(&out_padded[..l * out_dim]);
            Ok(LayerOut { h: out, out_dim, host_seconds: host })
        }

        fn run_astgcn(&mut self, ctx: &LayerCtx<'_>, x: &[f32],
                      n: usize, sub: &crate::graph::LocalGraph)
                      -> Result<LayerOut, EngineError> {
            let ft = ctx.f_in;
            let meta = self
                .manifest
                .select("astgcn", ctx.dataset, 0, n, 0)?
                .clone();
            self.compiled(&meta)?;
            let t0 = Instant::now();
            let v_max = meta.v_max;
            let mut xp = vec![0f32; v_max * ft];
            xp[..n * ft].copy_from_slice(x);
            let adj = pad::dense_norm_adj(sub, v_max)?;
            let mut literals: Vec<xla::Literal> = Vec::new();
            for (pname, dims) in &meta.params {
                let t = ctx
                    .weights
                    .get(&format!("l0.{pname}"))
                    .expect("astgcn artifact param");
                literals.push(f32_literal(&t.f32_data, dims)?);
            }
            literals.push(f32_literal(&xp, &[v_max, ft])?);
            literals.push(f32_literal(&adj, &[v_max, v_max])?);
            let exe = &self.executables[&meta.name];
            let result = exe.execute::<xla::Literal>(&literals)?[0][0]
                .to_literal_sync()?;
            let outp: Vec<f32> = result.to_tuple1()?.to_vec::<f32>()?;
            let host = t0.elapsed().as_secs_f64();
            let out_dim = meta.out_dim;
            let mut out = vec![0f32; n * out_dim];
            out.copy_from_slice(&outp[..n * out_dim]);
            Ok(LayerOut { h: out, out_dim, host_seconds: host })
        }
    }

    fn f32_literal(data: &[f32], dims: &[usize])
                   -> Result<xla::Literal, EngineError> {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                       data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            dims,
            bytes,
        )?)
    }

    fn i32_literal(data: &[i32], dims: &[usize])
                   -> Result<xla::Literal, EngineError> {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>());
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                       data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            dims,
            bytes,
        )?)
    }
}

/// Deterministic glorot-style init used when a trained bundle is missing
/// (latency experiments only).
fn synthesize_weights(model: &str, f_in: usize, classes: usize, key: &str)
                      -> WeightBundle {
    let hidden = reference::HIDDEN;
    let classes = classes.max(1);
    let mut rng = Rng::new(mix64(key.len() as u64 * 0x9E37) ^ 0xBEEF);
    let dir = std::env::temp_dir();
    let path = dir.join(format!("{key}_synth.fgw"));
    let mut entries: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
    let glorot = |r: usize, c: usize, rng: &mut Rng| -> Vec<f32> {
        let lim = (6.0 / (r + c) as f64).sqrt();
        (0..r * c)
            .map(|_| rng.range_f64(-lim, lim) as f32)
            .collect()
    };
    match model {
        "astgcn" => {
            let datt = 16;
            let t_out = 12;
            entries.push(("l0.w1".into(), vec![f_in, datt],
                          glorot(f_in, datt, &mut rng)));
            entries.push(("l0.w2".into(), vec![f_in, datt],
                          glorot(f_in, datt, &mut rng)));
            entries.push(("l0.wgc".into(), vec![f_in, hidden],
                          glorot(f_in, hidden, &mut rng)));
            entries.push(("l0.wself".into(), vec![f_in, hidden],
                          glorot(f_in, hidden, &mut rng)));
            entries.push(("l0.wout".into(), vec![hidden, t_out],
                          glorot(hidden, t_out, &mut rng)));
            entries.push(("l0.bout".into(), vec![t_out],
                          vec![0.0; t_out]));
        }
        _ => {
            let dims = [(f_in, hidden), (hidden, classes)];
            for (li, &(fi, fo)) in dims.iter().enumerate() {
                let wfi = if model == "sage" { 2 * fi } else { fi };
                entries.push((format!("l{li}.w"), vec![wfi, fo],
                              glorot(wfi, fo, &mut rng)));
                entries.push((format!("l{li}.b"), vec![fo],
                              vec![0.0; fo]));
                if model == "gat" {
                    entries.push((format!("l{li}.a_src"), vec![fo],
                                  glorot(fo, 1, &mut rng)));
                    entries.push((format!("l{li}.a_dst"), vec![fo],
                                  glorot(fo, 1, &mut rng)));
                }
            }
        }
    }
    let refs: Vec<(&str, &[usize], &[f32])> = entries
        .iter()
        .map(|(n, d, v)| (n.as_str(), d.as_slice(), v.as_slice()))
        .collect();
    write_fgw(&path, &refs).expect("write synth weights");
    read_fgw(&path).expect("read synth weights")
}

#[cfg(test)]
mod tests {
    use super::super::pad::EdgeArrays;
    use super::*;

    #[test]
    fn weights_key_collapses_rmat() {
        assert_eq!(weights_key("gcn", "rmat40k"), "weights_gcn_rmat");
        assert_eq!(weights_key("gcn", "siot"), "weights_gcn_siot");
    }

    fn two_vertex_edges() -> EdgeArrays {
        EdgeArrays {
            src: vec![0, 1],
            dst: vec![1, 0],
            ew: vec![1.0, 1.0],
            inv_deg: vec![0.5, 0.5],
            n: 2,
            n_local: 2,
        }
    }

    #[test]
    fn reference_engine_with_synth_weights_runs_all_models() {
        let dir = std::env::temp_dir().join("engine_test_none");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Reference, &dir).unwrap();
        let edges = two_vertex_edges();
        for model in ["gcn", "sage"] {
            let h = vec![1.0f32; 2 * 8];
            let out = eng
                .run_layer(model, "tiny", 0, &h, 8, &edges, 8, 3)
                .unwrap();
            assert_eq!(out.out_dim, reference::HIDDEN);
            assert_eq!(out.h.len(), 2 * reference::HIDDEN);
            // layer 1 -> classes
            let out2 = eng
                .run_layer(model, "tiny", 1, &out.h, out.out_dim, &edges,
                           8, 3)
                .unwrap();
            assert_eq!(out2.out_dim, 3);
        }
        assert!(!eng.synthetic_weights.is_empty());
    }

    #[test]
    fn csr_engine_matches_reference_engine() {
        let dir = std::env::temp_dir().join("engine_test_csr");
        std::fs::create_dir_all(&dir).unwrap();
        let mut re = Engine::new(EngineKind::Reference, &dir).unwrap();
        let mut ce = Engine::new(EngineKind::Csr, &dir).unwrap();
        assert_eq!(ce.backend_name(), "csr");
        let edges = two_vertex_edges();
        for model in ["gcn", "sage", "gat"] {
            let h = vec![0.5f32; 2 * 8];
            let a = re
                .run_layer(model, "tiny", 0, &h, 8, &edges, 8, 3)
                .unwrap();
            let b = ce
                .run_layer(model, "tiny", 0, &h, 8, &edges, 8, 3)
                .unwrap();
            assert_eq!(a.out_dim, b.out_dim);
            let err = a
                .h
                .iter()
                .zip(&b.h)
                .map(|(x, y)| (x - y).abs())
                .fold(0f32, f32::max);
            assert!(err < 1e-5, "{model}: csr deviates by {err}");
        }
    }

    #[test]
    fn batched_facade_matches_serial_on_both_backends() {
        let dir = std::env::temp_dir().join("engine_test_batched");
        std::fs::create_dir_all(&dir).unwrap();
        let edges = two_vertex_edges();
        let h = [0.25f32, 1.0, -0.5, 0.75, 2.0, 0.0, 1.5, -1.0];
        for kind in [EngineKind::Reference, EngineKind::Csr] {
            let mut eng = Engine::new(kind, &dir).unwrap();
            let batched = eng
                .run_layer_batched("gcn", "tiny", 0, &h, 2, &edges, 2,
                                   3, 2)
                .unwrap();
            let a = eng
                .run_layer("gcn", "tiny", 0, &h[..4], 2, &edges, 2, 3)
                .unwrap();
            let b = eng
                .run_layer("gcn", "tiny", 0, &h[4..], 2, &edges, 2, 3)
                .unwrap();
            assert_eq!(batched.out_dim, a.out_dim);
            let d = a.out_dim;
            assert_eq!(&batched.h[..2 * d], &a.h[..]);
            assert_eq!(&batched.h[2 * d..], &b.h[..]);
        }
    }

    #[test]
    fn synth_weights_are_deterministic() {
        let a = synthesize_weights("gcn", 10, 2, "k1");
        let b = synthesize_weights("gcn", 10, 2, "k1");
        assert_eq!(a.get("l0.w").unwrap().f32_data,
                   b.get("l0.w").unwrap().f32_data);
    }
}
