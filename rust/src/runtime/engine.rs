//! Execution engines behind the BSP runtime:
//!
//! * `EngineKind::Pjrt` — loads the AOT HLO-text artifacts produced by the
//!   Python compile path, compiles them ONCE on the PJRT CPU client (one
//!   executable per bucket, cached) and executes layers from the request
//!   path. Python never runs here.
//! * `EngineKind::Reference` — the in-tree pure-Rust forward (numeric
//!   oracle; also used for very large sweeps where bucket padding cost
//!   obscures the effect under study).
//!
//! Weight bundles come from `artifacts/weights_<model>_<dataset>.fgw`
//! (training output). When a bundle is absent the engine falls back to a
//! deterministic glorot init so latency experiments remain runnable
//! without the training step; accuracy experiments require real weights.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::rng::{mix64, Rng};

#[cfg(feature = "pjrt")]
use super::artifacts::ArtifactMeta;
use super::artifacts::{Manifest, ManifestError};
use super::pad::{self, EdgeArrays};
use super::reference;
use super::weights::{read_fgw, write_fgw, WeightBundle};

#[derive(Debug)]
pub enum EngineError {
    Manifest(ManifestError),
    Weights(super::weights::FgwError),
    Xla(String),
    Io(std::io::Error),
    /// Unknown model name reached the runtime (user input).
    Model(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::Manifest(e) => write!(f, "manifest: {e}"),
            EngineError::Weights(e) => write!(f, "weights: {e}"),
            EngineError::Xla(m) => write!(f, "xla: {m}"),
            EngineError::Io(e) => write!(f, "io: {e}"),
            EngineError::Model(m) => write!(f, "unknown model {m}"),
        }
    }
}

impl std::error::Error for EngineError {}

impl From<ManifestError> for EngineError {
    fn from(e: ManifestError) -> Self {
        EngineError::Manifest(e)
    }
}

impl From<super::weights::FgwError> for EngineError {
    fn from(e: super::weights::FgwError) -> Self {
        EngineError::Weights(e)
    }
}

impl From<std::io::Error> for EngineError {
    fn from(e: std::io::Error) -> Self {
        EngineError::Io(e)
    }
}

impl From<pad::UnknownModel> for EngineError {
    fn from(e: pad::UnknownModel) -> Self {
        EngineError::Model(e.0)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for EngineError {
    fn from(e: xla::Error) -> Self {
        EngineError::Xla(e.to_string())
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Pjrt,
    Reference,
}

/// Output of one layer execution.
#[derive(Clone, Debug)]
pub struct LayerOut {
    /// [n, out_dim] row-major, unpadded.
    pub h: Vec<f32>,
    pub out_dim: usize,
    /// Host wall-clock of the compute (scaled by fog multipliers upstream).
    pub host_seconds: f64,
}

#[cfg(feature = "pjrt")]
struct PjrtState {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Trained-parameter literals per artifact — weights are constant
    /// across the serving lifetime, so build them once (§Perf iter. 4).
    param_literals: HashMap<String, Vec<xla::Literal>>,
}

/// Placeholder so the engine's shape is identical without the feature;
/// no value of this type is ever constructed then.
#[cfg(not(feature = "pjrt"))]
#[allow(dead_code)]
struct PjrtState {}

#[cfg(feature = "pjrt")]
fn init_pjrt(artifacts_dir: &Path)
             -> Result<(Option<Manifest>, Option<PjrtState>), EngineError> {
    let m = Manifest::load(artifacts_dir)?;
    let client = xla::PjRtClient::cpu()?;
    Ok((Some(m), Some(PjrtState {
        client,
        executables: HashMap::new(),
        param_literals: HashMap::new(),
    })))
}

#[cfg(not(feature = "pjrt"))]
fn init_pjrt(_artifacts_dir: &Path)
             -> Result<(Option<Manifest>, Option<PjrtState>), EngineError> {
    Err(EngineError::Xla(
        "built without the `pjrt` cargo feature; use the reference \
         engine, or vendor the xla crate (see rust/Cargo.toml) and \
         rebuild with --features pjrt"
            .to_string(),
    ))
}

pub struct Engine {
    pub kind: EngineKind,
    artifacts_dir: PathBuf,
    manifest: Option<Manifest>,
    pjrt: Option<PjrtState>,
    weights: HashMap<String, WeightBundle>,
    /// Names of bundles that were random-initialized (missing on disk).
    pub synthetic_weights: Vec<String>,
}

fn weights_key(model: &str, dataset: &str) -> String {
    let ds = if dataset.starts_with("rmat") { "rmat" } else { dataset };
    format!("weights_{model}_{ds}")
}

impl Engine {
    pub fn new(kind: EngineKind, artifacts_dir: &Path)
               -> Result<Engine, EngineError> {
        let (manifest, pjrt) = match kind {
            EngineKind::Pjrt => init_pjrt(artifacts_dir)?,
            EngineKind::Reference => {
                (Manifest::load(artifacts_dir).ok(), None)
            }
        };
        Ok(Engine {
            kind,
            artifacts_dir: artifacts_dir.to_path_buf(),
            manifest,
            pjrt,
            weights: HashMap::new(),
            synthetic_weights: Vec::new(),
        })
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    /// Fetch (or lazily load / synthesize) the weight bundle.
    pub fn weights(&mut self, model: &str, dataset: &str, f_in: usize,
                   classes: usize) -> &WeightBundle {
        let key = weights_key(model, dataset);
        if !self.weights.contains_key(&key) {
            let path = self.artifacts_dir.join(format!("{key}.fgw"));
            let bundle = match read_fgw(&path) {
                Ok(b) => b,
                Err(_) => {
                    self.synthetic_weights.push(key.clone());
                    synthesize_weights(model, f_in, classes, &key)
                }
            };
            self.weights.insert(key.clone(), bundle);
        }
        &self.weights[&key]
    }

    /// Execute one message-passing layer on a partition.
    pub fn run_layer(
        &mut self,
        model: &str,
        dataset: &str,
        layer: usize,
        h: &[f32],
        f_in: usize,
        edges: &EdgeArrays,
        f_raw: usize,
        classes: usize,
    ) -> Result<LayerOut, EngineError> {
        let n = edges.n;
        let last = layer + 1 == reference::model_layers(model);
        match self.kind {
            EngineKind::Reference => {
                let wb = self
                    .weights(model, dataset, f_raw, classes)
                    .clone();
                let t = Instant::now();
                let out = reference::run_layer(model, layer, &wb, h, f_in,
                                               edges, last)?;
                let host = t.elapsed().as_secs_f64();
                let out_dim = out.len() / edges.n_local.max(1);
                let _ = n;
                Ok(LayerOut { h: out, out_dim, host_seconds: host })
            }
            EngineKind::Pjrt => {
                self.run_layer_pjrt(model, dataset, layer, h, f_in, edges,
                                    f_raw, classes)
            }
        }
    }

    #[cfg(feature = "pjrt")]
    fn compiled(&mut self, meta: &ArtifactMeta)
                -> Result<(), EngineError> {
        let st = self.pjrt.as_mut().expect("pjrt state");
        if st.executables.contains_key(&meta.name) {
            return Ok(());
        }
        if std::env::var_os("FOGRAPH_DEBUG").is_some() {
            eprintln!("[engine] compiling {} (v={} e={} l={})",
                      meta.name, meta.v_max, meta.e_max, meta.l_max);
        }
        let proto = xla::HloModuleProto::from_text_file(&meta.path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = st.client.compile(&comp)?;
        st.executables.insert(meta.name.clone(), exe);
        Ok(())
    }

    /// Unreachable without the feature: `Engine::new(Pjrt, ..)` already
    /// failed, so no Pjrt-kind engine exists to dispatch here.
    #[cfg(not(feature = "pjrt"))]
    #[allow(clippy::too_many_arguments)]
    fn run_layer_pjrt(
        &mut self,
        _model: &str,
        _dataset: &str,
        _layer: usize,
        _h: &[f32],
        _f_in: usize,
        _edges: &EdgeArrays,
        _f_raw: usize,
        _classes: usize,
    ) -> Result<LayerOut, EngineError> {
        Err(EngineError::Xla("pjrt feature disabled".to_string()))
    }

    #[cfg(feature = "pjrt")]
    #[allow(clippy::too_many_arguments)]
    fn run_layer_pjrt(
        &mut self,
        model: &str,
        dataset: &str,
        layer: usize,
        h: &[f32],
        f_in: usize,
        edges: &EdgeArrays,
        f_raw: usize,
        classes: usize,
    ) -> Result<LayerOut, EngineError> {
        let n = edges.n;
        let meta = self
            .manifest
            .as_ref()
            .expect("pjrt engine has manifest")
            .select_l(model, dataset, layer, n, edges.num_edges(),
                      edges.n_local)?
            .clone();
        self.compiled(&meta)?;
        let wb = self.weights(model, dataset, f_raw, classes).clone();
        // constant parameter literals, built once per artifact
        if !self
            .pjrt
            .as_ref()
            .unwrap()
            .param_literals
            .contains_key(&meta.name)
        {
            let mut params: Vec<xla::Literal> = Vec::new();
            for (pname, dims) in &meta.params {
                let t = wb
                    .get(&format!("l{layer}.{pname}"))
                    .expect("weight tensor for artifact param");
                params.push(f32_literal(&t.f32_data, dims)?);
            }
            self.pjrt
                .as_mut()
                .unwrap()
                .param_literals
                .insert(meta.name.clone(), params);
        }

        let t0 = Instant::now();
        let padded = pad::pad_layer(h, n, f_in, edges, meta.v_max,
                                    meta.e_max, meta.l_max);
        let mut literals: Vec<&xla::Literal> = Vec::new();
        let st = self.pjrt.as_ref().unwrap();
        let cached = &st.param_literals[&meta.name];
        for lit in cached {
            literals.push(lit);
        }
        let mut data_literals: Vec<xla::Literal> = Vec::new();
        for (dname, dims, dtype) in &meta.data {
            let lit = match (dname.as_str(), dtype.as_str()) {
                ("h", _) => f32_literal(&padded.h, dims)?,
                ("src", _) => i32_literal(&padded.src, dims)?,
                ("dst", _) => i32_literal(&padded.dst, dims)?,
                ("ew", _) => f32_literal(&padded.ew, dims)?,
                ("inv_deg", _) => f32_literal(&padded.inv_deg, dims)?,
                (other, _) => panic!("unknown data input {other}"),
            };
            data_literals.push(lit);
        }
        for lit in &data_literals {
            literals.push(lit);
        }
        let exe = &st.executables[&meta.name];
        let result = exe.execute::<&xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let out_padded: Vec<f32> = result.to_tuple1()?.to_vec::<f32>()?;
        let host = t0.elapsed().as_secs_f64();
        let out_dim = meta.out_dim;
        // the artifact computes [l_max, out_dim]; keep owned rows only
        let l = edges.n_local;
        let mut out = vec![0f32; l * out_dim];
        out.copy_from_slice(&out_padded[..l * out_dim]);
        Ok(LayerOut { h: out, out_dim, host_seconds: host })
    }

    /// Execute the ASTGCN block on a partition (dense adjacency).
    pub fn run_astgcn(&mut self, dataset: &str, x: &[f32], n: usize,
                      ft: usize, sub: &crate::graph::LocalGraph)
                      -> Result<LayerOut, EngineError> {
        match self.kind {
            EngineKind::Reference => {
                let wb = self.weights("astgcn", dataset, ft, 0).clone();
                let adj = pad::dense_norm_adj(sub, n);
                let t = Instant::now();
                let out = reference::run_astgcn(&wb, x, n, ft, &adj);
                let host = t.elapsed().as_secs_f64();
                let out_dim = out.len() / n;
                Ok(LayerOut { h: out, out_dim, host_seconds: host })
            }
            EngineKind::Pjrt => self.run_astgcn_pjrt(dataset, x, n, ft, sub),
        }
    }

    /// See `run_layer_pjrt`: unreachable without the feature.
    #[cfg(not(feature = "pjrt"))]
    fn run_astgcn_pjrt(&mut self, _dataset: &str, _x: &[f32], _n: usize,
                       _ft: usize, _sub: &crate::graph::LocalGraph)
                       -> Result<LayerOut, EngineError> {
        Err(EngineError::Xla("pjrt feature disabled".to_string()))
    }

    #[cfg(feature = "pjrt")]
    fn run_astgcn_pjrt(&mut self, dataset: &str, x: &[f32], n: usize,
                       ft: usize, sub: &crate::graph::LocalGraph)
                       -> Result<LayerOut, EngineError> {
        let meta = self
            .manifest
            .as_ref()
            .expect("manifest")
            .select("astgcn", dataset, 0, n, 0)?
            .clone();
        self.compiled(&meta)?;
        let wb = self.weights("astgcn", dataset, ft, 0).clone();
        let t0 = Instant::now();
        let v_max = meta.v_max;
        let mut xp = vec![0f32; v_max * ft];
        xp[..n * ft].copy_from_slice(x);
        let adj = pad::dense_norm_adj(sub, v_max);
        let mut literals: Vec<xla::Literal> = Vec::new();
        for (pname, dims) in &meta.params {
            let t = wb.get(&format!("l0.{pname}")).unwrap();
            literals.push(f32_literal(&t.f32_data, dims)?);
        }
        literals.push(f32_literal(&xp, &[v_max, ft])?);
        literals.push(f32_literal(&adj, &[v_max, v_max])?);
        let st = self.pjrt.as_ref().unwrap();
        let exe = &st.executables[&meta.name];
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let outp: Vec<f32> = result.to_tuple1()?.to_vec::<f32>()?;
        let host = t0.elapsed().as_secs_f64();
        let out_dim = meta.out_dim;
        let mut out = vec![0f32; n * out_dim];
        out.copy_from_slice(&outp[..n * out_dim]);
        Ok(LayerOut { h: out, out_dim, host_seconds: host })
    }
}

#[cfg(feature = "pjrt")]
fn f32_literal(data: &[f32], dims: &[usize])
               -> Result<xla::Literal, EngineError> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

#[cfg(feature = "pjrt")]
fn i32_literal(data: &[i32], dims: &[usize])
               -> Result<xla::Literal, EngineError> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        dims,
        bytes,
    )?)
}

/// Deterministic glorot-style init used when a trained bundle is missing
/// (latency experiments only).
fn synthesize_weights(model: &str, f_in: usize, classes: usize, key: &str)
                      -> WeightBundle {
    let hidden = reference::HIDDEN;
    let classes = classes.max(1);
    let mut rng = Rng::new(mix64(key.len() as u64 * 0x9E37) ^ 0xBEEF);
    let dir = std::env::temp_dir();
    let path = dir.join(format!("{key}_synth.fgw"));
    let mut entries: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
    let glorot = |r: usize, c: usize, rng: &mut Rng| -> Vec<f32> {
        let lim = (6.0 / (r + c) as f64).sqrt();
        (0..r * c)
            .map(|_| rng.range_f64(-lim, lim) as f32)
            .collect()
    };
    match model {
        "astgcn" => {
            let datt = 16;
            let t_out = 12;
            entries.push(("l0.w1".into(), vec![f_in, datt],
                          glorot(f_in, datt, &mut rng)));
            entries.push(("l0.w2".into(), vec![f_in, datt],
                          glorot(f_in, datt, &mut rng)));
            entries.push(("l0.wgc".into(), vec![f_in, hidden],
                          glorot(f_in, hidden, &mut rng)));
            entries.push(("l0.wself".into(), vec![f_in, hidden],
                          glorot(f_in, hidden, &mut rng)));
            entries.push(("l0.wout".into(), vec![hidden, t_out],
                          glorot(hidden, t_out, &mut rng)));
            entries.push(("l0.bout".into(), vec![t_out],
                          vec![0.0; t_out]));
        }
        _ => {
            let dims = [(f_in, hidden), (hidden, classes)];
            for (li, &(fi, fo)) in dims.iter().enumerate() {
                let wfi = if model == "sage" { 2 * fi } else { fi };
                entries.push((format!("l{li}.w"), vec![wfi, fo],
                              glorot(wfi, fo, &mut rng)));
                entries.push((format!("l{li}.b"), vec![fo],
                              vec![0.0; fo]));
                if model == "gat" {
                    entries.push((format!("l{li}.a_src"), vec![fo],
                                  glorot(fo, 1, &mut rng)));
                    entries.push((format!("l{li}.a_dst"), vec![fo],
                                  glorot(fo, 1, &mut rng)));
                }
            }
        }
    }
    let refs: Vec<(&str, &[usize], &[f32])> = entries
        .iter()
        .map(|(n, d, v)| (n.as_str(), d.as_slice(), v.as_slice()))
        .collect();
    write_fgw(&path, &refs).expect("write synth weights");
    read_fgw(&path).expect("read synth weights")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_key_collapses_rmat() {
        assert_eq!(weights_key("gcn", "rmat40k"), "weights_gcn_rmat");
        assert_eq!(weights_key("gcn", "siot"), "weights_gcn_siot");
    }

    #[test]
    fn reference_engine_with_synth_weights_runs_all_models() {
        let dir = std::env::temp_dir().join("engine_test_none");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Reference, &dir).unwrap();
        let edges = EdgeArrays {
            src: vec![0, 1],
            dst: vec![1, 0],
            ew: vec![1.0, 1.0],
            inv_deg: vec![0.5, 0.5],
            n: 2,
            n_local: 2,
        };
        for model in ["gcn", "sage"] {
            let h = vec![1.0f32; 2 * 8];
            let out = eng
                .run_layer(model, "tiny", 0, &h, 8, &edges, 8, 3)
                .unwrap();
            assert_eq!(out.out_dim, reference::HIDDEN);
            assert_eq!(out.h.len(), 2 * reference::HIDDEN);
            // layer 1 -> classes
            let out2 = eng
                .run_layer(model, "tiny", 1, &out.h, out.out_dim, &edges,
                           8, 3)
                .unwrap();
            assert_eq!(out2.out_dim, 3);
        }
        assert!(!eng.synthetic_weights.is_empty());
    }

    #[test]
    fn synth_weights_are_deterministic() {
        let a = synthesize_weights("gcn", 10, 2, "k1");
        let b = synthesize_weights("gcn", 10, 2, "k1");
        assert_eq!(a.get("l0.w").unwrap().f32_data,
                   b.get("l0.w").unwrap().f32_data);
    }
}
