//! Cache-blocked, autovectorization-friendly GEMM with bias — the
//! combination kernel every backend routes through (Wu et al.'s
//! characterization: combination is compute-bound, so the win is
//! register blocking, not memory layout).
//!
//! Structure: `MR` output rows are computed together (one register
//! block, so each streamed weight row is reused `MR` times), and the K
//! dimension is unrolled in groups of `KU`, so each pass over the
//! output row performs `MR × KU` fused multiply-adds per element
//! between one load/store round-trip — `KU×` less out-row traffic and
//! a `KU`-deep independent-sum tree that hides FP latency. All inner
//! loops run over fixed-length zipped slices, so LLVM emits
//! bounds-check-free SIMD.
//!
//! Row-decomposition invariance (the property the intra-fog sharded
//! pool relies on): every output row's arithmetic is a pure function
//! of that row's inputs — the one-hot zero-group skip is decided PER
//! ROW, never jointly for an MR pair, and the fused two-row loop
//! evaluates the same `a0·w0 + a1·w1 + a2·w2 + a3·w3` expression the
//! single-row path does. Computing rows `[r0, r1)` of a matrix
//! therefore produces bit-identical values to the same rows of the
//! full-matrix call, for ANY contiguous split — pooled, sharded and
//! serial execution agree bit-for-bit, and
//! `tests/backend_parity.rs` asserts it across random split points.
//!
//! Dispatch: when the one-time `kernels::simd` probe detects
//! `avx2+fma`, `gemm_bias_into` routes to the 8-wide FMA micro-kernel
//! (`simd::x86::gemm_bias_into`) — same row structure, same per-row
//! skip, ~1e-7-relative drift from FMA contraction (asserted ≤ 1e-5
//! against the scalar path). `gemm_bias_into_scalar` keeps the
//! portable kernel callable directly for parity tests and margin
//! measurement.
//!
//! Design note: the textbook MR×NR accumulator-tile micro-kernel
//! (accumulators held in a fixed NR-wide register tile, K-panelized)
//! was measured here too and LOSES under baseline x86-64 codegen — a
//! 4×16 f32 tile is the entire SSE register file, so the accumulators
//! spill and the kernel runs below the naive loop. The shipped
//! row-paired K-unrolled form is the variant that actually wins at
//! serving shapes; `repro bench-kernels` records the measured margin
//! in BENCH_kernels.json. (Re-measure before re-attempting tiles on
//! the AVX2 path too — the current AVX2 kernel keeps the row-at-a-time
//! structure and wins on width + FMA alone.)
//!
//! The naive kernel's one-hot zero skip survives as a per-group branch
//! (a K group whose `KU` x-entries are all zero is skipped), so
//! sparse layer-0 feature matrices keep their fast path.
//! `gemm_bias_naive` preserves the textbook triple loop as the numeric
//! baseline; `rust/tests/backend_parity.rs` asserts tiled == naive
//! within 1e-5 across random shapes.

use super::simd;

/// Output rows per register block.
pub const MR: usize = 2;
/// K-unroll depth (weight rows streamed per out-row round-trip).
pub const KU: usize = 4;

/// Textbook row-at-a-time matmul with bias — the naive baseline
/// (formerly `reference::matmul_bias`). Kept verbatim so parity tests
/// and `repro bench-kernels` can quantify the blocked kernel against
/// it.
pub fn gemm_bias_naive(x: &[f32], n: usize, fi: usize, w: &[f32],
                       fo: usize, b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * fi);
    debug_assert_eq!(w.len(), fi * fo);
    let mut out = vec![0f32; n * fo];
    for r in 0..n {
        let xr = &x[r * fi..(r + 1) * fi];
        let or = &mut out[r * fo..(r + 1) * fo];
        or.copy_from_slice(&b[..fo]);
        for (k, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue; // sparse one-hot features: skip zero entries
            }
            let wr = &w[k * fo..(k + 1) * fo];
            for (o, &wv) in or.iter_mut().zip(wr.iter()) {
                *o += xv * wv;
            }
        }
    }
    out
}

/// Blocked `out[n, fo] = x[n, fi] @ w[fi, fo] + b` into a fresh vector.
pub fn gemm_bias(x: &[f32], n: usize, fi: usize, w: &[f32], fo: usize,
                 b: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; n * fo];
    gemm_bias_into(x, n, fi, w, fo, b, &mut out);
    out
}

/// Rows `[r0, r1)` of the blocked matmul-with-bias — the row-range
/// view the sharded pool executes. Bit-identical to the same rows of
/// the full call (row-decomposition invariance, see module docs).
pub fn gemm_bias_rows(x: &[f32], fi: usize, w: &[f32], fo: usize,
                      b: &[f32], r0: usize, r1: usize) -> Vec<f32> {
    debug_assert!(r0 <= r1 && r1 * fi <= x.len());
    gemm_bias(&x[r0 * fi..r1 * fi], r1 - r0, fi, w, fo, b)
}

/// Matmul-with-bias writing into a caller-owned buffer (the
/// scratch-reuse entry point; `out` is fully overwritten). Dispatches
/// to the AVX2+FMA micro-kernel when the runtime probe detected it.
pub fn gemm_bias_into(x: &[f32], n: usize, fi: usize, w: &[f32],
                      fo: usize, b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), n * fi);
    debug_assert_eq!(w.len(), fi * fo);
    assert_eq!(out.len(), n * fo);
    if simd::try_gemm_bias_into(x, n, fi, w, fo, b, out) {
        return;
    }
    gemm_bias_into_scalar(x, n, fi, w, fo, b, out);
}

/// The portable blocked kernel (tuned for baseline SSE2 codegen) —
/// public so parity tests and `repro bench-kernels` can measure the
/// SIMD path against it regardless of what the dispatcher picked.
pub fn gemm_bias_into_scalar(x: &[f32], n: usize, fi: usize, w: &[f32],
                             fo: usize, b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), n * fi);
    debug_assert_eq!(w.len(), fi * fo);
    assert_eq!(out.len(), n * fo);
    for r in 0..n {
        out[r * fo..(r + 1) * fo].copy_from_slice(&b[..fo]);
    }
    let mut r = 0;
    while r + MR <= n {
        let xa = &x[r * fi..(r + 1) * fi];
        let xb = &x[(r + 1) * fi..(r + 2) * fi];
        let (oa, ob) =
            out[r * fo..(r + 2) * fo].split_at_mut(fo);
        let mut k = 0;
        while k + KU <= fi {
            let (a0, a1, a2, a3) =
                (xa[k], xa[k + 1], xa[k + 2], xa[k + 3]);
            let (b0, b1, b2, b3) =
                (xb[k], xb[k + 1], xb[k + 2], xb[k + 3]);
            // one-hot fast path, decided PER ROW so any row split
            // reproduces the same arithmetic (see module docs)
            let za = a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0;
            let zb = b0 == 0.0 && b1 == 0.0 && b2 == 0.0 && b3 == 0.0;
            if za && zb {
                k += KU;
                continue;
            }
            let w0 = &w[k * fo..(k + 1) * fo];
            let w1 = &w[(k + 1) * fo..(k + 2) * fo];
            let w2 = &w[(k + 2) * fo..(k + 3) * fo];
            let w3 = &w[(k + 3) * fo..(k + 4) * fo];
            if !za && !zb {
                let it = oa
                    .iter_mut()
                    .zip(ob.iter_mut())
                    .zip(w0)
                    .zip(w1)
                    .zip(w2)
                    .zip(w3);
                for (((((ov_a, ov_b), &v0), &v1), &v2), &v3) in it {
                    *ov_a += a0 * v0 + a1 * v1 + a2 * v2 + a3 * v3;
                    *ov_b += b0 * v0 + b1 * v1 + b2 * v2 + b3 * v3;
                }
            } else if !za {
                let it = oa.iter_mut().zip(w0).zip(w1).zip(w2).zip(w3);
                for ((((ov, &v0), &v1), &v2), &v3) in it {
                    *ov += a0 * v0 + a1 * v1 + a2 * v2 + a3 * v3;
                }
            } else {
                let it = ob.iter_mut().zip(w0).zip(w1).zip(w2).zip(w3);
                for ((((ov, &v0), &v1), &v2), &v3) in it {
                    *ov += b0 * v0 + b1 * v1 + b2 * v2 + b3 * v3;
                }
            }
            k += KU;
        }
        while k < fi {
            let av = xa[k];
            let bv = xb[k];
            if av != 0.0 || bv != 0.0 {
                let wr = &w[k * fo..(k + 1) * fo];
                if av != 0.0 && bv != 0.0 {
                    for ((ov_a, ov_b), &wv) in
                        oa.iter_mut().zip(ob.iter_mut()).zip(wr)
                    {
                        *ov_a += av * wv;
                        *ov_b += bv * wv;
                    }
                } else if av != 0.0 {
                    for (ov, &wv) in oa.iter_mut().zip(wr) {
                        *ov += av * wv;
                    }
                } else {
                    for (ov, &wv) in ob.iter_mut().zip(wr) {
                        *ov += bv * wv;
                    }
                }
            }
            k += 1;
        }
        r += MR;
    }
    // row remainder (n odd): single-row K-unrolled sweep
    while r < n {
        let xr = &x[r * fi..(r + 1) * fi];
        let or = &mut out[r * fo..(r + 1) * fo];
        let mut k = 0;
        while k + KU <= fi {
            let (a0, a1, a2, a3) =
                (xr[k], xr[k + 1], xr[k + 2], xr[k + 3]);
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                k += KU;
                continue;
            }
            let w0 = &w[k * fo..(k + 1) * fo];
            let w1 = &w[(k + 1) * fo..(k + 2) * fo];
            let w2 = &w[(k + 2) * fo..(k + 3) * fo];
            let w3 = &w[(k + 3) * fo..(k + 4) * fo];
            let it = or.iter_mut().zip(w0).zip(w1).zip(w2).zip(w3);
            for ((((ov, &v0), &v1), &v2), &v3) in it {
                *ov += a0 * v0 + a1 * v1 + a2 * v2 + a3 * v3;
            }
            k += KU;
        }
        while k < fi {
            let xv = xr[k];
            if xv != 0.0 {
                let wr = &w[k * fo..(k + 1) * fo];
                for (ov, &wv) in or.iter_mut().zip(wr) {
                    *ov += xv * wv;
                }
            }
            k += 1;
        }
        r += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let tol = 1e-5 * (1.0 + x.abs().max(y.abs()));
            assert!((x - y).abs() <= tol, "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn blocked_matches_naive_on_block_multiples() {
        let mut rng = Rng::new(11);
        let (n, fi, fo) = (MR * 6, KU * 8, 64);
        let x: Vec<f32> =
            (0..n * fi).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let w: Vec<f32> =
            (0..fi * fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let b: Vec<f32> =
            (0..fo).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        close(&gemm_bias(&x, n, fi, &w, fo, &b),
              &gemm_bias_naive(&x, n, fi, &w, fo, &b));
    }

    #[test]
    fn blocked_matches_naive_on_ragged_shapes() {
        let mut rng = Rng::new(12);
        for &(n, fi, fo) in &[(1, 1, 1), (3, 5, 7), (MR + 1, KU + 3, 9),
                              (17, 33, 15), (9, 2, 130), (5, KU - 1, 6)]
        {
            let x: Vec<f32> = (0..n * fi)
                .map(|_| {
                    if rng.bool(0.4) {
                        0.0
                    } else {
                        rng.normal_f32(0.0, 0.3)
                    }
                })
                .collect();
            let w: Vec<f32> =
                (0..fi * fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
            let b: Vec<f32> =
                (0..fo).map(|_| rng.normal_f32(0.0, 0.5)).collect();
            close(&gemm_bias(&x, n, fi, &w, fo, &b),
                  &gemm_bias_naive(&x, n, fi, &w, fo, &b));
        }
    }

    #[test]
    fn zero_rows_produce_bias_rows() {
        let (n, fi, fo) = (MR * 2 + 1, 24, 10);
        let x = vec![0f32; n * fi];
        let w = vec![0.5f32; fi * fo];
        let b: Vec<f32> = (0..fo).map(|c| c as f32).collect();
        let out = gemm_bias(&x, n, fi, &w, fo, &b);
        for r in 0..n {
            assert_eq!(&out[r * fo..(r + 1) * fo], &b[..]);
        }
    }

    #[test]
    fn into_variant_overwrites_stale_contents() {
        let mut rng = Rng::new(13);
        let (n, fi, fo) = (6, 10, 12);
        let x: Vec<f32> =
            (0..n * fi).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let w: Vec<f32> =
            (0..fi * fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let b = vec![0.25f32; fo];
        let mut out = vec![777f32; n * fo];
        gemm_bias_into(&x, n, fi, &w, fo, &b, &mut out);
        close(&out, &gemm_bias_naive(&x, n, fi, &w, fo, &b));
    }

    /// THE sharding invariant: any contiguous row split reproduces the
    /// full-matrix result bit-for-bit, including rows with the one-hot
    /// zero-group fast path (whichever SIMD path is dispatched).
    #[test]
    fn row_splits_are_bitwise_identical() {
        let mut rng = Rng::new(14);
        for trial in 0..20 {
            let n = 3 + rng.usize_below(40);
            let fi = 1 + rng.usize_below(50);
            let fo = 1 + rng.usize_below(40);
            let x: Vec<f32> = (0..n * fi)
                .map(|_| {
                    if rng.bool(0.35) {
                        0.0
                    } else {
                        rng.normal_f32(0.0, 0.3)
                    }
                })
                .collect();
            let w: Vec<f32> =
                (0..fi * fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
            let b: Vec<f32> =
                (0..fo).map(|_| rng.normal_f32(0.0, 0.5)).collect();
            let full = gemm_bias(&x, n, fi, &w, fo, &b);
            let cut = 1 + rng.usize_below(n - 1);
            let mut stitched =
                gemm_bias_rows(&x, fi, &w, fo, &b, 0, cut);
            stitched.extend(gemm_bias_rows(&x, fi, &w, fo, &b, cut, n));
            assert_eq!(full, stitched,
                       "trial {trial}: split at {cut}/{n} deviates");
        }
    }

    /// When AVX2+FMA is detected the dispatched kernel must stay
    /// within 1e-5 relative of the portable scalar kernel.
    #[test]
    fn dispatched_matches_scalar_within_tolerance() {
        let mut rng = Rng::new(15);
        let (n, fi, fo) = (33, 47, 29);
        let x: Vec<f32> =
            (0..n * fi).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let w: Vec<f32> =
            (0..fi * fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let b: Vec<f32> =
            (0..fo).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let dispatched = gemm_bias(&x, n, fi, &w, fo, &b);
        let mut scalar = vec![0f32; n * fo];
        gemm_bias_into_scalar(&x, n, fi, &w, fo, &b, &mut scalar);
        close(&dispatched, &scalar);
    }
}
