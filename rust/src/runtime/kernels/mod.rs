//! The dedicated kernel layer every execution backend routes through:
//!
//! * `gemm` — register-blocked, K-unrolled matmul-with-bias (the
//!   combination kernel; compute-bound, so the win is weight-row reuse
//!   across an MR-row block and KU-deep independent sums).
//! * `spmm` — edge-unrolled CSR aggregation (the memory-bandwidth-bound
//!   kernel; full-width sequential gathers the prefetcher can follow,
//!   EU source rows per out-row round-trip).
//! * `pool` — persistent per-fog worker threads with channel handoff,
//!   so measured per-batch timings reflect kernel cost rather than
//!   thread start-up. Each fog worker leads a `shard` helper group
//!   sized from its partition volume, so large partitions run
//!   row-parallel inside the fog (`--kernel-threads`).
//! * `shard` — intra-fog row parallelism: deterministic contiguous
//!   row ranges with fixed-order reduction, so pooled, sharded and
//!   serial execution are bit-identical.
//! * `simd` — one-time runtime dispatch (`is_x86_feature_detected!`)
//!   to `target_feature(avx2,fma)` micro-kernels, with the shipped
//!   SSE2-tuned shapes as the portable fallback.
//!
//! The tile/unroll shapes were chosen by measurement (see the design
//! notes in `gemm.rs` / `spmm.rs`): the classic MR×NR accumulator tile
//! and the row-blocked + feature-tiled SpMM both regress under
//! baseline x86-64 codegen, so the shipped kernels are the variants
//! that actually win at serving shapes.
//!
//! Both compute kernels keep their naive predecessors
//! (`gemm_bias_naive` / `csr_spmm_naive`) as in-tree baselines:
//! `rust/tests/backend_parity.rs` asserts numerical parity and
//! `repro bench-kernels` records the measured speedups in
//! BENCH_kernels.json.

pub mod gemm;
pub mod pool;
pub mod shard;
pub mod simd;
pub mod spmm;

pub use gemm::{gemm_bias, gemm_bias_into, gemm_bias_naive,
               gemm_bias_rows};
pub use pool::{group_widths, FogJob, FogKernel, FogWorkerPool,
               Inject, JobTrace, Reply, DEFAULT_TASK_DEADLINE_S};
pub use shard::{min_rows_per_shard, min_rows_per_shard_env,
                min_rows_per_shard_source, probe_min_rows_per_shard,
                split_rows, ShardClosure, ShardExec, ShardGroup};
pub use spmm::{csr_spmm, csr_spmm_into, csr_spmm_naive,
               csr_spmm_rows};

/// Reusable intermediate buffers for the layer kernels — one per
/// executor (backend or pool worker), so the per-layer/per-batch hot
/// path performs no `Vec` allocations for aggregates, combine inputs or
/// attention projections (buffers grow once to the high-water mark and
/// are reused forever).
#[derive(Debug, Default)]
pub struct KernelScratch {
    /// SpMM aggregate, [n_local, f].
    pub agg: Vec<f32>,
    /// Combine-stage GEMM input, [batch * n_local, f or 2f].
    pub comb: Vec<f32>,
    /// Dense projection (GAT z), [batch * n, fo].
    pub z: Vec<f32>,
    /// Per-row attention scalars (GAT), [batch * n] each.
    pub att_src: Vec<f32>,
    pub att_dst: Vec<f32>,
}

/// Resize a scratch buffer to `len` elements without shrinking its
/// capacity. Contents are UNSPECIFIED (stale data from earlier layers
/// survives): every kernel that takes a scratch buffer fully
/// overwrites it, so zero-filling here would be a redundant
/// O(len) memset on the per-layer hot path (only newly grown tail
/// elements are initialized, and growth stops at the high-water mark).
pub fn resized(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
    if buf.len() < len {
        buf.resize(len, 0.0);
    } else {
        buf.truncate(len);
    }
    buf.as_mut_slice()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resized_reuses_capacity_and_keeps_stale_prefix() {
        let mut buf = vec![1.0f32; 128];
        let cap = buf.capacity();
        let s = resized(&mut buf, 64);
        assert_eq!(s.len(), 64);
        assert_eq!(buf.capacity(), cap);
        // growth initializes only the new tail; the prefix is stale by
        // contract (every kernel consumer fully overwrites)
        let s2 = resized(&mut buf, 200);
        assert_eq!(s2.len(), 200);
        assert!(s2[128..].iter().all(|&x| x == 0.0));
    }
}
