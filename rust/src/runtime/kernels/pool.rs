//! Persistent per-fog worker pool: one long-lived thread per fog with
//! channel handoff, replacing the per-micro-batch `std::thread::scope`
//! spawns the measured serving path used before. Spawning costs tens of
//! microseconds per thread per batch — comparable to a small bucket's
//! entire kernel time — so with the pool, measured per-bucket timings
//! reflect kernel cost, not thread start-up.
//!
//! Each worker owns its fog's partition structures (`Arc`-shared with
//! the plan) and a private `KernelScratch`, so the steady-state batch
//! path allocates nothing but the output activations. The BSP barrier
//! is the result collection in `dispatch`: one reply per dispatched
//! job.

use std::cell::Cell;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::graph::LocalGraph;
use crate::runtime::csr_backend::{run_astgcn_csr, run_layer_csr_with,
                                  CsrPartition};
use crate::runtime::weights::WeightBundle;

use super::KernelScratch;

/// One unit of per-fog work. `state` moves in and the output moves back
/// through the result channel — no shared mutable state.
pub enum FogJob {
    /// One gcn|gat|sage message-passing layer over a block-diagonal
    /// batch (`state` is [batch * n, dim] block-major).
    Layer {
        layer: usize,
        dim: usize,
        last: bool,
        batch: usize,
        state: Vec<f32>,
        weights: Arc<WeightBundle>,
    },
    /// The ASTGCN block, executed once per batch block (`state` is
    /// [batch * n, ft] block-major; output stacks [n, t_out] blocks).
    Astgcn {
        ft: usize,
        batch: usize,
        state: Vec<f32>,
        weights: Arc<WeightBundle>,
    },
}

impl FogJob {
    /// Execute on the calling thread. Pool workers and the serial
    /// oracle (`BatchedBspPlan::execute_serial`) share this code path,
    /// so pooled and unpooled runs are bit-identical. Returns the
    /// output activations and the measured kernel seconds.
    pub fn run(self, model: &str, csr: Option<&CsrPartition>,
               sub: &LocalGraph, scratch: &mut KernelScratch)
               -> (Vec<f32>, f64) {
        match self {
            FogJob::Layer { layer, dim, last, batch, state, weights } => {
                let csr = csr.expect("CSR built at plan construction");
                let t = Instant::now();
                let out = run_layer_csr_with(model, layer, &weights,
                                             &state, dim, csr, last,
                                             batch, scratch)
                    .expect("model validated at plan construction");
                (out, t.elapsed().as_secs_f64())
            }
            FogJob::Astgcn { ft, batch, state, weights } => {
                let n = sub.n_total();
                let t = Instant::now();
                let mut out = Vec::new();
                for bk in 0..batch {
                    let block = run_astgcn_csr(
                        &weights,
                        &state[bk * n * ft..(bk + 1) * n * ft],
                        n,
                        ft,
                        sub,
                    );
                    if bk == 0 {
                        out.reserve_exact(block.len() * batch);
                    }
                    out.extend_from_slice(&block);
                }
                (out, t.elapsed().as_secs_f64())
            }
        }
    }
}

struct Reply {
    fog: usize,
    out: Vec<f32>,
    seconds: f64,
    /// The worker's job panicked; `dispatch` re-raises on the caller's
    /// thread (the pool equivalent of `thread::scope`'s join-propagate).
    panicked: bool,
}

/// The persistent pool: `senders[j]` feeds fog j's worker; `results`
/// collects replies from all workers.
pub struct FogWorkerPool {
    senders: Vec<Sender<FogJob>>,
    results: Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
    /// Set when a worker panic was re-raised: the results channel may
    /// still hold that round's other replies, so further dispatches
    /// would mis-attribute them. A poisoned pool refuses to dispatch.
    poisoned: Cell<bool>,
}

impl FogWorkerPool {
    /// Spawn one worker per fog. `fogs[j]` carries the structures the
    /// worker computes over (the CSR is `None` for astgcn, which works
    /// on the local graph directly).
    pub fn new(
        model: &str,
        fogs: Vec<(Arc<LocalGraph>, Option<Arc<CsrPartition>>)>,
    ) -> FogWorkerPool {
        let (res_tx, res_rx) = channel::<Reply>();
        let mut senders = Vec::with_capacity(fogs.len());
        let mut handles = Vec::with_capacity(fogs.len());
        for (j, (sub, csr)) in fogs.into_iter().enumerate() {
            let (tx, rx) = channel::<FogJob>();
            senders.push(tx);
            let results = res_tx.clone();
            let model = model.to_string();
            let handle = std::thread::Builder::new()
                .name(format!("fog-worker-{j}"))
                .spawn(move || {
                    worker_loop(j, &model, sub, csr, rx, results)
                })
                .expect("spawn fog worker");
            handles.push(handle);
        }
        FogWorkerPool {
            senders,
            results: res_rx,
            handles,
            poisoned: Cell::new(false),
        }
    }

    pub fn len(&self) -> usize {
        self.senders.len()
    }

    pub fn is_empty(&self) -> bool {
        self.senders.is_empty()
    }

    /// Hand one job per fog to the workers (`None` = no work, e.g. a
    /// fog owning no vertices) and wait at the BSP barrier for every
    /// reply. Returns per-fog outputs and measured kernel seconds
    /// (empty/0.0 for `None` slots).
    pub fn dispatch(&self, jobs: Vec<Option<FogJob>>)
                    -> (Vec<Vec<f32>>, Vec<f64>) {
        assert_eq!(jobs.len(), self.senders.len());
        assert!(
            !self.poisoned.get(),
            "fog worker pool poisoned by an earlier worker panic; \
             rebuild the plan"
        );
        let mut outs: Vec<Vec<f32>> =
            (0..jobs.len()).map(|_| Vec::new()).collect();
        let mut secs = vec![0f64; jobs.len()];
        let mut pending = 0usize;
        for (j, job) in jobs.into_iter().enumerate() {
            if let Some(job) = job {
                self.senders[j]
                    .send(job)
                    .expect("fog worker alive while pool exists");
                pending += 1;
            }
        }
        for _ in 0..pending {
            // recv fails only if every worker died; individual worker
            // panics arrive as `panicked` replies and re-raise here
            let r = self.results.recv().expect("fog worker reply");
            if r.panicked {
                self.poisoned.set(true);
                panic!("fog worker {} panicked during kernel \
                        execution",
                       r.fog);
            }
            secs[r.fog] = r.seconds;
            outs[r.fog] = r.out;
        }
        (outs, secs)
    }
}

impl Drop for FogWorkerPool {
    fn drop(&mut self) {
        // closing the job channels ends the worker loops
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    fog: usize,
    model: &str,
    sub: Arc<LocalGraph>,
    csr: Option<Arc<CsrPartition>>,
    jobs: Receiver<FogJob>,
    results: Sender<Reply>,
) {
    let mut scratch = KernelScratch::default();
    while let Ok(job) = jobs.recv() {
        // a panicking job must not leave dispatch() counting a reply
        // that never comes (the other workers keep the channel open):
        // catch it, report it, and retire this worker
        let ran = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                job.run(model, csr.as_deref(), &sub, &mut scratch)
            }),
        );
        match ran {
            Ok((out, seconds)) => {
                let reply =
                    Reply { fog, out, seconds, panicked: false };
                if results.send(reply).is_err() {
                    break; // pool dropped mid-flight
                }
            }
            Err(_) => {
                let _ = results.send(Reply {
                    fog,
                    out: Vec::new(),
                    seconds: 0.0,
                    panicked: true,
                });
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, subgraph};
    use crate::runtime::csr_backend::run_layer_csr;
    use crate::runtime::pad;
    use crate::runtime::{Engine, EngineKind};

    #[test]
    fn pooled_layer_matches_inline_execution() {
        let (mut g, _) = generate::sbm(120, 500, 3, 0.85, 19);
        let f_in = 6;
        let mut rng = crate::util::rng::Rng::new(20);
        g.features =
            (0..120 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = f_in;
        let assignment: Vec<u32> =
            (0..120).map(|v| (v % 2) as u32).collect();
        let (subs, _) = subgraph::extract(&g, &assignment, 2);
        let dir = std::env::temp_dir().join("pool_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let wb = Arc::new(eng.weights("gcn", "tiny", f_in, 3).clone());
        let csrs: Vec<Arc<CsrPartition>> = subs
            .iter()
            .map(|s| {
                Arc::new(CsrPartition::from_edges(
                    &pad::prep_edges("gcn", s).unwrap(),
                ))
            })
            .collect();
        let states: Vec<Vec<f32>> = subs
            .iter()
            .map(|s| {
                (0..s.n_total() * f_in)
                    .map(|_| rng.normal_f32(0.0, 1.0))
                    .collect()
            })
            .collect();
        let fogs: Vec<(Arc<LocalGraph>, Option<Arc<CsrPartition>>)> =
            subs.iter()
                .cloned()
                .map(Arc::new)
                .zip(csrs.iter().cloned().map(Some))
                .collect();
        let pool = FogWorkerPool::new("gcn", fogs);
        assert_eq!(pool.len(), 2);
        let jobs: Vec<Option<FogJob>> = states
            .iter()
            .map(|st| {
                Some(FogJob::Layer {
                    layer: 0,
                    dim: f_in,
                    last: false,
                    batch: 1,
                    state: st.clone(),
                    weights: wb.clone(),
                })
            })
            .collect();
        let (outs, secs) = pool.dispatch(jobs);
        for j in 0..2 {
            let inline = run_layer_csr("gcn", 0, &wb, &states[j], f_in,
                                       &csrs[j], false, 1)
                .unwrap();
            assert_eq!(outs[j], inline, "fog {j} pooled != inline");
            assert!(secs[j] >= 0.0);
        }
    }

    #[test]
    fn none_jobs_are_skipped() {
        let g = crate::graph::Graph::from_undirected_edges(2, &[(0, 1)]);
        let sub = subgraph::extract_one(&g, &[0, 1]);
        let csr = Arc::new(CsrPartition::from_edges(
            &pad::prep_edges("gcn", &sub).unwrap(),
        ));
        let pool = FogWorkerPool::new(
            "gcn",
            vec![(Arc::new(sub), Some(csr))],
        );
        let (outs, secs) = pool.dispatch(vec![None]);
        assert!(outs[0].is_empty());
        assert_eq!(secs[0], 0.0);
    }
}
