//! Persistent fog-aware sharded worker pool: one long-lived leader
//! thread per fog with channel handoff (replacing the per-micro-batch
//! `std::thread::scope` spawns the measured serving path used before),
//! plus a per-fog `ShardGroup` of helper threads sized at pool
//! construction (`group_widths`), so one large partition runs
//! row-parallel inside its fog instead of serial while other cores
//! idle. Spawning costs tens of microseconds per thread per batch —
//! comparable to a small bucket's entire kernel time — so with the
//! pool, measured per-bucket timings reflect kernel cost, not thread
//! start-up.
//!
//! Workers are *structure-free*: every `FogJob` carries `Arc` handles
//! to the partition structures it computes over (its `LocalGraph`
//! view, the CSR for message-passing models, the ASTGCN in-neighbor
//! lists) plus the model name and weights. That decoupling is what
//! lets one pool serve many `BatchedBspPlan`s at once — the
//! multi-tenant serving fabric keeps a single `--kernel-threads`
//! budget of threads alive while its plan cache holds one plan per
//! distinct `(model, dataset)` — and it also means a mid-run replan
//! rebuilds plan structures without respawning a single thread.
//!
//! A `FogJob` whose row count clears the active shard floor
//! (`shard::min_rows_per_shard`) per worker is split into
//! deterministic contiguous row ranges with a fixed-order reduction,
//! so pooled, sharded and `BatchedBspPlan::execute_serial` outputs are
//! bit-identical. The BSP barrier is the result collection in
//! `dispatch`: one reply per dispatched job.
//!
//! Timing: each reply separates `seconds` (pure kernel wall-clock,
//! measured inside the leader from first touch to completion — shard
//! parallelism is visible here) from `queue_wait_s` (send-to-dequeue
//! latency on the job channel), so the per-bucket timings fed to
//! `OnlineProfiler` reflect kernel cost, not queueing.
//!
//! Observability: a job may carry a [`JobTrace`] — the flight
//! recorder's per-worker ring for this fog plus identity tags. The
//! worker then records wall-clock `queue` and `kernel` spans around
//! the existing measurements (generalizing the queue-wait/kernel
//! split the replies always carried) with a lock-free ring push; an
//! untraced job pays exactly one `Option` check.

use std::cell::Cell;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::graph::LocalGraph;
use crate::obs::recorder::{Recorder, Ring};
use crate::obs::span::{Phase, SpanEvent};
use crate::runtime::csr_backend::{run_astgcn_csr_cached,
                                  run_astgcn_csr_sharded,
                                  run_layer_csr_sharded,
                                  run_layer_csr_with, CsrPartition,
                                  InNbrLists};
use crate::runtime::weights::WeightBundle;

use super::shard::{ShardExec, ShardGroup};
use super::KernelScratch;

/// Default wall-clock deadline for one fog task. Generous on purpose:
/// the barrier path treats a miss as a fatal hang (poison + panic),
/// so only a genuinely wedged worker should ever trip it. Chaos runs
/// lower it per-pipeline to make injected crashes detectable fast.
pub const DEFAULT_TASK_DEADLINE_S: f64 = 30.0;

/// A worker-side fault injected into one job by the chaos plane. The
/// measured executor stamps these from the run's `ChaosPlan`, so
/// faults act where real ones would — inside the worker, after the
/// coordinator has already committed the dispatch.
#[derive(Clone, Copy, Debug)]
pub enum Inject {
    /// Crashed fog: the worker withholds the reply forever. The
    /// coordinator sees a task that never completes — exactly the
    /// signature of a dead node.
    DropReply,
    /// Straggler at `speed`× (in (0, 1)): the kernel result stands,
    /// but the reply reports `1/speed`× the measured kernel time and
    /// the worker wall-waits (capped) so hedging actually races it.
    Slow { speed: f64 },
}

/// Which kernel a `FogJob` runs.
#[derive(Clone, Copy, Debug)]
pub enum FogKernel {
    /// One gcn|gat|sage message-passing layer over a block-diagonal
    /// batch (`state` is [batch * n, dim] block-major).
    Layer { layer: usize, dim: usize, last: bool },
    /// The ASTGCN block, executed once per batch block (`state` is
    /// [batch * n, ft] block-major; output stacks [n, t_out] blocks).
    Astgcn { ft: usize },
}

/// Flight-recorder context a traced job carries to its worker: the
/// ring is dedicated to this (plan, fog) pair and fog j's jobs only
/// ever reach worker j, so the ring keeps its single-producer
/// contract by construction.
pub struct JobTrace {
    pub rec: Arc<Recorder>,
    pub ring: Arc<Ring>,
    pub tenant: u32,
    pub layer: i32,
}

/// One unit of per-fog work, self-contained: the kernel selector plus
/// `Arc` handles to everything it computes over. `state` moves in and
/// the output moves back through the result channel — no shared
/// mutable state, and no per-worker structure ownership, so any worker
/// (of any plan sharing the pool) can run any fog's job.
pub struct FogJob {
    pub kernel: FogKernel,
    /// Model name ("gcn" | "sage" | "gat" | "astgcn"), shared not
    /// cloned: one job is built per fog per layer per micro-batch.
    pub model: Arc<str>,
    pub batch: usize,
    pub state: Vec<f32>,
    pub weights: Arc<WeightBundle>,
    /// Partition view (row counts; the astgcn path reads n_total).
    pub sub: Arc<LocalGraph>,
    /// CSR for the message-passing models; `None` for astgcn.
    pub csr: Option<Arc<CsrPartition>>,
    /// In-neighbor lists for astgcn; `None` otherwise.
    pub nbr: Option<Arc<InNbrLists>>,
    /// Flight-recorder context; `None` = untraced (the default).
    pub trace: Option<JobTrace>,
    /// Private reply channel for pipelined submission: `Some` routes
    /// this job's reply there instead of the pool's shared results
    /// channel, so concurrent pipelines (one per plan sharing the
    /// pool) never interleave replies with each other or with a
    /// barrier `dispatch`. `None` = classic barrier dispatch.
    pub reply_to: Option<Sender<Reply>>,
    /// Coordinator-assigned task identity, echoed back on the reply.
    /// `0` = untagged (barrier dispatch and the fault-free pipeline,
    /// which map replies by per-fog FIFO order instead). Hedged
    /// re-dispatch needs explicit identity because the same logical
    /// task may race on two workers and only the first reply counts.
    pub task: u64,
    /// Chaos fault to apply inside the worker; `None` = healthy.
    pub inject: Option<Inject>,
}

impl FogJob {
    /// Execute on the calling thread (row-sharding onto `shards` when
    /// the job is large enough). Pool leaders and the serial oracle
    /// (`BatchedBspPlan::execute_serial`) share this code path with
    /// matching shard widths, and every row kernel is
    /// row-decomposition invariant, so pooled and unpooled runs are
    /// bit-identical. Returns the output activations and the measured
    /// kernel seconds.
    pub fn run(self, scratch: &mut KernelScratch,
               shards: &ShardExec<'_>) -> (Vec<f32>, f64) {
        let FogJob { kernel, model, batch, state, weights, sub, csr,
                     nbr, .. } = self;
        match kernel {
            FogKernel::Layer { layer, dim, last } => {
                let csr =
                    csr.expect("CSR built at plan construction");
                let t = Instant::now();
                let out = if shards
                    .effective_shards(batch * csr.n_local)
                    > 1
                {
                    run_layer_csr_sharded(&model, layer, &weights,
                                          &Arc::new(state), dim, &csr,
                                          last, batch, shards)
                        .expect("model validated at plan construction")
                } else {
                    run_layer_csr_with(&model, layer, &weights, &state,
                                       dim, &csr, last, batch, scratch)
                        .expect("model validated at plan construction")
                };
                (out, t.elapsed().as_secs_f64())
            }
            FogKernel::Astgcn { ft } => {
                let n = sub.n_total();
                let nbr = nbr
                    .expect("in-neighbor lists built at plan \
                             construction");
                let t = Instant::now();
                if shards.effective_shards(n) > 1 {
                    let out = run_astgcn_csr_sharded(
                        &weights,
                        &Arc::new(state),
                        n,
                        ft,
                        &nbr,
                        batch,
                        shards,
                    );
                    return (out, t.elapsed().as_secs_f64());
                }
                let mut out = Vec::new();
                for bk in 0..batch {
                    let block = run_astgcn_csr_cached(
                        &weights,
                        &state[bk * n * ft..(bk + 1) * n * ft],
                        n,
                        ft,
                        &nbr,
                    );
                    if bk == 0 {
                        out.reserve_exact(block.len() * batch);
                    }
                    out.extend_from_slice(&block);
                }
                (out, t.elapsed().as_secs_f64())
            }
        }
    }
}

/// One worker reply. A fog's replies arrive on its channel in job
/// submission order (workers are per-fog FIFO), which is what lets a
/// pipelined coordinator map replies back to (batch, layer) with a
/// per-fog tag queue instead of a wire-format identity.
pub struct Reply {
    pub fog: usize,
    /// Echo of `FogJob::task` (0 = untagged). Note `fog` is the index
    /// of the *worker* that ran the job — for a hedged task that is
    /// not the logical fog, which is why tagged replies are mapped by
    /// `task`, never by `fog`.
    pub task: u64,
    pub out: Vec<f32>,
    /// Pure kernel wall-clock (shard parallelism included).
    pub seconds: f64,
    /// Send-to-dequeue latency on the job channel — reported apart
    /// from `seconds` so profiler observations stay queueing-free.
    pub queue_wait_s: f64,
    /// The worker's job panicked; `dispatch` re-raises on the caller's
    /// thread (the pool equivalent of `thread::scope`'s join-propagate).
    pub panicked: bool,
}

/// Per-fog worker-group widths from partition volume: the largest
/// partition gets `kernel_threads` workers and the others
/// proportionally fewer (always at least one), so cores go where the
/// rows are after heterogeneity-aware placement skews the partition
/// sizes.
///
/// Note on the simulation model: widths are deliberately NOT a shared
/// host budget — each fog simulates a separate physical machine, so
/// `kernel_threads` models PER-NODE parallelism and the pool may run
/// up to `Σ widths` threads on this host (exactly as the pre-sharding
/// pool ran `n_fogs` concurrent workers). When measuring on a small
/// host, size `--kernel-threads` with `cores / n_fogs` in mind or the
/// per-fog timings include host contention the real cluster would not
/// see.
pub fn group_widths(volumes: &[usize], kernel_threads: usize)
                    -> Vec<usize> {
    let kt = kernel_threads.max(1);
    let mx = volumes.iter().copied().max().unwrap_or(0);
    volumes
        .iter()
        .map(|&v| {
            if mx == 0 || v == 0 {
                1
            } else {
                ((kt * v).div_ceil(mx)).clamp(1, kt)
            }
        })
        .collect()
}

/// The persistent pool: `senders[j]` feeds fog j's leader worker;
/// `results` collects replies from all workers. Plans hold it behind
/// an `Arc`, so many plans (the fabric's plan cache) share one set of
/// threads; it dies with the last plan.
pub struct FogWorkerPool {
    senders: Vec<Sender<(Instant, FogJob)>>,
    results: Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
    widths: Vec<usize>,
    /// Set when a worker panic was re-raised: the results channel may
    /// still hold that round's other replies, so further dispatches
    /// would mis-attribute them. A poisoned pool refuses to dispatch.
    poisoned: Cell<bool>,
    /// Wall-clock deadline for one task at the `dispatch` barrier: a
    /// fog that never replies surfaces as a poisoned pool instead of
    /// a wedged run.
    task_deadline_s: Cell<f64>,
}

impl FogWorkerPool {
    /// One single-threaded worker per fog (no intra-fog sharding).
    pub fn new(n_fogs: usize) -> FogWorkerPool {
        FogWorkerPool::with_widths(vec![1; n_fogs])
    }

    /// Spawn one leader worker per fog, fog j's leading a shard helper
    /// group of `widths[j] - 1` threads (see `group_widths` for the
    /// volume-proportional sizing plans use).
    pub fn with_widths(widths: Vec<usize>) -> FogWorkerPool {
        let (res_tx, res_rx) = channel::<Reply>();
        let mut senders = Vec::with_capacity(widths.len());
        let mut handles = Vec::with_capacity(widths.len());
        for (j, &width) in widths.iter().enumerate() {
            let (tx, rx) = channel::<(Instant, FogJob)>();
            senders.push(tx);
            let results = res_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("fog-worker-{j}"))
                .spawn(move || worker_loop(j, width.max(1), rx, results))
                .expect("spawn fog worker");
            handles.push(handle);
        }
        FogWorkerPool {
            senders,
            results: res_rx,
            handles,
            widths,
            poisoned: Cell::new(false),
            task_deadline_s: Cell::new(DEFAULT_TASK_DEADLINE_S),
        }
    }

    /// Wall-clock deadline for one task at the barrier (and the
    /// default a `BspPipeline` on this pool starts from).
    pub fn task_deadline_s(&self) -> f64 {
        self.task_deadline_s.get()
    }

    /// Set the per-task deadline (seconds; must be positive finite).
    pub fn set_task_deadline(&self, s: f64) {
        assert!(s.is_finite() && s > 0.0, "task deadline must be > 0");
        self.task_deadline_s.set(s);
    }

    pub fn len(&self) -> usize {
        self.senders.len()
    }

    pub fn is_empty(&self) -> bool {
        self.senders.is_empty()
    }

    /// Per-fog worker-group widths (leader + shard helpers).
    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    /// A worker panic was re-raised from `dispatch`: the pool refuses
    /// further work. Callers that would otherwise share this handle
    /// into a new plan (`BatchedBspPlan::with_shared_pool`,
    /// `MeasuredExec::rebuild`) must check this and spawn a fresh pool
    /// instead — "rebuild the plan" is the documented recovery path.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.get()
    }

    /// Hand one job per fog to the workers (`None` = no work, e.g. a
    /// fog owning no vertices) and wait at the BSP barrier for every
    /// reply. Returns per-fog outputs, measured kernel seconds and
    /// job-channel queue waits (empty/0.0 for `None` slots).
    pub fn dispatch(&self, jobs: Vec<Option<FogJob>>)
                    -> (Vec<Vec<f32>>, Vec<f64>, Vec<f64>) {
        assert_eq!(jobs.len(), self.senders.len());
        assert!(
            !self.poisoned.get(),
            "fog worker pool poisoned by an earlier worker panic; \
             rebuild the plan"
        );
        let mut outs: Vec<Vec<f32>> =
            (0..jobs.len()).map(|_| Vec::new()).collect();
        let mut secs = vec![0f64; jobs.len()];
        let mut waits = vec![0f64; jobs.len()];
        let mut pending = 0usize;
        for (j, job) in jobs.into_iter().enumerate() {
            if let Some(job) = job {
                self.senders[j]
                    .send((Instant::now(), job))
                    .expect("fog worker alive while pool exists");
                pending += 1;
            }
        }
        let deadline =
            Duration::from_secs_f64(self.task_deadline_s.get());
        for _ in 0..pending {
            // individual worker panics arrive as `panicked` replies
            // and re-raise here; a task that never replies at all (a
            // hung or chaos-crashed fog) trips the deadline instead of
            // wedging the barrier forever
            let r = match self.results.recv_timeout(deadline) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => {
                    self.poisoned.set(true);
                    panic!(
                        "fog task exceeded the {:.3}s deadline at the \
                         BSP barrier; pool poisoned — rebuild the plan",
                        self.task_deadline_s.get()
                    );
                }
                Err(RecvTimeoutError::Disconnected) => {
                    self.poisoned.set(true);
                    panic!("all fog workers died before replying");
                }
            };
            if r.panicked {
                self.poisoned.set(true);
                panic!("fog worker {} panicked during kernel \
                        execution",
                       r.fog);
            }
            secs[r.fog] = r.seconds;
            waits[r.fog] = r.queue_wait_s;
            outs[r.fog] = r.out;
        }
        (outs, secs, waits)
    }

    /// Asynchronous submission for the pipelined executor: hand fog
    /// `j` one job *without* waiting at a barrier. The job must carry
    /// a `reply_to` channel (enforced) — the caller owns reply
    /// collection and ordering, the pool only guarantees per-fog FIFO
    /// processing. Sends never block (job channels are unbounded), so
    /// a single-threaded coordinator can keep every fog fed while it
    /// processes earlier replies.
    pub fn submit(&self, fog: usize, job: FogJob) {
        assert!(
            !self.poisoned.get(),
            "fog worker pool poisoned by an earlier worker panic; \
             rebuild the plan"
        );
        assert!(
            job.reply_to.is_some(),
            "pipelined submission requires a reply_to channel"
        );
        self.senders[fog]
            .send((Instant::now(), job))
            .expect("fog worker alive while pool exists");
    }

    /// Mark the pool poisoned (a pipelined caller saw a `panicked`
    /// reply on its private channel and is about to re-raise).
    pub fn poison(&self) {
        self.poisoned.set(true);
    }
}

impl Drop for FogWorkerPool {
    fn drop(&mut self) {
        // closing the job channels ends the worker loops
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    fog: usize,
    width: usize,
    jobs: Receiver<(Instant, FogJob)>,
    results: Sender<Reply>,
) {
    let mut scratch = KernelScratch::default();
    // helper threads only when this fog can actually shard
    let group = if width > 1 {
        Some(ShardGroup::new(width - 1, &format!("fog{fog}")))
    } else {
        None
    };
    while let Ok((sent, mut job)) = jobs.recv() {
        let queue_wait_s = sent.elapsed().as_secs_f64();
        let trace = job.trace.take();
        let reply_to = job.reply_to.take();
        let inject = job.inject.take();
        let task = job.task;
        let batch = job.batch;
        if matches!(inject, Some(Inject::DropReply)) {
            // chaos-crashed fog: swallow the job whole — no kernel
            // run, no reply — so the coordinator sees the exact
            // signature of a dead node (a task that never completes)
            continue;
        }
        let exec = match &group {
            Some(g) => ShardExec::Group(g),
            None => ShardExec::Inline(1),
        };
        // a panicking job must not leave dispatch() counting a reply
        // that never comes (the other workers keep the channel open):
        // catch it, report it, and retire this worker
        let ran = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                job.run(&mut scratch, &exec)
            }),
        );
        match ran {
            Ok((out, mut seconds)) => {
                if let Some(Inject::Slow { speed }) = inject {
                    // straggler: the bit-exact result stands, but the
                    // task reports 1/speed× its kernel time and waits
                    // a capped slice of that extra wall time so a
                    // hedged healthy replica can actually win the race
                    let slowed = seconds / speed.clamp(1e-3, 1.0);
                    let wait = (slowed - seconds).min(0.25);
                    if wait > 0.0 {
                        std::thread::sleep(Duration::from_secs_f64(
                            wait,
                        ));
                    }
                    seconds = slowed;
                }
                if let Some(tr) = &trace {
                    // wall-clock spans on this worker's dedicated
                    // ring: kernel just finished, so its start is
                    // now - seconds, preceded by the channel wait
                    let end_us = tr.rec.wall_now_us();
                    let start_us = end_us - seconds * 1e6;
                    let wait_us = queue_wait_s * 1e6;
                    tr.rec.span(
                        &tr.ring,
                        SpanEvent::new(
                            Phase::Queue,
                            tr.tenant,
                            start_us - wait_us,
                            wait_us,
                        )
                        .fog(fog)
                        .on_wall(),
                    );
                    let mut kernel_ev = SpanEvent::new(
                        Phase::Kernel,
                        tr.tenant,
                        start_us,
                        seconds * 1e6,
                    )
                    .fog(fog)
                    .count(batch)
                    .on_wall();
                    kernel_ev.layer = tr.layer;
                    tr.rec.span(&tr.ring, kernel_ev);
                }
                let reply = Reply {
                    fog,
                    task,
                    out,
                    seconds,
                    queue_wait_s,
                    panicked: false,
                };
                match &reply_to {
                    // a dropped pipeline (caller unwound mid-flight)
                    // just discards the reply; the worker lives on
                    Some(tx) => {
                        let _ = tx.send(reply);
                    }
                    None => {
                        if results.send(reply).is_err() {
                            break; // pool dropped mid-flight
                        }
                    }
                }
            }
            Err(_) => {
                let reply = Reply {
                    fog,
                    task,
                    out: Vec::new(),
                    seconds: 0.0,
                    queue_wait_s,
                    panicked: true,
                };
                match &reply_to {
                    Some(tx) => {
                        let _ = tx.send(reply);
                    }
                    None => {
                        let _ = results.send(reply);
                    }
                }
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, subgraph};
    use crate::runtime::csr_backend::run_layer_csr;
    use crate::runtime::pad;
    use crate::runtime::{Engine, EngineKind};

    type FogSetup = (Vec<Arc<LocalGraph>>,
                     Vec<Arc<CsrPartition>>,
                     Arc<WeightBundle>,
                     Vec<Vec<f32>>,
                     usize);

    fn two_fog_setup() -> FogSetup {
        let (mut g, _) = generate::sbm(120, 500, 3, 0.85, 19);
        let f_in = 6;
        let mut rng = crate::util::rng::Rng::new(20);
        g.features =
            (0..120 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = f_in;
        let assignment: Vec<u32> =
            (0..120).map(|v| (v % 2) as u32).collect();
        let (subs, _) = subgraph::extract(&g, &assignment, 2);
        let dir = std::env::temp_dir().join("pool_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let wb = Arc::new(eng.weights("gcn", "tiny", f_in, 3).clone());
        let csrs: Vec<Arc<CsrPartition>> = subs
            .iter()
            .map(|s| {
                Arc::new(CsrPartition::from_edges(
                    &pad::prep_edges("gcn", s).unwrap(),
                ))
            })
            .collect();
        let states: Vec<Vec<f32>> = subs
            .iter()
            .map(|s| {
                (0..s.n_total() * f_in)
                    .map(|_| rng.normal_f32(0.0, 1.0))
                    .collect()
            })
            .collect();
        let subs: Vec<Arc<LocalGraph>> =
            subs.into_iter().map(Arc::new).collect();
        (subs, csrs, wb, states, f_in)
    }

    fn layer_jobs(subs: &[Arc<LocalGraph>],
                  csrs: &[Arc<CsrPartition>], states: &[Vec<f32>],
                  wb: &Arc<WeightBundle>, f_in: usize, batch: usize)
                  -> Vec<Option<FogJob>> {
        let model: Arc<str> = Arc::from("gcn");
        states
            .iter()
            .enumerate()
            .map(|(j, st)| {
                // block-diagonal batch of identical snapshot blocks
                let mut state =
                    Vec::with_capacity(batch * st.len());
                for _ in 0..batch {
                    state.extend_from_slice(st);
                }
                Some(FogJob {
                    kernel: FogKernel::Layer {
                        layer: 0,
                        dim: f_in,
                        last: false,
                    },
                    model: model.clone(),
                    batch,
                    state,
                    weights: wb.clone(),
                    sub: subs[j].clone(),
                    csr: Some(csrs[j].clone()),
                    nbr: None,
                    trace: None,
                    reply_to: None,
                    task: 0,
                    inject: None,
                })
            })
            .collect()
    }

    #[test]
    fn pooled_layer_matches_inline_execution() {
        let (subs, csrs, wb, states, f_in) = two_fog_setup();
        let pool = FogWorkerPool::new(2);
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.widths(), &[1, 1]);
        let (outs, secs, waits) = pool.dispatch(
            layer_jobs(&subs, &csrs, &states, &wb, f_in, 1));
        for j in 0..2 {
            let inline = run_layer_csr("gcn", 0, &wb, &states[j], f_in,
                                       &csrs[j], false, 1)
                .unwrap();
            assert_eq!(outs[j], inline, "fog {j} pooled != inline");
            assert!(secs[j] >= 0.0);
            assert!(waits[j] >= 0.0);
        }
    }

    #[test]
    fn sharded_pool_matches_single_threaded_pool() {
        let (subs, csrs, wb, states, f_in) = two_fog_setup();
        let one = FogWorkerPool::new(2);
        let volumes: Vec<usize> =
            subs.iter().map(|s| s.n_local).collect();
        let four =
            FogWorkerPool::with_widths(group_widths(&volumes, 4));
        assert!(four.widths().iter().all(|&w| (1..=4).contains(&w)));
        // equal partitions: every fog gets the full width
        assert_eq!(four.widths(), &[4, 4]);
        // batch 16 × 60 owned rows clears the shard floor, so the
        // 4-wide pool genuinely shards while the 1-wide pool cannot
        let batch = 16;
        let (o1, _, _) = one.dispatch(
            layer_jobs(&subs, &csrs, &states, &wb, f_in, batch));
        let (o4, _, _) = four.dispatch(
            layer_jobs(&subs, &csrs, &states, &wb, f_in, batch));
        assert_eq!(o1, o4, "sharded pool deviates from 1-thread pool");
    }

    #[test]
    fn one_pool_serves_jobs_from_two_structure_sets() {
        // the multi-tenant sharing contract: a single pool runs jobs
        // carrying structures from DIFFERENT plans, interleaved, and
        // each job computes over exactly the structures it carries
        let (subs, csrs, wb, states, f_in) = two_fog_setup();
        let (mut g2, _) = generate::sbm(90, 360, 2, 0.8, 23);
        g2.feature_dim = f_in;
        let mut rng = crate::util::rng::Rng::new(33);
        g2.features =
            (0..90 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let assignment2: Vec<u32> =
            (0..90).map(|v| (v % 2) as u32).collect();
        let (subs2, _) = subgraph::extract(&g2, &assignment2, 2);
        let csrs2: Vec<Arc<CsrPartition>> = subs2
            .iter()
            .map(|s| {
                Arc::new(CsrPartition::from_edges(
                    &pad::prep_edges("gcn", s).unwrap(),
                ))
            })
            .collect();
        let states2: Vec<Vec<f32>> = subs2
            .iter()
            .map(|s| {
                (0..s.n_total() * f_in)
                    .map(|_| rng.normal_f32(0.0, 1.0))
                    .collect()
            })
            .collect();
        let subs2: Vec<Arc<LocalGraph>> =
            subs2.into_iter().map(Arc::new).collect();
        let pool = FogWorkerPool::new(2);
        let (oa, _, _) = pool.dispatch(
            layer_jobs(&subs, &csrs, &states, &wb, f_in, 1));
        let (ob, _, _) = pool.dispatch(
            layer_jobs(&subs2, &csrs2, &states2, &wb, f_in, 1));
        let (oa2, _, _) = pool.dispatch(
            layer_jobs(&subs, &csrs, &states, &wb, f_in, 1));
        for j in 0..2 {
            let ia = run_layer_csr("gcn", 0, &wb, &states[j], f_in,
                                   &csrs[j], false, 1)
                .unwrap();
            let ib = run_layer_csr("gcn", 0, &wb, &states2[j], f_in,
                                   &csrs2[j], false, 1)
                .unwrap();
            assert_eq!(oa[j], ia, "plan A fog {j}");
            assert_eq!(ob[j], ib, "plan B fog {j}");
            assert_eq!(oa2[j], ia, "plan A fog {j} after interleave");
        }
    }

    #[test]
    fn group_widths_scale_with_volume() {
        assert_eq!(group_widths(&[100, 100], 4), vec![4, 4]);
        assert_eq!(group_widths(&[400, 100, 0], 4), vec![4, 1, 1]);
        assert_eq!(group_widths(&[300, 150], 4), vec![4, 2]);
        assert_eq!(group_widths(&[10, 20], 1), vec![1, 1]);
        assert_eq!(group_widths(&[], 4), Vec::<usize>::new());
    }

    #[test]
    fn traced_jobs_record_wall_spans() {
        use crate::obs::clock::ClockMode;
        let (subs, csrs, wb, states, f_in) = two_fog_setup();
        let pool = FogWorkerPool::new(2);
        let rec = Recorder::with_capacity(ClockMode::Wall, 64);
        let rings: Vec<Arc<Ring>> =
            (0..2).map(|_| rec.ring()).collect();
        let mut jobs = layer_jobs(&subs, &csrs, &states, &wb, f_in, 2);
        for (j, job) in jobs.iter_mut().enumerate() {
            job.as_mut().unwrap().trace = Some(JobTrace {
                rec: Arc::clone(&rec),
                ring: Arc::clone(&rings[j]),
                tenant: 0,
                layer: 0,
            });
        }
        let (outs, _, _) = pool.dispatch(jobs);
        assert!(!outs[0].is_empty());
        // the reply barrier orders worker pushes before this read
        let evs = rec.events();
        let kernels: Vec<_> =
            evs.iter().filter(|e| e.phase == Phase::Kernel).collect();
        assert_eq!(kernels.len(), 2);
        assert!(kernels.iter().all(|e| e.wall && e.dur_us >= 0.0));
        assert!(kernels.iter().any(|e| e.fog == 0));
        assert!(kernels.iter().any(|e| e.fog == 1));
        assert_eq!(
            evs.iter().filter(|e| e.phase == Phase::Queue).count(),
            2
        );
        // traced and untraced dispatch compute identical outputs
        let (plain, _, _) = pool.dispatch(
            layer_jobs(&subs, &csrs, &states, &wb, f_in, 2));
        assert_eq!(outs, plain);
    }

    #[test]
    fn none_jobs_are_skipped() {
        let pool = FogWorkerPool::new(1);
        let (outs, secs, waits) = pool.dispatch(vec![None]);
        assert!(outs[0].is_empty());
        assert_eq!(secs[0], 0.0);
        assert_eq!(waits[0], 0.0);
    }

    #[test]
    fn slow_inject_keeps_outputs_and_inflates_seconds() {
        let (subs, csrs, wb, states, f_in) = two_fog_setup();
        let pool = FogWorkerPool::new(2);
        let (base, base_secs, _) = pool.dispatch(
            layer_jobs(&subs, &csrs, &states, &wb, f_in, 1));
        let mut jobs = layer_jobs(&subs, &csrs, &states, &wb, f_in, 1);
        jobs[1].as_mut().unwrap().inject =
            Some(Inject::Slow { speed: 0.25 });
        let (slow, slow_secs, _) = pool.dispatch(jobs);
        // the straggler's result is bit-identical — only time changes
        assert_eq!(base, slow);
        assert!(base_secs[1] >= 0.0);
        assert!(
            slow_secs[1] >= base_secs[1],
            "slowed task reports at least its healthy kernel time"
        );
    }

    #[test]
    fn drop_reply_inject_withholds_the_reply() {
        // distinguish "reply withheld" from "reply lost" via task
        // tags on a private channel: the healthy task's reply arrives,
        // the crashed task's never does
        let (subs, csrs, wb, states, f_in) = two_fog_setup();
        let pool = FogWorkerPool::new(2);
        let (tx, rx) = channel::<Reply>();
        let mut jobs: Vec<FogJob> =
            layer_jobs(&subs, &csrs, &states, &wb, f_in, 1)
                .into_iter()
                .map(|j| j.unwrap())
                .collect();
        for (i, j) in jobs.iter_mut().enumerate() {
            j.reply_to = Some(tx.clone());
            j.task = i as u64 + 1;
        }
        jobs[0].inject = Some(Inject::DropReply);
        let mut it = jobs.into_iter();
        pool.submit(0, it.next().unwrap());
        pool.submit(1, it.next().unwrap());
        let r = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("healthy fog replies");
        assert_eq!(r.task, 2, "only the healthy task replies");
        assert!(
            rx.recv_timeout(Duration::from_millis(200)).is_err(),
            "crashed fog's reply is withheld forever"
        );
    }

    #[test]
    fn dispatch_deadline_surfaces_a_dead_fog() {
        let (subs, csrs, wb, states, f_in) = two_fog_setup();
        let pool = FogWorkerPool::new(2);
        pool.set_task_deadline(0.2);
        assert_eq!(pool.task_deadline_s(), 0.2);
        let mut jobs = layer_jobs(&subs, &csrs, &states, &wb, f_in, 1);
        jobs[0].as_mut().unwrap().inject = Some(Inject::DropReply);
        let hung = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| pool.dispatch(jobs)),
        );
        assert!(hung.is_err(), "barrier must not wedge on a dead fog");
        assert!(pool.is_poisoned());
    }
}
