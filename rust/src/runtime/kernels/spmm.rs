//! Edge-unrolled CSR SpMM — the aggregation kernel (Wu et al.'s
//! characterization: aggregation is memory-bandwidth-bound, so the win
//! is locality and fewer out-row round-trips, not FLOPs).
//!
//! Structure: each owned row streams its gathered source rows at full
//! feature width (sequential reads the hardware prefetcher can run
//! ahead of) and unrolls `EU` edges per pass, so the destination row
//! does one load/store round-trip per `EU` gathered rows instead of
//! per edge — `EU×` less accumulator traffic and an `EU`-deep
//! independent-sum tree that hides gather latency. Unit-weight edge
//! groups (every gcn/gat/sage edge after `prep_edges`) skip the
//! multiply entirely.
//!
//! Design note: the textbook row-blocked + feature-tiled SpMM (sweep
//! the block's edges once per FT-wide feature tile, accumulate in an
//! FT register tile) was measured here too and LOSES badly — tiling
//! turns each gather into a single isolated cache line, which defeats
//! the prefetcher that full-width sequential row reads feed, and
//! re-reads the CSR metadata f/FT times. The shipped edge-unrolled
//! form is the variant that actually wins at serving widths;
//! `repro bench-kernels` records the measured margin in
//! BENCH_kernels.json.
//!
//! Zero-weight (masked) edges never reach these kernels:
//! `CsrPartition::from_edges` drops them at construction, so the hot
//! loop carries no per-edge mask branch. `csr_spmm_naive` preserves
//! the scalar edge-at-a-time loop as the baseline for parity tests and
//! `repro bench-kernels`.
//!
//! Row-decomposition invariance: each destination row reads only its
//! own edge segment, so aggregating rows `[v0, v1)` is bit-identical
//! to the same rows of the full sweep for ANY contiguous split — the
//! property the intra-fog sharded pool relies on
//! (`csr_spmm_rows_into` is the row-range entry point).
//!
//! Design note (SIMD): an AVX2+FMA SpMM micro-kernel exists
//! (`kernels::simd::x86::csr_spmm_rows_into`, same edge unroll and
//! unit-weight fast path) but is NOT dispatched: measured at the
//! bench shapes it is 0.95–1.01x of this portable kernel — SpMM is
//! DRAM-bandwidth-bound and the SSE2-autovectorized loop already
//! saturates it, so the wider vectors buy nothing (and sometimes lose
//! on the gather-heavy small-f shapes). The kernel stays in-tree so
//! `repro bench-kernels` keeps quantifying the margin
//! (`simd_margin` rows) and the parity suite keeps exercising it —
//! re-measure there before flipping the dispatch (the GEMM story is
//! different: see `gemm.rs`).

use crate::runtime::csr_backend::CsrPartition;

/// Edges unrolled per destination-row pass.
pub const EU: usize = 4;

/// Scalar edge-at-a-time SpMM — the naive baseline (formerly
/// `csr_backend::csr_aggregate`).
pub fn csr_spmm_naive(csr: &CsrPartition, h: &[f32], f: usize)
                      -> Vec<f32> {
    let l = csr.n_local;
    let mut agg = vec![0f32; l * f];
    for v in 0..l {
        let row = &mut agg[v * f..(v + 1) * f];
        for e in csr.row_ptr[v]..csr.row_ptr[v + 1] {
            let w = csr.val[e];
            if w == 0.0 {
                continue;
            }
            let u = csr.col[e] as usize;
            let hu = &h[u * f..(u + 1) * f];
            if w == 1.0 {
                for (a, &x) in row.iter_mut().zip(hu) {
                    *a += x;
                }
            } else {
                for (a, &x) in row.iter_mut().zip(hu) {
                    *a += w * x;
                }
            }
        }
    }
    agg
}

/// Edge-unrolled SpMM into a fresh vector:
/// `agg[v] = Σ_{(u,v)} w · h[u]` over owned rows v.
pub fn csr_spmm(csr: &CsrPartition, h: &[f32], f: usize) -> Vec<f32> {
    let mut agg = vec![0f32; csr.n_local * f];
    csr_spmm_into(csr, h, f, &mut agg);
    agg
}

/// Edge-unrolled SpMM into a caller-owned buffer (`out` is fully
/// overwritten) — the scratch-reuse entry point for the per-layer hot
/// path.
pub fn csr_spmm_into(csr: &CsrPartition, h: &[f32], f: usize,
                     out: &mut [f32]) {
    csr_spmm_rows_into(csr, h, f, 0, csr.n_local, out);
}

/// Owned rows `[v0, v1)` of the aggregate, written into `out`
/// (`(v1 - v0) * f`, fully overwritten) — the row-range view the
/// sharded pool executes. Bit-identical to the same rows of the full
/// sweep (row-decomposition invariance, see module docs). Stays on
/// the portable kernel on every host: the AVX2 variant measured even
/// (see the SIMD design note above).
pub fn csr_spmm_rows_into(csr: &CsrPartition, h: &[f32], f: usize,
                          v0: usize, v1: usize, out: &mut [f32]) {
    csr_spmm_rows_into_scalar(csr, h, f, v0, v1, out);
}

/// Row-sharded aggregate into a fresh vector (`csr_spmm_rows_into`
/// convenience wrapper).
pub fn csr_spmm_rows(csr: &CsrPartition, h: &[f32], f: usize,
                     v0: usize, v1: usize) -> Vec<f32> {
    let mut out = vec![0f32; (v1 - v0) * f];
    csr_spmm_rows_into(csr, h, f, v0, v1, &mut out);
    out
}

/// The portable edge-unrolled kernel (tuned for baseline SSE2
/// codegen) — public so parity tests and `repro bench-kernels` can
/// measure the SIMD path against it regardless of what the dispatcher
/// picked.
pub fn csr_spmm_rows_into_scalar(csr: &CsrPartition, h: &[f32],
                                 f: usize, v0: usize, v1: usize,
                                 out: &mut [f32]) {
    assert!(v0 <= v1 && v1 <= csr.n_local);
    assert_eq!(out.len(), (v1 - v0) * f);
    debug_assert!(h.len() >= csr.n * f);
    for v in v0..v1 {
        let row = &mut out[(v - v0) * f..(v - v0 + 1) * f];
        row.fill(0.0);
        let hi = csr.row_ptr[v + 1];
        let mut e = csr.row_ptr[v];
        while e + EU <= hi {
            let u0 = csr.col[e] as usize;
            let u1 = csr.col[e + 1] as usize;
            let u2 = csr.col[e + 2] as usize;
            let u3 = csr.col[e + 3] as usize;
            let (w0, w1, w2, w3) = (csr.val[e], csr.val[e + 1],
                                    csr.val[e + 2], csr.val[e + 3]);
            let h0 = &h[u0 * f..(u0 + 1) * f];
            let h1 = &h[u1 * f..(u1 + 1) * f];
            let h2 = &h[u2 * f..(u2 + 1) * f];
            let h3 = &h[u3 * f..(u3 + 1) * f];
            let it = row.iter_mut().zip(h0).zip(h1).zip(h2).zip(h3);
            if w0 == 1.0 && w1 == 1.0 && w2 == 1.0 && w3 == 1.0 {
                for ((((a, &x0), &x1), &x2), &x3) in it {
                    *a += (x0 + x1) + (x2 + x3);
                }
            } else {
                for ((((a, &x0), &x1), &x2), &x3) in it {
                    *a += w0 * x0 + w1 * x1 + w2 * x2 + w3 * x3;
                }
            }
            e += EU;
        }
        while e < hi {
            let w = csr.val[e];
            let u = csr.col[e] as usize;
            let hu = &h[u * f..(u + 1) * f];
            if w == 1.0 {
                for (a, &x) in row.iter_mut().zip(hu) {
                    *a += x;
                }
            } else {
                for (a, &x) in row.iter_mut().zip(hu) {
                    *a += w * x;
                }
            }
            e += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pad::EdgeArrays;
    use crate::util::rng::Rng;

    /// Random digraph with some isolated (empty-row) vertices and a mix
    /// of unit / fractional edge weights.
    fn random_csr(n: usize, ne: usize, seed: u64) -> CsrPartition {
        let mut rng = Rng::new(seed);
        let mut src = Vec::with_capacity(ne);
        let mut dst = Vec::with_capacity(ne);
        let mut ew = Vec::with_capacity(ne);
        for _ in 0..ne {
            src.push(rng.usize_below(n) as u32);
            // leave the last quarter of vertices edge-free
            dst.push(rng.usize_below((3 * n / 4).max(1)) as u32);
            ew.push(if rng.bool(0.5) {
                1.0
            } else {
                rng.normal_f32(0.5, 0.2)
            });
        }
        CsrPartition::from_edges(&EdgeArrays {
            src,
            dst,
            ew,
            inv_deg: vec![1.0; n],
            n,
            n_local: n,
        })
    }

    #[test]
    fn unrolled_matches_naive_across_widths() {
        let csr = random_csr(150, 700, 21);
        let mut rng = Rng::new(22);
        for f in [1, 3, 15, 16, 21, 64, 130] {
            let h: Vec<f32> = (0..csr.n * f)
                .map(|_| rng.normal_f32(0.0, 0.5))
                .collect();
            let a = csr_spmm(&csr, &h, f);
            let b = csr_spmm_naive(&csr, &h, f);
            for (x, y) in a.iter().zip(&b) {
                let tol = 1e-5 * (1.0 + x.abs());
                assert!((x - y).abs() <= tol, "f={f}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn empty_rows_stay_zero() {
        let csr = random_csr(80, 200, 23);
        let f = 18;
        let h = vec![1.0f32; csr.n * f];
        let agg = csr_spmm(&csr, &h, f);
        for v in 0..csr.n_local {
            if csr.row_ptr[v] == csr.row_ptr[v + 1] {
                assert!(agg[v * f..(v + 1) * f]
                    .iter()
                    .all(|&x| x == 0.0));
            }
        }
    }

    #[test]
    fn into_variant_overwrites_stale_contents() {
        let csr = random_csr(40, 160, 24);
        let f = 16;
        let mut rng = Rng::new(25);
        let h: Vec<f32> = (0..csr.n * f)
            .map(|_| rng.normal_f32(0.0, 0.5))
            .collect();
        let mut out = vec![777f32; csr.n_local * f];
        csr_spmm_into(&csr, &h, f, &mut out);
        assert_eq!(out, csr_spmm(&csr, &h, f));
    }

    /// THE sharding invariant: any contiguous row split reproduces the
    /// full sweep bit-for-bit (whichever SIMD path is dispatched).
    #[test]
    fn row_splits_are_bitwise_identical() {
        let csr = random_csr(120, 500, 26);
        let mut rng = Rng::new(27);
        for f in [1usize, 7, 16, 33, 64] {
            let h: Vec<f32> = (0..csr.n * f)
                .map(|_| rng.normal_f32(0.0, 0.5))
                .collect();
            let full = csr_spmm(&csr, &h, f);
            let cut = 1 + rng.usize_below(csr.n_local - 1);
            let mut stitched = csr_spmm_rows(&csr, &h, f, 0, cut);
            stitched.extend(csr_spmm_rows(&csr, &h, f, cut,
                                          csr.n_local));
            assert_eq!(full, stitched,
                       "f={f}: split at {cut} deviates");
        }
    }

    /// The in-tree (non-dispatched) AVX2 SpMM kernel must stay within
    /// 1e-5 relative of the portable kernel when the feature is
    /// detected (no-op assertion otherwise).
    #[test]
    fn avx2_kernel_matches_scalar_within_tolerance() {
        let csr = random_csr(90, 400, 28);
        let mut rng = Rng::new(29);
        for f in [5usize, 16, 40] {
            let h: Vec<f32> = (0..csr.n * f)
                .map(|_| rng.normal_f32(0.0, 0.5))
                .collect();
            let mut avx2 = vec![0f32; csr.n_local * f];
            if !crate::runtime::kernels::simd::try_csr_spmm_rows_into(
                &csr, &h, f, 0, csr.n_local, &mut avx2,
            ) {
                return; // feature not detected on this host
            }
            let scalar = csr_spmm(&csr, &h, f);
            for (i, (a, e)) in avx2.iter().zip(&scalar).enumerate() {
                let tol = 1e-5 * (1.0 + a.abs().max(e.abs()));
                assert!((a - e).abs() <= tol,
                        "f={f} elem {i}: {a} vs {e}");
            }
        }
    }
}
