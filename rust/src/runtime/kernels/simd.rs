//! Runtime-dispatched SIMD micro-kernels: `#[target_feature]`-gated
//! AVX2+FMA variants of the GEMM and SpMM inner loops behind the same
//! kernel API, selected once per process with
//! `is_x86_feature_detected!` (the shipped portable kernels are tuned
//! for baseline SSE2 codegen and remain the fallback — and, per the
//! in-tree design notes, the measured-and-rejected MR×NR register
//! tiles were NOT resurrected here; the AVX2 kernels keep the same
//! row-at-a-time structure and win on width + FMA, not on re-tiling).
//!
//! What actually dispatches (all measured, see the design notes in
//! `gemm.rs`/`spmm.rs` and the `simd_margin` rows of
//! BENCH_kernels.json):
//!
//! * GEMM — routes to `x86::gemm_bias_into` when detected
//!   (compute-bound; ~1.3–1.45x over the portable kernel at serving
//!   shapes).
//! * SpMM — stays portable everywhere: the AVX2 variant measured
//!   0.95–1.01x (DRAM-bound; SSE2 autovectorization already saturates
//!   bandwidth). The kernel remains here for the bench and parity
//!   suites to keep the measurement honest over time.
//!
//! Numerics: FMA contracts each multiply-add into one rounding, so the
//! AVX2 path is NOT bit-identical to the scalar path — parity is
//! asserted to 1e-5 relative (`tests/backend_parity.rs`,
//! `repro bench-kernels`). What IS preserved exactly is row-
//! decomposition invariance: both paths compute every output row with
//! an instruction sequence that depends only on that row's inputs, so
//! sharded/pooled/serial runs agree bit-for-bit *within* whichever
//! path the dispatcher picked.
//!
//! `FOGRAPH_SIMD=baseline` forces the portable path (useful for CI
//! determinism checks and for measuring the SIMD margin itself).

use std::sync::OnceLock;

/// The instruction path the one-time dispatcher selected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdPath {
    /// 8-wide f32 with fused multiply-add (`avx2,fma`).
    Avx2Fma,
    /// The portable kernels (LLVM autovectorizes for baseline SSE2).
    Baseline,
}

/// Detect once; every kernel call afterwards is a plain load + branch.
pub fn active() -> SimdPath {
    static PATH: OnceLock<SimdPath> = OnceLock::new();
    *PATH.get_or_init(|| {
        if matches!(
            std::env::var("FOGRAPH_SIMD").as_deref(),
            Ok("baseline") | Ok("scalar") | Ok("sse2")
        ) {
            return SimdPath::Baseline;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2")
                && is_x86_feature_detected!("fma")
            {
                return SimdPath::Avx2Fma;
            }
        }
        SimdPath::Baseline
    })
}

/// True when the dispatcher routes kernels through the AVX2+FMA path.
pub fn avx2_active() -> bool {
    active() == SimdPath::Avx2Fma
}

/// Stable label for artifacts/reports (`BENCH_kernels.json`,
/// loadtest JSON `simd` field).
pub fn name() -> &'static str {
    match active() {
        SimdPath::Avx2Fma => "avx2+fma",
        SimdPath::Baseline => "sse2-baseline",
    }
}

/// Dispatch hook for `gemm::gemm_bias_into`: runs the AVX2+FMA
/// micro-kernel and returns `true` when the probe detected it; `false`
/// means the caller takes the portable path (always, on non-x86_64).
#[allow(clippy::too_many_arguments)]
pub fn try_gemm_bias_into(x: &[f32], n: usize, fi: usize, w: &[f32],
                          fo: usize, b: &[f32], out: &mut [f32])
                          -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_active() {
            // SAFETY: the one-time dispatcher verified avx2+fma
            unsafe {
                x86::gemm_bias_into(x, n, fi, w, fo, b, out);
            }
            return true;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (x, n, fi, w, fo, b, out);
    }
    false
}

/// AVX2 SpMM entry with the `try_gemm_bias_into` contract. NOT used
/// by the production dispatch (the portable SpMM measured as fast or
/// faster — see the `spmm.rs` design note); the bench and parity
/// suites call it to keep quantifying the margin.
pub fn try_csr_spmm_rows_into(csr: &crate::runtime::csr_backend::CsrPartition,
                              h: &[f32], f: usize, v0: usize, v1: usize,
                              out: &mut [f32]) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_active() {
            // SAFETY: the one-time dispatcher verified avx2+fma
            unsafe {
                x86::csr_spmm_rows_into(csr, h, f, v0, v1, out);
            }
            return true;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (csr, h, f, v0, v1, out);
    }
    false
}

#[cfg(target_arch = "x86_64")]
pub mod x86 {
    //! The `target_feature(enable = "avx2,fma")` kernels. Callers must
    //! verify detection first (the dispatchers in `gemm.rs`/`spmm.rs`
    //! do; tests go through `super::avx2_active()`).

    use std::arch::x86_64::*;

    use crate::runtime::csr_backend::CsrPartition;

    /// AVX2+FMA matmul-with-bias over all `n` rows of `x`, writing
    /// `out = x @ w + b`. Row-at-a-time with the same K-unroll depth
    /// (4) and whole-zero K-group skip as the portable kernel, 8-wide
    /// over the output row with a scalar tail.
    ///
    /// # Safety
    /// Requires `avx2` and `fma` at runtime
    /// (`is_x86_feature_detected!`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemm_bias_into(x: &[f32], n: usize, fi: usize,
                                 w: &[f32], fo: usize, b: &[f32],
                                 out: &mut [f32]) {
        debug_assert_eq!(x.len(), n * fi);
        debug_assert_eq!(w.len(), fi * fo);
        debug_assert_eq!(out.len(), n * fo);
        let wide = fo / 8 * 8;
        for r in 0..n {
            let xr = &x[r * fi..(r + 1) * fi];
            let or = &mut out[r * fo..(r + 1) * fo];
            or.copy_from_slice(&b[..fo]);
            let mut k = 0;
            while k + 4 <= fi {
                let (a0, a1, a2, a3) =
                    (xr[k], xr[k + 1], xr[k + 2], xr[k + 3]);
                // one-hot fast path: a whole-zero K group does no work
                if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                    k += 4;
                    continue;
                }
                let w0 = &w[k * fo..(k + 1) * fo];
                let w1 = &w[(k + 1) * fo..(k + 2) * fo];
                let w2 = &w[(k + 2) * fo..(k + 3) * fo];
                let w3 = &w[(k + 3) * fo..(k + 4) * fo];
                let v0 = _mm256_set1_ps(a0);
                let v1 = _mm256_set1_ps(a1);
                let v2 = _mm256_set1_ps(a2);
                let v3 = _mm256_set1_ps(a3);
                let mut c = 0;
                while c < wide {
                    let mut acc =
                        _mm256_loadu_ps(or.as_ptr().add(c));
                    acc = _mm256_fmadd_ps(
                        v0,
                        _mm256_loadu_ps(w0.as_ptr().add(c)),
                        acc,
                    );
                    acc = _mm256_fmadd_ps(
                        v1,
                        _mm256_loadu_ps(w1.as_ptr().add(c)),
                        acc,
                    );
                    acc = _mm256_fmadd_ps(
                        v2,
                        _mm256_loadu_ps(w2.as_ptr().add(c)),
                        acc,
                    );
                    acc = _mm256_fmadd_ps(
                        v3,
                        _mm256_loadu_ps(w3.as_ptr().add(c)),
                        acc,
                    );
                    _mm256_storeu_ps(or.as_mut_ptr().add(c), acc);
                    c += 8;
                }
                for c in wide..fo {
                    or[c] += a0 * w0[c]
                        + a1 * w1[c]
                        + a2 * w2[c]
                        + a3 * w3[c];
                }
                k += 4;
            }
            while k < fi {
                let av = xr[k];
                if av != 0.0 {
                    let wr = &w[k * fo..(k + 1) * fo];
                    let va = _mm256_set1_ps(av);
                    let mut c = 0;
                    while c < wide {
                        let acc = _mm256_fmadd_ps(
                            va,
                            _mm256_loadu_ps(wr.as_ptr().add(c)),
                            _mm256_loadu_ps(or.as_ptr().add(c)),
                        );
                        _mm256_storeu_ps(or.as_mut_ptr().add(c), acc);
                        c += 8;
                    }
                    for c in wide..fo {
                        or[c] += av * wr[c];
                    }
                }
                k += 1;
            }
        }
    }

    /// AVX2+FMA CSR SpMM over owned rows `v0..v1`, writing the shard's
    /// aggregate into `out` (`(v1 - v0) * f`, fully overwritten). Same
    /// 4-edge unroll and unit-weight fast path as the portable kernel,
    /// 8-wide over the feature row with a scalar tail.
    ///
    /// # Safety
    /// Requires `avx2` and `fma` at runtime
    /// (`is_x86_feature_detected!`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn csr_spmm_rows_into(csr: &CsrPartition, h: &[f32],
                                     f: usize, v0: usize, v1: usize,
                                     out: &mut [f32]) {
        debug_assert!(v1 <= csr.n_local && v0 <= v1);
        debug_assert_eq!(out.len(), (v1 - v0) * f);
        debug_assert!(h.len() >= csr.n * f);
        let wide = f / 8 * 8;
        for v in v0..v1 {
            let row = &mut out[(v - v0) * f..(v - v0 + 1) * f];
            row.fill(0.0);
            let hi = csr.row_ptr[v + 1];
            let mut e = csr.row_ptr[v];
            while e + 4 <= hi {
                let u0 = csr.col[e] as usize;
                let u1 = csr.col[e + 1] as usize;
                let u2 = csr.col[e + 2] as usize;
                let u3 = csr.col[e + 3] as usize;
                let (w0, w1, w2, w3) = (csr.val[e], csr.val[e + 1],
                                        csr.val[e + 2], csr.val[e + 3]);
                let h0 = &h[u0 * f..(u0 + 1) * f];
                let h1 = &h[u1 * f..(u1 + 1) * f];
                let h2 = &h[u2 * f..(u2 + 1) * f];
                let h3 = &h[u3 * f..(u3 + 1) * f];
                if w0 == 1.0 && w1 == 1.0 && w2 == 1.0 && w3 == 1.0 {
                    let mut c = 0;
                    while c < wide {
                        let s01 = _mm256_add_ps(
                            _mm256_loadu_ps(h0.as_ptr().add(c)),
                            _mm256_loadu_ps(h1.as_ptr().add(c)),
                        );
                        let s23 = _mm256_add_ps(
                            _mm256_loadu_ps(h2.as_ptr().add(c)),
                            _mm256_loadu_ps(h3.as_ptr().add(c)),
                        );
                        let acc = _mm256_add_ps(
                            _mm256_loadu_ps(row.as_ptr().add(c)),
                            _mm256_add_ps(s01, s23),
                        );
                        _mm256_storeu_ps(row.as_mut_ptr().add(c), acc);
                        c += 8;
                    }
                    for c in wide..f {
                        row[c] += (h0[c] + h1[c]) + (h2[c] + h3[c]);
                    }
                } else {
                    let vw0 = _mm256_set1_ps(w0);
                    let vw1 = _mm256_set1_ps(w1);
                    let vw2 = _mm256_set1_ps(w2);
                    let vw3 = _mm256_set1_ps(w3);
                    let mut c = 0;
                    while c < wide {
                        let mut acc =
                            _mm256_loadu_ps(row.as_ptr().add(c));
                        acc = _mm256_fmadd_ps(
                            vw0,
                            _mm256_loadu_ps(h0.as_ptr().add(c)),
                            acc,
                        );
                        acc = _mm256_fmadd_ps(
                            vw1,
                            _mm256_loadu_ps(h1.as_ptr().add(c)),
                            acc,
                        );
                        acc = _mm256_fmadd_ps(
                            vw2,
                            _mm256_loadu_ps(h2.as_ptr().add(c)),
                            acc,
                        );
                        acc = _mm256_fmadd_ps(
                            vw3,
                            _mm256_loadu_ps(h3.as_ptr().add(c)),
                            acc,
                        );
                        _mm256_storeu_ps(row.as_mut_ptr().add(c), acc);
                        c += 8;
                    }
                    for c in wide..f {
                        row[c] += w0 * h0[c]
                            + w1 * h1[c]
                            + w2 * h2[c]
                            + w3 * h3[c];
                    }
                }
                e += 4;
            }
            while e < hi {
                let wv = csr.val[e];
                let u = csr.col[e] as usize;
                let hu = &h[u * f..(u + 1) * f];
                if wv == 1.0 {
                    let mut c = 0;
                    while c < wide {
                        let acc = _mm256_add_ps(
                            _mm256_loadu_ps(row.as_ptr().add(c)),
                            _mm256_loadu_ps(hu.as_ptr().add(c)),
                        );
                        _mm256_storeu_ps(row.as_mut_ptr().add(c), acc);
                        c += 8;
                    }
                    for c in wide..f {
                        row[c] += hu[c];
                    }
                } else {
                    let vw = _mm256_set1_ps(wv);
                    let mut c = 0;
                    while c < wide {
                        let acc = _mm256_fmadd_ps(
                            vw,
                            _mm256_loadu_ps(hu.as_ptr().add(c)),
                            _mm256_loadu_ps(row.as_ptr().add(c)),
                        );
                        _mm256_storeu_ps(row.as_mut_ptr().add(c), acc);
                        c += 8;
                    }
                    for c in wide..f {
                        row[c] += wv * hu[c];
                    }
                }
                e += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_is_stable_and_named() {
        let a = active();
        assert_eq!(a, active(), "one-time dispatch never flips");
        match a {
            SimdPath::Avx2Fma => assert_eq!(name(), "avx2+fma"),
            SimdPath::Baseline => assert_eq!(name(), "sse2-baseline"),
        }
        assert_eq!(avx2_active(), a == SimdPath::Avx2Fma);
    }
}
