//! Intra-fog row parallelism: a persistent helper-thread group per fog
//! worker, so one large partition no longer runs serial while other
//! cores idle (the pool used to map exactly one thread per fog, which
//! is precisely wrong after heterogeneity-aware placement concentrates
//! work on the beefiest node).
//!
//! Execution model: a row-parallel pass is a list of deterministic
//! contiguous row ranges (`split_rows`), one closure per range. The
//! group leader (the fog's pool worker, or the serial oracle) sends
//! ranges `1..k` to its helpers, computes range `0` inline, and
//! collects the shard outputs in **fixed range order** — the reduction
//! is an ordered copy into the destination buffer, never an
//! accumulation, so pooled, sharded and serial execution are
//! bit-identical. On top of that, every row kernel in this layer is
//! *row-decomposition invariant* (each output row's arithmetic is a
//! pure function of its own inputs — see the design notes in
//! `gemm.rs`/`spmm.rs`), so the equality holds for ANY split points,
//! not just matching ones; `tests/backend_parity.rs` asserts it across
//! random splits.
//!
//! Helpers are long-lived threads with channel handoff (same rationale
//! as the per-fog pool itself: spawning costs tens of microseconds,
//! comparable to a small shard's entire kernel time). Work below the
//! active shard floor is not split at all — the round trip would cost
//! more than the parallelism buys. The floor itself is a property of
//! the host (channel round-trip latency vs. per-row kernel cost), so
//! when `FOGRAPH_MIN_ROWS_PER_SHARD` is unset it is **derived** by a
//! one-shot micro-probe (`probe_min_rows_per_shard`) rather than
//! hard-coded; the env override still wins and is still exit-2
//! validated at CLI startup.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::OnceLock;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::util::cli::parse_bounded_usize;

/// A unit of row-range work: runs on a helper (or inline) and returns
/// its shard's output rows.
pub type ShardClosure = Box<dyn FnOnce() -> Vec<f32> + Send + 'static>;

/// Fallback minimum row-blocks of work per shard: below the active
/// floor, the channel round trip and per-shard buffers outweigh the
/// parallel win, so the pass runs unsplit. This constant is used only
/// when the micro-probe cannot produce a sane measurement (degenerate
/// clock, probe thread failure); the normal unset-env path derives the
/// floor per host via [`probe_min_rows_per_shard`]. Overridable via
/// [`MIN_ROWS_ENV`], which always wins over the probe.
pub const MIN_ROWS_PER_SHARD: usize = 256;

/// Clamp bounds for the probed floor. Below 64 rows the per-shard
/// output buffers dominate regardless of channel latency; above 4096
/// the probe is claiming handoff costs so high that sharding would
/// never fire on realistic partitions, which is more likely a noisy
/// measurement than a real machine property.
pub const PROBE_FLOOR_MIN: usize = 64;
/// Upper clamp for the probed floor (see [`PROBE_FLOOR_MIN`]).
pub const PROBE_FLOOR_MAX: usize = 4096;

/// Environment override for the shard floor. Must parse to an integer
/// in `1..=MAX_MIN_ROWS_PER_SHARD`; CLI entry points validate it at
/// startup (exit 2 on 0 / junk) via [`min_rows_per_shard_env`].
pub const MIN_ROWS_ENV: &str = "FOGRAPH_MIN_ROWS_PER_SHARD";

/// Typo guard for the override: a floor above this disables sharding
/// on every realistic partition, which is better spelled
/// `--kernel-threads 1`.
pub const MAX_MIN_ROWS_PER_SHARD: usize = 1 << 24;

static ACTIVE_MIN_ROWS: OnceLock<usize> = OnceLock::new();

/// Parse one candidate floor value (pure; unit-testable without
/// touching process environment). Delegates to the shared bounded
/// parser in `util::cli`, the same one `FOGRAPH_TRACE_BUF` uses, so
/// every env knob is validated identically by construction.
pub fn parse_min_rows_per_shard(v: &str) -> Result<usize, String> {
    parse_bounded_usize(MIN_ROWS_ENV, v, 1, MAX_MIN_ROWS_PER_SHARD)
}

/// Read + validate the environment override (`Ok(fallback)` when
/// unset — validation only; the *active* unset-env value is the
/// probed one from [`min_rows_per_shard`]). CLI entry points call
/// this once at startup so a bad value is a loud exit-2, not a silent
/// fallback; keeping it probe-free means startup validation never
/// pays the measurement.
pub fn min_rows_per_shard_env() -> Result<usize, String> {
    match std::env::var(MIN_ROWS_ENV) {
        Ok(v) => parse_min_rows_per_shard(&v),
        Err(_) => Ok(MIN_ROWS_PER_SHARD),
    }
}

/// The active shard floor: the validated environment override when
/// set, otherwise the micro-probe-derived per-host value. Latched on
/// first use (library callers may race threads through
/// `effective_shards`; the floor must not change mid-run). Invalid
/// override values fall back to the probe here — the CLI has already
/// rejected them before any kernel runs.
pub fn min_rows_per_shard() -> usize {
    *ACTIVE_MIN_ROWS.get_or_init(|| match std::env::var(MIN_ROWS_ENV) {
        Ok(v) => parse_min_rows_per_shard(&v)
            .unwrap_or_else(|_| probe_min_rows_per_shard()),
        Err(_) => probe_min_rows_per_shard(),
    })
}

/// Where the active floor came from: `"env-override"` when the
/// operator set [`MIN_ROWS_ENV`], `"micro-probe"` otherwise. Reported
/// next to the value in `BENCH_kernels.json` so benchmark numbers
/// carry their provenance.
pub fn min_rows_per_shard_source() -> &'static str {
    if std::env::var(MIN_ROWS_ENV).is_ok() {
        "env-override"
    } else {
        "micro-probe"
    }
}

static PROBED_MIN_ROWS: OnceLock<usize> = OnceLock::new();

/// Number of row-blocks the timing probe streams per repetition —
/// large enough that the `Instant` read amortises to noise.
const PROBE_ROWS: usize = 8192;
/// Floats per probe row-block: the order of a small per-vertex feature
/// slice, the granularity `split_rows` actually divides.
const PROBE_ROW_WIDTH: usize = 32;
/// Repetitions per measurement; the minimum is kept (least-preempted).
const PROBE_REPS: usize = 5;
/// Two-shard handoffs timed against the probe helper group.
const PROBE_HANDOFFS: usize = 64;

/// One-shot micro-probe: derive the break-even shard floor for this
/// host as `handoff round-trip seconds / per-row kernel seconds`,
/// rounded up to a power of two and clamped to
/// `[PROBE_FLOOR_MIN, PROBE_FLOOR_MAX]`. Cached for the process — the
/// probe spawns one short-lived helper thread and runs ~1 ms of
/// arithmetic, so it must not re-run per plan build. Falls back to
/// [`MIN_ROWS_PER_SHARD`] when either measurement is degenerate
/// (zero / non-finite, e.g. a coarse clock or a failed spawn).
pub fn probe_min_rows_per_shard() -> usize {
    *PROBED_MIN_ROWS.get_or_init(|| {
        derive_floor(probe_per_row_seconds(), probe_handoff_seconds())
    })
}

/// Pure derivation step, split out so tests can pin the arithmetic
/// without timing anything.
pub fn derive_floor(per_row_s: f64, handoff_s: f64) -> usize {
    if !per_row_s.is_finite()
        || !handoff_s.is_finite()
        || per_row_s <= 0.0
        || handoff_s <= 0.0
    {
        return MIN_ROWS_PER_SHARD;
    }
    let breakeven = (handoff_s / per_row_s).ceil();
    if !breakeven.is_finite() || breakeven < 1.0 {
        return MIN_ROWS_PER_SHARD;
    }
    let rows = (breakeven as usize).max(1).next_power_of_two();
    rows.clamp(PROBE_FLOOR_MIN, PROBE_FLOOR_MAX)
}

/// Seconds per row-block of representative kernel work: a fused
/// multiply-add reduction over [`PROBE_ROW_WIDTH`] floats, the same
/// shape as one output row of the dense micro-kernels.
fn probe_per_row_seconds() -> f64 {
    let src: Vec<f32> = (0..PROBE_ROWS * PROBE_ROW_WIDTH)
        .map(|i| ((i % 97) as f32) * 0.03125 + 0.5)
        .collect();
    let mut out = vec![0f32; PROBE_ROWS];
    let mut best = f64::INFINITY;
    for _ in 0..PROBE_REPS {
        let t = Instant::now();
        for (r, o) in out.iter_mut().enumerate() {
            let row = &src[r * PROBE_ROW_WIDTH..(r + 1) * PROBE_ROW_WIDTH];
            let mut acc = 0f32;
            for &v in row {
                acc = v.mul_add(1.0009765, acc);
            }
            *o = acc;
        }
        best = best.min(t.elapsed().as_secs_f64());
        std::hint::black_box(&out);
    }
    best / PROBE_ROWS as f64
}

/// Seconds per two-shard handoff round trip through a real
/// [`ShardGroup`]: send + recv + per-shard buffer return, exactly the
/// overhead `effective_shards` trades against kernel time.
fn probe_handoff_seconds() -> f64 {
    let group = ShardGroup::new(1, "floor-probe");
    let tiny = || {
        vec![
            Box::new(|| vec![1.0f32]) as ShardClosure,
            Box::new(|| vec![2.0f32]) as ShardClosure,
        ]
    };
    // warm the helper (first dispatch pays thread wake-up)
    std::hint::black_box(group.run(tiny()));
    let mut best = f64::INFINITY;
    for _ in 0..PROBE_REPS {
        let t = Instant::now();
        for _ in 0..PROBE_HANDOFFS {
            std::hint::black_box(group.run(tiny()));
        }
        best = best.min(t.elapsed().as_secs_f64());
    }
    best / PROBE_HANDOFFS as f64
}

struct HelperTask {
    shard: usize,
    work: ShardClosure,
}

struct HelperReply {
    shard: usize,
    out: Vec<f32>,
    panicked: bool,
}

/// `helpers` persistent threads plus the calling thread = a worker
/// group of width `helpers + 1`.
pub struct ShardGroup {
    txs: Vec<Sender<HelperTask>>,
    results: Receiver<HelperReply>,
    handles: Vec<JoinHandle<()>>,
}

impl ShardGroup {
    /// Spawn `helpers` long-lived shard threads. `label` names them
    /// (`<label>-shard-<i>`) for debuggability.
    pub fn new(helpers: usize, label: &str) -> ShardGroup {
        let (res_tx, res_rx) = channel::<HelperReply>();
        let mut txs = Vec::with_capacity(helpers);
        let mut handles = Vec::with_capacity(helpers);
        for i in 0..helpers {
            let (tx, rx) = channel::<HelperTask>();
            txs.push(tx);
            let results = res_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("{label}-shard-{i}"))
                .spawn(move || helper_loop(rx, results))
                .expect("spawn shard helper");
            handles.push(handle);
        }
        ShardGroup { txs, results: res_rx, handles }
    }

    /// Workers in the group, including the calling thread.
    pub fn width(&self) -> usize {
        self.txs.len() + 1
    }

    /// Execute one closure per shard: closures `1..k` on the helpers,
    /// closure `0` on the calling thread (so the leader is never idle).
    /// Returns the outputs in closure order — the fixed-order
    /// reduction. Panics if a helper's closure panicked (the caller —
    /// a pool worker — reports it up through the pool's poison path).
    pub fn run(&self, closures: Vec<ShardClosure>) -> Vec<Vec<f32>> {
        let k = closures.len();
        assert!(k >= 1, "at least one shard");
        assert!(
            k <= self.width(),
            "more shards ({k}) than group width ({})",
            self.width()
        );
        let mut iter = closures.into_iter();
        let first = iter.next().expect("first shard closure");
        for (i, work) in iter.enumerate() {
            self.txs[i]
                .send(HelperTask { shard: i + 1, work })
                .expect("shard helper alive while group exists");
        }
        let mut outs: Vec<Vec<f32>> =
            (0..k).map(|_| Vec::new()).collect();
        outs[0] = first();
        for _ in 1..k {
            let r = self.results.recv().expect("shard helper reply");
            if r.panicked {
                panic!("shard helper panicked during kernel execution");
            }
            outs[r.shard] = r.out;
        }
        outs
    }
}

impl Drop for ShardGroup {
    fn drop(&mut self) {
        // closing the task channels ends the helper loops
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn helper_loop(tasks: Receiver<HelperTask>, results: Sender<HelperReply>) {
    while let Ok(task) = tasks.recv() {
        let shard = task.shard;
        // a panicking shard must not leave the leader waiting for a
        // reply that never comes: catch, report, retire this helper
        let ran = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(move || (task.work)()),
        );
        match ran {
            Ok(out) => {
                if results
                    .send(HelperReply { shard, out, panicked: false })
                    .is_err()
                {
                    break; // group dropped mid-flight
                }
            }
            Err(_) => {
                let _ = results.send(HelperReply {
                    shard,
                    out: Vec::new(),
                    panicked: true,
                });
                break;
            }
        }
    }
}

/// How a row-parallel pass executes: on a fog's persistent helper
/// group, or inline in shard order with the same logical width (the
/// spawn-free serial oracle). Both run identical closures over
/// identical ranges, so their outputs are bit-identical by
/// construction.
pub enum ShardExec<'a> {
    Group(&'a ShardGroup),
    Inline(usize),
}

impl ShardExec<'_> {
    /// Workers this executor represents (>= 1).
    pub fn width(&self) -> usize {
        match self {
            ShardExec::Group(g) => g.width(),
            ShardExec::Inline(k) => (*k).max(1),
        }
    }

    /// Shards a pass over `work_rows` total row-blocks should use:
    /// capped by the group width and by the active shard floor
    /// (`min_rows_per_shard`, env-overridable) of work per shard.
    pub fn effective_shards(&self, work_rows: usize) -> usize {
        self.width().min((work_rows / min_rows_per_shard()).max(1))
    }

    /// Run the pass: on the group, or sequentially in shard order.
    pub fn run(&self, closures: Vec<ShardClosure>) -> Vec<Vec<f32>> {
        match self {
            ShardExec::Group(g) => g.run(closures),
            ShardExec::Inline(_) => {
                closures.into_iter().map(|c| c()).collect()
            }
        }
    }
}

/// Deterministic contiguous split of `rows` into at most `shards`
/// non-empty ranges, sizes differing by at most one (the first
/// `rows % k` ranges are one longer). Pure function of its arguments —
/// every executor that splits the same way gets the same ranges.
pub fn split_rows(rows: usize, shards: usize) -> Vec<(usize, usize)> {
    if rows == 0 {
        return Vec::new();
    }
    let k = shards.clamp(1, rows);
    let base = rows / k;
    let rem = rows % k;
    let mut ranges = Vec::with_capacity(k);
    let mut at = 0usize;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        ranges.push((at, at + len));
        at += len;
    }
    debug_assert_eq!(at, rows);
    ranges
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn split_rows_is_contiguous_and_balanced() {
        for rows in [1usize, 2, 7, 255, 256, 1000, 1001] {
            for k in [1usize, 2, 3, 4, 8] {
                let r = split_rows(rows, k);
                assert!(!r.is_empty());
                assert!(r.len() <= k.min(rows));
                assert_eq!(r[0].0, 0);
                assert_eq!(r.last().unwrap().1, rows);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "contiguous");
                }
                let sizes: Vec<usize> =
                    r.iter().map(|&(a, b)| b - a).collect();
                let mx = *sizes.iter().max().unwrap();
                let mn = *sizes.iter().min().unwrap();
                assert!(mx - mn <= 1, "balanced within one row");
                assert!(mn >= 1);
            }
        }
        assert!(split_rows(0, 4).is_empty());
    }

    #[test]
    fn group_runs_shards_in_fixed_order() {
        let group = ShardGroup::new(3, "test");
        assert_eq!(group.width(), 4);
        let data: Arc<Vec<f32>> =
            Arc::new((0..64).map(|i| i as f32).collect());
        let ranges = split_rows(64, 4);
        let closures: Vec<ShardClosure> = ranges
            .iter()
            .map(|&(a, b)| {
                let d = data.clone();
                Box::new(move || d[a..b].to_vec()) as ShardClosure
            })
            .collect();
        let outs = group.run(closures);
        let flat: Vec<f32> =
            outs.into_iter().flatten().collect();
        assert_eq!(flat, *data, "ordered concatenation reproduces input");
    }

    #[test]
    fn inline_exec_matches_group_exec() {
        let group = ShardGroup::new(2, "test");
        let make = |exec: &ShardExec| -> Vec<f32> {
            let ranges = split_rows(100, exec.width());
            let closures: Vec<ShardClosure> = ranges
                .iter()
                .map(|&(a, b)| {
                    Box::new(move || {
                        (a..b).map(|i| (i * i) as f32).collect()
                    }) as ShardClosure
                })
                .collect();
            exec.run(closures).into_iter().flatten().collect()
        };
        let pooled = make(&ShardExec::Group(&group));
        let inline = make(&ShardExec::Inline(3));
        assert_eq!(pooled, inline);
    }

    #[test]
    fn min_rows_override_parses_and_rejects() {
        assert_eq!(parse_min_rows_per_shard("1"), Ok(1));
        assert_eq!(parse_min_rows_per_shard("256"), Ok(256));
        assert_eq!(parse_min_rows_per_shard(" 4096 "), Ok(4096));
        assert_eq!(
            parse_min_rows_per_shard(&MAX_MIN_ROWS_PER_SHARD.to_string()),
            Ok(MAX_MIN_ROWS_PER_SHARD)
        );
        for bad in ["0", "-1", "many", "", "1e3",
                    "16777217" /* MAX + 1 */] {
            assert!(parse_min_rows_per_shard(bad).is_err(),
                    "{bad:?} accepted");
        }
    }

    #[test]
    fn active_floor_is_probed_when_env_unset() {
        // the test runner does not set the override, so the latched
        // value is the micro-probe result: a power of two inside the
        // clamp band, stable across calls (OnceLock), and labelled
        // with probe provenance. The env contract stays Ok when unset
        // (validation-only path, never probes).
        if std::env::var(MIN_ROWS_ENV).is_err() {
            let floor = min_rows_per_shard();
            assert_eq!(floor, probe_min_rows_per_shard());
            assert!((PROBE_FLOOR_MIN..=PROBE_FLOOR_MAX)
                        .contains(&floor),
                    "probed floor {floor} outside clamp band");
            assert!(floor.is_power_of_two()
                        || floor == MIN_ROWS_PER_SHARD,
                    "floor {floor} neither pow2 nor fallback");
            assert_eq!(min_rows_per_shard(), floor, "latched");
            assert_eq!(min_rows_per_shard_source(), "micro-probe");
            assert_eq!(min_rows_per_shard_env(),
                       Ok(MIN_ROWS_PER_SHARD));
        }
    }

    #[test]
    fn derive_floor_arithmetic_and_fallbacks() {
        // break-even rounds up to pow2: 100 rows of 1µs vs 100µs
        // handoff → 100 → 128
        assert_eq!(derive_floor(1e-6, 100e-6), 128);
        // clamps: tiny handoff floors at PROBE_FLOOR_MIN, huge
        // handoff ceils at PROBE_FLOOR_MAX
        assert_eq!(derive_floor(1e-6, 1e-9), PROBE_FLOOR_MIN);
        assert_eq!(derive_floor(1e-9, 1.0), PROBE_FLOOR_MAX);
        // exact pow2 stays put
        assert_eq!(derive_floor(1e-6, 512e-6), 512);
        // degenerate measurements fall back to the static default
        for (r, h) in [(0.0, 1e-6), (1e-6, 0.0), (-1.0, 1e-6),
                       (f64::NAN, 1e-6), (1e-6, f64::INFINITY)] {
            assert_eq!(derive_floor(r, h), MIN_ROWS_PER_SHARD,
                       "({r}, {h}) should fall back");
        }
    }

    #[test]
    fn effective_shards_respects_min_rows() {
        let floor = min_rows_per_shard();
        let exec = ShardExec::Inline(4);
        assert_eq!(exec.effective_shards(floor / 2), 1);
        assert_eq!(exec.effective_shards(floor), 1);
        assert_eq!(exec.effective_shards(2 * floor), 2);
        assert_eq!(exec.effective_shards(100 * floor), 4);
    }
}
