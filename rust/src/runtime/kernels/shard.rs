//! Intra-fog row parallelism: a persistent helper-thread group per fog
//! worker, so one large partition no longer runs serial while other
//! cores idle (the pool used to map exactly one thread per fog, which
//! is precisely wrong after heterogeneity-aware placement concentrates
//! work on the beefiest node).
//!
//! Execution model: a row-parallel pass is a list of deterministic
//! contiguous row ranges (`split_rows`), one closure per range. The
//! group leader (the fog's pool worker, or the serial oracle) sends
//! ranges `1..k` to its helpers, computes range `0` inline, and
//! collects the shard outputs in **fixed range order** — the reduction
//! is an ordered copy into the destination buffer, never an
//! accumulation, so pooled, sharded and serial execution are
//! bit-identical. On top of that, every row kernel in this layer is
//! *row-decomposition invariant* (each output row's arithmetic is a
//! pure function of its own inputs — see the design notes in
//! `gemm.rs`/`spmm.rs`), so the equality holds for ANY split points,
//! not just matching ones; `tests/backend_parity.rs` asserts it across
//! random splits.
//!
//! Helpers are long-lived threads with channel handoff (same rationale
//! as the per-fog pool itself: spawning costs tens of microseconds,
//! comparable to a small shard's entire kernel time). Work below
//! `MIN_ROWS_PER_SHARD` rows is not split at all — the round trip
//! would cost more than the parallelism buys.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::OnceLock;
use std::thread::JoinHandle;

use crate::util::cli::parse_bounded_usize;

/// A unit of row-range work: runs on a helper (or inline) and returns
/// its shard's output rows.
pub type ShardClosure = Box<dyn FnOnce() -> Vec<f32> + Send + 'static>;

/// Default minimum row-blocks of work per shard: below this, the
/// channel round trip and per-shard buffers outweigh the parallel win,
/// so the pass runs unsplit. Overridable per-host via
/// [`MIN_ROWS_ENV`] (the right floor is a property of the channel
/// round-trip vs. per-row kernel cost, which varies across hosts).
pub const MIN_ROWS_PER_SHARD: usize = 256;

/// Environment override for the shard floor. Must parse to an integer
/// in `1..=MAX_MIN_ROWS_PER_SHARD`; CLI entry points validate it at
/// startup (exit 2 on 0 / junk) via [`min_rows_per_shard_env`].
pub const MIN_ROWS_ENV: &str = "FOGRAPH_MIN_ROWS_PER_SHARD";

/// Typo guard for the override: a floor above this disables sharding
/// on every realistic partition, which is better spelled
/// `--kernel-threads 1`.
pub const MAX_MIN_ROWS_PER_SHARD: usize = 1 << 24;

static ACTIVE_MIN_ROWS: OnceLock<usize> = OnceLock::new();

/// Parse one candidate floor value (pure; unit-testable without
/// touching process environment). Delegates to the shared bounded
/// parser in `util::cli`, the same one `FOGRAPH_TRACE_BUF` uses, so
/// every env knob is validated identically by construction.
pub fn parse_min_rows_per_shard(v: &str) -> Result<usize, String> {
    parse_bounded_usize(MIN_ROWS_ENV, v, 1, MAX_MIN_ROWS_PER_SHARD)
}

/// Read + validate the environment override (`Ok(default)` when
/// unset). CLI entry points call this once at startup so a bad value
/// is a loud exit-2, not a silent fallback.
pub fn min_rows_per_shard_env() -> Result<usize, String> {
    match std::env::var(MIN_ROWS_ENV) {
        Ok(v) => parse_min_rows_per_shard(&v),
        Err(_) => Ok(MIN_ROWS_PER_SHARD),
    }
}

/// The active shard floor: the validated environment override, or the
/// built-in default. Latched on first use (library callers may race
/// threads through `effective_shards`; the floor must not change
/// mid-run). Invalid values fall back to the default here — the CLI
/// has already rejected them before any kernel runs.
pub fn min_rows_per_shard() -> usize {
    *ACTIVE_MIN_ROWS.get_or_init(|| {
        min_rows_per_shard_env().unwrap_or(MIN_ROWS_PER_SHARD)
    })
}

struct HelperTask {
    shard: usize,
    work: ShardClosure,
}

struct HelperReply {
    shard: usize,
    out: Vec<f32>,
    panicked: bool,
}

/// `helpers` persistent threads plus the calling thread = a worker
/// group of width `helpers + 1`.
pub struct ShardGroup {
    txs: Vec<Sender<HelperTask>>,
    results: Receiver<HelperReply>,
    handles: Vec<JoinHandle<()>>,
}

impl ShardGroup {
    /// Spawn `helpers` long-lived shard threads. `label` names them
    /// (`<label>-shard-<i>`) for debuggability.
    pub fn new(helpers: usize, label: &str) -> ShardGroup {
        let (res_tx, res_rx) = channel::<HelperReply>();
        let mut txs = Vec::with_capacity(helpers);
        let mut handles = Vec::with_capacity(helpers);
        for i in 0..helpers {
            let (tx, rx) = channel::<HelperTask>();
            txs.push(tx);
            let results = res_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("{label}-shard-{i}"))
                .spawn(move || helper_loop(rx, results))
                .expect("spawn shard helper");
            handles.push(handle);
        }
        ShardGroup { txs, results: res_rx, handles }
    }

    /// Workers in the group, including the calling thread.
    pub fn width(&self) -> usize {
        self.txs.len() + 1
    }

    /// Execute one closure per shard: closures `1..k` on the helpers,
    /// closure `0` on the calling thread (so the leader is never idle).
    /// Returns the outputs in closure order — the fixed-order
    /// reduction. Panics if a helper's closure panicked (the caller —
    /// a pool worker — reports it up through the pool's poison path).
    pub fn run(&self, closures: Vec<ShardClosure>) -> Vec<Vec<f32>> {
        let k = closures.len();
        assert!(k >= 1, "at least one shard");
        assert!(
            k <= self.width(),
            "more shards ({k}) than group width ({})",
            self.width()
        );
        let mut iter = closures.into_iter();
        let first = iter.next().expect("first shard closure");
        for (i, work) in iter.enumerate() {
            self.txs[i]
                .send(HelperTask { shard: i + 1, work })
                .expect("shard helper alive while group exists");
        }
        let mut outs: Vec<Vec<f32>> =
            (0..k).map(|_| Vec::new()).collect();
        outs[0] = first();
        for _ in 1..k {
            let r = self.results.recv().expect("shard helper reply");
            if r.panicked {
                panic!("shard helper panicked during kernel execution");
            }
            outs[r.shard] = r.out;
        }
        outs
    }
}

impl Drop for ShardGroup {
    fn drop(&mut self) {
        // closing the task channels ends the helper loops
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn helper_loop(tasks: Receiver<HelperTask>, results: Sender<HelperReply>) {
    while let Ok(task) = tasks.recv() {
        let shard = task.shard;
        // a panicking shard must not leave the leader waiting for a
        // reply that never comes: catch, report, retire this helper
        let ran = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(move || (task.work)()),
        );
        match ran {
            Ok(out) => {
                if results
                    .send(HelperReply { shard, out, panicked: false })
                    .is_err()
                {
                    break; // group dropped mid-flight
                }
            }
            Err(_) => {
                let _ = results.send(HelperReply {
                    shard,
                    out: Vec::new(),
                    panicked: true,
                });
                break;
            }
        }
    }
}

/// How a row-parallel pass executes: on a fog's persistent helper
/// group, or inline in shard order with the same logical width (the
/// spawn-free serial oracle). Both run identical closures over
/// identical ranges, so their outputs are bit-identical by
/// construction.
pub enum ShardExec<'a> {
    Group(&'a ShardGroup),
    Inline(usize),
}

impl ShardExec<'_> {
    /// Workers this executor represents (>= 1).
    pub fn width(&self) -> usize {
        match self {
            ShardExec::Group(g) => g.width(),
            ShardExec::Inline(k) => (*k).max(1),
        }
    }

    /// Shards a pass over `work_rows` total row-blocks should use:
    /// capped by the group width and by the active shard floor
    /// (`min_rows_per_shard`, env-overridable) of work per shard.
    pub fn effective_shards(&self, work_rows: usize) -> usize {
        self.width().min((work_rows / min_rows_per_shard()).max(1))
    }

    /// Run the pass: on the group, or sequentially in shard order.
    pub fn run(&self, closures: Vec<ShardClosure>) -> Vec<Vec<f32>> {
        match self {
            ShardExec::Group(g) => g.run(closures),
            ShardExec::Inline(_) => {
                closures.into_iter().map(|c| c()).collect()
            }
        }
    }
}

/// Deterministic contiguous split of `rows` into at most `shards`
/// non-empty ranges, sizes differing by at most one (the first
/// `rows % k` ranges are one longer). Pure function of its arguments —
/// every executor that splits the same way gets the same ranges.
pub fn split_rows(rows: usize, shards: usize) -> Vec<(usize, usize)> {
    if rows == 0 {
        return Vec::new();
    }
    let k = shards.clamp(1, rows);
    let base = rows / k;
    let rem = rows % k;
    let mut ranges = Vec::with_capacity(k);
    let mut at = 0usize;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        ranges.push((at, at + len));
        at += len;
    }
    debug_assert_eq!(at, rows);
    ranges
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn split_rows_is_contiguous_and_balanced() {
        for rows in [1usize, 2, 7, 255, 256, 1000, 1001] {
            for k in [1usize, 2, 3, 4, 8] {
                let r = split_rows(rows, k);
                assert!(!r.is_empty());
                assert!(r.len() <= k.min(rows));
                assert_eq!(r[0].0, 0);
                assert_eq!(r.last().unwrap().1, rows);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "contiguous");
                }
                let sizes: Vec<usize> =
                    r.iter().map(|&(a, b)| b - a).collect();
                let mx = *sizes.iter().max().unwrap();
                let mn = *sizes.iter().min().unwrap();
                assert!(mx - mn <= 1, "balanced within one row");
                assert!(mn >= 1);
            }
        }
        assert!(split_rows(0, 4).is_empty());
    }

    #[test]
    fn group_runs_shards_in_fixed_order() {
        let group = ShardGroup::new(3, "test");
        assert_eq!(group.width(), 4);
        let data: Arc<Vec<f32>> =
            Arc::new((0..64).map(|i| i as f32).collect());
        let ranges = split_rows(64, 4);
        let closures: Vec<ShardClosure> = ranges
            .iter()
            .map(|&(a, b)| {
                let d = data.clone();
                Box::new(move || d[a..b].to_vec()) as ShardClosure
            })
            .collect();
        let outs = group.run(closures);
        let flat: Vec<f32> =
            outs.into_iter().flatten().collect();
        assert_eq!(flat, *data, "ordered concatenation reproduces input");
    }

    #[test]
    fn inline_exec_matches_group_exec() {
        let group = ShardGroup::new(2, "test");
        let make = |exec: &ShardExec| -> Vec<f32> {
            let ranges = split_rows(100, exec.width());
            let closures: Vec<ShardClosure> = ranges
                .iter()
                .map(|&(a, b)| {
                    Box::new(move || {
                        (a..b).map(|i| (i * i) as f32).collect()
                    }) as ShardClosure
                })
                .collect();
            exec.run(closures).into_iter().flatten().collect()
        };
        let pooled = make(&ShardExec::Group(&group));
        let inline = make(&ShardExec::Inline(3));
        assert_eq!(pooled, inline);
    }

    #[test]
    fn min_rows_override_parses_and_rejects() {
        assert_eq!(parse_min_rows_per_shard("1"), Ok(1));
        assert_eq!(parse_min_rows_per_shard("256"), Ok(256));
        assert_eq!(parse_min_rows_per_shard(" 4096 "), Ok(4096));
        assert_eq!(
            parse_min_rows_per_shard(&MAX_MIN_ROWS_PER_SHARD.to_string()),
            Ok(MAX_MIN_ROWS_PER_SHARD)
        );
        for bad in ["0", "-1", "many", "", "1e3",
                    "16777217" /* MAX + 1 */] {
            assert!(parse_min_rows_per_shard(bad).is_err(),
                    "{bad:?} accepted");
        }
    }

    #[test]
    fn active_floor_defaults_when_env_unset() {
        // the test runner does not set the override, so the latched
        // value is the compiled default (also pins the env contract:
        // `min_rows_per_shard_env` is Ok when unset)
        if std::env::var(MIN_ROWS_ENV).is_err() {
            assert_eq!(min_rows_per_shard(), MIN_ROWS_PER_SHARD);
            assert_eq!(min_rows_per_shard_env(),
                       Ok(MIN_ROWS_PER_SHARD));
        }
    }

    #[test]
    fn effective_shards_respects_min_rows() {
        let exec = ShardExec::Inline(4);
        assert_eq!(exec.effective_shards(10), 1);
        assert_eq!(exec.effective_shards(MIN_ROWS_PER_SHARD), 1);
        assert_eq!(exec.effective_shards(2 * MIN_ROWS_PER_SHARD), 2);
        assert_eq!(exec.effective_shards(100 * MIN_ROWS_PER_SHARD), 4);
    }
}
