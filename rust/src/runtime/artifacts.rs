//! Artifact manifest: the index of AOT-lowered HLO modules emitted by
//! python/compile/aot.py, plus bucket selection (smallest lowered shape
//! that fits a partition).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug)]
pub enum ManifestError {
    Io(std::io::Error),
    Json(crate::util::json::ParseError),
    Schema(&'static str),
    NoBucket {
        model: String,
        dataset: String,
        layer: usize,
        v: usize,
        e: usize,
    },
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(e) => write!(f, "io: {e}"),
            ManifestError::Json(e) => write!(f, "json: {e}"),
            ManifestError::Schema(w) => {
                write!(f, "manifest field missing or wrong type: {w}")
            }
            ManifestError::NoBucket { model, dataset, layer, v, e } => {
                write!(
                    f,
                    "no artifact fits model={model} dataset={dataset} \
                     layer={layer} v={v} e={e}"
                )
            }
        }
    }
}

impl std::error::Error for ManifestError {}

impl From<std::io::Error> for ManifestError {
    fn from(e: std::io::Error) -> Self {
        ManifestError::Io(e)
    }
}

impl From<crate::util::json::ParseError> for ManifestError {
    fn from(e: crate::util::json::ParseError) -> Self {
        ManifestError::Json(e)
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: PathBuf,
    pub model: String,
    pub dataset: String,
    pub frac: usize,
    pub layer: usize,
    pub num_layers: usize,
    pub v_max: usize,
    pub e_max: usize,
    /// Owned-row capacity: the update matmul covers only these rows.
    pub l_max: usize,
    pub out_dim: usize,
    /// Ordered (name, shape) of trained-parameter inputs.
    pub params: Vec<(String, Vec<usize>)>,
    /// Ordered (name, shape, dtype) of data inputs.
    pub data: Vec<(String, Vec<usize>, String)>,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
    /// (model, dataset, layer) -> indices sorted by ascending v_max.
    by_key: HashMap<(String, String, usize), Vec<usize>>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let root = Json::parse(&text)?;
        let arr = root
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or(ManifestError::Schema("artifacts"))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for a in arr {
            let gets = |k: &'static str| -> Result<&Json, ManifestError> {
                a.get(k).ok_or(ManifestError::Schema(k))
            };
            let shapes = |key: &'static str| -> Result<Vec<(String, Vec<usize>, String)>, ManifestError> {
                let mut out = Vec::new();
                for item in gets(key)?.as_arr().ok_or(ManifestError::Schema(key))? {
                    let parts = item.as_arr().ok_or(ManifestError::Schema(key))?;
                    let name = parts[0].as_str()
                        .ok_or(ManifestError::Schema(key))?.to_string();
                    let dims: Vec<usize> = parts[1].as_arr()
                        .ok_or(ManifestError::Schema(key))?
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect();
                    let dtype = parts.get(2).and_then(|d| d.as_str())
                        .unwrap_or("f32").to_string();
                    out.push((name, dims, dtype));
                }
                Ok(out)
            };
            artifacts.push(ArtifactMeta {
                name: gets("name")?.as_str()
                    .ok_or(ManifestError::Schema("name"))?.to_string(),
                path: dir.join(gets("path")?.as_str()
                    .ok_or(ManifestError::Schema("path"))?),
                model: gets("model")?.as_str()
                    .ok_or(ManifestError::Schema("model"))?.to_string(),
                dataset: gets("dataset")?.as_str()
                    .ok_or(ManifestError::Schema("dataset"))?.to_string(),
                frac: gets("frac")?.as_usize()
                    .ok_or(ManifestError::Schema("frac"))?,
                layer: gets("layer")?.as_usize()
                    .ok_or(ManifestError::Schema("layer"))?,
                num_layers: gets("num_layers")?.as_usize()
                    .ok_or(ManifestError::Schema("num_layers"))?,
                v_max: gets("v_max")?.as_usize()
                    .ok_or(ManifestError::Schema("v_max"))?,
                e_max: gets("e_max")?.as_usize()
                    .ok_or(ManifestError::Schema("e_max"))?,
                // older manifests predate the local-row split
                l_max: a.get("l_max").and_then(|x| x.as_usize())
                    .unwrap_or_else(|| {
                        gets("v_max").and_then(|x| {
                            x.as_usize().ok_or(ManifestError::Schema("v_max"))
                        }).unwrap_or(0)
                    }),
                out_dim: gets("out_dim")?.as_usize()
                    .ok_or(ManifestError::Schema("out_dim"))?,
                params: shapes("params")?
                    .into_iter()
                    .map(|(n, d, _)| (n, d))
                    .collect(),
                data: shapes("data")?,
            });
        }
        let mut by_key: HashMap<(String, String, usize), Vec<usize>> =
            HashMap::new();
        for (i, a) in artifacts.iter().enumerate() {
            by_key
                .entry((a.model.clone(), a.dataset.clone(), a.layer))
                .or_default()
                .push(i);
        }
        for idxs in by_key.values_mut() {
            idxs.sort_by_key(|&i| (artifacts[i].v_max, artifacts[i].e_max));
        }
        Ok(Manifest { artifacts, by_key })
    }

    pub fn num_layers(&self, model: &str, dataset: &str) -> Option<usize> {
        self.artifacts
            .iter()
            .find(|a| a.model == model && a.dataset == dataset)
            .map(|a| a.num_layers)
    }

    /// Smallest bucket with v_max >= v and e_max >= e (and room for the
    /// owned rows: l_max >= l).
    pub fn select_l(&self, model: &str, dataset: &str, layer: usize,
                    v: usize, e: usize, l: usize)
                    -> Result<&ArtifactMeta, ManifestError> {
        let key = (model.to_string(), dataset.to_string(), layer);
        let idxs = self.by_key.get(&key).ok_or_else(|| {
            ManifestError::NoBucket {
                model: model.into(),
                dataset: dataset.into(),
                layer,
                v,
                e,
            }
        })?;
        idxs.iter()
            .map(|&i| &self.artifacts[i])
            .find(|a| a.v_max >= v && a.e_max >= e && a.l_max >= l)
            .ok_or_else(|| ManifestError::NoBucket {
                model: model.into(),
                dataset: dataset.into(),
                layer,
                v,
                e,
            })
    }

    /// Smallest bucket with v_max >= v and e_max >= e.
    pub fn select(&self, model: &str, dataset: &str, layer: usize,
                  v: usize, e: usize) -> Result<&ArtifactMeta, ManifestError> {
        let key = (model.to_string(), dataset.to_string(), layer);
        let idxs = self.by_key.get(&key).ok_or_else(|| {
            ManifestError::NoBucket {
                model: model.into(),
                dataset: dataset.into(),
                layer,
                v,
                e,
            }
        })?;
        idxs.iter()
            .map(|&i| &self.artifacts[i])
            .find(|a| a.v_max >= v && a.e_max >= e)
            .ok_or_else(|| ManifestError::NoBucket {
                model: model.into(),
                dataset: dataset.into(),
                layer,
                v,
                e,
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let text = r#"{
 "artifacts": [
  {"name": "gcn_siot_f4_l0", "path": "gcn_siot_f4_l0.hlo.txt",
   "model": "gcn", "dataset": "siot", "frac": 4, "layer": 0,
   "num_layers": 2, "v_max": 8192, "e_max": 131072, "out_dim": 64,
   "params": [["w", [52, 64], "f32"], ["b", [64], "f32"]],
   "data": [["h", [8192, 52], "f32"], ["src", [131072], "i32"],
            ["dst", [131072], "i32"], ["ew", [131072], "f32"],
            ["inv_deg", [8192, 1], "f32"]]},
  {"name": "gcn_siot_f1_l0", "path": "gcn_siot_f1_l0.hlo.txt",
   "model": "gcn", "dataset": "siot", "frac": 1, "layer": 0,
   "num_layers": 2, "v_max": 16384, "e_max": 309248, "out_dim": 64,
   "params": [["w", [52, 64], "f32"], ["b", [64], "f32"]],
   "data": [["h", [16384, 52], "f32"], ["src", [309248], "i32"],
            ["dst", [309248], "i32"], ["ew", [309248], "f32"],
            ["inv_deg", [16384, 1], "f32"]]}
 ],
 "format": 1
}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn loads_and_selects_smallest_fitting_bucket() {
        let dir = std::env::temp_dir().join("manifest_test");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.num_layers("gcn", "siot"), Some(2));
        let small = m.select("gcn", "siot", 0, 5000, 100_000).unwrap();
        assert_eq!(small.frac, 4);
        let big = m.select("gcn", "siot", 0, 9000, 100_000).unwrap();
        assert_eq!(big.frac, 1);
        // edge overflow forces the big bucket too
        let big2 = m.select("gcn", "siot", 0, 1000, 200_000).unwrap();
        assert_eq!(big2.frac, 1);
        assert!(m.select("gcn", "siot", 0, 999_999, 1).is_err());
        assert!(m.select("gat", "siot", 0, 1, 1).is_err());
        // param order preserved
        assert_eq!(small.params[0].0, "w");
        assert_eq!(small.data[1].2, "i32");
    }
}
