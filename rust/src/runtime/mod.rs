//! Execution runtime behind a pluggable backend architecture
//! (`backend::ExecBackend`): the `Engine` façade owns weight bundles and
//! the artifact manifest and dispatches kernels to one of
//!
//! * the AOT PJRT backend (Python-lowered HLO artifacts via the `xla`
//!   crate, behind the `pjrt` feature) — Python is never on the request
//!   path;
//! * the pure-Rust dense reference backend (numeric oracle and
//!   large-sweep fallback);
//! * the sparse CSR backend with block-diagonal batched execution
//!   (`csr_backend`), the engine behind `--exec measured` serving.
//!
//! All CPU backends route their numerics through the dedicated kernel
//! layer (`kernels`): register-blocked K-unrolled GEMM, edge-unrolled
//! CSR SpMM, and the persistent per-fog worker pool the measured
//! serving path executes on.
//!
//! Also includes the manifest/bucket index, the `.fgw` weight loader and
//! model-specific padding (twin of python/compile/prep.py).

pub mod artifacts;
pub mod backend;
pub mod csr_backend;
pub mod engine;
pub mod kernels;
pub mod pad;
pub mod reference;
pub mod weights;

pub use artifacts::{ArtifactMeta, Manifest};
pub use backend::{ExecBackend, LayerCtx};
pub use csr_backend::{CsrBackend, CsrPartition};
pub use engine::{Engine, EngineError, EngineKind, LayerOut};
pub use kernels::{FogWorkerPool, KernelScratch};
pub use pad::EdgeArrays;
pub use weights::WeightBundle;
