//! AOT runtime: loads the Python-compiled HLO-text artifacts and executes
//! them via the PJRT C API (`xla` crate) — Python is never on the request
//! path. Includes the manifest/bucket index, the `.fgw` weight loader,
//! model-specific padding (twin of python/compile/prep.py), and a pure-
//! Rust reference engine used as numeric oracle and large-sweep fallback.

pub mod artifacts;
pub mod engine;
pub mod pad;
pub mod reference;
pub mod weights;

pub use artifacts::{ArtifactMeta, Manifest};
pub use engine::{Engine, EngineError, EngineKind, LayerOut};
pub use pad::EdgeArrays;
pub use weights::WeightBundle;
