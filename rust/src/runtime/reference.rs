//! Pure-Rust GNN layer forward — the runtime's numeric oracle and the
//! fallback engine for large sweeps (no PJRT padding overhead). The math
//! mirrors python/compile/kernels/ref.py exactly; cross-engine parity is
//! asserted by rust/tests/pjrt_integration.rs.

use super::pad::{EdgeArrays, UnknownModel};
use super::weights::WeightBundle;

pub const HIDDEN: usize = 64;

/// Every model name the runtime understands (user input is validated
/// against this at the CLI boundary; deeper layers return
/// `UnknownModel` rather than panic).
pub const KNOWN_MODELS: [&str; 4] = ["gcn", "gat", "sage", "astgcn"];

pub fn known_model(model: &str) -> bool {
    KNOWN_MODELS.contains(&model)
}

pub fn model_layers(model: &str) -> usize {
    match model {
        "astgcn" => 1,
        _ => 2,
    }
}

/// Row-major matmul with bias: out[n, fo] = x[n, fi] @ w[fi, fo] + b.
/// Delegates to the tiled kernel layer (`kernels::gemm`); the textbook
/// loop survives as `kernels::gemm::gemm_bias_naive`, the baseline the
/// parity suite and `repro bench-kernels` measure against.
pub fn matmul_bias(x: &[f32], n: usize, fi: usize, w: &[f32], fo: usize,
                   b: &[f32]) -> Vec<f32> {
    super::kernels::gemm_bias(x, n, fi, w, fo, b)
}

pub(crate) fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

pub(crate) fn elu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = v.exp_m1();
        }
    }
}

/// Σ_{(u,v)∈E} ew · h_u scattered into rows v (ref.segment_aggregate).
pub fn segment_aggregate(h: &[f32], f: usize, edges: &EdgeArrays,
                         out_n: usize) -> Vec<f32> {
    let mut agg = vec![0f32; out_n * f];
    for ((&s, &d), &w) in
        edges.src.iter().zip(edges.dst.iter()).zip(edges.ew.iter())
    {
        if w == 0.0 {
            continue;
        }
        let hs = &h[s as usize * f..(s as usize + 1) * f];
        let ar = &mut agg[d as usize * f..(d as usize + 1) * f];
        if w == 1.0 {
            for (a, &x) in ar.iter_mut().zip(hs) {
                *a += x;
            }
        } else {
            for (a, &x) in ar.iter_mut().zip(hs) {
                *a += w * x;
            }
        }
    }
    agg
}

/// One message-passing layer (gcn / sage / gat), ref semantics.
/// `last` selects the linear output head (no activation).
pub fn run_layer(model: &str, layer: usize, weights: &WeightBundle,
                 h: &[f32], f_in: usize, edges: &EdgeArrays, last: bool)
                 -> Result<Vec<f32>, UnknownModel> {
    if !matches!(model, "gcn" | "sage" | "gat") {
        return Err(UnknownModel(model.to_string()));
    }
    let n = edges.n;
    // outputs cover the owned rows only — halo rows cost no update FLOPs
    // (mirrors the l_max dimension of the lowered artifacts)
    let l = edges.n_local;
    debug_assert_eq!(h.len(), n * f_in);
    let w = weights.get(&format!("l{layer}.w")).expect("missing weight");
    let b = weights.get(&format!("l{layer}.b")).expect("missing bias");
    let fo = *w.dims.last().unwrap();
    Ok(match model {
        "gcn" => {
            let agg = segment_aggregate(h, f_in, edges, l);
            let mut comb = vec![0f32; l * f_in];
            for v in 0..l {
                let s = edges.inv_deg[v];
                for k in 0..f_in {
                    comb[v * f_in + k] =
                        (agg[v * f_in + k] + h[v * f_in + k]) * s;
                }
            }
            let mut out = matmul_bias(&comb, l, f_in, &w.f32_data, fo,
                                      &b.f32_data);
            if !last {
                relu(&mut out);
            }
            out
        }
        "sage" => {
            let agg = segment_aggregate(h, f_in, edges, l);
            let mut comb = vec![0f32; l * 2 * f_in];
            for v in 0..l {
                let s = edges.inv_deg[v];
                for k in 0..f_in {
                    comb[v * 2 * f_in + k] = agg[v * f_in + k] * s;
                    comb[v * 2 * f_in + f_in + k] = h[v * f_in + k];
                }
            }
            let mut out = matmul_bias(&comb, l, 2 * f_in, &w.f32_data, fo,
                                      &b.f32_data);
            if !last {
                relu(&mut out);
            }
            out
        }
        "gat" => {
            let a_src = weights.get(&format!("l{layer}.a_src")).unwrap();
            let a_dst = weights.get(&format!("l{layer}.a_dst")).unwrap();
            // z spans ALL rows: halo sources feed the attention
            let z = matmul_bias(h, n, f_in, &w.f32_data, fo, &b.f32_data);
            // per-vertex attention scalars
            let dot = |row: usize, a: &[f32]| -> f32 {
                z[row * fo..(row + 1) * fo]
                    .iter()
                    .zip(a)
                    .map(|(x, y)| x * y)
                    .sum()
            };
            let es: Vec<f32> =
                (0..n).map(|v| dot(v, &a_src.f32_data)).collect();
            let ed: Vec<f32> =
                (0..n).map(|v| dot(v, &a_dst.f32_data)).collect();
            let ne = edges.num_edges();
            let mut logits = vec![0f32; ne];
            for i in 0..ne {
                let x = es[edges.src[i] as usize]
                    + ed[edges.dst[i] as usize];
                logits[i] = if x > 0.0 { x } else { 0.2 * x };
            }
            // segment softmax over dst (ew == 0 excluded); dst < l always
            let mut smax = vec![f32::NEG_INFINITY; l];
            for i in 0..ne {
                if edges.ew[i] > 0.0 {
                    let d = edges.dst[i] as usize;
                    smax[d] = smax[d].max(logits[i]);
                }
            }
            let mut ex = vec![0f32; ne];
            let mut denom = vec![0f32; l];
            for i in 0..ne {
                if edges.ew[i] > 0.0 {
                    let d = edges.dst[i] as usize;
                    ex[i] = (logits[i] - smax[d]).exp();
                    denom[d] += ex[i];
                }
            }
            let mut out = vec![0f32; l * fo];
            for i in 0..ne {
                if ex[i] == 0.0 {
                    continue;
                }
                let d = edges.dst[i] as usize;
                let alpha = ex[i] / denom[d].max(1e-16);
                let zs = &z[edges.src[i] as usize * fo
                    ..(edges.src[i] as usize + 1) * fo];
                let or = &mut out[d * fo..(d + 1) * fo];
                for (o, &x) in or.iter_mut().zip(zs) {
                    *o += alpha * x;
                }
            }
            if !last {
                elu(&mut out);
            }
            out
        }
        _ => unreachable!("model validated above"),
    })
}

/// ASTGCN-lite block, ref semantics (see python/compile/models/astgcn.py).
/// `adj` is dense row-normalized [n, n].
pub fn run_astgcn(weights: &WeightBundle, x: &[f32], n: usize, ft: usize,
                  adj: &[f32]) -> Vec<f32> {
    let w1 = weights.get("l0.w1").unwrap();
    let w2 = weights.get("l0.w2").unwrap();
    let wgc = weights.get("l0.wgc").unwrap();
    let wself = weights.get("l0.wself").unwrap();
    let wout = weights.get("l0.wout").unwrap();
    let bout = weights.get("l0.bout").unwrap();
    let datt = *w1.dims.last().unwrap();
    let hidden = *wgc.dims.last().unwrap();
    let t_out = *wout.dims.last().unwrap();
    let zeros_datt = vec![0f32; datt];
    let z1 = matmul_bias(x, n, ft, &w1.f32_data, datt, &zeros_datt);
    let z2 = matmul_bias(x, n, ft, &w2.f32_data, datt, &zeros_datt);
    let scale = 1.0 / (datt as f32).sqrt();
    // masked row softmax of z1 z2^T
    let mut a_eff = vec![0f32; n * n];
    for r in 0..n {
        let zr = &z1[r * datt..(r + 1) * datt];
        let mut row = vec![f32::NEG_INFINITY; n];
        let mut mx = f32::NEG_INFINITY;
        for c in 0..n {
            if adj[r * n + c] > 0.0 {
                let zc = &z2[c * datt..(c + 1) * datt];
                let s: f32 =
                    zr.iter().zip(zc).map(|(a, b)| a * b).sum::<f32>()
                        * scale;
                row[c] = s;
                mx = mx.max(s);
            }
        }
        if mx == f32::NEG_INFINITY {
            continue;
        }
        let mut denom = 0f32;
        for c in 0..n {
            if row[c] > f32::NEG_INFINITY {
                row[c] = (row[c] - mx).exp();
                denom += row[c];
            } else {
                row[c] = 0.0;
            }
        }
        for c in 0..n {
            a_eff[r * n + c] = adj[r * n + c] * row[c] / denom.max(1e-16);
        }
    }
    let zeros_h = vec![0f32; hidden];
    let hg = matmul_bias(x, n, ft, &wgc.f32_data, hidden, &zeros_h);
    let hs = matmul_bias(x, n, ft, &wself.f32_data, hidden, &zeros_h);
    // h = relu(a_eff @ hg + hs)
    let mut hh = hs;
    for r in 0..n {
        for c in 0..n {
            let a = a_eff[r * n + c];
            if a == 0.0 {
                continue;
            }
            let hgc = &hg[c * hidden..(c + 1) * hidden];
            let hr = &mut hh[r * hidden..(r + 1) * hidden];
            for (o, &x) in hr.iter_mut().zip(hgc) {
                *o += a * x;
            }
        }
    }
    relu(&mut hh);
    matmul_bias(&hh, n, hidden, &wout.f32_data, t_out, &bout.f32_data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::weights::{read_fgw, write_fgw};
    use crate::util::rng::Rng;

    fn bundle(entries: &[(&str, &[usize], &[f32])])
              -> WeightBundle {
        let dir = std::env::temp_dir().join("ref_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("b{}.fgw", entries.len()));
        write_fgw(&p, entries).unwrap();
        read_fgw(&p).unwrap()
    }

    fn chain_edges(n: usize, model: &str) -> EdgeArrays {
        // 0->1->2->...: each vertex v>0 has in-edge from v-1, symmetric
        let mut src = Vec::new();
        let mut dst = Vec::new();
        for v in 0..n - 1 {
            src.push(v as u32);
            dst.push(v as u32 + 1);
            src.push(v as u32 + 1);
            dst.push(v as u32);
        }
        let deg: Vec<f32> = (0..n)
            .map(|v| if v == 0 || v == n - 1 { 1.0 } else { 2.0 })
            .collect();
        let inv_deg = match model {
            "gcn" => deg.iter().map(|d| 1.0 / (d + 1.0)).collect(),
            "sage" => deg.iter().map(|d| 1.0 / d.max(1.0)).collect(),
            _ => vec![1.0; n],
        };
        if model == "gat" {
            for v in 0..n as u32 {
                src.push(v);
                dst.push(v);
            }
        }
        let ew = vec![1.0; src.len()];
        EdgeArrays { src, dst, ew, inv_deg, n, n_local: n }
    }

    #[test]
    fn matmul_bias_matches_manual() {
        let x = [1.0f32, 2.0, 3.0, 4.0]; // 2x2
        let w = [1.0f32, 0.0, 0.0, 1.0]; // identity
        let b = [0.5f32, -0.5];
        let out = matmul_bias(&x, 2, 2, &w, 2, &b);
        assert_eq!(out, vec![1.5, 1.5, 3.5, 3.5]);
    }

    #[test]
    fn gcn_two_vertex_manual_check() {
        // vertices {0,1} connected; h = [[1,0],[0,1]]; W = I; b = 0
        let w = [1.0f32, 0.0, 0.0, 1.0];
        let b = [0.0f32, 0.0];
        let wb = bundle(&[("l0.w", &[2, 2], &w), ("l0.b", &[2], &b)]);
        let edges = EdgeArrays {
            src: vec![0, 1],
            dst: vec![1, 0],
            ew: vec![1.0, 1.0],
            inv_deg: vec![0.5, 0.5],
            n: 2,
            n_local: 2,
        };
        let h = [1.0f32, 0.0, 0.0, 1.0];
        let out = run_layer("gcn", 0, &wb, &h, 2, &edges, true).unwrap();
        // v0: (h1 + h0)/2 = [0.5, 0.5]
        assert_eq!(out, vec![0.5, 0.5, 0.5, 0.5]);
    }

    #[test]
    fn gat_attention_rows_are_convex() {
        let mut rng = Rng::new(3);
        let n = 10;
        let f = 6;
        let w: Vec<f32> = (0..f * f).map(|_| rng.normal_f32(0.0, 0.4)).collect();
        let b = vec![0f32; f];
        let a1: Vec<f32> = (0..f).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let a2: Vec<f32> = (0..f).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let wb = bundle(&[
            ("l0.w", &[f, f], &w),
            ("l0.b", &[f], &b),
            ("l0.a_src", &[f], &a1),
            ("l0.a_dst", &[f], &a2),
        ]);
        let edges = chain_edges(n, "gat");
        let h: Vec<f32> = (0..n * f).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let out = run_layer("gat", 0, &wb, &h, f, &edges, true).unwrap();
        // each output row must lie within the z-range (convex combination)
        let z = matmul_bias(&h, n, f, &w, f, &b);
        for k in 0..f {
            let zmin = (0..n).map(|v| z[v * f + k]).fold(f32::MAX, f32::min);
            let zmax = (0..n).map(|v| z[v * f + k]).fold(f32::MIN, f32::max);
            for v in 0..n {
                let o = out[v * f + k];
                assert!(o >= zmin - 1e-4 && o <= zmax + 1e-4);
            }
        }
    }

    #[test]
    fn sage_concat_order_is_mean_then_self() {
        // single directed edge 0 -> 1 (use asymmetric arrays directly)
        let f = 2;
        // W = [[I];[0]] picks the mean part only
        let mut w = vec![0f32; 2 * f * f];
        w[0] = 1.0; // row 0 (mean dim 0) -> out 0
        w[f + 1] = 1.0; // row 1 (mean dim 1) -> out 1
        let b = vec![0f32; f];
        let wb = bundle(&[("l0.w", &[2 * f, f], &w), ("l0.b", &[f], &b)]);
        let edges = EdgeArrays {
            src: vec![0],
            dst: vec![1],
            ew: vec![1.0],
            inv_deg: vec![1.0, 1.0],
            n: 2,
            n_local: 2,
        };
        let h = [3.0f32, 4.0, 9.0, 9.0];
        let out = run_layer("sage", 0, &wb, &h, f, &edges, true).unwrap();
        // out[1] = mean part = h0
        assert_eq!(&out[2..], &[3.0, 4.0]);
    }

    #[test]
    fn astgcn_shapes_and_finiteness() {
        let mut rng = Rng::new(4);
        let n = 12;
        let ft = 36;
        let mk = |r: usize, c: usize, rng: &mut Rng| -> Vec<f32> {
            (0..r * c).map(|_| rng.normal_f32(0.0, 0.2)).collect()
        };
        let w1 = mk(ft, 16, &mut rng);
        let w2 = mk(ft, 16, &mut rng);
        let wgc = mk(ft, 64, &mut rng);
        let wself = mk(ft, 64, &mut rng);
        let wout = mk(64, 12, &mut rng);
        let bout = vec![0f32; 12];
        let wb = bundle(&[
            ("l0.w1", &[ft, 16], &w1),
            ("l0.w2", &[ft, 16], &w2),
            ("l0.wgc", &[ft, 64], &wgc),
            ("l0.wself", &[ft, 64], &wself),
            ("l0.wout", &[64, 12], &wout),
            ("l0.bout", &[12], &bout),
        ]);
        let x: Vec<f32> = (0..n * ft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        // ring adjacency
        let mut adj = vec![0f32; n * n];
        for v in 0..n {
            adj[v * n + v] = 1.0 / 3.0;
            adj[v * n + (v + 1) % n] = 1.0 / 3.0;
            adj[v * n + (v + n - 1) % n] = 1.0 / 3.0;
        }
        let out = run_astgcn(&wb, &x, n, ft, &adj);
        assert_eq!(out.len(), n * 12);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn isolated_vertex_keeps_self_information() {
        let w = [1.0f32, 0.0, 0.0, 1.0];
        let b = [0.0f32, 0.0];
        let wb = bundle(&[("l0.w", &[2, 2], &w), ("l0.b", &[2], &b)]);
        let edges = EdgeArrays {
            src: vec![],
            dst: vec![],
            ew: vec![],
            inv_deg: vec![1.0],
            n: 1,
            n_local: 1,
        };
        let out =
            run_layer("gcn", 0, &wb, &[2.0, -3.0], 2, &edges, true)
                .unwrap();
        assert_eq!(out, vec![2.0, -3.0]);
    }
}
