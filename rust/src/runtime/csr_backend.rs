//! Sparse CSR execution backend: aggregates directly over the
//! partition's compressed-sparse-row structure instead of padded dense
//! buffers, so memory and work are O(E·F) — never O(V²). Supports true
//! batched execution by stacking a micro-batch of requests into one
//! block-diagonal sparse batch (all blocks share the partition
//! structure, so one CSR drives every block and the feature transform
//! runs as a single stacked GEMM — the amortization the serving loop's
//! power-of-two buckets pay for).
//!
//! The numeric kernels live in `runtime::kernels` (tiled GEMM, blocked
//! SpMM); this module owns the CSR structure, the layer semantics and
//! the per-backend scratch reuse. Masked (zero-weight) edges are
//! dropped once at `CsrPartition::from_edges` instead of branch-checked
//! per edge per layer in the hot loops.
//!
//! Numeric semantics mirror `reference.rs` exactly (same normalization,
//! same activation, same attention masking); cross-backend parity is
//! asserted by `rust/tests/backend_parity.rs` to 1e-5.

use std::collections::HashMap;
use std::sync::Arc;

use crate::graph::LocalGraph;
use crate::obs::clock::Stopwatch;

use super::backend::{ExecBackend, LayerCtx};
use super::engine::{EngineError, LayerOut};
use super::kernels::shard::{split_rows, ShardClosure, ShardExec};
use super::kernels::{gemm_bias, gemm_bias_into, gemm_bias_rows,
                     resized, KernelScratch};
use super::kernels::spmm::{csr_spmm, csr_spmm_into,
                           csr_spmm_rows_into};
use super::pad::{EdgeArrays, UnknownModel};
use super::reference::{elu, relu};
use super::weights::WeightBundle;

/// Destination-indexed CSR view of one partition: row v lists the
/// incoming edges of OWNED vertex v (sources may be halo rows).
#[derive(Clone, Debug)]
pub struct CsrPartition {
    /// Length `n_local + 1`; `col/val[row_ptr[v]..row_ptr[v+1]]` are
    /// the in-edges of owned vertex v.
    pub row_ptr: Vec<usize>,
    /// Source row of each edge, local index space (may be >= n_local).
    pub col: Vec<u32>,
    /// Edge weight; never zero — masked entries are dropped at
    /// construction, so the kernels carry no per-edge mask branch.
    pub val: Vec<f32>,
    /// Per-owned-vertex normalization, length n_local.
    pub inv_deg: Vec<f32>,
    /// Total rows (owned + halo).
    pub n: usize,
    pub n_local: usize,
    /// COO edges this CSR was built from (including dropped masked
    /// edges) — the cache-staleness witness.
    pub n_source_edges: usize,
}

impl CsrPartition {
    /// Counting-sort the COO edge arrays by destination, dropping
    /// `ew == 0` (masked) edges: they contribute nothing to any kernel
    /// — aggregation skips them and the GAT/ASTGCN softmaxes exclude
    /// them — so paying a branch for them per edge per layer in the hot
    /// loop is pure waste.
    pub fn from_edges(edges: &EdgeArrays) -> CsrPartition {
        let l = edges.n_local;
        let ne = edges.num_edges();
        let mut row_ptr = vec![0usize; l + 1];
        for i in 0..ne {
            if edges.ew[i] != 0.0 {
                row_ptr[edges.dst[i] as usize + 1] += 1;
            }
        }
        for v in 0..l {
            row_ptr[v + 1] += row_ptr[v];
        }
        let nnz = row_ptr[l];
        let mut cursor: Vec<usize> = row_ptr[..l].to_vec();
        let mut col = vec![0u32; nnz];
        let mut val = vec![0f32; nnz];
        for i in 0..ne {
            if edges.ew[i] == 0.0 {
                continue;
            }
            let d = edges.dst[i] as usize;
            col[cursor[d]] = edges.src[i];
            val[cursor[d]] = edges.ew[i];
            cursor[d] += 1;
        }
        CsrPartition {
            row_ptr,
            col,
            val,
            inv_deg: edges.inv_deg.clone(),
            n: edges.n,
            n_local: l,
            n_source_edges: ne,
        }
    }

    /// Stored (unmasked) edges; `<= n_source_edges`.
    pub fn num_edges(&self) -> usize {
        self.col.len()
    }
}

/// Sparse weighted in-neighbor aggregation for one block:
/// `agg[v] = Σ_{(u,v)} w · h[u]` over owned rows v (the SpMM core).
/// Delegates to the blocked kernel (`kernels::spmm`).
pub fn csr_aggregate(csr: &CsrPartition, h: &[f32], f: usize)
                     -> Vec<f32> {
    csr_spmm(csr, h, f)
}

/// One message-passing layer over a block-diagonal batch of `batch`
/// requests: `h` is [batch * n, f_in] block-major; the output is
/// [batch * n_local, fo] block-major. `batch == 1` is the single-request
/// forward. Semantics mirror `reference::run_layer`. Allocates a fresh
/// scratch — the steady-state paths (backend, worker pool) hold one and
/// call `run_layer_csr_with`.
#[allow(clippy::too_many_arguments)]
pub fn run_layer_csr(model: &str, layer: usize, weights: &WeightBundle,
                     h: &[f32], f_in: usize, csr: &CsrPartition,
                     last: bool, batch: usize)
                     -> Result<Vec<f32>, UnknownModel> {
    let mut scratch = KernelScratch::default();
    run_layer_csr_with(model, layer, weights, h, f_in, csr, last, batch,
                       &mut scratch)
}

/// `run_layer_csr` with caller-owned scratch buffers: the per-layer
/// intermediates (aggregate, combine input, attention projections)
/// reuse `scratch` instead of allocating per call.
///
/// NOTE: the row-sharded twins (`run_layer_csr_sharded` and friends,
/// below) duplicate this arithmetic — numeric changes must be
/// mirrored there (see the MAINTENANCE INVARIANT comment).
#[allow(clippy::too_many_arguments)]
pub fn run_layer_csr_with(model: &str, layer: usize,
                          weights: &WeightBundle, h: &[f32],
                          f_in: usize, csr: &CsrPartition, last: bool,
                          batch: usize, scratch: &mut KernelScratch)
                          -> Result<Vec<f32>, UnknownModel> {
    if !matches!(model, "gcn" | "sage" | "gat") {
        return Err(UnknownModel(model.to_string()));
    }
    assert!(batch >= 1);
    let n = csr.n;
    let l = csr.n_local;
    debug_assert_eq!(h.len(), batch * n * f_in);
    let w = weights
        .get(&format!("l{layer}.w"))
        .expect("missing weight");
    let b = weights
        .get(&format!("l{layer}.b"))
        .expect("missing bias");
    let fo = *w.dims.last().unwrap();
    Ok(match model {
        "gcn" => {
            let agg = resized(&mut scratch.agg, l * f_in);
            let comb = resized(&mut scratch.comb, batch * l * f_in);
            for bk in 0..batch {
                let hb = &h[bk * n * f_in..(bk + 1) * n * f_in];
                csr_spmm_into(csr, hb, f_in, agg);
                let cb =
                    &mut comb[bk * l * f_in..(bk + 1) * l * f_in];
                for v in 0..l {
                    let s = csr.inv_deg[v];
                    for k in 0..f_in {
                        cb[v * f_in + k] =
                            (agg[v * f_in + k] + hb[v * f_in + k]) * s;
                    }
                }
            }
            let mut out = gemm_bias(comb, batch * l, f_in,
                                    &w.f32_data, fo, &b.f32_data);
            if !last {
                relu(&mut out);
            }
            out
        }
        "sage" => {
            let agg = resized(&mut scratch.agg, l * f_in);
            let comb =
                resized(&mut scratch.comb, batch * l * 2 * f_in);
            for bk in 0..batch {
                let hb = &h[bk * n * f_in..(bk + 1) * n * f_in];
                csr_spmm_into(csr, hb, f_in, agg);
                let cb = &mut comb
                    [bk * l * 2 * f_in..(bk + 1) * l * 2 * f_in];
                for v in 0..l {
                    let s = csr.inv_deg[v];
                    for k in 0..f_in {
                        cb[v * 2 * f_in + k] = agg[v * f_in + k] * s;
                        cb[v * 2 * f_in + f_in + k] =
                            hb[v * f_in + k];
                    }
                }
            }
            let mut out = gemm_bias(comb, batch * l, 2 * f_in,
                                    &w.f32_data, fo, &b.f32_data);
            if !last {
                relu(&mut out);
            }
            out
        }
        "gat" => {
            let a_src = weights
                .get(&format!("l{layer}.a_src"))
                .expect("gat a_src");
            let a_dst = weights
                .get(&format!("l{layer}.a_dst"))
                .expect("gat a_dst");
            // z spans ALL rows of ALL blocks: one stacked GEMM
            let z = resized(&mut scratch.z, batch * n * fo);
            gemm_bias_into(h, batch * n, f_in, &w.f32_data, fo,
                           &b.f32_data, z);
            let dot = |row: usize, a: &[f32]| -> f32 {
                z[row * fo..(row + 1) * fo]
                    .iter()
                    .zip(a)
                    .map(|(x, y)| x * y)
                    .sum()
            };
            let es = resized(&mut scratch.att_src, batch * n);
            for (r, e) in es.iter_mut().enumerate() {
                *e = dot(r, &a_src.f32_data);
            }
            let ed = resized(&mut scratch.att_dst, batch * n);
            for (r, e) in ed.iter_mut().enumerate() {
                *e = dot(r, &a_dst.f32_data);
            }
            let mut out = vec![0f32; batch * l * fo];
            let mut ex: Vec<f32> = Vec::new();
            for bk in 0..batch {
                let off = bk * n;
                for v in 0..l {
                    let lo = csr.row_ptr[v];
                    let hi = csr.row_ptr[v + 1];
                    if lo == hi {
                        continue; // isolated vertex (masked edges are
                                  // dropped at construction)
                    }
                    // segment softmax over the in-edges of v
                    let mut mx = f32::NEG_INFINITY;
                    for e in lo..hi {
                        let x = es[off + csr.col[e] as usize]
                            + ed[off + v];
                        let lg = if x > 0.0 { x } else { 0.2 * x };
                        mx = mx.max(lg);
                    }
                    ex.clear();
                    let mut denom = 0f32;
                    for e in lo..hi {
                        let x = es[off + csr.col[e] as usize]
                            + ed[off + v];
                        let lg = if x > 0.0 { x } else { 0.2 * x };
                        let exv = (lg - mx).exp();
                        ex.push(exv);
                        denom += exv;
                    }
                    let or = &mut out
                        [(bk * l + v) * fo..(bk * l + v + 1) * fo];
                    for (i, e) in (lo..hi).enumerate() {
                        if ex[i] == 0.0 {
                            continue;
                        }
                        let alpha = ex[i] / denom.max(1e-16);
                        let u = off + csr.col[e] as usize;
                        let zs = &z[u * fo..(u + 1) * fo];
                        for (o, &x) in or.iter_mut().zip(zs) {
                            *o += alpha * x;
                        }
                    }
                }
            }
            if !last {
                elu(&mut out);
            }
            out
        }
        _ => unreachable!("model validated above"),
    })
}

// ---- intra-fog row-sharded execution -----------------------------------
//
// The sharded variants below split a layer into deterministic
// contiguous owned-row ranges and execute one closure per range on a
// `ShardExec` (a fog's persistent helper group, or inline for the
// serial oracle), then reduce in fixed range order. Every row kernel
// in `runtime::kernels` is row-decomposition invariant, so sharded
// outputs are bit-identical to the unsharded (`run_layer_csr_with`)
// path for ANY split — asserted by `tests/backend_parity.rs` and the
// `repro bench-kernels` parity gates.
//
// MAINTENANCE INVARIANT: the per-row arithmetic here deliberately
// DUPLICATES `run_layer_csr_with` / `run_astgcn_csr` (the unsharded
// arms keep their zero-allocation KernelScratch hot path, which a
// one-shard delegation would lose). Any numeric change — activation
// slopes, softmax guards, normalization — must be applied to BOTH
// copies, or `--kernel-threads 1` and `> 1` silently diverge; the
// sharded-vs-unsharded bitwise suites in tests/backend_parity.rs are
// the tripwire, so extend them when touching either side.

/// Copy per-owned-row shard outputs (each `[batch * rows, fo]`
/// block-major over its range) into the full `[batch * l, fo]`
/// block-major layer output — the fixed-order reduction.
fn assemble_owned_rows(ranges: &[(usize, usize)],
                       shards: Vec<Vec<f32>>, l: usize, batch: usize,
                       fo: usize) -> Vec<f32> {
    let mut out = vec![0f32; batch * l * fo];
    for (&(v0, v1), sh) in ranges.iter().zip(&shards) {
        let rows = v1 - v0;
        debug_assert_eq!(sh.len(), batch * rows * fo);
        for bk in 0..batch {
            out[(bk * l + v0) * fo..(bk * l + v1) * fo]
                .copy_from_slice(
                    &sh[bk * rows * fo..(bk + 1) * rows * fo],
                );
        }
    }
    out
}

/// One shard of the gcn/sage layer: aggregate + combine + GEMM for
/// owned rows `[v0, v1)` across every batch block.
#[allow(clippy::too_many_arguments)]
fn layer_rows_gcn_sage(sage: bool, layer: usize, wb: &WeightBundle,
                       h: &[f32], f_in: usize, csr: &CsrPartition,
                       last: bool, batch: usize, v0: usize, v1: usize)
                       -> Vec<f32> {
    let n = csr.n;
    let rows = v1 - v0;
    let w = wb.get(&format!("l{layer}.w")).expect("missing weight");
    let b = wb.get(&format!("l{layer}.b")).expect("missing bias");
    let fo = *w.dims.last().unwrap();
    let cw = if sage { 2 * f_in } else { f_in };
    let mut agg = vec![0f32; rows * f_in];
    let mut comb = vec![0f32; batch * rows * cw];
    for bk in 0..batch {
        let hb = &h[bk * n * f_in..(bk + 1) * n * f_in];
        csr_spmm_rows_into(csr, hb, f_in, v0, v1, &mut agg);
        let cb = &mut comb[bk * rows * cw..(bk + 1) * rows * cw];
        for i in 0..rows {
            let s = csr.inv_deg[v0 + i];
            for k in 0..f_in {
                if sage {
                    cb[i * cw + k] = agg[i * f_in + k] * s;
                    cb[i * cw + f_in + k] = hb[(v0 + i) * f_in + k];
                } else {
                    cb[i * cw + k] = (agg[i * f_in + k]
                        + hb[(v0 + i) * f_in + k])
                        * s;
                }
            }
        }
    }
    let mut out = gemm_bias(&comb, batch * rows, cw, &w.f32_data, fo,
                            &b.f32_data);
    if !last {
        relu(&mut out);
    }
    out
}

/// GAT pass 1 shard: projection rows `[r0, r1)` of the flattened
/// `[batch * n]` row space, packed as `z ++ e_src ++ e_dst`.
fn gat_proj_rows(layer: usize, wb: &WeightBundle, h: &[f32],
                 f_in: usize, r0: usize, r1: usize) -> Vec<f32> {
    let w = wb.get(&format!("l{layer}.w")).expect("missing weight");
    let b = wb.get(&format!("l{layer}.b")).expect("missing bias");
    let a_src = wb.get(&format!("l{layer}.a_src")).expect("gat a_src");
    let a_dst = wb.get(&format!("l{layer}.a_dst")).expect("gat a_dst");
    let fo = *w.dims.last().unwrap();
    let rows = r1 - r0;
    let z = gemm_bias_rows(h, f_in, &w.f32_data, fo, &b.f32_data, r0,
                           r1);
    let dot = |i: usize, a: &[f32]| -> f32 {
        z[i * fo..(i + 1) * fo]
            .iter()
            .zip(a)
            .map(|(x, y)| x * y)
            .sum()
    };
    let mut packed = Vec::with_capacity(rows * fo + 2 * rows);
    packed.extend_from_slice(&z);
    for i in 0..rows {
        packed.push(dot(i, &a_src.f32_data));
    }
    for i in 0..rows {
        packed.push(dot(i, &a_dst.f32_data));
    }
    packed
}

/// GAT pass 2 shard: segment softmax + attention combine for owned
/// rows `[v0, v1)` across every batch block (reads the full assembled
/// projections).
#[allow(clippy::too_many_arguments)]
fn gat_combine_rows(z: &[f32], es: &[f32], ed: &[f32],
                    csr: &CsrPartition, fo: usize, last: bool,
                    batch: usize, v0: usize, v1: usize) -> Vec<f32> {
    let n = csr.n;
    let rows = v1 - v0;
    let mut out = vec![0f32; batch * rows * fo];
    let mut ex: Vec<f32> = Vec::new();
    for bk in 0..batch {
        let off = bk * n;
        for v in v0..v1 {
            let lo = csr.row_ptr[v];
            let hi = csr.row_ptr[v + 1];
            if lo == hi {
                continue; // isolated vertex (masked edges are
                          // dropped at construction)
            }
            // segment softmax over the in-edges of v
            let mut mx = f32::NEG_INFINITY;
            for e in lo..hi {
                let x = es[off + csr.col[e] as usize] + ed[off + v];
                let lg = if x > 0.0 { x } else { 0.2 * x };
                mx = mx.max(lg);
            }
            ex.clear();
            let mut denom = 0f32;
            for e in lo..hi {
                let x = es[off + csr.col[e] as usize] + ed[off + v];
                let lg = if x > 0.0 { x } else { 0.2 * x };
                let exv = (lg - mx).exp();
                ex.push(exv);
                denom += exv;
            }
            let or = &mut out[(bk * rows + (v - v0)) * fo
                ..(bk * rows + (v - v0) + 1) * fo];
            for (i, e) in (lo..hi).enumerate() {
                if ex[i] == 0.0 {
                    continue;
                }
                let alpha = ex[i] / denom.max(1e-16);
                let u = off + csr.col[e] as usize;
                let zs = &z[u * fo..(u + 1) * fo];
                for (o, &x) in or.iter_mut().zip(zs) {
                    *o += alpha * x;
                }
            }
        }
    }
    if !last {
        elu(&mut out);
    }
    out
}

/// Row-sharded `run_layer_csr_with`: splits the owned rows into
/// deterministic contiguous ranges and runs them on `shards`
/// (bit-identical to the unsharded path — see the section comment).
/// Inputs are `Arc`-shared so shard closures can run on long-lived
/// helper threads.
#[allow(clippy::too_many_arguments)]
pub fn run_layer_csr_sharded(model: &str, layer: usize,
                             weights: &Arc<WeightBundle>,
                             h: &Arc<Vec<f32>>, f_in: usize,
                             csr: &Arc<CsrPartition>, last: bool,
                             batch: usize, shards: &ShardExec<'_>)
                             -> Result<Vec<f32>, UnknownModel> {
    if !matches!(model, "gcn" | "sage" | "gat") {
        return Err(UnknownModel(model.to_string()));
    }
    assert!(batch >= 1);
    let l = csr.n_local;
    let n = csr.n;
    let w = weights
        .get(&format!("l{layer}.w"))
        .expect("missing weight");
    let fo = *w.dims.last().unwrap();
    Ok(match model {
        "gcn" | "sage" => {
            let sage = model == "sage";
            let ranges =
                split_rows(l, shards.effective_shards(batch * l));
            let closures: Vec<ShardClosure> = ranges
                .iter()
                .map(|&(v0, v1)| {
                    let wb = weights.clone();
                    let h = h.clone();
                    let csr = csr.clone();
                    Box::new(move || {
                        layer_rows_gcn_sage(sage, layer, &wb, &h,
                                            f_in, &csr, last, batch,
                                            v0, v1)
                    }) as ShardClosure
                })
                .collect();
            let outs = shards.run(closures);
            assemble_owned_rows(&ranges, outs, l, batch, fo)
        }
        "gat" => {
            // pass 1: projections over ALL rows of ALL blocks
            let all = batch * n;
            let ranges1 =
                split_rows(all, shards.effective_shards(all));
            let closures: Vec<ShardClosure> = ranges1
                .iter()
                .map(|&(r0, r1)| {
                    let wb = weights.clone();
                    let h = h.clone();
                    Box::new(move || {
                        gat_proj_rows(layer, &wb, &h, f_in, r0, r1)
                    }) as ShardClosure
                })
                .collect();
            let packs = shards.run(closures);
            let mut z = vec![0f32; all * fo];
            let mut es = vec![0f32; all];
            let mut ed = vec![0f32; all];
            for (&(r0, r1), p) in ranges1.iter().zip(&packs) {
                let rows = r1 - r0;
                z[r0 * fo..r1 * fo].copy_from_slice(&p[..rows * fo]);
                es[r0..r1].copy_from_slice(
                    &p[rows * fo..rows * fo + rows],
                );
                ed[r0..r1].copy_from_slice(&p[rows * fo + rows..]);
            }
            let (z, es, ed) =
                (Arc::new(z), Arc::new(es), Arc::new(ed));
            // pass 2: segment softmax + combine over owned rows
            let ranges2 =
                split_rows(l, shards.effective_shards(batch * l));
            let closures: Vec<ShardClosure> = ranges2
                .iter()
                .map(|&(v0, v1)| {
                    let z = z.clone();
                    let es = es.clone();
                    let ed = ed.clone();
                    let csr = csr.clone();
                    Box::new(move || {
                        gat_combine_rows(&z, &es, &ed, &csr, fo,
                                         last, batch, v0, v1)
                    }) as ShardClosure
                })
                .collect();
            let outs = shards.run(closures);
            assemble_owned_rows(&ranges2, outs, l, batch, fo)
        }
        _ => unreachable!("model validated above"),
    })
}

/// ASTGCN pass 1 shard: the four projections for rows `[r0, r1)` of
/// block `bk`, packed as `z1 ++ z2 ++ hg ++ hh`.
#[allow(clippy::too_many_arguments)]
fn astgcn_proj_rows(wb: &WeightBundle, x: &[f32], bk: usize, n: usize,
                    ft: usize, r0: usize, r1: usize) -> Vec<f32> {
    let w1 = wb.get("l0.w1").expect("astgcn w1");
    let w2 = wb.get("l0.w2").expect("astgcn w2");
    let wgc = wb.get("l0.wgc").expect("astgcn wgc");
    let wself = wb.get("l0.wself").expect("astgcn wself");
    let datt = *w1.dims.last().unwrap();
    let hidden = *wgc.dims.last().unwrap();
    let xb = &x[bk * n * ft..(bk + 1) * n * ft];
    let zeros_datt = vec![0f32; datt];
    let zeros_h = vec![0f32; hidden];
    let rows = r1 - r0;
    let mut packed =
        Vec::with_capacity(2 * rows * datt + 2 * rows * hidden);
    packed.extend(gemm_bias_rows(xb, ft, &w1.f32_data, datt,
                                 &zeros_datt, r0, r1));
    packed.extend(gemm_bias_rows(xb, ft, &w2.f32_data, datt,
                                 &zeros_datt, r0, r1));
    packed.extend(gemm_bias_rows(xb, ft, &wgc.f32_data, hidden,
                                 &zeros_h, r0, r1));
    packed.extend(gemm_bias_rows(xb, ft, &wself.f32_data, hidden,
                                 &zeros_h, r0, r1));
    packed
}

/// ASTGCN pass 2 shard: masked-attention combine + ReLU + output GEMM
/// for rows `[r0, r1)` of one block (reads the full assembled
/// projections and the shared in-neighbor lists).
#[allow(clippy::too_many_arguments)]
fn astgcn_combine_rows(wb: &WeightBundle, row_ptr: &[usize],
                       cols: &[u32], z1: &[f32], z2: &[f32],
                       hg: &[f32], hh: &[f32], r0: usize, r1: usize)
                       -> Vec<f32> {
    let w1 = wb.get("l0.w1").expect("astgcn w1");
    let wgc = wb.get("l0.wgc").expect("astgcn wgc");
    let wout = wb.get("l0.wout").expect("astgcn wout");
    let bout = wb.get("l0.bout").expect("astgcn bout");
    let datt = *w1.dims.last().unwrap();
    let hidden = *wgc.dims.last().unwrap();
    let t_out = *wout.dims.last().unwrap();
    let scale = 1.0 / (datt as f32).sqrt();
    let rows = r1 - r0;
    let mut hloc = vec![0f32; rows * hidden];
    let mut support: Vec<u32> = Vec::new();
    let mut scores: Vec<f32> = Vec::new();
    for (i, r) in (r0..r1).enumerate() {
        hloc[i * hidden..(i + 1) * hidden]
            .copy_from_slice(&hh[r * hidden..(r + 1) * hidden]);
        support.clear();
        scores.clear();
        support.extend_from_slice(&cols[row_ptr[r]..row_ptr[r + 1]]);
        support.push(r as u32);
        let zr = &z1[r * datt..(r + 1) * datt];
        let mut mx = f32::NEG_INFINITY;
        for &c in support.iter() {
            let zc = &z2[c as usize * datt..(c as usize + 1) * datt];
            let s: f32 = zr
                .iter()
                .zip(zc)
                .map(|(a, b)| a * b)
                .sum::<f32>()
                * scale;
            scores.push(s);
            mx = mx.max(s);
        }
        let mut denom = 0f32;
        for s in scores.iter_mut() {
            *s = (*s - mx).exp();
            denom += *s;
        }
        // adjacency value is uniform 1/(support size) after the dense
        // row normalization (all entries are 1 before normalizing)
        let adj = 1.0 / support.len() as f32;
        for (&c, &sc) in support.iter().zip(scores.iter()) {
            let a = adj * sc / denom.max(1e-16);
            if a == 0.0 {
                continue;
            }
            let hgc =
                &hg[c as usize * hidden..(c as usize + 1) * hidden];
            let hr = &mut hloc[i * hidden..(i + 1) * hidden];
            for (o, &xv) in hr.iter_mut().zip(hgc) {
                *o += a * xv;
            }
        }
    }
    relu(&mut hloc);
    gemm_bias(&hloc, rows, hidden, &wout.f32_data, t_out,
              &bout.f32_data)
}

/// Row-sharded `run_astgcn_csr` over a block-diagonal batch:
/// per block, the four projections then the attention combine run as
/// row-range shards on `shards` (bit-identical to the per-block
/// unsharded path). Output stacks `[n, t_out]` blocks like the serial
/// loop over `run_astgcn_csr`. `nbr` is the partition's cached
/// in-neighbor structure (`in_neighbor_lists`) — placement-invariant,
/// so callers build it once per plan, never inside timed kernel
/// regions.
pub fn run_astgcn_csr_sharded(weights: &Arc<WeightBundle>,
                              x: &Arc<Vec<f32>>, n: usize, ft: usize,
                              nbr: &Arc<InNbrLists>, batch: usize,
                              shards: &ShardExec<'_>) -> Vec<f32> {
    let w1 = weights.get("l0.w1").expect("astgcn w1");
    let wgc = weights.get("l0.wgc").expect("astgcn wgc");
    let wout = weights.get("l0.wout").expect("astgcn wout");
    let datt = *w1.dims.last().unwrap();
    let hidden = *wgc.dims.last().unwrap();
    let t_out = *wout.dims.last().unwrap();
    let ranges = split_rows(n, shards.effective_shards(n));
    let mut out = vec![0f32; batch * n * t_out];
    for bk in 0..batch {
        let closures: Vec<ShardClosure> = ranges
            .iter()
            .map(|&(r0, r1)| {
                let wb = weights.clone();
                let x = x.clone();
                Box::new(move || {
                    astgcn_proj_rows(&wb, &x, bk, n, ft, r0, r1)
                }) as ShardClosure
            })
            .collect();
        let packs = shards.run(closures);
        let mut z1 = vec![0f32; n * datt];
        let mut z2 = vec![0f32; n * datt];
        let mut hg = vec![0f32; n * hidden];
        let mut hh = vec![0f32; n * hidden];
        for (&(r0, r1), p) in ranges.iter().zip(&packs) {
            let rows = r1 - r0;
            let (d, h2) = (rows * datt, rows * hidden);
            z1[r0 * datt..r1 * datt].copy_from_slice(&p[..d]);
            z2[r0 * datt..r1 * datt]
                .copy_from_slice(&p[d..2 * d]);
            hg[r0 * hidden..r1 * hidden]
                .copy_from_slice(&p[2 * d..2 * d + h2]);
            hh[r0 * hidden..r1 * hidden]
                .copy_from_slice(&p[2 * d + h2..]);
        }
        let (z1, z2, hg, hh) =
            (Arc::new(z1), Arc::new(z2), Arc::new(hg), Arc::new(hh));
        let closures: Vec<ShardClosure> = ranges
            .iter()
            .map(|&(r0, r1)| {
                let wb = weights.clone();
                let nbr = nbr.clone();
                let z1 = z1.clone();
                let z2 = z2.clone();
                let hg = hg.clone();
                let hh = hh.clone();
                Box::new(move || {
                    astgcn_combine_rows(&wb, &nbr.0, &nbr.1, &z1,
                                        &z2, &hg, &hh, r0, r1)
                }) as ShardClosure
            })
            .collect();
        for (&(r0, r1), sh) in
            ranges.iter().zip(shards.run(closures))
        {
            out[(bk * n + r0) * t_out..(bk * n + r1) * t_out]
                .copy_from_slice(&sh);
        }
    }
    out
}

/// ASTGCN's cached per-partition structure: dst-grouped in-neighbor
/// lists `(row_ptr, cols)` over ALL rows. Placement-invariant, like
/// `CsrPartition` — the batched plan builds one per fog at
/// construction so the per-batch hot path (and its measured timings)
/// never pays the O(V + E) counting sort.
pub type InNbrLists = (Vec<usize>, Vec<u32>);

/// dst-grouped in-neighbor lists over ALL rows of a partition (halo
/// rows have no in-edges in the local COO; their support is the self
/// loop alone) — shared by the unsharded and sharded ASTGCN paths.
pub fn in_neighbor_lists(sub: &LocalGraph, n: usize) -> InNbrLists {
    let ne = sub.num_edges();
    let mut row_ptr = vec![0usize; n + 1];
    for &d in &sub.dst {
        row_ptr[d as usize + 1] += 1;
    }
    for r in 0..n {
        row_ptr[r + 1] += row_ptr[r];
    }
    let mut cols = vec![0u32; ne];
    let mut cursor: Vec<usize> = row_ptr[..n].to_vec();
    for i in 0..ne {
        let d = sub.dst[i] as usize;
        cols[cursor[d]] = sub.src[i];
        cursor[d] += 1;
    }
    (row_ptr, cols)
}

/// ASTGCN block with sparse masked attention: row r's support is its
/// in-neighbors plus itself, each adjacency entry 1/(indeg_r + 1) —
/// exactly the rows of `pad::dense_norm_adj`, never materialized
/// densely. Output covers all `n` rows, like the dense path. Assumes
/// the simple-graph invariants of `Graph::from_undirected_edges`
/// (no self loops, no duplicate edges), which every LocalGraph holds.
pub fn run_astgcn_csr(weights: &WeightBundle, x: &[f32], n: usize,
                      ft: usize, sub: &LocalGraph) -> Vec<f32> {
    run_astgcn_csr_cached(weights, x, n, ft,
                          &in_neighbor_lists(sub, n))
}

/// `run_astgcn_csr` with the partition's in-neighbor lists supplied by
/// the caller — the hot-path entry: `BatchedBspPlan` builds the lists
/// once per fog at construction, so measured per-batch timings pay
/// only the kernel, never the O(V + E) counting sort.
pub fn run_astgcn_csr_cached(weights: &WeightBundle, x: &[f32],
                             n: usize, ft: usize, nbr: &InNbrLists)
                             -> Vec<f32> {
    let (row_ptr, cols) = nbr;
    let w1 = weights.get("l0.w1").expect("astgcn w1");
    let w2 = weights.get("l0.w2").expect("astgcn w2");
    let wgc = weights.get("l0.wgc").expect("astgcn wgc");
    let wself = weights.get("l0.wself").expect("astgcn wself");
    let wout = weights.get("l0.wout").expect("astgcn wout");
    let bout = weights.get("l0.bout").expect("astgcn bout");
    let datt = *w1.dims.last().unwrap();
    let hidden = *wgc.dims.last().unwrap();
    let t_out = *wout.dims.last().unwrap();

    let zeros_datt = vec![0f32; datt];
    let z1 = gemm_bias(x, n, ft, &w1.f32_data, datt, &zeros_datt);
    let z2 = gemm_bias(x, n, ft, &w2.f32_data, datt, &zeros_datt);
    let scale = 1.0 / (datt as f32).sqrt();
    let zeros_h = vec![0f32; hidden];
    let hg = gemm_bias(x, n, ft, &wgc.f32_data, hidden, &zeros_h);
    let mut hh = gemm_bias(x, n, ft, &wself.f32_data, hidden,
                           &zeros_h);

    // per row: masked attention softmax over {in(r), r}, then the
    // normalized sparse combine hh_r += Σ_c a_eff[r][c] · hg_c
    let mut support: Vec<u32> = Vec::new();
    let mut scores: Vec<f32> = Vec::new();
    for r in 0..n {
        support.clear();
        scores.clear();
        support.extend_from_slice(&cols[row_ptr[r]..row_ptr[r + 1]]);
        support.push(r as u32);
        let zr = &z1[r * datt..(r + 1) * datt];
        let mut mx = f32::NEG_INFINITY;
        for &c in support.iter() {
            let zc = &z2[c as usize * datt..(c as usize + 1) * datt];
            let s: f32 = zr
                .iter()
                .zip(zc)
                .map(|(a, b)| a * b)
                .sum::<f32>()
                * scale;
            scores.push(s);
            mx = mx.max(s);
        }
        let mut denom = 0f32;
        for s in scores.iter_mut() {
            *s = (*s - mx).exp();
            denom += *s;
        }
        // adjacency value is uniform 1/(support size) after the dense
        // row normalization (all entries are 1 before normalizing)
        let adj = 1.0 / support.len() as f32;
        let hr_base = r * hidden;
        for (&c, &sc) in support.iter().zip(scores.iter()) {
            let a = adj * sc / denom.max(1e-16);
            if a == 0.0 {
                continue;
            }
            let hgc =
                &hg[c as usize * hidden..(c as usize + 1) * hidden];
            let hr = &mut hh[hr_base..hr_base + hidden];
            for (o, &xv) in hr.iter_mut().zip(hgc) {
                *o += a * xv;
            }
        }
    }
    relu(&mut hh);
    gemm_bias(&hh, n, hidden, &wout.f32_data, t_out, &bout.f32_data)
}

/// Structural fingerprint of the edge arrays — the CSR cache key. FNV-1a
/// over (n, n_local, src, dst, ew, inv_deg) so any change to the
/// partition view rebuilds the CSR.
fn fingerprint(edges: &EdgeArrays) -> u64 {
    const P: u64 = 0x0000_0100_0000_01b3;
    let eat = |x: u64, v: u64| (x ^ v).wrapping_mul(P);
    let mut x = eat(0xcbf2_9ce4_8422_2325, edges.n as u64);
    x = eat(x, edges.n_local as u64);
    for i in 0..edges.num_edges() {
        x = eat(x, ((edges.src[i] as u64) << 32) | edges.dst[i] as u64);
        x = eat(x, edges.ew[i].to_bits() as u64);
    }
    for &d in &edges.inv_deg {
        x = eat(x, d.to_bits() as u64);
    }
    x
}

/// Entries kept in the CSR cache before it resets — bounds memory when
/// a long-running loop keeps migrating partitions (each distinct
/// partition shape is one O(E) entry).
const CSR_CACHE_CAP: usize = 64;

/// The sparse backend: caches one `CsrPartition` per partition
/// fingerprint (the analogue of the PJRT per-bucket executable cache),
/// so the steady-state request path pays one O(E) fingerprint scan
/// plus the O(E·F) SpMM — never the O(E log E + scatter) rebuild.
/// Holds one `KernelScratch`, so per-layer intermediates reuse buffers
/// across requests. (The astgcn path groups edges per call instead;
/// its cost is dominated by the four dense feature transforms.)
#[derive(Debug, Default)]
pub struct CsrBackend {
    cache: HashMap<u64, CsrPartition>,
    scratch: KernelScratch,
}

impl CsrBackend {
    pub fn new() -> CsrBackend {
        CsrBackend::default()
    }

    fn partition<'a>(cache: &'a mut HashMap<u64, CsrPartition>,
                     edges: &EdgeArrays) -> &'a CsrPartition {
        let key = fingerprint(edges);
        // structural verification on hit (also in release): a 64-bit
        // fingerprint collision must rebuild, never silently compute
        // over the wrong partition
        let stale = cache.get(&key).is_some_and(|c| {
            c.n != edges.n
                || c.n_local != edges.n_local
                || c.n_source_edges != edges.num_edges()
        });
        if stale {
            cache.remove(&key);
        } else if !cache.contains_key(&key)
            && cache.len() >= CSR_CACHE_CAP
        {
            cache.clear();
        }
        cache
            .entry(key)
            .or_insert_with(|| CsrPartition::from_edges(edges))
    }
}

impl ExecBackend for CsrBackend {
    fn name(&self) -> &'static str {
        "csr"
    }

    fn run_layer(&mut self, ctx: &LayerCtx<'_>, h: &[f32],
                 edges: &EdgeArrays) -> Result<LayerOut, EngineError> {
        self.run_layer_batched(ctx, h, edges, 1)
    }

    fn run_layer_batched(&mut self, ctx: &LayerCtx<'_>, h: &[f32],
                         edges: &EdgeArrays, batch: usize)
                         -> Result<LayerOut, EngineError> {
        let CsrBackend { cache, scratch } = self;
        let csr = CsrBackend::partition(cache, edges);
        let t = Stopwatch::start();
        let out = run_layer_csr_with(ctx.model, ctx.layer, ctx.weights,
                                     h, ctx.f_in, csr, ctx.last, batch,
                                     scratch)?;
        let host = t.elapsed_s();
        let out_dim = out.len() / (batch * csr.n_local).max(1);
        Ok(LayerOut { h: out, out_dim, host_seconds: host })
    }

    fn run_astgcn(&mut self, ctx: &LayerCtx<'_>, x: &[f32], n: usize,
                  sub: &LocalGraph) -> Result<LayerOut, EngineError> {
        let t = Stopwatch::start();
        let out = run_astgcn_csr(ctx.weights, x, n, ctx.f_in, sub);
        let host = t.elapsed_s();
        let out_dim = out.len() / n.max(1);
        Ok(LayerOut { h: out, out_dim, host_seconds: host })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference;
    use crate::runtime::weights::{read_fgw, write_fgw};

    fn bundle(entries: &[(&str, &[usize], &[f32])]) -> WeightBundle {
        let dir = std::env::temp_dir().join("csr_backend_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("b{}.fgw", entries.len()));
        write_fgw(&p, entries).unwrap();
        read_fgw(&p).unwrap()
    }

    fn ring_edges(n: usize) -> EdgeArrays {
        let mut src = Vec::new();
        let mut dst = Vec::new();
        for v in 0..n as u32 {
            let nu = n as u32;
            src.push((v + 1) % nu);
            dst.push(v);
            src.push((v + nu - 1) % nu);
            dst.push(v);
        }
        let inv_deg = vec![1.0 / 3.0; n];
        let ew = vec![1.0; src.len()];
        EdgeArrays { src, dst, ew, inv_deg, n, n_local: n }
    }

    #[test]
    fn csr_build_groups_by_destination() {
        let e = ring_edges(5);
        let csr = CsrPartition::from_edges(&e);
        assert_eq!(csr.num_edges(), e.num_edges());
        assert_eq!(csr.n_source_edges, e.num_edges());
        for v in 0..5usize {
            let lo = csr.row_ptr[v];
            let hi = csr.row_ptr[v + 1];
            assert_eq!(hi - lo, 2, "ring vertex has 2 in-edges");
            let mut ins: Vec<u32> = csr.col[lo..hi].to_vec();
            ins.sort_unstable();
            let mut want = vec![
                ((v + 1) % 5) as u32,
                ((v + 4) % 5) as u32,
            ];
            want.sort_unstable();
            assert_eq!(ins, want);
        }
    }

    #[test]
    fn masked_edges_dropped_at_construction() {
        let mut e = ring_edges(5);
        e.ew[3] = 0.0;
        e.ew[7] = 0.0;
        let csr = CsrPartition::from_edges(&e);
        assert_eq!(csr.num_edges(), e.num_edges() - 2);
        assert_eq!(csr.n_source_edges, e.num_edges());
        assert!(csr.val.iter().all(|&w| w != 0.0));
        // aggregation still matches the masked COO reference semantics
        let f = 3;
        let mut rng = crate::util::rng::Rng::new(31);
        let h: Vec<f32> =
            (0..5 * f).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let a = csr_aggregate(&csr, &h, f);
        let b = reference::segment_aggregate(&h, f, &e, 5);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn csr_aggregate_matches_segment_aggregate() {
        let e = ring_edges(6);
        let csr = CsrPartition::from_edges(&e);
        let f = 3;
        let mut rng = crate::util::rng::Rng::new(2);
        let h: Vec<f32> =
            (0..6 * f).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let a = csr_aggregate(&csr, &h, f);
        let b = reference::segment_aggregate(&h, f, &e, 6);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn gcn_csr_matches_reference_layer() {
        let e = ring_edges(6);
        let csr = CsrPartition::from_edges(&e);
        let f = 4;
        let mut rng = crate::util::rng::Rng::new(3);
        let w: Vec<f32> =
            (0..f * f).map(|_| rng.normal_f32(0.0, 0.4)).collect();
        let b = vec![0f32; f];
        let wb = bundle(&[("l0.w", &[f, f], &w), ("l0.b", &[f], &b)]);
        let h: Vec<f32> =
            (0..6 * f).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let a = run_layer_csr("gcn", 0, &wb, &h, f, &csr, true, 1)
            .unwrap();
        let r = reference::run_layer("gcn", 0, &wb, &h, f, &e, true)
            .unwrap();
        for (x, y) in a.iter().zip(&r) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn batched_blocks_equal_independent_runs() {
        let e = ring_edges(5);
        let csr = CsrPartition::from_edges(&e);
        let f = 3;
        let mut rng = crate::util::rng::Rng::new(7);
        let w: Vec<f32> =
            (0..f * f).map(|_| rng.normal_f32(0.0, 0.4)).collect();
        let b = vec![0f32; f];
        let wb = bundle(&[("l0.w", &[f, f], &w), ("l0.b", &[f], &b)]);
        let h: Vec<f32> =
            (0..3 * 5 * f).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let stacked =
            run_layer_csr("gcn", 0, &wb, &h, f, &csr, false, 3)
                .unwrap();
        for bk in 0..3 {
            let one = run_layer_csr(
                "gcn", 0, &wb, &h[bk * 5 * f..(bk + 1) * 5 * f], f,
                &csr, false, 1,
            )
            .unwrap();
            assert_eq!(&stacked[bk * 5 * f..(bk + 1) * 5 * f], &one[..]);
        }
    }

    #[test]
    fn scratch_reuse_does_not_change_results() {
        let e = ring_edges(7);
        let csr = CsrPartition::from_edges(&e);
        let f = 4;
        let mut rng = crate::util::rng::Rng::new(8);
        let w: Vec<f32> =
            (0..2 * f * f).map(|_| rng.normal_f32(0.0, 0.4)).collect();
        let b = vec![0f32; f];
        let wb =
            bundle(&[("l0.w", &[2 * f, f], &w), ("l0.b", &[f], &b)]);
        let mut scratch = KernelScratch::default();
        let h1: Vec<f32> =
            (0..7 * f).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let h2: Vec<f32> =
            (0..2 * 7 * f).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        // interleave shapes so stale scratch contents would corrupt
        // results if any kernel read-before-write survived
        let a1 = run_layer_csr_with("sage", 0, &wb, &h1, f, &csr, false,
                                    1, &mut scratch)
            .unwrap();
        let a2 = run_layer_csr_with("sage", 0, &wb, &h2, f, &csr, false,
                                    2, &mut scratch)
            .unwrap();
        let b1 = run_layer_csr("sage", 0, &wb, &h1, f, &csr, false, 1)
            .unwrap();
        let b2 = run_layer_csr("sage", 0, &wb, &h2, f, &csr, false, 2)
            .unwrap();
        assert_eq!(a1, b1);
        assert_eq!(a2, b2);
    }

    #[test]
    fn unknown_model_is_an_error() {
        let e = ring_edges(3);
        let csr = CsrPartition::from_edges(&e);
        let wb = WeightBundle::default();
        let r = run_layer_csr("mlp", 0, &wb, &[0.0; 3], 1, &csr, true, 1);
        assert!(r.is_err());
    }

    #[test]
    fn fingerprint_distinguishes_structures() {
        let a = ring_edges(6);
        let mut b = ring_edges(6);
        b.src[0] = 3;
        assert_ne!(fingerprint(&a), fingerprint(&b));
        assert_eq!(fingerprint(&a), fingerprint(&ring_edges(6)));
    }
}
