//! Model-specific edge preparation + bucket padding — the Rust twin of
//! python/compile/prep.py (the conventions MUST match, since the Python
//! side trained the weights and lowered the HLO):
//!
//! - gcn:  no self loops; inv_deg = 1 / (deg_in + 1)
//! - sage: no self loops; inv_deg = 1 / max(deg_in, 1)
//! - gat:  self loops appended AFTER real edges; inv_deg = 1 (unused)
//!
//! Padding invariants (asserted by python/tests/test_models.py::
//! test_padding_rows_do_not_affect_real_rows): padded edges carry ew = 0
//! and endpoints 0; padded vertex rows are zeros with inv_deg = 1.

use crate::graph::LocalGraph;

/// Error for a model name the edge-preparation layer does not know.
/// Surfaces to the CLI as an exit-code-2 error instead of a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UnknownModel(pub String);

impl std::fmt::Display for UnknownModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "unknown model {}", self.0)
    }
}

impl std::error::Error for UnknownModel {}

/// Unpadded per-partition edge arrays in local index space.
#[derive(Clone, Debug)]
pub struct EdgeArrays {
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
    pub ew: Vec<f32>,
    /// Per-OWNED-vertex normalization, length n_local (flattened [l, 1]).
    pub inv_deg: Vec<f32>,
    /// Total rows (owned + halo).
    pub n: usize,
    /// Owned rows; layer outputs cover exactly these.
    pub n_local: usize,
}

impl EdgeArrays {
    pub fn num_edges(&self) -> usize {
        self.src.len()
    }
}

/// Build edge arrays for `model` from a halo-extracted local graph.
/// Degrees use the GLOBAL in-degree (the normalization the model was
/// trained with), which LocalGraph carries.
pub fn prep_edges(model: &str, sub: &LocalGraph)
                  -> Result<EdgeArrays, UnknownModel> {
    let n = sub.n_total();
    let l = sub.n_local;
    let mut src = sub.src.clone();
    let mut dst = sub.dst.clone();
    Ok(match model {
        "gat" => {
            // self loops for OWNED rows only (halo rows produce no output)
            for v in 0..l as u32 {
                src.push(v);
                dst.push(v);
            }
            let ew = vec![1.0; src.len()];
            EdgeArrays { src, dst, ew, inv_deg: vec![1.0; l], n,
                         n_local: l }
        }
        "gcn" => {
            let ew = vec![1.0; src.len()];
            let inv_deg = sub
                .global_degree[..l]
                .iter()
                .map(|&d| 1.0 / (d as f32 + 1.0))
                .collect();
            EdgeArrays { src, dst, ew, inv_deg, n, n_local: l }
        }
        "sage" => {
            let ew = vec![1.0; src.len()];
            let inv_deg = sub
                .global_degree[..l]
                .iter()
                .map(|&d| 1.0 / (d as f32).max(1.0))
                .collect();
            EdgeArrays { src, dst, ew, inv_deg, n, n_local: l }
        }
        other => return Err(UnknownModel(other.to_string())),
    })
}

/// Bucket-padded layer inputs, ready to become PJRT literals.
#[derive(Clone, Debug)]
pub struct PaddedLayer {
    pub h: Vec<f32>,       // [v_max, f_in]
    pub src: Vec<i32>,     // [e_max]
    pub dst: Vec<i32>,     // [e_max]
    pub ew: Vec<f32>,      // [e_max]
    pub inv_deg: Vec<f32>, // [l_max]
    pub v_max: usize,
    pub e_max: usize,
    pub l_max: usize,
    pub f_in: usize,
}

pub fn pad_layer(h: &[f32], n: usize, f_in: usize, edges: &EdgeArrays,
                 v_max: usize, e_max: usize, l_max: usize) -> PaddedLayer {
    assert!(n <= v_max, "{n} > bucket v_max {v_max}");
    assert!(edges.n_local <= l_max,
            "{} > bucket l_max {l_max}", edges.n_local);
    assert!(edges.num_edges() <= e_max,
            "{} > bucket e_max {e_max}", edges.num_edges());
    assert_eq!(h.len(), n * f_in);
    let mut hp = vec![0f32; v_max * f_in];
    hp[..n * f_in].copy_from_slice(h);
    let mut src = vec![0i32; e_max];
    let mut dst = vec![0i32; e_max];
    let mut ew = vec![0f32; e_max];
    for (i, (&s, (&d, &w))) in edges
        .src
        .iter()
        .zip(edges.dst.iter().zip(edges.ew.iter()))
        .enumerate()
    {
        src[i] = s as i32;
        dst[i] = d as i32;
        ew[i] = w;
    }
    let mut inv_deg = vec![1f32; l_max];
    inv_deg[..edges.n_local].copy_from_slice(&edges.inv_deg);
    PaddedLayer { h: hp, src, dst, ew, inv_deg, v_max, e_max, l_max, f_in }
}

/// Upper bound on the dense adjacency build: above this many rows the
/// O(v_max²) f32 buffer crosses the 64 MiB line and would silently eat
/// gigabytes on large sweeps. Callers get a sizing error instead; the
/// sparse CSR backend (`--engine csr`) has no dense-adjacency path at
/// all and serves any size.
pub const DENSE_ADJ_MAX_VERTICES: usize = 4096;

/// Sizing error from `dense_norm_adj`: the requested dense block would
/// exceed the `DENSE_ADJ_MAX_VERTICES` guard.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DenseAdjTooLarge {
    pub v_max: usize,
}

impl std::fmt::Display for DenseAdjTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "dense adjacency of {} rows exceeds the {}-row guard \
             (O(v²) allocation); use the sparse backend (--engine csr)",
            self.v_max, DENSE_ADJ_MAX_VERTICES
        )
    }
}

impl std::error::Error for DenseAdjTooLarge {}

/// Dense row-normalized D⁻¹(A+I) adjacency block for astgcn, padded to
/// v_max (padded rows/cols zero). Errors above the O(v²) sizing guard
/// instead of allocating unbounded memory.
pub fn dense_norm_adj(sub: &LocalGraph, v_max: usize)
                      -> Result<Vec<f32>, DenseAdjTooLarge> {
    if v_max > DENSE_ADJ_MAX_VERTICES {
        return Err(DenseAdjTooLarge { v_max });
    }
    let n = sub.n_total();
    assert!(n <= v_max);
    let mut a = vec![0f32; v_max * v_max];
    for (&s, &d) in sub.src.iter().zip(sub.dst.iter()) {
        a[d as usize * v_max + s as usize] = 1.0;
    }
    for v in 0..n {
        a[v * v_max + v] = 1.0;
    }
    for r in 0..n {
        let row = &mut a[r * v_max..r * v_max + n];
        let sum: f32 = row.iter().sum();
        if sum > 0.0 {
            for x in row.iter_mut() {
                *x /= sum;
            }
        }
    }
    Ok(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{subgraph, Graph};

    fn sub() -> LocalGraph {
        let g = Graph::from_undirected_edges(
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4)],
        );
        subgraph::extract_one(&g, &[1, 2])
    }

    #[test]
    fn gcn_inv_deg_uses_global_degree() {
        let s = sub();
        let e = prep_edges("gcn", &s).unwrap();
        // vertex 1 and 2 both have global degree 2 -> 1/3
        assert!((e.inv_deg[0] - 1.0 / 3.0).abs() < 1e-6);
        assert_eq!(e.num_edges(), s.num_edges());
        assert!(e.ew.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn gat_appends_self_loops() {
        let s = sub();
        let e = prep_edges("gat", &s).unwrap();
        assert_eq!(e.num_edges(), s.num_edges() + s.n_local);
        let last = e.num_edges() - 1;
        assert_eq!(e.src[last], e.dst[last]);
        assert!(e.inv_deg.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn sage_inv_deg_floors_at_one() {
        let g = Graph::from_undirected_edges(3, &[(0, 1)]);
        let s = subgraph::extract_one(&g, &[0, 2]); // vertex 2 isolated
        let e = prep_edges("sage", &s).unwrap();
        assert!((e.inv_deg[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn padding_layout() {
        let s = sub();
        let e = prep_edges("gcn", &s).unwrap();
        let n = s.n_total();
        let h: Vec<f32> = (0..n * 3).map(|x| x as f32).collect();
        let p = pad_layer(&h, n, 3, &e, 8, 16, 8);
        assert_eq!(p.h.len(), 24);
        assert_eq!(&p.h[..n * 3], &h[..]);
        assert!(p.h[n * 3..].iter().all(|&x| x == 0.0));
        assert!(p.ew[e.num_edges()..].iter().all(|&w| w == 0.0));
        assert!(p.inv_deg[e.n_local..].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn unknown_model_is_an_error_not_a_panic() {
        let s = sub();
        let e = prep_edges("transformer", &s);
        assert_eq!(e.unwrap_err(),
                   UnknownModel("transformer".to_string()));
    }

    #[test]
    #[should_panic(expected = "bucket v_max")]
    fn pad_rejects_overflow() {
        let s = sub();
        let e = prep_edges("gcn", &s).unwrap();
        let h = vec![0f32; s.n_total() * 3];
        pad_layer(&h, s.n_total(), 3, &e, 2, 16, 2);
    }

    #[test]
    fn dense_adj_refuses_oversized_blocks() {
        let s = sub();
        let err = dense_norm_adj(&s, DENSE_ADJ_MAX_VERTICES + 1);
        assert_eq!(
            err.unwrap_err(),
            DenseAdjTooLarge { v_max: DENSE_ADJ_MAX_VERTICES + 1 }
        );
    }

    #[test]
    fn dense_adj_rows_normalized() {
        let s = sub();
        let adj = dense_norm_adj(&s, 6).unwrap();
        let n = s.n_total();
        for r in 0..n {
            let sum: f32 = adj[r * 6..r * 6 + 6].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {r} sums to {sum}");
        }
        // padded rows are zero
        assert!(adj[n * 6..].iter().all(|&x| x == 0.0));
    }
}
