//! `.fgw` weight-bundle loader — byte-compatible with
//! python/compile/fgio.py::write_fgw (the training pipeline's output).

use std::collections::HashMap;
use std::fs;
use std::path::Path;

#[derive(Debug)]
pub enum FgwError {
    Io(std::io::Error),
    BadMagic,
    Truncated,
    BadDtype(u8),
    Missing(String),
}

impl std::fmt::Display for FgwError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FgwError::Io(e) => write!(f, "io: {e}"),
            FgwError::BadMagic => {
                write!(f, "bad magic (not a .fgw file)")
            }
            FgwError::Truncated => write!(f, "truncated file"),
            FgwError::BadDtype(d) => write!(f, "unknown dtype {d}"),
            FgwError::Missing(n) => write!(f, "missing tensor {n}"),
        }
    }
}

impl std::error::Error for FgwError {}

impl From<std::io::Error> for FgwError {
    fn from(e: std::io::Error) -> Self {
        FgwError::Io(e)
    }
}

/// A named dense tensor (f32 or i32 payload).
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub f32_data: Vec<f32>,
    pub i32_data: Vec<i32>,
}

impl Tensor {
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// An ordered, name-indexed weight bundle.
#[derive(Clone, Debug, Default)]
pub struct WeightBundle {
    pub tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl WeightBundle {
    pub fn get(&self, name: &str) -> Result<&Tensor, FgwError> {
        self.index
            .get(name)
            .map(|&i| &self.tensors[i])
            .ok_or_else(|| FgwError::Missing(name.to_string()))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }
}

pub fn read_fgw(path: &Path) -> Result<WeightBundle, FgwError> {
    let buf = fs::read(path)?;
    if buf.len() < 8 || &buf[..4] != b"FGW1" {
        return Err(FgwError::BadMagic);
    }
    let mut pos = 4usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8], FgwError> {
        if *pos + n > buf.len() {
            return Err(FgwError::Truncated);
        }
        let s = &buf[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let n_tensors =
        u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let mut bundle = WeightBundle::default();
    for _ in 0..n_tensors {
        let name_len =
            u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap())
                as usize;
        let name = String::from_utf8_lossy(take(&mut pos, name_len)?)
            .into_owned();
        let meta = take(&mut pos, 2)?;
        let (dtype, ndim) = (meta[0], meta[1] as usize);
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(u64::from_le_bytes(
                take(&mut pos, 8)?.try_into().unwrap(),
            ) as usize);
        }
        let count: usize = dims.iter().product::<usize>().max(
            if ndim == 0 { 1 } else { 0 },
        );
        let raw = take(&mut pos, count * 4)?;
        let mut t = Tensor {
            name: name.clone(),
            dims,
            f32_data: Vec::new(),
            i32_data: Vec::new(),
        };
        match dtype {
            0 => {
                t.f32_data = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            }
            1 => {
                t.i32_data = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            }
            d => return Err(FgwError::BadDtype(d)),
        }
        bundle.index.insert(name, bundle.tensors.len());
        bundle.tensors.push(t);
    }
    Ok(bundle)
}

/// Writer (tests + emitting random-init bundles when training is skipped).
pub fn write_fgw(path: &Path, tensors: &[(&str, &[usize], &[f32])])
                 -> Result<(), FgwError> {
    let mut out: Vec<u8> = Vec::new();
    out.extend_from_slice(b"FGW1");
    out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, dims, data) in tensors {
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.push(0u8); // f32
        out.push(dims.len() as u8);
        for d in *dims {
            out.extend_from_slice(&(*d as u64).to_le_bytes());
        }
        for x in *data {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_via_own_writer() {
        let dir = std::env::temp_dir().join("fgw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.fgw");
        let w = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = vec![0.5f32, -0.5];
        write_fgw(&p, &[("l0.w", &[3, 2], &w), ("l0.b", &[2], &b)]).unwrap();
        let bundle = read_fgw(&p).unwrap();
        assert_eq!(bundle.tensors.len(), 2);
        let t = bundle.get("l0.w").unwrap();
        assert_eq!(t.dims, vec![3, 2]);
        assert_eq!(t.f32_data, w);
        assert!(bundle.get("l9.w").is_err());
        assert!(bundle.contains("l0.b"));
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("fgw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.fgw");
        std::fs::write(&p, b"NOTFGW__").unwrap();
        assert!(matches!(read_fgw(&p), Err(FgwError::BadMagic)));
    }
}
