//! Bulk-Synchronous-Parallel distributed GNN execution (paper §III-E):
//! per layer, every fog computes its partition with the AOT executable,
//! then a synchronization exchanges boundary (halo) activations before
//! the next layer — K syncs for a K-layer GNN.
//!
//! Fogs are simulated as logically-parallel workers on this host. The
//! engine-driven path (`run`) measures each fog's layer compute
//! individually; the measured path (`BatchedBspPlan` / `run_parallel`)
//! executes the sparse CSR kernels on a persistent per-fog worker pool
//! (`runtime::kernels::pool`) over a block-diagonal micro-batch, so
//! per-fog times are observed under genuine concurrency and reflect
//! kernel cost rather than thread start-up. With
//! `--kernel-threads > 1` each fog worker leads a shard helper group
//! sized from its partition volume, so a single large partition runs
//! row-parallel inside its fog (and the measured timings — hence the
//! online profiler's η-scaled replans — see the sharded costs). The
//! serving pipeline scales those times by the node's capability
//! multiplier and takes the per-layer max (the BSP barrier).

use std::borrow::Borrow;
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::graph::{subgraph, ExchangePlan, Graph, LocalGraph};
use crate::obs::clock::Stopwatch;
use crate::obs::recorder::{Recorder, Ring};
use crate::obs::span::{Phase, SpanEvent};
use crate::runtime::csr_backend::{in_neighbor_lists, CsrPartition,
                                  InNbrLists};
use crate::runtime::kernels::{group_widths, FogJob, FogKernel,
                              FogWorkerPool, Inject, JobTrace,
                              KernelScratch, Reply, ShardExec,
                              DEFAULT_TASK_DEADLINE_S};
use crate::runtime::{engine::EngineError, EdgeArrays, Engine,
                     WeightBundle};

/// Flight-recorder context for a traced measured execution: the
/// recorder handle plus the rings the spans land in. Built once per
/// (tenant, plan) pair and reused across micro-batches, so each pool
/// worker remains the sole producer of its wall ring (`rings[j]` is
/// written only by fog worker `j`; `coord` only by the calling
/// thread). Dropping the context detaches tracing without touching
/// the execution path.
pub struct ExecTrace {
    pub rec: Arc<Recorder>,
    /// `rings[j]` — fog `j`'s wall-clock ring (kernel + queue spans).
    pub rings: Vec<Arc<Ring>>,
    /// Coordinator-thread ring (halo-sync wall spans).
    pub coord: Arc<Ring>,
    /// Canonical tenant index the spans are attributed to.
    pub tenant: u32,
}

impl ExecTrace {
    pub fn new(rec: &Arc<Recorder>, n_fogs: usize,
               tenant: u32) -> ExecTrace {
        ExecTrace {
            rec: rec.clone(),
            rings: (0..n_fogs).map(|_| rec.ring()).collect(),
            coord: rec.ring(),
            tenant,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BspResult {
    /// Assembled [V_global, out_dim] outputs (global vertex order).
    pub outputs: Vec<f32>,
    pub out_dim: usize,
    /// host_seconds[layer][fog] — pure kernel wall-clock (intra-fog
    /// shard parallelism included, job-channel queueing excluded).
    pub layer_host_seconds: Vec<Vec<f64>>,
    /// queue_wait_s[layer][fog] — job-channel send-to-dequeue latency,
    /// reported apart from kernel seconds so profiler observations
    /// stay queueing-free (all zero on the engine-driven and serial
    /// paths, which have no job channel).
    pub layer_queue_wait_seconds: Vec<Vec<f64>>,
    /// Activation bytes exchanged at each layer boundary (total).
    pub sync_bytes: Vec<usize>,
    /// Max per-fog OUTGOING bytes at each boundary — the bottleneck of
    /// the pairwise-parallel exchange.
    pub sync_max_out: Vec<usize>,
    /// Per-fog owned-vertex counts.
    pub fog_vertices: Vec<usize>,
    /// Per-fog cardinality ⟨|V|,|N_V|⟩ (for the online profiler).
    pub fog_cardinality: Vec<(usize, usize)>,
}

/// Per-fog receiver index: global id -> halo row slot. A pure function
/// of the partition, so the batched plan precomputes it once and the
/// per-batch sync pays no structure rebuild.
/// Per-fog map from halo global id → local row (n_local..n_total).
pub type HaloIndex = Vec<std::collections::HashMap<u32, usize>>;

/// Shared plan-construction validation: known model, sane width. The
/// width bound holds on the library path too, not just CLI parsing —
/// an absurd value would otherwise panic mid-run spawning
/// n_fogs × (threads - 1) helper threads.
fn validate_plan_inputs(model: &str, kernel_threads: usize)
                        -> Result<(), EngineError> {
    if !matches!(model, "gcn" | "sage" | "gat" | "astgcn") {
        return Err(EngineError::Unsupported(format!(
            "measured batched BSP supports gcn|gat|sage|astgcn, \
             not {model}"
        )));
    }
    if kernel_threads == 0
        || kernel_threads > crate::util::cli::MAX_KERNEL_THREADS
    {
        return Err(EngineError::Unsupported(format!(
            "kernel_threads must be in 1..={} (got {kernel_threads})",
            crate::util::cli::MAX_KERNEL_THREADS
        )));
    }
    Ok(())
}

/// Build the per-fog halo lookup once per grounding (public together
/// with [`sync_halo`] so integration tests can drive an exchange round
/// without standing up a worker pool).
pub fn build_halo_index<S: Borrow<LocalGraph>>(subs: &[S]) -> HaloIndex {
    subs.iter()
        .map(|s| {
            let s = s.borrow();
            s.vertices[s.n_local..]
                .iter()
                .enumerate()
                .map(|(i, &gid)| (gid, s.n_local + i))
                .collect()
        })
        .collect()
}

/// Exchange halo activations: copy each owner's local rows into the
/// requesters' halo slots, once per batch block (states are
/// [batch * n_total, dim] block-major). Returns total bytes moved
/// between fogs across all blocks. Generic over the sub container so
/// the engine path (`Vec<LocalGraph>`) and the shared-ownership plan
/// path (`Vec<Arc<LocalGraph>>`) use the same implementation. The row
/// copies are allocation-free: one split borrow per (owner, requester)
/// pair yields disjoint fog slices, and every row moves with a direct
/// `copy_from_slice` (tests/alloc_regression.rs holds this at zero
/// allocations per round).
pub fn sync_halo<S: Borrow<LocalGraph>>(
    subs: &[S],
    plan: &ExchangePlan,
    halo_index: &HaloIndex,
    states: &mut [Vec<f32>],
    dim: usize,
    batch: usize,
) -> usize {
    let mut bytes = 0usize;
    for owner in 0..subs.len() {
        for req in 0..subs.len() {
            let wanted = &plan.transfers[owner][req];
            if wanted.is_empty() {
                continue;
            }
            // a fog never requests its own rows, so the split below is
            // always between two distinct fogs
            debug_assert_ne!(owner, req, "no self transfers in plan");
            bytes += wanted.len() * dim * 4 * batch;
            let n_owner = subs[owner].borrow().n_total();
            let n_req = subs[req].borrow().n_total();
            let (src, dst) = if owner < req {
                let (lo, hi) = states.split_at_mut(req);
                (&lo[owner], &mut hi[0])
            } else {
                let (lo, hi) = states.split_at_mut(owner);
                (&hi[0], &mut lo[req])
            };
            for &owner_local in wanted {
                let gid =
                    subs[owner].borrow().vertices[owner_local as usize];
                let pos = *halo_index[req]
                    .get(&gid)
                    .expect("halo row for shipped vertex");
                for bk in 0..batch {
                    let src0 =
                        (bk * n_owner + owner_local as usize) * dim;
                    let dst0 = (bk * n_req + pos) * dim;
                    dst[dst0..dst0 + dim]
                        .copy_from_slice(&src[src0..src0 + dim]);
                }
            }
        }
    }
    bytes
}

/// Run a full multi-layer GNN over a placement.
///
/// * `features` — [V_global, f_in] row-major (already dequantized when a
///   codec was applied upstream).
/// * `assignment` — vertex → fog id.
#[allow(clippy::too_many_arguments)]
pub fn run(
    g: &Graph,
    features: &[f32],
    f_in: usize,
    assignment: &[u32],
    n_fogs: usize,
    model: &str,
    dataset: &str,
    classes: usize,
    engine: &mut Engine,
) -> Result<BspResult, EngineError> {
    let (subs, plan) = subgraph::extract(g, assignment, n_fogs);
    // astgcn uses the dense-adjacency path; no COO edge arrays needed
    let edges: Vec<EdgeArrays> = if model == "astgcn" {
        Vec::new()
    } else {
        subs.iter()
            .map(|s| crate::runtime::pad::prep_edges(model, s))
            .collect::<Result<Vec<_>, _>>()?
    };
    // initial states: local rows from collected features; halo zeroed
    // (filled by the first sync round)
    let mut states: Vec<Vec<f32>> = subs
        .iter()
        .map(|s| {
            let mut h = vec![0f32; s.n_total() * f_in];
            for (row, &gid) in s.vertices.iter().enumerate() {
                if row < s.n_local {
                    h[row * f_in..(row + 1) * f_in].copy_from_slice(
                        &features[gid as usize * f_in
                            ..(gid as usize + 1) * f_in],
                    );
                }
            }
            h
        })
        .collect();

    let num_layers = crate::runtime::reference::model_layers(model);
    let mut layer_host = Vec::with_capacity(num_layers);
    let mut sync_bytes = Vec::with_capacity(num_layers);
    let mut sync_max_out = Vec::with_capacity(num_layers);
    // per-fog outgoing vertex counts (placement-static)
    let out_counts: Vec<usize> = (0..n_fogs)
        .map(|owner| {
            plan.transfers[owner].iter().map(|t| t.len()).sum()
        })
        .collect();
    let max_out_vertices = out_counts.iter().copied().max().unwrap_or(0);
    let mut dim = f_in;
    let mut out_dim = f_in;
    let halo_index = build_halo_index(&subs);
    for layer in 0..num_layers {
        // sync round: ship current halo activations
        sync_bytes.push(sync_halo(&subs, &plan, &halo_index,
                                  &mut states, dim, 1));
        sync_max_out.push(max_out_vertices * dim * 4);
        let mut per_fog = Vec::with_capacity(n_fogs);
        let mut next_states: Vec<Vec<f32>> = Vec::with_capacity(n_fogs);
        for (j, sub) in subs.iter().enumerate() {
            if sub.n_total() == 0 {
                // fog holds no vertices (degenerate placement): no work
                per_fog.push(0.0);
                next_states.push(Vec::new());
                continue;
            }
            let out = if model == "astgcn" {
                engine.run_astgcn(dataset, &states[j], sub.n_total(),
                                  f_in, sub)?
            } else {
                engine.run_layer(model, dataset, layer, &states[j], dim,
                                 &edges[j], f_in, classes)?
            };
            per_fog.push(out.host_seconds);
            out_dim = out.out_dim;
            // layers emit OWNED rows only; rebuild the full local-space
            // state with halo slots zeroed — the next layer's sync round
            // fills them from their owners before any use.
            let rows = out.h.len() / out.out_dim;
            if rows == sub.n_total() {
                next_states.push(out.h);
            } else {
                debug_assert_eq!(rows, sub.n_local);
                let mut st = vec![0f32; sub.n_total() * out.out_dim];
                st[..sub.n_local * out.out_dim].copy_from_slice(&out.h);
                next_states.push(st);
            }
        }
        layer_host.push(per_fog);
        states = next_states;
        dim = out_dim;
    }

    // assemble global outputs from each fog's local rows
    let mut outputs = vec![0f32; g.num_vertices() * out_dim];
    for (j, sub) in subs.iter().enumerate() {
        for (row, &gid) in sub.vertices[..sub.n_local].iter().enumerate() {
            outputs[gid as usize * out_dim..(gid as usize + 1) * out_dim]
                .copy_from_slice(
                    &states[j][row * out_dim..(row + 1) * out_dim],
                );
        }
    }
    let layers = layer_host.len();
    Ok(BspResult {
        outputs,
        out_dim,
        layer_host_seconds: layer_host,
        layer_queue_wait_seconds: vec![vec![0.0; n_fogs]; layers],
        sync_bytes,
        sync_max_out,
        fog_vertices: subs.iter().map(|s| s.n_local).collect(),
        fog_cardinality: subs.iter().map(|s| s.cardinality()).collect(),
    })
}

/// Pre-extracted measured-execution plan for one placement: partition
/// views, the halo exchange plan, per-fog CSR structures and a
/// persistent per-fog worker pool, reusable across micro-batches — the
/// per-batch hot path pays only kernels and syncs, never partition
/// extraction or thread start-up. Covers every model: gcn|gat|sage run
/// the batched CSR layer kernels; astgcn runs the sparse-attention
/// block per batch block.
///
/// The pool is held behind an `Arc` and the workers are
/// structure-free (jobs carry their structures), so multiple plans —
/// the multi-tenant fabric's plan cache holds one per distinct
/// `(model, dataset)` — share one set of threads
/// (`with_shared_pool`), and a replan's `rebuild` swaps partition
/// structures without respawning a thread.
pub struct BatchedBspPlan {
    pub subs: Vec<Arc<LocalGraph>>,
    pub plan: ExchangePlan,
    /// One CSR per fog for the message-passing models; empty for
    /// astgcn (its kernel works on the local graph directly).
    pub csrs: Vec<Arc<CsrPartition>>,
    /// One in-neighbor structure per fog for astgcn; empty otherwise.
    /// Built once here so the per-batch hot path (and the measured
    /// timings it produces) never pays the O(V + E) counting sort.
    nbrs: Vec<Arc<InNbrLists>>,
    pool: Arc<FogWorkerPool>,
    halo_index: HaloIndex,
    model: Arc<str>,
    n_fogs: usize,
    nv: usize,
    kernel_threads: usize,
}

impl BatchedBspPlan {
    /// Single-threaded fogs (no intra-fog sharding) — the
    /// pre-`--kernel-threads` behavior.
    pub fn new(g: &Graph, assignment: &[u32], n_fogs: usize,
               model: &str) -> Result<BatchedBspPlan, EngineError> {
        BatchedBspPlan::with_threads(g, assignment, n_fogs, model, 1)
    }

    /// `kernel_threads` is the worker-group width the largest
    /// partition gets; smaller fogs get proportionally fewer workers
    /// (`kernels::pool::group_widths`). Builds a private pool; use
    /// `with_shared_pool` to reuse another plan's threads.
    pub fn with_threads(g: &Graph, assignment: &[u32], n_fogs: usize,
                        model: &str, kernel_threads: usize)
                        -> Result<BatchedBspPlan, EngineError> {
        validate_plan_inputs(model, kernel_threads)?;
        let mut volumes = vec![0usize; n_fogs];
        for &a in assignment {
            volumes[a as usize] += 1;
        }
        let pool = Arc::new(FogWorkerPool::with_widths(group_widths(
            &volumes,
            kernel_threads,
        )));
        BatchedBspPlan::with_shared_pool(g, assignment, n_fogs, model,
                                         kernel_threads, pool)
    }

    /// Build a plan on an EXISTING pool (one thread set shared across
    /// every plan holding the handle). The pool must have one worker
    /// per fog; shard widths are the pool's — kernels are
    /// row-decomposition invariant, so outputs are identical for any
    /// widths, only the parallel speedup differs.
    pub fn with_shared_pool(g: &Graph, assignment: &[u32],
                            n_fogs: usize, model: &str,
                            kernel_threads: usize,
                            pool: Arc<FogWorkerPool>)
                            -> Result<BatchedBspPlan, EngineError> {
        validate_plan_inputs(model, kernel_threads)?;
        if pool.len() != n_fogs {
            return Err(EngineError::Unsupported(format!(
                "shared pool has {} workers but the placement has \
                 {n_fogs} fogs",
                pool.len()
            )));
        }
        if pool.is_poisoned() {
            return Err(EngineError::Unsupported(
                "shared pool was poisoned by an earlier worker panic; \
                 build the plan on a fresh pool"
                    .to_string(),
            ));
        }
        let (subs, plan) = subgraph::extract(g, assignment, n_fogs);
        let subs: Vec<Arc<LocalGraph>> =
            subs.into_iter().map(Arc::new).collect();
        let csrs: Vec<Arc<CsrPartition>> = if model == "astgcn" {
            Vec::new()
        } else {
            subs.iter()
                .map(|s| {
                    crate::runtime::pad::prep_edges(model, s)
                        .map(|e| Arc::new(CsrPartition::from_edges(&e)))
                })
                .collect::<Result<Vec<_>, _>>()?
        };
        let nbrs: Vec<Arc<InNbrLists>> = if model == "astgcn" {
            subs.iter()
                .map(|s| Arc::new(in_neighbor_lists(s, s.n_total())))
                .collect()
        } else {
            Vec::new()
        };
        let halo_index = build_halo_index(&subs);
        Ok(BatchedBspPlan {
            subs,
            plan,
            csrs,
            nbrs,
            pool,
            halo_index,
            model: Arc::from(model),
            n_fogs,
            nv: g.num_vertices(),
            kernel_threads,
        })
    }

    pub fn n_fogs(&self) -> usize {
        self.n_fogs
    }

    /// The `--kernel-threads` value this plan was built with (max
    /// per-fog worker-group width).
    pub fn kernel_threads(&self) -> usize {
        self.kernel_threads
    }

    /// Handle to the persistent worker pool, for building further
    /// plans over the same threads (`with_shared_pool`).
    pub fn pool_handle(&self) -> Arc<FogWorkerPool> {
        self.pool.clone()
    }

    /// Per-fog worker-group widths (leader + shard helpers).
    pub fn widths(&self) -> &[usize] {
        self.pool.widths()
    }

    /// Per-fog cardinality ⟨|V|, |N_V|⟩ (for the online profiler).
    pub fn cardinality(&self, fog: usize) -> (usize, usize) {
        self.subs[fog].cardinality()
    }

    /// Largest per-fog outbound halo row count — the per-layer
    /// serialization-buffer high-water mark (`sync_max_out` is this
    /// times row bytes).
    fn max_out_vertices(&self) -> usize {
        (0..self.n_fogs)
            .map(|owner| {
                self.plan.transfers[owner]
                    .iter()
                    .map(|t| t.len())
                    .sum::<usize>()
            })
            .max()
            .unwrap_or(0)
    }

    /// Execute a block-diagonal batch of `batch` identical-snapshot
    /// requests. Per-fog layer compute runs on the persistent worker
    /// pool — one long-lived thread per fog, mirroring the
    /// logically-parallel fog machines — so the measured per-fog
    /// wall-clock reflects real concurrency without per-batch spawn
    /// cost. `outputs` stacks [batch * V, out_dim] block-major;
    /// `layer_host_seconds[layer][fog]` is each fog's measured batched
    /// kernel time.
    pub fn execute(&self, features: &[f32], f_in: usize,
                   wb: &Arc<WeightBundle>, batch: usize) -> BspResult {
        self.execute_inner(features, f_in, wb, batch, true, true, None)
    }

    /// Like `execute` but skips global-output assembly — the serving
    /// loop only consumes the measured timings, so the O(batch·V·F)
    /// gather would be pure waste per micro-batch. `outputs` is empty.
    pub fn execute_timings(&self, features: &[f32], f_in: usize,
                           wb: &Arc<WeightBundle>, batch: usize)
                           -> BspResult {
        self.execute_inner(features, f_in, wb, batch, false, true, None)
    }

    /// `execute_timings` with flight-recorder spans: each fog worker
    /// records wall-clock `kernel`/`queue` spans into its ring and the
    /// calling thread records halo-sync spans — numerically identical
    /// to the untraced path (tracing only observes the seconds the
    /// result already reports).
    pub fn execute_timings_traced(&self, features: &[f32], f_in: usize,
                                  wb: &Arc<WeightBundle>, batch: usize,
                                  trace: Option<&ExecTrace>)
                                  -> BspResult {
        self.execute_inner(features, f_in, wb, batch, false, true,
                           trace)
    }

    /// `execute` with every fog's kernels run inline on the calling
    /// thread — the spawn-free oracle. Shares the exact kernel code
    /// path with the pooled workers (`FogJob::run`), so pooled and
    /// serial outputs are bit-identical; `tests/backend_parity.rs`
    /// asserts it.
    pub fn execute_serial(&self, features: &[f32], f_in: usize,
                          wb: &Arc<WeightBundle>, batch: usize)
                          -> BspResult {
        self.execute_inner(features, f_in, wb, batch, true, false, None)
    }

    /// Build this layer's per-fog jobs, draining `states` (fogs owning
    /// no vertices get `None`).
    #[allow(clippy::too_many_arguments)]
    fn layer_jobs(&self, layer: usize, dim: usize, last: bool,
                  batch: usize, f_in: usize,
                  states: &mut [Vec<f32>], wb: &Arc<WeightBundle>,
                  trace: Option<&ExecTrace>) -> Vec<Option<FogJob>> {
        (0..self.n_fogs)
            .map(|j| {
                if self.subs[j].n_total() == 0 {
                    return None;
                }
                let state = std::mem::take(&mut states[j]);
                let kernel = if &*self.model == "astgcn" {
                    FogKernel::Astgcn { ft: f_in }
                } else {
                    FogKernel::Layer { layer, dim, last }
                };
                Some(FogJob {
                    kernel,
                    model: self.model.clone(),
                    batch,
                    state,
                    weights: wb.clone(),
                    sub: self.subs[j].clone(),
                    csr: self.csrs.get(j).cloned(),
                    nbr: self.nbrs.get(j).cloned(),
                    trace: trace.map(|tr| JobTrace {
                        rec: tr.rec.clone(),
                        ring: tr.rings[j].clone(),
                        tenant: tr.tenant,
                        layer: layer as i32,
                    }),
                    reply_to: None,
                    task: 0,
                    inject: None,
                })
            })
            .collect()
    }

    /// Run one layer's jobs inline (the serial oracle). Shard widths
    /// mirror the pool's per-fog groups (`ShardExec::Inline`), so the
    /// split points — and therefore the outputs — are identical to the
    /// pooled run by construction (and row-decomposition invariance
    /// makes them split-independent besides).
    fn run_jobs_serial(&self, jobs: Vec<Option<FogJob>>)
                       -> (Vec<Vec<f32>>, Vec<f64>) {
        let mut scratch = KernelScratch::default();
        let mut outs = Vec::with_capacity(jobs.len());
        let mut secs = Vec::with_capacity(jobs.len());
        for (j, job) in jobs.into_iter().enumerate() {
            match job {
                None => {
                    outs.push(Vec::new());
                    secs.push(0.0);
                }
                Some(job) => {
                    let exec =
                        ShardExec::Inline(self.pool.widths()[j]);
                    let (out, s) = job.run(&mut scratch, &exec);
                    outs.push(out);
                    secs.push(s);
                }
            }
        }
        (outs, secs)
    }

    #[allow(clippy::too_many_arguments)]
    fn execute_inner(&self, features: &[f32], f_in: usize,
                     wb: &Arc<WeightBundle>, batch: usize,
                     assemble_outputs: bool, pooled: bool,
                     trace: Option<&ExecTrace>) -> BspResult {
        assert!(batch >= 1);
        let n_fogs = self.n_fogs;
        let model: &str = &self.model;
        let num_layers = crate::runtime::reference::model_layers(model);
        // initial states: every block carries the same snapshot rows
        let mut states: Vec<Vec<f32>> = self
            .subs
            .iter()
            .map(|s| {
                let n = s.n_total();
                let mut h = vec![0f32; batch * n * f_in];
                for (row, &gid) in
                    s.vertices[..s.n_local].iter().enumerate()
                {
                    let src = &features[gid as usize * f_in
                        ..(gid as usize + 1) * f_in];
                    for bk in 0..batch {
                        let at = (bk * n + row) * f_in;
                        h[at..at + f_in].copy_from_slice(src);
                    }
                }
                h
            })
            .collect();

        let mut layer_host = Vec::with_capacity(num_layers);
        let mut layer_wait = Vec::with_capacity(num_layers);
        let mut sync_bytes = Vec::with_capacity(num_layers);
        let mut sync_max_out = Vec::with_capacity(num_layers);
        let out_counts: Vec<usize> = (0..n_fogs)
            .map(|owner| {
                self.plan.transfers[owner]
                    .iter()
                    .map(|t| t.len())
                    .sum()
            })
            .collect();
        let max_out_vertices =
            out_counts.iter().copied().max().unwrap_or(0);
        let mut dim = f_in;
        let mut out_dim = f_in;
        for layer in 0..num_layers {
            let sw = trace.map(|_| Stopwatch::start());
            sync_bytes.push(sync_halo(&self.subs, &self.plan,
                                      &self.halo_index, &mut states,
                                      dim, batch));
            if let (Some(tr), Some(sw)) = (trace, sw) {
                let dur_us = sw.elapsed_s() * 1e6;
                let end_us = tr.rec.wall_now_us();
                let mut ev = SpanEvent::new(Phase::Sync, tr.tenant,
                                            end_us - dur_us, dur_us)
                    .count(batch)
                    .on_wall();
                ev.layer = layer as i32;
                tr.rec.span(&tr.coord, ev);
            }
            sync_max_out.push(max_out_vertices * dim * 4 * batch);
            let last = layer + 1 == num_layers;
            let jobs = self.layer_jobs(layer, dim, last, batch, f_in,
                                       &mut states, wb, trace);
            let (outs, secs, waits) = if pooled {
                self.pool.dispatch(jobs)
            } else {
                let (outs, secs) = self.run_jobs_serial(jobs);
                let waits = vec![0.0; secs.len()];
                (outs, secs, waits)
            };
            let mut next_states: Vec<Vec<f32>> =
                Vec::with_capacity(n_fogs);
            for (j, out) in outs.into_iter().enumerate() {
                if out.is_empty() {
                    // fog owns no vertices (n_local > 0 ⟺ n_total > 0)
                    next_states.push(Vec::new());
                    continue;
                }
                let l = self.subs[j].n_local;
                let n = self.subs[j].n_total();
                if model == "astgcn" {
                    // the astgcn kernel emits ALL rows (halos included)
                    out_dim = out.len() / (batch * n);
                    next_states.push(out);
                } else {
                    out_dim = out.len() / (batch * l);
                    // rebuild full local-space states with halo slots
                    // zeroed (filled by the next sync round)
                    let mut st = vec![0f32; batch * n * out_dim];
                    for bk in 0..batch {
                        st[bk * n * out_dim..(bk * n + l) * out_dim]
                            .copy_from_slice(
                                &out[bk * l * out_dim
                                    ..(bk + 1) * l * out_dim],
                            );
                    }
                    next_states.push(st);
                }
            }
            layer_host.push(secs);
            layer_wait.push(waits);
            states = next_states;
            dim = out_dim;
        }

        // assemble stacked global outputs [batch * V, out_dim]
        let mut outputs = if assemble_outputs {
            vec![0f32; batch * self.nv * out_dim]
        } else {
            Vec::new()
        };
        if assemble_outputs {
            for (j, sub) in self.subs.iter().enumerate() {
                let n = sub.n_total();
                for bk in 0..batch {
                    for (row, &gid) in
                        sub.vertices[..sub.n_local].iter().enumerate()
                    {
                        let at =
                            (bk * self.nv + gid as usize) * out_dim;
                        let from = (bk * n + row) * out_dim;
                        outputs[at..at + out_dim].copy_from_slice(
                            &states[j][from..from + out_dim],
                        );
                    }
                }
            }
        }
        BspResult {
            outputs,
            out_dim,
            layer_host_seconds: layer_host,
            layer_queue_wait_seconds: layer_wait,
            sync_bytes,
            sync_max_out,
            fog_vertices: self.subs.iter().map(|s| s.n_local).collect(),
            fog_cardinality: self
                .subs
                .iter()
                .map(|s| s.cardinality())
                .collect(),
        }
    }
}

/// Input-assembly state for one layer of one in-flight batch: per-fog
/// buffers being filled by the owner's rebuild plus incoming halo
/// messages, and the dependency counters that decide when a fog's job
/// can dispatch without a global barrier.
struct LayerSlot {
    /// Per-fog input buffer; `None` before the fog's previous-layer
    /// reply created it and again after its job took it.
    bufs: Vec<Option<Vec<f32>>>,
    /// Fog's own previous-layer output was rebuilt into `bufs` (for
    /// layer 0: set at submit).
    own_done: Vec<bool>,
    /// Halo messages delivered into this fog's buffer so far.
    copies_in: Vec<usize>,
    dispatched: Vec<bool>,
    /// Halo messages that arrived before the destination fog's buffer
    /// existed: `(src_fog, staged_rows)`, delivered at creation.
    staged: Vec<Vec<(usize, Vec<f32>)>>,
}

impl LayerSlot {
    fn new(n_fogs: usize) -> LayerSlot {
        LayerSlot {
            bufs: (0..n_fogs).map(|_| None).collect(),
            own_done: vec![false; n_fogs],
            copies_in: vec![0; n_fogs],
            dispatched: vec![false; n_fogs],
            staged: (0..n_fogs).map(|_| Vec::new()).collect(),
        }
    }
}

/// One batch moving through the pipeline.
struct InflightBatch {
    seq: u64,
    batch: usize,
    f_in: usize,
    wb: Arc<WeightBundle>,
    num_layers: usize,
    /// Which fogs hold vertices (the rest never receive jobs).
    active: Vec<bool>,
    n_active: usize,
    /// Incoming-halo source count per fog (static per plan).
    n_in: Vec<usize>,
    layers: Vec<LayerSlot>,
    /// Final-layer outputs in local space (owned rows valid).
    final_states: Vec<Vec<f32>>,
    done_last: usize,
    complete: bool,
    /// Input dim per layer; `dims[L]` set when layer L's first input
    /// exists (`dims[0] = f_in`), `dims[num_layers]` = output dim.
    dims: Vec<usize>,
    layer_host: Vec<Vec<f64>>,
    layer_wait: Vec<Vec<f64>>,
    sync_bytes: Vec<usize>,
    sync_max_out: Vec<usize>,
}

/// Pipelined BSP executor: up to `depth` micro-batches in flight over
/// one `BatchedBspPlan`, with the global per-layer barrier of
/// `execute_inner` replaced by dependency-driven dispatch — fog j's
/// layer-L job launches as soon as (a) fog j's own layer-(L-1) output
/// is rebuilt and (b) every halo message destined for j at that
/// boundary has been delivered. The halo exchange therefore overlaps
/// straggler compute (layer-level double buffering: each layer's input
/// buffers assemble while the previous layer still runs elsewhere),
/// and a fog that finished batch N's last layer immediately starts
/// batch N+1's first — the per-fog request/reply channels of the
/// worker pool carry both without a single coordinator join.
///
/// Every value a task consumes is identical to the barrier executor's
/// (halo messages are plain row copies, kernels are
/// row-decomposition invariant), so final features are bit-identical
/// to `execute` for any depth and any reply order; only the measured
/// per-task wall seconds differ. `tests/backend_parity.rs` asserts
/// the bit-identity across models and depths.
///
/// The pipeline owns a private reply channel (`FogJob::reply_to`), so
/// plans sharing one worker pool can each run their own pipeline —
/// and interleave with barrier `dispatch` calls from other plans —
/// without reply cross-talk. Replies are mapped back to
/// (batch, layer) via per-fog FIFO tag queues, which is sound because
/// each fog worker processes its jobs in submission order.
pub struct BspPipeline {
    depth: usize,
    assemble: bool,
    tx: Sender<Reply>,
    rx: Receiver<Reply>,
    /// Per-fog (batch seq, layer) tags in submission order.
    tags: Vec<VecDeque<(u64, usize)>>,
    inflight: VecDeque<InflightBatch>,
    next_seq: u64,
    /// Chaos configuration (per-fog crash/speed masks); `None` = the
    /// fault-free pipeline, byte-identical to pre-chaos behavior.
    chaos: Option<PipelineChaos>,
    /// In-flight tagged tasks (chaos mode only), keyed by task id.
    /// Fault-free pipelines map replies by per-fog FIFO tags instead;
    /// hedging breaks that ordering contract (the same logical task
    /// may race on two workers), hence explicit identity.
    pending: HashMap<u64, PendingTask>,
    /// Next task id; 0 is reserved for "untagged".
    next_task: u64,
    /// Hedged tasks whose replica's reply arrived first.
    hedge_wins: u64,
    /// Late loser replies discarded after the race was decided.
    hedge_waste: u64,
    /// Wall-clock per-task deadline: past it, `collect` hedges (chaos)
    /// or poisons the pool (a genuinely hung worker) instead of
    /// blocking forever.
    task_deadline_s: f64,
}

/// Per-fog fault masks the measured executor derives from the run's
/// `ChaosPlan` at each batch's formation time.
#[derive(Clone, Debug)]
pub struct PipelineChaos {
    /// Fog's worker withholds every reply (dead node).
    pub crashed: Vec<bool>,
    /// Fog speed multiplier in (0, 1]; < 1 injects a straggler.
    pub speed: Vec<f64>,
}

/// A tagged task awaiting its (first) reply: everything needed to
/// re-submit the identical job to another fog's worker if the
/// deadline passes.
struct PendingTask {
    seq: u64,
    layer: usize,
    /// Logical fog — the partition the task computes, regardless of
    /// which worker ends up running it.
    fog: usize,
    /// Input snapshot kept for hedged re-dispatch (taken when hedged).
    state: Vec<f32>,
    submitted: Instant,
    hedged: bool,
}

impl BspPipeline {
    /// `depth` ≥ 1 in-flight batches (1 = submit/collect lockstep,
    /// still barrier-free within the batch); `assemble` controls
    /// global-output gathering exactly like `execute` vs
    /// `execute_timings`.
    pub fn new(n_fogs: usize, depth: usize,
               assemble: bool) -> BspPipeline {
        assert!(depth >= 1, "pipeline depth must be at least 1");
        let (tx, rx) = channel::<Reply>();
        BspPipeline {
            depth,
            assemble,
            tx,
            rx,
            tags: (0..n_fogs).map(|_| VecDeque::new()).collect(),
            inflight: VecDeque::new(),
            next_seq: 0,
            chaos: None,
            pending: HashMap::new(),
            next_task: 1,
            hedge_wins: 0,
            hedge_waste: 0,
            task_deadline_s: DEFAULT_TASK_DEADLINE_S,
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Install (or clear) the per-fog fault masks. With masks set,
    /// every job is tagged with an explicit task id and tracked for
    /// deadline-based hedged re-dispatch; `None` restores the
    /// fault-free FIFO-tag path bit-for-bit.
    pub fn set_chaos(&mut self, chaos: Option<PipelineChaos>) {
        if let Some(c) = &chaos {
            assert_eq!(c.crashed.len(), self.tags.len());
            assert_eq!(c.speed.len(), self.tags.len());
        }
        self.chaos = chaos;
    }

    /// Per-task wall deadline (seconds, positive finite) before
    /// `collect` hedges or gives up on a silent fog.
    pub fn set_task_deadline(&mut self, s: f64) {
        assert!(s.is_finite() && s > 0.0, "task deadline must be > 0");
        self.task_deadline_s = s;
    }

    /// (hedge wins, hedge waste) accumulated by this pipeline: wins =
    /// hedged tasks whose replica replied first; waste = late loser
    /// replies discarded after the race was decided.
    pub fn hedge_stats(&self) -> (u64, u64) {
        (self.hedge_wins, self.hedge_waste)
    }

    /// Batches submitted but not yet collected.
    pub fn pending(&self) -> usize {
        self.inflight.len()
    }

    /// Enqueue one block-diagonal micro-batch. The caller must keep
    /// `pending() < depth` (collect first — that blocking wait is the
    /// backpressure stall the fabric accounts as `pipeline_stall`).
    /// Layer-0 inputs and their halo exchange are built here, so the
    /// first jobs dispatch before this returns.
    pub fn submit(&mut self, plan: &BatchedBspPlan, features: &[f32],
                  f_in: usize, wb: &Arc<WeightBundle>, batch: usize,
                  trace: Option<&ExecTrace>) {
        assert!(batch >= 1);
        assert!(
            self.pending() < self.depth,
            "pipeline full: collect() before submitting (depth {})",
            self.depth
        );
        // opportunistically advance in-flight batches first
        self.pump(plan, trace);
        let n_fogs = plan.n_fogs;
        let model: &str = &plan.model;
        let num_layers = crate::runtime::reference::model_layers(model);
        let active: Vec<bool> =
            plan.subs.iter().map(|s| s.n_total() > 0).collect();
        let n_active = active.iter().filter(|&&a| a).count();
        let n_in: Vec<usize> = (0..n_fogs)
            .map(|d| {
                (0..n_fogs)
                    .filter(|&s| {
                        s != d && !plan.plan.transfers[s][d].is_empty()
                    })
                    .count()
            })
            .collect();
        let mut dims = vec![0usize; num_layers + 1];
        dims[0] = f_in;
        let mut b = InflightBatch {
            seq: self.next_seq,
            batch,
            f_in,
            wb: wb.clone(),
            num_layers,
            active,
            n_active,
            n_in,
            layers: (0..num_layers)
                .map(|_| LayerSlot::new(n_fogs))
                .collect(),
            final_states: vec![Vec::new(); n_fogs],
            done_last: 0,
            complete: n_active == 0,
            dims,
            layer_host: vec![vec![0.0; n_fogs]; num_layers],
            layer_wait: vec![vec![0.0; n_fogs]; num_layers],
            sync_bytes: vec![0; num_layers],
            sync_max_out: vec![0; num_layers],
        };
        self.next_seq += 1;

        // layer-0 inputs: snapshot rows per block, halo slots zeroed,
        // then the full initial exchange (all buffers exist, so every
        // message delivers immediately) — byte-equal to execute()'s
        // initial states + first sync_halo round.
        for (j, sub) in plan.subs.iter().enumerate() {
            if !b.active[j] {
                b.layers[0].own_done[j] = true;
                continue;
            }
            let n = sub.n_total();
            let mut h = vec![0f32; batch * n * f_in];
            for (row, &gid) in
                sub.vertices[..sub.n_local].iter().enumerate()
            {
                let src = &features[gid as usize * f_in
                    ..(gid as usize + 1) * f_in];
                for bk in 0..batch {
                    let at = (bk * n + row) * f_in;
                    h[at..at + f_in].copy_from_slice(src);
                }
            }
            b.layers[0].bufs[j] = Some(h);
            b.layers[0].own_done[j] = true;
        }
        if num_layers > 0 {
            b.sync_max_out[0] =
                plan.max_out_vertices() * f_in * 4 * batch;
        }
        self.inflight.push_back(b);
        let idx = self.inflight.len() - 1;
        for src in 0..n_fogs {
            self.ship_halo(plan, idx, 0, src, trace);
        }
        for j in 0..n_fogs {
            self.maybe_dispatch(plan, idx, 0, j, trace);
        }
    }

    /// Drain every reply that is already waiting (non-blocking), so
    /// workers stay fed between submit/collect calls.
    pub fn pump(&mut self, plan: &BatchedBspPlan,
                trace: Option<&ExecTrace>) {
        while let Ok(r) = self.rx.try_recv() {
            self.process_reply(plan, r, trace);
        }
    }

    /// Block until the OLDEST in-flight batch completes, then return
    /// its result (replies for younger batches are processed along the
    /// way — that is the overlap). A task that never replies within
    /// the deadline is hedged onto a healthy fog (chaos mode) or
    /// surfaces as a poisoned pool — the coordinator never wedges.
    pub fn collect(&mut self, plan: &BatchedBspPlan,
                   trace: Option<&ExecTrace>) -> BspResult {
        assert!(
            !self.inflight.is_empty(),
            "collect() with no batch in flight"
        );
        while !self.inflight.front().unwrap().complete {
            // wake at the earliest un-hedged task's deadline so an
            // overdue task is hedged even while other fogs' replies
            // keep the channel busy
            let dl = self.task_deadline_s;
            let wait = self
                .pending
                .values()
                .filter(|p| !p.hedged)
                .map(|p| {
                    (dl - p.submitted.elapsed().as_secs_f64()).max(0.0)
                })
                .fold(dl, f64::min);
            match self
                .rx
                .recv_timeout(Duration::from_secs_f64(wait.max(1e-3)))
            {
                Ok(r) => self.process_reply(plan, r, trace),
                Err(RecvTimeoutError::Timeout) => {
                    let hedged = if self.chaos.is_some() {
                        self.hedge_overdue(plan)
                    } else {
                        0
                    };
                    if hedged == 0 && wait >= dl {
                        // a full deadline passed with nothing to hedge:
                        // a genuinely hung worker (or a wedged hedge)
                        plan.pool.poison();
                        panic!(
                            "fog task exceeded the {dl:.3}s pipeline \
                             deadline; pool poisoned — rebuild the plan"
                        );
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    plan.pool.poison();
                    panic!("all fog workers died mid-pipeline");
                }
            }
        }
        let b = self.inflight.pop_front().unwrap();
        self.finish_batch(plan, b)
    }

    /// Hedged re-dispatch: every un-hedged task past the deadline is
    /// re-submitted — same task id, same input bytes — to the next
    /// non-crashed fog's worker queue. Workers are structure-free
    /// (the job carries its partition's structures) and the kernels
    /// are row-decomposition invariant, so the replica's output is
    /// bit-identical to what the silent fog would have produced; only
    /// timing changes. First reply wins; the loser's late reply is
    /// discarded by task id in `process_reply`. Returns how many
    /// tasks were hedged.
    fn hedge_overdue(&mut self, plan: &BatchedBspPlan) -> usize {
        let dl = self.task_deadline_s;
        let (crashed, speed) = {
            let c = self.chaos.as_ref().expect("chaos mode");
            (c.crashed.clone(), c.speed.clone())
        };
        let mut overdue: Vec<u64> = self
            .pending
            .iter()
            .filter(|(_, p)| {
                !p.hedged && p.submitted.elapsed().as_secs_f64() > dl
            })
            .map(|(&t, _)| t)
            .collect();
        overdue.sort_unstable();
        let n_hedged = overdue.len();
        for t in overdue {
            let (seq, layer, fog, state) = {
                let p = self.pending.get_mut(&t).expect("pending task");
                p.hedged = true;
                (p.seq, p.layer, p.fog, std::mem::take(&mut p.state))
            };
            let target = (1..=plan.n_fogs)
                .map(|k| (fog + k) % plan.n_fogs)
                .find(|&cand| !crashed[cand])
                .unwrap_or_else(|| {
                    plan.pool.poison();
                    panic!(
                        "every fog is crashed; cannot hedge task {t}"
                    )
                });
            let front_seq =
                self.inflight.front().expect("batch in flight").seq;
            let b = &self.inflight[(seq - front_seq) as usize];
            let last = layer + 1 == b.num_layers;
            let kernel = if &*plan.model == "astgcn" {
                FogKernel::Astgcn { ft: b.f_in }
            } else {
                FogKernel::Layer { layer, dim: b.dims[layer], last }
            };
            let job = FogJob {
                kernel,
                model: plan.model.clone(),
                batch: b.batch,
                state,
                weights: b.wb.clone(),
                sub: plan.subs[fog].clone(),
                csr: plan.csrs.get(fog).cloned(),
                nbr: plan.nbrs.get(fog).cloned(),
                // no trace: the replica runs on another fog's worker,
                // whose ring this task has no claim on
                trace: None,
                reply_to: Some(self.tx.clone()),
                task: t,
                inject: if speed[target] < 1.0 {
                    Some(Inject::Slow { speed: speed[target] })
                } else {
                    None
                },
            };
            plan.pool.submit(target, job);
        }
        n_hedged
    }

    /// Stage fog `src`'s freshly-rebuilt layer-`layer` owned rows into
    /// halo messages and deliver each to its destination (or park it
    /// until the destination's buffer exists). Pure row copies — the
    /// same bytes `sync_halo` moves, just per-source instead of
    /// all-at-once — accounted into `sync_bytes[layer]`.
    fn ship_halo(&mut self, plan: &BatchedBspPlan, idx: usize,
                 layer: usize, src: usize,
                 trace: Option<&ExecTrace>) {
        let b = &mut self.inflight[idx];
        let dim = b.dims[layer];
        let batch = b.batch;
        let sw = trace.map(|_| Stopwatch::start());
        let n_src = plan.subs[src].n_total();
        let mut shipped = false;
        for dst in 0..plan.n_fogs {
            let wanted = &plan.plan.transfers[src][dst];
            if dst == src || wanted.is_empty() {
                continue;
            }
            b.sync_bytes[layer] += wanted.len() * dim * 4 * batch;
            // compact wire message: rows [w][bk][dim]
            let mut msg =
                Vec::with_capacity(wanted.len() * batch * dim);
            {
                let sb = b.layers[layer].bufs[src]
                    .as_ref()
                    .expect("source buffer live while shipping");
                for &owner_local in wanted {
                    for bk in 0..batch {
                        let s0 =
                            (bk * n_src + owner_local as usize) * dim;
                        msg.extend_from_slice(&sb[s0..s0 + dim]);
                    }
                }
            }
            shipped = true;
            if b.layers[layer].own_done[dst] {
                Self::deliver(plan, b, layer, src, dst, &msg);
                b.layers[layer].copies_in[dst] += 1;
            } else {
                b.layers[layer].staged[dst].push((src, msg));
            }
        }
        if let (Some(tr), Some(sw)) = (trace, sw) {
            if shipped {
                let dur_us = sw.elapsed_s() * 1e6;
                let end_us = tr.rec.wall_now_us();
                let mut ev = SpanEvent::new(Phase::Sync, tr.tenant,
                                            end_us - dur_us, dur_us)
                    .fog(src)
                    .count(batch)
                    .on_wall();
                ev.layer = layer as i32;
                tr.rec.span(&tr.coord, ev);
            }
        }
    }

    /// Write one staged halo message into the destination buffer.
    fn deliver(plan: &BatchedBspPlan, b: &mut InflightBatch,
               layer: usize, src: usize, dst: usize, msg: &[f32]) {
        let dim = b.dims[layer];
        let batch = b.batch;
        let n_dst = plan.subs[dst].n_total();
        let wanted = &plan.plan.transfers[src][dst];
        let db = b.layers[layer].bufs[dst]
            .as_mut()
            .expect("destination buffer live while delivering");
        for (w, &owner_local) in wanted.iter().enumerate() {
            let gid = plan.subs[src].vertices[owner_local as usize];
            let pos = *plan.halo_index[dst]
                .get(&gid)
                .expect("halo row for shipped vertex");
            for bk in 0..batch {
                let m0 = (w * batch + bk) * dim;
                let d0 = (bk * n_dst + pos) * dim;
                db[d0..d0 + dim]
                    .copy_from_slice(&msg[m0..m0 + dim]);
            }
        }
    }

    /// Dispatch fog `j`'s layer job once its buffer is fully
    /// assembled (own rebuild + all incoming halo messages).
    fn maybe_dispatch(&mut self, plan: &BatchedBspPlan, idx: usize,
                      layer: usize, j: usize,
                      trace: Option<&ExecTrace>) {
        let seq = {
            let b = &mut self.inflight[idx];
            if !b.active[j]
                || b.layers[layer].dispatched[j]
                || !b.layers[layer].own_done[j]
                || b.layers[layer].copies_in[j] < b.n_in[j]
            {
                return;
            }
            b.layers[layer].dispatched[j] = true;
            b.seq
        };
        let b = &mut self.inflight[idx];
        let state = b.layers[layer].bufs[j]
            .take()
            .expect("dispatch takes a live buffer");
        let last = layer + 1 == b.num_layers;
        let kernel = if &*plan.model == "astgcn" {
            FogKernel::Astgcn { ft: b.f_in }
        } else {
            FogKernel::Layer { layer, dim: b.dims[layer], last }
        };
        // chaos mode tags the task and stamps the fog's fault; the
        // fault-free path stays untagged and FIFO-mapped, bit-for-bit
        let (task, inject) = match &self.chaos {
            Some(c) => {
                let t = self.next_task;
                self.next_task += 1;
                let inj = if c.crashed[j] {
                    Some(Inject::DropReply)
                } else if c.speed[j] < 1.0 {
                    Some(Inject::Slow { speed: c.speed[j] })
                } else {
                    None
                };
                (t, inj)
            }
            None => (0, None),
        };
        // keep a copy of the input bytes so an overdue task can be
        // hedged with the identical job (chaos mode only)
        let pending_state =
            if task != 0 { state.clone() } else { Vec::new() };
        let job = FogJob {
            kernel,
            model: plan.model.clone(),
            batch: b.batch,
            state,
            weights: b.wb.clone(),
            sub: plan.subs[j].clone(),
            csr: plan.csrs.get(j).cloned(),
            nbr: plan.nbrs.get(j).cloned(),
            trace: trace.map(|tr| JobTrace {
                rec: tr.rec.clone(),
                ring: tr.rings[j].clone(),
                tenant: tr.tenant,
                layer: layer as i32,
            }),
            reply_to: Some(self.tx.clone()),
            task,
            inject,
        };
        if task != 0 {
            self.pending.insert(task, PendingTask {
                seq,
                layer,
                fog: j,
                state: pending_state,
                submitted: Instant::now(),
                hedged: false,
            });
        } else {
            self.tags[j].push_back((seq, layer));
        }
        plan.pool.submit(j, job);
    }

    /// Advance the dependency graph with one worker reply.
    fn process_reply(&mut self, plan: &BatchedBspPlan, r: Reply,
                     trace: Option<&ExecTrace>) {
        if r.panicked {
            plan.pool.poison();
            panic!(
                "fog worker {} panicked during pipelined kernel \
                 execution",
                r.fog
            );
        }
        let (seq, layer, j) = if r.task != 0 {
            // tagged (chaos) reply: map by task id, never by r.fog —
            // a hedged replica runs on another fog's worker
            match self.pending.remove(&r.task) {
                None => {
                    // the race was already decided by the other
                    // replica; discard the loser's late reply
                    self.hedge_waste += 1;
                    return;
                }
                Some(p) => {
                    if p.hedged && r.fog != p.fog {
                        self.hedge_wins += 1;
                    }
                    (p.seq, p.layer, p.fog)
                }
            }
        } else {
            let (seq, layer) = self.tags[r.fog]
                .pop_front()
                .expect("reply matches a submitted job");
            (seq, layer, r.fog)
        };
        let front_seq =
            self.inflight.front().expect("batch in flight").seq;
        let idx = (seq - front_seq) as usize;
        let next = layer + 1;
        {
            let b = &mut self.inflight[idx];
            b.layer_host[layer][j] = r.seconds;
            b.layer_wait[layer][j] = r.queue_wait_s;
            let l = plan.subs[j].n_local;
            let n = plan.subs[j].n_total();
            let out = r.out;
            // rebuild fog j's full local-space state exactly as the
            // barrier executor does: astgcn emits all rows; the
            // message-passing models emit owned rows only, halo slots
            // zeroed until their owners' messages arrive.
            let (st, out_dim) = if &*plan.model == "astgcn" {
                let out_dim = out.len() / (b.batch * n);
                (out, out_dim)
            } else {
                let out_dim = out.len() / (b.batch * l);
                let mut st = vec![0f32; b.batch * n * out_dim];
                for bk in 0..b.batch {
                    st[bk * n * out_dim..(bk * n + l) * out_dim]
                        .copy_from_slice(
                            &out[bk * l * out_dim
                                ..(bk + 1) * l * out_dim],
                        );
                }
                (st, out_dim)
            };
            if b.dims[next] == 0 {
                b.dims[next] = out_dim;
                if next < b.num_layers {
                    b.sync_max_out[next] = plan.max_out_vertices()
                        * out_dim
                        * 4
                        * b.batch;
                }
            }
            debug_assert_eq!(b.dims[next], out_dim,
                             "fogs disagree on layer output dim");
            if next == b.num_layers {
                b.final_states[j] = st;
                b.done_last += 1;
                if b.done_last == b.n_active {
                    b.complete = true;
                }
                return;
            }
            let slot = &mut b.layers[next];
            slot.bufs[j] = Some(st);
            slot.own_done[j] = true;
            // deliver messages that arrived before this buffer existed
            let staged = std::mem::take(&mut slot.staged[j]);
            for (src, msg) in staged {
                Self::deliver(plan, b, next, src, j, &msg);
                b.layers[next].copies_in[j] += 1;
            }
        }
        // ship j's fresh rows to its dependents, then re-check
        // dispatch readiness for j and everyone j feeds
        self.ship_halo(plan, idx, next, j, trace);
        self.maybe_dispatch(plan, idx, next, j, trace);
        for dst in 0..plan.n_fogs {
            if dst != j && !plan.plan.transfers[j][dst].is_empty() {
                self.maybe_dispatch(plan, idx, next, dst, trace);
            }
        }
    }

    /// Build the `BspResult` for a completed batch (same shape and —
    /// when `assemble` — the same bytes as `execute`).
    fn finish_batch(&self, plan: &BatchedBspPlan,
                    b: InflightBatch) -> BspResult {
        let out_dim = if b.num_layers > 0 && b.n_active > 0 {
            b.dims[b.num_layers]
        } else {
            b.f_in
        };
        let mut outputs = if self.assemble {
            vec![0f32; b.batch * plan.nv * out_dim]
        } else {
            Vec::new()
        };
        if self.assemble {
            for (j, sub) in plan.subs.iter().enumerate() {
                let n = sub.n_total();
                for bk in 0..b.batch {
                    for (row, &gid) in
                        sub.vertices[..sub.n_local].iter().enumerate()
                    {
                        let at =
                            (bk * plan.nv + gid as usize) * out_dim;
                        let from = (bk * n + row) * out_dim;
                        outputs[at..at + out_dim].copy_from_slice(
                            &b.final_states[j][from..from + out_dim],
                        );
                    }
                }
            }
        }
        BspResult {
            outputs,
            out_dim,
            layer_host_seconds: b.layer_host,
            layer_queue_wait_seconds: b.layer_wait,
            sync_bytes: b.sync_bytes,
            sync_max_out: b.sync_max_out,
            fog_vertices:
                plan.subs.iter().map(|s| s.n_local).collect(),
            fog_cardinality: plan
                .subs
                .iter()
                .map(|s| s.cardinality())
                .collect(),
        }
    }
}

/// One-shot measured batched run: extract + execute. The outputs stack
/// [batch * V, out_dim]; every block is a forward over the same
/// snapshot, so blocks are numerically identical (asserted by
/// tests/backend_parity.rs).
#[allow(clippy::too_many_arguments)]
pub fn run_parallel(
    g: &Graph,
    features: &[f32],
    f_in: usize,
    assignment: &[u32],
    n_fogs: usize,
    model: &str,
    dataset: &str,
    classes: usize,
    engine: &mut Engine,
    batch: usize,
) -> Result<BspResult, EngineError> {
    let plan = BatchedBspPlan::new(g, assignment, n_fogs, model)?;
    let wb =
        Arc::new(engine.weights(model, dataset, f_in, classes).clone());
    Ok(plan.execute(features, f_in, &wb, batch))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::runtime::{Engine, EngineKind};

    /// THE distributed-correctness invariant: a k-way BSP run must produce
    /// bit-identical outputs to the single-fog run for every model.
    #[test]
    fn distributed_equals_single_fog() {
        let (mut g, _) = generate::sbm(300, 1200, 4, 0.85, 3);
        let f_in = 8;
        let mut rng = crate::util::rng::Rng::new(9);
        g.features =
            (0..300 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = f_in;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        for model in ["gcn", "sage", "gat"] {
            let mut eng = Engine::new(EngineKind::Reference, &dir).unwrap();
            let single = run(&g, &g.features, f_in, &vec![0; 300], 1,
                             model, "tiny", 3, &mut eng)
                .unwrap();
            let assignment: Vec<u32> =
                (0..300).map(|v| (v % 3) as u32).collect();
            let multi = run(&g, &g.features, f_in, &assignment, 3, model,
                            "tiny", 3, &mut eng)
                .unwrap();
            assert_eq!(single.out_dim, multi.out_dim);
            let max_err = single
                .outputs
                .iter()
                .zip(&multi.outputs)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(
                max_err < 2e-4,
                "{model}: distributed deviates by {max_err}"
            );
        }
    }

    #[test]
    fn sync_bytes_match_exchange_plan() {
        let (mut g, _) = generate::sbm(200, 800, 4, 0.9, 5);
        let f_in = 4;
        g.features = vec![1.0; 200 * f_in];
        g.feature_dim = f_in;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Reference, &dir).unwrap();
        let assignment: Vec<u32> = (0..200).map(|v| (v % 2) as u32).collect();
        let res = run(&g, &g.features, f_in, &assignment, 2, "gcn",
                      "tiny", 3, &mut eng)
            .unwrap();
        let (_, plan) = subgraph::extract(&g, &assignment, 2);
        assert_eq!(res.sync_bytes.len(), 2); // K = 2 layers
        assert_eq!(res.sync_bytes[0], plan.total_vertices() * f_in * 4);
        // hidden dim 64 at the second boundary
        assert_eq!(res.sync_bytes[1], plan.total_vertices() * 64 * 4);
        // pairwise-parallel bottleneck is at most the total
        assert!(res.sync_max_out[0] <= res.sync_bytes[0]);
        assert!(res.sync_max_out[0] >= res.sync_bytes[0] / 2);
        assert_eq!(res.fog_vertices, vec![100, 100]);
    }

    #[test]
    fn astgcn_runs_distributed() {
        let (mut g, _) = generate::sbm(60, 200, 3, 0.8, 7);
        let ft = 36;
        let mut rng = crate::util::rng::Rng::new(11);
        g.features =
            (0..60 * ft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = ft;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Reference, &dir).unwrap();
        let assignment: Vec<u32> = (0..60).map(|v| (v % 2) as u32).collect();
        let res = run(&g, &g.features, ft, &assignment, 2, "astgcn",
                      "tinypems", 0, &mut eng)
            .unwrap();
        assert_eq!(res.out_dim, 12);
        assert!(res.outputs.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn batched_plan_serves_astgcn() {
        let (mut g, _) = generate::sbm(60, 200, 3, 0.8, 7);
        let ft = 36;
        let mut rng = crate::util::rng::Rng::new(12);
        g.features =
            (0..60 * ft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = ft;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let assignment: Vec<u32> =
            (0..60).map(|v| (v % 2) as u32).collect();
        let batch = 2;
        let res = run_parallel(&g, &g.features, ft, &assignment, 2,
                               "astgcn", "tinypems", 0, &mut eng, batch)
            .unwrap();
        assert_eq!(res.out_dim, 12);
        assert_eq!(res.outputs.len(), batch * 60 * 12);
        assert!(res.outputs.iter().all(|v| v.is_finite()));
        // one layer, one timing per fog
        assert_eq!(res.layer_host_seconds.len(), 1);
        assert_eq!(res.layer_host_seconds[0].len(), 2);
        // both blocks are the same snapshot forward
        assert_eq!(&res.outputs[..60 * 12], &res.outputs[60 * 12..]);
    }

    #[test]
    fn unknown_model_is_rejected_by_plan() {
        let (g, _) = generate::sbm(40, 120, 2, 0.8, 3);
        let assignment = vec![0u32; 40];
        let r = BatchedBspPlan::new(&g, &assignment, 1, "mlp");
        assert!(r.is_err());
        let r = BatchedBspPlan::with_threads(&g, &assignment, 1,
                                             "gcn", 0);
        assert!(r.is_err(), "0 kernel threads is rejected");
    }

    /// Two plans over different placements sharing ONE pool must each
    /// produce exactly what a private-pool plan produces — the
    /// multi-tenant plan-cache contract.
    #[test]
    fn shared_pool_plans_match_private_pool_plans() {
        let (mut g, _) = generate::sbm(200, 800, 3, 0.85, 5);
        let f_in = 8;
        let mut rng = crate::util::rng::Rng::new(31);
        g.features =
            (0..200 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = f_in;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let a2: Vec<u32> = (0..200).map(|v| (v % 2) as u32).collect();
        let a2b: Vec<u32> =
            (0..200).map(|v| ((v / 7) % 2) as u32).collect();
        let wb_g = std::sync::Arc::new(
            eng.weights("gcn", "tiny", f_in, 3).clone(),
        );
        let wb_s = std::sync::Arc::new(
            eng.weights("sage", "tiny", f_in, 3).clone(),
        );
        let base =
            BatchedBspPlan::with_threads(&g, &a2, 2, "gcn", 2).unwrap();
        let pool = base.pool_handle();
        // a second model + a different placement on the SAME pool
        let shared = BatchedBspPlan::with_shared_pool(
            &g, &a2b, 2, "sage", 2, pool.clone(),
        )
        .unwrap();
        let private =
            BatchedBspPlan::with_threads(&g, &a2b, 2, "sage", 2)
                .unwrap();
        let rb = base.execute(&g.features, f_in, &wb_g, 4);
        let rs = shared.execute(&g.features, f_in, &wb_s, 4);
        let rp = private.execute(&g.features, f_in, &wb_s, 4);
        assert_eq!(rs.outputs, rp.outputs,
                   "shared-pool plan deviates from private-pool plan");
        // interleaving plans on the pool does not cross wires
        let rb2 = base.execute(&g.features, f_in, &wb_g, 4);
        assert_eq!(rb.outputs, rb2.outputs);
        // fog-count mismatch is rejected, not a hang
        assert!(BatchedBspPlan::with_shared_pool(
            &g, &a2b, 3, "gcn", 2, pool
        )
        .is_err());
    }

    /// Intra-fog sharding must not change a single output bit:
    /// 4-wide pooled == its serial oracle == the 1-wide plan, at a
    /// batch size that genuinely shards (batch · n_local clears
    /// MIN_ROWS_PER_SHARD).
    #[test]
    fn sharded_plan_is_bit_identical_to_single_threaded() {
        let (mut g, _) = generate::sbm(300, 1200, 4, 0.85, 3);
        let f_in = 8;
        let mut rng = crate::util::rng::Rng::new(21);
        g.features =
            (0..300 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = f_in;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let assignment: Vec<u32> =
            (0..300).map(|v| (v % 3) as u32).collect();
        let batch = 8;
        for model in ["gcn", "gat"] {
            let wb = std::sync::Arc::new(
                eng.weights(model, "tiny", f_in, 3).clone(),
            );
            let p1 = BatchedBspPlan::new(&g, &assignment, 3, model)
                .unwrap();
            let p4 = BatchedBspPlan::with_threads(&g, &assignment, 3,
                                                  model, 4)
                .unwrap();
            assert_eq!(p4.kernel_threads(), 4);
            let r1 = p1.execute(&g.features, f_in, &wb, batch);
            let r4 = p4.execute(&g.features, f_in, &wb, batch);
            let rs = p4.execute_serial(&g.features, f_in, &wb, batch);
            assert_eq!(r4.outputs, rs.outputs,
                       "{model}: pooled-sharded != serial oracle");
            assert_eq!(r4.outputs, r1.outputs,
                       "{model}: sharded != single-threaded");
            // queue waits are reported apart from kernel seconds
            assert_eq!(r4.layer_queue_wait_seconds.len(),
                       r4.layer_host_seconds.len());
            assert!(r4
                .layer_queue_wait_seconds
                .iter()
                .flatten()
                .all(|&w| w >= 0.0));
            assert!(rs
                .layer_queue_wait_seconds
                .iter()
                .flatten()
                .all(|&w| w == 0.0));
        }
    }

    /// The pipelined executor must be a pure scheduling change: for
    /// every model and depth, every in-flight batch's outputs are
    /// bit-identical to the barrier executor's, and the metadata
    /// (sync bytes, layer/fog shapes) matches too.
    #[test]
    fn pipelined_executor_is_bit_identical_to_barrier() {
        let (mut g, _) = generate::sbm(240, 960, 4, 0.85, 3);
        let f_in = 8;
        let mut rng = crate::util::rng::Rng::new(41);
        g.features =
            (0..240 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = f_in;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let assignment: Vec<u32> =
            (0..240).map(|v| (v % 3) as u32).collect();
        let batch = 4;
        for model in ["gcn", "sage", "gat"] {
            let wb = std::sync::Arc::new(
                eng.weights(model, "tiny", f_in, 3).clone(),
            );
            let plan = BatchedBspPlan::with_threads(&g, &assignment, 3,
                                                    model, 2)
                .unwrap();
            let want = plan.execute(&g.features, f_in, &wb, batch);
            for depth in [1usize, 2, 4] {
                let mut pipe = BspPipeline::new(plan.n_fogs(), depth,
                                                true);
                // keep the window full, then drain: 6 batches of the
                // same snapshot exercise cross-batch overlap
                let total = 6;
                let mut got = Vec::new();
                for _ in 0..total {
                    if pipe.pending() == depth {
                        got.push(pipe.collect(&plan, None));
                    }
                    pipe.submit(&plan, &g.features, f_in, &wb, batch,
                                None);
                }
                while pipe.pending() > 0 {
                    got.push(pipe.collect(&plan, None));
                }
                assert_eq!(got.len(), total);
                for r in &got {
                    assert_eq!(r.outputs, want.outputs,
                               "{model} depth {depth}: pipelined \
                                outputs deviate from barrier");
                    assert_eq!(r.out_dim, want.out_dim);
                    assert_eq!(r.sync_bytes, want.sync_bytes);
                    assert_eq!(r.sync_max_out, want.sync_max_out);
                    assert_eq!(r.fog_vertices, want.fog_vertices);
                    assert_eq!(r.layer_host_seconds.len(),
                               want.layer_host_seconds.len());
                }
            }
        }
    }

    /// Same contract for the single-layer spatio-temporal model, whose
    /// rebuild path (full-row emission) differs from message passing.
    #[test]
    fn pipelined_executor_matches_barrier_for_astgcn() {
        let (mut g, _) = generate::sbm(60, 200, 3, 0.8, 7);
        let ft = 36;
        let mut rng = crate::util::rng::Rng::new(42);
        g.features =
            (0..60 * ft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = ft;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let assignment: Vec<u32> =
            (0..60).map(|v| (v % 2) as u32).collect();
        let wb = std::sync::Arc::new(
            eng.weights("astgcn", "tinypems", ft, 0).clone(),
        );
        let plan =
            BatchedBspPlan::new(&g, &assignment, 2, "astgcn").unwrap();
        let want = plan.execute(&g.features, ft, &wb, 2);
        let mut pipe = BspPipeline::new(plan.n_fogs(), 3, true);
        for _ in 0..3 {
            pipe.submit(&plan, &g.features, ft, &wb, 2, None);
        }
        for _ in 0..3 {
            let r = pipe.collect(&plan, None);
            assert_eq!(r.outputs, want.outputs,
                       "astgcn pipelined outputs deviate");
            assert_eq!(r.out_dim, want.out_dim);
        }
    }
}
